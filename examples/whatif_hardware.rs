//! "What-if" hardware exploration (paper §2.1: the predictor "can estimate
//! the application performance on hardware that has not yet been
//! procured (e.g., … what would be the performance improvement if we used
//! SSDs?)") — only an explanatory model supports this.
//!
//! ```sh
//! cargo run --release --example whatif_hardware
//! ```

use wfpred::model::{Config, Platform};
use wfpred::predict::Predictor;
use wfpred::util::table::Table;
use wfpred::util::units::Bytes;
use wfpred::workload::blast::{blast, BlastParams};
use wfpred::workload::patterns::{pipeline, reduce, PatternScale};
use wfpred::workload::Workload;

fn main() {
    let platforms = [
        Platform::paper_testbed_hdd(),
        Platform::paper_testbed_ssd(),
        Platform::paper_testbed(), // RAMdisk
        Platform::paper_testbed_10g(),
    ];

    let scenarios: Vec<(&str, Workload, Config)> = vec![
        ("pipeline medium DSS", pipeline(19, PatternScale::Medium, false), Config::dss(19)),
        ("reduce large WASS", reduce(19, PatternScale::Large, true), Config::wass(19)),
        ("BLAST 14app/5sto 256KB", blast(14, &BlastParams::default()), Config::partitioned(14, 5, Bytes::kb(256))),
    ];

    println!("what-if: the same workloads on hardware we don't have\n");
    let mut t = Table::new(&["workload", "HDD", "SSD", "RAMdisk", "RAM+10GbE"]);
    for (name, wl, cfg) in &scenarios {
        let mut cells = vec![name.to_string()];
        for plat in &platforms {
            let p = Predictor::new(plat.clone()).predict(wl, cfg);
            cells.push(format!("{:.1}s", p.turnaround.as_secs_f64()));
        }
        t.row(&cells);
    }
    print!("{}", t.render());

    println!("\nreadings:");
    println!("  * the I/O-bound synthetic patterns gain dramatically from faster media");
    println!("    and the 10 GbE fabric;");
    println!("  * BLAST is compute-bound at the good partitioning — new storage hardware");
    println!("    barely moves it (buy CPUs, not SSDs, for this workload);");
    println!("  * exactly the provisioning guidance the paper's predictor is for (§2.1).");
}
