//! The three workflow data-access patterns (paper §3.1, Fig 3) measured
//! on the emulated testbed ("actual") and predicted by the queue model —
//! a compact replay of Figures 4–6.
//!
//! ```sh
//! cargo run --release --example pipeline_patterns
//! ```

use wfpred::model::{simulate, Config, Placement, Platform};
use wfpred::testbed::Testbed;
use wfpred::util::table::Table;
use wfpred::workload::patterns::{broadcast, pipeline, reduce, PatternScale};
use wfpred::workload::Workload;

fn main() {
    let tb = Testbed::new(Platform::paper_testbed()).with_trials(6, 10);
    let mut t = Table::new(&["benchmark", "config", "actual (s)", "predicted (s)"]);

    let mut add = |name: &str, wl: &Workload, cfg: &Config| {
        let actual = tb.run(wl, cfg);
        let pred = simulate(wl, cfg, &tb.platform);
        t.row(&[
            name.to_string(),
            cfg.label.clone(),
            format!("{:.2} ± {:.2}", actual.mean(), actual.std()),
            format!("{:.2}", pred.turnaround.as_secs_f64()),
        ]);
    };

    let n = 19;
    add("pipeline medium", &pipeline(n, PatternScale::Medium, false), &Config::dss(n));
    add("pipeline medium", &pipeline(n, PatternScale::Medium, true), &Config::wass(n));
    add("reduce   medium", &reduce(n, PatternScale::Medium, false), &Config::dss(n));
    add("reduce   medium", &reduce(n, PatternScale::Medium, true), &Config::wass(n));
    add("reduce   large ", &reduce(n, PatternScale::Large, false), &Config::dss(n));
    add("reduce   large ", &reduce(n, PatternScale::Large, true), &Config::wass(n));
    for r in [1u32, 2, 4] {
        let mut cfg = Config::wass(n).with_label(format!("WASS r={r}"));
        cfg.placement = Placement::RoundRobin;
        add("broadcast medium", &broadcast(n, PatternScale::Medium, r), &cfg);
    }

    println!("synthetic workflow patterns — actual (testbed, mean ± std) vs predicted:\n");
    print!("{}", t.render());
    println!("\nreadings:");
    println!("  * pipeline/reduce: the workflow-aware configuration wins (Figs 4-5);");
    println!("  * broadcast: replication levels are equivalent — striping already");
    println!("    spreads the load, so one replica saves storage (Fig 6);");
    println!("  * predictions track every choice correctly.");
}
