//! End-to-end driver (DESIGN.md §6, EXPERIMENTS.md §E2E): the full system
//! on a real small workload, proving all layers compose.
//!
//! 1. **Real bytes**: spawn the in-tree TCP object store (manager + 5
//!    storage nodes on loopback), stage a scaled-down BLAST database, and
//!    execute the BLAST I/O workload with real reads/writes, measuring
//!    wallclock.
//! 2. **System identification** (§2.5) against that store.
//! 3. **Provisioning search** (paper §3.2, scenarios I & II): AOT analytic
//!    prescreen through PJRT (L1/L2 artifact) + discrete-event refinement,
//!    answering the paper's questions — best partitioning, best chunk
//!    size, cost/performance trade-off.
//! 4. **§3.3 speedup accounting**: predictor cost vs the real run.
//!
//! ```sh
//! make artifacts && cargo run --release --example blast_provisioning
//! ```

use wfpred::ident::{identify, CampaignCfg, IdentConfig};
use wfpred::model::Platform;
use wfpred::predict::Predictor;
use wfpred::runtime::{ScorerRuntime, StageDesc};
use wfpred::search::{SearchSpace, Searcher};
use wfpred::store::{Cluster, StorePlacement};
use wfpred::util::table::Table;
use wfpred::util::units::Bytes;
use wfpred::workload::blast::{blast, BlastParams};
use std::time::Instant;

/// Scaled-down BLAST: 1/64 of the RefSeq database, 4 workers, real bytes.
fn run_real_blast() -> (f64, u64) {
    println!("== 1. real workload on the in-tree TCP store ==");
    let n_app = 4usize;
    let n_storage = 5usize;
    let db_bytes = (1.67 * (1u64 << 30) as f64 / 64.0) as usize; // ~26 MB
    let cl = Cluster::start(n_storage).expect("cluster");

    // Stage the database (prestaged in the paper: "we assume the database
    // is already loaded in intermediate storage").
    let mut stager = cl.client().unwrap().with_chunk_size(256 * 1024);
    let db: Vec<u8> = (0..db_bytes).map(|i| (i as u32).wrapping_mul(2654435761).to_le_bytes()[1]).collect();
    stager.write("refseq.db", &db).unwrap();
    for w in 0..n_app {
        let mut c = cl
            .client()
            .unwrap()
            .with_chunk_size(256 * 1024)
            .with_placement(StorePlacement::OnNode { node: w as u32 });
        c.write(&format!("queries.{w}"), &vec![b'A'; 64 * 1024]).unwrap();
    }

    // Run the workload: every worker reads the full DB + its query file,
    // "searches" (checksums — the storage system only sees the I/O), and
    // writes its result file.
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_app)
        .map(|w| {
            let addr = cl.manager.addr.clone();
            std::thread::spawn(move || {
                let mut c = wfpred::store::StoreClient::connect(&addr)
                    .unwrap()
                    .with_chunk_size(256 * 1024);
                let db = c.read("refseq.db").unwrap();
                let queries = c.read(&format!("queries.{w}")).unwrap();
                // Stand-in for sequence search: a pass over the data.
                let mut acc = 0u64;
                for chunk in db.chunks(8) {
                    acc = acc.wrapping_add(chunk.iter().map(|&b| b as u64).sum());
                }
                acc = acc.wrapping_add(queries.len() as u64);
                let result = format!("worker {w} score {acc}\n").repeat(2000);
                c.write(&format!("result.{w}"), result.as_bytes()).unwrap();
                (db.len(), acc)
            })
        })
        .collect();
    let mut total_read = 0usize;
    for h in handles {
        let (n, _) = h.join().unwrap();
        total_read += n;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "  {n_app} workers × {:.1} MB DB (striped over {n_storage} nodes, 256 KB chunks)",
        db_bytes as f64 / 1e6
    );
    println!("  moved {:.1} MB in {wall:.2}s — all bytes over real TCP loopback", total_read as f64 / 1e6);
    println!("  stored total: {:.1} MB across nodes\n", cl.stored_total() as f64 / 1e6);
    (wall, total_read as u64)
}

fn main() {
    let (real_wall, _) = run_real_blast();

    println!("== 2. system identification (paper §2.5) ==");
    let ident_cfg = IdentConfig {
        file_size: Bytes::mb(4),
        chunk_size: Bytes::kb(256),
        probe_size: Bytes::mb(4),
        campaign: CampaignCfg { rel_accuracy: 0.1, min_samples: 4, max_samples: 20 },
    };
    let id = identify(&ident_cfg).expect("identification");
    println!("{}\n", id.summary());

    println!("== 3. provisioning search (paper §3.2, scenarios I & II) ==");
    // The production question is posed for the paper's 20-node testbed;
    // the platform profile carries the 1 Gbps-era service times.
    let plat = Platform::paper_testbed();
    let predictor = Predictor::new(plat.clone());
    let params = BlastParams::default();
    let stages = vec![StageDesc {
        tasks_per_app: true,
        tasks_fixed: 0.0,
        read_mb: params.db_size.as_f64() as f32 / (1u64 << 20) as f32,
        read_local_frac: 0.0,
        write_mb: params.output_file.as_f64() as f32 / (1u64 << 20) as f32,
        fan_single: false,
        compute_total_s: params.queries as f32 * params.per_query.as_secs_f64() as f32,
    }];
    let rt = ScorerRuntime::load_default().ok();
    if rt.is_none() {
        println!("  (no AOT artifact — run `make artifacts` for the L1/L2 prescreen)");
    }
    let t0 = Instant::now();
    let space = SearchSpace::elastic(
        vec![11, 17, 20],
        vec![Bytes::kb(256), Bytes::mb(1), Bytes::mb(4)],
    );
    let mut searcher = Searcher::new(&predictor).with_top_k(10);
    if let Some(rt) = rt.as_ref() {
        searcher = searcher.with_runtime(rt);
    }
    let report = searcher.search(&space, &stages, |cfg| blast(cfg.n_app, &params));
    let search_wall = t0.elapsed().as_secs_f64();

    println!(
        "  explored {} configurations ({} pruned by the AOT analytic prescreen) in {search_wall:.2}s",
        report.candidates.len(),
        report.pruned
    );
    let show = |what: &str, i: usize| {
        let c = &report.candidates[i];
        println!(
            "  {what:<24} {:<28} time {:>7.1}s  cost {:>8.0} node-s",
            c.config.label,
            c.time_s(),
            c.cost_node_s()
        );
    };
    show("best performance:", report.best_time);
    show("lowest cost:", report.best_cost);
    show("most cost-efficient:", report.best_efficiency);

    println!("\n  pareto front (time/cost trade-off, scenario II):");
    let mut t = Table::new(&["config", "time (s)", "cost (node-s)"]);
    for &i in &report.pareto {
        let c = &report.candidates[i];
        t.row(&[c.config.label.clone(), format!("{:.1}", c.time_s()), format!("{:.0}", c.cost_node_s())]);
    }
    for line in t.render().lines() {
        println!("  {line}");
    }

    println!("\n== 4. §3.3 accounting ==");
    let best = &report.candidates[report.best_time];
    let per_pred = best
        .refined
        .as_ref()
        .map(|p| p.predictor_wallclock_secs)
        .unwrap_or(search_wall / report.candidates.len() as f64);
    println!("  one DES prediction: {:.0} ms on one core", per_pred * 1e3);
    println!(
        "  an actual 20-node run of the best config would occupy the cluster for {:.0}s",
        best.time_s()
    );
    println!(
        "  -> {:.0}x faster, {:.0}x fewer node-seconds (paper claims 10-100x / 200-2000x)",
        best.time_s() / per_pred,
        best.time_s() / per_pred * best.config.n_hosts() as f64
    );
    println!(
        "  (scaled-down real-bytes run above took {real_wall:.2}s of wallclock for 1/64 of the DB on 1 host)"
    );
}
