//! Quickstart: predict a workflow's turnaround under two storage
//! configurations and pick the better one.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use wfpred::prelude::*;

fn main() {
    // 1. A platform characterization. Normally this comes from system
    //    identification (`wfpred identify`); here we use the built-in
    //    profile of the paper's 20-node / 1 Gbps / RAMdisk testbed.
    let platform = Platform::paper_testbed();

    // 2. A workload: 19 three-stage pipelines (the paper's synthetic
    //    pipeline benchmark, medium scale). `true` adds the workflow-aware
    //    placement hints.
    let dss_workload = patterns::pipeline(19, PatternScale::Medium, false);
    let wass_workload = patterns::pipeline(19, PatternScale::Medium, true);

    // 3. Two candidate configurations for the same 19 dual-role nodes.
    let dss = Config::dss(19);
    let wass = Config::wass(19);

    // 4. Predict.
    let predictor = Predictor::new(platform);
    let p_dss = predictor.predict(&dss_workload, &dss);
    let p_wass = predictor.predict(&wass_workload, &wass);

    println!("pipeline benchmark (medium), 19 nodes + manager:");
    println!("  DSS  (striped everywhere):   {}", p_dss.turnaround);
    println!("  WASS (local placement):      {}", p_wass.turnaround);
    for (s, (a, b)) in p_dss.stage_times.iter().zip(&p_wass.stage_times).enumerate() {
        println!("    stage {s}:  DSS {a}   WASS {b}");
    }
    let speedup = p_dss.turnaround.as_secs_f64() / p_wass.turnaround.as_secs_f64();
    println!("  -> workflow-aware placement wins by {speedup:.1}x");
    println!(
        "  (predictor cost: {:.0} ms on one core vs occupying 20 nodes for a real run)",
        (p_dss.predictor_wallclock_secs + p_wass.predictor_wallclock_secs) * 1e3
    );
}
