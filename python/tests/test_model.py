"""L2 model sanity: monotonicity and decision-relevant behaviours of the
analytic scorer (ranking is what the search consumes)."""

import numpy as np

from compile.kernels.ref import score_configs_ref
from compile.kernels.queue_model import LANE, score_configs
from compile.model import lower_for_export, EXPORT_BATCH


def paper_platform():
    return np.array(
        [117.5e6, 600e6, 0.9, 0.75, 230e-6, 90e-6, 60e-6, 0.0], dtype=np.float32
    )


def blast_stage(db_mb=1710.0, out_mb=5.0, compute_total=2000.0):
    s = np.zeros((1, 8), dtype=np.float32)
    s[0] = [1, 0, db_mb, 0.0, out_mb, 0, compute_total, 1]
    return s


def partition_configs(chunk_mb=0.25):
    """19 nodes split n_app/19-n_app, one column per partitioning."""
    cfg = np.zeros((8, LANE), dtype=np.float32)
    for i, n_app in enumerate(range(1, 19)):
        cfg[:, i] = [n_app, 19 - n_app, 19 - n_app, 1, chunk_mb, 0, 8, 0]
    return cfg


def test_blast_partitioning_interior_optimum():
    cfg = partition_configs()
    out = np.asarray(score_configs(cfg, blast_stage(), paper_platform()))
    times = out[0, :18]
    best = int(np.argmin(times)) + 1  # n_app of the best column
    assert 5 <= best <= 17, f"interior optimum expected, got n_app={best}"
    assert times[0] > 2.0 * times[best - 1], "1-app edge should be much slower"


def test_more_storage_never_hurts_io_bound_stage():
    # Pure-IO stage (no compute): adding storage nodes at fixed app count
    # must not increase the estimate.
    plat = paper_platform()
    stage = blast_stage(compute_total=0.0)
    cfg = np.zeros((8, LANE), dtype=np.float32)
    for i, n_sto in enumerate(range(1, 20)):
        cfg[:, i] = [10, n_sto, n_sto, 1, 1.0, 0, 8, 0]
    out = np.asarray(score_configs_ref(cfg, stage, plat))
    t = out[0, :19]
    assert np.all(np.diff(t) <= 1e-6), f"not monotone: {t}"


def test_replication_increases_write_cost():
    plat = paper_platform()
    stage = np.zeros((1, 8), dtype=np.float32)
    stage[0] = [0, 19, 0.0, 0.0, 100.0, 0, 0.0, 1]  # pure write stage
    cfg = np.zeros((8, LANE), dtype=np.float32)
    for i, r in enumerate([1, 2, 4]):
        cfg[:, i] = [19, 19, 19, r, 1.0, 1, 8, 0]
    out = np.asarray(score_configs_ref(cfg, stage, plat))
    t = out[0, :3]
    assert t[0] < t[1] < t[2], f"replication should cost: {t}"


def test_incast_fan_in_slower_than_striped():
    plat = paper_platform()
    striped = np.zeros((1, 8), dtype=np.float32)
    striped[0] = [0, 19, 0.0, 0.0, 100.0, 0, 0.0, 1]
    fan = striped.copy()
    fan[0, 5] = 1  # single-node fan-in
    cfg = np.zeros((8, LANE), dtype=np.float32)
    cfg[:, 0] = [19, 19, 19, 1, 1.0, 1, 8, 0]
    t_striped = np.asarray(score_configs_ref(cfg, striped, plat))[0, 0]
    t_fan = np.asarray(score_configs_ref(cfg, fan, plat))[0, 0]
    assert t_fan > 2.0 * t_striped


def test_faster_network_never_slower():
    rng = np.random.default_rng(3)
    from tests.test_kernel import random_inputs

    cfg, stages, plat = random_inputs(rng, LANE, 3)
    slow = np.asarray(score_configs_ref(cfg, stages, plat))
    plat2 = plat.copy()
    plat2[0] *= 10.0  # 10× remote bandwidth
    plat2[1] *= 10.0
    fast = np.asarray(score_configs_ref(cfg, stages, plat2))
    assert np.all(fast[0] <= slow[0] + 1e-6)


def test_export_lowering_shapes():
    lowered = lower_for_export()
    text = lowered.as_text()
    assert f"8x{EXPORT_BATCH}" in text.replace(" ", "") or "tensor<8x4096xf32>" in text
