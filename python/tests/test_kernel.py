"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

The core signal: `score_configs` (Pallas, interpret=True) must match
`score_configs_ref` to 1e-5 across randomized inputs, shapes and stage
counts — including hypothesis-driven sweeps.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.queue_model import score_configs, LANE
from compile.kernels.ref import score_configs_ref


def random_inputs(rng, batch, n_stages):
    """Plausible random configs/stages/platform."""
    cfg = np.zeros((8, batch), dtype=np.float32)
    cfg[0] = rng.integers(1, 32, batch)  # n_app
    cfg[1] = rng.integers(1, 32, batch)  # n_storage
    cfg[2] = np.minimum(rng.integers(1, 32, batch), cfg[1])  # stripe
    cfg[3] = rng.integers(1, 4, batch)  # repl
    cfg[4] = rng.choice([0.25, 1.0, 4.0, 16.0], batch)  # chunk MiB
    cfg[5] = rng.integers(0, 2, batch)  # collocated
    cfg[6] = rng.choice([1, 4, 8, 16], batch)  # window
    stages = np.zeros((n_stages, 8), dtype=np.float32)
    stages[:, 0] = rng.integers(0, 2, n_stages)  # tasks_mode
    stages[:, 1] = rng.integers(1, 64, n_stages)  # tasks_fixed
    stages[:, 2] = rng.uniform(0, 2000, n_stages)  # read_mb
    stages[:, 3] = rng.uniform(0, 1, n_stages)  # read_local
    stages[:, 4] = rng.uniform(0, 500, n_stages)  # write_mb
    stages[:, 5] = rng.integers(0, 2, n_stages)  # write_fan
    stages[:, 6] = rng.uniform(0, 2000, n_stages)  # compute_total
    stages[:, 7] = rng.integers(0, 2, n_stages)  # active
    plat = np.array(
        [
            rng.uniform(50e6, 10e9),  # net_bps
            rng.uniform(100e6, 20e9),  # local_bps
            rng.uniform(0.1, 20.0),  # sm_write ns/B
            rng.uniform(0.1, 20.0),  # sm_read ns/B
            rng.uniform(1e-5, 1e-3),  # manager_op s
            rng.uniform(1e-5, 5e-4),  # latency s
            rng.uniform(1e-5, 5e-4),  # storage_op s
            0.0,
        ],
        dtype=np.float32,
    )
    return cfg, stages, plat


@pytest.mark.parametrize("batch", [LANE, 2 * LANE, 8 * LANE])
@pytest.mark.parametrize("n_stages", [1, 3, 6])
def test_kernel_matches_ref(batch, n_stages):
    rng = np.random.default_rng(batch * 31 + n_stages)
    cfg, stages, plat = random_inputs(rng, batch, n_stages)
    got = np.asarray(score_configs(cfg, stages, plat))
    want = np.asarray(score_configs_ref(cfg, stages, plat))
    assert got.shape == (2, batch)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tiles=st.integers(1, 4),
    n_stages=st.integers(1, 6),
)
def test_kernel_matches_ref_hypothesis(seed, tiles, n_stages):
    rng = np.random.default_rng(seed)
    cfg, stages, plat = random_inputs(rng, tiles * LANE, n_stages)
    got = np.asarray(score_configs(cfg, stages, plat))
    want = np.asarray(score_configs_ref(cfg, stages, plat))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_inactive_stages_contribute_zero():
    rng = np.random.default_rng(7)
    cfg, stages, plat = random_inputs(rng, LANE, 4)
    stages[:, 7] = 0.0  # all inactive
    got = np.asarray(score_configs(cfg, stages, plat))
    np.testing.assert_array_equal(got, np.zeros_like(got))


def test_non_multiple_of_lane_rejected():
    rng = np.random.default_rng(9)
    cfg, stages, plat = random_inputs(rng, LANE, 2)
    with pytest.raises(AssertionError):
        score_configs(cfg[:, : LANE - 1], stages, plat)


def test_outputs_finite_and_nonnegative():
    rng = np.random.default_rng(11)
    cfg, stages, plat = random_inputs(rng, 4 * LANE, 6)
    got = np.asarray(score_configs(cfg, stages, plat))
    assert np.all(np.isfinite(got))
    assert np.all(got >= 0.0)
    # cost = time × nodes ≥ time (nodes ≥ 1)
    assert np.all(got[1] >= got[0] - 1e-6)
