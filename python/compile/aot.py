"""AOT export: lower the L2 predictor to HLO *text* for the rust runtime.

HLO text — not `HloModuleProto.serialize()` — is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled XLA (xla_extension 0.5.1) rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly. Lowered with
`return_tuple=True`; the rust side unwraps with `to_tuple1()`.

Run once via `make artifacts`; python never runs on the request path.

Usage: python -m compile.aot --out ../artifacts/predictor.hlo.txt
"""

import argparse
import os

from jax._src.lib import xla_client as xc

from compile.model import EXPORT_BATCH, EXPORT_STAGES, lower_for_export


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/predictor.hlo.txt")
    args = ap.parse_args()

    lowered = lower_for_export()
    text = to_hlo_text(lowered)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)
    # ABI sidecar so the rust runtime can check shapes without parsing HLO.
    with open(args.out + ".meta", "w") as f:
        f.write(f"batch {EXPORT_BATCH}\nstages {EXPORT_STAGES}\n")
    print(f"wrote {len(text)} chars to {args.out} (B={EXPORT_BATCH}, S={EXPORT_STAGES})")


if __name__ == "__main__":
    main()
