"""L2 model: the batched configuration scorer the rust coordinator calls.

Wraps the L1 Pallas kernel (`kernels.queue_model`) into the jitted
function that `aot.py` lowers to the AOT artifact. The function signature
is the artifact ABI (shapes are static at export time):

    predictor(cfg: f32[8, B], stages: f32[S, 8], plat: f32[8]) -> f32[2, B]

Rust (`rust/src/runtime`) feeds the same layouts (see
`python/compile/kernels/ref.py` for field meaning) and reads back
(time, cost) per configuration. Padding conventions: unused batch columns
carry zeros (scored as garbage, ignored by the caller); unused stage rows
have active=0 and contribute exactly zero.
"""

import jax

from compile.kernels.queue_model import score_configs
from compile.kernels.ref import score_configs_ref

# Artifact ABI constants (DESIGN.md §8): 4096 configs, up to 6 stages.
EXPORT_BATCH = 4096
EXPORT_STAGES = 6


def predictor(cfg, stages, plat):
    """Score a batch of configurations (the exported computation)."""
    return score_configs(cfg, stages, plat)


def predictor_ref(cfg, stages, plat):
    """Pure-jnp twin of `predictor` (testing / what-if exploration)."""
    return score_configs_ref(cfg, stages, plat)


def lower_for_export():
    """Lower the jitted predictor at the export shapes."""
    spec_cfg = jax.ShapeDtypeStruct((8, EXPORT_BATCH), jax.numpy.float32)
    spec_stages = jax.ShapeDtypeStruct((EXPORT_STAGES, 8), jax.numpy.float32)
    spec_plat = jax.ShapeDtypeStruct((8,), jax.numpy.float32)
    return jax.jit(predictor).lower(spec_cfg, spec_stages, spec_plat)
