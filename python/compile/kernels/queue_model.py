"""L1 Pallas kernel: batched analytic configuration scoring.

The hot-spot of the configuration-space search is scoring thousands of
candidate deployments; this kernel evaluates one `(8, 128)` tile of
configurations per grid step, with the whole stage descriptor and platform
vector resident in VMEM.

TPU mapping (DESIGN.md §Hardware-Adaptation): the config matrix is laid
out fields-by-configs, so a tile is exactly one `(8, 128)`
sublane × lane VMEM register page; the per-stage loop is unrolled at trace
time (S is static); all math is elementwise VPU work — there is no matmul,
so the roofline is VPU/bandwidth-bound. `interpret=True` is mandatory
here: the CPU PJRT plugin cannot execute Mosaic custom-calls, and the AOT
artifact must run inside the rust coordinator on CPU.

Correctness: pytest asserts this kernel matches `ref.score_configs_ref`
to 1e-5 over randomized batches (including hypothesis-generated shapes).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MIB = float(1 << 20)

LANE = 128  # configs per tile (TPU lane width)


def _stage_time_tile(cfg, stage, plat):
    """Stage-time math for one (8, LANE) config tile.

    Mirrors ref.stage_time exactly, operating on a tile. `stage` and
    `plat` are loaded (8,) vectors; scalars are extracted at trace time
    via static indexing.
    """
    n_app = jnp.maximum(cfg[0, :], 1.0)
    n_sto = jnp.maximum(cfg[1, :], 1.0)
    stripe = jnp.clip(cfg[2, :], 1.0, cfg[1, :])
    repl = jnp.maximum(cfg[3, :], 1.0)
    chunk_mb = jnp.maximum(cfg[4, :], 1.0 / 1024.0)
    window = jnp.maximum(cfg[6, :], 1.0)

    net = plat[0]
    local = plat[1]
    sm_w = plat[2] * 1e-9
    sm_r = plat[3] * 1e-9
    man_op = plat[4]
    lat = plat[5]
    sto_op = plat[6]

    tasks = jnp.where(stage[0] > 0.5, n_app, stage[1])
    tasks = jnp.maximum(tasks, 0.0)
    waves = jnp.ceil(tasks / n_app)
    servers = jnp.maximum(jnp.minimum(tasks, n_app), 1.0)

    read_b = stage[2] * MIB
    local_frac = stage[3]
    write_b = stage[4] * MIB
    fan_single = stage[5] > 0.5
    compute_total = stage[6]

    remote_read = read_b * (1.0 - local_frac)
    local_read = read_b * local_frac
    read_bw = jnp.minimum(net, n_sto * net / jnp.maximum(tasks, 1.0))
    t_serial = remote_read / read_bw + local_read / local + write_b / net
    chunks = (read_b + write_b) / (chunk_mb * MIB)
    t_overhead = chunks * (2.0 * lat + sto_op) / window
    per_task_compute = jnp.where(
        tasks > 0.0, compute_total / jnp.maximum(tasks, 1.0), 0.0
    )
    t_client = waves * (t_serial + t_overhead + per_task_compute)

    t_read_nic = tasks * remote_read / (n_sto * net)
    write_targets = jnp.where(fan_single, 1.0, stripe)
    t_write_nic = tasks * write_b * repl / (write_targets * net)
    t_sm_read = tasks * read_b * sm_r / n_sto
    t_sm_write = tasks * write_b * repl * sm_w / write_targets
    t_man = tasks * 4.0 * man_op
    t_compute = compute_total / servers

    t = jnp.maximum(t_client, t_read_nic)
    t = jnp.maximum(t, t_write_nic)
    t = jnp.maximum(t, t_sm_read + t_sm_write)
    t = jnp.maximum(t, t_man)
    t = jnp.maximum(t, t_compute)
    active = stage[7] > 0.5
    return jnp.where(active & (tasks > 0.0), t, 0.0)


def _kernel(n_stages, cfg_ref, stages_ref, plat_ref, out_ref):
    """One grid step: score a (8, LANE) tile of configurations."""
    cfg = cfg_ref[...]
    plat = plat_ref[...]
    total = jnp.zeros((cfg.shape[1],), dtype=jnp.float32)
    for s in range(n_stages):  # static unroll — S is fixed at trace time
        total = total + _stage_time_tile(cfg, stages_ref[s, :], plat)
    nodes = jnp.where(cfg[5, :] > 0.5, jnp.maximum(cfg[0, :], cfg[1, :]), cfg[0, :] + cfg[1, :]) + 1.0
    out_ref[0, :] = total
    out_ref[1, :] = total * nodes


def score_configs(cfg, stages, plat):
    """Pallas scorer: (8, B) × (S, 8) × (8,) → (2, B). B must be a
    multiple of LANE (pad with dummy columns)."""
    cfg = jnp.asarray(cfg, dtype=jnp.float32)
    stages = jnp.asarray(stages, dtype=jnp.float32)
    plat = jnp.asarray(plat, dtype=jnp.float32)
    f, b = cfg.shape
    assert f == 8, f"config matrix must be (8, B), got {cfg.shape}"
    assert b % LANE == 0, f"batch {b} must be a multiple of {LANE}"
    s, sf = stages.shape
    assert sf == 8, f"stage matrix must be (S, 8), got {stages.shape}"

    grid = (b // LANE,)
    return pl.pallas_call(
        functools.partial(_kernel, s),
        grid=grid,
        in_specs=[
            # One (8, LANE) tile of configs per grid step.
            pl.BlockSpec((8, LANE), lambda i: (0, i)),
            # Whole stage descriptor + platform in VMEM every step.
            pl.BlockSpec((s, 8), lambda i: (0, 0)),
            pl.BlockSpec((8,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((2, LANE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((2, b), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(cfg, stages, plat)
