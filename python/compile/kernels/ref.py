"""Pure-jnp oracle for the analytic configuration-scoring kernel.

This is the L1 correctness reference: `queue_model.py` (the Pallas kernel)
must reproduce these numbers exactly (same ops, same dtype); pytest
asserts allclose at 1e-5 across randomized batches.

The model scores a *batch* of candidate deployments for a multi-stage
workflow with a closed-form bottleneck analysis — a vectorized version of
the back-of-envelope the paper's predictor replaces (§5 "back of the
envelope calculations are a common mechanism…"). The search layer uses it
to prune the configuration space before refining the top candidates with
the discrete-event predictor; only *ranking* quality matters (DESIGN.md
§8).

Input layout (all float32):
  cfg   (8, B): per-config columns
        row 0  n_app          application nodes
        row 1  n_storage      storage nodes
        row 2  stripe         stripe width
        row 3  repl           replication level
        row 4  chunk_mb       chunk size in MiB
        row 5  collocated     0/1 — app and storage share hosts
        row 6  io_window      outstanding chunk requests per op
        row 7  (reserved)
  stages (S, 8): per-stage columns
        col 0  tasks_mode     0 = fixed count, 1 = one task per app node
        col 1  tasks_fixed    task count when tasks_mode = 0
        col 2  read_mb        per-task bytes read (MiB)
        col 3  read_local     fraction of reads served from the local node
        col 4  write_mb       per-task bytes written (MiB)
        col 5  write_fan      0 = striped, 1 = all to a single node
        col 6  compute_total  total compute seconds across the stage
        col 7  active         1 = stage exists
  plat  (8,): net_bps, local_bps, sm_write_ns_per_byte, sm_read_ns_per_byte,
        manager_op_s, latency_s, storage_op_s, (reserved)

Output (2, B): row 0 = estimated makespan (s), row 1 = cost (node-seconds).
"""

import jax.numpy as jnp

MIB = float(1 << 20)


def stage_time(cfg, stage, plat):
    """Closed-form makespan estimate of one stage for every config.

    cfg: (8, B); stage: (8,) one row of the stage matrix; plat: (8,).
    Returns (B,) stage time in seconds.
    """
    n_app = jnp.maximum(cfg[0], 1.0)
    n_sto = jnp.maximum(cfg[1], 1.0)
    stripe = jnp.clip(cfg[2], 1.0, cfg[1])
    repl = jnp.maximum(cfg[3], 1.0)
    chunk_mb = jnp.maximum(cfg[4], 1.0 / 1024.0)
    window = jnp.maximum(cfg[6], 1.0)

    net = plat[0]
    local = plat[1]
    sm_w = plat[2] * 1e-9  # s per byte
    sm_r = plat[3] * 1e-9
    man_op = plat[4]
    lat = plat[5]
    sto_op = plat[6]

    tasks = jnp.where(stage[0] > 0.5, n_app, stage[1])
    tasks = jnp.maximum(tasks, 0.0)
    waves = jnp.ceil(tasks / n_app)
    servers = jnp.maximum(jnp.minimum(tasks, n_app), 1.0)

    read_b = stage[2] * MIB
    local_frac = stage[3]
    write_b = stage[4] * MIB
    fan_single = stage[5] > 0.5
    compute_total = stage[6]

    # --- per-task serial path (client viewpoint) ---
    remote_read = read_b * (1.0 - local_frac)
    local_read = read_b * local_frac
    # Remote reads run at the fair share of the storage-side aggregate
    # when it is below the client NIC rate (tasks contend for n_sto NICs).
    read_bw = jnp.minimum(net, n_sto * net / jnp.maximum(tasks, 1.0))
    # Writes leave the client once (chained replication downstream).
    t_serial = remote_read / read_bw + local_read / local + write_b / net
    # Per-chunk round-trip overhead, pipelined over the window.
    chunks = (read_b + write_b) / (chunk_mb * MIB)
    t_overhead = chunks * (2.0 * lat + sto_op) / window
    per_task_compute = jnp.where(
        tasks > 0.0, compute_total / jnp.maximum(tasks, 1.0), 0.0
    )
    t_client = waves * (t_serial + t_overhead + per_task_compute)

    # --- aggregate resource bottlenecks ---
    t_read_nic = tasks * remote_read / (n_sto * net)
    write_targets = jnp.where(fan_single, 1.0, stripe)
    t_write_nic = tasks * write_b * repl / (write_targets * net)
    t_sm_read = tasks * read_b * sm_r / n_sto
    t_sm_write = tasks * write_b * repl * sm_w / write_targets
    # Manager: ~4 metadata ops per task (alloc, commit, lookup, ack).
    t_man = tasks * 4.0 * man_op
    t_compute = compute_total / servers

    t = jnp.maximum(t_client, t_read_nic)
    t = jnp.maximum(t, t_write_nic)
    t = jnp.maximum(t, t_sm_read + t_sm_write)
    t = jnp.maximum(t, t_man)
    t = jnp.maximum(t, t_compute)
    active = stage[7] > 0.5
    return jnp.where(active & (tasks > 0.0), t, 0.0)


def score_configs_ref(cfg, stages, plat):
    """Reference scorer: (8, B), (S, 8), (8,) → (2, B)."""
    cfg = cfg.astype(jnp.float32)
    stages = stages.astype(jnp.float32)
    plat = plat.astype(jnp.float32)
    total = jnp.zeros(cfg.shape[1], dtype=jnp.float32)
    for s in range(stages.shape[0]):
        total = total + stage_time(cfg, stages[s], plat)
    nodes = jnp.where(cfg[5] > 0.5, jnp.maximum(cfg[0], cfg[1]), cfg[0] + cfg[1]) + 1.0
    cost = total * nodes
    return jnp.stack([total, cost], axis=0)
