//! Virtual clock + event queue.
//!
//! Events are an application-defined type `Ev`; the application state
//! implements [`SimState::handle`], which receives each event in
//! timestamp order (FIFO among equal timestamps, enforced by a sequence
//! number) together with a [`Scheduler`] for scheduling follow-up events.
//!
//! ## Slab-backed entries and cancellation
//!
//! Event payloads live in a slab arena whose slots are recycled through a
//! free list, so the steady-state frame path allocates nothing per event
//! (the heap itself holds small plain-data keys). The arena also gives
//! events an identity: [`Scheduler::at_cancellable`] returns an
//! [`EventToken`] and [`Scheduler::cancel`] retires the event in O(1)
//! without touching the heap — the dead key is skipped for the cost of a
//! slab-generation compare when it eventually surfaces. This is what lets
//! the weighted-fair NIC stations withdraw a superseded completion
//! announcement instead of delivering a stale event to the model
//! (`model/engine.rs`; the cancelled count is reported as
//! `SimReport::events_cancelled`).

use crate::util::units::SimTime;
use std::collections::BinaryHeap;

/// An event-queue key: min-heap by (time, seq). The payload stays in the
/// slab; `seq` doubles as the slot generation (it is unique per scheduled
/// event, so a key whose `seq` no longer matches its slot is dead).
#[derive(Clone)]
struct HeapKey {
    time: SimTime,
    seq: u64,
    slot: u32,
}

impl PartialEq for HeapKey {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for HeapKey {}
impl PartialOrd for HeapKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap: reverse for earliest-first.
        other.time.cmp(&self.time).then(other.seq.cmp(&self.seq))
    }
}

/// A slab slot: `seq` identifies the event currently occupying it
/// ([`FREE_SEQ`] when vacant), `ev` its payload.
#[derive(Clone)]
struct Slot<Ev> {
    seq: u64,
    ev: Option<Ev>,
}

/// Sentinel for a vacant slot. Event sequence numbers start at 1 and
/// count up, so no live event ever carries it.
const FREE_SEQ: u64 = u64::MAX;

/// Handle to a scheduled event, returned by [`Scheduler::at_cancellable`].
/// Pass it to [`Scheduler::cancel`] to retire the event before it fires;
/// once the event has been delivered (or cancelled) the token is inert —
/// a late `cancel` is a no-op returning `false`.
#[derive(Clone, Copy, Debug)]
pub struct EventToken {
    slot: u32,
    seq: u64,
}

/// Schedules future events; handed to [`SimState::handle`].
///
/// Cloning a `Scheduler` (requires `Ev: Clone`) snapshots the entire
/// queue — heap keys, slab payloads, free list, clock, and the
/// processed/cancelled counters — so a paused simulation can be forked
/// and resumed down divergent futures. The delta re-simulation path
/// (`model/delta.rs`) relies on this: counters travel with the clone,
/// which keeps `SimReport::events`/`events_cancelled` bit-identical to a
/// cold run that replayed the shared prefix itself.
#[derive(Clone)]
pub struct Scheduler<Ev> {
    heap: BinaryHeap<HeapKey>,
    slots: Vec<Slot<Ev>>,
    free: Vec<u32>,
    now: SimTime,
    seq: u64,
    processed: u64,
    cancelled: u64,
    live: usize,
}

impl<Ev> Scheduler<Ev> {
    pub fn new() -> Self {
        Scheduler {
            heap: BinaryHeap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            now: SimTime::ZERO,
            seq: 0,
            processed: 0,
            cancelled: 0,
            live: 0,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events delivered so far (cancelled events are never
    /// delivered and do not count).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Events cancelled before delivery ([`Scheduler::cancel`]).
    pub fn cancelled(&self) -> u64 {
        self.cancelled
    }

    /// Live (scheduled, not yet delivered or cancelled) events.
    pub fn pending(&self) -> usize {
        self.live
    }

    /// Pre-size the event queue and entry arena for about `n` concurrently
    /// pending events, so the hot loop starts from steady state instead of
    /// growing through it.
    pub fn reserve(&mut self, n: usize) {
        let extra = n.saturating_sub(self.live);
        self.heap.reserve(extra);
        self.slots.reserve(extra);
        self.free.reserve(extra);
    }

    /// Claim a slab slot for `ev` under the current `self.seq`.
    fn alloc_slot(&mut self, ev: Ev) -> u32 {
        match self.free.pop() {
            Some(i) => {
                let s = &mut self.slots[i as usize];
                debug_assert!(s.ev.is_none() && s.seq == FREE_SEQ, "free-list slot in use");
                s.seq = self.seq;
                s.ev = Some(ev);
                i
            }
            None => {
                let i = self.slots.len() as u32;
                self.slots.push(Slot { seq: self.seq, ev: Some(ev) });
                i
            }
        }
    }

    fn push(&mut self, t: SimTime, ev: Ev) -> EventToken {
        self.seq += 1;
        let seq = self.seq;
        let slot = self.alloc_slot(ev);
        self.heap.push(HeapKey { time: t, seq, slot });
        self.live += 1;
        EventToken { slot, seq }
    }

    /// Schedule `ev` at absolute time `t`. Scheduling into the past is a
    /// programming error and panics (in release builds too — the check is
    /// one predictable branch; the alternative is a silently rewinding
    /// clock). Callers that *mean* "no earlier than now" say so with
    /// [`Scheduler::at_or_now`].
    pub fn at(&mut self, t: SimTime, ev: Ev) {
        assert!(t >= self.now, "scheduling into the past: {t} < {}", self.now);
        let _ = self.push(t, ev);
    }

    /// Schedule `ev` at `t`, clamped to the current time if `t` is
    /// already past. Returns the time actually scheduled so callers can
    /// observe the clamp (e.g. log or account a deadline overrun) instead
    /// of having it silently absorbed.
    pub fn at_or_now(&mut self, t: SimTime, ev: Ev) -> SimTime {
        let t = t.max(self.now);
        let _ = self.push(t, ev);
        t
    }

    /// Schedule `ev` at absolute time `t` and return a token that can
    /// retire it before delivery ([`Scheduler::cancel`]). Past-time rules
    /// are as for [`Scheduler::at`].
    #[must_use = "hold the token if the event may need cancelling"]
    pub fn at_cancellable(&mut self, t: SimTime, ev: Ev) -> EventToken {
        assert!(t >= self.now, "scheduling into the past: {t} < {}", self.now);
        self.push(t, ev)
    }

    /// Cancel a previously scheduled event. Returns `true` when the event
    /// was still pending (it will now never be delivered); `false` when it
    /// had already fired or been cancelled. O(1): the payload slot is
    /// recycled immediately and the heap key is skipped lazily when it
    /// surfaces.
    pub fn cancel(&mut self, tok: EventToken) -> bool {
        let s = &mut self.slots[tok.slot as usize];
        if s.seq != tok.seq {
            return false;
        }
        s.seq = FREE_SEQ;
        s.ev = None;
        self.free.push(tok.slot);
        self.cancelled += 1;
        self.live -= 1;
        true
    }

    /// Schedule `ev` after a delay `dt`. Uses the same saturating
    /// [`SimTime`] addition as `Station`, so far-future delays clamp at
    /// `SimTime::MAX` instead of overflowing.
    pub fn after(&mut self, dt: SimTime, ev: Ev) {
        self.at(self.now + dt, ev);
    }

    /// Schedule `ev` immediately (at the current time, after already
    /// pending same-time events).
    pub fn immediately(&mut self, ev: Ev) {
        self.at(self.now, ev);
    }

    /// Time of the next live event without delivering it. Dead keys
    /// (cancelled events) surfacing at the top are retired here, exactly
    /// as [`Scheduler::pop`] would — peeking never changes what `pop`
    /// returns next, only when the lazy skip happens.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        while let Some(k) = self.heap.peek() {
            if self.slots[k.slot as usize].seq != k.seq {
                self.heap.pop();
                continue;
            }
            return Some(k.time);
        }
        None
    }

    /// The next live event (time and a borrow of its payload) without
    /// delivering it. The clock does not advance.
    pub fn peek(&mut self) -> Option<(SimTime, &Ev)> {
        let t = self.peek_time()?;
        let k = self.heap.peek().expect("peek_time found a live key");
        let ev = self.slots[k.slot as usize].ev.as_ref().expect("live slot without a payload");
        Some((t, ev))
    }

    fn pop(&mut self) -> Option<(SimTime, Ev)> {
        while let Some(k) = self.heap.pop() {
            let s = &mut self.slots[k.slot as usize];
            if s.seq != k.seq {
                // Cancelled: the slot was retired (and possibly reused
                // under a newer seq). Skip the dead key.
                continue;
            }
            let ev = s.ev.take().expect("live slot without a payload");
            s.seq = FREE_SEQ;
            self.free.push(k.slot);
            self.live -= 1;
            debug_assert!(k.time >= self.now, "event queue went backwards");
            self.now = k.time;
            self.processed += 1;
            return Some((k.time, ev));
        }
        None
    }
}

impl<Ev> Default for Scheduler<Ev> {
    fn default() -> Self {
        Self::new()
    }
}

/// Application state driven by the engine.
pub trait SimState {
    type Ev;
    /// Handle one event at virtual time `now`. Follow-ups go through `sched`.
    fn handle(&mut self, sched: &mut Scheduler<Self::Ev>, now: SimTime, ev: Self::Ev);
}

/// The engine: owns the scheduler and the application state.
pub struct Simulation<S: SimState> {
    pub sched: Scheduler<S::Ev>,
    pub state: S,
}

impl<S: SimState + Clone> Clone for Simulation<S>
where
    S::Ev: Clone,
{
    fn clone(&self) -> Self {
        Simulation { sched: self.sched.clone(), state: self.state.clone() }
    }
}

impl<S: SimState> Simulation<S> {
    pub fn new(state: S) -> Self {
        Simulation { sched: Scheduler::new(), state }
    }

    /// Deliver exactly one event. Returns `false` when the queue is
    /// drained. Interleaving `step` with [`Scheduler::peek`] between
    /// steps is observationally identical to [`Simulation::run`] — the
    /// delta re-simulation capture loop uses this to snapshot state at
    /// stage boundaries without perturbing delivery order.
    pub fn step(&mut self) -> bool {
        match self.sched.pop() {
            Some((t, ev)) => {
                self.state.handle(&mut self.sched, t, ev);
                true
            }
            None => false,
        }
    }

    /// Run until the event queue drains (or `max_events` is hit, as a
    /// runaway guard). Returns the final virtual time.
    pub fn run(&mut self) -> SimTime {
        self.run_capped(u64::MAX)
    }

    pub fn run_capped(&mut self, max_events: u64) -> SimTime {
        let mut n = 0u64;
        while let Some((t, ev)) = self.sched.pop() {
            self.state.handle(&mut self.sched, t, ev);
            n += 1;
            if n >= max_events {
                panic!("simulation exceeded {max_events} events — livelock?");
            }
        }
        self.sched.now()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Recorder {
        seen: Vec<(u64, u32)>,
        chain_left: u32,
    }

    impl SimState for Recorder {
        type Ev = u32;
        fn handle(&mut self, sched: &mut Scheduler<u32>, now: SimTime, ev: u32) {
            self.seen.push((now.as_ns(), ev));
            if ev == 99 && self.chain_left > 0 {
                self.chain_left -= 1;
                sched.after(SimTime::from_ns(10), 99);
            }
        }
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 0 });
        sim.sched.at(SimTime::from_ns(30), 3);
        sim.sched.at(SimTime::from_ns(10), 1);
        sim.sched.at(SimTime::from_ns(20), 2);
        let end = sim.run();
        assert_eq!(sim.state.seen, vec![(10, 1), (20, 2), (30, 3)]);
        assert_eq!(end.as_ns(), 30);
        assert_eq!(sim.sched.processed(), 3);
    }

    #[test]
    fn equal_timestamps_are_fifo() {
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 0 });
        for i in 0..100u32 {
            sim.sched.at(SimTime::from_ns(5), i);
        }
        sim.run();
        let evs: Vec<u32> = sim.state.seen.iter().map(|&(_, e)| e).collect();
        assert_eq!(evs, (0..100).collect::<Vec<u32>>(), "same-time events keep schedule order");
    }

    #[test]
    fn chained_events_advance_clock() {
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 5 });
        sim.sched.at(SimTime::ZERO, 99);
        let end = sim.run();
        assert_eq!(end.as_ns(), 50);
        assert_eq!(sim.state.seen.len(), 6);
    }

    #[test]
    #[should_panic(expected = "livelock")]
    fn runaway_guard_trips() {
        struct Forever;
        impl SimState for Forever {
            type Ev = ();
            fn handle(&mut self, sched: &mut Scheduler<()>, _now: SimTime, _ev: ()) {
                sched.immediately(());
            }
        }
        let mut sim = Simulation::new(Forever);
        sim.sched.at(SimTime::ZERO, ());
        sim.run_capped(1000);
    }

    #[test]
    fn far_future_delays_saturate_instead_of_overflowing() {
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 0 });
        sim.sched.at(SimTime::from_ns(10), 1);
        sim.run();
        // now = 10ns; a MAX delay must clamp at SimTime::MAX, not wrap.
        sim.sched.after(SimTime::MAX, 2);
        let end = sim.run();
        assert_eq!(end, SimTime::MAX);
        assert_eq!(sim.state.seen.last(), Some(&(u64::MAX, 2)));
    }

    #[test]
    fn immediately_runs_at_now_in_order() {
        struct S {
            log: Vec<&'static str>,
        }
        impl SimState for S {
            type Ev = &'static str;
            fn handle(&mut self, sched: &mut Scheduler<&'static str>, _now: SimTime, ev: &'static str) {
                self.log.push(ev);
                if ev == "first" {
                    sched.immediately("second");
                }
            }
        }
        let mut sim = Simulation::new(S { log: vec![] });
        sim.sched.at(SimTime::from_ns(7), "first");
        sim.run();
        assert_eq!(sim.state.log, vec!["first", "second"]);
        assert_eq!(sim.sched.now().as_ns(), 7);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn scheduling_into_the_past_panics() {
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 0 });
        sim.sched.at(SimTime::from_ns(10), 1);
        sim.run();
        sim.sched.at(SimTime::from_ns(5), 2);
    }

    #[test]
    fn at_or_now_clamps_and_reports_the_clamp() {
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 0 });
        sim.sched.at(SimTime::from_ns(10), 1);
        sim.run();
        // Past time: clamped to now, and the caller can see it was.
        let t = sim.sched.at_or_now(SimTime::from_ns(5), 2);
        assert_eq!(t, SimTime::from_ns(10), "clamped to now");
        // Future time: passes through unchanged.
        let t = sim.sched.at_or_now(SimTime::from_ns(25), 3);
        assert_eq!(t, SimTime::from_ns(25));
        sim.run();
        assert_eq!(sim.state.seen, vec![(10, 1), (10, 2), (25, 3)]);
    }

    #[test]
    fn cancelled_events_are_never_delivered() {
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 0 });
        let tok = sim.sched.at_cancellable(SimTime::from_ns(10), 1);
        sim.sched.at(SimTime::from_ns(20), 2);
        assert_eq!(sim.sched.pending(), 2);
        assert!(sim.sched.cancel(tok), "first cancel retires the event");
        assert!(!sim.sched.cancel(tok), "second cancel is inert");
        assert_eq!(sim.sched.pending(), 1);
        sim.run();
        assert_eq!(sim.state.seen, vec![(20, 2)], "only the live event fired");
        assert_eq!(sim.sched.processed(), 1, "skipped keys are not processed events");
        assert_eq!(sim.sched.cancelled(), 1);
    }

    #[test]
    fn cancel_after_delivery_is_inert() {
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 0 });
        let tok = sim.sched.at_cancellable(SimTime::from_ns(10), 1);
        sim.run();
        assert!(!sim.sched.cancel(tok), "the event already fired");
        assert_eq!(sim.sched.cancelled(), 0);
    }

    #[test]
    fn cancel_and_reschedule_keeps_only_the_replacement() {
        // The weighted-fair NIC pattern: each announcement supersedes the
        // previous one; only the latest may be delivered.
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 0 });
        let mut tok = sim.sched.at_cancellable(SimTime::from_ns(10), 1);
        for (t, ev) in [(15u64, 2u32), (12, 3), (30, 4)] {
            assert!(sim.sched.cancel(tok));
            tok = sim.sched.at_cancellable(SimTime::from_ns(t), ev);
        }
        sim.run();
        assert_eq!(sim.state.seen, vec![(30, 4)]);
        assert_eq!(sim.sched.cancelled(), 3);
    }

    #[test]
    fn slab_slots_are_recycled_not_grown() {
        // A long chain of one-at-a-time events must keep reusing the same
        // slot instead of growing the arena — the "no allocation per
        // event" property of the frame-path hot loop.
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 10_000 });
        sim.sched.reserve(4);
        sim.sched.at(SimTime::ZERO, 99);
        sim.run();
        assert_eq!(sim.state.seen.len(), 10_001);
        assert!(
            sim.sched.slots.len() <= 2,
            "steady-state chain grew the arena to {} slots",
            sim.sched.slots.len()
        );
    }

    #[test]
    fn peek_and_step_match_run() {
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 3 });
        sim.sched.at(SimTime::from_ns(30), 3);
        sim.sched.at(SimTime::from_ns(10), 99);
        let tok = sim.sched.at_cancellable(SimTime::from_ns(5), 7);
        assert!(sim.sched.cancel(tok));
        // Peek skips the dead key and reports the first live event.
        assert_eq!(sim.sched.peek(), Some((SimTime::from_ns(10), &99)));
        assert_eq!(sim.sched.peek_time(), Some(SimTime::from_ns(10)));
        let mut reference = Simulation::new(Recorder { seen: vec![], chain_left: 3 });
        reference.sched.at(SimTime::from_ns(30), 3);
        reference.sched.at(SimTime::from_ns(10), 99);
        reference.run();
        while sim.step() {}
        assert_eq!(sim.state.seen, reference.state.seen, "step-driven == run-driven");
        assert_eq!(sim.sched.peek_time(), None);
        assert!(!sim.step(), "drained queue steps false");
    }

    #[test]
    fn cloned_scheduler_resumes_identically() {
        // Fork a mid-flight simulation; both copies must finish with the
        // same trace and the same processed/cancelled totals.
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 10 });
        sim.sched.at(SimTime::ZERO, 99);
        let tok = sim.sched.at_cancellable(SimTime::from_ns(1), 1);
        assert!(sim.sched.cancel(tok));
        for _ in 0..4 {
            assert!(sim.step());
        }
        let mut fork = sim.clone();
        sim.run();
        fork.run();
        assert_eq!(sim.state.seen, fork.state.seen);
        assert_eq!(sim.sched.processed(), fork.sched.processed());
        assert_eq!(sim.sched.cancelled(), fork.sched.cancelled());
        assert_eq!(sim.sched.now(), fork.sched.now());
    }

    #[test]
    fn slot_reuse_does_not_resurrect_old_tokens() {
        // A token for a delivered event whose slot was since reused by a
        // newer event must not cancel the newcomer (seq acts as the
        // generation).
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 0 });
        let old = sim.sched.at_cancellable(SimTime::from_ns(1), 1);
        sim.run();
        let _new = sim.sched.at_cancellable(SimTime::from_ns(2), 2); // reuses the slot
        assert!(!sim.sched.cancel(old), "stale token must miss");
        sim.run();
        assert_eq!(sim.state.seen, vec![(1, 1), (2, 2)]);
    }
}
