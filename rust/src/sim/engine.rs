//! Virtual clock + event queue.
//!
//! Events are an application-defined type `Ev`; the application state
//! implements [`SimState::handle`], which receives each event in
//! timestamp order (FIFO among equal timestamps, enforced by a sequence
//! number) together with a [`Scheduler`] for scheduling follow-up events.

use crate::util::units::SimTime;
use std::collections::BinaryHeap;

/// An event queue entry: min-heap by (time, seq).
struct Entry<Ev> {
    time: SimTime,
    seq: u64,
    ev: Ev,
}

impl<Ev> PartialEq for Entry<Ev> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<Ev> Eq for Entry<Ev> {}
impl<Ev> PartialOrd for Entry<Ev> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<Ev> Ord for Entry<Ev> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap: reverse for earliest-first.
        other.time.cmp(&self.time).then(other.seq.cmp(&self.seq))
    }
}

/// Schedules future events; handed to [`SimState::handle`].
pub struct Scheduler<Ev> {
    heap: BinaryHeap<Entry<Ev>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<Ev> Scheduler<Ev> {
    pub fn new() -> Self {
        Scheduler { heap: BinaryHeap::new(), now: SimTime::ZERO, seq: 0, processed: 0 }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Events currently pending.
    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `ev` at absolute time `t` (must not be in the past).
    pub fn at(&mut self, t: SimTime, ev: Ev) {
        debug_assert!(t >= self.now, "scheduling into the past: {t} < {}", self.now);
        self.seq += 1;
        self.heap.push(Entry { time: t.max(self.now), seq: self.seq, ev });
    }

    /// Schedule `ev` after a delay `dt`. Uses the same saturating
    /// [`SimTime`] addition as `Station`, so far-future delays clamp at
    /// `SimTime::MAX` instead of overflowing.
    pub fn after(&mut self, dt: SimTime, ev: Ev) {
        self.at(self.now + dt, ev);
    }

    /// Schedule `ev` immediately (at the current time, after already
    /// pending same-time events).
    pub fn immediately(&mut self, ev: Ev) {
        self.at(self.now, ev);
    }

    fn pop(&mut self) -> Option<(SimTime, Ev)> {
        self.heap.pop().map(|e| {
            debug_assert!(e.time >= self.now, "event queue went backwards");
            self.now = e.time;
            self.processed += 1;
            (e.time, e.ev)
        })
    }
}

impl<Ev> Default for Scheduler<Ev> {
    fn default() -> Self {
        Self::new()
    }
}

/// Application state driven by the engine.
pub trait SimState {
    type Ev;
    /// Handle one event at virtual time `now`. Follow-ups go through `sched`.
    fn handle(&mut self, sched: &mut Scheduler<Self::Ev>, now: SimTime, ev: Self::Ev);
}

/// The engine: owns the scheduler and the application state.
pub struct Simulation<S: SimState> {
    pub sched: Scheduler<S::Ev>,
    pub state: S,
}

impl<S: SimState> Simulation<S> {
    pub fn new(state: S) -> Self {
        Simulation { sched: Scheduler::new(), state }
    }

    /// Run until the event queue drains (or `max_events` is hit, as a
    /// runaway guard). Returns the final virtual time.
    pub fn run(&mut self) -> SimTime {
        self.run_capped(u64::MAX)
    }

    pub fn run_capped(&mut self, max_events: u64) -> SimTime {
        let mut n = 0u64;
        while let Some((t, ev)) = self.sched.pop() {
            self.state.handle(&mut self.sched, t, ev);
            n += 1;
            if n >= max_events {
                panic!("simulation exceeded {max_events} events — livelock?");
            }
        }
        self.sched.now()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Recorder {
        seen: Vec<(u64, u32)>,
        chain_left: u32,
    }

    impl SimState for Recorder {
        type Ev = u32;
        fn handle(&mut self, sched: &mut Scheduler<u32>, now: SimTime, ev: u32) {
            self.seen.push((now.as_ns(), ev));
            if ev == 99 && self.chain_left > 0 {
                self.chain_left -= 1;
                sched.after(SimTime::from_ns(10), 99);
            }
        }
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 0 });
        sim.sched.at(SimTime::from_ns(30), 3);
        sim.sched.at(SimTime::from_ns(10), 1);
        sim.sched.at(SimTime::from_ns(20), 2);
        let end = sim.run();
        assert_eq!(sim.state.seen, vec![(10, 1), (20, 2), (30, 3)]);
        assert_eq!(end.as_ns(), 30);
        assert_eq!(sim.sched.processed(), 3);
    }

    #[test]
    fn equal_timestamps_are_fifo() {
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 0 });
        for i in 0..100u32 {
            sim.sched.at(SimTime::from_ns(5), i);
        }
        sim.run();
        let evs: Vec<u32> = sim.state.seen.iter().map(|&(_, e)| e).collect();
        assert_eq!(evs, (0..100).collect::<Vec<u32>>(), "same-time events keep schedule order");
    }

    #[test]
    fn chained_events_advance_clock() {
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 5 });
        sim.sched.at(SimTime::ZERO, 99);
        let end = sim.run();
        assert_eq!(end.as_ns(), 50);
        assert_eq!(sim.state.seen.len(), 6);
    }

    #[test]
    #[should_panic(expected = "livelock")]
    fn runaway_guard_trips() {
        struct Forever;
        impl SimState for Forever {
            type Ev = ();
            fn handle(&mut self, sched: &mut Scheduler<()>, _now: SimTime, _ev: ()) {
                sched.immediately(());
            }
        }
        let mut sim = Simulation::new(Forever);
        sim.sched.at(SimTime::ZERO, ());
        sim.run_capped(1000);
    }

    #[test]
    fn far_future_delays_saturate_instead_of_overflowing() {
        let mut sim = Simulation::new(Recorder { seen: vec![], chain_left: 0 });
        sim.sched.at(SimTime::from_ns(10), 1);
        sim.run();
        // now = 10ns; a MAX delay must clamp at SimTime::MAX, not wrap.
        sim.sched.after(SimTime::MAX, 2);
        let end = sim.run();
        assert_eq!(end, SimTime::MAX);
        assert_eq!(sim.state.seen.last(), Some(&(u64::MAX, 2)));
    }

    #[test]
    fn immediately_runs_at_now_in_order() {
        struct S {
            log: Vec<&'static str>,
        }
        impl SimState for S {
            type Ev = &'static str;
            fn handle(&mut self, sched: &mut Scheduler<&'static str>, _now: SimTime, ev: &'static str) {
                self.log.push(ev);
                if ev == "first" {
                    sched.immediately("second");
                }
            }
        }
        let mut sim = Simulation::new(S { log: vec![] });
        sim.sched.at(SimTime::from_ns(7), "first");
        sim.run();
        assert_eq!(sim.state.log, vec!["first", "second"]);
        assert_eq!(sim.sched.now().as_ns(), 7);
    }
}
