//! Routed network fabric: topology resolution and the multi-hop
//! transfer protocol, plus its single-pair reference oracle.
//!
//! The paper's testbed is one non-blocking switch, so the model engine
//! historically hard-wired every transfer to a single out-NIC/in-NIC
//! station pair — a *star*. This module generalizes that shape into a
//! routed fabric without giving back the O(1)-events-per-train economy
//! of bulk frame aggregation:
//!
//! * [`FabricPlan`] resolves a topology (star, or two-tier rack + core
//!   with an oversubscription ratio) into a set of core *links* and a
//!   [`Route`] per src→dst host pair. Star and in-rack pairs route over
//!   **zero** links — they keep the exact pre-fabric station pair — and
//!   cross-rack pairs traverse the source rack's uplink then the
//!   destination rack's downlink.
//! * Each core link is a weighted-fair shared server (the same
//!   virtual-time GPS [`FairStation`] the bulk in-NIC uses): all
//!   cross-rack trains through a rack's uplink share `rack_size /
//!   oversub` host lines of bandwidth, byte-proportionally.
//! * Multi-hop transfers are **pipelined at frame granularity**: a train
//!   cut-throughs into the next hop one leading-frame service after it
//!   starts (bulk) or store-and-forwards per frame (per-frame path), and
//!   final delivery is gated on *every* hop having finished the train —
//!   the bottleneck hop sets the delivery time, wherever it sits on the
//!   path. Each hop costs O(1) scheduler events per train.
//!
//! [`FabricPath`] is the station-level embodiment of that protocol (one
//! source out-NIC FIFO, `n` fair hops, the engine's exact coupling
//! rules), and [`RefStarFabric`] is the independently-written
//! *single-pair* shape — out FIFO + in fair server, the engine before
//! the fabric existed — kept as the reference oracle. The lockstep
//! proptest `prop_star_fabric_matches_reference` drives a zero-link
//! [`FabricPath`] against [`RefStarFabric`] and demands every announced
//! time, completion, queue depth and statistic integral match
//! **bit-for-bit**: the star topology is the degenerate fabric, not an
//! approximation of it.

use crate::sim::station::{FairStation, RefFairStation, Station, StationStats};
use crate::util::units::SimTime;
use std::collections::VecDeque;

// ---------------------------------------------------------------------
// Topology resolution
// ---------------------------------------------------------------------

/// A resolved topology: how many core links exist, how fast they are,
/// and which of them a given host pair crosses. Built once per
/// simulation from the platform's `Topology` knob (the `sim` layer does
/// not depend on `model`, so construction takes plain numbers).
#[derive(Clone, Debug)]
pub struct FabricPlan {
    /// Hosts per rack; `0` encodes the star (single switching domain).
    rack_size: usize,
    /// Core links: rack `r` owns uplink `2r` and downlink `2r + 1`.
    n_links: usize,
    /// Core-link service time per byte. Each link carries
    /// `rack_size / oversub` host lines: `ns_per_byte_remote · oversub /
    /// rack_size`.
    ns_per_byte_link: f64,
}

impl FabricPlan {
    /// The degenerate plan: no core links, every pair is single-hop.
    pub fn star() -> FabricPlan {
        FabricPlan { rack_size: 0, n_links: 0, ns_per_byte_link: 0.0 }
    }

    /// A two-tier rack + core plan over `n_hosts` hosts. A layout that
    /// fits every host into one rack *is* the star and resolves to the
    /// degenerate plan (no links, so the engine's event sequence is
    /// unchanged — the bit-identity anchor of the conformance suite).
    pub fn rack(n_hosts: usize, rack_size: usize, oversub: f64, ns_per_byte_remote: f64) -> FabricPlan {
        assert!(rack_size >= 1, "rack size must be at least 1");
        assert!(oversub > 0.0 && oversub.is_finite(), "oversubscription must be positive");
        let n_racks = n_hosts.div_ceil(rack_size);
        if n_racks <= 1 {
            return FabricPlan::star();
        }
        FabricPlan {
            rack_size,
            n_links: 2 * n_racks,
            ns_per_byte_link: ns_per_byte_remote * oversub / rack_size as f64,
        }
    }

    /// Number of core links (0 under the star).
    pub fn n_links(&self) -> usize {
        self.n_links
    }

    /// True when no pair ever routes over a core link.
    pub fn is_star(&self) -> bool {
        self.n_links == 0
    }

    /// Core-link service time per byte (meaningless under the star).
    pub fn ns_per_byte_link(&self) -> f64 {
        self.ns_per_byte_link
    }

    /// The rack a host lives in.
    pub fn rack_of(&self, host: usize) -> usize {
        if self.rack_size == 0 {
            0
        } else {
            host / self.rack_size
        }
    }

    /// The core links a `src → dst` transfer crosses, in traversal
    /// order: empty for star, same-host and in-rack pairs; source
    /// uplink then destination downlink otherwise.
    pub fn route(&self, src: usize, dst: usize) -> Route {
        if self.n_links == 0 {
            return Route::EMPTY;
        }
        let (rs, rd) = (self.rack_of(src), self.rack_of(dst));
        if rs == rd {
            return Route::EMPTY;
        }
        Route { n: 2, links: [2 * rs, 2 * rd + 1] }
    }
}

/// The ordered core links of one transfer (at most two in a two-tier
/// fabric: rack uplink, then rack downlink).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Route {
    n: u8,
    links: [usize; 2],
}

impl Route {
    pub const EMPTY: Route = Route { n: 0, links: [0, 0] };

    pub fn len(&self) -> usize {
        self.n as usize
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// First link on the path (None = deliver straight to the in-NIC).
    pub fn first(&self) -> Option<usize> {
        if self.n > 0 {
            Some(self.links[0])
        } else {
            None
        }
    }

    /// The link after `link` on this path (None = `link` is the last
    /// hop before the destination in-NIC).
    pub fn after(&self, link: usize) -> Option<usize> {
        if self.n == 2 && self.links[0] == link {
            Some(self.links[1])
        } else {
            None
        }
    }
}

// ---------------------------------------------------------------------
// Station-level path protocol (conformance harness)
// ---------------------------------------------------------------------

/// Per-hop service decomposition of one frame train (the station-level
/// mirror of the engine's `TrainSvc`, with the fair-share weight and
/// the analytic partial-last-frame wait carried along).
#[derive(Clone, Copy, Debug)]
pub struct TrainSpec {
    /// Aggregate service time at this hop (exact Σ of per-frame times).
    pub total: SimTime,
    /// Leading-frame service — the cut-through offset into the next hop.
    pub first: SimTime,
    /// Full-frame service (analytic intra-train pacing unit).
    pub unit: SimTime,
    /// Wire frames in the train.
    pub units: u64,
    /// Fair-share weight (wire bytes; clamped ≥ 1 by the fair server).
    pub weight: u64,
    /// Analytic short-last-frame wait (ns) charged at fair hops.
    pub tail_wait_ns: u64,
}

/// One pending internal event of a path mini-simulation. Exposed so the
/// lockstep driver can assert the two implementations agree on *what*
/// happens next, not just when.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PathEv {
    /// The source out-NIC finished its in-service train.
    OutDone,
    /// A train's leading frame reaches fair hop `h` (cut-through).
    Arrive(usize),
    /// Fair hop `h` finished a train.
    HopDone(usize),
}

/// What one [`FabricPath::step`]/[`RefStarFabric::step`] processed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PathStep {
    pub at: SimTime,
    pub ev: PathEv,
    /// A message fully delivered by this step (all hops done), if any.
    pub delivered: Option<usize>,
}

/// Event-selection rank shared by both mini-sims: completions before
/// arrivals at equal times (lowest hop first), out-NIC completions in
/// between, arrivals in FIFO scheduling order. Both implementations use
/// this exact rule, so lockstep comparison is well-defined.
fn rank(ev: &PathEv) -> (u8, usize) {
    match *ev {
        PathEv::HopDone(h) => (0, h),
        PathEv::OutDone => (1, 0),
        PathEv::Arrive(h) => (2, h),
    }
}

/// The routed-path protocol as a self-contained station-level
/// mini-simulation: one source out-NIC ([`Station`], FIFO) feeding
/// `n_hops` weighted-fair servers ([`FairStation`]) — the core links
/// plus the destination in-NIC — with the engine's coupling rules:
///
/// * a train cut-throughs into hop 1 one leading-frame service (plus
///   the path latency, charged once) after its out-NIC service starts;
/// * each fair hop forwards the cut-through one *link-rate* leading-
///   frame service after the train arrives, and charges the whole train
///   service to itself;
/// * delivery fires when the train has completed at **every** fair hop
///   (the bottleneck hop gates, wherever it is).
///
/// With `n_hops == 1` this is exactly the pre-fabric single-pair shape,
/// pinned bit-for-bit against [`RefStarFabric`] by the lockstep
/// proptest.
#[derive(Debug)]
pub struct FabricPath {
    lat: SimTime,
    out: Station<usize>,
    hops: Vec<FairStation<usize>>,
    /// Per-message per-hop specs: `specs[m][0]` is the out-NIC hop,
    /// `specs[m][1..]` the fair hops.
    specs: Vec<Vec<TrainSpec>>,
    /// Remaining fair-hop completions before message `m` delivers.
    gate: Vec<u32>,
    out_done: Option<SimTime>,
    /// Live announced completion per fair hop (arrivals supersede).
    hop_done: Vec<Option<SimTime>>,
    /// Scheduled cut-through arrivals `(t, hop, msg)`, FIFO by insertion.
    arrivals: VecDeque<(SimTime, usize, usize)>,
}

impl FabricPath {
    /// A path with `n_fair_hops` fair servers (≥ 1: links + in-NIC).
    pub fn new(lat: SimTime, n_fair_hops: usize) -> FabricPath {
        assert!(n_fair_hops >= 1);
        FabricPath {
            lat,
            out: Station::new(),
            hops: (0..n_fair_hops).map(|_| FairStation::new()).collect(),
            specs: Vec::new(),
            gate: Vec::new(),
            out_done: None,
            hop_done: vec![None; n_fair_hops],
            arrivals: VecDeque::new(),
        }
    }

    /// A message train enters the source out-NIC at `now`. `specs[0]`
    /// is its out-NIC decomposition, `specs[1..]` one per fair hop.
    /// Returns the message id deliveries refer to.
    pub fn send(&mut self, now: SimTime, specs: Vec<TrainSpec>) -> usize {
        assert_eq!(specs.len(), self.hops.len() + 1, "one spec per hop plus the out-NIC");
        let msg = self.specs.len();
        let s0 = specs[0];
        self.specs.push(specs);
        self.gate.push(self.hops.len() as u32);
        if let Some(t) = self.out.arrive_train(now, msg, s0.total, s0.units, s0.unit) {
            self.out_done = Some(t);
            self.arrivals.push_back((now + s0.first + self.lat, 1, msg));
        }
        msg
    }

    /// The earliest pending internal event.
    pub fn next(&self) -> Option<(SimTime, PathEv)> {
        let mut best: Option<(SimTime, PathEv)> = None;
        let mut consider = |t: SimTime, ev: PathEv| {
            let better = match &best {
                None => true,
                Some((bt, bev)) => t < *bt || (t == *bt && rank(&ev) < rank(bev)),
            };
            if better {
                best = Some((t, ev));
            }
        };
        if let Some(t) = self.out_done {
            consider(t, PathEv::OutDone);
        }
        for (h, d) in self.hop_done.iter().enumerate() {
            if let Some(t) = *d {
                consider(t, PathEv::HopDone(h));
            }
        }
        // FIFO: the front-most arrival wins ties among arrivals, so scan
        // front to back with a strictly-better comparison.
        for &(t, hop, _) in &self.arrivals {
            consider(t, PathEv::Arrive(hop));
        }
        best
    }

    /// Process the earliest pending event.
    pub fn step(&mut self) -> PathStep {
        let (at, ev) = self.next().expect("step() on an idle path");
        let mut delivered = None;
        match ev {
            PathEv::OutDone => {
                let (_msg, next) = self.out.complete(at);
                self.out_done = next;
                if next.is_some() {
                    let m2 = *self.out.in_service().expect("next completion implies in-service");
                    let s0 = self.specs[m2][0];
                    self.arrivals.push_back((at + s0.first + self.lat, 1, m2));
                }
            }
            PathEv::Arrive(hop) => {
                let pos = self
                    .arrivals
                    .iter()
                    .position(|&(t, h, _)| t == at && h == hop)
                    .expect("announced arrival is pending");
                let (_, _, msg) = self.arrivals.remove(pos).expect("position just found");
                let s = self.specs[msg][hop];
                let t = self.hops[hop - 1].arrive(at, msg, s.total, s.units, s.weight, s.tail_wait_ns);
                self.hop_done[hop - 1] = Some(t); // supersedes the old announcement
                if hop < self.hops.len() {
                    self.arrivals.push_back((at + s.first, hop + 1, msg));
                }
            }
            PathEv::HopDone(h) => {
                let (msg, next) = self.hops[h].complete(at);
                self.hop_done[h] = next;
                self.gate[msg] -= 1;
                if self.gate[msg] == 0 {
                    delivered = Some(msg);
                }
            }
        }
        PathStep { at, ev, delivered }
    }

    pub fn is_idle(&self) -> bool {
        self.next().is_none()
    }

    pub fn out_queue_len(&self) -> usize {
        self.out.queue_len()
    }

    pub fn hop_queue_len(&self, h: usize) -> usize {
        self.hops[h].queue_len()
    }

    /// Finalize statistics at `end` and return them: out-NIC first, then
    /// each fair hop in order.
    pub fn finish(mut self, end: SimTime) -> Vec<StationStats> {
        self.out.finish(end);
        let mut all = vec![self.out.stats.clone()];
        for mut h in self.hops {
            h.finish(end);
            all.push(h.stats.clone());
        }
        all
    }
}

// ---------------------------------------------------------------------
// Reference oracle: the pre-fabric single-pair shape
// ---------------------------------------------------------------------

/// The network shape the engine had before the routed fabric existed —
/// one source out-NIC FIFO feeding one destination in-NIC fair server,
/// cut-through coupled — written independently of [`FabricPath`] (its
/// fair server is the linear-scan [`RefFairStation`], its bookkeeping
/// its own) and kept as the conformance oracle: a zero-link
/// [`FabricPath`] must match it event-for-event, bit-for-bit. Hidden
/// from the supported API: it exists for the lockstep proptests.
#[doc(hidden)]
#[derive(Debug)]
pub struct RefStarFabric {
    lat: SimTime,
    out: Station<usize>,
    inn: RefFairStation<usize>,
    specs: Vec<[TrainSpec; 2]>,
    out_done: Option<SimTime>,
    in_done: Option<SimTime>,
    arrivals: VecDeque<(SimTime, usize)>,
}

impl RefStarFabric {
    pub fn new(lat: SimTime) -> RefStarFabric {
        RefStarFabric {
            lat,
            out: Station::new(),
            inn: RefFairStation::new(),
            specs: Vec::new(),
            out_done: None,
            in_done: None,
            arrivals: VecDeque::new(),
        }
    }

    /// A message train enters the pair: `out_spec` at the source
    /// out-NIC, `in_spec` at the destination in-NIC.
    pub fn send(&mut self, now: SimTime, out_spec: TrainSpec, in_spec: TrainSpec) -> usize {
        let msg = self.specs.len();
        self.specs.push([out_spec, in_spec]);
        if let Some(t) = self.out.arrive_train(now, msg, out_spec.total, out_spec.units, out_spec.unit)
        {
            self.out_done = Some(t);
            self.arrivals.push_back((now + out_spec.first + self.lat, msg));
        }
        msg
    }

    pub fn next(&self) -> Option<(SimTime, PathEv)> {
        let mut best: Option<(SimTime, PathEv)> = None;
        let mut consider = |t: SimTime, ev: PathEv| {
            let better = match &best {
                None => true,
                Some((bt, bev)) => t < *bt || (t == *bt && rank(&ev) < rank(bev)),
            };
            if better {
                best = Some((t, ev));
            }
        };
        if let Some(t) = self.in_done {
            consider(t, PathEv::HopDone(0));
        }
        if let Some(t) = self.out_done {
            consider(t, PathEv::OutDone);
        }
        for &(t, _) in &self.arrivals {
            consider(t, PathEv::Arrive(1));
        }
        best
    }

    pub fn step(&mut self) -> PathStep {
        let (at, ev) = self.next().expect("step() on an idle pair");
        let mut delivered = None;
        match ev {
            PathEv::OutDone => {
                let (_msg, next) = self.out.complete(at);
                self.out_done = next;
                if next.is_some() {
                    let m2 = *self.out.in_service().expect("next completion implies in-service");
                    let s0 = self.specs[m2][0];
                    self.arrivals.push_back((at + s0.first + self.lat, m2));
                }
            }
            PathEv::Arrive(_) => {
                let pos = self
                    .arrivals
                    .iter()
                    .position(|&(t, _)| t == at)
                    .expect("announced arrival is pending");
                let (_, msg) = self.arrivals.remove(pos).expect("position just found");
                let s = self.specs[msg][1];
                let t = self.inn.arrive(at, msg, s.total, s.units, s.weight, s.tail_wait_ns);
                self.in_done = Some(t);
            }
            PathEv::HopDone(_) => {
                let (msg, next) = self.inn.complete(at);
                self.in_done = next;
                delivered = Some(msg); // single hop: in-NIC completion delivers
            }
        }
        PathStep { at, ev, delivered }
    }

    pub fn is_idle(&self) -> bool {
        self.next().is_none()
    }

    pub fn out_queue_len(&self) -> usize {
        self.out.queue_len()
    }

    pub fn in_queue_len(&self) -> usize {
        self.inn.queue_len()
    }

    /// Finalize statistics at `end`: `[out, in]`.
    pub fn finish(mut self, end: SimTime) -> Vec<StationStats> {
        self.out.finish(end);
        self.inn.finish(end);
        vec![self.out.stats.clone(), self.inn.stats.clone()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ns(x: u64) -> SimTime {
        SimTime::from_ns(x)
    }

    /// An even `bytes`-byte train split into `units` equal frames of
    /// `unit_ns` each, at weight = bytes.
    fn spec(units: u64, unit_ns: u64, weight: u64) -> TrainSpec {
        TrainSpec {
            total: ns(unit_ns * units),
            first: ns(unit_ns),
            unit: ns(unit_ns),
            units,
            weight,
            tail_wait_ns: 0,
        }
    }

    #[test]
    fn star_plan_routes_nothing() {
        let p = FabricPlan::star();
        assert!(p.is_star());
        assert_eq!(p.n_links(), 0);
        assert!(p.route(0, 17).is_empty());
    }

    #[test]
    fn single_rack_layout_degenerates_to_star() {
        // Every host fits in one rack: no links, no routed pairs — the
        // engine's event sequence is untouched.
        let p = FabricPlan::rack(20, 32, 4.0, 8.0);
        assert!(p.is_star());
        assert!(p.route(1, 19).is_empty());
    }

    #[test]
    fn rack_plan_routes_cross_rack_pairs_over_two_links() {
        // 20 hosts in racks of 8: racks {0..8}, {8..16}, {16..20}.
        let p = FabricPlan::rack(20, 8, 4.0, 8.0);
        assert!(!p.is_star());
        assert_eq!(p.n_links(), 6);
        assert_eq!(p.rack_of(7), 0);
        assert_eq!(p.rack_of(8), 1);
        assert!(p.route(1, 7).is_empty(), "in-rack stays single-hop");
        assert!(p.route(3, 3).is_empty(), "same host never routes");
        let r = p.route(1, 9); // rack 0 -> rack 1
        assert_eq!(r.len(), 2);
        assert_eq!(r.first(), Some(0), "rack 0's uplink");
        assert_eq!(r.after(0), Some(3), "rack 1's downlink");
        assert_eq!(r.after(3), None, "downlink is the last hop");
        let back = p.route(9, 1); // rack 1 -> rack 0
        assert_eq!(back.first(), Some(2));
        assert_eq!(back.after(2), Some(1));
    }

    #[test]
    fn link_rate_scales_with_rack_size_and_oversub() {
        // rack_size 8, oversub 4: each link carries 2 host lines, so
        // bytes cost half the host-NIC ns/byte.
        let p = FabricPlan::rack(64, 8, 4.0, 8.0);
        assert!((p.ns_per_byte_link() - 4.0).abs() < 1e-12);
        // Non-blocking core (oversub 1): 8 lines, 8x faster than a host.
        let p = FabricPlan::rack(64, 8, 1.0, 8.0);
        assert!((p.ns_per_byte_link() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_link_path_matches_reference_on_a_scripted_mix() {
        // Deterministic lockstep smoke (the proptest randomizes this):
        // contended sends through a 1-fair-hop path vs the single-pair
        // oracle, every event and delivery bit-identical.
        let mut path = FabricPath::new(ns(90_000), 1);
        let mut oracle = RefStarFabric::new(ns(90_000));
        let script: [(u64, TrainSpec); 3] = [
            (0, spec(4, 500, 64 * 1024)),
            (100, spec(9, 500, 150_000)),
            (2_000, spec(1, 137, 137)),
        ];
        for &(at, s) in &script {
            let a = path.send(ns(at), vec![s, s]);
            let b = oracle.send(ns(at), s, s);
            assert_eq!(a, b);
        }
        let mut deliveries = 0;
        for _ in 0..64 {
            match (path.next(), oracle.next()) {
                (None, None) => break,
                (a, b) => assert_eq!(a, b, "pending event diverged"),
            }
            let sa = path.step();
            let sb = oracle.step();
            assert_eq!(sa, sb, "step diverged");
            assert_eq!(path.out_queue_len(), oracle.out_queue_len());
            assert_eq!(path.hop_queue_len(0), oracle.in_queue_len());
            if sa.delivered.is_some() {
                deliveries += 1;
            }
        }
        assert_eq!(deliveries, 3, "all messages delivered");
        let fa = path.finish(ns(10_000_000));
        let fb = oracle.finish(ns(10_000_000));
        for (a, b) in fa.iter().zip(fb.iter()) {
            assert_eq!(a.busy_ns, b.busy_ns);
            assert_eq!(a.qlen_ns, b.qlen_ns);
            assert_eq!(a.max_qlen, b.max_qlen);
            assert_eq!(a.arrivals, b.arrivals);
            assert_eq!(a.departures, b.departures);
        }
    }

    #[test]
    fn slow_middle_hop_gates_delivery() {
        // 3 fair hops; the middle one is 4x slower. Delivery must wait
        // for the bottleneck even though the in-NIC finishes earlier.
        let mut path = FabricPath::new(ns(0), 3);
        let fast = spec(4, 100, 4_000);
        let slow = spec(4, 400, 4_000);
        path.send(ns(0), vec![fast, fast, slow, fast]);
        let mut delivered_at = None;
        for _ in 0..32 {
            if path.is_idle() {
                break;
            }
            let s = path.step();
            if let Some(_m) = s.delivered {
                delivered_at = Some(s.at);
            }
        }
        let t = delivered_at.expect("message delivered");
        // Cut-throughs: hop 1 at 100 (out leading frame), hop 2 at 200,
        // hop 3 at 600 (after the slow hop's 400ns leading frame). The
        // slow hop charges 4 × 400 = 1600ns from 200 → done at 1800,
        // while the in-NIC finishes at 600 + 400 = 1000 — delivery is
        // gated on the bottleneck hop, not the last one.
        assert_eq!(t, ns(1_800));
    }

    #[test]
    fn pipelined_hops_overlap_like_cut_through() {
        // A single-hop-rate path: each extra hop adds one leading-frame
        // service, not one full train service (frame-granularity
        // pipelining, the O(1)-events analogue of store-and-forward).
        let s = spec(8, 250, 8_000);
        let mut one = FabricPath::new(ns(0), 1);
        one.send(ns(0), vec![s, s]);
        let mut t1 = None;
        while !one.is_idle() {
            let st = one.step();
            if st.delivered.is_some() {
                t1 = Some(st.at);
            }
        }
        let mut three = FabricPath::new(ns(0), 3);
        three.send(ns(0), vec![s, s, s, s]);
        let mut t3 = None;
        while !three.is_idle() {
            let st = three.step();
            if st.delivered.is_some() {
                t3 = Some(st.at);
            }
        }
        let (t1, t3) = (t1.unwrap(), t3.unwrap());
        assert_eq!(
            t3.as_ns() - t1.as_ns(),
            2 * 250,
            "two extra hops cost two leading-frame services"
        );
    }
}
