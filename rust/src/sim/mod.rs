//! Discrete-event simulation core.
//!
//! The paper's predictor is "a discrete-event simulator" instantiating "a
//! queue-based storage system model" (§2.3–2.4). This module provides the
//! domain-independent machinery: a virtual clock and event queue
//! ([`engine`]), FIFO single-server service stations ([`station`]) —
//! the "queues" every system component (manager, storage, client, NIC
//! in/out) is modeled as — and the routed network fabric ([`fabric`]):
//! topology resolution (star / two-tier rack + core) and the multi-hop
//! cut-through transfer protocol with its star-degenerate oracle.
//!
//! Both the coarse predictor (`model/`) and the high-fidelity testbed
//! (`testbed/`) run on this engine; they differ only in the protocol
//! detail of their event handlers (DESIGN.md §4).

pub mod engine;
pub mod fabric;
pub mod station;

pub use engine::{EventToken, Scheduler, SimState, Simulation};
pub use fabric::{FabricPlan, Route};
pub use station::{FairStation, Station, StationStats};
// The linear-scan / single-pair equivalence oracles, compiled for the
// integration proptests but kept out of the supported API surface.
#[doc(hidden)]
pub use fabric::RefStarFabric;
#[doc(hidden)]
pub use station::RefFairStation;
