//! Discrete-event simulation core.
//!
//! The paper's predictor is "a discrete-event simulator" instantiating "a
//! queue-based storage system model" (§2.3–2.4). This module provides the
//! domain-independent machinery: a virtual clock and event queue
//! ([`engine`]) and FIFO single-server service stations ([`station`]) —
//! the "queues" every system component (manager, storage, client, NIC
//! in/out) is modeled as.
//!
//! Both the coarse predictor (`model/`) and the high-fidelity testbed
//! (`testbed/`) run on this engine; they differ only in the protocol
//! detail of their event handlers (DESIGN.md §4).

pub mod engine;
pub mod station;

pub use engine::{EventToken, Scheduler, SimState, Simulation};
pub use station::{FairStation, Station, StationStats};
// The linear-scan equivalence oracle, compiled for the integration
// proptests but kept out of the supported API surface.
#[doc(hidden)]
pub use station::RefFairStation;
