//! FIFO single-server service station — the "queue" of the queue-based model.
//!
//! Every system component in the paper's model (manager, storage, client,
//! and each NIC's in/out side) "is modeled as a service that takes
//! requests from its queue". [`Station`] implements that: items arrive
//! with a service time; at most one is in service; the rest wait FIFO.
//!
//! The station does not own the clock — the caller schedules a completion
//! event at the time `arrive`/`complete` return, keeping the station
//! reusable across event types. Utilization and queueing statistics are
//! tracked for reports and model debugging (the paper's §5 "detect
//! performance anomalies" use case).
//!
//! ## Trains (bulk arrivals)
//!
//! The network fast path services a whole frame *train* (all frames of one
//! message) as a single entry instead of one entry per frame
//! ([`Station::arrive_train`]). Statistics stay exact under that
//! aggregation: every entry carries a unit count (frames), so `arrivals`,
//! `departures` and the queue-length integral are counted in frames in
//! both modes, and a burst train entering service adds the intra-train
//! waiting integral (`unit_svc · u(u−1)/2` — frame *i* of a burst waits
//! `i · unit_svc` behind its siblings) analytically.

use crate::util::units::SimTime;
use std::collections::VecDeque;

/// Accumulated station statistics.
#[derive(Clone, Debug, Default)]
pub struct StationStats {
    /// Units (frames for NIC stations, messages elsewhere) arrived.
    pub arrivals: u64,
    /// Units departed.
    pub departures: u64,
    /// Integral of busy state over time (ns of busy time).
    pub busy_ns: u64,
    /// Integral of queue length over time (ns·units), excluding in-service.
    pub qlen_ns: u128,
    /// Max queue length observed (waiting units, including the instant a
    /// burst train arrives).
    pub max_qlen: usize,
    last_change_ns: u64,
}

impl StationStats {
    #[inline(always)]
    fn advance(&mut self, now: SimTime, busy: bool, qlen: u64) {
        let dt = now.as_ns().saturating_sub(self.last_change_ns);
        if dt != 0 {
            if busy {
                self.busy_ns += dt;
            }
            if qlen != 0 {
                self.qlen_ns += dt as u128 * qlen as u128;
            }
            self.last_change_ns = now.as_ns();
        }
        if qlen as usize > self.max_qlen {
            self.max_qlen = qlen as usize;
        }
    }

    /// Utilization over `[0, horizon]`.
    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon.as_ns() == 0 {
            0.0
        } else {
            self.busy_ns as f64 / horizon.as_ns() as f64
        }
    }

    /// Time-averaged waiting-queue length over `[0, horizon]`.
    pub fn mean_qlen(&self, horizon: SimTime) -> f64 {
        if horizon.as_ns() == 0 {
            0.0
        } else {
            self.qlen_ns as f64 / horizon.as_ns() as f64
        }
    }
}

/// A waiting entry: the item, its service time, its unit count, and the
/// per-unit service time used for the analytic intra-train wait when it
/// eventually starts service.
#[derive(Debug)]
struct Waiter<T> {
    item: T,
    svc: SimTime,
    units: u64,
    unit_svc: SimTime,
}

/// A FIFO single-server queue of items `T`.
#[derive(Debug)]
pub struct Station<T> {
    in_service: Option<(T, u64)>,
    waiting: VecDeque<Waiter<T>>,
    /// Total units across waiting entries (what `queue_len` reports).
    waiting_units: u64,
    pub stats: StationStats,
}

impl<T> Default for Station<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Station<T> {
    pub fn new() -> Self {
        Station {
            in_service: None,
            waiting: VecDeque::new(),
            waiting_units: 0,
            stats: StationStats::default(),
        }
    }

    pub fn is_busy(&self) -> bool {
        self.in_service.is_some()
    }

    /// Waiting units (frames for NIC stations; identical to the item count
    /// when every entry is a single unit).
    pub fn queue_len(&self) -> usize {
        self.waiting_units as usize
    }

    /// The item currently in service, if any.
    pub fn in_service(&self) -> Option<&T> {
        self.in_service.as_ref().map(|(item, _)| item)
    }

    /// Intra-train waiting integral for a burst of `units` equal frames
    /// entering service: frame `i` waits `i · unit_svc`.
    #[inline(always)]
    fn burst_wait_ns(units: u64, unit_svc: SimTime) -> u128 {
        if units < 2 {
            0
        } else {
            unit_svc.as_ns() as u128 * (units as u128 * (units as u128 - 1) / 2)
        }
    }

    /// An item arrives needing `svc` service time. If the server is idle
    /// it enters service and the completion time is returned — the caller
    /// must schedule a completion event for it. Otherwise it waits.
    #[must_use = "schedule a completion event when Some(t) is returned"]
    #[inline]
    pub fn arrive(&mut self, now: SimTime, item: T, svc: SimTime) -> Option<SimTime> {
        self.arrive_train(now, item, svc, 1, SimTime::ZERO)
    }

    /// A train of `units` frames arrives as one analytically-drained entry
    /// with aggregate service time `svc`. `unit_svc` is the per-unit
    /// (full-frame) service time, used to account the intra-train waiting
    /// the per-frame path would have measured when the units arrive as a
    /// simultaneous burst; pass `SimTime::ZERO` for paced trains (e.g. the
    /// receive side, where frames trickle in at the service rate and never
    /// wait on each other).
    #[must_use = "schedule a completion event when Some(t) is returned"]
    #[inline]
    pub fn arrive_train(
        &mut self,
        now: SimTime,
        item: T,
        svc: SimTime,
        units: u64,
        unit_svc: SimTime,
    ) -> Option<SimTime> {
        debug_assert!(units >= 1);
        self.stats.advance(now, self.is_busy(), self.waiting_units);
        self.stats.arrivals += units;
        if self.in_service.is_none() {
            self.in_service = Some((item, units));
            self.stats.qlen_ns += Self::burst_wait_ns(units, unit_svc);
            // The instantaneous per-frame queue right after a burst.
            if unit_svc > SimTime::ZERO {
                let peak = (self.waiting_units + units - 1) as usize;
                self.stats.max_qlen = self.stats.max_qlen.max(peak);
            }
            Some(now + svc)
        } else {
            self.waiting_units += units;
            if unit_svc > SimTime::ZERO {
                self.stats.max_qlen = self.stats.max_qlen.max(self.waiting_units as usize);
            }
            self.waiting.push_back(Waiter { item, svc, units, unit_svc });
            None
        }
    }

    /// The in-service item completes. Returns it, plus the completion time
    /// of the next item if one starts service (caller schedules it).
    #[must_use = "schedule the next completion when the second field is Some"]
    #[inline]
    pub fn complete(&mut self, now: SimTime) -> (T, Option<SimTime>) {
        self.stats.advance(now, true, self.waiting_units);
        let (done, done_units) = self.in_service.take().expect("complete() on idle station");
        self.stats.departures += done_units;
        let next = self.waiting.pop_front().map(|w| {
            self.waiting_units -= w.units;
            self.stats.qlen_ns += Self::burst_wait_ns(w.units, w.unit_svc);
            self.in_service = Some((w.item, w.units));
            now + w.svc
        });
        (done, next)
    }

    /// Finalize stats bookkeeping at the end of a run.
    pub fn finish(&mut self, now: SimTime) {
        self.stats.advance(now, self.is_busy(), self.waiting_units);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ns(x: u64) -> SimTime {
        SimTime::from_ns(x)
    }

    #[test]
    fn idle_arrival_starts_service() {
        let mut st: Station<&str> = Station::new();
        let done = st.arrive(ns(100), "a", ns(50));
        assert_eq!(done, Some(ns(150)));
        assert!(st.is_busy());
        assert_eq!(st.queue_len(), 0);
        assert_eq!(st.in_service(), Some(&"a"));
    }

    #[test]
    fn busy_arrival_queues_fifo() {
        let mut st: Station<u32> = Station::new();
        assert!(st.arrive(ns(0), 1, ns(10)).is_some());
        assert!(st.arrive(ns(1), 2, ns(10)).is_none());
        assert!(st.arrive(ns(2), 3, ns(5)).is_none());
        assert_eq!(st.queue_len(), 2);

        let (done, next) = st.complete(ns(10));
        assert_eq!(done, 1);
        assert_eq!(next, Some(ns(20))); // item 2, svc 10, starting at 10
        let (done, next) = st.complete(ns(20));
        assert_eq!(done, 2);
        assert_eq!(next, Some(ns(25))); // item 3, svc 5
        let (done, next) = st.complete(ns(25));
        assert_eq!(done, 3);
        assert_eq!(next, None);
        assert!(!st.is_busy());
    }

    #[test]
    #[should_panic(expected = "idle station")]
    fn completing_idle_station_panics() {
        let mut st: Station<u32> = Station::new();
        let _ = st.complete(ns(1));
    }

    #[test]
    fn utilization_accounts_busy_time() {
        let mut st: Station<u32> = Station::new();
        // busy [0,10) and [20,30), idle elsewhere, horizon 40.
        let t = st.arrive(ns(0), 1, ns(10)).unwrap();
        let _ = st.complete(t);
        let t = st.arrive(ns(20), 2, ns(10)).unwrap();
        let _ = st.complete(t);
        st.finish(ns(40));
        assert!((st.stats.utilization(ns(40)) - 0.5).abs() < 1e-9);
        assert_eq!(st.stats.arrivals, 2);
        assert_eq!(st.stats.departures, 2);
    }

    #[test]
    fn queue_length_integral() {
        let mut st: Station<u32> = Station::new();
        let _ = st.arrive(ns(0), 1, ns(100)).unwrap();
        assert!(st.arrive(ns(0), 2, ns(100)).is_none()); // waits [0,100)
        let (_, next) = st.complete(ns(100));
        assert!(next.is_some());
        let _ = st.complete(ns(200));
        st.finish(ns(200));
        // one waiter for 100ns over a 200ns horizon -> mean qlen 0.5
        assert!((st.stats.mean_qlen(ns(200)) - 0.5).abs() < 1e-9);
        assert_eq!(st.stats.max_qlen, 1);
    }

    #[test]
    fn train_matches_per_frame_integrals() {
        // 4 equal frames of 10ns arriving together at an idle station.
        let mut per_frame: Station<u32> = Station::new();
        for i in 0..4 {
            let r = per_frame.arrive(ns(0), i, ns(10));
            assert_eq!(r.is_some(), i == 0);
        }
        let mut t = ns(10);
        loop {
            let (_, next) = per_frame.complete(t);
            match next {
                Some(n) => t = n,
                None => break,
            }
        }
        per_frame.finish(ns(40));

        let mut train: Station<u32> = Station::new();
        let done = train.arrive_train(ns(0), 9, ns(40), 4, ns(10)).unwrap();
        assert_eq!(done, ns(40));
        let _ = train.complete(ns(40));
        train.finish(ns(40));

        assert_eq!(per_frame.stats.busy_ns, train.stats.busy_ns);
        assert_eq!(per_frame.stats.qlen_ns, train.stats.qlen_ns, "intra-train wait integral");
        assert_eq!(per_frame.stats.arrivals, train.stats.arrivals);
        assert_eq!(per_frame.stats.departures, train.stats.departures);
        assert_eq!(per_frame.stats.max_qlen, train.stats.max_qlen);
    }

    #[test]
    fn queued_train_counts_units_while_waiting() {
        let mut st: Station<u32> = Station::new();
        let _ = st.arrive(ns(0), 1, ns(100)).unwrap();
        // An 8-frame train queues behind: 8 units waiting for 100ns.
        assert!(st.arrive_train(ns(0), 2, ns(80), 8, ns(10)).is_none());
        assert_eq!(st.queue_len(), 8);
        let (_, next) = st.complete(ns(100));
        assert_eq!(next, Some(ns(180)));
        assert_eq!(st.queue_len(), 0);
        let _ = st.complete(ns(180));
        st.finish(ns(180));
        // Waiting integral: 8 units × 100ns (queued) + 10·(8·7/2) intra-train.
        assert_eq!(st.stats.qlen_ns, 800 + 280);
        assert_eq!(st.stats.arrivals, 9);
        assert_eq!(st.stats.departures, 9);
    }

    #[test]
    fn paced_train_adds_no_intra_wait() {
        let mut st: Station<u32> = Station::new();
        let done = st.arrive_train(ns(0), 1, ns(40), 4, SimTime::ZERO).unwrap();
        let _ = st.complete(done);
        st.finish(done);
        assert_eq!(st.stats.qlen_ns, 0, "receive-side trains are paced, not bursty");
        assert_eq!(st.stats.arrivals, 4);
    }
}
