//! FIFO single-server service station — the "queue" of the queue-based model.
//!
//! Every system component in the paper's model (manager, storage, client,
//! and each NIC's in/out side) "is modeled as a service that takes
//! requests from its queue". [`Station`] implements that: items arrive
//! with a service time; at most one is in service; the rest wait FIFO.
//!
//! The station does not own the clock — the caller schedules a completion
//! event at the time `arrive`/`complete` return, keeping the station
//! reusable across event types. Utilization and queueing statistics are
//! tracked for reports and model debugging (the paper's §5 "detect
//! performance anomalies" use case).

use crate::util::units::SimTime;
use std::collections::VecDeque;

/// Accumulated station statistics.
#[derive(Clone, Debug, Default)]
pub struct StationStats {
    pub arrivals: u64,
    pub departures: u64,
    /// Integral of busy state over time (ns of busy time).
    pub busy_ns: u64,
    /// Integral of queue length over time (ns·items), excluding in-service.
    pub qlen_ns: u128,
    /// Max queue length observed.
    pub max_qlen: usize,
    last_change_ns: u64,
}

impl StationStats {
    #[inline(always)]
    fn advance(&mut self, now: SimTime, busy: bool, qlen: usize) {
        let dt = now.as_ns().saturating_sub(self.last_change_ns);
        if dt != 0 {
            if busy {
                self.busy_ns += dt;
            }
            if qlen != 0 {
                self.qlen_ns += dt as u128 * qlen as u128;
            }
            self.last_change_ns = now.as_ns();
        }
        if qlen > self.max_qlen {
            self.max_qlen = qlen;
        }
    }

    /// Utilization over `[0, horizon]`.
    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon.as_ns() == 0 {
            0.0
        } else {
            self.busy_ns as f64 / horizon.as_ns() as f64
        }
    }

    /// Time-averaged waiting-queue length over `[0, horizon]`.
    pub fn mean_qlen(&self, horizon: SimTime) -> f64 {
        if horizon.as_ns() == 0 {
            0.0
        } else {
            self.qlen_ns as f64 / horizon.as_ns() as f64
        }
    }
}

/// A FIFO single-server queue of items `T`.
#[derive(Debug)]
pub struct Station<T> {
    in_service: Option<T>,
    waiting: VecDeque<(T, SimTime)>,
    pub stats: StationStats,
}

impl<T> Default for Station<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Station<T> {
    pub fn new() -> Self {
        Station { in_service: None, waiting: VecDeque::new(), stats: StationStats::default() }
    }

    pub fn is_busy(&self) -> bool {
        self.in_service.is_some()
    }

    pub fn queue_len(&self) -> usize {
        self.waiting.len()
    }

    /// An item arrives needing `svc` service time. If the server is idle
    /// it enters service and the completion time is returned — the caller
    /// must schedule a completion event for it. Otherwise it waits.
    #[must_use = "schedule a completion event when Some(t) is returned"]
    #[inline]
    pub fn arrive(&mut self, now: SimTime, item: T, svc: SimTime) -> Option<SimTime> {
        self.stats.advance(now, self.is_busy(), self.waiting.len());
        self.stats.arrivals += 1;
        if self.in_service.is_none() {
            self.in_service = Some(item);
            Some(now + svc)
        } else {
            self.waiting.push_back((item, svc));
            None
        }
    }

    /// The in-service item completes. Returns it, plus the completion time
    /// of the next item if one starts service (caller schedules it).
    #[must_use = "schedule the next completion when the second field is Some"]
    #[inline]
    pub fn complete(&mut self, now: SimTime) -> (T, Option<SimTime>) {
        self.stats.advance(now, true, self.waiting.len());
        self.stats.departures += 1;
        let done = self.in_service.take().expect("complete() on idle station");
        let next = self.waiting.pop_front().map(|(item, svc)| {
            self.in_service = Some(item);
            now + svc
        });
        (done, next)
    }

    /// Finalize stats bookkeeping at the end of a run.
    pub fn finish(&mut self, now: SimTime) {
        self.stats.advance(now, self.is_busy(), self.waiting.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ns(x: u64) -> SimTime {
        SimTime::from_ns(x)
    }

    #[test]
    fn idle_arrival_starts_service() {
        let mut st: Station<&str> = Station::new();
        let done = st.arrive(ns(100), "a", ns(50));
        assert_eq!(done, Some(ns(150)));
        assert!(st.is_busy());
        assert_eq!(st.queue_len(), 0);
    }

    #[test]
    fn busy_arrival_queues_fifo() {
        let mut st: Station<u32> = Station::new();
        assert!(st.arrive(ns(0), 1, ns(10)).is_some());
        assert!(st.arrive(ns(1), 2, ns(10)).is_none());
        assert!(st.arrive(ns(2), 3, ns(5)).is_none());
        assert_eq!(st.queue_len(), 2);

        let (done, next) = st.complete(ns(10));
        assert_eq!(done, 1);
        assert_eq!(next, Some(ns(20))); // item 2, svc 10, starting at 10
        let (done, next) = st.complete(ns(20));
        assert_eq!(done, 2);
        assert_eq!(next, Some(ns(25))); // item 3, svc 5
        let (done, next) = st.complete(ns(25));
        assert_eq!(done, 3);
        assert_eq!(next, None);
        assert!(!st.is_busy());
    }

    #[test]
    #[should_panic(expected = "idle station")]
    fn completing_idle_station_panics() {
        let mut st: Station<u32> = Station::new();
        let _ = st.complete(ns(1));
    }

    #[test]
    fn utilization_accounts_busy_time() {
        let mut st: Station<u32> = Station::new();
        // busy [0,10) and [20,30), idle elsewhere, horizon 40.
        let t = st.arrive(ns(0), 1, ns(10)).unwrap();
        let _ = st.complete(t);
        let t = st.arrive(ns(20), 2, ns(10)).unwrap();
        let _ = st.complete(t);
        st.finish(ns(40));
        assert!((st.stats.utilization(ns(40)) - 0.5).abs() < 1e-9);
        assert_eq!(st.stats.arrivals, 2);
        assert_eq!(st.stats.departures, 2);
    }

    #[test]
    fn queue_length_integral() {
        let mut st: Station<u32> = Station::new();
        let _ = st.arrive(ns(0), 1, ns(100)).unwrap();
        assert!(st.arrive(ns(0), 2, ns(100)).is_none()); // waits [0,100)
        let (_, next) = st.complete(ns(100));
        assert!(next.is_some());
        let _ = st.complete(ns(200));
        st.finish(ns(200));
        // one waiter for 100ns over a 200ns horizon -> mean qlen 0.5
        assert!((st.stats.mean_qlen(ns(200)) - 0.5).abs() < 1e-9);
        assert_eq!(st.stats.max_qlen, 1);
    }
}
