//! FIFO single-server service station — the "queue" of the queue-based model.
//!
//! Every system component in the paper's model (manager, storage, client,
//! and each NIC's in/out side) "is modeled as a service that takes
//! requests from its queue". [`Station`] implements that: items arrive
//! with a service time; at most one is in service; the rest wait FIFO.
//!
//! The station does not own the clock — the caller schedules a completion
//! event at the time `arrive`/`complete` return, keeping the station
//! reusable across event types. Utilization and queueing statistics are
//! tracked for reports and model debugging (the paper's §5 "detect
//! performance anomalies" use case).
//!
//! ## Trains (bulk arrivals)
//!
//! The network fast path services a whole frame *train* (all frames of one
//! message) as a single entry instead of one entry per frame
//! ([`Station::arrive_train`]). Statistics stay exact under that
//! aggregation: every entry carries a unit count (frames), so `arrivals`,
//! `departures` and the queue-length integral are counted in frames in
//! both modes, and a burst train entering service adds the intra-train
//! waiting integral (`unit_svc · u(u−1)/2` — frame *i* of a burst waits
//! `i · unit_svc` behind its siblings) analytically.
//!
//! ## Weighted-fair train service ([`FairStation`])
//!
//! A FIFO of whole trains serializes concurrent messages at a contended
//! in-NIC, while the per-frame path interleaves their frames in arrival
//! order — under heavy incast the two diverge on *per-message* completion
//! times even though both are work-conserving. [`FairStation`] closes that
//! gap without giving back the O(1) event count: concurrent trains share
//! the server generalized-processor-sharing style with byte-proportional
//! weights, so equal-sized trains arriving together finish together (as
//! their interleaved frames would), a lone train gets the full rate (the
//! uncontended path stays bit-exact), and the server is busy exactly when
//! work is pending (busy integrals are conserved).
//!
//! The same server is reused unchanged for the routed fabric's core
//! links (`sim::fabric`): an oversubscribed rack uplink is just another
//! weighted-fair station whose capacity is a fraction of the host line
//! rate, so cross-rack trains share it byte-proportionally exactly like
//! incast trains share a receive NIC.
//!
//! The implementation is **virtual-time** GPS: a virtual clock advances at
//! `1 / Σ weights` of real time while the server is busy, every train is
//! stamped once, at arrival, with the virtual *finish tag*
//! `V + service / weight`, and — because tags never change and `V` is
//! monotone — the completion order is simply ascending tag order. A
//! `BinaryHeap` of tags plus incrementally maintained weight/unit totals
//! make every operation O(log m) in the m concurrently active trains; no
//! per-event drain over the actives, no linear head scan (the O(m²)
//! busy-period cost that capped wide incast, see PERF.md §Frame path).
//! The announced real completion time of the minimal tag *does* change
//! whenever membership changes — the caller withdraws the superseded
//! announcement through the engine's cancellable events
//! (`sim::engine::EventToken`) rather than receiving stale completions.
//!
//! [`RefFairStation`] keeps the old linear-scan shape (per-event walk over
//! the actives, scanned totals) computing the *same* virtual-time formulas
//! ([`vtmath`]): it is the O(m) reference oracle the equivalence proptests
//! drive in lockstep with [`FairStation`], and every announced time,
//! completion, and statistic must match bit-for-bit.

use crate::util::units::SimTime;
use std::collections::{BinaryHeap, VecDeque};

/// Accumulated station statistics.
#[derive(Clone, Debug, Default)]
pub struct StationStats {
    /// Units (frames for NIC stations, messages elsewhere) arrived.
    pub arrivals: u64,
    /// Units departed.
    pub departures: u64,
    /// Integral of busy state over time (ns of busy time).
    pub busy_ns: u64,
    /// Integral of queue length over time (ns·units), excluding in-service.
    pub qlen_ns: u128,
    /// Max queue length observed (waiting units, including the instant a
    /// burst train arrives).
    pub max_qlen: usize,
    last_change_ns: u64,
}

impl StationStats {
    #[inline(always)]
    fn advance(&mut self, now: SimTime, busy: bool, qlen: u64) {
        let dt = now.as_ns().saturating_sub(self.last_change_ns);
        if dt != 0 {
            if busy {
                self.busy_ns += dt;
            }
            if qlen != 0 {
                self.qlen_ns += dt as u128 * qlen as u128;
            }
            self.last_change_ns = now.as_ns();
        }
        if qlen as usize > self.max_qlen {
            self.max_qlen = qlen as usize;
        }
    }

    /// Utilization over `[0, horizon]`.
    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon.as_ns() == 0 {
            0.0
        } else {
            self.busy_ns as f64 / horizon.as_ns() as f64
        }
    }

    /// Time-averaged waiting-queue length over `[0, horizon]`.
    pub fn mean_qlen(&self, horizon: SimTime) -> f64 {
        if horizon.as_ns() == 0 {
            0.0
        } else {
            self.qlen_ns as f64 / horizon.as_ns() as f64
        }
    }

    /// [`StationStats::mean_qlen`] with an externally accounted
    /// over-count (ns·units) subtracted from the queue integral first.
    /// The model engine uses this to report analytically-paced in-NIC
    /// depths under bulk frame aggregation, where a whole train posts its
    /// frame-units at once instead of pacing them in (the integral itself
    /// stays raw so the lockstep Ref* oracles keep matching bit-for-bit).
    pub fn mean_qlen_corrected(&self, horizon: SimTime, overcount_ns: u128) -> f64 {
        if horizon.as_ns() == 0 {
            0.0
        } else {
            self.qlen_ns.saturating_sub(overcount_ns) as f64 / horizon.as_ns() as f64
        }
    }
}

/// A waiting entry: the item, its service time, its unit count, and the
/// per-unit service time used for the analytic intra-train wait when it
/// eventually starts service.
#[derive(Clone, Debug)]
struct Waiter<T> {
    item: T,
    svc: SimTime,
    units: u64,
    unit_svc: SimTime,
}

/// A FIFO single-server queue of items `T`.
#[derive(Clone, Debug)]
pub struct Station<T> {
    in_service: Option<(T, u64)>,
    waiting: VecDeque<Waiter<T>>,
    /// Total units across waiting entries (what `queue_len` reports).
    waiting_units: u64,
    pub stats: StationStats,
}

impl<T> Default for Station<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Station<T> {
    pub fn new() -> Self {
        Station {
            in_service: None,
            waiting: VecDeque::new(),
            waiting_units: 0,
            stats: StationStats::default(),
        }
    }

    pub fn is_busy(&self) -> bool {
        self.in_service.is_some()
    }

    /// Waiting units (frames for NIC stations; identical to the item count
    /// when every entry is a single unit).
    pub fn queue_len(&self) -> usize {
        self.waiting_units as usize
    }

    /// The item currently in service, if any.
    pub fn in_service(&self) -> Option<&T> {
        self.in_service.as_ref().map(|(item, _)| item)
    }

    /// Intra-train waiting integral for a burst of `units` equal frames
    /// entering service: frame `i` waits `i · unit_svc`.
    #[inline(always)]
    fn burst_wait_ns(units: u64, unit_svc: SimTime) -> u128 {
        if units < 2 {
            0
        } else {
            unit_svc.as_ns() as u128 * (units as u128 * (units as u128 - 1) / 2)
        }
    }

    /// An item arrives needing `svc` service time. If the server is idle
    /// it enters service and the completion time is returned — the caller
    /// must schedule a completion event for it. Otherwise it waits.
    #[must_use = "schedule a completion event when Some(t) is returned"]
    #[inline]
    pub fn arrive(&mut self, now: SimTime, item: T, svc: SimTime) -> Option<SimTime> {
        self.arrive_train(now, item, svc, 1, SimTime::ZERO)
    }

    /// A train of `units` frames arrives as one analytically-drained entry
    /// with aggregate service time `svc`. `unit_svc` is the per-unit
    /// (full-frame) service time, used to account the intra-train waiting
    /// the per-frame path would have measured when the units arrive as a
    /// simultaneous burst; pass `SimTime::ZERO` for paced trains (e.g. the
    /// receive side, where frames trickle in at the service rate and never
    /// wait on each other).
    #[must_use = "schedule a completion event when Some(t) is returned"]
    #[inline]
    pub fn arrive_train(
        &mut self,
        now: SimTime,
        item: T,
        svc: SimTime,
        units: u64,
        unit_svc: SimTime,
    ) -> Option<SimTime> {
        debug_assert!(units >= 1);
        self.stats.advance(now, self.is_busy(), self.waiting_units);
        self.stats.arrivals += units;
        if self.in_service.is_none() {
            self.in_service = Some((item, units));
            self.stats.qlen_ns += Self::burst_wait_ns(units, unit_svc);
            // The instantaneous per-frame queue right after a burst.
            if unit_svc > SimTime::ZERO {
                let peak = (self.waiting_units + units - 1) as usize;
                self.stats.max_qlen = self.stats.max_qlen.max(peak);
            }
            Some(now + svc)
        } else {
            self.waiting_units += units;
            if unit_svc > SimTime::ZERO {
                self.stats.max_qlen = self.stats.max_qlen.max(self.waiting_units as usize);
            }
            self.waiting.push_back(Waiter { item, svc, units, unit_svc });
            None
        }
    }

    /// The in-service item completes. Returns it, plus the completion time
    /// of the next item if one starts service (caller schedules it).
    #[must_use = "schedule the next completion when the second field is Some"]
    #[inline]
    pub fn complete(&mut self, now: SimTime) -> (T, Option<SimTime>) {
        self.stats.advance(now, true, self.waiting_units);
        let (done, done_units) = self.in_service.take().expect("complete() on idle station");
        self.stats.departures += done_units;
        let next = self.waiting.pop_front().map(|w| {
            self.waiting_units -= w.units;
            self.stats.qlen_ns += Self::burst_wait_ns(w.units, w.unit_svc);
            self.in_service = Some((w.item, w.units));
            now + w.svc
        });
        (done, next)
    }

    /// Abandon every waiting entry (a crashed node's queue): advances the
    /// statistics integrals to `now`, clears the queue, and returns the
    /// abandoned unit count. The in-service entry keeps its already-
    /// scheduled completion — the caller discards that completion's
    /// effect instead (a crashed server finishes nothing).
    pub fn drain_waiting(&mut self, now: SimTime) -> u64 {
        self.stats.advance(now, self.is_busy(), self.waiting_units);
        let dropped = self.waiting_units;
        self.waiting.clear();
        self.waiting_units = 0;
        dropped
    }

    /// Finalize stats bookkeeping at the end of a run.
    pub fn finish(&mut self, now: SimTime) {
        self.stats.advance(now, self.is_busy(), self.waiting_units);
    }
}

/// The virtual-time GPS formulas, shared verbatim by [`FairStation`] and
/// [`RefFairStation`] so the two cannot disagree by a rounding mode: the
/// equivalence proptests assert bit-identical announced times, and these
/// helpers are the single place the floating-point arithmetic lives.
///
/// All inputs are exact integers (ns, bytes) represented in `f64`; the
/// only inexact operations are the two divisions and the final product.
pub mod vtmath {
    /// Virtual time after `dt_ns` of busy real time at total weight `w`.
    #[inline(always)]
    pub fn advance(vt: f64, dt_ns: u64, total_weight: f64) -> f64 {
        vt + dt_ns as f64 / total_weight
    }

    /// Virtual finish tag of a train arriving at virtual time `vt`
    /// needing `svc_ns` dedicated service at fair-share weight `weight`.
    #[inline(always)]
    pub fn finish_tag(vt: f64, svc_ns: u64, weight: f64) -> f64 {
        vt + svc_ns as f64 / weight
    }

    /// Real ns until the tag `tag` is reached from virtual time `vt` at
    /// total weight `w`. Rounds up to the next whole ns and clamps at
    /// zero (an announcement rounded up can leave `vt` a hair past the
    /// next tag when its event fires).
    #[inline(always)]
    pub fn completion_dt(tag: f64, vt: f64, total_weight: f64) -> u64 {
        ((tag - vt) * total_weight).max(0.0).ceil() as u64
    }
}

/// An active train in virtual-time weighted-fair service. The finish tag
/// is assigned once, at arrival, and never changes; the heap orders by
/// `(tag, seq)`.
#[derive(Clone, Debug)]
struct VtEntry<T> {
    /// Virtual finish tag: `arrival_vt + svc / weight`.
    tag: f64,
    /// Arrival order — FIFO tie-break between equal tags.
    seq: u64,
    /// Virtual time at arrival (uncontended-exactness fast path).
    arrival_vt: f64,
    /// Dedicated service in ns (exact integer).
    svc_ns: u64,
    /// Service share weight (wire bytes of the train; ≥ 1).
    weight: f64,
    /// Frames aggregated in this entry (stats unit).
    units: u64,
    item: T,
}

impl<T> PartialEq for VtEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl<T> Eq for VtEntry<T> {}
impl<T> PartialOrd for VtEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for VtEntry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap: reverse for minimal (tag, seq) first.
        // Tags are finite (weights ≥ 1, service bounded), never NaN.
        other
            .tag
            .partial_cmp(&self.tag)
            .expect("finish tags are never NaN")
            .then(other.seq.cmp(&self.seq))
    }
}

/// A weighted-fair (GPS-style) shared server for frame trains, in
/// virtual time.
///
/// While `m` entries are active, entry `i` is served at rate `w_i / Σ w`
/// of the server capacity; with byte-proportional weights and service
/// time proportional to bytes, every entry's normalized remaining work
/// decays at the same virtual rate, so completions keep arrival order
/// among same-rate trains and a lone train is served at exactly the full
/// rate — the uncontended case matches the FIFO station bit-for-bit.
///
/// Costs are O(log m) per arrival/completion: finish tags are static, so
/// the completion order is the heap order, and the weight/unit totals are
/// maintained incrementally — no per-event walk over the actives.
///
/// The caller owns the clock: `arrive` and `complete` return the current
/// head's completion time. An arrival changes the shares and therefore
/// the head's *real* completion instant, so the time returned by `arrive`
/// **supersedes** any previously announced completion — the caller must
/// withdraw the old event (the model uses `Scheduler::at_cancellable` /
/// `cancel`) and schedule the new one. `complete` must consequently only
/// ever fire for the one live announcement.
#[derive(Clone, Debug)]
pub struct FairStation<T> {
    /// Active trains, min-heap by (finish tag, seq).
    active: BinaryHeap<VtEntry<T>>,
    /// Σ weights of the active trains. Weights are integers, so this
    /// incremental total is exact (and returns to exactly 0.0 at idle).
    total_weight: f64,
    /// Σ units of the active trains.
    total_units: u64,
    /// Virtual time within the current busy period (reset at idle so
    /// precision cannot decay across a long run).
    vt: f64,
    /// Monotone arrival counter (FIFO tie-break).
    seq: u64,
    /// Time the shared service was last advanced to, in ns.
    last_ns: u64,
    pub stats: StationStats,
}

impl<T> Default for FairStation<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> FairStation<T> {
    pub fn new() -> Self {
        FairStation {
            active: BinaryHeap::new(),
            total_weight: 0.0,
            total_units: 0,
            vt: 0.0,
            seq: 0,
            last_ns: 0,
            stats: StationStats::default(),
        }
    }

    pub fn is_busy(&self) -> bool {
        !self.active.is_empty()
    }

    /// Waiting units: every active train's frames except the head's — the
    /// analogue of the FIFO station's waiting queue (the earliest finisher
    /// plays the role of the in-service entry). Used both for reports and
    /// as the train-weighted queue depth the SYN-drop/mux laws observe.
    /// O(1): totals are incremental and the head is the heap top.
    pub fn queue_len(&self) -> usize {
        match self.active.peek() {
            None => 0,
            Some(head) => (self.total_units - head.units) as usize,
        }
    }

    /// Advance the shared service to `now`, charging stats for the span.
    /// O(1): entries are untouched — only the virtual clock moves.
    fn drain(&mut self, now: SimTime) {
        let now_ns = now.as_ns();
        let dt = now_ns.saturating_sub(self.last_ns);
        let busy = self.is_busy();
        let qlen = self.queue_len() as u64;
        self.stats.advance(now, busy, qlen);
        if busy && dt != 0 {
            self.vt = vtmath::advance(self.vt, dt, self.total_weight);
        }
        self.last_ns = now_ns;
    }

    /// Completion time of the current head under the current membership.
    /// Only valid immediately after `drain` (uses `last_ns` as "now").
    ///
    /// A lone train that has not shared the server since it arrived is
    /// announced at exactly `arrival + svc` (integer arithmetic): the
    /// uncontended bulk path must match the FIFO station bit-for-bit,
    /// and `(tag − vt) · w` could round a whole-ns value across the next
    /// integer where the dedicated service itself cannot.
    fn head_completion(&self) -> Option<SimTime> {
        let e = self.active.peek()?;
        let dt = if self.active.len() == 1 && e.arrival_vt == self.vt {
            e.svc_ns
        } else {
            vtmath::completion_dt(e.tag, self.vt, self.total_weight)
        };
        Some(SimTime::from_ns(self.last_ns.saturating_add(dt)))
    }

    /// A train of `units` frames arrives with aggregate dedicated service
    /// `svc` and fair-share weight `weight` (wire bytes; clamped to ≥ 1 so
    /// zero-byte control trains still get a share). `extra_wait_ns` is
    /// charged to the waiting integral analytically — the caller passes
    /// the per-frame path's partial-last-frame wait (`full − last` when
    /// the train's final wire frame is short) so the aggregated integrals
    /// stay exact for arbitrary wire sizes.
    ///
    /// Returns the head's completion time, superseding any previously
    /// announced completion — cancel the old event and schedule this one.
    #[must_use = "schedule the returned completion event (and cancel the superseded one)"]
    pub fn arrive(
        &mut self,
        now: SimTime,
        item: T,
        svc: SimTime,
        units: u64,
        weight: u64,
        extra_wait_ns: u64,
    ) -> SimTime {
        debug_assert!(units >= 1);
        let weight = weight.max(1) as f64;
        self.drain(now);
        self.stats.arrivals += units;
        self.stats.qlen_ns += extra_wait_ns as u128;
        self.seq += 1;
        self.active.push(VtEntry {
            tag: vtmath::finish_tag(self.vt, svc.as_ns(), weight),
            seq: self.seq,
            arrival_vt: self.vt,
            svc_ns: svc.as_ns(),
            weight,
            units,
            item,
        });
        self.total_weight += weight;
        self.total_units += units;
        let q = self.queue_len();
        if q > self.stats.max_qlen {
            self.stats.max_qlen = q;
        }
        self.head_completion().expect("just pushed an entry")
    }

    /// The (live) announced completion fires: pop the finished head and,
    /// if trains remain, return the next head's completion to schedule.
    /// The engine-level cancellation guarantees no stale completion is
    /// ever delivered, so firing on an idle station is a caller bug.
    #[must_use = "schedule the next completion when the second field is Some"]
    pub fn complete(&mut self, now: SimTime) -> (T, Option<SimTime>) {
        self.drain(now);
        let e = self.active.pop().expect("complete() on idle fair station");
        self.stats.departures += e.units;
        self.total_weight -= e.weight;
        self.total_units -= e.units;
        if self.active.is_empty() {
            // Idle: restart the busy-period virtual clock. The weight
            // total is exactly 0.0 here (integer adds/subtracts), but
            // re-zero defensively alongside vt.
            self.total_weight = 0.0;
            self.vt = 0.0;
        }
        (e.item, self.head_completion())
    }

    /// Finalize stats bookkeeping at the end of a run.
    pub fn finish(&mut self, now: SimTime) {
        self.drain(now);
    }
}

/// The O(m)-per-event linear-scan reference implementation of the
/// virtual-time weighted-fair server — the shape [`FairStation`] had
/// before the heap rewrite, retained as the equivalence oracle. Hidden
/// from the supported API: it exists for the integration proptests, and
/// nothing on a hot path may use it.
///
/// Entries are the same `VtEntry` the fast server keeps (its heap
/// ordering simply goes unused here), so the two cannot drift apart
/// field-wise. Totals are recomputed by scanning the actives, the head
/// is found by a linear minimum scan, and nothing is cached between
/// events; only the arithmetic ([`vtmath`]) is shared with
/// [`FairStation`]. Integer weight/unit sums are exact in `f64`
/// regardless of summation order, so every announced time, completion
/// and statistic must equal the fast implementation's **bit-for-bit**
/// (asserted by `prop_virtual_time_fair_station_matches_reference`).
#[doc(hidden)]
#[derive(Debug)]
pub struct RefFairStation<T> {
    active: Vec<VtEntry<T>>,
    vt: f64,
    seq: u64,
    last_ns: u64,
    pub stats: StationStats,
}

impl<T> Default for RefFairStation<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> RefFairStation<T> {
    pub fn new() -> Self {
        RefFairStation {
            active: Vec::new(),
            vt: 0.0,
            seq: 0,
            last_ns: 0,
            stats: StationStats::default(),
        }
    }

    pub fn is_busy(&self) -> bool {
        !self.active.is_empty()
    }

    fn total_weight(&self) -> f64 {
        self.active.iter().map(|e| e.weight).sum()
    }

    /// Index of the earliest finisher: minimal (tag, seq), by linear scan.
    fn head(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, e) in self.active.iter().enumerate() {
            let better = match best {
                None => true,
                Some(b) => {
                    let eb = &self.active[b];
                    e.tag < eb.tag || (e.tag == eb.tag && e.seq < eb.seq)
                }
            };
            if better {
                best = Some(i);
            }
        }
        best
    }

    pub fn queue_len(&self) -> usize {
        match self.head() {
            None => 0,
            Some(h) => {
                let total: u64 = self.active.iter().map(|e| e.units).sum();
                (total - self.active[h].units) as usize
            }
        }
    }

    fn drain(&mut self, now: SimTime) {
        let now_ns = now.as_ns();
        let dt = now_ns.saturating_sub(self.last_ns);
        let busy = self.is_busy();
        let qlen = self.queue_len() as u64;
        self.stats.advance(now, busy, qlen);
        if busy && dt != 0 {
            self.vt = vtmath::advance(self.vt, dt, self.total_weight());
        }
        self.last_ns = now_ns;
    }

    fn head_completion(&self) -> Option<SimTime> {
        let h = self.head()?;
        let e = &self.active[h];
        let dt = if self.active.len() == 1 && e.arrival_vt == self.vt {
            e.svc_ns
        } else {
            vtmath::completion_dt(e.tag, self.vt, self.total_weight())
        };
        Some(SimTime::from_ns(self.last_ns.saturating_add(dt)))
    }

    /// See [`FairStation::arrive`].
    #[must_use = "schedule the returned completion event (and cancel the superseded one)"]
    pub fn arrive(
        &mut self,
        now: SimTime,
        item: T,
        svc: SimTime,
        units: u64,
        weight: u64,
        extra_wait_ns: u64,
    ) -> SimTime {
        debug_assert!(units >= 1);
        let weight = weight.max(1) as f64;
        self.drain(now);
        self.stats.arrivals += units;
        self.stats.qlen_ns += extra_wait_ns as u128;
        self.seq += 1;
        self.active.push(VtEntry {
            tag: vtmath::finish_tag(self.vt, svc.as_ns(), weight),
            seq: self.seq,
            arrival_vt: self.vt,
            svc_ns: svc.as_ns(),
            weight,
            units,
            item,
        });
        let q = self.queue_len();
        if q > self.stats.max_qlen {
            self.stats.max_qlen = q;
        }
        self.head_completion().expect("just pushed an entry")
    }

    /// See [`FairStation::complete`].
    #[must_use = "schedule the next completion when the second field is Some"]
    pub fn complete(&mut self, now: SimTime) -> (T, Option<SimTime>) {
        self.drain(now);
        let h = self.head().expect("complete() on idle fair station");
        let e = self.active.swap_remove(h);
        self.stats.departures += e.units;
        if self.active.is_empty() {
            self.vt = 0.0;
        }
        (e.item, self.head_completion())
    }

    /// Finalize stats bookkeeping at the end of a run.
    pub fn finish(&mut self, now: SimTime) {
        self.drain(now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ns(x: u64) -> SimTime {
        SimTime::from_ns(x)
    }

    #[test]
    fn idle_arrival_starts_service() {
        let mut st: Station<&str> = Station::new();
        let done = st.arrive(ns(100), "a", ns(50));
        assert_eq!(done, Some(ns(150)));
        assert!(st.is_busy());
        assert_eq!(st.queue_len(), 0);
        assert_eq!(st.in_service(), Some(&"a"));
    }

    #[test]
    fn busy_arrival_queues_fifo() {
        let mut st: Station<u32> = Station::new();
        assert!(st.arrive(ns(0), 1, ns(10)).is_some());
        assert!(st.arrive(ns(1), 2, ns(10)).is_none());
        assert!(st.arrive(ns(2), 3, ns(5)).is_none());
        assert_eq!(st.queue_len(), 2);

        let (done, next) = st.complete(ns(10));
        assert_eq!(done, 1);
        assert_eq!(next, Some(ns(20))); // item 2, svc 10, starting at 10
        let (done, next) = st.complete(ns(20));
        assert_eq!(done, 2);
        assert_eq!(next, Some(ns(25))); // item 3, svc 5
        let (done, next) = st.complete(ns(25));
        assert_eq!(done, 3);
        assert_eq!(next, None);
        assert!(!st.is_busy());
    }

    #[test]
    fn drain_waiting_abandons_queue_but_not_in_service() {
        let mut st: Station<u32> = Station::new();
        let done = st.arrive(ns(0), 1, ns(10)).unwrap();
        assert!(st.arrive(ns(1), 2, ns(10)).is_none());
        assert!(st.arrive(ns(2), 3, ns(10)).is_none());
        assert_eq!(st.drain_waiting(ns(5)), 2, "two waiters abandoned");
        assert_eq!(st.queue_len(), 0);
        assert!(st.is_busy(), "in-service entry keeps its completion");
        let (item, next) = st.complete(done);
        assert_eq!(item, 1);
        assert_eq!(next, None, "nothing left to start");
        st.finish(ns(10));
        // Waiters queued over [1,5) and [2,5): 4 + 3 = 7 ns·units.
        assert_eq!(st.stats.qlen_ns, 7);
    }

    #[test]
    #[should_panic(expected = "idle station")]
    fn completing_idle_station_panics() {
        let mut st: Station<u32> = Station::new();
        let _ = st.complete(ns(1));
    }

    #[test]
    fn utilization_accounts_busy_time() {
        let mut st: Station<u32> = Station::new();
        // busy [0,10) and [20,30), idle elsewhere, horizon 40.
        let t = st.arrive(ns(0), 1, ns(10)).unwrap();
        let _ = st.complete(t);
        let t = st.arrive(ns(20), 2, ns(10)).unwrap();
        let _ = st.complete(t);
        st.finish(ns(40));
        assert!((st.stats.utilization(ns(40)) - 0.5).abs() < 1e-9);
        assert_eq!(st.stats.arrivals, 2);
        assert_eq!(st.stats.departures, 2);
    }

    #[test]
    fn queue_length_integral() {
        let mut st: Station<u32> = Station::new();
        let _ = st.arrive(ns(0), 1, ns(100)).unwrap();
        assert!(st.arrive(ns(0), 2, ns(100)).is_none()); // waits [0,100)
        let (_, next) = st.complete(ns(100));
        assert!(next.is_some());
        let _ = st.complete(ns(200));
        st.finish(ns(200));
        // one waiter for 100ns over a 200ns horizon -> mean qlen 0.5
        assert!((st.stats.mean_qlen(ns(200)) - 0.5).abs() < 1e-9);
        assert_eq!(st.stats.max_qlen, 1);
    }

    #[test]
    fn train_matches_per_frame_integrals() {
        // 4 equal frames of 10ns arriving together at an idle station.
        let mut per_frame: Station<u32> = Station::new();
        for i in 0..4 {
            let r = per_frame.arrive(ns(0), i, ns(10));
            assert_eq!(r.is_some(), i == 0);
        }
        let mut t = ns(10);
        loop {
            let (_, next) = per_frame.complete(t);
            match next {
                Some(n) => t = n,
                None => break,
            }
        }
        per_frame.finish(ns(40));

        let mut train: Station<u32> = Station::new();
        let done = train.arrive_train(ns(0), 9, ns(40), 4, ns(10)).unwrap();
        assert_eq!(done, ns(40));
        let _ = train.complete(ns(40));
        train.finish(ns(40));

        assert_eq!(per_frame.stats.busy_ns, train.stats.busy_ns);
        assert_eq!(per_frame.stats.qlen_ns, train.stats.qlen_ns, "intra-train wait integral");
        assert_eq!(per_frame.stats.arrivals, train.stats.arrivals);
        assert_eq!(per_frame.stats.departures, train.stats.departures);
        assert_eq!(per_frame.stats.max_qlen, train.stats.max_qlen);
    }

    #[test]
    fn queued_train_counts_units_while_waiting() {
        let mut st: Station<u32> = Station::new();
        let _ = st.arrive(ns(0), 1, ns(100)).unwrap();
        // An 8-frame train queues behind: 8 units waiting for 100ns.
        assert!(st.arrive_train(ns(0), 2, ns(80), 8, ns(10)).is_none());
        assert_eq!(st.queue_len(), 8);
        let (_, next) = st.complete(ns(100));
        assert_eq!(next, Some(ns(180)));
        assert_eq!(st.queue_len(), 0);
        let _ = st.complete(ns(180));
        st.finish(ns(180));
        // Waiting integral: 8 units × 100ns (queued) + 10·(8·7/2) intra-train.
        assert_eq!(st.stats.qlen_ns, 800 + 280);
        assert_eq!(st.stats.arrivals, 9);
        assert_eq!(st.stats.departures, 9);
    }

    #[test]
    fn paced_train_adds_no_intra_wait() {
        let mut st: Station<u32> = Station::new();
        let done = st.arrive_train(ns(0), 1, ns(40), 4, SimTime::ZERO).unwrap();
        let _ = st.complete(done);
        st.finish(done);
        assert_eq!(st.stats.qlen_ns, 0, "receive-side trains are paced, not bursty");
        assert_eq!(st.stats.arrivals, 4);
    }

    #[test]
    fn fair_lone_train_is_exact() {
        // A single train gets the full service rate: completion and stats
        // match the FIFO station bit-for-bit (integer arithmetic — no
        // virtual-time rounding on the uncontended path).
        let mut fq: FairStation<u32> = FairStation::new();
        let t = fq.arrive(ns(100), 7, ns(12_345), 4, 1_000, 0);
        assert_eq!(t, ns(100 + 12_345));
        assert!(fq.is_busy());
        assert_eq!(fq.queue_len(), 0, "a lone train is all in service");
        let (item, next) = fq.complete(t);
        assert_eq!(item, 7);
        assert!(next.is_none());
        fq.finish(ns(20_000));
        assert_eq!(fq.stats.busy_ns, 12_345);
        assert_eq!(fq.stats.qlen_ns, 0);
        assert_eq!(fq.stats.arrivals, 4);
        assert_eq!(fq.stats.departures, 4);
    }

    #[test]
    fn fair_lone_awkward_ratio_is_still_exact() {
        // svc / weight is a non-terminating binary fraction (100/3):
        // round-tripping through the virtual clock could land on 101 —
        // the dedicated-service fast path must keep this exactly 100.
        let mut fq: FairStation<u32> = FairStation::new();
        let t = fq.arrive(ns(1_000), 1, ns(100), 1, 3, 0);
        assert_eq!(t, ns(1_100));
        let (_, next) = fq.complete(t);
        assert!(next.is_none());
    }

    #[test]
    fn fair_equal_trains_finish_together() {
        // Two equal-weight, equal-size trains arriving together split the
        // server and finish at the same instant — the incast behavior the
        // per-frame path's interleaving produces, where a FIFO of whole
        // trains would finish them one full service apart.
        let mut fq: FairStation<u32> = FairStation::new();
        let t1 = fq.arrive(ns(0), 1, ns(100), 2, 500, 0);
        assert_eq!(t1, ns(100));
        let t2 = fq.arrive(ns(0), 2, ns(100), 2, 500, 0);
        assert_eq!(t2, ns(200), "shared service: head now finishes at Σ svc");
        // t1's announcement is superseded — the caller cancels that event
        // and only t2's ever fires.
        let (item, next) = fq.complete(t2);
        assert_eq!(item, 1, "ties complete in arrival order");
        let t3 = next.expect("second train still active");
        assert_eq!(t3, ns(200));
        let (item, next) = fq.complete(t3);
        assert_eq!(item, 2);
        assert!(next.is_none());
        fq.finish(ns(200));
        assert_eq!(fq.stats.busy_ns, 200, "work is conserved under sharing");
        assert_eq!(fq.stats.departures, 4);
    }

    #[test]
    fn fair_weights_are_byte_proportional() {
        // A heavy train (3x the bytes, 3x the service) and a light one
        // arriving together: byte-proportional shares mean both finish
        // tags coincide, so the light train does not starve the heavy one
        // — they finish at 400 in arrival order.
        let mut fq: FairStation<u32> = FairStation::new();
        let _ = fq.arrive(ns(0), 1, ns(300), 3, 3_000, 0);
        let t = fq.arrive(ns(0), 2, ns(100), 1, 1_000, 0);
        assert_eq!(t, ns(400), "head completes when the shared backlog drains");
        let (item, next) = fq.complete(t);
        assert_eq!(item, 1);
        let t2 = next.unwrap();
        assert_eq!(t2, ns(400));
        let (item, _) = fq.complete(t2);
        assert_eq!(item, 2);
    }

    #[test]
    fn fair_staggered_arrival_delays_head() {
        // B arrives halfway through A's lone service; A has drained half
        // its work, the rest is served at half rate.
        let mut fq: FairStation<u32> = FairStation::new();
        let t1 = fq.arrive(ns(0), 1, ns(100), 1, 100, 0);
        assert_eq!(t1, ns(100));
        let t2 = fq.arrive(ns(50), 2, ns(100), 1, 100, 0);
        assert_eq!(t2, ns(150), "A: 50ns left, served at 1/2 rate");
        let (item, next) = fq.complete(t2);
        assert_eq!(item, 1);
        let t3 = next.unwrap();
        assert_eq!(t3, ns(200), "B: 50ns left at full rate after A departs");
        let (item, _) = fq.complete(t3);
        assert_eq!(item, 2);
        fq.finish(ns(200));
        assert_eq!(fq.stats.busy_ns, 200);
    }

    #[test]
    fn fair_extra_wait_charges_the_integral() {
        let mut fq: FairStation<u32> = FairStation::new();
        let t = fq.arrive(ns(0), 1, ns(10), 2, 64, 7);
        let _ = fq.complete(t);
        fq.finish(t);
        assert_eq!(fq.stats.qlen_ns, 7, "analytic partial-frame wait only");
    }

    #[test]
    fn fair_zero_weight_is_clamped_to_a_minimal_share() {
        // A zero-byte control train must not divide by zero or starve:
        // weight clamps to 1, so two such trains share equally.
        let mut fq: FairStation<u32> = FairStation::new();
        let t1 = fq.arrive(ns(0), 1, ns(40), 1, 0, 0);
        assert_eq!(t1, ns(40));
        let t2 = fq.arrive(ns(0), 2, ns(40), 1, 0, 0);
        assert_eq!(t2, ns(80), "two unit shares: head finishes at Σ svc");
        let (item, next) = fq.complete(t2);
        assert_eq!(item, 1);
        let (item, _) = fq.complete(next.unwrap());
        assert_eq!(item, 2);
    }

    #[test]
    fn fair_busy_period_resets_the_virtual_clock() {
        // After the station idles, a fresh busy period must behave exactly
        // like the first one (vt restarts at zero).
        let mut fq: FairStation<u32> = FairStation::new();
        let t = fq.arrive(ns(0), 1, ns(100), 1, 8, 0);
        let _ = fq.complete(t);
        let t1 = fq.arrive(ns(1_000), 2, ns(100), 1, 8, 0);
        assert_eq!(t1, ns(1_100));
        let t2 = fq.arrive(ns(1_050), 3, ns(100), 1, 8, 0);
        assert_eq!(t2, ns(1_150), "identical to the first-busy-period stagger");
        let (item, next) = fq.complete(t2);
        assert_eq!(item, 2);
        let (item, _) = fq.complete(next.unwrap());
        assert_eq!(item, 3);
        fq.finish(ns(1_200));
        assert_eq!(fq.stats.busy_ns, 100 + 200);
    }

    #[test]
    fn reference_station_matches_fast_station_on_a_scripted_mix() {
        // Deterministic lockstep smoke test (the proptests randomize this):
        // staggered arrivals with unequal weights, announced times and
        // completions bit-identical between the heap and scan servers.
        let mut fast: FairStation<u32> = FairStation::new();
        let mut slow: RefFairStation<u32> = RefFairStation::new();
        let script = [
            (0u64, 10u32, 3_000u64, 1_000u64, 997u64),
            (40, 11, 1_500, 2, 313),
            (41, 12, 2_718, 30, 4_096),
        ];
        let mut pending = None;
        for &(at, item, svc, units, weight) in &script {
            let tf = fast.arrive(ns(at), item, ns(svc), units, weight, 0);
            let ts = slow.arrive(ns(at), item, ns(svc), units, weight, 0);
            assert_eq!(tf, ts, "announced completion diverged");
            pending = Some(tf);
        }
        while let Some(t) = pending {
            let (fi, fnext) = fast.complete(t);
            let (si, snext) = slow.complete(t);
            assert_eq!(fi, si, "completion order diverged");
            assert_eq!(fnext, snext, "next announcement diverged");
            pending = fnext;
        }
        fast.finish(ns(10_000));
        slow.finish(ns(10_000));
        assert_eq!(fast.stats.busy_ns, slow.stats.busy_ns);
        assert_eq!(fast.stats.qlen_ns, slow.stats.qlen_ns);
        assert_eq!(fast.stats.max_qlen, slow.stats.max_qlen);
        assert_eq!(fast.stats.departures, slow.stats.departures);
    }
}
