//! PJRT runtime: load the AOT-compiled analytic scorer and run it from
//! the rust hot path.
//!
//! `make artifacts` lowers the L2 JAX model (which calls the L1 Pallas
//! kernel) to `artifacts/predictor.hlo.txt` once; this module loads the
//! HLO text, compiles it on the PJRT CPU client, and executes it with
//! concrete batches. Python never runs at this point — the binary is
//! self-contained after artifacts are built.
//!
//! ABI (see python/compile/model.py): inputs `f32[8, B]` configs,
//! `f32[S, 8]` stages, `f32[8]` platform; output tuple of one
//! `f32[2, B]` (row 0 time, row 1 cost). B and S are static per artifact
//! and read from the `.meta` sidecar.

use crate::model::{Config, Platform};
use anyhow::{Context, Result};
use std::path::Path;

/// One stage descriptor for the analytic scorer (mirrors the python ABI).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageDesc {
    /// One task per app node (true) or fixed task count (false).
    pub tasks_per_app: bool,
    pub tasks_fixed: f32,
    pub read_mb: f32,
    pub read_local_frac: f32,
    pub write_mb: f32,
    /// All writes fan into a single node (collocation/incast).
    pub fan_single: bool,
    pub compute_total_s: f32,
}

impl StageDesc {
    fn encode(&self) -> [f32; 8] {
        [
            if self.tasks_per_app { 1.0 } else { 0.0 },
            self.tasks_fixed,
            self.read_mb,
            self.read_local_frac,
            self.write_mb,
            if self.fan_single { 1.0 } else { 0.0 },
            self.compute_total_s,
            1.0, // active
        ]
    }
}

/// Encode a [`Config`] into one column of the config matrix.
pub fn encode_config(cfg: &Config) -> [f32; 8] {
    [
        cfg.n_app as f32,
        cfg.n_storage as f32,
        cfg.stripe_width as f32,
        cfg.replication as f32,
        cfg.chunk_size.as_f64() as f32 / (1u64 << 20) as f32,
        if cfg.collocated { 1.0 } else { 0.0 },
        cfg.io_window as f32,
        0.0,
    ]
}

/// Encode a [`Platform`] into the scorer's platform vector.
pub fn encode_platform(plat: &Platform) -> [f32; 8] {
    [
        plat.net_remote_bps as f32,
        plat.net_local_bps as f32,
        plat.storage_ns_per_byte_write as f32,
        plat.storage_ns_per_byte_read as f32,
        plat.manager_op.as_secs_f64() as f32,
        plat.net_latency.as_secs_f64() as f32,
        plat.storage_op.as_secs_f64() as f32,
        0.0,
    ]
}

/// A compiled, executable scorer.
///
/// Real PJRT execution needs the `xla` bindings, which are not vendored
/// in the offline build; without the `pjrt` feature [`ScorerRuntime::load`]
/// returns an error and callers fall back to pure DES refinement.
pub struct ScorerRuntime {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    /// Static batch width of the artifact.
    pub batch: usize,
    /// Static stage capacity of the artifact.
    pub stages: usize,
}

/// (time seconds, cost node-seconds) per configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Score {
    pub time_s: f32,
    pub cost_node_s: f32,
}

impl ScorerRuntime {
    /// Load `artifacts/predictor.hlo.txt` (+ `.meta`) and compile it.
    pub fn load(artifact: impl AsRef<Path>) -> Result<ScorerRuntime> {
        let artifact = artifact.as_ref();
        let meta_path = format!("{}.meta", artifact.display());
        let meta = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path} (run `make artifacts`)"))?;
        let mut batch = 0usize;
        let mut stages = 0usize;
        for line in meta.lines() {
            let mut it = line.split_whitespace();
            match (it.next(), it.next()) {
                (Some("batch"), Some(v)) => batch = v.parse()?,
                (Some("stages"), Some(v)) => stages = v.parse()?,
                _ => {}
            }
        }
        anyhow::ensure!(batch > 0 && stages > 0, "bad meta file {meta_path}");
        Self::compile_artifact(artifact, batch, stages)
    }

    #[cfg(feature = "pjrt")]
    fn compile_artifact(artifact: &Path, batch: usize, stages: usize) -> Result<ScorerRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            artifact.to_str().context("non-utf8 artifact path")?,
        )
        .context("parsing HLO text (regenerate with `make artifacts`)")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling artifact")?;
        Ok(ScorerRuntime { exe, batch, stages })
    }

    #[cfg(not(feature = "pjrt"))]
    fn compile_artifact(_artifact: &Path, _batch: usize, _stages: usize) -> Result<ScorerRuntime> {
        anyhow::bail!(
            "PJRT runtime not compiled in: vendor the xla bindings (add them as a \
             path dependency in rust/Cargo.toml) and rebuild with `--features pjrt`; \
             offline builds fall back to DES-only refinement"
        )
    }

    /// Load from the default artifact location relative to the repo root.
    pub fn load_default() -> Result<ScorerRuntime> {
        ScorerRuntime::load("artifacts/predictor.hlo.txt")
    }

    /// Score configurations for a workflow described by `stage_descs`
    /// (≤ `stages`). Returns one [`Score`] per input config; inputs
    /// larger than the artifact batch are processed in batch-sized runs.
    pub fn score(
        &self,
        configs: &[[f32; 8]],
        stage_descs: &[StageDesc],
        platform: &[f32; 8],
    ) -> Result<Vec<Score>> {
        anyhow::ensure!(
            stage_descs.len() <= self.stages,
            "workflow has {} stages, artifact supports {}",
            stage_descs.len(),
            self.stages
        );
        let mut out = Vec::with_capacity(configs.len());
        for chunk in configs.chunks(self.batch) {
            out.extend(self.score_one_batch(chunk, stage_descs, platform)?);
        }
        Ok(out)
    }

    #[cfg(not(feature = "pjrt"))]
    fn score_one_batch(
        &self,
        _configs: &[[f32; 8]],
        _stage_descs: &[StageDesc],
        _platform: &[f32; 8],
    ) -> Result<Vec<Score>> {
        anyhow::bail!("PJRT runtime not compiled in")
    }

    #[cfg(feature = "pjrt")]
    fn score_one_batch(
        &self,
        configs: &[[f32; 8]],
        stage_descs: &[StageDesc],
        platform: &[f32; 8],
    ) -> Result<Vec<Score>> {
        debug_assert!(configs.len() <= self.batch);
        // Column-major fill of the (8, B) matrix, zero-padded.
        let b = self.batch;
        let mut cfg_mat = vec![0f32; 8 * b];
        for (j, col) in configs.iter().enumerate() {
            for (i, &v) in col.iter().enumerate() {
                cfg_mat[i * b + j] = v;
            }
        }
        let mut stage_mat = vec![0f32; self.stages * 8];
        for (s, d) in stage_descs.iter().enumerate() {
            stage_mat[s * 8..s * 8 + 8].copy_from_slice(&d.encode());
        }

        let cfg_lit = xla::Literal::vec1(&cfg_mat).reshape(&[8, b as i64])?;
        let stage_lit = xla::Literal::vec1(&stage_mat).reshape(&[self.stages as i64, 8])?;
        let plat_lit = xla::Literal::vec1(&platform[..]);

        let result = self.exe.execute::<xla::Literal>(&[cfg_lit, stage_lit, plat_lit])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?; // exported with return_tuple=True
        let values = out.to_vec::<f32>()?; // (2, B) row-major
        anyhow::ensure!(values.len() == 2 * b, "unexpected output size {}", values.len());
        Ok(configs
            .iter()
            .enumerate()
            .map(|(j, _)| Score { time_s: values[j], cost_node_s: values[b + j] })
            .collect())
    }
}

/// Describe a [`crate::workload::Workload`]'s stages for the scorer —
/// aggregates per-stage I/O volumes out of the task list.
pub fn describe_stages(wl: &crate::workload::Workload) -> Vec<StageDesc> {
    use crate::workload::FileHint;
    let n_stages = wl.n_stages() as usize;
    let mut descs = vec![StageDesc::default(); n_stages];
    let mut counts = vec![0u32; n_stages];
    for t in &wl.tasks {
        let s = t.stage as usize;
        counts[s] += 1;
        for &f in &t.reads {
            let file = &wl.files[f];
            descs[s].read_mb += file.size.as_f64() as f32 / (1u64 << 20) as f32;
            if matches!(file.hint, FileHint::Local | FileHint::OnNode(_)) {
                descs[s].read_local_frac += 1.0; // normalized below
            }
        }
        for &f in &t.writes {
            let file = &wl.files[f];
            descs[s].write_mb += file.size.as_f64() as f32 / (1u64 << 20) as f32;
            if matches!(file.hint, FileHint::OnNode(_)) {
                descs[s].fan_single = true;
            }
        }
        descs[s].compute_total_s += t.compute.as_secs_f64() as f32;
    }
    for (s, d) in descs.iter_mut().enumerate() {
        let n = counts[s].max(1) as f32;
        let n_reads: f32 = wl
            .tasks
            .iter()
            .filter(|t| t.stage as usize == s)
            .map(|t| t.reads.len() as f32)
            .sum();
        d.tasks_fixed = n;
        d.read_mb /= n;
        d.write_mb /= n;
        d.read_local_frac = if n_reads > 0.0 { d.read_local_frac / n_reads } else { 0.0 };
    }
    descs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::Bytes;
    use crate::workload::patterns::{reduce, PatternScale};

    #[test]
    fn encode_config_roundtrip_fields() {
        let c = Config::partitioned(14, 5, Bytes::kb(256));
        let e = encode_config(&c);
        assert_eq!(e[0], 14.0);
        assert_eq!(e[1], 5.0);
        assert_eq!(e[4], 0.25);
        assert_eq!(e[5], 0.0);
    }

    #[test]
    fn describe_stages_aggregates() {
        let wl = reduce(19, PatternScale::Medium, true);
        let d = describe_stages(&wl);
        assert_eq!(d.len(), 2);
        // Stage 0: 19 producers, 100 MB in (local hint), 10 MB out to one node.
        assert!((d[0].read_mb - 100.0).abs() < 1.0, "{}", d[0].read_mb);
        assert!((d[0].write_mb - 10.0).abs() < 0.1);
        assert!(d[0].fan_single, "collocated intermediates fan into one node");
        assert!(d[0].read_local_frac > 0.9);
        // Stage 1: the reducer reads 19 × 10 MB.
        assert!((d[1].read_mb - 190.0).abs() < 1.0);
    }
}
