//! The "actual system": a high-fidelity emulator standing in for the
//! paper's 20-node MosaStore deployment (DESIGN.md §3–4).
//!
//! Every experiment figure compares *actual* (this module: detailed
//! fidelity, stochastic, N trials, mean ± std error bars) against
//! *predicted* (the coarse deterministic model). The fidelity gap —
//! multi-round control paths, connection SYN loss with 3 s retries,
//! launch stagger, jitter, heterogeneity, manager contention — is exactly
//! the set of mechanisms the paper names as its own sources of prediction
//! error (§5), so the error we measure is structural, not circular.
//!
//! Trial counts follow the paper: "the average turnaround time and
//! standard deviation for 15 trials … enough to guarantee a 95%" CI; we
//! additionally run Jain's procedure to extend noisy campaigns.
//!
//! Campaigns are embarrassingly parallel: every trial is keyed by a pure
//! per-trial seed stream (`Rng::stream_seed(base_seed, i)`), so
//! [`Testbed::with_threads`] fans trials out over scoped workers while
//! Jain's stopping rule is applied to the results strictly in trial
//! order — an N-thread campaign is **byte-identical** to the sequential
//! one, just faster. `Testbed::aggregated()` additionally switches trials
//! to [`Fidelity::detailed_aggregated`] (the bulk train path with
//! train-weighted SYN/mux calibration), making each trial ~an order of
//! magnitude cheaper on chunk-heavy workloads.

use crate::model::{simulate_fid, Config, Fidelity, Platform, SimReport};
use crate::util::rng::Rng;
use crate::util::stats::{Campaign, Summary};
use crate::workload::Workload;

/// Aggregated results of a testbed measurement campaign.
#[derive(Clone, Debug)]
pub struct TrialStats {
    pub config_label: String,
    /// Turnaround seconds across trials.
    pub turnaround: Summary,
    /// Per-stage makespan seconds across trials.
    pub stages: Vec<Summary>,
    /// Mean connection SYN retries per trial (diagnostic).
    pub mean_conn_retries: f64,
    /// Wallclock seconds spent running all trials (for §3.3 speedup).
    pub wallclock_secs: f64,
    /// A representative report (last trial).
    pub sample: SimReport,
}

impl TrialStats {
    pub fn mean(&self) -> f64 {
        self.turnaround.mean()
    }
    pub fn std(&self) -> f64 {
        self.turnaround.std()
    }
}

/// The emulated testbed.
#[derive(Clone, Debug)]
pub struct Testbed {
    pub platform: Platform,
    /// Base fidelity (seed is overridden per trial).
    pub fidelity: Fidelity,
    /// Minimum trials (paper: 15 synthetic / 20 BLAST).
    pub min_trials: u64,
    pub max_trials: u64,
    /// Base seed; trial `i` runs on seed stream
    /// `Rng::stream_seed(base_seed, i)`.
    pub base_seed: u64,
    /// Worker threads for `run` campaigns (1 = the sequential reference;
    /// any value produces byte-identical statistics).
    pub threads: usize,
}

impl Testbed {
    pub fn new(platform: Platform) -> Testbed {
        Testbed {
            platform,
            fidelity: Fidelity::detailed(0),
            min_trials: 15,
            max_trials: 40,
            base_seed: 0x7E57_BED0,
            threads: 1,
        }
    }

    pub fn with_trials(mut self, min: u64, max: u64) -> Testbed {
        self.min_trials = min;
        self.max_trials = max.max(min);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Testbed {
        self.base_seed = seed;
        self
    }

    /// Fan `run` campaigns out over up to `threads` workers. Results are
    /// byte-identical to `threads == 1`; only the wallclock changes.
    pub fn with_threads(mut self, threads: usize) -> Testbed {
        self.threads = threads.max(1);
        self
    }

    /// Replace the campaign fidelity (the per-trial seed is still
    /// overridden for every trial).
    pub fn with_fidelity(mut self, fidelity: Fidelity) -> Testbed {
        self.fidelity = fidelity;
        self
    }

    /// Switch trials to the detailed-with-aggregation tier
    /// ([`Fidelity::detailed_aggregated`]): same stochastic mechanisms,
    /// bulk train path, train-weighted SYN/mux calibration — ~an order of
    /// magnitude cheaper per trial on chunk-heavy workloads.
    pub fn aggregated(self) -> Testbed {
        self.with_fidelity(Fidelity::detailed_aggregated(0))
    }

    /// Seed stream of trial `i` — a pure function of `(base_seed, i)`, so
    /// trials can run on any worker in any order.
    pub fn trial_seed(&self, i: u64) -> u64 {
        Rng::stream_seed(self.base_seed, i)
    }

    /// Run one trial with an explicit seed.
    pub fn trial(&self, wl: &Workload, cfg: &Config, seed: u64) -> SimReport {
        let fid = Fidelity { seed, ..self.fidelity.clone() };
        simulate_fid(wl, cfg, &self.platform, fid)
    }

    /// Run a measurement campaign: trials until the 95% CI is within ±5%
    /// of the mean (Jain's procedure), bounded by [min_trials, max_trials].
    ///
    /// Trials are generated in parallel waves across `self.threads`
    /// workers and reduced strictly in trial order (slot-ordered), so the
    /// returned statistics are byte-identical to a sequential campaign.
    pub fn run(&self, wl: &Workload, cfg: &Config) -> TrialStats {
        let t0 = std::time::Instant::now();
        let n_stages = wl.n_stages();
        let mut stages: Vec<Summary> = (0..n_stages).map(|_| Summary::new()).collect();
        let mut retries = 0u64;
        let mut sample: Option<SimReport> = None;

        let campaign = Campaign {
            rel_accuracy: 0.05,
            min_samples: self.min_trials,
            max_samples: self.max_trials,
        };
        let turnaround = campaign.run_par(
            self.threads,
            |i| self.trial(wl, cfg, self.trial_seed(i)),
            |rep| {
                for (s, summ) in stages.iter_mut().enumerate() {
                    summ.add(rep.stage_time(s as u32).as_secs_f64());
                }
                retries += rep.conn_retries;
                let t = rep.turnaround.as_secs_f64();
                sample = Some(rep);
                t
            },
        );

        TrialStats {
            config_label: cfg.label.clone(),
            mean_conn_retries: retries as f64 / turnaround.n().max(1) as f64,
            turnaround,
            stages,
            wallclock_secs: t0.elapsed().as_secs_f64(),
            sample: sample.expect("at least one trial"),
        }
    }

    /// Total emulated node-seconds consumed by the campaign — the
    /// "resources" side of the paper's §3.3 comparison (actual runs burn
    /// `nodes × turnaround` per trial; the predictor burns one machine's
    /// wallclock).
    pub fn node_seconds(&self, stats: &TrialStats, cfg: &Config) -> f64 {
        stats.turnaround.mean() * stats.turnaround.n() as f64 * cfg.n_hosts() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::patterns::{pipeline, PatternScale};

    fn quick_testbed() -> Testbed {
        Testbed::new(Platform::paper_testbed()).with_trials(3, 5)
    }

    #[test]
    fn trials_vary_but_reproduce_with_seed() {
        let tb = quick_testbed();
        let wl = pipeline(4, PatternScale::Small, false);
        let cfg = Config::dss(4);
        let a = tb.trial(&wl, &cfg, 7);
        let b = tb.trial(&wl, &cfg, 7);
        let c = tb.trial(&wl, &cfg, 8);
        assert_eq!(a.turnaround, b.turnaround, "same seed ⇒ identical trial");
        assert_ne!(a.turnaround, c.turnaround, "different seed ⇒ different trial");
    }

    #[test]
    fn campaign_reports_spread() {
        let tb = quick_testbed();
        let wl = pipeline(4, PatternScale::Small, false);
        let stats = tb.run(&wl, &Config::dss(4));
        assert!(stats.turnaround.n() >= 3);
        assert!(stats.mean() > 0.0);
        assert!(stats.std() >= 0.0);
        assert_eq!(stats.stages.len(), 3);
        assert!(stats.wallclock_secs > 0.0);
    }

    #[test]
    fn parallel_campaign_is_byte_identical_to_sequential() {
        let wl = pipeline(4, PatternScale::Small, false);
        let cfg = Config::dss(4);
        let seq = quick_testbed().run(&wl, &cfg);
        for threads in [2usize, 4, 8] {
            let par = quick_testbed().with_threads(threads).run(&wl, &cfg);
            assert_eq!(seq.turnaround.n(), par.turnaround.n(), "{threads} threads");
            assert_eq!(
                seq.turnaround.mean().to_bits(),
                par.turnaround.mean().to_bits(),
                "{threads} threads: mean"
            );
            assert_eq!(
                seq.turnaround.std().to_bits(),
                par.turnaround.std().to_bits(),
                "{threads} threads: std"
            );
            assert_eq!(
                seq.mean_conn_retries.to_bits(),
                par.mean_conn_retries.to_bits(),
                "{threads} threads: retries"
            );
            assert_eq!(seq.sample.turnaround, par.sample.turnaround, "{threads} threads: sample");
            assert_eq!(seq.stages.len(), par.stages.len());
            for (a, b) in seq.stages.iter().zip(par.stages.iter()) {
                assert_eq!(a.mean().to_bits(), b.mean().to_bits(), "{threads} threads: stages");
            }
        }
    }

    #[test]
    fn aggregated_tier_matches_per_frame_statistics_and_is_cheaper() {
        // The detailed-with-aggregation tier reruns the same stochastic
        // mechanisms over the bulk train path with train-weighted SYN/mux
        // calibration. It is a different (equally valid) stochastic
        // realization, so we compare campaign *means*, loosely, and
        // require the trials to be much cheaper in events.
        let wl = pipeline(6, PatternScale::Small, false);
        let cfg = Config::dss(6);
        let per_frame = Testbed::new(Platform::paper_testbed()).with_trials(5, 5).run(&wl, &cfg);
        let agg = Testbed::new(Platform::paper_testbed())
            .aggregated()
            .with_trials(5, 5)
            .run(&wl, &cfg);
        let drift = (agg.mean() - per_frame.mean()).abs() / per_frame.mean();
        assert!(
            drift < 0.25,
            "aggregated tier drifted {:.1}% from per-frame (agg {:.2}s vs {:.2}s)",
            drift * 100.0,
            agg.mean(),
            per_frame.mean()
        );
        assert!(
            agg.sample.events < per_frame.sample.events,
            "aggregation must cut events: {} vs {}",
            agg.sample.events,
            per_frame.sample.events
        );
    }

    #[test]
    fn detailed_is_slower_than_coarse() {
        // The detailed protocol adds control rounds, connections and
        // stagger: an actual run must take longer than the prediction.
        let tb = quick_testbed();
        let wl = pipeline(4, PatternScale::Small, false);
        let cfg = Config::dss(4);
        let actual = tb.trial(&wl, &cfg, 1);
        let predicted = crate::model::simulate(&wl, &cfg, &tb.platform);
        assert!(
            actual.turnaround > predicted.turnaround,
            "actual {} ≤ predicted {}",
            actual.turnaround,
            predicted.turnaround
        );
    }
}
