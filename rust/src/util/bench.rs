//! Hand-rolled benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` invokes the `[[bench]]` binaries (declared with
//! `harness = false`); each uses [`BenchRunner`] for wallclock timing with
//! warmup, repetition, and summary statistics, and writes machine-readable
//! results under `results/`.

use crate::util::stats::Summary;
use std::time::Instant;

/// Measures a closure's wallclock time over warmup + measured iterations.
pub struct BenchRunner {
    pub warmup_iters: u32,
    pub iters: u32,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner { warmup_iters: 2, iters: 10 }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration.
    pub secs: Summary,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12.6}s/iter  ±{:>10.6}  (n={})",
            self.name,
            self.secs.mean(),
            self.secs.std(),
            self.secs.n()
        )
    }
}

impl BenchRunner {
    pub fn new(warmup_iters: u32, iters: u32) -> Self {
        BenchRunner { warmup_iters, iters }
    }

    /// Time `f`, returning per-iteration stats. `f` receives the iteration
    /// index so benchmarks can vary seeds without timing setup code.
    pub fn run(&self, name: &str, mut f: impl FnMut(u32)) -> BenchResult {
        for i in 0..self.warmup_iters {
            f(i);
        }
        let mut s = Summary::new();
        for i in 0..self.iters {
            let t0 = Instant::now();
            f(self.warmup_iters + i);
            s.add(t0.elapsed().as_secs_f64());
        }
        let r = BenchResult { name: name.to_string(), secs: s };
        println!("{}", r.line());
        r
    }
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Write a results file under `results/`, creating the directory.
pub fn write_results(name: &str, contents: &str) {
    let dir = std::path::Path::new("results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(name);
    std::fs::write(&path, contents).unwrap_or_else(|e| panic!("write {path:?}: {e}"));
    println!("[results written to {}]", path.display());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_expected_iterations() {
        let mut calls = 0u32;
        let r = BenchRunner { warmup_iters: 3, iters: 5 }.run("t", |_| calls += 1);
        assert_eq!(calls, 8);
        assert_eq!(r.secs.n(), 5);
        assert!(r.secs.mean() >= 0.0);
    }
}
