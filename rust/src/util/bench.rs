//! Hand-rolled benchmark timing substrate (criterion is unavailable
//! offline): [`BenchRunner`] for wallclock timing with warmup,
//! repetition, and summary statistics, plus [`black_box`]. The
//! measurement harness built on top of it is [`crate::bench`] (the
//! `wfpred bench` cell registry).

use crate::util::stats::Summary;
use std::time::Instant;

/// Measures a closure's wallclock time over warmup + measured iterations.
pub struct BenchRunner {
    pub warmup_iters: u32,
    pub iters: u32,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner { warmup_iters: 2, iters: 10 }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration.
    pub secs: Summary,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12.6}s/iter  ±{:>10.6}  (n={})",
            self.name,
            self.secs.mean(),
            self.secs.std(),
            self.secs.n()
        )
    }
}

impl BenchRunner {
    pub fn new(warmup_iters: u32, iters: u32) -> Self {
        BenchRunner { warmup_iters, iters }
    }

    /// Time `f`, returning per-iteration stats. `f` receives the iteration
    /// index so benchmarks can vary seeds without timing setup code.
    pub fn run(&self, name: &str, mut f: impl FnMut(u32)) -> BenchResult {
        for i in 0..self.warmup_iters {
            f(i);
        }
        let mut s = Summary::new();
        for i in 0..self.iters {
            let t0 = Instant::now();
            f(self.warmup_iters + i);
            s.add(t0.elapsed().as_secs_f64());
        }
        let r = BenchResult { name: name.to_string(), secs: s };
        println!("{}", r.line());
        r
    }
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Scoped numeric lookup in a results file: `"key": <number>` inside the
/// object value of the first `"scope":` occurrence (empty scope searches
/// the whole text). The scope's object is delimited by a balanced-brace
/// scan, so a key absent from the scope is `None` rather than silently
/// matching a later sibling object. Tailored to this crate's own
/// [`crate::util::jsonw::Json`] writer (which never emits braces inside
/// the strings of these files) — it is a baseline-file reader for the
/// bench regression gate, not a general JSON parser.
pub fn json_number_in(text: &str, scope: &str, key: &str) -> Option<f64> {
    let region = if scope.is_empty() {
        text
    } else {
        let needle = format!("\"{scope}\":");
        let rest = &text[text.find(&needle)? + needle.len()..];
        let open = rest.find('{')?;
        let mut depth = 0usize;
        let mut close = None;
        for (i, c) in rest[open..].char_indices() {
            match c {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        close = Some(open + i);
                        break;
                    }
                }
                _ => {}
            }
        }
        &rest[open..=close?]
    };
    let needle = format!("\"{key}\":");
    let pos = region.find(&needle)? + needle.len();
    let rest = region[pos..].trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || matches!(c, '+' | '-' | '.' | 'e' | 'E')))
        .unwrap_or(rest.len());
    rest[..end].parse::<f64>().ok()
}

/// Relative-tolerance comparison for the regression gate:
/// `|fresh − base| ≤ tol · |base|` (exact match required when base is 0).
pub fn within_rel(fresh: f64, base: f64, tol: f64) -> bool {
    if base == 0.0 {
        fresh == 0.0
    } else {
        (fresh - base).abs() <= tol * base.abs()
    }
}

/// Write a results file under `results/`, creating the directory.
pub fn write_results(name: &str, contents: &str) {
    let dir = std::path::Path::new("results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(name);
    std::fs::write(&path, contents).unwrap_or_else(|e| panic!("write {path:?}: {e}"));
    println!("[results written to {}]", path.display());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::jsonw::Json;

    #[test]
    fn runs_expected_iterations() {
        let mut calls = 0u32;
        let r = BenchRunner { warmup_iters: 3, iters: 5 }.run("t", |_| calls += 1);
        assert_eq!(calls, 8);
        assert_eq!(r.secs.n(), 5);
        assert!(r.secs.mean() >= 0.0);
    }

    #[test]
    fn json_number_in_reads_own_writer_output() {
        let text = Json::obj()
            .set("workload", "blast")
            .set("bulk", Json::obj().set("events", 1234u64).set("sim_turnaround_s", 17.25))
            .set("per_frame", Json::obj().set("events", 9876u64).set("wall_secs", 3.5))
            .set("event_reduction_x", 8.0)
            .render();
        assert_eq!(json_number_in(&text, "bulk", "events"), Some(1234.0));
        assert_eq!(json_number_in(&text, "bulk", "sim_turnaround_s"), Some(17.25));
        assert_eq!(json_number_in(&text, "per_frame", "events"), Some(9876.0));
        assert_eq!(json_number_in(&text, "", "event_reduction_x"), Some(8.0));
        assert_eq!(json_number_in(&text, "missing", "events"), None);
        assert_eq!(json_number_in(&text, "bulk", "missing"), None);
        // A key absent from the scope must NOT match a later sibling's key.
        assert_eq!(json_number_in(&text, "bulk", "wall_secs"), None);
        // Nested scopes stay within their own braces.
        let nested = Json::obj()
            .set("outer", Json::obj().set("inner", Json::obj().set("x", 1u64)).set("y", 2u64))
            .set("x", 3u64)
            .render();
        assert_eq!(json_number_in(&nested, "outer", "x"), Some(1.0));
        assert_eq!(json_number_in(&nested, "inner", "x"), Some(1.0));
        assert_eq!(json_number_in(&nested, "outer", "y"), Some(2.0));
        assert_eq!(json_number_in(&nested, "", "x"), Some(1.0));
    }

    #[test]
    fn within_rel_bounds() {
        assert!(within_rel(110.0, 100.0, 0.10));
        assert!(!within_rel(110.1, 100.0, 0.10));
        assert!(within_rel(90.0, 100.0, 0.10));
        assert!(within_rel(0.0, 0.0, 0.10));
        assert!(!within_rel(1.0, 0.0, 0.10));
    }
}
