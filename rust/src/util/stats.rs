//! Sample statistics and Jain's confidence-interval-driven sample counts.
//!
//! The paper (§2.5, §3) sizes every measurement campaign "to achieve 95%
//! confidence intervals with ±5% accuracy according to the procedure
//! described in [Jain, *The Art of Computer Systems Performance
//! Analysis*]". [`Campaign`] implements exactly that loop: keep adding
//! samples until the half-width of the CI is within the requested
//! fraction of the mean (with floor/ceiling sample counts).

/// Running sample statistics (Welford's algorithm — numerically stable).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Summary::new();
        for &x in xs {
            s.add(x);
        }
        s
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn n(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }

    /// Half-width of the 95% confidence interval for the mean
    /// (Student-t for small n, normal beyond the table).
    pub fn ci95_half(&self) -> f64 {
        t_value_95(self.n.saturating_sub(1)) * self.sem()
    }

    /// Relative CI half-width (half-width / mean); `inf` when mean is 0.
    pub fn ci95_rel(&self) -> f64 {
        if self.mean.abs() < f64::EPSILON {
            f64::INFINITY
        } else {
            self.ci95_half() / self.mean.abs()
        }
    }
}

/// Two-sided 95% Student-t critical values by degrees of freedom.
/// Exact table entries for df ≤ 30, 1.96 asymptote beyond.
pub fn t_value_95(df: u64) -> f64 {
    const TABLE: [f64; 31] = [
        f64::INFINITY, // df = 0 (undefined; forces "keep sampling")
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
        2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
        2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if (df as usize) < TABLE.len() {
        TABLE[df as usize]
    } else {
        1.96
    }
}

/// Jain's procedure: run `sample()` until the 95% CI half-width is within
/// `rel_accuracy` of the mean, bounded by `[min_samples, max_samples]`.
pub struct Campaign {
    pub rel_accuracy: f64,
    pub min_samples: u64,
    pub max_samples: u64,
}

impl Default for Campaign {
    fn default() -> Self {
        // Paper: 95% CI, ±5%; 15–20 trials in practice. We keep a small
        // floor so the CI is meaningful and a generous ceiling.
        Campaign { rel_accuracy: 0.05, min_samples: 5, max_samples: 200 }
    }
}

impl Campaign {
    pub fn run(&self, mut sample: impl FnMut(u64) -> f64) -> Summary {
        let mut s = Summary::new();
        for i in 0..self.max_samples {
            s.add(sample(i));
            if s.n() >= self.min_samples && s.ci95_rel() <= self.rel_accuracy {
                break;
            }
        }
        s
    }

    /// Jain's procedure with sample generation fanned out over `threads`
    /// workers, reduced strictly in sample order.
    ///
    /// `gen(i)` produces sample `i`'s raw measurement (it must be a pure
    /// function of `i` — e.g. a trial keyed by a per-index seed stream);
    /// `consume` reduces each measurement to the tracked value and may
    /// accumulate side statistics. Generation proceeds in waves
    /// (`min_samples` first, then one wave per `threads`), but `consume`
    /// always sees samples `0, 1, 2, …` in order and the stopping rule is
    /// applied after each, exactly as in the sequential [`Campaign::run`]
    /// — so the returned [`Summary`] (and everything `consume`
    /// accumulates) is byte-identical regardless of thread count. Samples
    /// speculatively generated beyond the stopping point are discarded.
    pub fn run_par<T, F, G>(&self, threads: usize, gen: F, mut consume: G) -> Summary
    where
        T: Send,
        F: Fn(u64) -> T + Sync,
        G: FnMut(T) -> f64,
    {
        let mut s = Summary::new();
        let mut next = 0u64;
        'waves: while next < self.max_samples {
            let wave = if next == 0 {
                self.min_samples.clamp(1, self.max_samples)
            } else {
                (threads.max(1) as u64).min(self.max_samples - next)
            };
            let base = next;
            let batch = crate::coordinator::par_map_indexed(wave as usize, threads, |k| {
                gen(base + k as u64)
            });
            for x in batch {
                s.add(consume(x));
                next += 1;
                if s.n() >= self.min_samples && s.ci95_rel() <= self.rel_accuracy {
                    break 'waves;
                }
            }
        }
        s
    }
}

/// Relative error |a-b| / |b| (b is the reference). `inf` when b == 0 ≠ a.
pub fn rel_err(a: f64, b: f64) -> f64 {
    if b.abs() < f64::EPSILON {
        if a.abs() < f64::EPSILON {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (a - b).abs() / b.abs()
    }
}

/// Percentile (nearest-rank) of an unsorted slice; p in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * v.len() as f64).ceil().max(1.0) as usize;
    v[rank.min(v.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn summary_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let s = Summary::from_slice(&xs);
        assert_eq!(s.n(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // naive sample variance = 32/7
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn ci_shrinks_with_samples() {
        let mut r = Rng::new(3);
        let mut small = Summary::new();
        let mut big = Summary::new();
        for i in 0..10_000 {
            let x = r.normal(10.0, 1.0);
            if i < 10 {
                small.add(x);
            }
            big.add(x);
        }
        assert!(big.ci95_half() < small.ci95_half() / 10.0);
    }

    #[test]
    fn campaign_stops_when_tight() {
        // Deterministic constant sample: CI collapses immediately at the floor.
        let c = Campaign::default();
        let s = c.run(|_| 42.0);
        assert_eq!(s.n(), c.min_samples);
        assert_eq!(s.mean(), 42.0);
    }

    #[test]
    fn campaign_keeps_sampling_when_noisy() {
        let mut r = Rng::new(5);
        let c = Campaign { rel_accuracy: 0.02, min_samples: 5, max_samples: 500 };
        let s = c.run(|_| r.normal(100.0, 30.0));
        assert!(s.n() > 10, "30% noise should need far more than the floor, got {}", s.n());
        assert!(s.ci95_rel() <= 0.02 || s.n() == 500);
    }

    #[test]
    fn run_par_is_byte_identical_to_sequential() {
        // A noisy sampler keyed purely by index: the parallel waves must
        // reproduce the sequential stopping point and Summary bits.
        let gen = |i: u64| {
            let mut r = Rng::new(Rng::stream_seed(99, i));
            r.normal(100.0, 20.0)
        };
        let c = Campaign { rel_accuracy: 0.04, min_samples: 5, max_samples: 60 };
        let seq = c.run(gen);
        for threads in [1usize, 2, 4, 7] {
            let par = c.run_par(threads, gen, |x| x);
            assert_eq!(seq.n(), par.n(), "{threads} threads");
            assert_eq!(seq.mean().to_bits(), par.mean().to_bits(), "{threads} threads");
            assert_eq!(seq.std().to_bits(), par.std().to_bits(), "{threads} threads");
            assert_eq!(seq.min().to_bits(), par.min().to_bits(), "{threads} threads");
            assert_eq!(seq.max().to_bits(), par.max().to_bits(), "{threads} threads");
        }
    }

    #[test]
    fn run_par_consume_sees_samples_in_order() {
        let c = Campaign { rel_accuracy: 0.0, min_samples: 9, max_samples: 9 };
        let mut seen = Vec::new();
        let s = c.run_par(3, |i| i as f64, |x| {
            seen.push(x as u64);
            x
        });
        assert_eq!(s.n(), 9);
        assert_eq!(seen, (0..9).collect::<Vec<u64>>());
    }

    #[test]
    fn t_table_sane() {
        assert!(t_value_95(1) > t_value_95(5));
        assert!(t_value_95(5) > t_value_95(30));
        assert_eq!(t_value_95(1000), 1.96);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 90.0), 90.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 1.0), 1.0);
    }

    #[test]
    fn rel_err_basics() {
        assert_eq!(rel_err(110.0, 100.0), 0.1_f64);
        assert_eq!(rel_err(0.0, 0.0), 0.0);
        assert!(rel_err(1.0, 0.0).is_infinite());
    }
}
