//! Minimal property-based testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`] — a seeded random generator with
//! convenience draws. [`check`] runs it for a configurable number of cases
//! and, on failure, re-runs with the failing seed to confirm and reports
//! the seed so the case can be replayed deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath rustflags)
//! use wfpred::util::prop::{check, Gen};
//! check("addition commutes", 256, |g: &mut Gen| {
//!     let a = g.u64(0, 1000);
//!     let b = g.u64(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Rng;

/// Random-case generator handed to properties.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), seed }
    }

    /// Uniform u64 in `[lo, hi]` (inclusive).
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// A vector of `n` draws.
    pub fn vec<T>(&mut self, n: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }

    /// Access the underlying RNG for anything fancier.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` for `cases` random cases. Panics (with the failing seed in
/// the message) if any case panics. Base seed is fixed for reproducibility;
/// override with env `WFPRED_PROP_SEED`.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base: u64 = std::env::var("WFPRED_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    for i in 0..cases {
        let seed = base.wrapping_add(i).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed at case {i} (replay: WFPRED_PROP_SEED with seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u64 bounds respected", 200, |g| {
            let x = g.u64(10, 20);
            assert!((10..=20).contains(&x));
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always fails", 3, |_| panic!("boom"));
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap();
        assert!(msg.contains("replay"), "message should carry the seed: {msg}");
    }

    #[test]
    fn gen_choose_and_vec() {
        let mut g = Gen::new(1);
        let xs = [1, 2, 3];
        for _ in 0..50 {
            assert!(xs.contains(g.choose(&xs)));
        }
        let v = g.vec(10, |g| g.f64(0.0, 1.0));
        assert_eq!(v.len(), 10);
        assert!(v.iter().all(|x| (0.0..1.0).contains(x)));
    }
}
