//! Tiny JSON writer plus a flat-object reader (serde is unavailable
//! offline).
//!
//! Results files (`results/*.json`) are emitted through this writer so
//! downstream tooling can consume bench output. The prediction service
//! additionally round-trips **flat** single-line objects — the JSONL
//! on-disk store and the `batch`/`serve` query protocol — through
//! [`Json::render_compact`] and [`parse_flat`]. Nested objects stay
//! write-only; the crate's other interchange formats (traces, platform
//! files) are line-oriented text with their own parsers.

use std::fmt::Write;

/// A JSON value under construction.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }
    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kv) = self {
            kv.push((key.to_string(), v.into()));
        } else {
            panic!("set() on non-object");
        }
        self
    }

    pub fn push(&mut self, v: impl Into<Json>) {
        if let Json::Arr(ref mut xs) = self {
            xs.push(v.into());
        } else {
            panic!("push() on non-array");
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    /// Single-line rendering for JSONL records (`render` pretty-prints).
    pub fn render_compact(&self) -> String {
        let mut s = String::new();
        self.write_compact(&mut s);
        s
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    Json::Str(k.clone()).write(out, 0);
                    out.push_str(": ");
                    v.write_compact(out);
                }
                out.push('}');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    x.write_compact(out);
                }
                out.push(']');
            }
            other => other.write(out, 0),
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    x.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                if kv.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    let _ = write!(out, "{pad}\"{k}\": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

/// A scalar (or flat numeric array) read back from one line of this
/// writer's compact output. The service layer's JSONL store and the
/// `batch`/`serve` query protocol need flat objects only; nested objects
/// are rejected by [`parse_flat`].
#[derive(Debug, Clone, PartialEq)]
pub enum Scalar {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    NumArr(Vec<f64>),
}

/// Parse one flat JSON object (`{"k": v, …}`) into key/value pairs in
/// source order. Values may be strings, numbers, booleans, null, or
/// arrays of numbers — exactly what [`Json::render_compact`] emits for
/// the service's records.
pub fn parse_flat(text: &str) -> Result<Vec<(String, Scalar)>, String> {
    let mut p = Parser { s: text.as_bytes(), i: 0 };
    p.ws();
    p.expect(b'{')?;
    p.ws();
    let mut out = Vec::new();
    if p.peek() == Some(b'}') {
        p.i += 1;
    } else {
        loop {
            p.ws();
            let key = p.string()?;
            p.ws();
            p.expect(b':')?;
            p.ws();
            let val = p.value()?;
            out.push((key, val));
            p.ws();
            match p.next() {
                Some(b',') => continue,
                Some(b'}') => break,
                other => return Err(format!("expected ',' or '}}', got {other:?}")),
            }
        }
    }
    p.ws();
    if p.i != p.s.len() {
        return Err("trailing content after object".into());
    }
    Ok(out)
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn next(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.i += 1;
        Some(c)
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        match self.next() {
            Some(x) if x == c => Ok(()),
            other => Err(format!("expected {:?}, got {other:?}", c as char)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            // Strings may hold multi-byte UTF-8, but both structural bytes
            // ('"' and '\\') are single-byte in UTF-8, so a byte scan that
            // copies everything else through verbatim is safe.
            let start = self.i;
            while self.i < self.s.len() && self.s[self.i] != b'"' && self.s[self.i] != b'\\' {
                self.i += 1;
            }
            out.push_str(std::str::from_utf8(&self.s[start..self.i]).map_err(|e| e.to_string())?);
            match self.next() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.next() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.next().ok_or("truncated \\u escape")?;
                            code = code * 16 + (d as char).to_digit(16).ok_or("bad \\u digit")?;
                        }
                        out.push(char::from_u32(code).ok_or("bad \\u codepoint")?);
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<f64, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'+' | b'-' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.s[start..self.i]).map_err(|e| e.to_string())?;
        text.parse::<f64>().map_err(|_| format!("bad number {text:?}"))
    }

    fn word(&mut self) -> String {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_alphabetic() {
                self.i += 1;
            } else {
                break;
            }
        }
        String::from_utf8_lossy(&self.s[start..self.i]).into_owned()
    }

    fn value(&mut self) -> Result<Scalar, String> {
        match self.peek() {
            Some(b'"') => Ok(Scalar::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                self.ws();
                let mut xs = Vec::new();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Scalar::NumArr(xs));
                }
                loop {
                    self.ws();
                    xs.push(self.number()?);
                    self.ws();
                    match self.next() {
                        Some(b',') => continue,
                        Some(b']') => break,
                        other => return Err(format!("expected ',' or ']', got {other:?}")),
                    }
                }
                Ok(Scalar::NumArr(xs))
            }
            Some(b't') | Some(b'f') => match self.word().as_str() {
                "true" => Ok(Scalar::Bool(true)),
                "false" => Ok(Scalar::Bool(false)),
                w => Err(format!("bad literal {w:?}")),
            },
            Some(b'n') => {
                let w = self.word();
                if w == "null" {
                    Ok(Scalar::Null)
                } else {
                    Err(format!("bad literal {w:?}"))
                }
            }
            Some(c) if c.is_ascii_digit() || c == b'-' || c == b'+' => {
                Ok(Scalar::Num(self.number()?))
            }
            other => Err(format!("unexpected value start {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig4")
            .set("n", 19u64)
            .set("ok", true)
            .set("vals", vec![1.0, 2.5])
            .set("inner", Json::obj().set("x", 1u64));
        let s = j.render();
        assert!(s.contains("\"name\": \"fig4\""));
        assert!(s.contains("\"vals\": [1, 2.5]"));
        assert!(s.contains("\"x\": 1"));
    }

    #[test]
    fn escapes_strings() {
        let s = Json::Str("a\"b\\c\nd".into()).render();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn integers_render_clean() {
        assert_eq!(Json::Num(3.0).render(), "3");
        assert_eq!(Json::Num(3.25).render(), "3.25");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn compact_rendering_is_single_line() {
        let j = Json::obj()
            .set("name", "x")
            .set("n", 3u64)
            .set("xs", vec![1.0, 2.5])
            .set("ok", true);
        let s = j.render_compact();
        assert!(!s.contains('\n'), "{s}");
        assert_eq!(s, "{\"name\": \"x\", \"n\": 3, \"xs\": [1, 2.5], \"ok\": true}");
    }

    #[test]
    fn parse_flat_roundtrips_compact_output() {
        let j = Json::obj()
            .set("fp", "00ff00ff00ff00ff00ff00ff00ff00ff")
            .set("turnaround_ns", 123_456_789u64)
            .set("cost_node_s", 12.5)
            .set("stages_ns", vec![1.0, 2.0, 3.0])
            .set("exact", true)
            .set("note", "a\"b\\c\nd");
        let kv = parse_flat(&j.render_compact()).unwrap();
        assert_eq!(kv[0], ("fp".into(), Scalar::Str("00ff00ff00ff00ff00ff00ff00ff00ff".into())));
        assert_eq!(kv[1], ("turnaround_ns".into(), Scalar::Num(123_456_789.0)));
        assert_eq!(kv[2], ("cost_node_s".into(), Scalar::Num(12.5)));
        assert_eq!(kv[3], ("stages_ns".into(), Scalar::NumArr(vec![1.0, 2.0, 3.0])));
        assert_eq!(kv[4], ("exact".into(), Scalar::Bool(true)));
        assert_eq!(kv[5], ("note".into(), Scalar::Str("a\"b\\c\nd".into())));
    }

    #[test]
    fn parse_flat_accepts_hand_written_queries() {
        let kv =
            parse_flat(" { \"pattern\": \"blast\", \"app-nodes\": 14, \"wass\": false } ").unwrap();
        assert_eq!(kv.len(), 3);
        assert_eq!(kv[0].1, Scalar::Str("blast".into()));
        assert_eq!(kv[1].1, Scalar::Num(14.0));
        assert_eq!(kv[2].1, Scalar::Bool(false));
        assert_eq!(parse_flat("{}").unwrap(), Vec::new());
        assert_eq!(parse_flat("{\"x\": null}").unwrap()[0].1, Scalar::Null);
    }

    #[test]
    fn parse_flat_rejects_nesting_and_garbage() {
        assert!(parse_flat("{\"a\": {\"b\": 1}}").is_err(), "nested objects are out of scope");
        assert!(parse_flat("{\"a\": 1} trailing").is_err());
        assert!(parse_flat("{\"a\" 1}").is_err());
        assert!(parse_flat("not json").is_err());
        assert!(parse_flat("{\"a\": [1, \"x\"]}").is_err(), "only numeric arrays");
    }
}
