//! Tiny JSON writer (serde is unavailable offline).
//!
//! Results files (`results/*.json`) are emitted through this writer so
//! downstream tooling can consume bench output. Writing only — the crate's
//! own interchange formats (traces, platform files) are line-oriented text
//! with their own parsers.

use std::fmt::Write;

/// A JSON value under construction.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }
    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kv) = self {
            kv.push((key.to_string(), v.into()));
        } else {
            panic!("set() on non-object");
        }
        self
    }

    pub fn push(&mut self, v: impl Into<Json>) {
        if let Json::Arr(ref mut xs) = self {
            xs.push(v.into());
        } else {
            panic!("push() on non-array");
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    x.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                if kv.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    let _ = write!(out, "{pad}\"{k}\": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig4")
            .set("n", 19u64)
            .set("ok", true)
            .set("vals", vec![1.0, 2.5])
            .set("inner", Json::obj().set("x", 1u64));
        let s = j.render();
        assert!(s.contains("\"name\": \"fig4\""));
        assert!(s.contains("\"vals\": [1, 2.5]"));
        assert!(s.contains("\"x\": 1"));
    }

    #[test]
    fn escapes_strings() {
        let s = Json::Str("a\"b\\c\nd".into()).render();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn integers_render_clean() {
        assert_eq!(Json::Num(3.0).render(), "3");
        assert_eq!(Json::Num(3.25).render(), "3.25");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }
}
