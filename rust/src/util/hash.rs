//! Small vendored-style hashing substrate (FNV-1a, 64-bit).
//!
//! The prediction service needs fingerprints that are **stable across
//! runs and processes** — std's `DefaultHasher` is seeded per process
//! (`RandomState`), so it cannot key an on-disk store. FNV-1a is tiny,
//! dependency-free, and byte-order-explicit; the service's 128-bit
//! fingerprint runs two independently-seeded streams over the same
//! canonical byte sequence (see `service::fingerprint`).

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a 64-bit hasher.
#[derive(Clone, Debug)]
pub struct Fnv64 {
    state: u64,
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64 { state: FNV_OFFSET }
    }

    /// Start an independent stream: the seed is absorbed as the first
    /// word, so distinct seeds give decorrelated hashes of equal input.
    pub fn with_seed(seed: u64) -> Fnv64 {
        let mut h = Fnv64::new();
        h.write_u64(seed);
        h
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn write_u8(&mut self, x: u8) {
        self.write_bytes(&[x]);
    }

    pub fn write_u32(&mut self, x: u32) {
        self.write_bytes(&x.to_le_bytes());
    }

    pub fn write_u64(&mut self, x: u64) {
        self.write_bytes(&x.to_le_bytes());
    }

    pub fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }

    /// Bit pattern, so -0.0 and 0.0 (and every NaN payload) stay distinct
    /// and the hash is exactly reproducible.
    pub fn write_f64(&mut self, x: f64) {
        self.write_u64(x.to_bits());
    }

    pub fn write_bool(&mut self, x: bool) {
        self.write_u8(x as u8);
    }

    /// Length-prefixed, so `("ab", "c")` never collides with `("a", "bc")`.
    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write_bytes(s.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// SplitMix64 finalizer: diffuses per-item hashes before an
/// order-invariant (wrapping-sum) combination, so structured item hashes
/// do not cancel each other.
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_fnv1a_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(Fnv64::new().finish(), FNV_OFFSET);
        let mut h = Fnv64::new();
        h.write_bytes(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut h = Fnv64::new();
        h.write_bytes(b"foobar");
        assert_eq!(h.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn length_prefix_prevents_concat_collisions() {
        let mut a = Fnv64::new();
        a.write_str("ab");
        a.write_str("c");
        let mut b = Fnv64::new();
        b.write_str("a");
        b.write_str("bc");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn seeded_streams_differ() {
        let mut a = Fnv64::with_seed(1);
        let mut b = Fnv64::with_seed(2);
        a.write_str("same input");
        b.write_str("same input");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn mix64_diffuses_small_differences() {
        assert_ne!(mix64(1), mix64(2));
        // Neighboring inputs should differ in many bits after mixing.
        let d = (mix64(41) ^ mix64(42)).count_ones();
        assert!(d > 16, "only {d} bits differ");
    }
}
