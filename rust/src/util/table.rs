//! ASCII table rendering for bench/report output.
//!
//! Figure-shaped output (the `figures.*` bench cells, `wfpred compare`)
//! prints its series as tables whose rows mirror what the paper plots,
//! so the output is directly comparable to the paper's figures.

/// A simple column-aligned table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut w = vec![0usize; ncols];
        let width = |s: &str| s.chars().count();
        for (i, h) in self.header.iter().enumerate() {
            w[i] = width(h);
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(width(c));
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str("| ");
                out.push_str(c);
                out.push_str(&" ".repeat(w[i] - c.chars().count() + 1));
            }
            out.push_str("|\n");
        };
        line(&mut out, &self.header);
        let mut sep = String::new();
        for wi in &w {
            sep.push('|');
            sep.push_str(&"-".repeat(wi + 2));
        }
        sep.push_str("|\n");
        out.push_str(&sep);
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }
}

/// Format seconds with 2 decimals, e.g. "12.34s".
pub fn secs(x: f64) -> String {
    format!("{x:.2}s")
}

/// Format a mean ± std pair.
pub fn pm(mean: f64, std: f64) -> String {
    format!("{mean:.2} ± {std:.2}")
}

/// Format a ratio as a percentage with sign, e.g. "-16.0%".
pub fn pct(x: f64) -> String {
    format!("{:+.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["config", "actual", "predicted"]);
        t.row(&["DSS".into(), "100.00".into(), "84.00".into()]);
        t.row(&["WASS".into(), "60.00".into(), "59.50".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()), "all lines same width");
        assert!(s.contains("WASS"));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(secs(1.234), "1.23s");
        assert_eq!(pm(5.0, 0.25), "5.00 ± 0.25");
        assert_eq!(pct(-0.16), "-16.0%");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
