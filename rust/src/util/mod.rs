//! Self-contained substrates used across the crate.
//!
//! The build environment is fully offline; `anyhow` is shimmed in-tree
//! (`vendor/anyhow`), the `xla` PJRT bindings are feature-gated (see
//! PERF.md §Runtime), and the usual ecosystem crates (rand, serde, clap,
//! criterion, proptest) are re-implemented here at the scale this
//! project needs.

pub mod hash;
pub mod rng;
pub mod stats;
pub mod units;
pub mod flags;
pub mod jsonw;
pub mod table;
pub mod prop;
pub mod bench;
