//! Byte and virtual-time units.
//!
//! Virtual time is `u64` **nanoseconds** wrapped in [`SimTime`]; byte
//! counts are `u64` wrapped in [`Bytes`]. Both are plain newtypes with
//! arithmetic, ordering and human-readable display — enough type safety
//! to keep "seconds" and "bytes" from mixing, without an `uom`-style tower.

use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, Sub, SubAssign};

/// Virtual simulation time in nanoseconds.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);
    pub const MAX: SimTime = SimTime(u64::MAX);

    pub fn from_ns(ns: u64) -> Self {
        SimTime(ns)
    }
    pub fn from_us(us: u64) -> Self {
        SimTime(us * 1_000)
    }
    pub fn from_ms(ms: u64) -> Self {
        SimTime(ms * 1_000_000)
    }
    pub fn from_secs_f64(s: f64) -> Self {
        debug_assert!(s >= 0.0, "negative time: {s}");
        SimTime((s.max(0.0) * 1e9).round() as u64)
    }
    pub fn as_ns(self) -> u64 {
        self.0
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }
    pub fn as_ms_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }
    pub fn saturating_sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    /// Saturating: far-future times clamp at [`SimTime::MAX`] instead of
    /// wrapping/panicking, so `now + huge_timeout` stays a valid (never
    /// reached) event time.
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_add(rhs.0))
    }
}
impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        *self = *self + rhs;
    }
}
impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        debug_assert!(self.0 >= rhs.0, "time underflow");
        SimTime(self.0 - rhs.0)
    }
}
impl SubAssign for SimTime {
    fn sub_assign(&mut self, rhs: SimTime) {
        *self = *self - rhs;
    }
}
impl Mul<u64> for SimTime {
    type Output = SimTime;
    fn mul(self, rhs: u64) -> SimTime {
        SimTime(self.0 * rhs)
    }
}
impl Div<u64> for SimTime {
    type Output = SimTime;
    fn div(self, rhs: u64) -> SimTime {
        SimTime(self.0 / rhs)
    }
}

impl fmt::Debug for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ns = self.0;
        if ns >= 1_000_000_000 {
            write!(f, "{:.3}s", ns as f64 / 1e9)
        } else if ns >= 1_000_000 {
            write!(f, "{:.3}ms", ns as f64 / 1e6)
        } else if ns >= 1_000 {
            write!(f, "{:.3}us", ns as f64 / 1e3)
        } else {
            write!(f, "{ns}ns")
        }
    }
}

/// A byte count.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Bytes(pub u64);

pub const KB: u64 = 1 << 10;
pub const MB: u64 = 1 << 20;
pub const GB: u64 = 1 << 30;

impl Bytes {
    pub const ZERO: Bytes = Bytes(0);
    pub fn kb(n: u64) -> Self {
        Bytes(n * KB)
    }
    pub fn mb(n: u64) -> Self {
        Bytes(n * MB)
    }
    pub fn gb(n: u64) -> Self {
        Bytes(n * GB)
    }
    pub fn as_u64(self) -> u64 {
        self.0
    }
    pub fn as_f64(self) -> f64 {
        self.0 as f64
    }
    /// Number of `chunk`-sized chunks needed to hold `self` (ceiling);
    /// zero-byte files still occupy one (empty) chunk entry.
    pub fn chunks(self, chunk: Bytes) -> u64 {
        if self.0 == 0 {
            1
        } else {
            self.0.div_ceil(chunk.0.max(1))
        }
    }
}

impl Add for Bytes {
    type Output = Bytes;
    fn add(self, rhs: Bytes) -> Bytes {
        Bytes(self.0 + rhs.0)
    }
}
impl AddAssign for Bytes {
    fn add_assign(&mut self, rhs: Bytes) {
        self.0 += rhs.0;
    }
}
impl Sub for Bytes {
    type Output = Bytes;
    fn sub(self, rhs: Bytes) -> Bytes {
        Bytes(self.0 - rhs.0)
    }
}
impl Mul<u64> for Bytes {
    type Output = Bytes;
    fn mul(self, rhs: u64) -> Bytes {
        Bytes(self.0 * rhs)
    }
}

impl fmt::Debug for Bytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

impl fmt::Display for Bytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let b = self.0;
        if b >= GB {
            write!(f, "{:.2}GB", b as f64 / GB as f64)
        } else if b >= MB {
            write!(f, "{:.2}MB", b as f64 / MB as f64)
        } else if b >= KB {
            write!(f, "{:.2}KB", b as f64 / KB as f64)
        } else {
            write!(f, "{b}B")
        }
    }
}

/// Time to move `bytes` at `bytes_per_sec` (exact, rounds to ns).
pub fn transfer_time(bytes: Bytes, bytes_per_sec: f64) -> SimTime {
    debug_assert!(bytes_per_sec > 0.0);
    SimTime::from_secs_f64(bytes.as_f64() / bytes_per_sec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_display_scales() {
        assert_eq!(SimTime::from_ns(5).to_string(), "5ns");
        assert_eq!(SimTime::from_us(5).to_string(), "5.000us");
        assert_eq!(SimTime::from_ms(5).to_string(), "5.000ms");
        assert_eq!(SimTime::from_secs_f64(5.0).to_string(), "5.000s");
    }

    #[test]
    fn bytes_display_scales() {
        assert_eq!(Bytes(10).to_string(), "10B");
        assert_eq!(Bytes::kb(2).to_string(), "2.00KB");
        assert_eq!(Bytes::mb(100).to_string(), "100.00MB");
        assert_eq!(Bytes::gb(1).to_string(), "1.00GB");
    }

    #[test]
    fn chunk_count_ceiling() {
        assert_eq!(Bytes::mb(100).chunks(Bytes::mb(1)), 100);
        assert_eq!(Bytes(1).chunks(Bytes::mb(1)), 1);
        assert_eq!(Bytes(MB + 1).chunks(Bytes::mb(1)), 2);
        assert_eq!(Bytes(0).chunks(Bytes::mb(1)), 1, "zero-size files hold one chunk entry");
    }

    #[test]
    fn transfer_time_at_1gbps() {
        // 1 Gbps = 125 MB/s; 125 MB should take exactly 1 s.
        let t = transfer_time(Bytes(125_000_000), 125e6);
        assert_eq!(t, SimTime::from_secs_f64(1.0));
    }

    #[test]
    fn arithmetic_roundtrip() {
        let a = SimTime::from_ms(3) + SimTime::from_us(500);
        assert_eq!(a.as_ns(), 3_500_000);
        assert_eq!((a - SimTime::from_us(500)).as_ns(), 3_000_000);
        assert_eq!((Bytes::mb(1) * 3).as_u64(), 3 * MB);
    }

    #[test]
    fn addition_saturates_at_max() {
        assert_eq!(SimTime::MAX + SimTime::from_ns(1), SimTime::MAX);
        assert_eq!(SimTime::from_ns(5) + SimTime::MAX, SimTime::MAX);
        let mut t = SimTime::MAX;
        t += SimTime::from_secs_f64(1.0);
        assert_eq!(t, SimTime::MAX);
    }
}
