//! Minimal command-line flag parser (clap is unavailable offline).
//!
//! Supports `--name value`, `--name=value`, boolean `--flag`, positional
//! arguments, and generates a usage string. Typed getters parse on access
//! and report errors with the flag name.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_bool: bool,
}

/// Parsed command line for one (sub)command.
#[derive(Debug, Default)]
pub struct Flags {
    program: String,
    specs: Vec<FlagSpec>,
    values: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Flags {
    pub fn new(program: &str) -> Self {
        Flags { program: program.to_string(), ..Default::default() }
    }

    pub fn flag(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, default: Some(default), is_bool: false });
        self
    }

    pub fn flag_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, default: None, is_bool: false });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, default: Some("false"), is_bool: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("usage: {} [flags] [args]\n", self.program);
        for f in &self.specs {
            let d = f.default.map(|d| format!(" (default: {d})")).unwrap_or_default();
            let _ = writeln!(s, "  --{:<20} {}{}", f.name, f.help, d);
        }
        s
    }

    /// Parse `args` (not including argv[0]). Unknown flags are errors.
    pub fn parse(mut self, args: &[String]) -> Result<Self, String> {
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(body) = a.strip_prefix("--") {
                if body == "help" {
                    return Err(self.usage());
                }
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n{}", self.usage()))?
                    .clone();
                let value = if spec.is_bool {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    args.get(i).cloned().ok_or_else(|| format!("--{name} needs a value"))?
                };
                self.values.insert(name.to_string(), value);
            } else {
                self.positionals.push(a.clone());
            }
            i += 1;
        }
        // Check required flags are present.
        for s in &self.specs {
            if s.default.is_none() && !self.values.contains_key(s.name) {
                return Err(format!("missing required flag --{}\n{}", s.name, self.usage()));
            }
        }
        Ok(self)
    }

    fn raw(&self, name: &str) -> &str {
        if let Some(v) = self.values.get(name) {
            return v;
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default)
            .unwrap_or_else(|| panic!("flag --{name} was never declared"))
    }

    pub fn get(&self, name: &str) -> String {
        self.raw(name).to_string()
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.raw(name).parse().unwrap_or_else(|_| panic!("--{name}: expected integer, got {:?}", self.raw(name)))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.raw(name).parse().unwrap_or_else(|_| panic!("--{name}: expected float, got {:?}", self.raw(name)))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.raw(name), "true" | "1" | "yes")
    }

    /// Comma-separated u64 list, e.g. `--chunks 256,1024,4096`.
    pub fn get_u64_list(&self, name: &str) -> Vec<u64> {
        self.raw(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad list item {s:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_values_and_defaults() {
        let f = Flags::new("t")
            .flag("nodes", "20", "node count")
            .flag("chunk", "1048576", "chunk size")
            .switch("verbose", "chatty")
            .parse(&argv(&["--nodes", "11", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(f.get_u64("nodes"), 11);
        assert_eq!(f.get_u64("chunk"), 1048576);
        assert!(f.get_bool("verbose"));
        assert_eq!(f.positionals, vec!["pos1"]);
    }

    #[test]
    fn equals_syntax() {
        let f = Flags::new("t").flag("x", "0", "x").parse(&argv(&["--x=3.5"])).unwrap();
        assert_eq!(f.get_f64("x"), 3.5);
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(Flags::new("t").parse(&argv(&["--bogus", "1"])).is_err());
    }

    #[test]
    fn required_flag_enforced() {
        assert!(Flags::new("t").flag_req("must", "m").parse(&argv(&[])).is_err());
        let f = Flags::new("t").flag_req("must", "m").parse(&argv(&["--must", "v"])).unwrap();
        assert_eq!(f.get("must"), "v");
    }

    #[test]
    fn list_parsing() {
        let f = Flags::new("t").flag("cs", "1,2,3", "sizes").parse(&argv(&[])).unwrap();
        assert_eq!(f.get_u64_list("cs"), vec![1, 2, 3]);
    }
}
