//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` seeds `Xoshiro256**` (Blackman & Vigna), the same
//! construction the reference implementations recommend. Determinism is a
//! hard requirement: the predictor must produce identical output for
//! identical seeds (asserted by property tests), and testbed trials are
//! reproducible given `(seed, trial)`.

/// SplitMix64 — used to expand a single `u64` seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the crate-wide PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed; any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// The four xoshiro256** state words, in order. Exposed so the delta
    /// re-simulation checkpoints (`model/delta.rs`) can persist the exact
    /// stream position a stage boundary was reached at; restoring via
    /// [`Rng::from_state_words`] continues the identical sequence.
    pub fn state_words(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at an exact stream position previously captured
    /// with [`Rng::state_words`].
    pub fn from_state_words(s: [u64; 4]) -> Self {
        Rng { s }
    }

    /// Derive an independent stream, e.g. per trial or per host.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Seed of independent stream `stream` under `base` — a pure function
    /// of the pair, mixed through SplitMix64 so neighboring stream indices
    /// (trial 0, 1, 2, …) yield decorrelated generators. Campaigns use
    /// this for per-trial seeds: trial `i`'s stream depends only on
    /// `(base, i)`, never on which worker thread runs it or in what
    /// order, so parallel campaigns are byte-identical to sequential
    /// ones.
    pub fn stream_seed(base: u64, stream: u64) -> u64 {
        let mut sm = SplitMix64(base ^ stream.wrapping_mul(0xA24BAED4963EE407));
        sm.next_u64()
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's method without the rejection loop is fine at our scale;
        // keep the rejection loop for exactness.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)` (f64).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Normal(mu, sigma) via Box–Muller (polar form).
    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return mu + sigma * u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Exponential with mean `mean`.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0,1]
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear in 1000 draws");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_roughly_match() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
        assert!((var - 4.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn exp_mean_roughly_matches() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exp(5.0)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.15, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>(), "astronomically unlikely to be identity");
    }

    #[test]
    fn stream_seeds_are_pure_and_decorrelated() {
        // Pure function of (base, index).
        assert_eq!(Rng::stream_seed(42, 7), Rng::stream_seed(42, 7));
        // Distinct across neighboring indices and bases.
        let seeds: Vec<u64> = (0..100).map(|i| Rng::stream_seed(42, i)).collect();
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len(), "collisions across 100 streams");
        assert_ne!(Rng::stream_seed(1, 0), Rng::stream_seed(2, 0));
        // Neighboring streams produce decorrelated draws.
        let mut a = Rng::new(Rng::stream_seed(42, 0));
        let mut b = Rng::new(Rng::stream_seed(42, 1));
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(21);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
