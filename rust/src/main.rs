//! wfpred CLI entrypoint.
fn main() {
    wfpred::cli::main();
}
