//! User-facing predictor façade.
//!
//! [`Predictor`] wraps the queue-based model: given a workload, a
//! configuration and a platform (from system identification), it returns a
//! [`Prediction`] with the turnaround estimate, per-stage breakdown, and
//! the cost metrics the provisioning scenarios need (paper §3.2: cost =
//! total CPU time = nodes × turnaround). It also reports the predictor's
//! own wallclock cost so the §3.3 speedup claim can be measured.

use crate::model::{simulate, Config, Platform, SimReport};
use crate::util::units::SimTime;
use crate::workload::Workload;
use std::time::Instant;

/// A performance prediction for one (workload, config) point.
#[derive(Clone, Debug)]
pub struct Prediction {
    /// Predicted application turnaround.
    pub turnaround: SimTime,
    /// Predicted per-stage makespans.
    pub stage_times: Vec<SimTime>,
    /// Allocation cost in node-seconds: (hosts incl. manager) × turnaround.
    pub cost_node_secs: f64,
    /// Wallclock the predictor itself spent (for §3.3 speedup accounting).
    pub predictor_wallclock_secs: f64,
    /// Full simulation report (per-op records, utilization, …).
    pub report: SimReport,
}

impl Prediction {
    /// Cost per unit of performance (node-seconds per completed task) —
    /// "the allocation that is most cost efficient (i.e., has lowest cost
    /// per unit of performance)".
    pub fn cost_efficiency(&self) -> f64 {
        self.cost_node_secs / self.report.tasks.len().max(1) as f64
    }
}

/// The performance predictor: a platform characterization plus the model.
#[derive(Clone, Debug)]
pub struct Predictor {
    pub platform: Platform,
}

impl Predictor {
    pub fn new(platform: Platform) -> Predictor {
        Predictor { platform }
    }

    /// Predict the turnaround of `workload` under `config`.
    pub fn predict(&self, workload: &Workload, config: &Config) -> Prediction {
        let t0 = Instant::now();
        let report = simulate(workload, config, &self.platform);
        let wall = t0.elapsed().as_secs_f64();
        let stage_times = (0..report.n_stages()).map(|s| report.stage_time(s)).collect();
        let cost = config.n_hosts() as f64 * report.turnaround.as_secs_f64();
        Prediction {
            turnaround: report.turnaround,
            stage_times,
            cost_node_secs: cost,
            predictor_wallclock_secs: wall,
            report,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::Bytes;
    use crate::workload::{FileSpec, TaskSpec};

    fn tiny_workload() -> Workload {
        let mut w = Workload::new("tiny");
        let a = w.add_file(FileSpec::new("in", Bytes::mb(4)).prestaged());
        let b = w.add_file(FileSpec::new("out", Bytes::mb(4)));
        w.add_task(TaskSpec::new("t", 0).reads(a).writes(b));
        w
    }

    #[test]
    fn predicts_tiny_workload() {
        let p = Predictor::new(Platform::paper_testbed());
        let pred = p.predict(&tiny_workload(), &Config::dss(4));
        assert!(pred.turnaround > SimTime::ZERO);
        assert_eq!(pred.stage_times.len(), 1);
        assert!(pred.cost_node_secs > 0.0);
        assert_eq!(pred.report.tasks.len(), 1);
        assert!(pred.cost_efficiency() > 0.0);
    }

    #[test]
    fn deterministic() {
        let p = Predictor::new(Platform::paper_testbed());
        let a = p.predict(&tiny_workload(), &Config::dss(4));
        let b = p.predict(&tiny_workload(), &Config::dss(4));
        assert_eq!(a.turnaround, b.turnaround);
    }
}
