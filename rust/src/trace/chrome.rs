//! Chrome trace-event rendering of a recorded run (loadable in
//! `chrome://tracing` and Perfetto).
//!
//! Output is the plain trace-event *array* format: `[` … `]` with one
//! complete event per line. Every event is a flat object — nested `args`
//! are flattened to `arg_*` top-level keys — so each line (brackets and
//! trailing commas stripped) round-trips [`crate::util::jsonw::parse_flat`],
//! which the schema test exploits. Timestamps and durations are in
//! microseconds, per the trace-event spec.
//!
//! Process/thread layout: pid 1 is the application (one tid per client,
//! carrying task phases, ops, chunk attempts, and fault-recovery spans);
//! pid 2 is the station fabric (one tid per lane, in [`Lane`] order,
//! carrying residency spans tagged with their queue-wait split).

use crate::trace::recorder::Recorder;
use crate::trace::{Lane, MsgTag};
use crate::util::jsonw::Json;
use std::collections::BTreeMap;

const PID_APP: u64 = 1;
const PID_STATIONS: u64 = 2;

fn us(ns: u64) -> f64 {
    ns as f64 / 1000.0
}

/// One complete-span event (`ph: "X"`) as a flat single-line object.
fn span(name: &str, cat: &str, pid: u64, tid: u64, start: u64, end: u64) -> Json {
    Json::obj()
        .set("name", name)
        .set("cat", cat)
        .set("ph", "X")
        .set("ts", us(start))
        .set("dur", us(end.saturating_sub(start)))
        .set("pid", pid)
        .set("tid", tid)
}

/// Render the full span log as Chrome trace-event JSON.
pub fn chrome_trace(rec: &Recorder) -> String {
    let mut events: Vec<String> = Vec::new();

    for p in &rec.phases {
        let e = span(p.phase.as_str(), "phase", PID_APP, p.client as u64, p.start, p.end)
            .set("arg_task", p.task as u64);
        events.push(e.render_compact());
    }

    for o in &rec.ops {
        let name = if o.is_write { "write-op" } else { "read-op" };
        let e = span(name, "op", PID_APP, o.client as u64, o.start, o.end)
            .set("arg_op", o.op as u64)
            .set("arg_task", o.task as u64)
            .set("arg_bytes", o.bytes)
            .set("arg_abandoned", o.abandoned);
        events.push(e.render_compact());
    }

    for a in &rec.attempts {
        let client = rec.ops[a.op].client as u64;
        let e = span("chunk-attempt", "chunk", PID_APP, client, a.issue, a.settle)
            .set("arg_op", a.op as u64)
            .set("arg_chunk", a.chunk as u64)
            .set("arg_attempt", a.attempt as u64);
        events.push(e.render_compact());
    }

    for f in &rec.faults {
        let client = rec.ops[f.op].client as u64;
        let e = span("fault-recovery", "fault", PID_APP, client, f.start, f.end)
            .set("arg_op", f.op as u64)
            .set("arg_chunk", f.chunk as u64);
        events.push(e.render_compact());
    }

    // Stable per-lane thread ids, in Lane order.
    let mut lane_tid: BTreeMap<Lane, u64> = BTreeMap::new();
    for v in &rec.visits {
        let next = lane_tid.len() as u64;
        lane_tid.entry(v.lane).or_insert(next);
    }
    for v in &rec.visits {
        let tag = rec.tags.get(v.msg).copied().unwrap_or_else(MsgTag::default);
        let e = span(tag.kind, "station", PID_STATIONS, lane_tid[&v.lane], v.arrive, v.depart)
            .set("arg_lane", v.lane.label())
            .set("arg_msg", v.msg as u64)
            .set("arg_ctrl", tag.ctrl)
            .set("arg_wait_us", us(v.wait()))
            .set("arg_svc_us", us(v.svc));
        events.push(e.render_compact());
    }

    let mut out = String::with_capacity(events.len() * 96 + 4);
    out.push_str("[\n");
    for (i, e) in events.iter().enumerate() {
        out.push_str(e);
        if i + 1 < events.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Probe, TaskPhase};
    use crate::util::jsonw::{parse_flat, Scalar};
    use crate::util::units::SimTime;

    fn t(ns: u64) -> SimTime {
        SimTime::from_ns(ns)
    }

    fn sample() -> Recorder {
        let mut r = Recorder::new();
        r.task_phase(t(0), 0, 0, TaskPhase::Write);
        r.op_start(t(0), 0, 0, 0, true, 4096);
        r.msg(0, MsgTag::data("ChunkPut", 0, 0, 0));
        r.chunk_issue(t(5), 0, 0, 0);
        r.station_arrive(t(5), Lane::NicOut(0), 0, t(10));
        r.station_depart(t(15), Lane::NicOut(0), 0);
        r.station_arrive(t(15), Lane::Storage(1), 0, t(40));
        r.station_depart(t(80), Lane::Storage(1), 0);
        r.chunk_settle(t(100), 0, 0, 0);
        r.op_end(t(110), 0);
        r.task_phase(t(110), 0, 0, TaskPhase::Done);
        r.finish(t(110));
        r
    }

    /// The schema contract: every line of the array body is one flat
    /// object `parse_flat` accepts, carrying the required trace-event
    /// fields with the right types.
    #[test]
    fn every_event_line_roundtrips_parse_flat() {
        let text = chrome_trace(&sample());
        let body: Vec<&str> = text
            .lines()
            .filter(|l| !l.is_empty() && *l != "[" && *l != "]")
            .collect();
        assert_eq!(body.len(), 2 + 1 + 1 + 2, "phase, op, attempt, two visits");
        for line in body {
            let kv = parse_flat(line.trim_end_matches(',')).unwrap_or_else(|e| {
                panic!("line is not a flat object: {e}\n{line}");
            });
            let get = |k: &str| kv.iter().find(|(key, _)| key == k).map(|(_, v)| v.clone());
            assert!(matches!(get("name"), Some(Scalar::Str(_))));
            assert_eq!(get("ph"), Some(Scalar::Str("X".into())));
            assert!(matches!(get("ts"), Some(Scalar::Num(_))));
            assert!(matches!(get("dur"), Some(Scalar::Num(d)) if d >= 0.0));
            assert!(matches!(get("pid"), Some(Scalar::Num(_))));
            assert!(matches!(get("tid"), Some(Scalar::Num(_))));
        }
    }

    #[test]
    fn timestamps_are_microseconds_and_waits_surface() {
        let text = chrome_trace(&sample());
        // Storage visit: arrive 15ns, svc 40ns, depart 80ns → wait 25ns.
        let line = text.lines().find(|l| l.contains("storage:1")).expect("storage visit event");
        let kv = parse_flat(line.trim_end_matches(',')).unwrap();
        let get = |k: &str| {
            kv.iter()
                .find_map(|(key, v)| match v {
                    Scalar::Num(x) if key == k => Some(*x),
                    _ => None,
                })
                .unwrap_or_else(|| panic!("missing numeric {k}"))
        };
        assert!((get("ts") - 0.015).abs() < 1e-12);
        assert!((get("dur") - 0.065).abs() < 1e-12);
        assert!((get("arg_svc_us") - 0.040).abs() < 1e-12);
        assert!((get("arg_wait_us") - 0.025).abs() < 1e-12);
    }

    #[test]
    fn whole_output_is_a_json_array() {
        let text = chrome_trace(&sample());
        assert!(text.starts_with("[\n"));
        assert!(text.ends_with("]\n"));
        // Commas separate events (none after the last).
        let body: Vec<&str> =
            text.lines().filter(|l| !l.is_empty() && *l != "[" && *l != "]").collect();
        for (i, l) in body.iter().enumerate() {
            assert_eq!(l.ends_with(','), i + 1 < body.len(), "comma placement at line {i}");
        }
    }
}
