//! Flight recorder: zero-cost probe points through the simulation core.
//!
//! The model engine is generic over a [`Probe`] — a set of hook methods
//! called at every semantically meaningful instant of a run: message
//! creation, station arrivals/departures, whole-file operation and
//! per-chunk-attempt lifecycles, task phase transitions. The default
//! [`NoopProbe`] has empty bodies, so the monomorphized engine compiles
//! the hooks away entirely: `simulate_fid` runs the exact event sequence
//! it ran before this module existed (pinned bit-for-bit by
//! `prop_noop_probe_and_recorder_are_bit_identical`, the same lockstep
//! style as `RefFairStation`/`RefPlacement`).
//!
//! The [`Recorder`] probe assembles the hook stream into structured
//! spans — op → chunk attempt (including fault retries and failovers) →
//! per-station residency split into queue-wait vs service, plus manager
//! control-message spans and windowed utilization series per station.
//! On top of the span log, [`critical_path`] walks the dependency chain
//! that ends at turnaround and attributes every nanosecond of
//! `[0, turnaround]` to a component [`Class`] — the tiling is exact by
//! construction, not within a tolerance. [`chrome_trace`] renders the
//! span log as Chrome trace-event JSON (loadable in Perfetto), one flat
//! object per line so each event round-trips `util::jsonw::parse_flat`.
//!
//! Dependency direction: `model` depends on `trace`, never the reverse —
//! the probe vocabulary here is plain data ([`Lane`], [`MsgTag`],
//! [`TaskPhase`]) that the engine maps its own types onto.

mod chrome;
mod critical;
mod recorder;

pub use chrome::chrome_trace;
pub use critical::{critical_path, Attribution, Segment};
pub use recorder::{AttemptSpan, FaultSpan, OpSpan, PhaseSpan, Recorder, StationVisit, UtilSeries};

use crate::util::units::SimTime;

/// Sentinel for "message belongs to no operation" (e.g. `MetaPing`).
pub const NO_OP: usize = usize::MAX;

/// One station queue somewhere in the modeled system. Plain data so the
/// probe vocabulary stays independent of the engine's station types.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Lane {
    /// Host `h`'s transmit NIC queue.
    NicOut(u32),
    /// Host `h`'s receive NIC queue.
    NicIn(u32),
    /// Core-fabric link `n`'s transmission queue (routed topologies
    /// only; the star fabric has no links, so star runs never emit it).
    Link(u32),
    /// The metadata manager's service queue.
    Manager,
    /// Storage node `s`'s service queue.
    Storage(u32),
    /// Client `c`'s service queue.
    Client(u32),
}

impl Lane {
    /// The attribution class residency in this lane belongs to.
    pub fn class(self) -> Class {
        match self {
            Lane::NicOut(_) => Class::OutNic,
            Lane::NicIn(_) => Class::InNic,
            Lane::Link(_) => Class::CoreLink,
            Lane::Manager => Class::Manager,
            Lane::Storage(_) => Class::Storage,
            Lane::Client(_) => Class::ClientCompute,
        }
    }

    /// Human-readable lane label (`out-nic:3`, `manager`, …).
    pub fn label(self) -> String {
        match self {
            Lane::NicOut(h) => format!("out-nic:{h}"),
            Lane::NicIn(h) => format!("in-nic:{h}"),
            Lane::Link(n) => format!("link:{n}"),
            Lane::Manager => "manager".to_string(),
            Lane::Storage(s) => format!("storage:{s}"),
            Lane::Client(c) => format!("client:{c}"),
        }
    }
}

/// Component classes the critical path is attributed to. `Idle` absorbs
/// wall-clock with no active task on the walked chain (delayed releases;
/// zero on the paper workloads, which release everything at t=0), so the
/// classes always tile `[0, turnaround]` exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Class {
    ClientCompute,
    OutNic,
    InNic,
    CoreLink,
    Storage,
    Manager,
    FaultRecovery,
    Idle,
}

/// Number of attribution classes (`Class::ALL.len()`).
pub const N_CLASSES: usize = 8;

impl Class {
    pub const ALL: [Class; N_CLASSES] = [
        Class::ClientCompute,
        Class::OutNic,
        Class::InNic,
        Class::CoreLink,
        Class::Storage,
        Class::Manager,
        Class::FaultRecovery,
        Class::Idle,
    ];

    /// Stable snake_case name (bench record keys, JSON output).
    pub fn as_str(self) -> &'static str {
        match self {
            Class::ClientCompute => "client_compute",
            Class::OutNic => "out_nic",
            Class::InNic => "in_nic",
            Class::CoreLink => "core_link",
            Class::Storage => "storage",
            Class::Manager => "manager",
            Class::FaultRecovery => "fault_recovery",
            Class::Idle => "idle",
        }
    }

    /// Dense index into `[T; N_CLASSES]` accumulators.
    pub fn index(self) -> usize {
        Class::ALL.iter().position(|&c| c == self).expect("class in ALL")
    }
}

/// Per-task execution phase, as the driver reports it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TaskPhase {
    Read,
    Compute,
    Write,
    /// Terminal marker: finished or abandoned. Never opens a span.
    Done,
}

impl TaskPhase {
    pub fn as_str(self) -> &'static str {
        match self {
            TaskPhase::Read => "read",
            TaskPhase::Compute => "compute",
            TaskPhase::Write => "write",
            TaskPhase::Done => "done",
        }
    }
}

/// What a message is, attributed: the payload kind plus the operation /
/// chunk / attempt it serves (control messages carry the op they belong
/// to; pure-load messages like `MetaPing` carry [`NO_OP`]).
#[derive(Clone, Copy, Debug)]
pub struct MsgTag {
    /// Stable payload-kind name (`ChunkPut`, `WriteAlloc`, …).
    pub kind: &'static str,
    /// Control-plane message (metadata round trips, acks) vs data chunk.
    pub ctrl: bool,
    pub op: usize,
    pub chunk: u32,
    pub attempt: u32,
}

impl MsgTag {
    /// A control message belonging to `op` ([`NO_OP`] for pure load).
    pub fn ctrl(kind: &'static str, op: usize) -> MsgTag {
        MsgTag { kind, ctrl: true, op, chunk: u32::MAX, attempt: 0 }
    }

    /// A data-path message carrying one chunk attempt.
    pub fn data(kind: &'static str, op: usize, chunk: u32, attempt: u32) -> MsgTag {
        MsgTag { kind, ctrl: false, op, chunk, attempt }
    }
}

impl Default for MsgTag {
    fn default() -> MsgTag {
        MsgTag::ctrl("?", NO_OP)
    }
}

/// Probe points the simulation core reports into. Every method has an
/// empty default body and is `#[inline(always)]`: a probe that overrides
/// nothing (the [`NoopProbe`]) monomorphizes to zero instructions, so the
/// untraced engine pays nothing — not a branch, not a load. Probes must
/// never influence the simulation (they get `&mut self` only, no access
/// to the world or scheduler), so recording cannot perturb a prediction.
pub trait Probe {
    /// A message was created (before any station sees it).
    #[inline(always)]
    fn msg(&mut self, _msg: usize, _tag: MsgTag) {}

    /// A message (or frame train) joined a station queue. `svc` is the
    /// service it will consume there; per-frame NIC paths report one
    /// arrival per frame and the recorder accumulates the service.
    #[inline(always)]
    fn station_arrive(&mut self, _now: SimTime, _lane: Lane, _msg: usize, _svc: SimTime) {}

    /// A message fully departed a station (its last frame, on NIC lanes).
    #[inline(always)]
    fn station_depart(&mut self, _now: SimTime, _lane: Lane, _msg: usize) {}

    /// A whole-file operation was issued at a client.
    #[inline(always)]
    fn op_start(
        &mut self,
        _now: SimTime,
        _op: usize,
        _task: usize,
        _client: usize,
        _is_write: bool,
        _bytes: u64,
    ) {
    }

    /// A whole-file operation completed.
    #[inline(always)]
    fn op_end(&mut self, _now: SimTime, _op: usize) {}

    /// A whole-file operation was declared unrecoverable (degraded mode).
    #[inline(always)]
    fn op_abandoned(&mut self, _now: SimTime, _op: usize) {}

    /// One chunk attempt was issued (attempt 0 and every retry).
    #[inline(always)]
    fn chunk_issue(&mut self, _now: SimTime, _op: usize, _chunk: u32, _attempt: u32) {}

    /// The live attempt of a chunk was acknowledged.
    #[inline(always)]
    fn chunk_settle(&mut self, _now: SimTime, _op: usize, _chunk: u32, _attempt: u32) {}

    /// A task moved into `phase` ([`TaskPhase::Done`] on finish/abandon).
    #[inline(always)]
    fn task_phase(&mut self, _now: SimTime, _task: usize, _client: usize, _phase: TaskPhase) {}
}

/// The default probe: records nothing, costs nothing.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopProbe;

impl Probe for NoopProbe {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_index_round_trips() {
        for (i, c) in Class::ALL.iter().enumerate() {
            assert_eq!(c.index(), i);
        }
        assert_eq!(Class::ALL.len(), N_CLASSES);
    }

    #[test]
    fn lane_class_mapping() {
        assert_eq!(Lane::NicOut(0).class(), Class::OutNic);
        assert_eq!(Lane::NicIn(3).class(), Class::InNic);
        assert_eq!(Lane::Link(5).class(), Class::CoreLink);
        assert_eq!(Lane::Manager.class(), Class::Manager);
        assert_eq!(Lane::Storage(1).class(), Class::Storage);
        assert_eq!(Lane::Client(2).class(), Class::ClientCompute);
        assert_eq!(Lane::NicOut(3).label(), "out-nic:3");
        assert_eq!(Lane::Link(5).label(), "link:5");
        assert_eq!(Lane::Manager.label(), "manager");
    }

    #[test]
    fn noop_probe_accepts_every_hook() {
        let mut p = NoopProbe;
        p.msg(0, MsgTag::default());
        p.station_arrive(SimTime::ZERO, Lane::Manager, 0, SimTime::ZERO);
        p.station_depart(SimTime::ZERO, Lane::Manager, 0);
        p.op_start(SimTime::ZERO, 0, 0, 0, true, 1);
        p.op_end(SimTime::ZERO, 0);
        p.op_abandoned(SimTime::ZERO, 0);
        p.chunk_issue(SimTime::ZERO, 0, 0, 0);
        p.chunk_settle(SimTime::ZERO, 0, 0, 0);
        p.task_phase(SimTime::ZERO, 0, 0, TaskPhase::Read);
    }
}
