//! Critical-path extraction: walk the span chain that ends at turnaround
//! backwards and attribute every nanosecond of `[0, turnaround]` to a
//! component [`Class`].
//!
//! The walk is a covering-span recursion. At the top level the task
//! intervals (from the recorder's phase spans, so abandoned tasks count
//! too) cover the timeline; gaps with no active task are `Idle`. Inside
//! a task, its phase spans tile the interval by construction: `Compute`
//! is client compute outright, while `Read`/`Write` descend into the
//! task's op sub-spans — station residencies (split wait vs service) and
//! fault-recovery spans. At each step the walker picks the sub-span
//! covering the current instant that extends furthest (ties to the
//! latest start) and clips to it; an uncovered gap below a span is
//! attributed to that span's class, so e.g. network propagation between
//! an out-NIC departure and the matching in-NIC arrival folds into the
//! out-NIC class. Every step strictly decreases the cursor and every
//! emitted segment abuts the previous one, so the attribution tiles the
//! window *exactly* — the unit tests assert the invariant with `==`, and
//! `prop_noop_probe_and_recorder_are_bit_identical` re-checks it on
//! random workloads.

use crate::trace::recorder::Recorder;
use crate::trace::{Class, TaskPhase, N_CLASSES, NO_OP};
use std::collections::HashMap;

/// One attributed segment of the critical path. Segments are ascending,
/// contiguous, and tile `[0, turnaround]` exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segment {
    pub start: u64,
    pub end: u64,
    pub class: Class,
    /// Queue-wait portion of a station residency (vs service / other).
    pub wait: bool,
}

/// The attributed critical path of one run.
#[derive(Clone, Debug, Default)]
pub struct Attribution {
    pub turnaround: u64,
    pub segments: Vec<Segment>,
}

impl Attribution {
    /// Nanoseconds attributed to each class (indexed by [`Class::index`]).
    pub fn totals(&self) -> [u64; N_CLASSES] {
        self.totals_in(0, self.turnaround)
    }

    /// Queue-wait nanoseconds per class.
    pub fn waits(&self) -> [u64; N_CLASSES] {
        let mut acc = [0u64; N_CLASSES];
        for s in &self.segments {
            if s.wait {
                acc[s.class.index()] += s.end - s.start;
            }
        }
        acc
    }

    /// Per-class overlap with `[lo, hi)` — the per-stage breakdown
    /// clips segments against each stage's makespan window.
    pub fn totals_in(&self, lo: u64, hi: u64) -> [u64; N_CLASSES] {
        let mut acc = [0u64; N_CLASSES];
        for s in &self.segments {
            let (a, b) = (s.start.max(lo), s.end.min(hi));
            if b > a {
                acc[s.class.index()] += b - a;
            }
        }
        acc
    }

    /// The tiling invariant: segments are contiguous from 0 to
    /// turnaround, so the class totals sum to turnaround exactly.
    pub fn tiles_exactly(&self) -> bool {
        let mut cursor = 0u64;
        for s in &self.segments {
            if s.start != cursor || s.end <= s.start {
                return false;
            }
            cursor = s.end;
        }
        cursor == self.turnaround
    }
}

/// A sub-span candidate inside an op walk.
#[derive(Clone, Copy, Debug)]
struct Sub {
    start: u64,
    end: u64,
    class: Class,
    wait: bool,
}

/// Extract and attribute the critical path from a finished recording
/// (call [`Recorder::finish`] first so turnaround and stalled spans are
/// closed).
pub fn critical_path(rec: &Recorder) -> Attribution {
    // Task intervals and per-task phase lists, from the phase log (pushed
    // chronologically per task, so each list is start-sorted).
    let mut phases: HashMap<usize, Vec<usize>> = HashMap::new();
    let mut intervals: Vec<(usize, u64, u64)> = Vec::new();
    let mut seen: HashMap<usize, usize> = HashMap::new();
    for (i, p) in rec.phases.iter().enumerate() {
        phases.entry(p.task).or_default().push(i);
        match seen.get(&p.task) {
            Some(&slot) => {
                let iv = &mut intervals[slot];
                iv.1 = iv.1.min(p.start);
                iv.2 = iv.2.max(p.end);
            }
            None => {
                seen.insert(p.task, intervals.len());
                intervals.push((p.task, p.start, p.end));
            }
        }
    }

    // Op sub-spans, bucketed by (task, is_write) so a read phase only
    // walks read-op activity and a write phase only write-op activity.
    let mut subs: HashMap<(usize, bool), Vec<Sub>> = HashMap::new();
    for v in &rec.visits {
        let tag = match rec.tags.get(v.msg) {
            Some(t) if t.op != NO_OP => *t,
            _ => continue, // pure-load messages ride no op's chain
        };
        let o = &rec.ops[tag.op];
        let bucket = subs.entry((o.task, o.is_write)).or_default();
        let class = v.lane.class();
        let mid = v.svc_start();
        if mid > v.arrive {
            bucket.push(Sub { start: v.arrive, end: mid, class, wait: true });
        }
        if v.depart > mid {
            bucket.push(Sub { start: mid, end: v.depart, class, wait: false });
        }
    }
    for f in &rec.faults {
        let o = &rec.ops[f.op];
        if f.end > f.start {
            subs.entry((o.task, o.is_write)).or_default().push(Sub {
                start: f.start,
                end: f.end,
                class: Class::FaultRecovery,
                wait: false,
            });
        }
    }

    // Walk backwards from turnaround, emitting segments in descending
    // order (reversed at the end).
    let turn = rec.turnaround;
    let mut segs: Vec<Segment> = Vec::new();
    let mut t = turn;
    while t > 0 {
        let best = intervals
            .iter()
            .filter(|iv| iv.1 < t)
            .max_by_key(|iv| (iv.2.min(t), iv.1));
        match best {
            None => {
                push(&mut segs, 0, t, Class::Idle, false);
                t = 0;
            }
            Some(&(task, start, end)) if end >= t => {
                attribute_task(rec, &phases, &subs, task, start, t, &mut segs);
                t = start;
            }
            Some(&(_, _, end)) => {
                push(&mut segs, end, t, Class::Idle, false);
                t = end;
            }
        }
    }
    segs.reverse();
    let attr = Attribution { turnaround: turn, segments: segs };
    debug_assert!(attr.tiles_exactly(), "critical path must tile [0, turnaround]");
    attr
}

/// Attribute `[lo, hi]` of one task by walking its phase spans backwards.
fn attribute_task(
    rec: &Recorder,
    phases: &HashMap<usize, Vec<usize>>,
    subs: &HashMap<(usize, bool), Vec<Sub>>,
    task: usize,
    lo: u64,
    hi: u64,
    segs: &mut Vec<Segment>,
) {
    static EMPTY: Vec<usize> = Vec::new();
    let list = phases.get(&task).unwrap_or(&EMPTY);
    let mut t = hi;
    for &pi in list.iter().rev() {
        if t <= lo {
            return;
        }
        let p = &rec.phases[pi];
        if p.start >= t {
            continue;
        }
        let phi = p.end.min(t);
        let plo = p.start.max(lo);
        if t > phi {
            // Slack between phases (never happens for the contiguous
            // driver, but keeps the tiling total): the client holds it.
            push(segs, phi, t, Class::ClientCompute, false);
        }
        if phi > plo {
            match p.phase {
                TaskPhase::Compute => push(segs, plo, phi, Class::ClientCompute, false),
                TaskPhase::Read => {
                    attribute_interval(subs.get(&(task, false)), plo, phi, segs)
                }
                TaskPhase::Write | TaskPhase::Done => {
                    attribute_interval(subs.get(&(task, true)), plo, phi, segs)
                }
            }
        }
        t = plo;
    }
    if t > lo {
        push(segs, lo, t, Class::ClientCompute, false);
    }
}

/// The within-op covering-span walk over `[a, b]`.
fn attribute_interval(subs: Option<&Vec<Sub>>, a: u64, b: u64, segs: &mut Vec<Segment>) {
    static NONE: Vec<Sub> = Vec::new();
    let subs = subs.unwrap_or(&NONE);
    let mut t = b;
    while t > a {
        let best = subs
            .iter()
            .filter(|s| s.start < t)
            .max_by_key(|s| (s.end.min(t), s.start));
        match best {
            None => {
                // No recorded activity at all below t: the client is
                // orchestrating (issuing the op, processing locally).
                push(segs, a, t, Class::ClientCompute, false);
                t = a;
            }
            Some(s) if s.end >= t => {
                let cut = s.start.max(a);
                push(segs, cut, t, s.class, s.wait);
                t = cut;
            }
            Some(s) => {
                // Gap above the latest-ending span: the time directly
                // after that activity (e.g. wire propagation after an
                // out-NIC departure) is charged to its class.
                let cut = s.end.max(a);
                push(segs, cut, t, s.class, false);
                t = cut;
            }
        }
    }
}

fn push(segs: &mut Vec<Segment>, start: u64, end: u64, class: Class, wait: bool) {
    debug_assert!(start < end, "empty segment [{start}, {end})");
    debug_assert!(
        segs.last().map(|s| s.start == end).unwrap_or(true),
        "segments must abut (descending build)"
    );
    segs.push(Segment { start, end, class, wait });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Lane, MsgTag, Probe, TaskPhase};
    use crate::util::units::SimTime;

    fn t(ns: u64) -> SimTime {
        SimTime::from_ns(ns)
    }

    /// Hand-built run: one task, read [0,100], compute [100,200],
    /// write [200,400]; the write rides one storage visit [210,300]
    /// (svc 40) and one out-NIC visit [200,210] (svc 10).
    fn tiny_recording() -> Recorder {
        let mut r = Recorder::new();
        r.task_phase(t(0), 0, 0, TaskPhase::Read);
        r.op_start(t(0), 0, 0, 0, false, 64);
        r.op_end(t(100), 0);
        r.task_phase(t(100), 0, 0, TaskPhase::Compute);
        r.task_phase(t(200), 0, 0, TaskPhase::Write);
        r.op_start(t(200), 1, 0, 0, true, 64);
        r.msg(0, MsgTag::data("ChunkPut", 1, 0, 0));
        r.station_arrive(t(200), Lane::NicOut(0), 0, t(10));
        r.station_depart(t(210), Lane::NicOut(0), 0);
        r.station_arrive(t(210), Lane::Storage(0), 0, t(40));
        r.station_depart(t(300), Lane::Storage(0), 0);
        r.op_end(t(400), 1);
        r.task_phase(t(400), 0, 0, TaskPhase::Done);
        r.finish(t(400));
        r
    }

    #[test]
    fn attribution_tiles_and_classifies() {
        let attr = critical_path(&tiny_recording());
        assert!(attr.tiles_exactly(), "segments: {:?}", attr.segments);
        let totals = attr.totals();
        assert_eq!(totals.iter().sum::<u64>(), 400, "classes tile [0, turnaround]");
        // Read phase had no recorded activity → client compute; compute
        // phase → client compute; write: out-NIC 10, storage 90 (50 wait
        // + 40 service), gap [300,400] charged to storage (preceding
        // activity).
        assert_eq!(totals[Class::ClientCompute.index()], 200);
        assert_eq!(totals[Class::OutNic.index()], 10);
        assert_eq!(totals[Class::Storage.index()], 190);
        assert_eq!(totals[Class::Idle.index()], 0);
        let waits = attr.waits();
        assert_eq!(waits[Class::Storage.index()], 50, "queue-wait split survives the walk");
    }

    #[test]
    fn idle_fills_gaps_with_no_active_task() {
        let mut r = Recorder::new();
        r.task_phase(t(100), 0, 0, TaskPhase::Read);
        r.task_phase(t(150), 0, 0, TaskPhase::Done);
        r.finish(t(300));
        let attr = critical_path(&r);
        assert!(attr.tiles_exactly());
        let totals = attr.totals();
        assert_eq!(totals[Class::Idle.index()], 250, "[0,100) and (150,300]");
        assert_eq!(totals.iter().sum::<u64>(), 300);
    }

    #[test]
    fn fault_spans_win_the_covering_walk() {
        let mut r = Recorder::new();
        r.task_phase(t(0), 0, 0, TaskPhase::Write);
        r.op_start(t(0), 0, 0, 0, true, 64);
        r.chunk_issue(t(10), 0, 0, 0);
        r.chunk_issue(t(510), 0, 0, 1); // fault span [10, 510]
        r.chunk_settle(t(520), 0, 0, 1);
        r.op_end(t(530), 0);
        r.task_phase(t(530), 0, 0, TaskPhase::Done);
        r.finish(t(530));
        let attr = critical_path(&r);
        assert!(attr.tiles_exactly());
        // Retry window [10, 510] plus the trailing gap (510, 530] with no
        // later span, which the walk charges to the preceding activity.
        assert_eq!(attr.totals()[Class::FaultRecovery.index()], 520);
    }

    #[test]
    fn per_window_totals_clip() {
        let attr = critical_path(&tiny_recording());
        let head = attr.totals_in(0, 100);
        assert_eq!(head.iter().sum::<u64>(), 100);
        assert_eq!(head[Class::ClientCompute.index()], 100);
        let tail = attr.totals_in(250, 400);
        assert_eq!(tail.iter().sum::<u64>(), 150);
        assert_eq!(tail[Class::Storage.index()], 150);
    }
}
