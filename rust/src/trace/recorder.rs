//! The recording probe: assembles the hook stream into structured spans.
//!
//! Spans are closed in event order, so every vector here is
//! deterministic for a deterministic run; the open-span maps are only
//! ever *keyed into* (never iterated into output), so `HashMap` ordering
//! cannot leak into results.

use crate::trace::{Lane, MsgTag, Probe, TaskPhase, NO_OP};
use crate::util::units::SimTime;
use std::collections::{BTreeMap, HashMap};

/// One message's full residency in one station queue, with the
/// queue-wait vs service split. `svc` is the dedicated service the
/// station charged (summed over frames on per-frame NIC paths); the wait
/// is everything else: `depart − arrive − svc`, i.e. FIFO queueing at
/// single-server stations and the analytic share-starvation of the
/// weighted-fair in-NIC (a GPS server never finishes a train before
/// `arrive + svc`, so the split is well defined there too).
#[derive(Clone, Copy, Debug)]
pub struct StationVisit {
    pub lane: Lane,
    pub msg: usize,
    pub arrive: u64,
    pub depart: u64,
    pub svc: u64,
}

impl StationVisit {
    /// Instant service began: `depart − svc`, clamped into the visit.
    pub fn svc_start(&self) -> u64 {
        self.depart.saturating_sub(self.svc).max(self.arrive)
    }

    /// Queue-wait nanoseconds (residency minus service).
    pub fn wait(&self) -> u64 {
        self.svc_start() - self.arrive
    }
}

/// One whole-file operation's lifetime at its client.
#[derive(Clone, Copy, Debug)]
pub struct OpSpan {
    pub op: usize,
    pub task: usize,
    pub client: usize,
    pub is_write: bool,
    pub bytes: u64,
    pub start: u64,
    pub end: u64,
    /// Declared unrecoverable instead of completing (degraded mode).
    pub abandoned: bool,
}

/// One chunk attempt, issue to acknowledgment.
#[derive(Clone, Copy, Debug)]
pub struct AttemptSpan {
    pub op: usize,
    pub chunk: u32,
    pub attempt: u32,
    pub issue: u64,
    pub settle: u64,
}

/// Time lost to fault recovery for one chunk: from the issue of a doomed
/// attempt to the issue of its replacement (covering the attempt's wasted
/// transfers, the timeout wait, and the backoff delay) — or to the
/// instant the op was abandoned, for the final attempt of a failed op.
#[derive(Clone, Copy, Debug)]
pub struct FaultSpan {
    pub op: usize,
    pub chunk: u32,
    pub start: u64,
    pub end: u64,
}

/// One task-phase residency (read / compute / write). Per task, phase
/// spans are contiguous from task start to task end by construction.
#[derive(Clone, Copy, Debug)]
pub struct PhaseSpan {
    pub task: usize,
    pub client: usize,
    pub phase: TaskPhase,
    pub start: u64,
    pub end: u64,
}

/// Windowed utilization of one lane: fraction of each `window_ns`-wide
/// window spent in service, over `[0, turnaround]`.
#[derive(Clone, Debug)]
pub struct UtilSeries {
    pub lane: Lane,
    pub window_ns: u64,
    pub busy: Vec<f64>,
}

/// The flight recorder. Implements [`Probe`] by appending spans; after
/// the run, [`Recorder::finish`] closes whatever is still open at
/// turnaround (stalled ops and phases of degraded runs).
#[derive(Debug, Default)]
pub struct Recorder {
    /// Message tags, indexed by message id.
    pub tags: Vec<MsgTag>,
    /// Closed station visits, in departure order.
    pub visits: Vec<StationVisit>,
    /// Operation spans, indexed by op id.
    pub ops: Vec<OpSpan>,
    /// Settled chunk attempts, in settle order.
    pub attempts: Vec<AttemptSpan>,
    /// Fault-recovery spans, in retry/abandon order.
    pub faults: Vec<FaultSpan>,
    /// Closed task-phase spans, in close order.
    pub phases: Vec<PhaseSpan>,
    /// Turnaround the run ended at (set by [`Recorder::finish`]).
    pub turnaround: u64,

    open_visits: HashMap<(Lane, usize), (u64, u64)>,
    open_attempts: HashMap<(usize, u32), (u64, u32)>,
    open_phases: HashMap<usize, (u64, usize, TaskPhase)>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    /// Close everything still open at the end of the run. Open phases and
    /// ops (stalled by unrecoverable failures) are clipped to turnaround;
    /// open attempts of abandoned ops were already folded into fault
    /// spans, and in-flight station residencies are dropped — nothing
    /// that never departed can sit on the critical path.
    pub fn finish(&mut self, turnaround: SimTime) {
        self.turnaround = turnaround.as_ns();
        let mut open: Vec<usize> = self.open_phases.keys().copied().collect();
        open.sort_unstable();
        for task in open {
            let (start, client, phase) = self.open_phases.remove(&task).expect("key just listed");
            self.phases.push(PhaseSpan { task, client, phase, start, end: self.turnaround });
        }
        for o in self.ops.iter_mut() {
            if o.end == u64::MAX {
                o.end = self.turnaround;
            }
        }
        self.open_visits.clear();
        self.open_attempts.clear();
    }

    /// Per-lane windowed service-time series over `[0, turnaround]`,
    /// lanes in [`Lane`] order. Service intervals (`depart − svc` to
    /// `depart`) are credited exactly across window boundaries.
    pub fn utilization(&self, window_ns: u64) -> Vec<UtilSeries> {
        let window_ns = window_ns.max(1);
        let horizon = self.turnaround.max(1);
        let n_windows = horizon.div_ceil(window_ns) as usize;
        let mut lanes: BTreeMap<Lane, Vec<u64>> = BTreeMap::new();
        for v in &self.visits {
            let (mut lo, hi) = (v.svc_start(), v.depart.min(horizon));
            let buckets = lanes.entry(v.lane).or_insert_with(|| vec![0u64; n_windows]);
            while lo < hi {
                let w = (lo / window_ns) as usize;
                let w_end = ((w as u64 + 1) * window_ns).min(hi);
                buckets[w.min(n_windows - 1)] += w_end - lo;
                lo = w_end;
            }
        }
        lanes
            .into_iter()
            .map(|(lane, busy_ns)| UtilSeries {
                lane,
                window_ns,
                busy: busy_ns
                    .into_iter()
                    .enumerate()
                    .map(|(w, ns)| {
                        let span = window_ns.min(horizon - (w as u64 * window_ns).min(horizon));
                        if span == 0 {
                            0.0
                        } else {
                            ns as f64 / span as f64
                        }
                    })
                    .collect(),
            })
            .collect()
    }

    /// Total recorded spans (a cheap size signal for stats output).
    pub fn n_spans(&self) -> usize {
        self.visits.len() + self.attempts.len() + self.faults.len() + self.phases.len()
            + self.ops.len()
    }
}

impl Probe for Recorder {
    fn msg(&mut self, msg: usize, tag: MsgTag) {
        if msg >= self.tags.len() {
            self.tags.resize_with(msg + 1, MsgTag::default);
        }
        self.tags[msg] = tag;
    }

    fn station_arrive(&mut self, now: SimTime, lane: Lane, msg: usize, svc: SimTime) {
        let e = self.open_visits.entry((lane, msg)).or_insert((now.as_ns(), 0));
        e.1 += svc.as_ns();
    }

    fn station_depart(&mut self, now: SimTime, lane: Lane, msg: usize) {
        if let Some((arrive, svc)) = self.open_visits.remove(&(lane, msg)) {
            self.visits.push(StationVisit { lane, msg, arrive, depart: now.as_ns(), svc });
        }
    }

    fn op_start(
        &mut self,
        now: SimTime,
        op: usize,
        task: usize,
        client: usize,
        is_write: bool,
        bytes: u64,
    ) {
        debug_assert_eq!(op, self.ops.len(), "ops are issued in id order");
        self.ops.push(OpSpan {
            op,
            task,
            client,
            is_write,
            bytes,
            start: now.as_ns(),
            end: u64::MAX,
            abandoned: false,
        });
    }

    fn op_end(&mut self, now: SimTime, op: usize) {
        self.ops[op].end = now.as_ns();
    }

    fn op_abandoned(&mut self, now: SimTime, op: usize) {
        self.ops[op].end = now.as_ns();
        self.ops[op].abandoned = true;
        // The final attempt never settles: fold it into a fault span
        // ending at the abandonment, like every earlier doomed attempt.
        let mut stale: Vec<(usize, u32)> =
            self.open_attempts.keys().filter(|k| k.0 == op).copied().collect();
        stale.sort_unstable();
        for key in stale {
            let (issue, _) = self.open_attempts.remove(&key).expect("key just listed");
            self.faults.push(FaultSpan { op, chunk: key.1, start: issue, end: now.as_ns() });
        }
    }

    fn chunk_issue(&mut self, now: SimTime, op: usize, chunk: u32, attempt: u32) {
        if let Some((prev_issue, _)) = self.open_attempts.insert((op, chunk), (now.as_ns(), attempt))
        {
            // A re-issue supersedes a doomed attempt: everything since
            // that attempt's issue — its wasted transfers, the timeout
            // wait, the backoff — was fault recovery.
            debug_assert!(attempt > 0, "attempt 0 re-issued");
            self.faults.push(FaultSpan { op, chunk, start: prev_issue, end: now.as_ns() });
        }
    }

    fn chunk_settle(&mut self, now: SimTime, op: usize, chunk: u32, attempt: u32) {
        if let Some((issue, a)) = self.open_attempts.remove(&(op, chunk)) {
            debug_assert_eq!(a, attempt, "settle of a non-live attempt");
            self.attempts.push(AttemptSpan { op, chunk, attempt, issue, settle: now.as_ns() });
        }
    }

    fn task_phase(&mut self, now: SimTime, task: usize, client: usize, phase: TaskPhase) {
        if let Some((start, c, prev)) = self.open_phases.remove(&task) {
            self.phases.push(PhaseSpan { task, client: c, phase: prev, start, end: now.as_ns() });
        }
        if phase != TaskPhase::Done {
            self.open_phases.insert(task, (now.as_ns(), client, phase));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Class;

    fn t(ns: u64) -> SimTime {
        SimTime::from_ns(ns)
    }

    #[test]
    fn visit_splits_wait_and_service() {
        let mut r = Recorder::new();
        r.station_arrive(t(100), Lane::Storage(0), 7, t(30));
        r.station_depart(t(200), Lane::Storage(0), 7);
        assert_eq!(r.visits.len(), 1);
        let v = r.visits[0];
        assert_eq!(v.svc, 30);
        assert_eq!(v.wait(), 70, "residency 100ns minus 30ns service");
        assert_eq!(v.svc_start(), 170);
        assert_eq!(v.lane.class(), Class::Storage);
    }

    #[test]
    fn per_frame_arrivals_accumulate_service() {
        let mut r = Recorder::new();
        // Three frames of one message pace into an in-NIC.
        r.station_arrive(t(0), Lane::NicIn(1), 3, t(10));
        r.station_arrive(t(10), Lane::NicIn(1), 3, t(10));
        r.station_arrive(t(20), Lane::NicIn(1), 3, t(10));
        r.station_depart(t(30), Lane::NicIn(1), 3);
        let v = r.visits[0];
        assert_eq!((v.arrive, v.depart, v.svc), (0, 30, 30));
        assert_eq!(v.wait(), 0, "uncontended pacing is all service");
    }

    #[test]
    fn retry_produces_fault_span_and_final_settle() {
        let mut r = Recorder::new();
        r.op_start(t(0), 0, 0, 0, true, 1024);
        r.chunk_issue(t(10), 0, 2, 0);
        r.chunk_issue(t(500), 0, 2, 1); // timeout + backoff later
        r.chunk_settle(t(600), 0, 2, 1);
        assert_eq!(r.faults.len(), 1);
        assert_eq!((r.faults[0].start, r.faults[0].end), (10, 500));
        assert_eq!(r.attempts.len(), 1);
        assert_eq!((r.attempts[0].issue, r.attempts[0].settle, r.attempts[0].attempt), (500, 600, 1));
    }

    #[test]
    fn abandonment_closes_the_final_attempt_as_fault_time() {
        let mut r = Recorder::new();
        r.op_start(t(0), 0, 3, 1, false, 64);
        r.chunk_issue(t(5), 0, 0, 0);
        r.op_abandoned(t(90), 0);
        assert!(r.ops[0].abandoned);
        assert_eq!(r.ops[0].end, 90);
        assert_eq!(r.faults.len(), 1);
        assert_eq!((r.faults[0].start, r.faults[0].end), (5, 90));
        assert!(r.attempts.is_empty());
    }

    #[test]
    fn phases_are_contiguous_and_close_at_finish() {
        let mut r = Recorder::new();
        r.task_phase(t(0), 4, 2, TaskPhase::Read);
        r.task_phase(t(100), 4, 2, TaskPhase::Compute);
        r.task_phase(t(250), 4, 2, TaskPhase::Write);
        r.finish(t(400));
        assert_eq!(r.phases.len(), 3);
        assert_eq!(
            r.phases.iter().map(|p| (p.phase, p.start, p.end)).collect::<Vec<_>>(),
            vec![
                (TaskPhase::Read, 0, 100),
                (TaskPhase::Compute, 100, 250),
                (TaskPhase::Write, 250, 400),
            ]
        );
    }

    #[test]
    fn utilization_windows_credit_service_exactly() {
        let mut r = Recorder::new();
        r.station_arrive(t(0), Lane::NicOut(0), 0, t(150));
        r.station_depart(t(150), Lane::NicOut(0), 0);
        r.finish(t(200));
        let series = r.utilization(100);
        assert_eq!(series.len(), 1);
        let s = &series[0];
        assert_eq!(s.lane, Lane::NicOut(0));
        assert_eq!(s.busy.len(), 2);
        assert!((s.busy[0] - 1.0).abs() < 1e-12, "first window fully busy");
        assert!((s.busy[1] - 0.5).abs() < 1e-12, "half of the second window");
    }
}
