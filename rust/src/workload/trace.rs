//! On-disk workload description (paper §2.6): a line-oriented text format
//! carrying the file set (with placement hints), the task set (with
//! compute times), and the read/write edges that form the file dependency
//! graph. "The client traces can be obtained by running and profiling the
//! application" — `store/` and `testbed/` runs can be exported here and
//! replayed through the predictor.
//!
//! Format (one record per line, `#` comments):
//! ```text
//! wfpred-trace v1
//! workload <name>
//! file <name> <bytes> <hint> <replicas|-> <prestaged|->
//! task <name> <stage> <compute_ns> <pin|-> [release_ns]
//! read <task> <file>
//! write <task> <file>
//! ```
//! Hints: `default`, `local`, `striped`, `node:<k>`.

use crate::util::units::{Bytes, SimTime};
use crate::workload::spec::{FileHint, FileSpec, TaskSpec, Workload};
use std::collections::HashMap;
use std::fmt::Write as _;

/// Serialize a workload to the trace text format.
pub fn to_text(w: &Workload) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "wfpred-trace v1");
    let _ = writeln!(s, "workload {}", escape(&w.name));
    for f in &w.files {
        let hint = match f.hint {
            FileHint::Default => "default".to_string(),
            FileHint::Local => "local".to_string(),
            FileHint::OnNode(k) => format!("node:{k}"),
            FileHint::Striped => "striped".to_string(),
        };
        let repl = f.replication.map(|r| r.to_string()).unwrap_or_else(|| "-".into());
        let pre = if f.prestaged { "prestaged" } else { "-" };
        let _ = writeln!(s, "file {} {} {hint} {repl} {pre}", escape(&f.name), f.size.as_u64());
    }
    for t in &w.tasks {
        let pin = t.pin_client.map(|p| p.to_string()).unwrap_or_else(|| "-".into());
        let _ = writeln!(
            s,
            "task {} {} {} {pin} {}",
            escape(&t.name),
            t.stage,
            t.compute.as_ns(),
            t.release.as_ns()
        );
    }
    for t in &w.tasks {
        for &f in &t.reads {
            let _ = writeln!(s, "read {} {}", escape(&t.name), escape(&w.files[f].name));
        }
        for &f in &t.writes {
            let _ = writeln!(s, "write {} {}", escape(&t.name), escape(&w.files[f].name));
        }
    }
    s
}

/// Parse the trace text format back into a workload.
pub fn from_text(text: &str) -> Result<Workload, String> {
    let mut lines = text.lines().enumerate().filter(|(_, l)| {
        let l = l.trim();
        !l.is_empty() && !l.starts_with('#')
    });
    let (_, first) = lines.next().ok_or("empty trace")?;
    if first.trim() != "wfpred-trace v1" {
        return Err(format!("bad header {first:?} (want \"wfpred-trace v1\")"));
    }
    let mut w = Workload::new("unnamed");
    let mut file_ids: HashMap<String, usize> = HashMap::new();
    let mut task_ids: HashMap<String, usize> = HashMap::new();

    for (ln, raw) in lines {
        let line = raw.trim();
        let mut it = line.split_whitespace();
        let kind = it.next().unwrap();
        let ctx = |e: &str| format!("line {}: {e}: {raw:?}", ln + 1);
        match kind {
            "workload" => {
                w.name = unescape(it.next().ok_or_else(|| ctx("missing name"))?);
            }
            "file" => {
                let name = unescape(it.next().ok_or_else(|| ctx("missing name"))?);
                let size: u64 =
                    it.next().ok_or_else(|| ctx("missing size"))?.parse().map_err(|_| ctx("bad size"))?;
                let hint_s = it.next().ok_or_else(|| ctx("missing hint"))?;
                let hint = match hint_s {
                    "default" => FileHint::Default,
                    "local" => FileHint::Local,
                    "striped" => FileHint::Striped,
                    h => {
                        let k = h
                            .strip_prefix("node:")
                            .ok_or_else(|| ctx("bad hint"))?
                            .parse()
                            .map_err(|_| ctx("bad node hint"))?;
                        FileHint::OnNode(k)
                    }
                };
                let repl_s = it.next().ok_or_else(|| ctx("missing replicas"))?;
                let pre_s = it.next().ok_or_else(|| ctx("missing prestaged"))?;
                let mut f = FileSpec::new(name.clone(), Bytes(size)).hint(hint);
                if repl_s != "-" {
                    f = f.replicas(repl_s.parse().map_err(|_| ctx("bad replicas"))?);
                }
                if pre_s == "prestaged" {
                    f = f.prestaged();
                }
                if file_ids.insert(name.clone(), w.add_file(f)).is_some() {
                    return Err(ctx(&format!("duplicate file {name:?}")));
                }
            }
            "task" => {
                let name = unescape(it.next().ok_or_else(|| ctx("missing name"))?);
                let stage: u32 =
                    it.next().ok_or_else(|| ctx("missing stage"))?.parse().map_err(|_| ctx("bad stage"))?;
                let comp: u64 =
                    it.next().ok_or_else(|| ctx("missing compute"))?.parse().map_err(|_| ctx("bad compute"))?;
                let pin_s = it.next().ok_or_else(|| ctx("missing pin"))?;
                let mut t = TaskSpec::new(name.clone(), stage).compute(SimTime::from_ns(comp));
                if pin_s != "-" {
                    t = t.pin(pin_s.parse().map_err(|_| ctx("bad pin"))?);
                }
                if let Some(rel) = it.next() {
                    t = t.release_at(SimTime::from_ns(rel.parse().map_err(|_| ctx("bad release"))?));
                }
                if task_ids.insert(name.clone(), w.add_task(t)).is_some() {
                    return Err(ctx(&format!("duplicate task {name:?}")));
                }
            }
            "read" | "write" => {
                let tname = unescape(it.next().ok_or_else(|| ctx("missing task"))?);
                let fname = unescape(it.next().ok_or_else(|| ctx("missing file"))?);
                let &ti = task_ids.get(&tname).ok_or_else(|| ctx("unknown task"))?;
                let &fi = file_ids.get(&fname).ok_or_else(|| ctx("unknown file"))?;
                if kind == "read" {
                    w.tasks[ti].reads.push(fi);
                } else {
                    w.tasks[ti].writes.push(fi);
                }
            }
            k => return Err(ctx(&format!("unknown record {k:?}"))),
        }
    }
    w.validate()?;
    Ok(w)
}

/// Names may not contain whitespace; escape it.
fn escape(s: &str) -> String {
    s.replace(' ', "\\s")
}

fn unescape(s: &str) -> String {
    s.replace("\\s", " ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::patterns::{pipeline, PatternScale};
    use crate::workload::blast::{blast, BlastParams};

    fn assert_roundtrip(w: &Workload) {
        let text = to_text(w);
        let back = from_text(&text).expect("parse back");
        assert_eq!(back.name, w.name);
        assert_eq!(back.files.len(), w.files.len());
        assert_eq!(back.tasks.len(), w.tasks.len());
        for (a, b) in w.files.iter().zip(back.files.iter()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.size, b.size);
            assert_eq!(a.hint, b.hint);
            assert_eq!(a.replication, b.replication);
            assert_eq!(a.prestaged, b.prestaged);
        }
        for (a, b) in w.tasks.iter().zip(back.tasks.iter()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.stage, b.stage);
            assert_eq!(a.compute, b.compute);
            assert_eq!(a.reads, b.reads);
            assert_eq!(a.writes, b.writes);
            assert_eq!(a.pin_client, b.pin_client);
            assert_eq!(a.release, b.release);
        }
    }

    #[test]
    fn roundtrip_pipeline() {
        assert_roundtrip(&pipeline(5, PatternScale::Medium, true));
    }

    #[test]
    fn roundtrip_blast() {
        assert_roundtrip(&blast(14, &BlastParams::default()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_text("").is_err());
        assert!(from_text("not-a-trace").is_err());
        assert!(from_text("wfpred-trace v1\nbogus line here").is_err());
        assert!(from_text("wfpred-trace v1\nread ghost ghost").is_err());
    }

    #[test]
    fn rejects_duplicate_file() {
        let t = "wfpred-trace v1\nworkload x\nfile a 10 default - -\nfile a 10 default - -";
        assert!(from_text(t).unwrap_err().contains("duplicate"));
    }

    #[test]
    fn names_with_spaces_survive() {
        let mut w = Workload::new("has space");
        let f = w.add_file(FileSpec::new("my file", Bytes::mb(1)).prestaged());
        w.add_task(TaskSpec::new("my task", 0).reads(f));
        assert_roundtrip(&w);
    }
}
