//! BLAST workload (paper §3.2, Fig 7): "each node receives a set of DNA
//! sequences as input (a file for each node) and all nodes search the same
//! database file. The workload includes 200 search queries using the
//! RefSeq database (total size of 1.67 GB) … We assume the database is
//! already loaded in intermediate storage."
//!
//! The storage system sees only BLAST's I/O shape: every worker reads the
//! full database plus its private query file, computes (CPU-bound search)
//! and writes its result file. Per-query compute time is a calibration
//! constant (the real tool's search speed); the default reproduces the
//! paper's regime where the best partitioning trades app nodes against
//! storage bandwidth (Fig 8).

use crate::util::units::{Bytes, SimTime};
use crate::workload::spec::{FileSpec, TaskSpec, Workload};

/// BLAST workload parameters.
#[derive(Clone, Debug)]
pub struct BlastParams {
    /// Total search queries to distribute over application nodes.
    pub queries: u32,
    /// Database size (RefSeq in the paper: 1.67 GB).
    pub db_size: Bytes,
    /// Per-node query input file size.
    pub query_file: Bytes,
    /// Per-node result file size.
    pub output_file: Bytes,
    /// Compute time per query (calibration constant).
    pub per_query: SimTime,
}

impl Default for BlastParams {
    fn default() -> Self {
        BlastParams {
            queries: 200,
            db_size: Bytes((1.67 * (1u64 << 30) as f64) as u64),
            query_file: Bytes::mb(1),
            output_file: Bytes::mb(5),
            // ~10 s per RefSeq search on a 2.33 GHz Xeon core; calibrated
            // so the partitioning optimum lands where Fig 8 reports it
            // (14 app / 5 storage) with the paper's ~10x best-to-worst
            // spread. See EXPERIMENTS.md §Fig8.
            per_query: SimTime::from_secs_f64(10.0),
        }
    }
}

/// Build the BLAST workload for `n_app` application nodes.
///
/// One task per node; queries are split as evenly as possible (the first
/// `queries % n_app` nodes take one extra). Query files and the database
/// are prestaged; the database is striped system-wide (Default hint), so
/// this workload has no single-node locality and the scheduler spreads
/// tasks freely.
pub fn blast(n_app: usize, p: &BlastParams) -> Workload {
    assert!(n_app > 0);
    let mut w = Workload::new(format!("blast-q{}-n{}", p.queries, n_app));
    let db = w.add_file(FileSpec::new("refseq.db", p.db_size).prestaged());
    let base = p.queries / n_app as u32;
    let extra = (p.queries % n_app as u32) as usize;
    for i in 0..n_app {
        let q = base + u32::from(i < extra);
        let qf = w.add_file(FileSpec::new(format!("queries.{i}"), p.query_file).prestaged());
        let out = w.add_file(FileSpec::new(format!("result.{i}"), p.output_file));
        w.add_task(
            TaskSpec::new(format!("blast.{i}"), 0)
                .reads(db)
                .reads(qf)
                .writes(out)
                .compute(SimTime(p.per_query.as_ns() * q as u64)),
        );
    }
    debug_assert!(w.validate().is_ok());
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_split_is_even() {
        let p = BlastParams::default();
        let w = blast(14, &p);
        assert_eq!(w.tasks.len(), 14);
        let total: u64 = w.tasks.iter().map(|t| t.compute.as_ns() / p.per_query.as_ns()).sum();
        assert_eq!(total, 200);
        let max = w.tasks.iter().map(|t| t.compute.as_ns()).max().unwrap();
        let min = w.tasks.iter().map(|t| t.compute.as_ns()).min().unwrap();
        assert!(max - min <= p.per_query.as_ns(), "split within one query");
    }

    #[test]
    fn all_tasks_read_db() {
        let w = blast(8, &BlastParams::default());
        assert!(w.tasks.iter().all(|t| t.reads.contains(&0)));
        assert!(w.files[0].prestaged);
        assert!(w.validate().is_ok());
    }

    #[test]
    fn db_size_matches_paper() {
        let p = BlastParams::default();
        let gb = p.db_size.as_f64() / (1u64 << 30) as f64;
        assert!((gb - 1.67).abs() < 0.01);
    }

    #[test]
    fn single_node_takes_all_queries() {
        let p = BlastParams::default();
        let w = blast(1, &p);
        assert_eq!(w.tasks[0].compute.as_ns(), 200 * p.per_query.as_ns());
    }
}
