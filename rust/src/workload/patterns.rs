//! Synthetic benchmarks for the common workflow data-access patterns
//! (paper §3.1, Fig 3): pipeline, reduce, and broadcast — "among the most
//! used patterns uncovered by studying over 20 scientific workflow
//! applications".
//!
//! Each generator takes `wass: bool`: when true, the workload carries the
//! pattern-specific placement hints a workflow-aware deployment would use
//! (local placement for pipeline intermediates, collocation for reduce
//! inputs, replication for broadcast files); when false it is the plain
//! DSS workload. This mirrors the paper, where per-file optimizations are
//! "described as part of the application workload description" (§2.4).
//!
//! **Sizes are an assumption** (the paper's Fig 3 content did not survive
//! into our source text): medium pipeline is 100 MB → 200 MB → 100 MB →
//! 10 MB per pipeline, reduce is 100 MB inputs / 10 MB intermediates /
//! 10 MB output, broadcast is one 100 MB file; `large` is 10× medium
//! (§3.1). See DESIGN.md §6.

use crate::util::units::{Bytes, SimTime, MB};
use crate::workload::spec::{FileHint, FileSpec, TaskSpec, Workload};

/// Workload scale: `large` is 10× `medium`, `small` 10× below (the paper
/// omits small "because it already exhibits a similar performance between
/// different configurations").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PatternScale {
    Small,
    Medium,
    Large,
}

impl PatternScale {
    /// Multiplier applied to the medium file sizes.
    pub fn factor(self) -> u64 {
        match self {
            PatternScale::Small => 1, // divided below
            PatternScale::Medium => 1,
            PatternScale::Large => 10,
        }
    }

    fn size(self, medium_mb: u64) -> Bytes {
        match self {
            PatternScale::Small => Bytes((medium_mb * MB) / 10),
            PatternScale::Medium => Bytes::mb(medium_mb),
            PatternScale::Large => Bytes::mb(medium_mb * 10),
        }
    }
}

impl std::fmt::Display for PatternScale {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PatternScale::Small => write!(f, "small"),
            PatternScale::Medium => write!(f, "medium"),
            PatternScale::Large => write!(f, "large"),
        }
    }
}

/// Pipeline benchmark: `n` parallel pipelines, three processing stages
/// each; "the output of one task is the input of the next task in the
/// chain". WASS stores intermediates on the node that produced them and
/// the scheduler follows the data.
pub fn pipeline(n: usize, scale: PatternScale, wass: bool) -> Workload {
    let mut w = Workload::new(format!("pipeline-{scale}-{}", sysname(wass)));
    for p in 0..n {
        let hint_in = if wass { FileHint::OnNode(p) } else { FileHint::Default };
        let hint_mid = if wass { FileHint::Local } else { FileHint::Default };
        let input =
            w.add_file(FileSpec::new(format!("in.{p}"), scale.size(100)).hint(hint_in).prestaged());
        let f1 = w.add_file(FileSpec::new(format!("mid1.{p}"), scale.size(200)).hint(hint_mid));
        let f2 = w.add_file(FileSpec::new(format!("mid2.{p}"), scale.size(100)).hint(hint_mid));
        let out = w.add_file(FileSpec::new(format!("out.{p}"), scale.size(10)).hint(hint_mid));
        w.add_task(TaskSpec::new(format!("s1.{p}"), 0).reads(input).writes(f1));
        w.add_task(TaskSpec::new(format!("s2.{p}"), 1).reads(f1).writes(f2));
        w.add_task(TaskSpec::new(format!("s3.{p}"), 2).reads(f2).writes(out));
    }
    debug_assert!(w.validate().is_ok());
    w
}

/// Reduce (gather) benchmark: `n` producers each consume an input and
/// produce an intermediate; one reducer consumes all intermediates.
/// WASS collocates all intermediates on one storage node (`reduce_node`)
/// and the reducer runs there.
pub fn reduce(n: usize, scale: PatternScale, wass: bool) -> Workload {
    let mut w = Workload::new(format!("reduce-{scale}-{}", sysname(wass)));
    let reduce_node = 0usize;
    let mut mids = Vec::with_capacity(n);
    for p in 0..n {
        let hint_in = if wass { FileHint::OnNode(p) } else { FileHint::Default };
        let hint_mid = if wass { FileHint::OnNode(reduce_node) } else { FileHint::Default };
        let input =
            w.add_file(FileSpec::new(format!("in.{p}"), scale.size(100)).hint(hint_in).prestaged());
        let mid = w.add_file(FileSpec::new(format!("mid.{p}"), scale.size(10)).hint(hint_mid));
        w.add_task(TaskSpec::new(format!("produce.{p}"), 0).reads(input).writes(mid));
        mids.push(mid);
    }
    let hint_out = if wass { FileHint::Local } else { FileHint::Default };
    let out = w.add_file(FileSpec::new("reduce.out", scale.size(10)).hint(hint_out));
    let mut t = TaskSpec::new("reduce", 1).writes(out);
    for mid in mids {
        t = t.reads(mid);
    }
    w.add_task(t);
    debug_assert!(w.validate().is_ok());
    w
}

/// Broadcast benchmark: one producer creates a file consumed by `n`
/// parallel tasks. The candidate optimization is replication
/// (`replicas` ≥ 1); the paper's finding (Fig 6) is that striping already
/// spreads the load, so replicas do not pay off.
pub fn broadcast(n: usize, scale: PatternScale, replicas: u32) -> Workload {
    let mut w = Workload::new(format!("broadcast-{scale}-r{replicas}"));
    let seed =
        w.add_file(FileSpec::new("seed", scale.size(10)).prestaged());
    let shared = w.add_file(
        FileSpec::new("broadcast", scale.size(100)).replicas(replicas),
    );
    w.add_task(TaskSpec::new("produce", 0).reads(seed).writes(shared));
    for p in 0..n {
        let out = w.add_file(FileSpec::new(format!("out.{p}"), scale.size(10)));
        w.add_task(TaskSpec::new(format!("consume.{p}"), 1).reads(shared).writes(out));
    }
    debug_assert!(w.validate().is_ok());
    w
}

fn sysname(wass: bool) -> &'static str {
    if wass {
        "wass"
    } else {
        "dss"
    }
}

/// Attach a uniform compute time to every task of a workload (the
/// synthetic benchmarks are "composed exclusively of I/O operations", so
/// the default is zero; tests use this to model mixed workloads).
pub fn with_compute(mut w: Workload, t: SimTime) -> Workload {
    for task in &mut w.tasks {
        task.compute = t;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_shape() {
        let w = pipeline(19, PatternScale::Medium, false);
        assert_eq!(w.tasks.len(), 19 * 3);
        assert_eq!(w.files.len(), 19 * 4);
        assert_eq!(w.n_stages(), 3);
        assert!(w.validate().is_ok());
        // All files default-placed in DSS mode.
        assert!(w.files.iter().all(|f| f.hint == FileHint::Default));
    }

    #[test]
    fn pipeline_wass_hints() {
        let w = pipeline(3, PatternScale::Medium, true);
        // Inputs pinned per pipeline, intermediates local.
        assert_eq!(w.files[0].hint, FileHint::OnNode(0));
        assert_eq!(w.files[1].hint, FileHint::Local);
        assert!(w.files[0].prestaged);
        assert!(!w.files[1].prestaged);
    }

    #[test]
    fn large_is_10x_medium() {
        let m = pipeline(2, PatternScale::Medium, false);
        let l = pipeline(2, PatternScale::Large, false);
        assert_eq!(l.bytes_written().as_u64(), 10 * m.bytes_written().as_u64());
    }

    #[test]
    fn reduce_shape() {
        let w = reduce(19, PatternScale::Medium, true);
        assert_eq!(w.tasks.len(), 20);
        assert_eq!(w.n_stages(), 2);
        // Reducer reads all 19 intermediates.
        let red = w.tasks.iter().find(|t| t.name == "reduce").unwrap();
        assert_eq!(red.reads.len(), 19);
        // All intermediates collocated on node 0 under WASS.
        for p in 0..19 {
            let mid = w.files.iter().find(|f| f.name == format!("mid.{p}")).unwrap();
            assert_eq!(mid.hint, FileHint::OnNode(0));
        }
        assert!(w.validate().is_ok());
    }

    #[test]
    fn broadcast_shape() {
        let w = broadcast(19, PatternScale::Medium, 4);
        assert_eq!(w.tasks.len(), 20);
        let shared = w.files.iter().find(|f| f.name == "broadcast").unwrap();
        assert_eq!(shared.replication, Some(4));
        assert!(w.validate().is_ok());
        // 19 consumers all read the shared file.
        let readers = w.tasks.iter().filter(|t| t.reads.contains(&1)).count();
        assert_eq!(readers, 19);
    }

    #[test]
    fn scales_are_ordered() {
        let s = PatternScale::Small.size(100);
        let m = PatternScale::Medium.size(100);
        let l = PatternScale::Large.size(100);
        assert!(s < m && m < l);
        assert_eq!(l.as_u64(), 10 * m.as_u64());
        assert_eq!(m.as_u64(), 10 * s.as_u64());
    }

    #[test]
    fn with_compute_applies_uniformly() {
        let w = with_compute(pipeline(2, PatternScale::Small, false), SimTime::from_ms(5));
        assert!(w.tasks.iter().all(|t| t.compute == SimTime::from_ms(5)));
    }
}
