//! Workload structure: files, tasks, and the dependency DAG they induce.
//!
//! Workflow applications communicate through intermediate files with a
//! single-writer / many-readers discipline (paper §2: "relatively large
//! files, single-write-many-reads"). [`Workload::validate`] enforces that
//! discipline plus acyclicity, so every other layer may assume it.

use crate::util::units::{Bytes, SimTime};

pub type FileId = usize;
pub type TaskId = usize;

/// Per-file data placement hint (paper §2.4: "file-specific configuration
/// … is described as part of the application workload description").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FileHint {
    /// Use the system-wide placement policy.
    Default,
    /// Place on the storage node collocated with the *writing* client
    /// (pipeline-optimized placement).
    Local,
    /// Place all chunks on one specific storage node (collocation for the
    /// reduce pattern, or pre-staged inputs pinned to a node).
    OnNode(usize),
    /// Stripe system-wide regardless of the system default — the
    /// broadcast-friendly placement for widely shared inputs (striping
    /// already spreads the read load, Fig 6).
    Striped,
}

/// A file in the intermediate storage system.
#[derive(Clone, Debug)]
pub struct FileSpec {
    pub name: String,
    pub size: Bytes,
    pub hint: FileHint,
    /// Per-file replication level override (broadcast optimization).
    pub replication: Option<u32>,
    /// Already present in intermediate storage at t=0 (e.g., the BLAST
    /// database: "we assume the database is already loaded").
    pub prestaged: bool,
}

impl FileSpec {
    pub fn new(name: impl Into<String>, size: Bytes) -> Self {
        FileSpec { name: name.into(), size, hint: FileHint::Default, replication: None, prestaged: false }
    }
    pub fn hint(mut self, h: FileHint) -> Self {
        self.hint = h;
        self
    }
    pub fn replicas(mut self, r: u32) -> Self {
        self.replication = Some(r);
        self
    }
    pub fn prestaged(mut self) -> Self {
        self.prestaged = true;
        self
    }
}

/// A task: reads inputs, computes, writes outputs. Tasks are the nodes of
/// the workflow DAG; edges are files.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub name: String,
    /// Stage label for per-stage reporting (paper Fig 5c).
    pub stage: u32,
    pub reads: Vec<FileId>,
    pub writes: Vec<FileId>,
    pub compute: SimTime,
    /// Earliest release time. The paper names its idealized simultaneous
    /// launch as the main inaccuracy source ("all pipelines are launched
    /// in the simulation exactly at the same time while … coordination
    /// overheads make them slightly staggered", §5) and prescribes "a
    /// richer workload description" — this is that extension: traces can
    /// carry measured submission times.
    pub release: SimTime,
    /// Pin to a specific client (used by tests; patterns normally rely on
    /// data-location-aware scheduling instead).
    pub pin_client: Option<usize>,
}

impl TaskSpec {
    pub fn new(name: impl Into<String>, stage: u32) -> Self {
        TaskSpec {
            name: name.into(),
            stage,
            reads: Vec::new(),
            writes: Vec::new(),
            compute: SimTime::ZERO,
            release: SimTime::ZERO,
            pin_client: None,
        }
    }
    pub fn reads(mut self, f: FileId) -> Self {
        self.reads.push(f);
        self
    }
    pub fn writes(mut self, f: FileId) -> Self {
        self.writes.push(f);
        self
    }
    pub fn compute(mut self, t: SimTime) -> Self {
        self.compute = t;
        self
    }
    pub fn pin(mut self, client: usize) -> Self {
        self.pin_client = Some(client);
        self
    }
    pub fn release_at(mut self, t: SimTime) -> Self {
        self.release = t;
        self
    }
}

/// A complete workload description.
#[derive(Clone, Debug, Default)]
pub struct Workload {
    pub name: String,
    pub files: Vec<FileSpec>,
    pub tasks: Vec<TaskSpec>,
}

impl Workload {
    pub fn new(name: impl Into<String>) -> Self {
        Workload { name: name.into(), files: Vec::new(), tasks: Vec::new() }
    }

    pub fn add_file(&mut self, f: FileSpec) -> FileId {
        self.files.push(f);
        self.files.len() - 1
    }

    pub fn add_task(&mut self, t: TaskSpec) -> TaskId {
        self.tasks.push(t);
        self.tasks.len() - 1
    }

    pub fn n_stages(&self) -> u32 {
        self.tasks.iter().map(|t| t.stage + 1).max().unwrap_or(0)
    }

    /// Total bytes written by tasks (excludes prestaged files).
    pub fn bytes_written(&self) -> Bytes {
        let mut b = Bytes::ZERO;
        for t in &self.tasks {
            for &f in &t.writes {
                b += self.files[f].size;
            }
        }
        b
    }

    /// Total bytes read by tasks.
    pub fn bytes_read(&self) -> Bytes {
        let mut b = Bytes::ZERO;
        for t in &self.tasks {
            for &f in &t.reads {
                b += self.files[f].size;
            }
        }
        b
    }

    /// The task that writes `file`, if any.
    pub fn writer_of(&self, file: FileId) -> Option<TaskId> {
        self.tasks.iter().position(|t| t.writes.contains(&file))
    }

    /// Check the single-writer discipline, reference validity, and
    /// acyclicity of the induced task DAG. Returns a human-readable error.
    pub fn validate(&self) -> Result<(), String> {
        let mut writer: Vec<Option<TaskId>> = vec![None; self.files.len()];
        for (ti, t) in self.tasks.iter().enumerate() {
            for &f in t.reads.iter().chain(t.writes.iter()) {
                if f >= self.files.len() {
                    return Err(format!("task {} references unknown file {}", t.name, f));
                }
            }
            for &f in &t.writes {
                if self.files[f].prestaged {
                    return Err(format!("task {} writes prestaged file {}", t.name, self.files[f].name));
                }
                if let Some(prev) = writer[f] {
                    return Err(format!(
                        "file {} written by both {} and {}",
                        self.files[f].name, self.tasks[prev].name, t.name
                    ));
                }
                writer[f] = Some(ti);
            }
        }
        for (fi, f) in self.files.iter().enumerate() {
            if !f.prestaged && writer[fi].is_none() {
                // A read of a never-written, non-prestaged file would deadlock.
                if self.tasks.iter().any(|t| t.reads.contains(&fi)) {
                    return Err(format!("file {} is read but never written nor prestaged", f.name));
                }
            }
        }
        // Kahn's algorithm over task deps (read-after-write edges).
        let n = self.tasks.len();
        let mut indeg = vec![0usize; n];
        let mut out: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for (ti, t) in self.tasks.iter().enumerate() {
            for &f in &t.reads {
                if let Some(w) = writer[f] {
                    out[w].push(ti);
                    indeg[ti] += 1;
                }
            }
        }
        let mut q: Vec<TaskId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0;
        while let Some(u) = q.pop() {
            seen += 1;
            for &v in &out[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    q.push(v);
                }
            }
        }
        if seen != n {
            return Err("task dependency graph has a cycle".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini() -> Workload {
        let mut w = Workload::new("mini");
        let a = w.add_file(FileSpec::new("in", Bytes::mb(1)).prestaged());
        let b = w.add_file(FileSpec::new("mid", Bytes::mb(2)));
        let c = w.add_file(FileSpec::new("out", Bytes::mb(1)));
        w.add_task(TaskSpec::new("t1", 0).reads(a).writes(b));
        w.add_task(TaskSpec::new("t2", 1).reads(b).writes(c));
        w
    }

    #[test]
    fn valid_workload_passes() {
        assert!(mini().validate().is_ok());
        assert_eq!(mini().n_stages(), 2);
        assert_eq!(mini().bytes_written(), Bytes::mb(3));
        assert_eq!(mini().bytes_read(), Bytes::mb(3));
        assert_eq!(mini().writer_of(1), Some(0));
        assert_eq!(mini().writer_of(0), None);
    }

    #[test]
    fn double_writer_rejected() {
        let mut w = mini();
        w.add_task(TaskSpec::new("t3", 0).writes(1));
        let e = w.validate().unwrap_err();
        assert!(e.contains("written by both"), "{e}");
    }

    #[test]
    fn dangling_read_rejected() {
        let mut w = mini();
        let ghost = w.add_file(FileSpec::new("ghost", Bytes::mb(1)));
        w.add_task(TaskSpec::new("t4", 0).reads(ghost));
        let e = w.validate().unwrap_err();
        assert!(e.contains("never written"), "{e}");
    }

    #[test]
    fn cycle_rejected() {
        let mut w = Workload::new("cyc");
        let a = w.add_file(FileSpec::new("a", Bytes::mb(1)));
        let b = w.add_file(FileSpec::new("b", Bytes::mb(1)));
        w.add_task(TaskSpec::new("t1", 0).reads(b).writes(a));
        w.add_task(TaskSpec::new("t2", 0).reads(a).writes(b));
        let e = w.validate().unwrap_err();
        assert!(e.contains("cycle"), "{e}");
    }

    #[test]
    fn write_to_prestaged_rejected() {
        let mut w = mini();
        w.add_task(TaskSpec::new("t5", 0).writes(0));
        assert!(w.validate().unwrap_err().contains("prestaged"));
    }
}
