//! Montage-like workload (paper Fig 1): the astronomy mosaic workflow the
//! paper ran on Grid'5000 to demonstrate that "different storage system
//! configurations deliver different performance and the choice of the
//! optimal configuration point is not intuitive".
//!
//! We model Montage's characteristic I/O structure at reduced scale:
//! a projection fan (per-tile reprojection), an overlap-fitting stage that
//! reads *neighboring* tiles (cross-node traffic), and a final mosaic
//! stage that gathers everything (reduce-like). What matters for Fig 1 is
//! the mix of parallel medium-size writes and a wide gather — the mix that
//! makes low stripe widths congest storage nodes and high stripe widths
//! pay connection-handling/metadata overheads.

use crate::util::units::Bytes;
use crate::workload::spec::{FileSpec, TaskSpec, Workload};

/// Build a Montage-like mosaic workload over `tiles` input tiles.
pub fn montage(tiles: usize) -> Workload {
    assert!(tiles >= 2);
    let mut w = Workload::new(format!("montage-{tiles}"));
    let mut projected = Vec::with_capacity(tiles);
    // Stage 0 — mProject: reproject each raw tile (read 20 MB, write 25 MB).
    for i in 0..tiles {
        let raw = w.add_file(FileSpec::new(format!("raw.{i}"), Bytes::mb(20)).prestaged());
        let proj = w.add_file(FileSpec::new(format!("proj.{i}"), Bytes::mb(25)));
        w.add_task(TaskSpec::new(format!("mProject.{i}"), 0).reads(raw).writes(proj));
        projected.push(proj);
    }
    // Stage 1 — mDiffFit: fit each overlapping pair (ring topology).
    let mut fits = Vec::with_capacity(tiles);
    for i in 0..tiles {
        let j = (i + 1) % tiles;
        let fit = w.add_file(FileSpec::new(format!("fit.{i}"), Bytes::mb(5)));
        w.add_task(
            TaskSpec::new(format!("mDiffFit.{i}"), 1)
                .reads(projected[i])
                .reads(projected[j])
                .writes(fit),
        );
        fits.push(fit);
    }
    // Stage 2 — mConcatFit + mAdd: gather all fits and projections into
    // the mosaic (a wide reduce).
    let mosaic = w.add_file(FileSpec::new("mosaic.fits", Bytes::mb(50)));
    let mut add = TaskSpec::new("mAdd", 2).writes(mosaic);
    for &f in fits.iter().chain(projected.iter()) {
        add = add.reads(f);
    }
    w.add_task(add);
    debug_assert!(w.validate().is_ok());
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure() {
        let w = montage(19);
        assert_eq!(w.n_stages(), 3);
        assert_eq!(w.tasks.len(), 19 + 19 + 1);
        assert!(w.validate().is_ok());
    }

    #[test]
    fn diff_fit_reads_neighbors() {
        let w = montage(4);
        let t = w.tasks.iter().find(|t| t.name == "mDiffFit.3").unwrap();
        // Reads proj.3 and proj.0 (ring wrap-around).
        let names: Vec<&str> = t.reads.iter().map(|&f| w.files[f].name.as_str()).collect();
        assert_eq!(names, vec!["proj.3", "proj.0"]);
    }

    #[test]
    fn mosaic_gathers_everything() {
        let w = montage(10);
        let add = w.tasks.iter().find(|t| t.name == "mAdd").unwrap();
        assert_eq!(add.reads.len(), 20, "all fits + all projections");
    }
}
