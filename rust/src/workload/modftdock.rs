//! modFTDock workload — the third application the paper's introduction
//! names ("e.g., modFTDock, Montage or BLAST"): protein-docking with the
//! FTDock engine, structured as the classic many-task campaign.
//!
//! I/O shape (from the 3D-Dock suite the paper cites): every docking task
//! reads the shared *receptor* structure plus its own *ligand* candidate,
//! runs a (compute-heavy) FFT correlation search, and writes a scored
//! transform list; a final merge/rescore stage gathers all outputs —
//! i.e., a broadcast stage fused with a reduce stage, which is exactly
//! why the paper groups it with the patterns of §3.1.

use crate::util::units::{Bytes, SimTime};
use crate::workload::spec::{FileHint, FileSpec, TaskSpec, Workload};

/// modFTDock campaign parameters.
#[derive(Clone, Debug)]
pub struct DockParams {
    /// Ligand candidates to dock (one task each).
    pub ligands: usize,
    /// Shared receptor structure size.
    pub receptor: Bytes,
    /// Per-ligand structure size.
    pub ligand_file: Bytes,
    /// Per-task scored-transforms output.
    pub scores_file: Bytes,
    /// FFT search time per ligand.
    pub per_dock: SimTime,
    /// Final merged ranking size.
    pub ranking: Bytes,
    /// Replicate the receptor (broadcast optimization) this many times.
    pub receptor_replicas: u32,
}

impl Default for DockParams {
    fn default() -> Self {
        DockParams {
            ligands: 38,
            receptor: Bytes::mb(150),
            ligand_file: Bytes::mb(8),
            scores_file: Bytes::mb(12),
            per_dock: SimTime::from_secs_f64(45.0),
            ranking: Bytes::mb(20),
            receptor_replicas: 1,
        }
    }
}

/// Build the modFTDock workload: `ligands` docking tasks (stage 0) + one
/// merge task (stage 1). `wass` adds the pattern hints: receptor
/// replication (broadcast) and score collocation (reduce).
pub fn modftdock(p: &DockParams, wass: bool) -> Workload {
    assert!(p.ligands > 0);
    let mut w = Workload::new(format!("modftdock-{}-{}", p.ligands, if wass { "wass" } else { "dss" }));
    // The receptor is read by everyone: keep it striped even under a
    // local-placement system policy (Fig 6's insight), optionally with
    // replicas.
    let mut receptor = FileSpec::new("receptor.pdb", p.receptor).prestaged();
    if wass {
        receptor = receptor.hint(FileHint::Striped);
        if p.receptor_replicas > 1 {
            receptor = receptor.replicas(p.receptor_replicas);
        }
    }
    let receptor = w.add_file(receptor);

    let merge_node = 0usize;
    let score_hint = if wass { FileHint::OnNode(merge_node) } else { FileHint::Default };
    let mut scores = Vec::with_capacity(p.ligands);
    for i in 0..p.ligands {
        let lig_hint = if wass { FileHint::Striped } else { FileHint::Default };
        let lig =
            w.add_file(FileSpec::new(format!("ligand.{i}.pdb"), p.ligand_file).hint(lig_hint).prestaged());
        let out = w.add_file(FileSpec::new(format!("scores.{i}"), p.scores_file).hint(score_hint));
        w.add_task(
            TaskSpec::new(format!("ftdock.{i}"), 0)
                .reads(receptor)
                .reads(lig)
                .writes(out)
                .compute(p.per_dock),
        );
        scores.push(out);
    }
    let rank_hint = if wass { FileHint::Local } else { FileHint::Default };
    let ranking = w.add_file(FileSpec::new("ranking.out", p.ranking).hint(rank_hint));
    let mut merge = TaskSpec::new("rpscore-merge", 1).writes(ranking);
    for s in scores {
        merge = merge.reads(s);
    }
    w.add_task(merge);
    debug_assert!(w.validate().is_ok());
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{simulate, Config, Platform};

    #[test]
    fn structure() {
        let w = modftdock(&DockParams::default(), false);
        assert_eq!(w.tasks.len(), 39);
        assert_eq!(w.n_stages(), 2);
        assert!(w.validate().is_ok());
        // Every docking task reads the shared receptor.
        let shared_readers = w.tasks.iter().filter(|t| t.reads.contains(&0)).count();
        assert_eq!(shared_readers, 38);
    }

    #[test]
    fn wass_hints_applied() {
        let p = DockParams { receptor_replicas: 3, ..Default::default() };
        let w = modftdock(&p, true);
        assert_eq!(w.files[0].replication, Some(3));
        let s0 = w.files.iter().find(|f| f.name == "scores.0").unwrap();
        assert_eq!(s0.hint, FileHint::OnNode(0));
    }

    #[test]
    fn wass_beats_dss_like_other_patterns() {
        // 38 tasks over 19 nodes: two waves of docking, then a gather.
        let plat = Platform::paper_testbed();
        let dss = simulate(&modftdock(&DockParams::default(), false), &Config::dss(19), &plat);
        let wass = simulate(&modftdock(&DockParams::default(), true), &Config::wass(19), &plat);
        println!(
            "modftdock: DSS={:.1}s WASS={:.1}s",
            dss.turnaround.as_secs_f64(),
            wass.turnaround.as_secs_f64()
        );
        assert!(wass.turnaround <= dss.turnaround, "pattern hints should not hurt");
        // Compute dominates (45 s × 2 waves ≥ 90 s floor).
        assert!(dss.turnaround.as_secs_f64() > 90.0);
    }
}
