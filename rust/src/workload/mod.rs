//! Workload descriptions (paper §2.6).
//!
//! A workload is a set of files and a set of tasks whose file
//! reads/writes induce a dependency DAG. The paper's simulator consumes
//! "per client I/O operations trace … and a files' dependency graph";
//! [`spec`] is that structure, [`trace`] is the on-disk text format,
//! [`patterns`] generates the synthetic pipeline / reduce / broadcast
//! benchmarks, and [`blast`]/[`montage`] generate the real-application
//! workloads used in the paper's evaluation.

pub mod spec;
pub mod patterns;
pub mod blast;
pub mod montage;
pub mod modftdock;
pub mod trace;

pub use spec::{FileHint, FileId, FileSpec, TaskId, TaskSpec, Workload};
