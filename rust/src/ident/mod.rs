//! System identification (paper §2.5): seed the model by measuring a real
//! deployment — "simple, lightweight, effective, and does not require
//! system changes to collect monitoring information".
//!
//! The procedure, exactly as the paper describes it:
//! 1. a network throughput probe (iperf-style; here an echo stream through
//!    the store's socket layer) gives `μ_net` for remote and loopback;
//! 2. 0-size writes/reads "force a request to go through the manager, but
//!    … not touch the storage module"; since T_cli and T_man cannot be
//!    separated without probes, the paper sets `T_cli := 0` and charges
//!    the whole 0-size cost to the manager — we do the same;
//! 3. reads/writes of chunk-sized files give `T_tot`; then
//!    `T_sm = T_tot − T_net − T_man`, normalized per byte:
//!    `μ_sm = T_sm / chunkSize`.
//!
//! Sample counts are chosen by Jain's 95%-CI ± 5% procedure
//! ([`crate::util::stats::Campaign`]), like the paper's.
//!
//! Identification runs against the in-tree TCP store on loopback; on a
//! real multi-host deployment the identical procedure would run between
//! hosts. The derived [`Platform`] describes *this machine*; the paper-
//! testbed presets in [`crate::model::platform`] are the same quantities
//! scaled to the paper's 1 Gbps-era hardware (see EXPERIMENTS.md
//! §Identification).

use crate::model::platform::{DiskKind, Platform};
use crate::store::{Cluster, StorePlacement};
use crate::util::stats::{Campaign, Summary};
use crate::util::units::{Bytes, SimTime};
use anyhow::Result;
use std::time::Instant;

/// Raw measurements from one identification run.
#[derive(Clone, Debug)]
pub struct Identification {
    /// Loopback throughput (bytes/s) from the echo probe.
    pub net_local_bps: f64,
    /// Manager service time per op (from 0-size ops; T_cli := 0).
    pub manager_op: SimTime,
    /// Storage service time per byte, write path (ns/B).
    pub storage_ns_per_byte_write: f64,
    /// Storage service time per byte, read path (ns/B).
    pub storage_ns_per_byte_read: f64,
    /// Chunk size used for normalization.
    pub chunk_size: Bytes,
    /// Sample counts actually used (per Jain's procedure).
    pub samples: IdentSamples,
}

#[derive(Clone, Debug, Default)]
pub struct IdentSamples {
    pub net: u64,
    pub zero: u64,
    pub write: u64,
    pub read: u64,
}

/// Identification configuration.
#[derive(Clone, Debug)]
pub struct IdentConfig {
    /// File size for the read/write timing runs.
    pub file_size: Bytes,
    pub chunk_size: Bytes,
    /// Echo-probe payload.
    pub probe_size: Bytes,
    pub campaign: CampaignCfg,
}

#[derive(Clone, Debug)]
pub struct CampaignCfg {
    pub rel_accuracy: f64,
    pub min_samples: u64,
    pub max_samples: u64,
}

impl Default for IdentConfig {
    fn default() -> Self {
        IdentConfig {
            file_size: Bytes::mb(8),
            chunk_size: Bytes::mb(1),
            probe_size: Bytes::mb(8),
            campaign: CampaignCfg { rel_accuracy: 0.05, min_samples: 5, max_samples: 60 },
        }
    }
}

impl IdentConfig {
    fn campaign(&self) -> Campaign {
        Campaign {
            rel_accuracy: self.campaign.rel_accuracy,
            min_samples: self.campaign.min_samples,
            max_samples: self.campaign.max_samples,
        }
    }
}

/// Run the full §2.5 procedure against a freshly spawned 1-manager,
/// 1-storage-node, 1-client deployment ("deploys one client, one storage
/// node and the manager"; on loopback here).
pub fn identify(cfg: &IdentConfig) -> Result<Identification> {
    let cluster = Cluster::start(1)?;
    let mut client = cluster
        .client()?
        .with_chunk_size(cfg.chunk_size.as_u64())
        .with_placement(StorePlacement::OnNode { node: 0 });

    let mut samples = IdentSamples::default();

    // 1. Network throughput probe (echo: counts both directions).
    let payload = vec![0xA5u8; cfg.probe_size.as_u64() as usize];
    let net = cfg.campaign().run(|_| {
        let t0 = Instant::now();
        client.ping_node(0, &payload).expect("ping");
        // Echo moves the payload twice.
        2.0 * payload.len() as f64 / t0.elapsed().as_secs_f64()
    });
    samples.net = net.n();
    let net_local_bps = net.mean();

    // 2. 0-size ops → manager time (T_cli := 0 per the paper).
    let zero = cfg.campaign().run(|i| {
        let t0 = Instant::now();
        client.zero_size_op(&format!("__ident_zero.{i}")).expect("zero op");
        // One zero-op = write (alloc+put+commit) + read (lookup+get):
        // 3 manager round trips + 2 storage round trips of zero bytes.
        // Charge it all to the manager over 5 requests, as the paper
        // charges all 0-size cost to the manager.
        t0.elapsed().as_secs_f64() / 5.0
    });
    samples.zero = zero.n();
    let manager_op = SimTime::from_secs_f64(zero.mean());

    // 3. Chunked writes and reads → storage service time per byte.
    let fsize = cfg.file_size.as_u64() as usize;
    let data: Vec<u8> = (0..fsize).map(|i| (i * 31 % 251) as u8).collect();
    let n_chunks = cfg.file_size.chunks(cfg.chunk_size) as f64;

    let mut widx = 0u64;
    let write = cfg.campaign().run(|_| {
        widx += 1;
        let t0 = Instant::now();
        client.write(&format!("__ident_w.{widx}"), &data).expect("write");
        t0.elapsed().as_secs_f64()
    });
    samples.write = write.n();

    let mut ridx = 0u64;
    let read = cfg.campaign().run(|_| {
        ridx += 1;
        let name = format!("__ident_r.{ridx}");
        client.write(&name, &data).expect("write for read");
        let t0 = Instant::now();
        let back = client.read(&name).expect("read");
        assert_eq!(back.len(), fsize);
        t0.elapsed().as_secs_f64()
    });
    samples.read = read.n();

    // T_sm = T_tot − T_net − T_man, normalized per byte.
    let t_net = data.len() as f64 / net_local_bps;
    let per_byte = |tot: &Summary, mgr_ops: f64| -> f64 {
        let t_man = mgr_ops * manager_op.as_secs_f64();
        let t_sm = (tot.mean() - t_net - t_man).max(0.0);
        t_sm / data.len() as f64 * 1e9
    };
    // Write path: alloc + commit (2 manager ops) + n_chunks puts.
    let storage_ns_per_byte_write = per_byte(&write, 2.0);
    // Read path: lookup (1 manager op) + n_chunks gets.
    let _ = n_chunks;
    let storage_ns_per_byte_read = per_byte(&read, 1.0);

    Ok(Identification {
        net_local_bps,
        manager_op,
        storage_ns_per_byte_write,
        storage_ns_per_byte_read,
        chunk_size: cfg.chunk_size,
        samples,
    })
}

impl Identification {
    /// Build a [`Platform`] for *this machine* from the measurements.
    /// Loopback is used for both remote and local paths (single-host
    /// deployment); a multi-host run would measure them separately.
    pub fn to_platform(&self) -> Platform {
        Platform {
            label: "identified-localhost".into(),
            net_remote_bps: self.net_local_bps,
            net_local_bps: self.net_local_bps,
            net_latency: SimTime::from_us(30),
            net_latency_local: SimTime::from_us(30),
            frame_size: Bytes::kb(64),
            storage_ns_per_byte_write: self.storage_ns_per_byte_write,
            storage_ns_per_byte_read: self.storage_ns_per_byte_read,
            storage_op: SimTime::from_us(20),
            manager_op: self.manager_op,
            client_op: SimTime::ZERO, // T_cli := 0, as the paper chooses
            hdd_seek: SimTime::ZERO,
            host_speed: Vec::new(),
            node_capacity: Bytes::ZERO,
            disk: DiskKind::Ram,
        }
    }

    /// Human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "net(loopback) = {:.1} MB/s\nμ_man = {} / op\nμ_sm(write) = {:.3} ns/B\nμ_sm(read) = {:.3} ns/B\nsamples: net={} zero={} write={} read={}",
            self.net_local_bps / 1e6,
            self.manager_op,
            self.storage_ns_per_byte_write,
            self.storage_ns_per_byte_read,
            self.samples.net,
            self.samples.zero,
            self.samples.write,
            self.samples.read,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Full procedure at reduced sample counts (wallclock-bounded test).
    #[test]
    fn identification_produces_sane_platform() {
        let cfg = IdentConfig {
            file_size: Bytes::mb(2),
            chunk_size: Bytes::kb(256),
            probe_size: Bytes::mb(2),
            campaign: CampaignCfg { rel_accuracy: 0.2, min_samples: 3, max_samples: 8 },
        };
        let id = identify(&cfg).expect("identification");
        println!("{}", id.summary());
        // Loopback throughput on any modern machine: 100 MB/s .. 100 GB/s.
        assert!(id.net_local_bps > 1e8, "loopback {:.1} MB/s too slow", id.net_local_bps / 1e6);
        assert!(id.net_local_bps < 1e11);
        // Manager ops are sub-millisecond on loopback but non-zero.
        assert!(id.manager_op.as_ns() > 1_000, "manager op {} suspiciously fast", id.manager_op);
        assert!(id.manager_op.as_ns() < 50_000_000, "manager op {} too slow", id.manager_op);
        // Storage per-byte times are non-negative and below 1 µs/B.
        assert!(id.storage_ns_per_byte_write >= 0.0);
        assert!(id.storage_ns_per_byte_write < 1000.0);
        let p = id.to_platform();
        assert!(p.validate().is_ok());
        // Jain's procedure respected the floor.
        assert!(id.samples.zero >= 3 && id.samples.write >= 3);
    }
}
