//! Canonical fingerprints for `(Workload, Config, Platform, Fidelity)`
//! evaluation points.
//!
//! The fingerprint is the cache key of the whole serving layer, so it has
//! to be (a) **stable across runs and processes** — it keys the on-disk
//! store — and (b) **canonical over workload structure**: two workload
//! descriptions that differ only in the order their files and tasks were
//! appended (a trace emitted by a different front-end, say) are the same
//! evaluation point. Files and tasks are therefore hashed individually —
//! task read/write lists reference per-file hashes, never positional
//! `FileId`s — and combined with an order-invariant wrapping sum (each
//! item hash diffused through [`mix64`] first so structured values do not
//! cancel). Everything else — every `Config` knob, every `Platform`
//! service time, every `Fidelity` switch — feeds the hash directly: any
//! single knob change must produce a distinct fingerprint
//! (property-tested in `tests/proptests.rs`).
//!
//! 128 bits (two independently-seeded FNV-1a streams over the same byte
//! sequence) keeps the accidental-collision probability negligible at
//! millions of stored predictions.

use crate::model::{Config, DiskKind, Fidelity, Placement, Platform, Topology};
use crate::util::hash::{mix64, Fnv64};
use crate::workload::{FileSpec, TaskSpec, Workload};
use std::fmt;

/// 128-bit canonical fingerprint of one evaluation point.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint {
    pub hi: u64,
    pub lo: u64,
}

impl Fingerprint {
    /// Shard index for an `n`-way sharded structure.
    pub fn shard(&self, n: usize) -> usize {
        (mix64(self.hi) % n.max(1) as u64) as usize
    }

    /// Parse the 32-hex-digit form produced by `Display`.
    pub fn parse(s: &str) -> Option<Fingerprint> {
        if s.len() != 32 || !s.is_ascii() {
            return None;
        }
        let hi = u64::from_str_radix(&s[..16], 16).ok()?;
        let lo = u64::from_str_radix(&s[16..], 16).ok()?;
        Some(Fingerprint { hi, lo })
    }
}

impl fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}{:016x}", self.hi, self.lo)
    }
}

impl fmt::Debug for Fingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Fingerprint({self})")
    }
}

/// Two independently-seeded FNV-1a streams fed the same byte sequence.
struct H2 {
    a: Fnv64,
    b: Fnv64,
}

impl H2 {
    fn new() -> H2 {
        H2 { a: Fnv64::with_seed(0x5EED_0001), b: Fnv64::with_seed(0x5EED_0002) }
    }

    fn u32(&mut self, x: u32) {
        self.a.write_u32(x);
        self.b.write_u32(x);
    }

    fn u64(&mut self, x: u64) {
        self.a.write_u64(x);
        self.b.write_u64(x);
    }

    fn usize(&mut self, x: usize) {
        self.u64(x as u64);
    }

    fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }

    fn bool(&mut self, x: bool) {
        self.a.write_bool(x);
        self.b.write_bool(x);
    }

    fn str(&mut self, s: &str) {
        self.a.write_str(s);
        self.b.write_str(s);
    }

    fn finish(&self) -> (u64, u64) {
        (self.a.finish(), self.b.finish())
    }

    fn fp(&self) -> Fingerprint {
        Fingerprint { hi: self.a.finish(), lo: self.b.finish() }
    }
}

/// Position-free token of one file: the identity a task reference hashes.
fn file_token(f: &FileSpec) -> (u64, u64) {
    let mut h = H2::new();
    h.str(&f.name);
    h.u64(f.size.as_u64());
    match f.hint {
        crate::workload::FileHint::Default => h.u32(0),
        crate::workload::FileHint::Local => h.u32(1),
        crate::workload::FileHint::OnNode(n) => {
            h.u32(2);
            h.usize(n);
        }
        crate::workload::FileHint::Striped => h.u32(3),
    }
    match f.replication {
        None => h.u32(0),
        Some(r) => {
            h.u32(1);
            h.u32(r);
        }
    }
    h.bool(f.prestaged);
    h.finish()
}

/// Position-free token of one task: file references are the referenced
/// files' tokens (order within a task's read/write lists is semantic and
/// kept), so permuting the workload's file array leaves this unchanged.
fn task_token(t: &TaskSpec, file_tok: &[(u64, u64)]) -> (u64, u64) {
    let mut h = H2::new();
    h.str(&t.name);
    h.u32(t.stage);
    h.u64(t.compute.as_ns());
    h.u64(t.release.as_ns());
    match t.pin_client {
        None => h.u32(0),
        Some(c) => {
            h.u32(1);
            h.usize(c);
        }
    }
    h.u64(t.reads.len() as u64);
    for &f in &t.reads {
        let (a, b) = file_tok[f];
        h.u64(a);
        h.u64(b);
    }
    h.u64(t.writes.len() as u64);
    for &f in &t.writes {
        let (a, b) = file_tok[f];
        h.u64(a);
        h.u64(b);
    }
    h.finish()
}

fn hash_config(h: &mut H2, cfg: &Config) {
    // The label is part of the key: it flows verbatim into
    // `SimReport::config_label`, and a cache hit must reproduce the
    // direct prediction byte-for-byte.
    h.str(&cfg.label);
    h.usize(cfg.n_app);
    h.usize(cfg.n_storage);
    h.bool(cfg.collocated);
    h.usize(cfg.stripe_width);
    h.u32(cfg.replication);
    h.u64(cfg.chunk_size.as_u64());
    h.u32(match cfg.placement {
        Placement::RoundRobin => 0,
        Placement::Local => 1,
    });
    h.bool(cfg.location_aware);
    h.usize(cfg.io_window);
    // Fault plans are hashed only when non-empty, so a config with the
    // default (empty) plan keeps the fingerprint it had before fault
    // support existed — warm-start stores stay valid. The seed is hashed
    // only alongside actual fault events: it feeds no decision on an
    // empty plan, and two plans differing in any event or the seed are
    // distinct evaluation points.
    if !cfg.faults.is_empty() {
        h.str("faults.v1");
        h.u64(cfg.faults.seed);
        h.usize(cfg.faults.crashes.len());
        for c in &cfg.faults.crashes {
            h.usize(c.storage);
            h.u64(c.at.as_ns());
        }
        h.usize(cfg.faults.stragglers.len());
        for s in &cfg.faults.stragglers {
            h.usize(s.host);
            h.u64(s.at.as_ns());
            h.f64(s.slowdown);
        }
        h.usize(cfg.faults.links.len());
        for l in &cfg.faults.links {
            h.usize(l.src);
            h.usize(l.dst);
            h.u64(l.from.as_ns());
            h.u64(l.until.as_ns());
            h.f64(l.prob);
        }
    }
}

fn hash_platform(h: &mut H2, p: &Platform) {
    h.str(&p.label);
    h.f64(p.net_remote_bps);
    h.f64(p.net_local_bps);
    h.u64(p.net_latency.as_ns());
    h.u64(p.net_latency_local.as_ns());
    h.u64(p.frame_size.as_u64());
    h.f64(p.storage_ns_per_byte_write);
    h.f64(p.storage_ns_per_byte_read);
    h.u64(p.storage_op.as_ns());
    h.u64(p.manager_op.as_ns());
    h.u64(p.client_op.as_ns());
    h.u64(p.hdd_seek.as_ns());
    h.u64(p.host_speed.len() as u64);
    for &s in &p.host_speed {
        h.f64(s);
    }
    h.u64(p.node_capacity.as_u64());
    h.u32(match p.disk {
        DiskKind::Ram => 0,
        DiskKind::Hdd => 1,
        DiskKind::Ssd => 2,
    });
    // The topology is hashed only when it is not the star, so a star
    // platform keeps the fingerprint it had before the routed fabric
    // existed — warm-start stores stay valid (same contract as the
    // `faults.v1` block in `hash_config`). Any rack layout is a distinct
    // evaluation point: memoized answers must never leak across
    // topologies.
    if let Topology::Rack { rack_size, oversub } = p.topology {
        h.str("topology.v1");
        h.usize(rack_size);
        h.f64(oversub);
    }
}

fn hash_fidelity(h: &mut H2, f: &Fidelity) {
    h.bool(f.frame_aggregation);
    h.bool(f.control_rounds);
    h.u32(f.alloc_batch);
    h.bool(f.connections);
    h.u64(f.conn_timeout.as_ns());
    h.usize(f.syn_drop_qlen);
    h.usize(f.syn_drop_full);
    h.u64(f.stagger_mean.as_ns());
    h.f64(f.jitter_sigma);
    h.f64(f.manager_contention);
    h.f64(f.hetero_sigma);
    h.f64(f.mux_eta);
    h.u64(f.per_target_setup.as_ns());
    h.f64(f.train_qlen_scale);
    h.bool(f.random_placement);
    h.u64(f.seed);
}

/// The canonical fingerprint of one evaluation point.
pub fn fingerprint(wl: &Workload, cfg: &Config, plat: &Platform, fid: &Fidelity) -> Fingerprint {
    let file_tok: Vec<(u64, u64)> = wl.files.iter().map(file_token).collect();
    let (mut fa, mut fb) = (0u64, 0u64);
    for &(a, b) in &file_tok {
        fa = fa.wrapping_add(mix64(a));
        fb = fb.wrapping_add(mix64(b));
    }
    let (mut ta, mut tb) = (0u64, 0u64);
    for t in &wl.tasks {
        let (a, b) = task_token(t, &file_tok);
        ta = ta.wrapping_add(mix64(a));
        tb = tb.wrapping_add(mix64(b));
    }
    let mut h = H2::new();
    h.str("wfpred.fingerprint.v1");
    h.str(&wl.name);
    h.u64(wl.files.len() as u64);
    h.u64(fa);
    h.u64(fb);
    h.u64(wl.tasks.len() as u64);
    h.u64(ta);
    h.u64(tb);
    hash_config(&mut h, cfg);
    hash_platform(&mut h, plat);
    hash_fidelity(&mut h, fid);
    h.fp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::Bytes;
    use crate::workload::{FileSpec, TaskSpec};

    fn wl() -> Workload {
        let mut w = Workload::new("fp-test");
        let a = w.add_file(FileSpec::new("in", Bytes::mb(4)).prestaged());
        let b = w.add_file(FileSpec::new("mid", Bytes::mb(2)));
        let c = w.add_file(FileSpec::new("out", Bytes::mb(1)));
        w.add_task(TaskSpec::new("t1", 0).reads(a).writes(b));
        w.add_task(TaskSpec::new("t2", 1).reads(b).writes(c));
        w
    }

    fn fp_of(w: &Workload) -> Fingerprint {
        fingerprint(w, &Config::dss(4), &Platform::paper_testbed(), &Fidelity::coarse())
    }

    #[test]
    fn stable_across_calls_and_clones() {
        let w = wl();
        assert_eq!(fp_of(&w), fp_of(&w.clone()));
    }

    #[test]
    fn invariant_under_file_and_task_reorder() {
        let w = wl();
        // Reverse the file array and remap references; reverse tasks.
        let mut r = Workload::new("fp-test");
        let n = w.files.len();
        for f in w.files.iter().rev() {
            r.add_file(f.clone());
        }
        for t in w.tasks.iter().rev() {
            let mut t2 = t.clone();
            t2.reads = t.reads.iter().map(|&f| n - 1 - f).collect();
            t2.writes = t.writes.iter().map(|&f| n - 1 - f).collect();
            r.add_task(t2);
        }
        assert_eq!(fp_of(&w), fp_of(&r));
    }

    #[test]
    fn sensitive_to_workload_content() {
        let w = wl();
        let base = fp_of(&w);
        let mut bigger = w.clone();
        bigger.files[1].size = Bytes::mb(3);
        assert_ne!(base, fp_of(&bigger));
        let mut renamed = w.clone();
        renamed.tasks[0].name = "t1x".into();
        assert_ne!(base, fp_of(&renamed));
        let mut other_name = w.clone();
        other_name.name = "fp-test-2".into();
        assert_ne!(base, fp_of(&other_name));
    }

    #[test]
    fn sensitive_to_config_platform_and_fidelity() {
        let w = wl();
        let base = fp_of(&w);
        let cfg = Config::dss(4).with_chunk(Bytes::kb(256));
        assert_ne!(base, fingerprint(&w, &cfg, &Platform::paper_testbed(), &Fidelity::coarse()));
        assert_ne!(
            base,
            fingerprint(&w, &Config::dss(4), &Platform::paper_testbed_10g(), &Fidelity::coarse())
        );
        assert_ne!(
            base,
            fingerprint(
                &w,
                &Config::dss(4),
                &Platform::paper_testbed(),
                &Fidelity::coarse_per_frame()
            )
        );
    }

    #[test]
    fn fault_plans_are_distinct_points_but_empty_plans_are_free() {
        use crate::model::FaultPlan;
        let w = wl();
        let plat = Platform::paper_testbed();
        let fid = Fidelity::coarse();
        let base = fp_of(&w);
        let seeded_empty =
            Config::dss(4).with_fault_plan(FaultPlan { seed: 77, ..FaultPlan::default() });
        assert_eq!(
            base,
            fingerprint(&w, &seeded_empty, &plat, &fid),
            "an empty plan (whatever its seed) keeps the pre-fault fingerprint"
        );
        let crash = Config::dss(4).with_fault_plan(FaultPlan::parse("crash=1@2").unwrap());
        let fp_crash = fingerprint(&w, &crash, &plat, &fid);
        assert_ne!(base, fp_crash);
        let later = Config::dss(4).with_fault_plan(FaultPlan::parse("crash=1@3").unwrap());
        assert_ne!(fp_crash, fingerprint(&w, &later, &plat, &fid));
        let reseeded =
            Config::dss(4).with_fault_plan(FaultPlan::parse("seed=9;crash=1@2").unwrap());
        assert_ne!(fp_crash, fingerprint(&w, &reseeded, &plat, &fid));
    }

    #[test]
    fn rack_topologies_are_distinct_points_but_star_is_free() {
        let w = wl();
        let fid = Fidelity::coarse();
        let cfg = Config::dss(4);
        let base = fp_of(&w);
        // Star is the pre-fabric default: same fingerprint as before the
        // topology knob existed.
        let mut star = Platform::paper_testbed();
        star.topology = Topology::Star;
        assert_eq!(base, fingerprint(&w, &cfg, &star, &fid));
        let mut rack = Platform::paper_testbed();
        rack.topology = Topology::Rack { rack_size: 8, oversub: 4.0 };
        let fp_rack = fingerprint(&w, &cfg, &rack, &fid);
        assert_ne!(base, fp_rack, "a rack layout is a distinct evaluation point");
        let mut wider = Platform::paper_testbed();
        wider.topology = Topology::Rack { rack_size: 16, oversub: 4.0 };
        assert_ne!(fp_rack, fingerprint(&w, &cfg, &wider, &fid));
        let mut leaner = Platform::paper_testbed();
        leaner.topology = Topology::Rack { rack_size: 8, oversub: 2.0 };
        assert_ne!(fp_rack, fingerprint(&w, &cfg, &leaner, &fid));
    }

    #[test]
    fn display_parse_roundtrip() {
        let fp = fp_of(&wl());
        let s = fp.to_string();
        assert_eq!(s.len(), 32);
        assert_eq!(Fingerprint::parse(&s), Some(fp));
        assert_eq!(Fingerprint::parse("zz"), None);
        assert_eq!(Fingerprint::parse(&"g".repeat(32)), None);
    }
}
