//! The prediction-serving subsystem: all evaluation traffic flows
//! through a [`Service`].
//!
//! The paper's value proposition is answering many what-if configuration
//! queries orders of magnitude cheaper than running the application
//! (§3.3). A predictor that re-simulates every `predict` call from
//! scratch leaves most of that value on the table the moment two callers
//! — a grid sweep and an annealing chain, two annealing chains, two
//! `batch` invocations — ask about the same point. This module turns the
//! predictor into a serving system:
//!
//! * **Fingerprints** ([`fingerprint`](mod@fingerprint)) — a canonical, process-stable
//!   128-bit key over `(Workload, Config, Platform, Fidelity)`,
//!   order-invariant over workload file/task layout.
//! * **Memoization** ([`cache`]) — a sharded in-memory LRU of full
//!   [`Prediction`]s; a warm hit reproduces the direct
//!   `Predictor::predict` result byte-for-byte (minus the wallclock it
//!   did not spend).
//! * **Warm starts** ([`store`]) — an optional append-only JSONL store
//!   of prediction summaries, replayed on open, so batch campaigns
//!   warm-start across processes.
//! * **Single-flight deduplication** — concurrent requests for one
//!   fingerprint block on the one in-flight simulation (a condvar per
//!   entry) instead of duplicating work; batches fan out over
//!   [`coordinator::par_map_indexed`].
//! * **Surrogate fast-path** ([`surrogate`]) — multilinear interpolation
//!   over already-evaluated grid neighbors, gated by a per-answer error
//!   estimate and always attributed ([`Answer::Surrogate`] vs
//!   [`Answer::Exact`]); with the gate off it is never consulted.
//! * **Crash safety** — a request thread that panics mid-simulation must
//!   not take the process-wide service down with it: the single-flight
//!   leader finishes its flight from a drop guard (waiters wake and
//!   re-execute), and every shared-state lock shrugs off poisoning
//!   instead of propagating the panic to unrelated requests. Exact
//!   answers carry the run's degraded-mode [`FailureStats`] so callers
//!   can tell a clean prediction from one that failed over or lost work.
//!
//! The `Searcher` and `Annealer` evaluate through a service handle
//! (creating a private cold one when the caller does not supply a handle,
//! so results stay byte-identical to direct prediction), and the
//! `wfpred batch` / `wfpred serve` commands expose the same layer as a
//! newline-delimited query protocol.

pub mod cache;
pub mod fingerprint;
pub mod store;
pub mod surrogate;

pub use cache::CacheCounters;
pub use fingerprint::{fingerprint, Fingerprint};
pub use store::{DiskStore, FailureStats, StoredAnswer};
pub use surrogate::{Estimate, GridCoord, SurrogateGrid};

use crate::coordinator;
use crate::model::{Config, DeltaBase, DeltaOutcome, Fidelity, SimReport, StageCheckpoint};
use crate::predict::{Prediction, Predictor};
use crate::workload::Workload;
use cache::ShardedLru;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Default in-memory cache budget (whole `Prediction`s, LRU-evicted).
pub const DEFAULT_CACHE_CAPACITY: usize = 4096;

/// Where an exact answer came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    Simulated,
    Memory,
    Disk,
}

impl Source {
    pub fn as_str(self) -> &'static str {
        match self {
            Source::Simulated => "simulated",
            Source::Memory => "memory",
            Source::Disk => "disk",
        }
    }
}

/// Which evaluation engine produced a number. Carried on every served
/// [`Answer::Exact`], persisted with [`StoredAnswer`]s, and stamped on
/// bench-cell records (`rust/METHODOLOGY.md`), so engine-vs-engine
/// comparisons are attributed rather than inferred.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineId {
    /// Bulk frame-aggregated deterministic model — the paper's predictor.
    Coarse,
    /// Per-frame deterministic reference tier.
    CoarsePerFrame,
    /// Per-frame stochastic tier (the emulated testbed).
    Detailed,
    /// Frame-aggregated stochastic tier.
    DetailedAggregated,
    /// Grid interpolation over exact samples — no simulation at all.
    Surrogate,
}

impl EngineId {
    /// Classify a fidelity: frame aggregation × stochastic noise sources
    /// span the four simulation engines.
    pub fn of_fidelity(f: &Fidelity) -> EngineId {
        match (f.frame_aggregation, f.stochastic()) {
            (true, false) => EngineId::Coarse,
            (false, false) => EngineId::CoarsePerFrame,
            (false, true) => EngineId::Detailed,
            (true, true) => EngineId::DetailedAggregated,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            EngineId::Coarse => "coarse",
            EngineId::CoarsePerFrame => "coarse_per_frame",
            EngineId::Detailed => "detailed",
            EngineId::DetailedAggregated => "detailed_aggregated",
            EngineId::Surrogate => "surrogate",
        }
    }

    /// Inverse of [`EngineId::as_str`]; `None` for unknown labels (a
    /// store written by a newer build).
    pub fn parse(s: &str) -> Option<EngineId> {
        Some(match s {
            "coarse" => EngineId::Coarse,
            "coarse_per_frame" => EngineId::CoarsePerFrame,
            "detailed" => EngineId::Detailed,
            "detailed_aggregated" => EngineId::DetailedAggregated,
            "surrogate" => EngineId::Surrogate,
            _ => return None,
        })
    }
}

/// A served answer. Exact answers are attributed to their source and the
/// engine that computed them, and carry the run's degraded-mode failure
/// accounting; surrogate answers always carry their error estimate (and
/// no failure stats — they are interpolations, not runs).
#[derive(Clone, Debug)]
pub enum Answer {
    Exact {
        fp: Fingerprint,
        turnaround_s: f64,
        cost_node_s: f64,
        source: Source,
        engine: EngineId,
        failures: FailureStats,
        /// `Some` when the simulation behind this answer was computed by a
        /// delta warm-start this process (how many stages were spliced vs
        /// replayed); `None` for cold simulations and disk-store answers.
        delta: Option<DeltaOutcome>,
    },
    Surrogate {
        fp: Fingerprint,
        turnaround_s: f64,
        cost_node_s: f64,
        est_err: f64,
    },
}

impl Answer {
    pub fn fp(&self) -> Fingerprint {
        match self {
            Answer::Exact { fp, .. } | Answer::Surrogate { fp, .. } => *fp,
        }
    }

    pub fn turnaround_s(&self) -> f64 {
        match self {
            Answer::Exact { turnaround_s, .. } | Answer::Surrogate { turnaround_s, .. } => {
                *turnaround_s
            }
        }
    }

    pub fn cost_node_s(&self) -> f64 {
        match self {
            Answer::Exact { cost_node_s, .. } | Answer::Surrogate { cost_node_s, .. } => {
                *cost_node_s
            }
        }
    }

    pub fn is_exact(&self) -> bool {
        matches!(self, Answer::Exact { .. })
    }

    /// The engine that produced this answer (surrogate answers are their
    /// own engine).
    pub fn engine(&self) -> EngineId {
        match self {
            Answer::Exact { engine, .. } => *engine,
            Answer::Surrogate { .. } => EngineId::Surrogate,
        }
    }

    /// `Some` only for surrogate answers — exact answers have no model
    /// error to estimate.
    pub fn est_err(&self) -> Option<f64> {
        match self {
            Answer::Surrogate { est_err, .. } => Some(*est_err),
            Answer::Exact { .. } => None,
        }
    }

    /// `Some` only for exact answers — a surrogate interpolation never
    /// ran the fault plan.
    pub fn failures(&self) -> Option<FailureStats> {
        match self {
            Answer::Exact { failures, .. } => Some(*failures),
            Answer::Surrogate { .. } => None,
        }
    }

    /// The delta warm-start attribution, when the simulation behind this
    /// answer was resumed from a checkpoint rather than run cold.
    pub fn delta(&self) -> Option<DeltaOutcome> {
        match self {
            Answer::Exact { delta, .. } => *delta,
            Answer::Surrogate { .. } => None,
        }
    }
}

/// One query of the batch/serve protocol. `family` namespaces the
/// surrogate grid: queries that interpolate against each other must share
/// it (same workload family and platform; the grid coordinate axes —
/// allocation, partitioning, chunk, replication — are what vary inside a
/// family).
#[derive(Clone, Debug)]
pub struct Query {
    pub workload: Workload,
    pub config: Config,
    pub family: u64,
}

#[derive(Default, Debug)]
struct Counters {
    hits: AtomicU64,
    misses: AtomicU64,
    dedup_waits: AtomicU64,
    disk_hits: AtomicU64,
    surrogate_answers: AtomicU64,
    delta_hits: AtomicU64,
    delta_stages_skipped: AtomicU64,
    delta_stages_replayed: AtomicU64,
}

/// Monotonic service counters (a snapshot; see [`Service::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// In-memory cache hits.
    pub hits: u64,
    /// Simulations actually executed.
    pub misses: u64,
    /// Requests that blocked on another caller's in-flight simulation.
    pub dedup_waits: u64,
    /// Summary answers served from the on-disk store.
    pub disk_hits: u64,
    /// Surrogate interpolations that passed their error gate.
    pub surrogate_answers: u64,
    /// Simulations served by a delta warm-start instead of a cold run
    /// (always `<= misses`: a warm-started simulation is still a
    /// simulation — bit-identical to the cold one, just cheaper).
    pub delta_hits: u64,
    /// Stages spliced from checkpoints across all delta warm-starts.
    pub delta_stages_skipped: u64,
    /// Stages actually re-simulated across all delta warm-starts.
    pub delta_stages_replayed: u64,
    /// Raw shard-level cache probes (hit/miss/evict), summed across
    /// shards. Distinct from `hits`/`misses` above: those classify served
    /// answers, these count every cache probe — including the
    /// single-flight double-check under the inflight lock — so
    /// `cache.hits >= hits`.
    pub cache: CacheCounters,
}

impl StatsSnapshot {
    /// Answers backed by a real run (any source) — the complement of
    /// [`surrogate_answers`](StatsSnapshot::surrogate_answers).
    pub fn exact_answers(&self) -> u64 {
        self.hits + self.misses + self.disk_hits + self.dedup_waits
    }
}

/// Per-fingerprint single-flight rendezvous.
#[derive(Default)]
struct FlightState {
    /// The leader is gone (normally or by panic); no further progress
    /// will happen on this flight.
    finished: bool,
    result: Option<Arc<Prediction>>,
}

struct Flight {
    state: Mutex<FlightState>,
    done: Condvar,
}

/// The prediction service.
pub struct Service {
    predictor: Predictor,
    fidelity: Fidelity,
    cache: ShardedLru,
    disk: Option<DiskStore>,
    inflight: Mutex<HashMap<Fingerprint, Arc<Flight>>>,
    grids: Mutex<HashMap<u64, SurrogateGrid>>,
    counters: Counters,
    /// Incremental re-simulation toggle (on by default; benches keep a
    /// cold-path control cell via [`Service::without_delta`]).
    delta_enabled: bool,
    /// The most recent captured base simulation. One slot, most-recent
    /// wins: search campaigns evaluate neighbors of the point they just
    /// evaluated, so the last base is the one whose prefix they share.
    /// A delta hit keeps the base; a cold run replaces it.
    delta_base: Mutex<Option<Arc<DeltaBase>>>,
    /// Delta attribution per answered fingerprint, kept service-side so
    /// `Prediction` itself stays byte-comparable with the cold path.
    delta_outcomes: Mutex<HashMap<Fingerprint, DeltaOutcome>>,
}

impl Service {
    pub fn new(predictor: Predictor) -> Service {
        Service::with_capacity(predictor, DEFAULT_CACHE_CAPACITY)
    }

    pub fn with_capacity(predictor: Predictor, capacity: usize) -> Service {
        Service {
            predictor,
            fidelity: Fidelity::coarse(),
            cache: ShardedLru::new(capacity),
            disk: None,
            inflight: Mutex::new(HashMap::new()),
            grids: Mutex::new(HashMap::new()),
            counters: Counters::default(),
            delta_enabled: true,
            delta_base: Mutex::new(None),
            delta_outcomes: Mutex::new(HashMap::new()),
        }
    }

    /// Disable the incremental re-simulation path: every miss runs the
    /// cold predictor. Answers are bit-identical either way (that is the
    /// delta invariant); this exists for the cold control cell of the
    /// `search.delta.*` benches and for A/B debugging.
    pub fn without_delta(mut self) -> Service {
        self.delta_enabled = false;
        self
    }

    /// Attach (and replay) the append-only JSONL store at `path`.
    pub fn with_disk_store(mut self, path: impl AsRef<std::path::Path>) -> Result<Service, String> {
        self.disk = Some(DiskStore::open(path)?);
        Ok(self)
    }

    pub fn predictor(&self) -> &Predictor {
        &self.predictor
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    pub fn disk_len(&self) -> usize {
        self.disk.as_ref().map(|d| d.len()).unwrap_or(0)
    }

    pub fn stats(&self) -> StatsSnapshot {
        StatsSnapshot {
            hits: self.counters.hits.load(Ordering::Relaxed),
            misses: self.counters.misses.load(Ordering::Relaxed),
            dedup_waits: self.counters.dedup_waits.load(Ordering::Relaxed),
            disk_hits: self.counters.disk_hits.load(Ordering::Relaxed),
            surrogate_answers: self.counters.surrogate_answers.load(Ordering::Relaxed),
            delta_hits: self.counters.delta_hits.load(Ordering::Relaxed),
            delta_stages_skipped: self.counters.delta_stages_skipped.load(Ordering::Relaxed),
            delta_stages_replayed: self.counters.delta_stages_replayed.load(Ordering::Relaxed),
            cache: self.cache.counters(),
        }
    }

    /// The delta warm-start attribution of `fp`, when the simulation
    /// behind it was resumed from a checkpoint this process.
    pub fn delta_outcome(&self, fp: Fingerprint) -> Option<DeltaOutcome> {
        self.delta_outcomes.lock().unwrap_or_else(|e| e.into_inner()).get(&fp).copied()
    }

    /// The canonical fingerprint of `(workload, config)` under this
    /// service's platform and fidelity.
    pub fn fingerprint(&self, workload: &Workload, config: &Config) -> Fingerprint {
        fingerprint(workload, config, &self.predictor.platform, &self.fidelity)
    }

    /// Exact evaluation: memoized and deduplicated; on a miss the result
    /// is exactly `Predictor::predict`'s.
    pub fn evaluate(&self, workload: &Workload, config: &Config) -> Arc<Prediction> {
        let fp = self.fingerprint(workload, config);
        self.evaluate_fp(fp, workload, config)
    }

    fn evaluate_fp(&self, fp: Fingerprint, workload: &Workload, config: &Config) -> Arc<Prediction> {
        if let Some(p) = self.cache.get(&fp) {
            self.counters.hits.fetch_add(1, Ordering::Relaxed);
            return p;
        }
        let (flight, leader) = {
            // Every service lock tolerates poisoning: a panic on one
            // request thread (the flight drop-guard below already keeps
            // the map consistent) must not wedge the rest of `serve`.
            let mut inflight = self.inflight.lock().unwrap_or_else(|e| e.into_inner());
            // Re-check under the map lock: a leader that finished after
            // our cache probe has already moved its result to the cache
            // and removed its flight entry.
            if let Some(p) = self.cache.get(&fp) {
                self.counters.hits.fetch_add(1, Ordering::Relaxed);
                return p;
            }
            match inflight.get(&fp) {
                Some(f) => (f.clone(), false),
                None => {
                    let f = Arc::new(Flight {
                        state: Mutex::new(FlightState::default()),
                        done: Condvar::new(),
                    });
                    inflight.insert(fp, f.clone());
                    (f, true)
                }
            }
        };
        if leader {
            // Finish the flight even if the simulation panics: the drop
            // guard removes the inflight entry and wakes every follower,
            // so they retry (and surface the failure on their own
            // threads) instead of deadlocking on a condvar forever.
            struct FinishFlight<'a> {
                service: &'a Service,
                fp: Fingerprint,
                flight: &'a Arc<Flight>,
            }
            impl Drop for FinishFlight<'_> {
                fn drop(&mut self) {
                    // Runs on the panic path too, so both locks must
                    // accept an already-poisoned mutex.
                    self.service
                        .inflight
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .remove(&self.fp);
                    self.flight.state.lock().unwrap_or_else(|e| e.into_inner()).finished = true;
                    self.flight.done.notify_all();
                }
            }
            let finish = FinishFlight { service: self, fp, flight: &flight };
            // Simulate outside every lock; followers wait on the flight.
            let (p, checkpoints) = self.predict_point(fp, workload, config);
            let pred = Arc::new(p);
            self.counters.misses.fetch_add(1, Ordering::Relaxed);
            self.cache.insert(fp, pred.clone());
            if let Some(disk) = &self.disk {
                disk.put(
                    fp,
                    &StoredAnswer::of(&pred, EngineId::of_fidelity(&self.fidelity))
                        .with_checkpoints(checkpoints),
                );
            }
            finish.flight.state.lock().unwrap_or_else(|e| e.into_inner()).result =
                Some(pred.clone());
            drop(finish);
            pred
        } else {
            self.counters.dedup_waits.fetch_add(1, Ordering::Relaxed);
            let mut state = flight.state.lock().unwrap_or_else(|e| e.into_inner());
            while !state.finished {
                // A leader that panicked poisons this mutex; the waiter
                // still wants the (consistent) state to see `finished`
                // and retry, not to propagate the foreign panic.
                state = match flight.done.wait(state) {
                    Ok(g) => g,
                    Err(e) => e.into_inner(),
                };
            }
            match state.result.clone() {
                Some(p) => p,
                None => {
                    // The leader died without producing a result; its
                    // inflight entry is gone, so retry from the top.
                    drop(state);
                    self.evaluate_fp(fp, workload, config)
                }
            }
        }
    }

    /// One simulation, through the incremental re-simulation path when
    /// enabled: resume from the most recent captured base when the
    /// stage-fingerprint prefix matches (replaying only the changed
    /// suffix), otherwise run cold and capture a fresh base. The answer
    /// is bit-identical either way — `prop_delta_resim_matches_cold` pins
    /// this — so both arms count as `misses` ("simulations actually
    /// executed") and campaign accounting is unchanged. Returns the
    /// checkpoint summaries worth persisting alongside the answer.
    fn predict_point(
        &self,
        fp: Fingerprint,
        workload: &Workload,
        config: &Config,
    ) -> (Prediction, Vec<StageCheckpoint>) {
        if !self.delta_enabled {
            return (self.predictor.predict(workload, config), Vec::new());
        }
        let t0 = Instant::now();
        let base = self.delta_base.lock().unwrap_or_else(|e| e.into_inner()).clone();
        if let Some(base) = base {
            if let Some(r) = base.resume(workload, config) {
                self.counters.delta_hits.fetch_add(1, Ordering::Relaxed);
                self.counters
                    .delta_stages_skipped
                    .fetch_add(r.outcome.stages_skipped as u64, Ordering::Relaxed);
                self.counters
                    .delta_stages_replayed
                    .fetch_add(r.outcome.stages_replayed as u64, Ordering::Relaxed);
                self.delta_outcomes
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .insert(fp, r.outcome);
                let p = prediction_of(r.report, config, t0.elapsed().as_secs_f64());
                return (p, r.checkpoints);
            }
        }
        let (report, new_base) =
            DeltaBase::capture(workload, config, &self.predictor.platform, self.fidelity.clone());
        let checkpoints = new_base.checkpoints();
        *self.delta_base.lock().unwrap_or_else(|e| e.into_inner()) = Some(Arc::new(new_base));
        (prediction_of(report, config, t0.elapsed().as_secs_f64()), checkpoints)
    }

    /// Memory- or disk-hit answer for a known point, if any (one probe
    /// of each layer, counted).
    fn lookup(&self, fp: Fingerprint) -> Option<Answer> {
        if let Some(p) = self.cache.get(&fp) {
            self.counters.hits.fetch_add(1, Ordering::Relaxed);
            return Some(Answer::Exact {
                fp,
                turnaround_s: p.turnaround.as_secs_f64(),
                cost_node_s: p.cost_node_secs,
                source: Source::Memory,
                engine: EngineId::of_fidelity(&self.fidelity),
                failures: FailureStats::of(&p.report),
                delta: self.delta_outcome(fp),
            });
        }
        let a = self.disk.as_ref().and_then(|d| d.get(&fp))?;
        self.counters.disk_hits.fetch_add(1, Ordering::Relaxed);
        Some(Answer::Exact {
            fp,
            turnaround_s: a.turnaround.as_secs_f64(),
            cost_node_s: a.cost_node_s,
            source: Source::Disk,
            engine: a.engine,
            failures: a.failures,
            delta: None,
        })
    }

    fn simulate_answer(&self, fp: Fingerprint, workload: &Workload, config: &Config) -> Answer {
        let p = self.evaluate_fp(fp, workload, config);
        Answer::Exact {
            fp,
            turnaround_s: p.turnaround.as_secs_f64(),
            cost_node_s: p.cost_node_secs,
            source: Source::Simulated,
            engine: EngineId::of_fidelity(&self.fidelity),
            failures: FailureStats::of(&p.report),
            delta: self.delta_outcome(fp),
        }
    }

    /// Summary-level query for the batch/serve path: memory cache →
    /// on-disk store → fresh simulation, attributed.
    pub fn query(&self, workload: &Workload, config: &Config) -> Answer {
        let fp = self.fingerprint(workload, config);
        match self.lookup(fp) {
            Some(a) => a,
            None => self.simulate_answer(fp, workload, config),
        }
    }

    /// Serve a batch. With `max_est_err <= 0` (the gate off) every query
    /// is answered exactly, fanned out over the worker pool
    /// ([`coordinator::par_map_indexed`]); duplicate fingerprints collapse
    /// onto one simulation via single-flight, and answers come back in
    /// input order. With the gate on, queries are answered in stream
    /// order so each exact answer seeds the surrogate grid for later ones
    /// — an unmemoized query whose interpolation error fits the gate is
    /// served by the surrogate (and attributed as such); a memoized one is
    /// always served exactly, since the truth is already paid for.
    pub fn serve_batch(&self, queries: &[Query], threads: usize, max_est_err: f64) -> Vec<Answer> {
        if max_est_err <= 0.0 {
            return coordinator::par_map_indexed(queries.len(), threads, |i| {
                self.query(&queries[i].workload, &queries[i].config)
            });
        }
        queries
            .iter()
            .map(|q| {
                let coord = GridCoord::of(&q.config);
                let fp = self.fingerprint(&q.workload, &q.config);
                // A memoized point is always served exactly — the truth
                // is already paid for; surrogate only covers fresh ones.
                if let Some(a) = self.lookup(fp) {
                    self.note_sample(q.family, coord, a.turnaround_s());
                    return a;
                }
                if let Some(est) = self.interpolate(q.family, coord, max_est_err) {
                    return Answer::Surrogate {
                        fp,
                        turnaround_s: est.time_s,
                        cost_node_s: est.time_s * q.config.n_hosts() as f64,
                        est_err: est.est_err,
                    };
                }
                let a = self.simulate_answer(fp, &q.workload, &q.config);
                self.note_sample(q.family, coord, a.turnaround_s());
                a
            })
            .collect()
    }

    /// Record an exact sample into workload family `family`'s surrogate
    /// grid.
    pub fn note_sample(&self, family: u64, coord: GridCoord, time_s: f64) {
        self.grids
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .entry(family)
            .or_default()
            .note(coord, time_s);
    }

    /// Surrogate fast-path: an interpolated estimate for `coord` within
    /// `family`, only when its error bound fits `max_est_err`. Counted in
    /// [`StatsSnapshot::surrogate_answers`] when it answers.
    pub fn interpolate(&self, family: u64, coord: GridCoord, max_est_err: f64) -> Option<Estimate> {
        let grids = self.grids.lock().unwrap_or_else(|e| e.into_inner());
        let est = grids.get(&family)?.interpolate(coord)?;
        if est.est_err <= max_est_err {
            self.counters.surrogate_answers.fetch_add(1, Ordering::Relaxed);
            Some(est)
        } else {
            None
        }
    }
}

/// Assemble a [`Prediction`] from a finished report exactly the way
/// `Predictor::predict` does, so delta and cold answers are
/// indistinguishable downstream (only the wallclock — which the predictor
/// measures, not computes — differs).
fn prediction_of(report: SimReport, config: &Config, wall: f64) -> Prediction {
    let stage_times = (0..report.n_stages()).map(|s| report.stage_time(s)).collect();
    let cost = config.n_hosts() as f64 * report.turnaround.as_secs_f64();
    Prediction {
        turnaround: report.turnaround,
        stage_times,
        cost_node_secs: cost,
        predictor_wallclock_secs: wall,
        report,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Platform;
    use crate::util::units::Bytes;
    use crate::workload::blast::{blast, BlastParams};

    fn service() -> Service {
        Service::new(Predictor::new(Platform::paper_testbed()))
    }

    fn point() -> (Workload, Config) {
        let params = BlastParams { queries: 20, ..Default::default() };
        (blast(4, &params), Config::partitioned(4, 3, Bytes::kb(256)))
    }

    #[test]
    fn memoizes_and_counts() {
        let svc = service();
        let (wl, cfg) = point();
        let a = svc.evaluate(&wl, &cfg);
        let b = svc.evaluate(&wl, &cfg);
        assert_eq!(a.turnaround, b.turnaround);
        assert!(Arc::ptr_eq(&a, &b), "warm hit returns the cached prediction itself");
        let s = svc.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 1);
        assert_eq!(svc.cache_len(), 1);
        assert!(s.cache.hits >= s.hits, "shard probes include every served hit");
        assert!(s.cache.misses >= s.misses, "the one simulation probed and missed first");
        assert_eq!(s.cache.evictions, 0);
        assert_eq!(s.exact_answers(), 2);
    }

    #[test]
    fn distinct_points_do_not_collide() {
        let svc = service();
        let (wl, cfg) = point();
        let other = Config::partitioned(4, 3, Bytes::mb(1));
        let a = svc.evaluate(&wl, &cfg);
        let b = svc.evaluate(&wl, &other);
        assert_eq!(svc.stats().misses, 2);
        assert_ne!(a.report.config_label, b.report.config_label);
    }

    #[test]
    fn concurrent_duplicates_single_flight() {
        let svc = service();
        let (wl, cfg) = point();
        let results = coordinator::par_map_indexed(8, 8, |_| svc.evaluate(&wl, &cfg));
        let s = svc.stats();
        assert_eq!(s.misses, 1, "one simulation for 8 concurrent duplicates");
        assert_eq!(s.hits + s.dedup_waits + s.misses, 8, "every call classified exactly once");
        for r in &results {
            assert_eq!(r.turnaround, results[0].turnaround);
        }
        assert!(svc.inflight.lock().unwrap().is_empty(), "flight table drains");
    }

    #[test]
    fn query_attributes_sources() {
        let svc = service();
        let (wl, cfg) = point();
        let a = svc.query(&wl, &cfg);
        let b = svc.query(&wl, &cfg);
        match (&a, &b) {
            (
                Answer::Exact { source: Source::Simulated, turnaround_s: ta, .. },
                Answer::Exact { source: Source::Memory, turnaround_s: tb, .. },
            ) => assert_eq!(ta, tb),
            other => panic!("unexpected attribution {other:?}"),
        }
        assert_eq!(a.fp(), b.fp());
        assert!(a.is_exact() && a.est_err().is_none());
    }

    #[test]
    fn poisoned_locks_do_not_wedge_the_service() {
        let svc = service();
        let (wl, cfg) = point();
        // Poison the grid and inflight mutexes the way a panicking
        // request thread would: by unwinding while the guard is held.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = svc.grids.lock().unwrap();
            panic!("injected panic while holding the grids lock");
        }));
        assert!(r.is_err());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = svc.inflight.lock().unwrap();
            panic!("injected panic while holding the inflight lock");
        }));
        assert!(r.is_err());
        // Every path that takes those locks must still work.
        svc.note_sample(7, GridCoord::of(&cfg), 1.25);
        let _ = svc.interpolate(7, GridCoord::of(&cfg), 0.5);
        let a = svc.evaluate(&wl, &cfg);
        let b = svc.query(&wl, &cfg);
        assert_eq!(a.turnaround.as_secs_f64(), b.turnaround_s());
        assert_eq!(b.failures(), Some(FailureStats::default()), "fault-free run, clean stats");
    }

    #[test]
    fn gate_off_never_answers_surrogate() {
        let svc = service();
        let params = BlastParams { queries: 20, ..Default::default() };
        let queries: Vec<Query> = (2..=6)
            .map(|n| Query {
                workload: blast(n, &params),
                config: Config::partitioned(n, 7 - n, Bytes::kb(256)),
                family: 1,
            })
            .collect();
        let answers = svc.serve_batch(&queries, 2, 0.0);
        assert_eq!(answers.len(), 5);
        assert!(answers.iter().all(Answer::is_exact));
        assert_eq!(svc.stats().surrogate_answers, 0);
    }
}
