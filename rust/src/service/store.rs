//! Append-only on-disk prediction store (JSONL), so campaigns warm-start
//! across processes.
//!
//! Each line is one flat record keyed by the canonical
//! [`Fingerprint`](super::fingerprint::Fingerprint): the summary of a
//! prediction that is worth persisting — turnaround, cost, per-stage
//! times, event/byte accounting. The full `SimReport` (per-op records,
//! utilization) stays in the in-memory cache only: it is large, and the
//! cross-process consumers (batch scoring, surrogate seeding, `serve`)
//! need the summary. Records are written through
//! [`Json::render_compact`](crate::util::jsonw::Json::render_compact) and
//! read back with [`jsonw::parse_flat`](crate::util::jsonw::parse_flat);
//! appends are flushed per record so a killed campaign still seeds its
//! successor.

use super::fingerprint::Fingerprint;
use super::EngineId;
use crate::model::{SimReport, StageCheckpoint, StageFp};
use crate::predict::Prediction;
use crate::util::jsonw::{self, Json, Scalar};
use crate::util::units::{Bytes, SimTime};
use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Degraded-mode accounting carried on an answer. All-zero (and
/// `unrecoverable == false`) whenever the query's fault plan was empty,
/// including every record written before fault injection existed.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FailureStats {
    /// Chunk attempts re-issued after a timeout.
    pub retries: u64,
    /// Chunk attempts routed away from the fault-free replica target.
    pub failovers: u64,
    /// Per-chunk timeouts that fired.
    pub timeouts: u64,
    /// Whether any operation was lost for good.
    pub unrecoverable: bool,
}

impl FailureStats {
    pub fn of(r: &SimReport) -> FailureStats {
        FailureStats {
            retries: r.fault_retries,
            failovers: r.fault_failovers,
            timeouts: r.fault_timeouts,
            unrecoverable: r.unrecoverable(),
        }
    }
}

/// The persisted summary of one prediction.
#[derive(Clone, Debug, PartialEq)]
pub struct StoredAnswer {
    pub turnaround: SimTime,
    pub cost_node_s: f64,
    pub stage_times: Vec<SimTime>,
    pub events: u64,
    pub net_bytes: Bytes,
    /// Which engine simulated this answer. Records written before engine
    /// provenance existed parse as [`EngineId::Coarse`] — the only engine
    /// the service ran at the time.
    pub engine: EngineId,
    pub failures: FailureStats,
    /// Per-stage checkpoint summaries of the run behind this answer
    /// (`model/delta.rs`): stage fingerprints prove prefix sharing across
    /// processes, the integrals document where the boundaries fell.
    /// Records written before incremental re-simulation existed — or
    /// whose `ckpts` field a newer/older build mangled — parse with an
    /// empty list, which downstream means "cold path only".
    pub checkpoints: Vec<StageCheckpoint>,
}

impl StoredAnswer {
    pub fn of(p: &Prediction, engine: EngineId) -> StoredAnswer {
        StoredAnswer {
            turnaround: p.turnaround,
            cost_node_s: p.cost_node_secs,
            stage_times: p.stage_times.clone(),
            events: p.report.events,
            net_bytes: p.report.net_bytes,
            engine,
            failures: FailureStats::of(&p.report),
            checkpoints: Vec::new(),
        }
    }

    pub fn with_checkpoints(mut self, checkpoints: Vec<StageCheckpoint>) -> StoredAnswer {
        self.checkpoints = checkpoints;
        self
    }
}

/// Checkpoints travel as one compact string — `;`-separated checkpoints
/// of `:`-separated hex fields — because every quantity here (RNG state
/// words, 64-bit fingerprint halves, ns integrals) must round-trip
/// *exactly*, and flat-JSON numbers are f64-backed (53-bit mantissa).
fn encode_checkpoints(cks: &[StageCheckpoint]) -> String {
    cks.iter()
        .map(|c| {
            format!(
                "{:x}:{}:{:x}:{:x}:{:x}:{:x}:{:x}:{:x}:{:x}:{:x}:{:x}:{:x}:{:x}:{:x}",
                c.stage,
                c.fp,
                c.t_ns,
                c.events,
                c.tasks_finished,
                c.net_bytes,
                c.n_allocs,
                c.n_groups,
                c.manager_busy_ns,
                c.storage_busy_ns,
                c.rng[0],
                c.rng[1],
                c.rng[2],
                c.rng[3],
            )
        })
        .collect::<Vec<_>>()
        .join(";")
}

/// Inverse of [`encode_checkpoints`]. Lenient by design: any malformation
/// yields `None` (the caller stores an empty list and the answer itself
/// survives) — checkpoint summaries are an optimization substrate, never
/// worth losing a record over.
fn decode_checkpoints(s: &str) -> Option<Vec<StageCheckpoint>> {
    if s.is_empty() {
        return Some(Vec::new());
    }
    let mut out = Vec::new();
    for part in s.split(';') {
        let f: Vec<&str> = part.split(':').collect();
        if f.len() != 14 {
            return None;
        }
        let hex = |i: usize| u64::from_str_radix(f[i], 16).ok();
        out.push(StageCheckpoint {
            stage: hex(0)? as u32,
            fp: StageFp::parse(f[1])?,
            t_ns: hex(2)?,
            events: hex(3)?,
            tasks_finished: hex(4)? as u32,
            net_bytes: hex(5)?,
            n_allocs: hex(6)? as u32,
            n_groups: hex(7)? as u32,
            manager_busy_ns: hex(8)?,
            storage_busy_ns: hex(9)?,
            rng: [hex(10)?, hex(11)?, hex(12)?, hex(13)?],
        });
    }
    Some(out)
}

/// The store: a replayed in-memory index plus an append-only writer.
pub struct DiskStore {
    path: PathBuf,
    writer: Mutex<BufWriter<File>>,
    loaded: Mutex<HashMap<Fingerprint, StoredAnswer>>,
    salvaged: usize,
    reclaimed: usize,
}

impl DiskStore {
    /// Open `path` (creating it if needed) and replay existing records.
    /// A corrupt *interior* record — a flipped bit, an editor accident, a
    /// record from a future format — is quarantined: logged, counted in
    /// [`salvaged`](Self::salvaged), and skipped, so one bad line cannot
    /// hold the whole warm-start substrate hostage. A corrupt *final*
    /// record is what a crash or full disk mid-append leaves behind, so
    /// it is likewise dropped with a warning and every complete record
    /// is recovered.
    pub fn open(path: impl AsRef<Path>) -> Result<DiskStore, String> {
        let path = path.as_ref().to_path_buf();
        let mut loaded = HashMap::new();
        let mut salvaged = 0usize;
        let mut parsed = 0usize;
        if let Ok(text) = std::fs::read_to_string(&path) {
            let lines: Vec<&str> = text.lines().collect();
            for (idx, raw) in lines.iter().enumerate() {
                let line = raw.trim();
                if line.is_empty() {
                    continue;
                }
                match Self::parse_line(line) {
                    Some((fp, ans)) => {
                        parsed += 1;
                        // Last record wins: a later append for the same
                        // fingerprint (another process, or a richer
                        // format) supersedes the earlier one.
                        loaded.insert(fp, ans);
                    }
                    None if idx + 1 == lines.len() => {
                        eprintln!(
                            "[service] dropping truncated final record in {}",
                            path.display()
                        );
                    }
                    None => {
                        salvaged += 1;
                        eprintln!(
                            "[service] quarantining corrupt record at line {} of {}: {line:?}",
                            idx + 1,
                            path.display()
                        );
                    }
                }
            }
        }
        // Compact-on-open: when replay found superseded records (several
        // appenders, or repeated campaigns over one store), rewrite the
        // file as exactly the surviving newest-per-fingerprint set. A
        // clean store is left byte-untouched — no rewrite churn on the
        // common path — and a failed rewrite is only a warning: the
        // in-memory index is already correct either way.
        let reclaimed = parsed - loaded.len();
        if reclaimed > 0 {
            let mut fps: Vec<&Fingerprint> = loaded.keys().collect();
            fps.sort();
            let mut text = String::new();
            for fp in fps {
                text.push_str(&Self::render_record(*fp, &loaded[fp]));
                text.push('\n');
            }
            let tmp = path.with_extension("compact.tmp");
            let rewrote = std::fs::write(&tmp, &text)
                .and_then(|_| std::fs::rename(&tmp, &path));
            match rewrote {
                Ok(()) => eprintln!(
                    "[service] compacted {}: reclaimed {reclaimed} superseded record{}",
                    path.display(),
                    if reclaimed == 1 { "" } else { "s" }
                ),
                Err(e) => {
                    eprintln!("[service] store compaction of {} failed: {e}", path.display())
                }
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| format!("open {}: {e}", path.display()))?;
        Ok(DiskStore {
            path,
            writer: Mutex::new(BufWriter::new(file)),
            loaded: Mutex::new(loaded),
            salvaged,
            reclaimed,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Corrupt interior records skipped while replaying at `open` time
    /// (the truncated-tail drop is not counted — that is the normal
    /// crash-recovery path, not data damage).
    pub fn salvaged(&self) -> usize {
        self.salvaged
    }

    /// Superseded records reclaimed by compact-on-open (0 when the store
    /// was already one record per fingerprint and was left untouched).
    pub fn reclaimed(&self) -> usize {
        self.reclaimed
    }

    pub fn len(&self) -> usize {
        self.lock_loaded().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn get(&self, fp: &Fingerprint) -> Option<StoredAnswer> {
        self.lock_loaded().get(fp).cloned()
    }

    /// Record one answer (idempotent per fingerprint) and flush. An
    /// append failure (disk full, permissions) is surfaced on stderr and
    /// the record is dropped from the in-memory index too, so what the
    /// index claims and what the next `open` replays stay consistent.
    pub fn put(&self, fp: Fingerprint, ans: &StoredAnswer) {
        {
            let mut m = self.lock_loaded();
            if m.contains_key(&fp) {
                return;
            }
            m.insert(fp, ans.clone());
        }
        let line = Self::render_record(fp, ans);
        let mut w = self.writer.lock().unwrap_or_else(|e| e.into_inner());
        let wrote = writeln!(w, "{line}").and_then(|_| w.flush());
        drop(w);
        if let Err(e) = wrote {
            eprintln!("[service] failed to append to {}: {e}", self.path.display());
            self.lock_loaded().remove(&fp);
        }
    }

    /// A panic while a lock was held must not wedge every later request
    /// (the store outlives request threads in `serve`), so poisoning is
    /// shrugged off: the guarded maps are always left key-consistent.
    fn lock_loaded(&self) -> std::sync::MutexGuard<'_, HashMap<Fingerprint, StoredAnswer>> {
        self.loaded.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Render one record exactly as [`DiskStore::put`] appends it (also
    /// used verbatim by compact-on-open, so a compacted store replays to
    /// the same index).
    fn render_record(fp: Fingerprint, ans: &StoredAnswer) -> String {
        let stages: Vec<Json> =
            ans.stage_times.iter().map(|t| Json::Num(t.as_ns() as f64)).collect();
        let mut line = Json::obj()
            .set("fp", fp.to_string())
            .set("turnaround_ns", ans.turnaround.as_ns())
            .set("cost_node_s", ans.cost_node_s)
            .set("stages_ns", Json::Arr(stages))
            .set("events", ans.events)
            .set("net_bytes", ans.net_bytes.as_u64())
            .set("engine", ans.engine.as_str())
            .set("fault_retries", ans.failures.retries)
            .set("fault_failovers", ans.failures.failovers)
            .set("fault_timeouts", ans.failures.timeouts)
            .set("unrecoverable", ans.failures.unrecoverable);
        if !ans.checkpoints.is_empty() {
            line = line.set("ckpts", encode_checkpoints(&ans.checkpoints));
        }
        line.render_compact()
    }

    fn parse_line(line: &str) -> Option<(Fingerprint, StoredAnswer)> {
        let kv = jsonw::parse_flat(line).ok()?;
        let get = |k: &str| kv.iter().find(|(key, _)| key == k).map(|(_, v)| v);
        let num = |k: &str| match get(k) {
            Some(Scalar::Num(x)) => Some(*x),
            _ => None,
        };
        let fp = match get("fp")? {
            Scalar::Str(s) => Fingerprint::parse(s)?,
            _ => return None,
        };
        let stage_times = match get("stages_ns")? {
            Scalar::NumArr(xs) => xs.iter().map(|&x| SimTime::from_ns(x as u64)).collect(),
            _ => return None,
        };
        // The engine key is absent from pre-provenance stores, which were
        // only ever written by the coarse engine; an unknown label (a
        // newer build's store) also falls back rather than dropping the
        // record.
        let engine = match get("engine") {
            Some(Scalar::Str(s)) => EngineId::parse(s).unwrap_or(EngineId::Coarse),
            _ => EngineId::Coarse,
        };
        // Failure keys are absent from pre-fault-injection stores; such
        // records are by construction fault-free, so default to zero.
        let failures = FailureStats {
            retries: num("fault_retries").unwrap_or(0.0) as u64,
            failovers: num("fault_failovers").unwrap_or(0.0) as u64,
            timeouts: num("fault_timeouts").unwrap_or(0.0) as u64,
            unrecoverable: matches!(get("unrecoverable"), Some(Scalar::Bool(true))),
        };
        // The ckpts key is absent from pre-delta stores (PR 9), and a
        // mangled value degrades to "no checkpoints" rather than losing
        // the answer — the same leniency the engine/fault keys get.
        let checkpoints = match get("ckpts") {
            Some(Scalar::Str(s)) => decode_checkpoints(s).unwrap_or_default(),
            _ => Vec::new(),
        };
        Some((
            fp,
            StoredAnswer {
                turnaround: SimTime::from_ns(num("turnaround_ns")? as u64),
                cost_node_s: num("cost_node_s")?,
                stage_times,
                events: num("events")? as u64,
                net_bytes: Bytes(num("net_bytes")? as u64),
                engine,
                failures,
                checkpoints,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("wfpred_store_{}_{name}.jsonl", std::process::id()))
    }

    fn ckpt(i: u64) -> StageCheckpoint {
        StageCheckpoint {
            stage: i as u32,
            // Extreme u64s on purpose: they do not round-trip through f64,
            // so this pins the hex encoding.
            fp: StageFp { hi: u64::MAX - i, lo: 0x0123_4567_89AB_CDEF ^ i },
            t_ns: u64::MAX - 7 * i,
            events: (1 << 60) + i,
            tasks_finished: 40 + i as u32,
            net_bytes: (1 << 55) + i,
            n_allocs: 12 + i as u32,
            n_groups: 3 + i as u32,
            manager_busy_ns: (1 << 54) + i,
            storage_busy_ns: (1 << 53) + i,
            rng: [u64::MAX - i, i.wrapping_mul(0x9E37), 1 + i, u64::MAX / 3 + i],
        }
    }

    fn sample(i: u64) -> (Fingerprint, StoredAnswer) {
        (
            Fingerprint { hi: i, lo: i.wrapping_mul(31) },
            StoredAnswer {
                turnaround: SimTime::from_ms(100 + i),
                cost_node_s: 10.5 * (i + 1) as f64,
                stage_times: vec![SimTime::from_ms(40), SimTime::from_ms(60 + i)],
                events: 1000 + i,
                net_bytes: Bytes::mb(i + 1),
                engine: if i % 2 == 0 { EngineId::Coarse } else { EngineId::Detailed },
                failures: FailureStats {
                    retries: i,
                    failovers: 2 * i,
                    timeouts: i,
                    unrecoverable: i % 2 == 1,
                },
                checkpoints: (0..i % 3).map(ckpt).collect(),
            },
        )
    }

    #[test]
    fn roundtrips_across_reopen() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        {
            let store = DiskStore::open(&path).unwrap();
            assert!(store.is_empty());
            for i in 0..3 {
                let (fp, ans) = sample(i);
                store.put(fp, &ans);
            }
            assert_eq!(store.len(), 3);
        }
        let reopened = DiskStore::open(&path).unwrap();
        assert_eq!(reopened.len(), 3);
        for i in 0..3 {
            let (fp, ans) = sample(i);
            assert_eq!(reopened.get(&fp), Some(ans), "record {i}");
        }
        assert_eq!(reopened.get(&Fingerprint { hi: 99, lo: 99 }), None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn put_is_idempotent_per_fingerprint() {
        let path = tmp("idem");
        let _ = std::fs::remove_file(&path);
        let store = DiskStore::open(&path).unwrap();
        let (fp, ans) = sample(7);
        store.put(fp, &ans);
        store.put(fp, &ans);
        drop(store);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 1, "duplicate puts must not append");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_interior_record_is_salvaged_around() {
        let path = tmp("corrupt");
        let (fp, ans) = sample(1);
        let good = {
            let _ = std::fs::remove_file(&path);
            let store = DiskStore::open(&path).unwrap();
            assert_eq!(store.salvaged(), 0);
            store.put(fp, &ans);
            drop(store);
            std::fs::read_to_string(&path).unwrap()
        };
        std::fs::write(&path, format!("{{\"fp\": \"nope\"}}\nnot json at all\n{good}")).unwrap();
        let store = DiskStore::open(&path).unwrap();
        assert_eq!(store.len(), 1, "the intact record survives its corrupt neighbors");
        assert_eq!(store.get(&fp), Some(ans));
        assert_eq!(store.salvaged(), 2, "each quarantined line is counted");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn records_without_failure_keys_parse_as_fault_free() {
        // Stores written before fault injection existed lack the
        // fault_* / unrecoverable keys entirely.
        let path = tmp("legacy");
        let fp = Fingerprint { hi: 5, lo: 155 };
        std::fs::write(
            &path,
            format!(
                "{{\"fp\": \"{fp}\", \"turnaround_ns\": 1500000, \"cost_node_s\": 2.5, \
                 \"stages_ns\": [1500000], \"events\": 42, \"net_bytes\": 1024}}\n"
            ),
        )
        .unwrap();
        let store = DiskStore::open(&path).unwrap();
        assert_eq!(store.salvaged(), 0);
        let ans = store.get(&fp).expect("legacy record parses");
        assert_eq!(ans.failures, FailureStats::default());
        assert!(!ans.failures.unrecoverable);
        assert_eq!(ans.engine, EngineId::Coarse, "pre-provenance records were coarse-only");
        assert!(ans.checkpoints.is_empty(), "pre-delta records carry no checkpoints");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn pre_delta_records_parse_and_mangled_ckpts_degrade() {
        // A verbatim pre-PR-9 store line (engine key present, no ckpts)
        // plus a record whose ckpts value was mangled: both must parse,
        // the latter with its checkpoints dropped, never the answer.
        let path = tmp("predelta");
        let a = Fingerprint { hi: 1, lo: 2 };
        let b = Fingerprint { hi: 3, lo: 4 };
        std::fs::write(
            &path,
            format!(
                "{{\"fp\": \"{a}\", \"turnaround_ns\": 2000000, \"cost_node_s\": 4.5, \
                 \"stages_ns\": [2000000], \"events\": 10, \"net_bytes\": 2048, \
                 \"engine\": \"coarse\", \"fault_retries\": 0, \"fault_failovers\": 0, \
                 \"fault_timeouts\": 0, \"unrecoverable\": false}}\n\
                 {{\"fp\": \"{b}\", \"turnaround_ns\": 3000000, \"cost_node_s\": 6.5, \
                 \"stages_ns\": [3000000], \"events\": 11, \"net_bytes\": 4096, \
                 \"engine\": \"coarse\", \"fault_retries\": 0, \"fault_failovers\": 0, \
                 \"fault_timeouts\": 0, \"unrecoverable\": false, \
                 \"ckpts\": \"0:tooshort\"}}\n"
            ),
        )
        .unwrap();
        let store = DiskStore::open(&path).unwrap();
        assert_eq!(store.salvaged(), 0, "both records are healthy answers");
        assert_eq!(store.reclaimed(), 0);
        assert!(store.get(&a).expect("pre-delta record parses").checkpoints.is_empty());
        assert!(
            store.get(&b).expect("the answer outlives its mangled ckpts").checkpoints.is_empty()
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compact_on_open_keeps_newest_record_per_fingerprint() {
        let path = tmp("compact");
        let _ = std::fs::remove_file(&path);
        let (fp, old) = sample(1);
        let (fp2, keep) = sample(2);
        let newer = StoredAnswer { cost_node_s: 99.0, checkpoints: vec![ckpt(5)], ..old.clone() };
        // Simulate two appenders racing on one store: the same
        // fingerprint appended twice (newer record last), plus a normal
        // record.
        let text = format!(
            "{}\n{}\n{}\n",
            DiskStore::render_record(fp, &old),
            DiskStore::render_record(fp2, &keep),
            DiskStore::render_record(fp, &newer),
        );
        std::fs::write(&path, text).unwrap();
        let store = DiskStore::open(&path).unwrap();
        assert_eq!(store.reclaimed(), 1, "one superseded record reclaimed");
        assert_eq!(store.len(), 2);
        assert_eq!(store.get(&fp), Some(newer.clone()), "newest record wins");
        assert_eq!(store.get(&fp2), Some(keep.clone()));
        drop(store);
        // The rewritten file holds exactly the survivors and replays to
        // the same index with nothing left to reclaim.
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2, "compaction rewrote the file");
        let reopened = DiskStore::open(&path).unwrap();
        assert_eq!(reopened.reclaimed(), 0, "a clean store is left untouched");
        assert_eq!(reopened.get(&fp), Some(newer));
        assert_eq!(reopened.get(&fp2), Some(keep));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_final_record_is_recovered_from() {
        // A crash mid-append leaves a partial last line; the store must
        // recover every complete record and drop only the tail.
        let path = tmp("truncated");
        let _ = std::fs::remove_file(&path);
        {
            let store = DiskStore::open(&path).unwrap();
            let (fp, ans) = sample(3);
            store.put(fp, &ans);
        }
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"fp\": \"0123\", \"turnaro");
        std::fs::write(&path, text).unwrap();
        let store = DiskStore::open(&path).unwrap();
        assert_eq!(store.len(), 1, "complete records survive a truncated tail");
        let (fp, ans) = sample(3);
        assert_eq!(store.get(&fp), Some(ans));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compact_on_open_interleaves_corruption_with_superseded_records() {
        // The worst replay: corrupt lines *between* the superseded and
        // superseding appends, plus a crash-truncated tail. Salvage and
        // reclaim must account independently, the newest record must
        // still win, and the compaction rewrite must purge the corrupt
        // lines along with the superseded ones.
        let path = tmp("interleaved");
        let _ = std::fs::remove_file(&path);
        let (fp, old) = sample(1);
        let (fp2, keep) = sample(2);
        let newer =
            StoredAnswer { cost_node_s: 123.0, checkpoints: vec![ckpt(1)], ..old.clone() };
        let text = format!(
            "not json at all\n{}\n{{\"fp\": \"mangled\"}}\n{}\n{}\n{{\"fp",
            DiskStore::render_record(fp, &old),
            DiskStore::render_record(fp2, &keep),
            DiskStore::render_record(fp, &newer),
        );
        std::fs::write(&path, text).unwrap();
        let store = DiskStore::open(&path).unwrap();
        assert_eq!(store.salvaged(), 2, "interior corruption counted; the crashed tail is not");
        assert_eq!(store.reclaimed(), 1, "one superseded record reclaimed");
        assert_eq!(store.len(), 2);
        assert_eq!(store.get(&fp), Some(newer.clone()), "newest record wins across corruption");
        assert_eq!(store.get(&fp2), Some(keep.clone()));
        drop(store);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2, "the rewrite holds exactly the survivors");
        let reopened = DiskStore::open(&path).unwrap();
        assert_eq!(reopened.salvaged(), 0, "corrupt lines are gone after compaction");
        assert_eq!(reopened.reclaimed(), 0, "nothing left to reclaim");
        assert_eq!(reopened.get(&fp), Some(newer));
        assert_eq!(reopened.get(&fp2), Some(keep));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_ckpts_hex_field_falls_back_cold() {
        // A ckpts entry with the right field count but a truncated
        // stage-fingerprint hex value (31 chars, not 32) must fail the
        // codec — and at the store level degrade to "answer intact,
        // checkpoints empty": a cold warm-start, never a lost record.
        let short = "0:fffffffffffffffa0123456789abcde:1:2:3:4:5:6:7:8:9:a:b:c";
        assert_eq!(short.split(':').count(), 14, "field count is not what fails here");
        assert!(decode_checkpoints(short).is_none(), "a 31-hex fingerprint must not parse");

        let path = tmp("shortfp");
        let _ = std::fs::remove_file(&path);
        let (fp, ans) = sample(4);
        assert!(!ans.checkpoints.is_empty(), "the sample must carry a checkpoint");
        let rendered = DiskStore::render_record(fp, &ans);
        let full = ans.checkpoints[0].fp.to_string();
        let mangled = rendered.replace(&full, &full[..full.len() - 1]);
        assert_ne!(rendered, mangled, "the checkpoint fingerprint must appear verbatim");
        std::fs::write(&path, format!("{mangled}\n")).unwrap();
        let store = DiskStore::open(&path).unwrap();
        assert_eq!(store.salvaged(), 0, "the answer itself is healthy");
        let got = store.get(&fp).expect("the answer outlives its truncated checkpoint");
        assert!(got.checkpoints.is_empty(), "decode falls back cold");
        assert_eq!(StoredAnswer { checkpoints: vec![], ..ans }, got);
        let _ = std::fs::remove_file(&path);
    }
}
