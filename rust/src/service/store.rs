//! Append-only on-disk prediction store (JSONL), so campaigns warm-start
//! across processes.
//!
//! Each line is one flat record keyed by the canonical
//! [`Fingerprint`](super::fingerprint::Fingerprint): the summary of a
//! prediction that is worth persisting — turnaround, cost, per-stage
//! times, event/byte accounting. The full `SimReport` (per-op records,
//! utilization) stays in the in-memory cache only: it is large, and the
//! cross-process consumers (batch scoring, surrogate seeding, `serve`)
//! need the summary. Records are written through
//! [`Json::render_compact`](crate::util::jsonw::Json::render_compact) and
//! read back with [`jsonw::parse_flat`](crate::util::jsonw::parse_flat);
//! appends are flushed per record so a killed campaign still seeds its
//! successor.

use super::fingerprint::Fingerprint;
use crate::predict::Prediction;
use crate::util::jsonw::{self, Json, Scalar};
use crate::util::units::{Bytes, SimTime};
use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// The persisted summary of one prediction.
#[derive(Clone, Debug, PartialEq)]
pub struct StoredAnswer {
    pub turnaround: SimTime,
    pub cost_node_s: f64,
    pub stage_times: Vec<SimTime>,
    pub events: u64,
    pub net_bytes: Bytes,
}

impl StoredAnswer {
    pub fn of(p: &Prediction) -> StoredAnswer {
        StoredAnswer {
            turnaround: p.turnaround,
            cost_node_s: p.cost_node_secs,
            stage_times: p.stage_times.clone(),
            events: p.report.events,
            net_bytes: p.report.net_bytes,
        }
    }
}

/// The store: a replayed in-memory index plus an append-only writer.
pub struct DiskStore {
    path: PathBuf,
    writer: Mutex<BufWriter<File>>,
    loaded: Mutex<HashMap<Fingerprint, StoredAnswer>>,
}

impl DiskStore {
    /// Open `path` (creating it if needed) and replay existing records.
    /// A corrupt interior record is an error, not a silent skip: the
    /// store is the warm-start substrate and half-read state would be
    /// confusing. A corrupt *final* record is what a crash or full disk
    /// mid-append leaves behind, so it is dropped with a warning and the
    /// rest of the store is recovered.
    pub fn open(path: impl AsRef<Path>) -> Result<DiskStore, String> {
        let path = path.as_ref().to_path_buf();
        let mut loaded = HashMap::new();
        if let Ok(text) = std::fs::read_to_string(&path) {
            let lines: Vec<&str> = text.lines().collect();
            for (idx, raw) in lines.iter().enumerate() {
                let line = raw.trim();
                if line.is_empty() {
                    continue;
                }
                match Self::parse_line(line) {
                    Some((fp, ans)) => {
                        loaded.insert(fp, ans);
                    }
                    None if idx + 1 == lines.len() => {
                        eprintln!(
                            "[service] dropping truncated final record in {}",
                            path.display()
                        );
                    }
                    None => {
                        return Err(format!("corrupt record in {}: {line:?}", path.display()));
                    }
                }
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| format!("open {}: {e}", path.display()))?;
        Ok(DiskStore { path, writer: Mutex::new(BufWriter::new(file)), loaded: Mutex::new(loaded) })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn len(&self) -> usize {
        self.loaded.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn get(&self, fp: &Fingerprint) -> Option<StoredAnswer> {
        self.loaded.lock().unwrap().get(fp).cloned()
    }

    /// Record one answer (idempotent per fingerprint) and flush. An
    /// append failure (disk full, permissions) is surfaced on stderr and
    /// the record is dropped from the in-memory index too, so what the
    /// index claims and what the next `open` replays stay consistent.
    pub fn put(&self, fp: Fingerprint, ans: &StoredAnswer) {
        {
            let mut m = self.loaded.lock().unwrap();
            if m.contains_key(&fp) {
                return;
            }
            m.insert(fp, ans.clone());
        }
        let stages: Vec<Json> =
            ans.stage_times.iter().map(|t| Json::Num(t.as_ns() as f64)).collect();
        let line = Json::obj()
            .set("fp", fp.to_string())
            .set("turnaround_ns", ans.turnaround.as_ns())
            .set("cost_node_s", ans.cost_node_s)
            .set("stages_ns", Json::Arr(stages))
            .set("events", ans.events)
            .set("net_bytes", ans.net_bytes.as_u64())
            .render_compact();
        let mut w = self.writer.lock().unwrap();
        let wrote = writeln!(w, "{line}").and_then(|_| w.flush());
        drop(w);
        if let Err(e) = wrote {
            eprintln!("[service] failed to append to {}: {e}", self.path.display());
            self.loaded.lock().unwrap().remove(&fp);
        }
    }

    fn parse_line(line: &str) -> Option<(Fingerprint, StoredAnswer)> {
        let kv = jsonw::parse_flat(line).ok()?;
        let get = |k: &str| kv.iter().find(|(key, _)| key == k).map(|(_, v)| v);
        let num = |k: &str| match get(k) {
            Some(Scalar::Num(x)) => Some(*x),
            _ => None,
        };
        let fp = match get("fp")? {
            Scalar::Str(s) => Fingerprint::parse(s)?,
            _ => return None,
        };
        let stage_times = match get("stages_ns")? {
            Scalar::NumArr(xs) => xs.iter().map(|&x| SimTime::from_ns(x as u64)).collect(),
            _ => return None,
        };
        Some((
            fp,
            StoredAnswer {
                turnaround: SimTime::from_ns(num("turnaround_ns")? as u64),
                cost_node_s: num("cost_node_s")?,
                stage_times,
                events: num("events")? as u64,
                net_bytes: Bytes(num("net_bytes")? as u64),
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("wfpred_store_{}_{name}.jsonl", std::process::id()))
    }

    fn sample(i: u64) -> (Fingerprint, StoredAnswer) {
        (
            Fingerprint { hi: i, lo: i.wrapping_mul(31) },
            StoredAnswer {
                turnaround: SimTime::from_ms(100 + i),
                cost_node_s: 10.5 * (i + 1) as f64,
                stage_times: vec![SimTime::from_ms(40), SimTime::from_ms(60 + i)],
                events: 1000 + i,
                net_bytes: Bytes::mb(i + 1),
            },
        )
    }

    #[test]
    fn roundtrips_across_reopen() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        {
            let store = DiskStore::open(&path).unwrap();
            assert!(store.is_empty());
            for i in 0..3 {
                let (fp, ans) = sample(i);
                store.put(fp, &ans);
            }
            assert_eq!(store.len(), 3);
        }
        let reopened = DiskStore::open(&path).unwrap();
        assert_eq!(reopened.len(), 3);
        for i in 0..3 {
            let (fp, ans) = sample(i);
            assert_eq!(reopened.get(&fp), Some(ans), "record {i}");
        }
        assert_eq!(reopened.get(&Fingerprint { hi: 99, lo: 99 }), None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn put_is_idempotent_per_fingerprint() {
        let path = tmp("idem");
        let _ = std::fs::remove_file(&path);
        let store = DiskStore::open(&path).unwrap();
        let (fp, ans) = sample(7);
        store.put(fp, &ans);
        store.put(fp, &ans);
        drop(store);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 1, "duplicate puts must not append");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_interior_record_is_an_error() {
        let path = tmp("corrupt");
        let (fp, ans) = sample(1);
        let good = {
            let _ = std::fs::remove_file(&path);
            let store = DiskStore::open(&path).unwrap();
            store.put(fp, &ans);
            drop(store);
            std::fs::read_to_string(&path).unwrap()
        };
        std::fs::write(&path, format!("{{\"fp\": \"nope\"}}\n{good}")).unwrap();
        let err = DiskStore::open(&path).unwrap_err();
        assert!(err.contains("corrupt"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_final_record_is_recovered_from() {
        // A crash mid-append leaves a partial last line; the store must
        // recover every complete record and drop only the tail.
        let path = tmp("truncated");
        let _ = std::fs::remove_file(&path);
        {
            let store = DiskStore::open(&path).unwrap();
            let (fp, ans) = sample(3);
            store.put(fp, &ans);
        }
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"fp\": \"0123\", \"turnaro");
        std::fs::write(&path, text).unwrap();
        let store = DiskStore::open(&path).unwrap();
        assert_eq!(store.len(), 1, "complete records survive a truncated tail");
        let (fp, ans) = sample(3);
        assert_eq!(store.get(&fp), Some(ans));
        let _ = std::fs::remove_file(&path);
    }
}
