//! Surrogate fast-path: multilinear interpolation of turnaround over
//! already-evaluated neighbor configurations in a `SearchSpace`-style
//! grid.
//!
//! QoSFlow-style observation (PAPERS.md): once a few exact evaluations
//! pin down a workload family's response surface, an interpretable local
//! model can answer the *flat* interior of a configuration sweep, leaving
//! full simulation for the frontier. The grid here is the search layer's
//! decision space — (total allocation, replication) are exact-match axes,
//! (n_app, chunk size) interpolate (linearly in `n_app`, linearly in
//! `log2(chunk)`). Every estimate carries its own error bound, derived
//! from the relative spread of the bracketing samples: the interpolant
//! cannot be trusted beyond how much the function moves across its
//! bracket, so steep regions (where the search frontier lives) report
//! large `est_err` and get kicked back to exact simulation by the
//! caller's gate.
//!
//! Collocated deployments vary `total` together with `n_app`, so they
//! never bracket and always fall through to exact evaluation — the
//! surrogate serves the paper's partitioned (BLAST-style) sweeps, which
//! are exactly the batch "score a whole config space" queries.

use crate::model::Config;
use std::collections::{BTreeMap, HashMap};

/// Grid coordinate of one configuration within a workload family.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GridCoord {
    pub total_hosts: usize,
    pub n_app: usize,
    pub chunk: u64,
    pub replication: u32,
}

impl GridCoord {
    pub fn of(cfg: &Config) -> GridCoord {
        GridCoord {
            total_hosts: cfg.n_hosts(),
            n_app: cfg.n_app,
            chunk: cfg.chunk_size.as_u64(),
            replication: cfg.replication,
        }
    }
}

/// A surrogate answer: the estimate and its error bound, always together.
#[derive(Clone, Copy, Debug)]
pub struct Estimate {
    pub time_s: f64,
    /// Relative error bound from the local bracket spread (0 for an exact
    /// grid point). Callers gate on this; it is never absent.
    pub est_err: f64,
}

/// Exact samples of one workload family, keyed for interpolation.
#[derive(Default, Debug)]
pub struct SurrogateGrid {
    /// (total hosts, replication) → chunk bytes → (n_app → time_s).
    lines: HashMap<(usize, u32), BTreeMap<u64, BTreeMap<usize, f64>>>,
}

impl SurrogateGrid {
    pub fn new() -> SurrogateGrid {
        SurrogateGrid::default()
    }

    /// Record one exact evaluation.
    pub fn note(&mut self, c: GridCoord, time_s: f64) {
        self.lines
            .entry((c.total_hosts, c.replication))
            .or_default()
            .entry(c.chunk)
            .or_default()
            .insert(c.n_app, time_s);
    }

    /// Total samples held.
    pub fn samples(&self) -> usize {
        self.lines.values().flat_map(|m| m.values()).map(|l| l.len()).sum()
    }

    /// Linear interpolation along `n_app` within one chunk line.
    fn interp_line(line: &BTreeMap<usize, f64>, n_app: usize) -> Option<Estimate> {
        if let Some(&t) = line.get(&n_app) {
            return Some(Estimate { time_s: t, est_err: 0.0 });
        }
        let (&lo, &t_lo) = line.range(..n_app).next_back()?;
        let (&hi, &t_hi) = line.range(n_app + 1..).next()?;
        let x = (n_app - lo) as f64 / (hi - lo) as f64;
        let time_s = t_lo + (t_hi - t_lo) * x;
        if time_s <= 0.0 {
            return None;
        }
        let est_err = (t_hi - t_lo).abs() / t_lo.min(t_hi).max(f64::MIN_POSITIVE);
        Some(Estimate { time_s, est_err })
    }

    /// Multilinear interpolation at `c`: exact match on (total hosts,
    /// replication), linear in `n_app`, linear in `log2(chunk)` between
    /// the nearest sampled chunk lines when the chunk is unsampled.
    /// `None` when the point is not bracketed by samples.
    pub fn interpolate(&self, c: GridCoord) -> Option<Estimate> {
        let chunks = self.lines.get(&(c.total_hosts, c.replication))?;
        if let Some(line) = chunks.get(&c.chunk) {
            if let Some(e) = Self::interp_line(line, c.n_app) {
                return Some(e);
            }
        }
        let (&c_lo, lo_line) = chunks.range(..c.chunk).next_back()?;
        let (&c_hi, hi_line) = chunks.range(c.chunk + 1..).next()?;
        let a = Self::interp_line(lo_line, c.n_app)?;
        let b = Self::interp_line(hi_line, c.n_app)?;
        let x = ((c.chunk as f64).log2() - (c_lo as f64).log2())
            / ((c_hi as f64).log2() - (c_lo as f64).log2());
        let time_s = a.time_s + (b.time_s - a.time_s) * x;
        if time_s <= 0.0 {
            return None;
        }
        let spread = (b.time_s - a.time_s).abs() / a.time_s.min(b.time_s).max(f64::MIN_POSITIVE);
        Some(Estimate { time_s, est_err: a.est_err.max(b.est_err) + spread })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coord(n_app: usize, chunk: u64) -> GridCoord {
        GridCoord { total_hosts: 20, n_app, chunk, replication: 1 }
    }

    #[test]
    fn linear_in_n_app() {
        let mut g = SurrogateGrid::new();
        g.note(coord(2, 1024), 100.0);
        g.note(coord(8, 1024), 40.0);
        assert_eq!(g.samples(), 2);
        let e = g.interpolate(coord(5, 1024)).unwrap();
        assert!((e.time_s - 70.0).abs() < 1e-9, "{}", e.time_s);
        assert!((e.est_err - 60.0 / 40.0).abs() < 1e-9, "{}", e.est_err);
        // Exact grid point: zero error.
        let x = g.interpolate(coord(8, 1024)).unwrap();
        assert_eq!(x.time_s, 40.0);
        assert_eq!(x.est_err, 0.0);
    }

    #[test]
    fn refuses_unbracketed_points() {
        let mut g = SurrogateGrid::new();
        g.note(coord(2, 1024), 100.0);
        g.note(coord(8, 1024), 40.0);
        assert!(g.interpolate(coord(1, 1024)).is_none(), "below the bracket");
        assert!(g.interpolate(coord(9, 1024)).is_none(), "above the bracket");
        assert!(g.interpolate(coord(5, 512)).is_none(), "chunk not bracketed");
        // Other exact-match axes must match exactly.
        assert!(g
            .interpolate(GridCoord { total_hosts: 16, n_app: 5, chunk: 1024, replication: 1 })
            .is_none());
        assert!(g
            .interpolate(GridCoord { total_hosts: 20, n_app: 5, chunk: 1024, replication: 2 })
            .is_none());
    }

    #[test]
    fn bilinear_across_chunk_lines() {
        let mut g = SurrogateGrid::new();
        g.note(coord(2, 256), 120.0);
        g.note(coord(8, 256), 60.0);
        g.note(coord(2, 4096), 100.0);
        g.note(coord(8, 4096), 40.0);
        // Chunk 1024 is the log-midpoint of 256..4096.
        let e = g.interpolate(coord(5, 1024)).unwrap();
        let lo = 90.0; // midpoint of the 256 line at n_app 5
        let hi = 70.0; // midpoint of the 4096 line at n_app 5
        assert!((e.time_s - (lo + hi) / 2.0).abs() < 1e-9, "{}", e.time_s);
        assert!(e.est_err > 0.0);
    }

    #[test]
    fn flat_lines_report_small_error_steep_lines_large() {
        let mut g = SurrogateGrid::new();
        g.note(coord(2, 1024), 50.0);
        g.note(coord(8, 1024), 51.0);
        let flat = g.interpolate(coord(5, 1024)).unwrap();
        assert!(flat.est_err < 0.05, "{}", flat.est_err);
        let mut s = SurrogateGrid::new();
        s.note(coord(2, 1024), 500.0);
        s.note(coord(8, 1024), 50.0);
        let steep = s.interpolate(coord(5, 1024)).unwrap();
        assert!(steep.est_err > 1.0, "{}", steep.est_err);
    }
}
