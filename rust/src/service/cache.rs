//! Sharded in-memory LRU cache of full [`Prediction`]s.
//!
//! Shards bound lock contention when many serving threads hit the cache
//! concurrently (the fingerprint's mixed high word picks the shard, so
//! shard load is uniform). Within a shard, recency is an intrusive
//! doubly-linked list threaded through a slot arena (indices, not
//! pointers): a hit unlinks its node and relinks it at the head, eviction
//! pops the tail — both O(1), independent of shard size, so the cache
//! stays cheap at the 10⁵+-entry capacities fleet-wide campaigns want
//! (the previous min-scan eviction was O(shard size) per insert).

use super::fingerprint::Fingerprint;
use crate::predict::Prediction;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

pub const DEFAULT_SHARDS: usize = 16;

/// Monotonic per-shard probe counters. Each shard mutates its own copy
/// under the shard lock it already holds (no extra atomics on the hot
/// path); [`ShardedLru::counters`] sums them for the serving-tier stats
/// line.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheCounters {
    /// Probes that found their fingerprint in the shard.
    pub hits: u64,
    /// Probes that found nothing.
    pub misses: u64,
    /// Entries displaced to make room (refreshing an existing key never
    /// counts — it evicts nothing).
    pub evictions: u64,
}

impl CacheCounters {
    fn add(&mut self, o: &CacheCounters) {
        self.hits += o.hits;
        self.misses += o.misses;
        self.evictions += o.evictions;
    }
}

/// Vacant link slot.
const NIL: u32 = u32::MAX;

/// A recency-list node in the slot arena. `prev` is toward the
/// most-recently-used end (the head), `next` toward the eviction end.
struct Node {
    fp: Fingerprint,
    value: Arc<Prediction>,
    prev: u32,
    next: u32,
}

struct Shard {
    map: HashMap<Fingerprint, u32>,
    nodes: Vec<Option<Node>>,
    free: Vec<u32>,
    /// Most recently used (NIL when empty).
    head: u32,
    /// Least recently used — the eviction victim (NIL when empty).
    tail: u32,
    stats: CacheCounters,
}

impl Default for Shard {
    fn default() -> Shard {
        Shard {
            map: HashMap::new(),
            nodes: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            stats: CacheCounters::default(),
        }
    }
}

impl Shard {
    fn node(&self, i: u32) -> &Node {
        self.nodes[i as usize].as_ref().expect("linked slot is occupied")
    }

    fn node_mut(&mut self, i: u32) -> &mut Node {
        self.nodes[i as usize].as_mut().expect("linked slot is occupied")
    }

    /// Detach `i` from the recency list (its links become dangling; the
    /// caller relinks or frees it).
    fn unlink(&mut self, i: u32) {
        let (prev, next) = {
            let n = self.node(i);
            (n.prev, n.next)
        };
        match prev {
            NIL => self.head = next,
            p => self.node_mut(p).next = next,
        }
        match next {
            NIL => self.tail = prev,
            x => self.node_mut(x).prev = prev,
        }
    }

    /// Link `i` at the most-recently-used end.
    fn push_front(&mut self, i: u32) {
        let old = self.head;
        {
            let n = self.node_mut(i);
            n.prev = NIL;
            n.next = old;
        }
        match old {
            NIL => self.tail = i,
            h => self.node_mut(h).prev = i,
        }
        self.head = i;
    }

    /// Mark `i` as just used.
    fn touch(&mut self, i: u32) {
        if self.head != i {
            self.unlink(i);
            self.push_front(i);
        }
    }

    /// Evict the least-recently-used entry (no-op on an empty shard).
    fn evict_tail(&mut self) {
        let i = self.tail;
        if i == NIL {
            return;
        }
        self.unlink(i);
        let n = self.nodes[i as usize].take().expect("tail slot is occupied");
        self.map.remove(&n.fp);
        self.free.push(i);
        self.stats.evictions += 1;
    }

    /// Place a brand-new node at the MRU position, reusing a free slot.
    fn insert_front(&mut self, fp: Fingerprint, value: Arc<Prediction>) {
        let node = Node { fp, value, prev: NIL, next: NIL };
        let i = match self.free.pop() {
            Some(i) => {
                debug_assert!(self.nodes[i as usize].is_none(), "free-list slot in use");
                self.nodes[i as usize] = Some(node);
                i
            }
            None => {
                let i = self.nodes.len() as u32;
                self.nodes.push(Some(node));
                i
            }
        };
        self.push_front(i);
        self.map.insert(fp, i);
    }
}

/// The sharded LRU.
pub struct ShardedLru {
    shards: Vec<Mutex<Shard>>,
    per_shard_capacity: usize,
}

impl ShardedLru {
    /// `capacity` is the total entry budget, split evenly across
    /// [`DEFAULT_SHARDS`] shards.
    pub fn new(capacity: usize) -> ShardedLru {
        ShardedLru::with_shards(capacity, DEFAULT_SHARDS)
    }

    pub fn with_shards(capacity: usize, shards: usize) -> ShardedLru {
        let shards = shards.max(1);
        ShardedLru {
            per_shard_capacity: capacity.div_ceil(shards).max(1),
            shards: (0..shards).map(|_| Mutex::new(Shard::default())).collect(),
        }
    }

    /// Shard locks shrug off poisoning: a panic elsewhere while a guard
    /// was held (the cache is process-wide in `serve`) must not turn
    /// every later request into a panic. Mutations keep the map and the
    /// recency list consistent at every await-free step, so the state
    /// behind a poisoned lock is still well-formed.
    fn shard(&self, fp: &Fingerprint) -> std::sync::MutexGuard<'_, Shard> {
        self.shards[fp.shard(self.shards.len())].lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn get(&self, fp: &Fingerprint) -> Option<Arc<Prediction>> {
        let mut s = self.shard(fp);
        let i = match s.map.get(fp) {
            Some(&i) => i,
            None => {
                s.stats.misses += 1;
                return None;
            }
        };
        s.stats.hits += 1;
        s.touch(i);
        Some(s.node(i).value.clone())
    }

    pub fn insert(&self, fp: Fingerprint, value: Arc<Prediction>) {
        let mut s = self.shard(&fp);
        if let Some(&i) = s.map.get(&fp) {
            // Refresh in place: overwriting an existing key must not evict
            // a neighbor.
            s.node_mut(i).value = value;
            s.touch(i);
            return;
        }
        if s.map.len() >= self.per_shard_capacity {
            s.evict_tail();
        }
        s.insert_front(fp, value);
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap_or_else(|e| e.into_inner()).map.len()).sum()
    }

    /// Sum of the per-shard probe counters (hit/miss/evict).
    pub fn counters(&self) -> CacheCounters {
        let mut total = CacheCounters::default();
        for s in &self.shards {
            total.add(&s.lock().unwrap_or_else(|e| e.into_inner()).stats);
        }
        total
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Config, Platform};
    use crate::predict::Predictor;
    use crate::util::units::Bytes;
    use crate::workload::{FileSpec, TaskSpec, Workload};

    fn pred() -> Arc<Prediction> {
        let mut w = Workload::new("c");
        let a = w.add_file(FileSpec::new("in", Bytes::mb(1)).prestaged());
        let b = w.add_file(FileSpec::new("out", Bytes::mb(1)));
        w.add_task(TaskSpec::new("t", 0).reads(a).writes(b));
        Arc::new(Predictor::new(Platform::paper_testbed()).predict(&w, &Config::dss(3)))
    }

    fn fp(i: u64) -> Fingerprint {
        Fingerprint { hi: i, lo: !i }
    }

    #[test]
    fn get_after_insert() {
        let c = ShardedLru::new(8);
        assert!(c.is_empty());
        assert!(c.get(&fp(1)).is_none());
        let p = pred();
        c.insert(fp(1), p.clone());
        assert_eq!(c.len(), 1);
        let got = c.get(&fp(1)).unwrap();
        assert_eq!(got.turnaround, p.turnaround);
    }

    #[test]
    fn evicts_least_recently_used_within_a_shard() {
        // One shard so every key contends for the same capacity.
        let c = ShardedLru::with_shards(2, 1);
        let p = pred();
        c.insert(fp(1), p.clone());
        c.insert(fp(2), p.clone());
        assert!(c.get(&fp(1)).is_some(), "touch 1 so 2 becomes the LRU victim");
        c.insert(fp(3), p.clone());
        assert_eq!(c.len(), 2);
        assert!(c.get(&fp(1)).is_some());
        assert!(c.get(&fp(2)).is_none(), "2 was least recently used");
        assert!(c.get(&fp(3)).is_some());
    }

    #[test]
    fn reinsert_does_not_evict() {
        let c = ShardedLru::with_shards(2, 1);
        let p = pred();
        c.insert(fp(1), p.clone());
        c.insert(fp(2), p.clone());
        c.insert(fp(2), p.clone());
        assert_eq!(c.len(), 2, "overwriting an existing key must not evict a neighbor");
    }

    #[test]
    fn eviction_order_survives_interleaved_hits() {
        // The intrusive list must track recency through an arbitrary
        // get/insert interleaving, including slot reuse after evictions.
        let c = ShardedLru::with_shards(3, 1);
        let p = pred();
        c.insert(fp(1), p.clone());
        c.insert(fp(2), p.clone());
        c.insert(fp(3), p.clone()); // MRU→LRU: 3 2 1
        assert!(c.get(&fp(1)).is_some()); // 1 3 2
        assert!(c.get(&fp(2)).is_some()); // 2 1 3
        c.insert(fp(4), p.clone()); // evicts 3 → 4 2 1
        assert!(c.get(&fp(3)).is_none(), "3 was the LRU at insert(4)");
        c.insert(fp(5), p.clone()); // evicts 1 → 5 4 2
        assert!(c.get(&fp(1)).is_none(), "1 was the LRU at insert(5)");
        assert_eq!(c.len(), 3);
        for k in [2u64, 4, 5] {
            assert!(c.get(&fp(k)).is_some(), "{k} must have survived");
        }
        // The verification gets reordered recency to 5 4 2. One more
        // round on recycled slots: rescue the current LRU, then displace.
        assert!(c.get(&fp(2)).is_some()); // 2 5 4
        c.insert(fp(6), p.clone()); // evicts 4
        assert!(c.get(&fp(4)).is_none(), "4 was the LRU after 2 was touched");
        assert!(c.get(&fp(2)).is_some());
        assert!(c.get(&fp(5)).is_some());
        assert!(c.get(&fp(6)).is_some());
    }

    #[test]
    fn counters_track_hits_misses_and_evictions() {
        let c = ShardedLru::with_shards(2, 1);
        let p = pred();
        assert_eq!(c.counters(), CacheCounters::default());
        assert!(c.get(&fp(1)).is_none()); // miss
        c.insert(fp(1), p.clone());
        c.insert(fp(2), p.clone());
        assert!(c.get(&fp(1)).is_some()); // hit
        c.insert(fp(2), p.clone()); // refresh: no eviction
        c.insert(fp(3), p.clone()); // evicts 2 (LRU after 1 was touched)
        assert!(c.get(&fp(2)).is_none()); // miss
        let s = c.counters();
        assert_eq!(s, CacheCounters { hits: 1, misses: 2, evictions: 1 });
    }

    #[test]
    fn single_entry_shard_churn() {
        // head == tail edge cases: repeated insert/evict on capacity 1.
        let c = ShardedLru::with_shards(1, 1);
        let p = pred();
        for k in 0..10u64 {
            c.insert(fp(k), p.clone());
            assert_eq!(c.len(), 1);
            assert!(c.get(&fp(k)).is_some());
            if k > 0 {
                assert!(c.get(&fp(k - 1)).is_none());
            }
        }
    }
}
