//! Sharded in-memory LRU cache of full [`Prediction`]s.
//!
//! Shards bound lock contention when many serving threads hit the cache
//! concurrently (the fingerprint's mixed high word picks the shard, so
//! shard load is uniform). Within a shard, recency is a monotonic tick
//! per access and eviction scans for the minimum — O(shard size), which
//! at the default capacity (a few hundred entries per shard) is far
//! cheaper than the simulations the cache is saving, and avoids an
//! intrusive-list implementation the crate would have to maintain.

use super::fingerprint::Fingerprint;
use crate::predict::Prediction;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

pub const DEFAULT_SHARDS: usize = 16;

struct Entry {
    value: Arc<Prediction>,
    last_used: u64,
}

#[derive(Default)]
struct Shard {
    map: HashMap<Fingerprint, Entry>,
    tick: u64,
}

/// The sharded LRU.
pub struct ShardedLru {
    shards: Vec<Mutex<Shard>>,
    per_shard_capacity: usize,
}

impl ShardedLru {
    /// `capacity` is the total entry budget, split evenly across
    /// [`DEFAULT_SHARDS`] shards.
    pub fn new(capacity: usize) -> ShardedLru {
        ShardedLru::with_shards(capacity, DEFAULT_SHARDS)
    }

    pub fn with_shards(capacity: usize, shards: usize) -> ShardedLru {
        let shards = shards.max(1);
        ShardedLru {
            per_shard_capacity: capacity.div_ceil(shards).max(1),
            shards: (0..shards).map(|_| Mutex::new(Shard::default())).collect(),
        }
    }

    fn shard(&self, fp: &Fingerprint) -> &Mutex<Shard> {
        &self.shards[fp.shard(self.shards.len())]
    }

    pub fn get(&self, fp: &Fingerprint) -> Option<Arc<Prediction>> {
        let mut s = self.shard(fp).lock().unwrap();
        s.tick += 1;
        let tick = s.tick;
        let e = s.map.get_mut(fp)?;
        e.last_used = tick;
        Some(e.value.clone())
    }

    pub fn insert(&self, fp: Fingerprint, value: Arc<Prediction>) {
        let mut s = self.shard(&fp).lock().unwrap();
        s.tick += 1;
        let tick = s.tick;
        if !s.map.contains_key(&fp) && s.map.len() >= self.per_shard_capacity {
            let victim = s.map.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| *k);
            if let Some(victim) = victim {
                s.map.remove(&victim);
            }
        }
        s.map.insert(fp, Entry { value, last_used: tick });
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Config, Platform};
    use crate::predict::Predictor;
    use crate::util::units::Bytes;
    use crate::workload::{FileSpec, TaskSpec, Workload};

    fn pred() -> Arc<Prediction> {
        let mut w = Workload::new("c");
        let a = w.add_file(FileSpec::new("in", Bytes::mb(1)).prestaged());
        let b = w.add_file(FileSpec::new("out", Bytes::mb(1)));
        w.add_task(TaskSpec::new("t", 0).reads(a).writes(b));
        Arc::new(Predictor::new(Platform::paper_testbed()).predict(&w, &Config::dss(3)))
    }

    fn fp(i: u64) -> Fingerprint {
        Fingerprint { hi: i, lo: !i }
    }

    #[test]
    fn get_after_insert() {
        let c = ShardedLru::new(8);
        assert!(c.is_empty());
        assert!(c.get(&fp(1)).is_none());
        let p = pred();
        c.insert(fp(1), p.clone());
        assert_eq!(c.len(), 1);
        let got = c.get(&fp(1)).unwrap();
        assert_eq!(got.turnaround, p.turnaround);
    }

    #[test]
    fn evicts_least_recently_used_within_a_shard() {
        // One shard so every key contends for the same capacity.
        let c = ShardedLru::with_shards(2, 1);
        let p = pred();
        c.insert(fp(1), p.clone());
        c.insert(fp(2), p.clone());
        assert!(c.get(&fp(1)).is_some(), "touch 1 so 2 becomes the LRU victim");
        c.insert(fp(3), p.clone());
        assert_eq!(c.len(), 2);
        assert!(c.get(&fp(1)).is_some());
        assert!(c.get(&fp(2)).is_none(), "2 was least recently used");
        assert!(c.get(&fp(3)).is_some());
    }

    #[test]
    fn reinsert_does_not_evict() {
        let c = ShardedLru::with_shards(2, 1);
        let p = pred();
        c.insert(fp(1), p.clone());
        c.insert(fp(2), p.clone());
        c.insert(fp(2), p.clone());
        assert_eq!(c.len(), 2, "overwriting an existing key must not evict a neighbor");
    }
}
