//! Spawn a whole store (manager + storage nodes) in-process on loopback —
//! the deployment harness for tests, system identification, and the
//! end-to-end example.

use crate::store::client::StoreClient;
use crate::store::manager::Manager;
use crate::store::node::StorageNode;
use anyhow::Result;

/// A running cluster. Dropping it shuts everything down.
pub struct Cluster {
    pub manager: Manager,
    pub nodes: Vec<StorageNode>,
}

impl Cluster {
    /// Start a manager and `n` storage nodes.
    pub fn start(n: usize) -> Result<Cluster> {
        let manager = Manager::start()?;
        let nodes: Result<Vec<StorageNode>> =
            (0..n).map(|_| StorageNode::start(&manager.addr)).collect();
        Ok(Cluster { manager, nodes: nodes? })
    }

    /// A new client connected to this cluster.
    pub fn client(&self) -> Result<StoreClient> {
        StoreClient::connect(&self.manager.addr)
    }

    /// Total bytes stored across all nodes.
    pub fn stored_total(&self) -> u64 {
        self.nodes.iter().map(|n| n.stored_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_starts_and_registers() {
        let cl = Cluster::start(4).unwrap();
        assert_eq!(cl.manager.node_count(), 4);
        let c = cl.client().unwrap();
        assert_eq!(c.n_nodes(), 4);
    }
}
