//! A storage node: stores chunks in RAM (the paper's RAMdisk-backed
//! deployment) and implements chained replication — "the storage component
//! is responsible for storing and replicating data chunks" (§2.3).

use crate::store::wire::{self, op, Dec, Enc};
use anyhow::Result;
use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type ChunkKey = (String, u32);

#[derive(Default)]
struct Store {
    chunks: HashMap<ChunkKey, Vec<u8>>,
    bytes: u64,
}

/// Handle to a running storage node.
pub struct StorageNode {
    pub addr: String,
    pub id: u32,
    store: Arc<Mutex<Store>>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl StorageNode {
    /// Start a node on an ephemeral port and register with the manager.
    pub fn start(manager_addr: &str) -> Result<StorageNode> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();

        // Register with the manager.
        let mut m = TcpStream::connect(manager_addr)?;
        m.set_nodelay(true)?;
        let resp = wire::call(&mut m, Enc::new(op::REGISTER).str(&addr).finish())?;
        let id = Dec::new(&resp[1..]).u32()?;

        let store = Arc::new(Mutex::new(Store::default()));
        let stop = Arc::new(AtomicBool::new(false));
        let (store2, stop2) = (store.clone(), stop.clone());
        let accept_thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let st = store2.clone();
                        std::thread::spawn(move || serve_conn(stream, st));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(StorageNode { addr, id, store, stop, accept_thread: Some(accept_thread) })
    }

    /// Bytes currently stored (the §2.4 "storage used" report).
    pub fn stored_bytes(&self) -> u64 {
        self.store.lock().unwrap().bytes
    }

    pub fn chunk_count(&self) -> usize {
        self.store.lock().unwrap().chunks.len()
    }
}

impl Drop for StorageNode {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(&self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn serve_conn(mut stream: TcpStream, store: Arc<Mutex<Store>>) {
    let _ = stream.set_nodelay(true);
    loop {
        let msg = match wire::read_msg(&mut stream) {
            Ok(m) => m,
            Err(_) => return,
        };
        let resp = handle(&msg, &store).unwrap_or_else(|e| wire::err_resp(&e.to_string()));
        if wire::write_msg(&mut stream, &resp).is_err() {
            return;
        }
    }
}

fn handle(msg: &[u8], store: &Arc<Mutex<Store>>) -> Result<Vec<u8>> {
    let opcode = msg[0];
    let mut d = Dec::new(&msg[1..]);
    match opcode {
        op::PUT => {
            // file, chunk_idx, chain (addrs of remaining replicas), data
            let file = d.str()?;
            let chunk = d.u32()?;
            let n_chain = d.u32()? as usize;
            let chain: Vec<String> = (0..n_chain).map(|_| d.str()).collect::<Result<_>>()?;
            let data = d.bytes()?.to_vec();
            {
                let mut st = store.lock().unwrap();
                st.bytes += data.len() as u64;
                st.chunks.insert((file.clone(), chunk), data.clone());
            }
            // Chained replication: forward before acking, so the ack means
            // the whole chain stored (same semantics the model simulates).
            if let Some((next, rest)) = chain.split_first() {
                let mut s = TcpStream::connect(next)?;
                s.set_nodelay(true)?;
                let mut e = Enc::new(op::PUT).str(&file).u32(chunk).u32(rest.len() as u32);
                for r in rest {
                    e = e.str(r);
                }
                wire::call(&mut s, e.bytes(&data).finish())?;
            }
            Ok(Enc::new(op::PUT).finish())
        }
        op::GET => {
            let file = d.str()?;
            let chunk = d.u32()?;
            let st = store.lock().unwrap();
            let data = st
                .chunks
                .get(&(file.clone(), chunk))
                .ok_or_else(|| anyhow::anyhow!("no chunk {chunk} of {file}"))?;
            Ok(Enc::new(op::GET).bytes(data).finish())
        }
        op::PING => {
            let payload = d.bytes()?;
            Ok(Enc::new(op::PING).bytes(payload).finish())
        }
        o => anyhow::bail!("storage: bad opcode {o}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::manager::Manager;
    use crate::store::wire::call;

    #[test]
    fn put_get_roundtrip() {
        let m = Manager::start().unwrap();
        let n = StorageNode::start(&m.addr).unwrap();
        let mut c = TcpStream::connect(&n.addr).unwrap();
        let data = vec![42u8; 1 << 16];
        call(&mut c, Enc::new(op::PUT).str("f").u32(0).u32(0).bytes(&data).finish()).unwrap();
        let r = call(&mut c, Enc::new(op::GET).str("f").u32(0).finish()).unwrap();
        assert_eq!(Dec::new(&r[1..]).bytes().unwrap(), &data[..]);
        assert_eq!(n.stored_bytes(), 1 << 16);
    }

    #[test]
    fn chained_replication_stores_on_all() {
        let m = Manager::start().unwrap();
        let n1 = StorageNode::start(&m.addr).unwrap();
        let n2 = StorageNode::start(&m.addr).unwrap();
        let n3 = StorageNode::start(&m.addr).unwrap();
        let mut c = TcpStream::connect(&n1.addr).unwrap();
        let data = vec![7u8; 1000];
        call(
            &mut c,
            Enc::new(op::PUT).str("f").u32(3).u32(2).str(&n2.addr).str(&n3.addr).bytes(&data).finish(),
        )
        .unwrap();
        assert_eq!(n1.stored_bytes(), 1000);
        assert_eq!(n2.stored_bytes(), 1000);
        assert_eq!(n3.stored_bytes(), 1000);
    }

    #[test]
    fn missing_chunk_errors() {
        let m = Manager::start().unwrap();
        let n = StorageNode::start(&m.addr).unwrap();
        let mut c = TcpStream::connect(&n.addr).unwrap();
        assert!(call(&mut c, Enc::new(op::GET).str("ghost").u32(0).finish()).is_err());
    }
}
