//! The client-side system access interface (SAI): "implements data access
//! protocols after they interact with the manager that stores data
//! placement information" (§2.2). Whole-file writes and reads, chunked,
//! striped, with chained replication — the same state machine the model
//! simulates.

use crate::store::wire::{self, op, Dec, Enc};
use crate::store::StorePlacement;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::Arc;

/// A connected store client.
pub struct StoreClient {
    manager: TcpStream,
    /// node_id → address. Shared `Arc<str>`s: the write path hands out
    /// one address per forwarded replica per chunk, which must not cost
    /// a fresh `String` allocation each time (replication degree × chunk
    /// count adds up on large files).
    node_addrs: Vec<Arc<str>>,
    /// Pooled data connections, one per storage node.
    node_conns: HashMap<u32, TcpStream>,
    pub chunk_size: u64,
    pub replication: u32,
    pub placement: StorePlacement,
}

impl StoreClient {
    pub fn connect(manager_addr: &str) -> Result<StoreClient> {
        let manager = TcpStream::connect(manager_addr).context("connecting to manager")?;
        manager.set_nodelay(true)?;
        let mut manager = manager;
        let resp = wire::call(&mut manager, Enc::new(op::NODES).finish())?;
        let mut d = Dec::new(&resp[1..]);
        let n = d.u32()?;
        let node_addrs: Vec<Arc<str>> =
            (0..n).map(|_| d.str().map(Arc::from)).collect::<Result<_>>()?;
        Ok(StoreClient {
            manager,
            node_addrs,
            node_conns: HashMap::new(),
            chunk_size: 1 << 20,
            replication: 1,
            placement: StorePlacement::RoundRobin { stripe: n.max(1) },
        })
    }

    pub fn with_chunk_size(mut self, c: u64) -> StoreClient {
        self.chunk_size = c;
        self
    }
    pub fn with_replication(mut self, r: u32) -> StoreClient {
        self.replication = r;
        self
    }
    pub fn with_placement(mut self, p: StorePlacement) -> StoreClient {
        self.placement = p;
        self
    }

    pub fn n_nodes(&self) -> usize {
        self.node_addrs.len()
    }

    fn node_conn(&mut self, id: u32) -> Result<&mut TcpStream> {
        if !self.node_conns.contains_key(&id) {
            let addr = self
                .node_addrs
                .get(id as usize)
                .ok_or_else(|| anyhow::anyhow!("unknown node {id}"))?;
            let s = TcpStream::connect(&**addr)
                .with_context(|| format!("connecting to node {id}"))?;
            s.set_nodelay(true)?;
            self.node_conns.insert(id, s);
        }
        Ok(self.node_conns.get_mut(&id).unwrap())
    }

    /// Write a whole file: alloc → chunk puts (chained replication) →
    /// commit. Returns per-chunk replica groups.
    pub fn write(&mut self, name: &str, data: &[u8]) -> Result<Vec<Vec<u32>>> {
        let (ptag, parg) = match self.placement {
            StorePlacement::RoundRobin { stripe } => (0u8, stripe),
            StorePlacement::OnNode { node } => (1u8, node),
        };
        let resp = wire::call(
            &mut self.manager,
            Enc::new(op::ALLOC)
                .str(name)
                .u64(data.len() as u64)
                .u64(self.chunk_size)
                .u32(self.replication)
                .u8(ptag)
                .u32(parg)
                .finish(),
        )?;
        let mut d = Dec::new(&resp[1..]);
        let n_chunks = d.u32()? as usize;
        let groups: Vec<Vec<u32>> = (0..n_chunks).map(|_| d.u32_list()).collect::<Result<_>>()?;

        for (i, group) in groups.iter().enumerate() {
            let lo = i * self.chunk_size as usize;
            let hi = ((i + 1) * self.chunk_size as usize).min(data.len());
            let chunk = &data[lo.min(data.len())..hi];
            let primary = group[0];
            // Forwarding chain: encode the shared addresses straight into
            // the wire body — no per-replica String clones.
            let rest = &group[1..];
            let mut e = Enc::new(op::PUT).str(name).u32(i as u32).u32(rest.len() as u32);
            for &g in rest {
                e = e.str(&self.node_addrs[g as usize]);
            }
            let body = e.bytes(chunk).finish();
            let conn = self.node_conn(primary)?;
            wire::call(conn, body)?;
        }

        wire::call(&mut self.manager, Enc::new(op::COMMIT).str(name).finish())?;
        Ok(groups)
    }

    /// Read a whole file: lookup → chunk gets. The replica for each chunk
    /// is chosen round-robin; on a node failure (connect or request
    /// error) the client fails over to the remaining replicas — the
    /// availability story replication buys (§2.2 "replication is often
    /// used to increase reliability").
    pub fn read(&mut self, name: &str) -> Result<Vec<u8>> {
        let resp = wire::call(&mut self.manager, Enc::new(op::LOOKUP).str(name).finish())?;
        let mut d = Dec::new(&resp[1..]);
        let size = d.u64()? as usize;
        let _chunk_size = d.u64()?;
        let n_chunks = d.u32()? as usize;
        let groups: Vec<Vec<u32>> = (0..n_chunks).map(|_| d.u32_list()).collect::<Result<_>>()?;

        let mut out = Vec::with_capacity(size);
        for (i, group) in groups.iter().enumerate() {
            let body = Enc::new(op::GET).str(name).u32(i as u32).finish();
            let mut last_err: Option<anyhow::Error> = None;
            let mut got = false;
            // Try each replica, starting at the round-robin choice.
            for k in 0..group.len() {
                let src = group[(i + k) % group.len()];
                let attempt = self
                    .node_conn(src)
                    .and_then(|conn| wire::call(conn, body.clone()));
                match attempt {
                    Ok(r) => {
                        out.extend_from_slice(Dec::new(&r[1..]).bytes()?);
                        got = true;
                        break;
                    }
                    Err(e) => {
                        // Drop the (possibly broken) pooled connection so a
                        // later attempt reconnects fresh.
                        self.node_conns.remove(&src);
                        last_err = Some(e);
                    }
                }
            }
            if !got {
                return Err(last_err
                    .unwrap_or_else(|| anyhow::anyhow!("no replicas for chunk {i}"))
                    .context(format!("chunk {i} of {name}: all replicas failed")));
            }
        }
        anyhow::ensure!(out.len() == size, "read {} bytes, metadata says {size}", out.len());
        Ok(out)
    }

    /// A 0-size write+read pair — the paper's §2.5 trick to isolate
    /// manager cost ("a request to go through the manager, but it does
    /// not touch the storage module").
    pub fn zero_size_op(&mut self, name: &str) -> Result<()> {
        self.write(name, &[])?;
        let back = self.read(name)?;
        anyhow::ensure!(back.is_empty());
        Ok(())
    }

    /// Echo `payload` off a storage node — the iperf-style network probe.
    pub fn ping_node(&mut self, id: u32, payload: &[u8]) -> Result<usize> {
        let body = Enc::new(op::PING).bytes(payload).finish();
        let conn = self.node_conn(id)?;
        let r = wire::call(conn, body)?;
        Ok(Dec::new(&r[1..]).bytes()?.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::cluster::Cluster;

    #[test]
    fn write_read_roundtrip_striped() {
        let cl = Cluster::start(3).unwrap();
        let mut c = cl.client().unwrap().with_chunk_size(4096);
        let data: Vec<u8> = (0..20_000u32).map(|i| (i % 251) as u8).collect();
        let groups = c.write("stripey", &data).unwrap();
        assert_eq!(groups.len(), 5, "20000/4096 -> 5 chunks");
        let back = c.read("stripey").unwrap();
        assert_eq!(back, data);
        // Chunks actually spread across nodes.
        let primaries: std::collections::HashSet<u32> = groups.iter().map(|g| g[0]).collect();
        assert!(primaries.len() > 1);
    }

    #[test]
    fn replicated_write_lands_on_replicas() {
        let cl = Cluster::start(3).unwrap();
        let mut c = cl.client().unwrap().with_chunk_size(1024).with_replication(2);
        let data = vec![9u8; 3000];
        c.write("dup", &data).unwrap();
        let total: u64 = cl.nodes.iter().map(|n| n.stored_bytes()).sum();
        assert_eq!(total, 6000, "every byte stored twice");
        assert_eq!(c.read("dup").unwrap(), data);
    }

    #[test]
    fn onnode_placement() {
        let cl = Cluster::start(3).unwrap();
        let mut c = cl
            .client()
            .unwrap()
            .with_chunk_size(1024)
            .with_placement(StorePlacement::OnNode { node: 1 });
        c.write("pinned", &vec![1u8; 5000]).unwrap();
        assert_eq!(cl.nodes[1].stored_bytes(), 5000);
        assert_eq!(cl.nodes[0].stored_bytes(), 0);
        assert_eq!(cl.nodes[2].stored_bytes(), 0);
    }

    #[test]
    fn zero_size_ops_work() {
        let cl = Cluster::start(2).unwrap();
        let mut c = cl.client().unwrap();
        c.zero_size_op("empty").unwrap();
    }

    #[test]
    fn read_unknown_file_errors() {
        let cl = Cluster::start(1).unwrap();
        let mut c = cl.client().unwrap();
        assert!(c.read("nope").is_err());
    }
}
