//! Wire protocol: length-prefixed binary messages with hand-rolled
//! encoding (no serde offline).
//!
//! Frame layout: `[u32 big-endian length][u8 opcode][body …]`.
//! Bodies are built/parsed with [`Enc`]/[`Dec`]; all integers big-endian,
//! strings and blobs length-prefixed.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;

/// Opcodes. Requests and responses share the numbering; a response to op
/// X carries opcode X with `ok`/payload in the body.
pub mod op {
    // manager
    pub const REGISTER: u8 = 1; // storage node announces itself
    pub const ALLOC: u8 = 2; // client requests write targets
    pub const COMMIT: u8 = 3; // client commits a write
    pub const LOOKUP: u8 = 4; // client resolves a file's chunk map
    pub const NODES: u8 = 5; // client fetches node_id → addr table
    // storage
    pub const PUT: u8 = 16; // store one chunk (with replica chain)
    pub const GET: u8 = 17; // fetch one chunk
    pub const PING: u8 = 18; // echo (network probe)
    // generic
    pub const ERR: u8 = 255;
}

/// Max message size we accept (1 GB guards against corrupt frames).
pub const MAX_MSG: u32 = 1 << 30;

/// Append-only body encoder.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new(opcode: u8) -> Enc {
        Enc { buf: vec![opcode] }
    }
    pub fn u8(mut self, x: u8) -> Enc {
        self.buf.push(x);
        self
    }
    pub fn u32(mut self, x: u32) -> Enc {
        self.buf.extend_from_slice(&x.to_be_bytes());
        self
    }
    pub fn u64(mut self, x: u64) -> Enc {
        self.buf.extend_from_slice(&x.to_be_bytes());
        self
    }
    pub fn str(self, s: &str) -> Enc {
        self.bytes(s.as_bytes())
    }
    pub fn bytes(mut self, b: &[u8]) -> Enc {
        self.buf.extend_from_slice(&(b.len() as u32).to_be_bytes());
        self.buf.extend_from_slice(b);
        self
    }
    pub fn u32_list(mut self, xs: &[u32]) -> Enc {
        self.buf.extend_from_slice(&(xs.len() as u32).to_be_bytes());
        for &x in xs {
            self.buf.extend_from_slice(&x.to_be_bytes());
        }
        self
    }
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor-based body decoder.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated message: want {n} bytes at {}, have {}", self.pos, self.buf.len());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_be_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_be_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }
    pub fn str(&mut self) -> Result<String> {
        Ok(String::from_utf8(self.bytes()?.to_vec()).context("non-utf8 string")?)
    }
    pub fn u32_list(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        (0..n).map(|_| self.u32()).collect()
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Write one framed message.
pub fn write_msg(stream: &mut TcpStream, body: &[u8]) -> Result<()> {
    let len = body.len() as u32;
    debug_assert!(len <= MAX_MSG);
    stream.write_all(&len.to_be_bytes())?;
    stream.write_all(body)?;
    Ok(())
}

/// Read one framed message.
pub fn read_msg(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf).context("reading frame length")?;
    let len = u32::from_be_bytes(len_buf);
    if len > MAX_MSG {
        bail!("frame too large: {len}");
    }
    let mut body = vec![0u8; len as usize];
    stream.read_exact(&mut body).context("reading frame body")?;
    Ok(body)
}

/// Round-trip a request and parse the response; checks the opcode echoes.
pub fn call(stream: &mut TcpStream, body: Vec<u8>) -> Result<Vec<u8>> {
    let opcode = body[0];
    write_msg(stream, &body)?;
    let resp = read_msg(stream)?;
    if resp.is_empty() {
        bail!("empty response");
    }
    if resp[0] == op::ERR {
        let mut d = Dec::new(&resp[1..]);
        bail!("remote error: {}", d.str().unwrap_or_else(|_| "<garbled>".into()));
    }
    if resp[0] != opcode {
        bail!("opcode mismatch: sent {opcode}, got {}", resp[0]);
    }
    Ok(resp)
}

/// Build an error response.
pub fn err_resp(msg: &str) -> Vec<u8> {
    Enc::new(op::ERR).str(msg).finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enc_dec_roundtrip() {
        let body = Enc::new(op::ALLOC)
            .str("file.dat")
            .u64(123456789)
            .u32(7)
            .bytes(&[1, 2, 3])
            .u32_list(&[10, 20, 30])
            .finish();
        assert_eq!(body[0], op::ALLOC);
        let mut d = Dec::new(&body[1..]);
        assert_eq!(d.str().unwrap(), "file.dat");
        assert_eq!(d.u64().unwrap(), 123456789);
        assert_eq!(d.u32().unwrap(), 7);
        assert_eq!(d.bytes().unwrap(), &[1, 2, 3]);
        assert_eq!(d.u32_list().unwrap(), vec![10, 20, 30]);
        assert_eq!(d.remaining(), 0);
    }

    #[test]
    fn dec_rejects_truncation() {
        let body = Enc::new(op::GET).u64(1).finish();
        let mut d = Dec::new(&body[1..5]);
        assert!(d.u64().is_err());
    }

    #[test]
    fn framed_messages_over_socket() {
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let m = read_msg(&mut s).unwrap();
            write_msg(&mut s, &m).unwrap(); // echo
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let sent = Enc::new(op::PING).bytes(&vec![7u8; 100_000]).finish();
        let got = call(&mut c, sent.clone()).unwrap();
        assert_eq!(got, sent);
        server.join().unwrap();
    }
}
