//! A real distributed object store — the architecture of §2.2 over actual
//! TCP sockets.
//!
//! This is the in-tree stand-in for MosaStore: a centralized metadata
//! **manager**, RAM-backed **storage nodes**, and a client-side system
//! access interface (**SAI**) that stripes files into chunks, replicates
//! them (chained), and implements exactly the read/write protocols the
//! model simulates (alloc → chunk puts → commit; lookup → chunk gets).
//!
//! It exists for three reasons:
//! 1. **System identification** (paper §2.5) needs a real system to probe:
//!    `ident/` runs its throughput/0-size/read-write benchmarks against
//!    this store over loopback.
//! 2. **Protocol credibility**: the simulated protocol is the same state
//!    machine that demonstrably works over real sockets (`store_e2e`
//!    integration tests move real bytes).
//! 3. **End-to-end driver**: `examples/blast_provisioning.rs` replays a
//!    scaled-down BLAST workload against this store and compares wallclock
//!    against the predictor (§3.3's 200×–2000× resource claim).
//!
//! Deliberately synchronous: one OS thread per connection (tokio is not
//! available offline, and at 20-node scale threads are simpler and as
//! fast over loopback).

pub mod wire;
pub mod manager;
pub mod node;
pub mod client;
pub mod cluster;

pub use client::StoreClient;
pub use cluster::Cluster;

/// Placement policy requested by the client at alloc time (mirrors
/// [`crate::workload::FileHint`] + the system-wide default).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StorePlacement {
    /// Round-robin stripe of the given width.
    RoundRobin { stripe: u32 },
    /// All chunks on one node.
    OnNode { node: u32 },
}
