//! The metadata manager: "maintains the stored files' metadata and system
//! state … implements data placement policies by returning free chunks
//! when requested by write operations, and keeps track of file to chunk
//! mapping and chunk placement" (paper §2.4).

use crate::store::wire::{self, op, Dec, Enc};
use anyhow::Result;
use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Metadata of one file.
///
/// Placement is interned, structurally mirroring the model's
/// [`PlacementArena`](crate::model::placement::PlacementArena): instead
/// of one materialized replica-group `Vec` per chunk, the table keeps
/// the stripe primaries, the replication level, and the ring modulus
/// captured at allocation time. Chunk `i`'s replica group
/// `(stripe[i % stripe.len()] + k) % ring_mod` is derived on demand and
/// materialized only at the moment a wire response needs the explicit
/// chain — metadata cost per file is O(stripe), not O(chunks × repl).
#[derive(Clone, Debug, Default)]
struct FileMeta {
    size: u64,
    chunk_size: u64,
    n_chunks: u64,
    /// Stripe primaries (chunk `i` starts at `stripe[i % stripe.len()]`).
    stripe: Vec<u32>,
    /// Replication level (ring successors of the primary).
    repl: u32,
    /// Node count at allocation time — the replica-ring modulus. Later
    /// registrations must not change already-allocated placements.
    ring_mod: u32,
    committed: bool,
}

impl FileMeta {
    /// Materialize chunk `i`'s replica chain into `out` (wire encoding
    /// only; `out` is a reusable scratch buffer).
    fn fill_group(&self, i: u64, out: &mut Vec<u32>) {
        out.clear();
        let primary = self.stripe[(i % self.stripe.len() as u64) as usize];
        out.extend((0..self.repl).map(|k| (primary + k) % self.ring_mod));
    }

    /// Append every chunk's (derived) replica group to a wire response —
    /// one scratch buffer for the whole response, not one `Vec` per chunk.
    fn encode_groups(&self, mut e: Enc) -> Enc {
        let mut scratch = Vec::with_capacity(self.repl as usize);
        for i in 0..self.n_chunks {
            self.fill_group(i, &mut scratch);
            e = e.u32_list(&scratch);
        }
        e
    }
}

#[derive(Default)]
struct State {
    nodes: Vec<String>, // node_id -> addr
    files: HashMap<String, FileMeta>,
    rr_cursor: usize,
}

/// Handle to a running manager server.
pub struct Manager {
    pub addr: String,
    state: Arc<Mutex<State>>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl Manager {
    /// Start a manager on an ephemeral loopback port.
    pub fn start() -> Result<Manager> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let state = Arc::new(Mutex::new(State::default()));
        let stop = Arc::new(AtomicBool::new(false));
        let (state2, stop2) = (state.clone(), stop.clone());
        let accept_thread = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let st = state2.clone();
                        std::thread::spawn(move || serve_conn(stream, st));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Manager { addr, state, stop, accept_thread: Some(accept_thread) })
    }

    /// Number of registered storage nodes.
    pub fn node_count(&self) -> usize {
        self.state.lock().unwrap().nodes.len()
    }

    /// Stored-file names (diagnostics).
    pub fn file_names(&self) -> Vec<String> {
        self.state.lock().unwrap().files.keys().cloned().collect()
    }
}

impl Drop for Manager {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Poke the listener so accept() returns.
        let _ = TcpStream::connect(&self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn serve_conn(mut stream: TcpStream, state: Arc<Mutex<State>>) {
    let _ = stream.set_nodelay(true);
    loop {
        let msg = match wire::read_msg(&mut stream) {
            Ok(m) => m,
            Err(_) => return, // peer closed
        };
        let resp = handle(&msg, &state).unwrap_or_else(|e| wire::err_resp(&e.to_string()));
        if wire::write_msg(&mut stream, &resp).is_err() {
            return;
        }
    }
}

fn handle(msg: &[u8], state: &Arc<Mutex<State>>) -> Result<Vec<u8>> {
    let opcode = msg[0];
    let mut d = Dec::new(&msg[1..]);
    let mut st = state.lock().unwrap();
    match opcode {
        op::REGISTER => {
            let addr = d.str()?;
            let id = st.nodes.len() as u32;
            st.nodes.push(addr);
            Ok(Enc::new(op::REGISTER).u32(id).finish())
        }
        op::NODES => {
            let mut e = Enc::new(op::NODES).u32(st.nodes.len() as u32);
            for a in &st.nodes {
                e = e.str(a);
            }
            Ok(e.finish())
        }
        op::ALLOC => {
            // file, size, chunk_size, replication, placement{0:rr stripe | 1:onnode node}
            let file = d.str()?;
            let size = d.u64()?;
            let chunk_size = d.u64()?;
            let repl = d.u32()?.max(1);
            let ptag = d.u8()?;
            let parg = d.u32()?;
            let n = st.nodes.len() as u32;
            anyhow::ensure!(n > 0, "no storage nodes registered");
            anyhow::ensure!(repl <= n, "replication {repl} exceeds {n} nodes");
            if let Some(f) = st.files.get(&file) {
                anyhow::ensure!(!f.committed, "file {file} already committed (single-writer)");
            }
            let n_chunks = if size == 0 { 1 } else { size.div_ceil(chunk_size.max(1)) };
            let stripe: Vec<u32> = match ptag {
                0 => {
                    let w = parg.clamp(1, n);
                    let start = st.rr_cursor as u32 % n;
                    st.rr_cursor += 1;
                    (0..w).map(|k| (start + k) % n).collect()
                }
                1 => vec![parg % n],
                t => anyhow::bail!("bad placement tag {t}"),
            };
            let meta = FileMeta {
                size,
                chunk_size,
                n_chunks,
                stripe,
                repl,
                ring_mod: n,
                committed: false,
            };
            let e = meta.encode_groups(Enc::new(op::ALLOC).u32(n_chunks as u32));
            st.files.insert(file, meta);
            Ok(e.finish())
        }
        op::COMMIT => {
            let file = d.str()?;
            let f = st.files.get_mut(&file).ok_or_else(|| anyhow::anyhow!("unknown file {file}"))?;
            f.committed = true;
            Ok(Enc::new(op::COMMIT).finish())
        }
        op::LOOKUP => {
            let file = d.str()?;
            let f = st.files.get(&file).ok_or_else(|| anyhow::anyhow!("unknown file {file}"))?;
            anyhow::ensure!(f.committed, "file {file} not committed");
            let e = f.encode_groups(
                Enc::new(op::LOOKUP).u64(f.size).u64(f.chunk_size).u32(f.n_chunks as u32),
            );
            Ok(e.finish())
        }
        o => anyhow::bail!("manager: bad opcode {o}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::wire::call;

    #[test]
    fn register_and_alloc_roundrobin() {
        let m = Manager::start().unwrap();
        let mut c = TcpStream::connect(&m.addr).unwrap();
        for i in 0..3 {
            let r = call(&mut c, Enc::new(op::REGISTER).str(&format!("127.0.0.1:{}", 9000 + i)).finish()).unwrap();
            assert_eq!(Dec::new(&r[1..]).u32().unwrap(), i);
        }
        assert_eq!(m.node_count(), 3);

        // Alloc 5 chunks, stripe 2, repl 2.
        let r = call(
            &mut c,
            Enc::new(op::ALLOC).str("f").u64(5 << 20).u64(1 << 20).u32(2).u8(0).u32(2).finish(),
        )
        .unwrap();
        let mut d = Dec::new(&r[1..]);
        let n_chunks = d.u32().unwrap();
        assert_eq!(n_chunks, 5);
        let g0 = d.u32_list().unwrap();
        assert_eq!(g0.len(), 2, "replica group size");
        let g1 = d.u32_list().unwrap();
        assert_ne!(g0[0], g1[0], "stripe alternates primaries");
    }

    #[test]
    fn lookup_requires_commit() {
        let m = Manager::start().unwrap();
        let mut c = TcpStream::connect(&m.addr).unwrap();
        call(&mut c, Enc::new(op::REGISTER).str("x").finish()).unwrap();
        call(&mut c, Enc::new(op::ALLOC).str("f").u64(10).u64(1 << 20).u32(1).u8(0).u32(1).finish()).unwrap();
        assert!(call(&mut c, Enc::new(op::LOOKUP).str("f").finish()).is_err());
        call(&mut c, Enc::new(op::COMMIT).str("f").finish()).unwrap();
        let r = call(&mut c, Enc::new(op::LOOKUP).str("f").finish()).unwrap();
        let mut d = Dec::new(&r[1..]);
        assert_eq!(d.u64().unwrap(), 10);
    }

    #[test]
    fn double_write_rejected() {
        let m = Manager::start().unwrap();
        let mut c = TcpStream::connect(&m.addr).unwrap();
        call(&mut c, Enc::new(op::REGISTER).str("x").finish()).unwrap();
        let alloc =
            || Enc::new(op::ALLOC).str("f").u64(10).u64(1 << 20).u32(1).u8(0).u32(1).finish();
        call(&mut c, alloc()).unwrap();
        call(&mut c, Enc::new(op::COMMIT).str("f").finish()).unwrap();
        assert!(call(&mut c, alloc()).is_err(), "single-writer discipline");
    }

    #[test]
    fn onnode_placement_pins_chunks() {
        let m = Manager::start().unwrap();
        let mut c = TcpStream::connect(&m.addr).unwrap();
        for i in 0..4 {
            call(&mut c, Enc::new(op::REGISTER).str(&format!("n{i}")).finish()).unwrap();
        }
        let r = call(
            &mut c,
            Enc::new(op::ALLOC).str("f").u64(3 << 20).u64(1 << 20).u32(1).u8(1).u32(2).finish(),
        )
        .unwrap();
        let mut d = Dec::new(&r[1..]);
        let n = d.u32().unwrap();
        for _ in 0..n {
            assert_eq!(d.u32_list().unwrap(), vec![2]);
        }
    }
}
