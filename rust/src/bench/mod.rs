//! The prediction barometer: a declarative benchmark registry, runner,
//! record store, and gate DSL behind the `wfpred bench` subcommand.
//!
//! This module replaced the three ad-hoc bench binaries (`microbench`,
//! `figures`, `ablations`) with one registry-driven harness. The moving
//! parts, bottom-up:
//!
//! * [`record`] — [`record::CellRecord`]: one flat-JSON measurement
//!   record per cell per run, with every metric key a documented
//!   constant in [`record::keys`].
//! * [`gate`] — [`gate::Gate`]: absolute, drift (vs the cell's own armed
//!   baseline), and same-run cross-cell predicates.
//! * [`registry`] — [`registry::CellDef`]: the full cell matrix as data;
//!   `(workload × platform × fidelity/engine × fault-plan)` per cell,
//!   selected by name glob.
//! * [`runner`] — [`runner::run_cells`]: executes a selection, persists
//!   records + per-cell history under `results/records/`, and evaluates
//!   gates so a regression is reported *by cell name*.
//!
//! The narrative guide — cell taxonomy, record schema, gate semantics,
//! how to add a cell, how baselines arm — is `rust/METHODOLOGY.md`,
//! compiled into rustdoc below (so its links and examples are checked
//! under `RUSTDOCFLAGS="-D warnings"`; see [`methodology`]).

pub mod gate;
pub mod record;
pub mod registry;
pub mod runner;

pub use gate::{Gate, GateOutcome};
pub use record::CellRecord;
pub use registry::{glob_match, registry as cells, CellDef, CellKind};
pub use runner::{list_cells, run_cells, RunOptions, RunReport};

/// The benchmark methodology guide (`rust/METHODOLOGY.md`), verbatim.
///
/// Including it here makes the rustdoc build the guide's CI gate: broken
/// intra-doc links fail under `-D warnings`, and its `rust` code blocks
/// compile as doctests.
#[doc = include_str!("../../METHODOLOGY.md")]
pub mod methodology {}
