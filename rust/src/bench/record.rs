//! Flat, machine-readable benchmark records — one per cell per run.
//!
//! A [`CellRecord`] is the unit the whole barometer trades in: the runner
//! emits one per executed cell, `results/records/<cell>.json` holds the
//! armed baseline copy, `results/records/history/<cell>.jsonl` accumulates
//! one line per recorded run, and the gate DSL ([`crate::bench::gate`])
//! evaluates against them. Records are deliberately *flat* key → number
//! maps (plus three reserved string keys) so they round-trip through the
//! in-tree JSON substrate: [`crate::util::jsonw::Json::render_compact`]
//! on the way out, [`crate::util::jsonw::parse_flat`] on the way in.
//!
//! Every metric key that may appear in a record is a named constant in
//! [`keys`], and `rust/METHODOLOGY.md` documents each one; a unit test
//! below fails the build if a key constant is missing from the guide, so
//! the documented schema cannot drift from the code (the pre-records
//! incast table in `results/README.md` did exactly that).

use crate::util::jsonw::{parse_flat, Json, Scalar};

/// Reserved string key: the cell name (`suite.cell` taxonomy).
pub const FIELD_CELL: &str = "cell";
/// Reserved string key: engine provenance ([`crate::service::EngineId`]
/// label, or a derived label like `detailed-no_jitter` for ablation cells).
pub const FIELD_ENGINE: &str = "engine";
/// Reserved string key: run identity (`$GITHUB_SHA` in CI, `local` else).
pub const FIELD_RUN: &str = "run";

/// Every metric key a record may carry. Grouped by the cell kind that
/// emits it; see `rust/METHODOLOGY.md` § Record schema for semantics.
pub mod keys {
    /// Simulation events processed (mean over reps for stochastic engines).
    pub const EVENTS: &str = "events";
    /// Completion announcements cancelled before firing (mean over reps).
    pub const EVENTS_CANCELLED: &str = "events_cancelled";
    /// `events_cancelled / (events + events_cancelled)`.
    pub const STALE_EVENT_RATIO: &str = "stale_event_ratio";
    /// Simulated turnaround in seconds (mean over reps).
    pub const SIM_TURNAROUND_S: &str = "sim_turnaround_s";
    /// Mean wallclock per rep (host-dependent; never drift-gated).
    pub const WALL_SECS: &str = "wall_secs";
    /// Min wallclock over reps — the least-interference estimator used by
    /// same-run ratio gates.
    pub const WALL_SECS_MIN: &str = "wall_secs_min";
    /// `wall_secs * 1e9 / events`.
    pub const NS_PER_EVENT: &str = "ns_per_event";
    /// `wall_secs_min * 1e9 / events`.
    pub const NS_PER_EVENT_MIN: &str = "ns_per_event_min";
    /// `events / wall_secs`.
    pub const EVENTS_PER_SEC: &str = "events_per_sec";
    /// Timed repetitions this record aggregates.
    pub const REPS: &str = "reps";
    /// Chunk attempts re-issued after a degraded-mode timeout.
    pub const FAULT_RETRIES: &str = "fault_retries";
    /// Chunk attempts routed away from the fault-free target.
    pub const FAULT_FAILOVERS: &str = "fault_failovers";
    /// Per-chunk timeouts that fired.
    pub const FAULT_TIMEOUTS: &str = "fault_timeouts";
    /// Operations declared unrecoverable (every replica lost / budget spent).
    pub const UNRECOVERABLE_OPS: &str = "unrecoverable_ops";
    /// Tasks abandoned because an operation was unrecoverable.
    pub const FAILED_TASKS: &str = "failed_tasks";
    /// Config echo on fault cells: replication factor.
    pub const REPLICATION: &str = "replication";
    /// Config echo on fault cells: storage nodes crashed at t = 0.
    pub const CRASHES: &str = "crashes";
    /// Derived onto `incast.4096_fullstripe` after a run that also executed
    /// `incast.4096`: `ns_per_event_min(fullstripe) / ns_per_event_min(stripe64)`.
    pub const NS_PER_EVENT_VS_STRIPE64_X: &str = "ns_per_event_vs_stripe64_x";
    /// Campaign trials executed (fixed-trial testbeds: min = max).
    pub const TRIALS: &str = "trials";
    /// Testbed campaign mean turnaround in seconds.
    pub const ACTUAL_MEAN_S: &str = "actual_mean_s";
    /// Testbed campaign turnaround standard deviation in seconds.
    pub const ACTUAL_STD_S: &str = "actual_std_s";
    /// Coarse-predictor turnaround for the same `(workload, config)`.
    pub const PREDICTED_S: &str = "predicted_s";
    /// `|predicted_s - actual_mean_s| / actual_mean_s`.
    pub const REL_ERR: &str = "rel_err";
    /// Wallclock the predictor itself spent (§3.3 speedup accounting).
    pub const PREDICTOR_WALL_SECS: &str = "predictor_wall_secs";
    /// `actual_mean_s / predictor_wall_secs` — time speedup vs measuring.
    pub const TIME_RATIO: &str = "time_ratio";
    /// `time_ratio * total_hosts` — resource-normalized speedup (§3.3).
    pub const RESOURCE_RATIO: &str = "resource_ratio";
    /// `actual_mean_s * total_hosts` in node-seconds.
    pub const ACTUAL_COST_NODE_S: &str = "actual_cost_node_s";
    /// Predicted allocation cost in node-seconds.
    pub const PRED_COST_NODE_S: &str = "pred_cost_node_s";
    /// Service probe: mean cold-evaluate latency (fresh cache), seconds.
    pub const COLD_SECS: &str = "cold_secs";
    /// Service probe: mean warm-hit latency, seconds.
    pub const WARM_SECS: &str = "warm_secs";
    /// `cold_secs / warm_secs`.
    pub const WARM_SPEEDUP_X: &str = "warm_speedup_x";
    /// Dedup probe: concurrent duplicate clients.
    pub const DEDUP_CLIENTS: &str = "dedup_clients";
    /// Dedup probe: total duplicate queries issued (clients × per-client).
    pub const DEDUP_QUERIES: &str = "dedup_queries";
    /// Dedup probe: simulations actually run (service cache misses).
    pub const DEDUP_SIMS: &str = "dedup_sims";
    /// `dedup_queries / dedup_sims`.
    pub const DEDUP_FACTOR_X: &str = "dedup_factor_x";
    /// Surrogate probe: off-grid queries issued.
    pub const SURROGATE_QUERIES: &str = "surrogate_queries";
    /// Surrogate probe: off-grid queries the interpolator answered.
    pub const SURROGATE_ANSWERS: &str = "surrogate_answers";
    /// Largest self-reported interpolation error estimate.
    pub const SURROGATE_MAX_EST_ERR: &str = "surrogate_max_est_err";
    /// Largest *observed* relative error vs an exact simulation of the
    /// same off-grid point (deterministic, so drift-gateable).
    pub const SURROGATE_MAX_REL_ERR: &str = "surrogate_max_rel_err";
    /// Mean interpolation latency per answered query, seconds.
    pub const SURROGATE_SECS_PER_QUERY: &str = "surrogate_secs_per_query";
    /// Trace cells: spans the flight recorder captured.
    pub const TRACE_SPANS: &str = "trace_spans";
    /// Critical-path attribution (trace cells): client compute seconds on
    /// the path ending at turnaround. The eight `cp_*_s` keys tile
    /// `[0, turnaround]` exactly, so they sum to `sim_turnaround_s`.
    pub const CP_CLIENT_COMPUTE_S: &str = "cp_client_compute_s";
    /// Critical-path attribution: sender-NIC wait + service seconds.
    pub const CP_OUT_NIC_S: &str = "cp_out_nic_s";
    /// Critical-path attribution: receiver-NIC wait + service seconds.
    pub const CP_IN_NIC_S: &str = "cp_in_nic_s";
    /// Critical-path attribution: core-fabric-link wait + service seconds
    /// (always 0 under the star topology, which has no core links).
    pub const CP_CORE_LINK_S: &str = "cp_core_link_s";
    /// Critical-path attribution: storage-service wait + service seconds.
    pub const CP_STORAGE_S: &str = "cp_storage_s";
    /// Critical-path attribution: manager control-message seconds.
    pub const CP_MANAGER_S: &str = "cp_manager_s";
    /// Critical-path attribution: timeout/retry/failover recovery seconds.
    pub const CP_FAULT_RECOVERY_S: &str = "cp_fault_recovery_s";
    /// Critical-path attribution: seconds with no task active at all.
    pub const CP_IDLE_S: &str = "cp_idle_s";
    /// Delta probe: campaign evaluations per wallclock second.
    pub const EVALS_PER_SEC: &str = "evals_per_sec";
    /// Delta probe: evaluations answered by a delta warm-start (spliced
    /// from a neighbor's stage checkpoints) instead of a cold run.
    pub const DELTA_HITS: &str = "delta_hits";
    /// Delta probe: stages skipped (restored from checkpoints) across the
    /// campaign's delta warm-starts.
    pub const DELTA_STAGES_SKIPPED: &str = "delta_stages_skipped";
    /// Delta probe: stages actually re-simulated across the campaign's
    /// delta warm-starts.
    pub const DELTA_STAGES_REPLAYED: &str = "delta_stages_replayed";
    /// `delta_stages_skipped / (delta_stages_skipped +
    /// delta_stages_replayed)` — the fraction of delta-warm-start stage
    /// work answered from checkpoints (0 when no warm-start happened).
    pub const STAGES_SKIPPED_RATIO: &str = "stages_skipped_ratio";
    /// Delta probe: sum of predicted turnarounds over the sweep, seconds.
    /// Deterministic, so exact cross-cell equality pins bit-identity of
    /// the delta path against the cold reference.
    pub const TURNAROUND_SUM_S: &str = "turnaround_sum_s";

    /// Every key above, for schema-coverage tests and doc generation.
    pub const ALL: &[&str] = &[
        EVENTS,
        EVENTS_CANCELLED,
        STALE_EVENT_RATIO,
        SIM_TURNAROUND_S,
        WALL_SECS,
        WALL_SECS_MIN,
        NS_PER_EVENT,
        NS_PER_EVENT_MIN,
        EVENTS_PER_SEC,
        REPS,
        FAULT_RETRIES,
        FAULT_FAILOVERS,
        FAULT_TIMEOUTS,
        UNRECOVERABLE_OPS,
        FAILED_TASKS,
        REPLICATION,
        CRASHES,
        NS_PER_EVENT_VS_STRIPE64_X,
        TRIALS,
        ACTUAL_MEAN_S,
        ACTUAL_STD_S,
        PREDICTED_S,
        REL_ERR,
        PREDICTOR_WALL_SECS,
        TIME_RATIO,
        RESOURCE_RATIO,
        ACTUAL_COST_NODE_S,
        PRED_COST_NODE_S,
        COLD_SECS,
        WARM_SECS,
        WARM_SPEEDUP_X,
        DEDUP_CLIENTS,
        DEDUP_QUERIES,
        DEDUP_SIMS,
        DEDUP_FACTOR_X,
        SURROGATE_QUERIES,
        SURROGATE_ANSWERS,
        SURROGATE_MAX_EST_ERR,
        SURROGATE_MAX_REL_ERR,
        SURROGATE_SECS_PER_QUERY,
        TRACE_SPANS,
        CP_CLIENT_COMPUTE_S,
        CP_OUT_NIC_S,
        CP_IN_NIC_S,
        CP_CORE_LINK_S,
        CP_STORAGE_S,
        CP_MANAGER_S,
        CP_FAULT_RECOVERY_S,
        CP_IDLE_S,
        EVALS_PER_SEC,
        DELTA_HITS,
        DELTA_STAGES_SKIPPED,
        DELTA_STAGES_REPLAYED,
        STAGES_SKIPPED_RATIO,
        TURNAROUND_SUM_S,
    ];
}

/// One cell's measurements from one run: three string fields plus an
/// ordered flat map of numeric metrics.
#[derive(Clone, Debug, PartialEq)]
pub struct CellRecord {
    /// Cell name, `suite.cell` (e.g. `incast.4096_fullstripe`).
    pub cell: String,
    /// Engine provenance label (see [`FIELD_ENGINE`]).
    pub engine: String,
    /// Run identity (see [`FIELD_RUN`]).
    pub run_id: String,
    metrics: Vec<(String, f64)>,
}

impl CellRecord {
    pub fn new(cell: &str, engine: &str, run_id: &str) -> CellRecord {
        CellRecord {
            cell: cell.to_string(),
            engine: engine.to_string(),
            run_id: run_id.to_string(),
            metrics: Vec::new(),
        }
    }

    /// Set a metric, replacing any previous value under the same key.
    pub fn set(&mut self, key: &str, value: f64) -> &mut CellRecord {
        if let Some(slot) = self.metrics.iter_mut().find(|(k, _)| k == key) {
            slot.1 = value;
        } else {
            self.metrics.push((key.to_string(), value));
        }
        self
    }

    /// Look a metric up by key.
    pub fn get(&self, key: &str) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    /// The metrics in insertion order.
    pub fn metrics(&self) -> &[(String, f64)] {
        &self.metrics
    }

    /// Render as one compact flat-JSON line (the record/history format).
    pub fn render_compact(&self) -> String {
        let mut j = Json::obj()
            .set(FIELD_CELL, self.cell.as_str())
            .set(FIELD_ENGINE, self.engine.as_str())
            .set(FIELD_RUN, self.run_id.as_str());
        for (k, v) in &self.metrics {
            j = j.set(k, *v);
        }
        j.render_compact()
    }

    /// Parse a record previously rendered by [`CellRecord::render_compact`].
    ///
    /// Strict on shape: nested objects are rejected by `parse_flat`
    /// itself, and any non-numeric value outside the three reserved
    /// string fields is an error — a baseline file that does not parse is
    /// treated by the runner as missing (bootstrap), never half-read.
    pub fn parse(text: &str) -> Result<CellRecord, String> {
        let mut rec = CellRecord::new("", "", "");
        for (key, val) in parse_flat(text)? {
            match (key.as_str(), val) {
                (FIELD_CELL, Scalar::Str(s)) => rec.cell = s,
                (FIELD_ENGINE, Scalar::Str(s)) => rec.engine = s,
                (FIELD_RUN, Scalar::Str(s)) => rec.run_id = s,
                (_, Scalar::Num(v)) => {
                    rec.metrics.push((key, v));
                }
                (k, other) => {
                    return Err(format!("record key {k:?}: expected a number, got {other:?}"))
                }
            }
        }
        if rec.cell.is_empty() {
            return Err("record has no \"cell\" field".into());
        }
        Ok(rec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CellRecord {
        let mut r = CellRecord::new("incast.4096", "coarse", "deadbeef");
        r.set(keys::EVENTS, 1.25e6)
            .set(keys::SIM_TURNAROUND_S, 42.5)
            .set(keys::STALE_EVENT_RATIO, 0.0625)
            .set(keys::REPS, 3.0);
        r
    }

    #[test]
    fn round_trips_through_compact_json() {
        let r = sample();
        let back = CellRecord::parse(&r.render_compact()).expect("parse own rendering");
        assert_eq!(back, r);
    }

    #[test]
    fn set_replaces_in_place() {
        let mut r = sample();
        let n = r.metrics().len();
        r.set(keys::EVENTS, 2.0e6);
        assert_eq!(r.metrics().len(), n, "no duplicate key");
        assert_eq!(r.get(keys::EVENTS), Some(2.0e6));
        assert_eq!(r.metrics()[0].0, keys::EVENTS, "order preserved");
    }

    #[test]
    fn parse_rejects_non_numeric_metrics_and_missing_cell() {
        let bad = "{\"cell\": \"x\", \"events\": \"lots\"}";
        assert!(CellRecord::parse(bad).is_err());
        let no_cell = "{\"events\": 1.0}";
        assert!(CellRecord::parse(no_cell).is_err());
    }

    #[test]
    fn key_constants_are_unique() {
        for (i, a) in keys::ALL.iter().enumerate() {
            for b in &keys::ALL[i + 1..] {
                assert_ne!(a, b, "duplicate key constant");
            }
        }
    }

    /// The documented schema is generated from these constants: every key
    /// that can appear in a record must be documented (as `` `key` ``) in
    /// METHODOLOGY.md, or this test fails the build.
    #[test]
    fn methodology_documents_every_key() {
        let guide = include_str!("../../METHODOLOGY.md");
        for key in keys::ALL {
            let marker = format!("`{key}`");
            assert!(
                guide.contains(&marker),
                "METHODOLOGY.md does not document record key {key:?}"
            );
        }
        for field in [FIELD_CELL, FIELD_ENGINE, FIELD_RUN] {
            assert!(
                guide.contains(&format!("`{field}`")),
                "METHODOLOGY.md does not document reserved field {field:?}"
            );
        }
    }
}
