//! The benchmark registry: every cell the barometer can run, as data.
//!
//! A **cell** is one declarative definition of a measurement —
//! `(workload × platform × fidelity/engine × fault-plan)` — named
//! `suite.cell` and carrying its own [`Gate`] list. Adding a row to the
//! matrix is adding one [`CellDef`] to [`registry`]; the runner, the
//! record schema, `--list`, CI gating, and METHODOLOGY's taxonomy all
//! follow from the definition. Cells with `ci: true` form the default
//! suite that `wfpred bench --check` gates on every push; the rest
//! (`figures.*`, `ablations.*`) are paper-figure and sensitivity sweeps
//! selected explicitly by glob.
//!
//! Specs are *descriptions*, not built objects: the runner materializes
//! [`Workload`]/[`Config`]/[`Platform`]/[`Fidelity`] values from them at
//! execution time, so the registry itself stays cheap to enumerate and
//! trivially testable.

use super::gate::Gate;
use super::record::keys;
use crate::model::{Config, FaultPlan, Fidelity, Placement, Platform, Topology};
use crate::service::EngineId;
use crate::util::units::{Bytes, SimTime};
use crate::workload::blast::{blast, BlastParams};
use crate::workload::montage::montage;
use crate::workload::patterns::{broadcast, pipeline, reduce, PatternScale};
use crate::workload::{FileSpec, TaskSpec, Workload};

/// Which identified platform a cell runs against.
#[derive(Clone, Debug)]
pub enum PlatformSpec {
    /// The paper's 20-node testbed characterization.
    Paper,
    /// The HDD-backed variant (Fig 10 scenarios).
    Hdd,
    /// Paper testbed with an overridden wire frame size (frames ablation).
    FrameKb(u64),
    /// Paper testbed with one host's compute scaled (heterogeneous rows).
    HostSpeed { host: usize, mult: f64 },
    /// Paper testbed routed through the two-tier rack + core fabric
    /// (`Topology::Rack`). A `rack_size` covering every host lays out a
    /// single rack, which degenerates to the star — the identity cells
    /// exploit exactly that.
    RackTopo { rack_size: usize, oversub: f64 },
}

impl PlatformSpec {
    pub fn build(&self) -> Platform {
        match *self {
            PlatformSpec::Paper => Platform::paper_testbed(),
            PlatformSpec::Hdd => Platform::paper_testbed_hdd(),
            PlatformSpec::FrameKb(kb) => {
                let mut p = Platform::paper_testbed();
                p.frame_size = Bytes::kb(kb);
                p
            }
            PlatformSpec::HostSpeed { host, mult } => {
                Platform::paper_testbed().with_host_speed(host, mult)
            }
            PlatformSpec::RackTopo { rack_size, oversub } => {
                let mut p = Platform::paper_testbed();
                p.topology = Topology::Rack { rack_size, oversub };
                p
            }
        }
    }
}

/// Which workflow a cell replays.
#[derive(Clone, Debug)]
pub enum WorkloadSpec {
    Pipeline { n: usize, scale: PatternScale, wass: bool },
    Reduce { n: usize, scale: PatternScale, wass: bool },
    Broadcast { n: usize, scale: PatternScale, replicas: u32 },
    Blast { n_app: usize, queries: u32 },
    Montage { tiles: usize },
    /// One task streaming one prestaged file — the pure-read window probe.
    SingleReader { mb: u64 },
}

impl WorkloadSpec {
    pub fn build(&self) -> Workload {
        match *self {
            WorkloadSpec::Pipeline { n, scale, wass } => pipeline(n, scale, wass),
            WorkloadSpec::Reduce { n, scale, wass } => reduce(n, scale, wass),
            WorkloadSpec::Broadcast { n, scale, replicas } => broadcast(n, scale, replicas),
            WorkloadSpec::Blast { n_app, queries } => {
                blast(n_app, &BlastParams { queries, ..BlastParams::default() })
            }
            WorkloadSpec::Montage { tiles } => montage(tiles),
            WorkloadSpec::SingleReader { mb } => {
                let mut w = Workload::new("single-reader");
                let f = w.add_file(FileSpec::new("big", Bytes::mb(mb)).prestaged());
                w.add_task(TaskSpec::new("reader", 0).reads(f));
                w
            }
        }
    }
}

/// The storage-configuration decision a cell evaluates.
#[derive(Clone, Debug)]
pub struct ConfigSpec {
    pub base: ConfigBase,
    pub stripe: Option<usize>,
    pub replication: Option<u32>,
    pub chunk_kb: Option<u64>,
    pub window: Option<usize>,
    pub round_robin: bool,
    /// Storage-node crashes spread at t = 0 (`FaultPlan::spread_crashes`);
    /// 0 means a fault-free plan.
    pub crashes: usize,
}

#[derive(Clone, Debug)]
pub enum ConfigBase {
    Dss(usize),
    Wass(usize),
    Partitioned { n_app: usize, n_storage: usize },
}

impl ConfigSpec {
    pub fn dss(n: usize) -> ConfigSpec {
        ConfigSpec::of(ConfigBase::Dss(n))
    }
    pub fn wass(n: usize) -> ConfigSpec {
        ConfigSpec::of(ConfigBase::Wass(n))
    }
    pub fn partitioned(n_app: usize, n_storage: usize) -> ConfigSpec {
        ConfigSpec::of(ConfigBase::Partitioned { n_app, n_storage })
    }
    fn of(base: ConfigBase) -> ConfigSpec {
        ConfigSpec {
            base,
            stripe: None,
            replication: None,
            chunk_kb: None,
            window: None,
            round_robin: false,
            crashes: 0,
        }
    }
    pub fn stripe(mut self, w: usize) -> ConfigSpec {
        self.stripe = Some(w);
        self
    }
    pub fn replication(mut self, r: u32) -> ConfigSpec {
        self.replication = Some(r);
        self
    }
    pub fn chunk_kb(mut self, kb: u64) -> ConfigSpec {
        self.chunk_kb = Some(kb);
        self
    }
    pub fn window(mut self, w: usize) -> ConfigSpec {
        self.window = Some(w);
        self
    }
    pub fn round_robin(mut self) -> ConfigSpec {
        self.round_robin = true;
        self
    }
    pub fn crashes(mut self, n: usize) -> ConfigSpec {
        self.crashes = n;
        self
    }

    pub fn build(&self) -> Config {
        let mut cfg = match self.base {
            ConfigBase::Dss(n) => Config::dss(n),
            ConfigBase::Wass(n) => Config::wass(n),
            ConfigBase::Partitioned { n_app, n_storage } => {
                Config::partitioned(n_app, n_storage, Bytes::kb(self.chunk_kb.unwrap_or(1024)))
            }
        };
        if let (Some(kb), false) = (self.chunk_kb, matches!(self.base, ConfigBase::Partitioned { .. }))
        {
            cfg = cfg.with_chunk(Bytes::kb(kb));
        }
        if let Some(w) = self.stripe {
            cfg = cfg.with_stripe(w);
        }
        if let Some(r) = self.replication {
            cfg = cfg.with_replication(r);
        }
        if let Some(w) = self.window {
            cfg = cfg.with_window(w);
        }
        if self.round_robin {
            cfg.placement = Placement::RoundRobin;
        }
        if self.crashes > 0 {
            let plan = FaultPlan::spread_crashes(cfg.n_storage, self.crashes, SimTime::ZERO);
            cfg = cfg.with_fault_plan(plan);
        }
        cfg
    }
}

/// A detailed-tier knob knocked out by an ablation cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AblationKnob {
    ControlRounds,
    Connections,
    Mux,
    Stagger,
    Jitter,
    Hetero,
    ManagerContention,
}

impl AblationKnob {
    pub fn label(self) -> &'static str {
        match self {
            AblationKnob::ControlRounds => "no_control_rounds",
            AblationKnob::Connections => "no_connections",
            AblationKnob::Mux => "no_mux",
            AblationKnob::Stagger => "no_stagger",
            AblationKnob::Jitter => "no_jitter",
            AblationKnob::Hetero => "no_hetero",
            AblationKnob::ManagerContention => "no_contention",
        }
    }

    pub fn apply(self, seed: u64) -> Fidelity {
        let mut f = Fidelity::detailed(seed);
        match self {
            AblationKnob::ControlRounds => f.control_rounds = false,
            AblationKnob::Connections => f.connections = false,
            AblationKnob::Mux => f.mux_eta = 0.0,
            AblationKnob::Stagger => f.stagger_mean = SimTime::ZERO,
            AblationKnob::Jitter => f.jitter_sigma = 0.0,
            AblationKnob::Hetero => f.hetero_sigma = 0.0,
            AblationKnob::ManagerContention => f.manager_contention = 0.0,
        }
        f
    }
}

/// Which evaluation engine a `Sim` cell drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineSpec {
    Coarse,
    CoarsePerFrame,
    Detailed,
    DetailedAggregated,
    /// The detailed tier with one noise source knocked out.
    DetailedMinus(AblationKnob),
}

impl EngineSpec {
    pub fn fidelity(&self, seed: u64) -> Fidelity {
        match *self {
            EngineSpec::Coarse => Fidelity::coarse(),
            EngineSpec::CoarsePerFrame => Fidelity::coarse_per_frame(),
            EngineSpec::Detailed => Fidelity::detailed(seed),
            EngineSpec::DetailedAggregated => Fidelity::detailed_aggregated(seed),
            EngineSpec::DetailedMinus(k) => k.apply(seed),
        }
    }

    /// Engine-provenance label stamped on the cell's records.
    pub fn label(&self) -> String {
        match *self {
            EngineSpec::Coarse => EngineId::Coarse.as_str().to_string(),
            EngineSpec::CoarsePerFrame => EngineId::CoarsePerFrame.as_str().to_string(),
            EngineSpec::Detailed => EngineId::Detailed.as_str().to_string(),
            EngineSpec::DetailedAggregated => EngineId::DetailedAggregated.as_str().to_string(),
            EngineSpec::DetailedMinus(k) => {
                format!("{}-{}", EngineId::Detailed.as_str(), k.label())
            }
        }
    }
}

/// The service-layer probes (ported from the retired `microbench`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServiceProbe {
    /// Cold evaluate vs warm sharded-LRU hit on the acceptance workload.
    QueryPath,
    /// Concurrent duplicate clients through single-flight dedup.
    Dedup,
    /// Seed a surrogate grid with exact samples, interpolate off-grid
    /// points, and compare each answer against an exact simulation.
    Surrogate,
    /// Single-knob stripe sweep through a delta-enabled service: the
    /// first point simulates cold and captures stage checkpoints, every
    /// neighbor warm-starts from them (incremental re-simulation).
    DeltaSweep,
    /// The same stripe sweep through [`crate::service::Service::without_delta`]
    /// — the cold reference the sweep cell gates its throughput and
    /// bit-identity against in the same run.
    DeltaCold,
}

/// How a cell is executed.
#[derive(Clone, Debug)]
pub enum CellKind {
    /// Direct `simulate_fid` runs. For stochastic engines `reps` doubles
    /// as the seed count and deterministic metrics are means over seeds.
    Sim { workload: WorkloadSpec, config: ConfigSpec, engine: EngineSpec, reps: u32 },
    /// A fixed-trial testbed campaign (min = max = `trials`, so the
    /// Jain stopping rule never adds trials and the campaign mean is
    /// deterministic) plus one coarse prediction of the same point.
    Campaign { workload: WorkloadSpec, config: ConfigSpec, aggregated: bool, trials: u64 },
    /// A service-layer probe.
    Service(ServiceProbe),
    /// One `simulate_traced` run with critical-path attribution: records
    /// the eight `cp_*_s` keys (which tile `[0, turnaround]` exactly)
    /// alongside the usual simulation metrics.
    Trace { workload: WorkloadSpec, config: ConfigSpec, engine: EngineSpec },
}

/// One benchmark cell: a name, how to run it, and what must hold.
#[derive(Clone, Debug)]
pub struct CellDef {
    /// `suite.cell` — globbable, and the record/history file stem.
    pub name: String,
    /// Member of the default CI suite (`wfpred bench --check` with no
    /// globs)?
    pub ci: bool,
    /// One-line description for `--list` and regression reports.
    pub note: String,
    pub platform: PlatformSpec,
    pub kind: CellKind,
    pub gates: Vec<Gate>,
}

impl CellDef {
    /// The engine-provenance label this cell stamps on its records.
    pub fn engine_label(&self) -> String {
        match &self.kind {
            CellKind::Sim { engine, .. } | CellKind::Trace { engine, .. } => engine.label(),
            CellKind::Campaign { aggregated, .. } => {
                if *aggregated {
                    format!("testbed_{}", EngineId::DetailedAggregated.as_str())
                } else {
                    format!("testbed_{}", EngineId::Detailed.as_str())
                }
            }
            CellKind::Service(ServiceProbe::Surrogate) => {
                EngineId::Surrogate.as_str().to_string()
            }
            CellKind::Service(_) => EngineId::Coarse.as_str().to_string(),
        }
    }
}

/// Glob match over cell names: `*` spans any run (including `.`), `?`
/// matches one byte. `scale.*`, `faults.r?_c16`, `*fullstripe*` all work.
pub fn glob_match(pat: &str, name: &str) -> bool {
    fn rec(p: &[u8], n: &[u8]) -> bool {
        match (p.first(), n.first()) {
            (None, None) => true,
            (Some(b'*'), _) => rec(&p[1..], n) || (!n.is_empty() && rec(p, &n[1..])),
            (Some(b'?'), Some(_)) => rec(&p[1..], &n[1..]),
            (Some(a), Some(b)) if a == b => rec(&p[1..], &n[1..]),
            _ => false,
        }
    }
    rec(pat.as_bytes(), name.as_bytes())
}

/// Resolve selection globs against the registry. An empty glob list
/// selects the CI suite; a glob matching nothing is an error (typo
/// protection — a check that silently gated zero cells would be green
/// forever).
pub fn select<'a>(cells: &'a [CellDef], globs: &[String]) -> Result<Vec<&'a CellDef>, String> {
    if globs.is_empty() {
        return Ok(cells.iter().filter(|c| c.ci).collect());
    }
    let mut picked: Vec<&CellDef> = Vec::new();
    for g in globs {
        let mut any = false;
        for c in cells.iter().filter(|c| glob_match(g, &c.name)) {
            any = true;
            if !picked.iter().any(|p| p.name == c.name) {
                picked.push(c);
            }
        }
        if !any {
            return Err(format!("glob {g:?} matches no cell (see `wfpred bench --list`)"));
        }
    }
    Ok(picked)
}

/// The acceptance workload shared by the frame-path, engine-comparison
/// and service suites: BLAST, 40 queries over 10 app nodes, 5 storage
/// nodes, 1 MB chunks.
const ACCEPT_N_APP: usize = 10;
const ACCEPT_QUERIES: u32 = 40;

fn accept_workload() -> WorkloadSpec {
    WorkloadSpec::Blast { n_app: ACCEPT_N_APP, queries: ACCEPT_QUERIES }
}

fn accept_config() -> ConfigSpec {
    ConfigSpec::partitioned(ACCEPT_N_APP, 5).chunk_kb(1024)
}

/// A CI `Sim` cell on the paper platform; use [`extra`] to demote a
/// record-only sweep cell out of the CI suite.
fn sim(
    name: &str,
    note: &str,
    workload: WorkloadSpec,
    config: ConfigSpec,
    engine: EngineSpec,
    reps: u32,
    gates: Vec<Gate>,
) -> CellDef {
    CellDef {
        name: name.to_string(),
        ci: true,
        note: note.to_string(),
        platform: PlatformSpec::Paper,
        kind: CellKind::Sim { workload, config, engine, reps },
        gates,
    }
}

/// A record-only `Campaign` cell; callers that gate it set `ci`/`gates`
/// on the returned definition.
fn campaign(
    name: &str,
    note: &str,
    platform: PlatformSpec,
    workload: WorkloadSpec,
    config: ConfigSpec,
    aggregated: bool,
    trials: u64,
) -> CellDef {
    CellDef {
        name: name.to_string(),
        ci: false,
        note: note.to_string(),
        platform,
        kind: CellKind::Campaign { workload, config, aggregated, trials },
        gates: Vec::new(),
    }
}

/// Demote a cell out of the CI suite (sweeps that only need records).
fn extra(mut cell: CellDef) -> CellDef {
    cell.ci = false;
    cell
}

/// Standard drift pair for deterministic simulation cells.
fn drift2() -> Vec<Gate> {
    vec![Gate::drift(keys::EVENTS), Gate::drift(keys::SIM_TURNAROUND_S)]
}

/// Build the full registry. Deterministic and cheap — safe to call from
/// tests, `--list`, and every runner invocation.
pub fn registry() -> Vec<CellDef> {
    let mut cells: Vec<CellDef> = Vec::new();

    // ── frame_path: the PR-1/2 bulk-aggregation barometer ────────────────
    cells.push(sim(
        "frame_path.per_frame",
        "acceptance workload, per-frame reference engine",
        accept_workload(),
        accept_config(),
        EngineSpec::CoarsePerFrame,
        5,
        drift2(),
    ));
    {
        let mut gates = drift2();
        // Bulk aggregation must keep >= 5x fewer events than the per-frame
        // reference (the old event_reduction_x >= 5, inverted) while
        // reproducing its turnaround to 1% in the same run.
        gates.push(Gate::le_cell(keys::EVENTS, "frame_path.per_frame", 0.2));
        gates.push(Gate::within_cell(keys::SIM_TURNAROUND_S, "frame_path.per_frame", 0.01));
        cells.push(sim(
            "frame_path.bulk",
            "acceptance workload, bulk frame-aggregated engine",
            accept_workload(),
            accept_config(),
            EngineSpec::Coarse,
            5,
            gates,
        ));
    }

    // ── scale: the pipeline scaling curve ────────────────────────────────
    for hosts in [64usize, 256, 1024] {
        cells.push(sim(
            &format!("scale.hosts_{hosts}"),
            "pipeline scaling curve point (DSS)",
            WorkloadSpec::Pipeline { n: hosts - 1, scale: PatternScale::Small, wass: false },
            ConfigSpec::dss(hosts - 1),
            EngineSpec::Coarse,
            3,
            drift2(),
        ));
    }

    // ── incast: reduce fan-in and stale-event accounting ─────────────────
    for hosts in [256usize, 1024, 4096] {
        let mut gates = drift2();
        gates.push(Gate::Range { key: keys::STALE_EVENT_RATIO, lo: 0.0, hi: 0.5 });
        cells.push(sim(
            &format!("incast.{hosts}"),
            "reduce incast point, stripe capped at 64",
            WorkloadSpec::Reduce { n: hosts - 1, scale: PatternScale::Small, wass: false },
            ConfigSpec::dss(hosts - 1).stripe(64.min(hosts - 1)),
            EngineSpec::Coarse,
            3,
            gates,
        ));
    }
    {
        let mut gates = drift2();
        gates.push(Gate::Range { key: keys::STALE_EVENT_RATIO, lo: 0.0, hi: 0.5 });
        // Full-stripe placement may cost at most 10% more per event than
        // the stripe-64 row from the same run (min-over-reps wallclock on
        // both sides, so the bound is host-independent).
        gates.push(Gate::ratio_range(keys::NS_PER_EVENT_MIN, "incast.4096", 0.0, 1.1));
        cells.push(sim(
            "incast.4096_fullstripe",
            "worst-case interned placement: every write allocates the full ring",
            WorkloadSpec::Reduce { n: 4095, scale: PatternScale::Small, wass: false },
            ConfigSpec::dss(4095),
            EngineSpec::Coarse,
            3,
            gates,
        ));
    }

    // ── topology: routed-fabric identity and oversubscription curves ─────
    // The star-identity cells run a *degenerate* rack layout (one rack
    // covering every host, oversubscription 1) through the routed-fabric
    // code path; the fabric plans zero core links there, so the runs must
    // reproduce their star counterparts from the same run exactly — the
    // registry-level face of the `RefStarFabric` lockstep oracle.
    {
        let mut gates = drift2();
        gates.push(Gate::Range { key: keys::STALE_EVENT_RATIO, lo: 0.0, hi: 0.5 });
        gates.push(Gate::eq_cell(keys::EVENTS, "incast.1024"));
        gates.push(Gate::eq_cell(keys::SIM_TURNAROUND_S, "incast.1024"));
        cells.push(CellDef {
            name: "topology.star_identity".into(),
            ci: true,
            note: "incast.1024 spec on a degenerate one-rack fabric (must equal star)".into(),
            platform: PlatformSpec::RackTopo { rack_size: 2048, oversub: 1.0 },
            kind: CellKind::Sim {
                workload: WorkloadSpec::Reduce { n: 1023, scale: PatternScale::Small, wass: false },
                config: ConfigSpec::dss(1023).stripe(64),
                engine: EngineSpec::Coarse,
                reps: 3,
            },
            gates,
        });
    }
    {
        let mut gates = drift2();
        gates.push(Gate::eq_cell(keys::EVENTS, "frame_path.bulk"));
        gates.push(Gate::eq_cell(keys::SIM_TURNAROUND_S, "frame_path.bulk"));
        cells.push(CellDef {
            name: "topology.star_identity_accept".into(),
            ci: true,
            note: "acceptance workload on a degenerate one-rack fabric (must equal star)".into(),
            platform: PlatformSpec::RackTopo { rack_size: 64, oversub: 1.0 },
            kind: CellKind::Sim {
                workload: accept_workload(),
                config: accept_config(),
                engine: EngineSpec::Coarse,
                reps: 5,
            },
            gates,
        });
    }
    // Oversubscribed cores on the 1024-host incast: racks of 8 share an
    // uplink/downlink pair provisioned at `rack_size / oversub` NIC rates,
    // so the concurrent write phase serializes on the core and turnaround
    // grows monotonically with the ratio.
    {
        let mut gates = drift2();
        gates.push(Gate::Range { key: keys::STALE_EVENT_RATIO, lo: 0.0, hi: 0.5 });
        gates.push(Gate::ge_cell(keys::SIM_TURNAROUND_S, "incast.1024", 0.0));
        cells.push(CellDef {
            name: "topology.oversub_2x".into(),
            ci: true,
            note: "incast.1024 spec on racks of 8 with a 2x-oversubscribed core".into(),
            platform: PlatformSpec::RackTopo { rack_size: 8, oversub: 2.0 },
            kind: CellKind::Sim {
                workload: WorkloadSpec::Reduce { n: 1023, scale: PatternScale::Small, wass: false },
                config: ConfigSpec::dss(1023).stripe(64),
                engine: EngineSpec::Coarse,
                reps: 3,
            },
            gates,
        });
    }
    {
        let mut gates = drift2();
        gates.push(Gate::Range { key: keys::STALE_EVENT_RATIO, lo: 0.0, hi: 0.5 });
        gates.push(Gate::ge_cell(keys::SIM_TURNAROUND_S, "topology.oversub_2x", 0.0));
        // The acceptance criterion: an oversubscribed core must cost
        // *measurably* more than the star on the same workload, same run.
        gates.push(Gate::ratio_range(keys::SIM_TURNAROUND_S, "incast.1024", 1.02, f64::INFINITY));
        cells.push(CellDef {
            name: "topology.oversub_8x".into(),
            ci: true,
            note: "incast.1024 spec on racks of 8 with an 8x-oversubscribed core".into(),
            platform: PlatformSpec::RackTopo { rack_size: 8, oversub: 8.0 },
            kind: CellKind::Sim {
                workload: WorkloadSpec::Reduce { n: 1023, scale: PatternScale::Small, wass: false },
                config: ConfigSpec::dss(1023).stripe(64),
                engine: EngineSpec::Coarse,
                reps: 3,
            },
            gates,
        });
    }

    // ── faults: degraded-mode invariants over (replication × crashes) ────
    // Static name table so cross-cell gates can hold `&'static str` peers.
    const FAULT_CELLS: [[&str; 4]; 3] = [
        ["faults.r1_c0", "faults.r1_c1", "faults.r1_c4", "faults.r1_c16"],
        ["faults.r2_c0", "faults.r2_c1", "faults.r2_c4", "faults.r2_c16"],
        ["faults.r3_c0", "faults.r3_c1", "faults.r3_c4", "faults.r3_c16"],
    ];
    const CRASH_LEVELS: [usize; 4] = [0, 1, 4, 16];
    for repl in [1u32, 2, 3] {
        for (ci_idx, &crashes) in CRASH_LEVELS.iter().enumerate() {
            let row = &FAULT_CELLS[repl as usize - 1];
            let mut gates = drift2();
            if repl == 1 && crashes == 0 {
                // Fault-free plan must not perturb the engine at all.
                gates.push(Gate::eq_cell(keys::EVENTS, "incast.1024"));
            }
            if repl == 1 && crashes > 0 {
                gates.push(Gate::Min { key: keys::UNRECOVERABLE_OPS, min: 1.0 });
            }
            if repl >= 2 {
                gates.push(Gate::Max { key: keys::UNRECOVERABLE_OPS, max: 0.0 });
                if ci_idx > 0 {
                    // Turnaround is monotone non-decreasing in crash count
                    // (0.5% slack for degraded-mode rounding).
                    gates.push(Gate::ge_cell(keys::SIM_TURNAROUND_S, row[ci_idx - 1], 0.005));
                }
                if crashes == 16 {
                    gates.push(Gate::le_cell(keys::SIM_TURNAROUND_S, row[0], 3.0));
                }
            }
            cells.push(sim(
                row[ci_idx],
                "1024-host reduce incast under spread crashes at t=0",
                WorkloadSpec::Reduce { n: 1023, scale: PatternScale::Small, wass: false },
                ConfigSpec::dss(1023).stripe(64).replication(repl).crashes(crashes),
                EngineSpec::Coarse,
                1,
                gates,
            ));
        }
    }

    // ── service: the prediction-serving probes ───────────────────────────
    cells.push(CellDef {
        name: "service.query_path".into(),
        ci: true,
        note: "cold simulate vs warm sharded-LRU hit".into(),
        platform: PlatformSpec::Paper,
        kind: CellKind::Service(ServiceProbe::QueryPath),
        gates: vec![Gate::Min { key: keys::WARM_SPEEDUP_X, min: 10.0 }],
    });
    cells.push(CellDef {
        name: "service.dedup".into(),
        ci: true,
        note: "8 concurrent duplicate clients through single-flight".into(),
        platform: PlatformSpec::Paper,
        kind: CellKind::Service(ServiceProbe::Dedup),
        gates: vec![Gate::GeKey { key: keys::DEDUP_FACTOR_X, floor_key: keys::DEDUP_CLIENTS }],
    });
    cells.push(CellDef {
        name: "service.surrogate".into(),
        ci: true,
        note: "grid interpolation vs exact simulation on off-grid points".into(),
        platform: PlatformSpec::Paper,
        kind: CellKind::Service(ServiceProbe::Surrogate),
        gates: vec![
            Gate::Min { key: keys::SURROGATE_ANSWERS, min: 1.0 },
            // Every answer must carry a self-estimate (key presence is the
            // invariant; the estimate itself may be small).
            Gate::Min { key: keys::SURROGATE_MAX_EST_ERR, min: 0.0 },
            // Observed error vs exact is deterministic: bound and drift it.
            Gate::Max { key: keys::SURROGATE_MAX_REL_ERR, max: 0.5 },
            Gate::drift(keys::SURROGATE_MAX_REL_ERR),
        ],
    });

    // ── search.delta: incremental re-simulation on a single-knob sweep ───
    // The sweep perturbs only the stripe width, so every neighbor shares
    // the heavy first stage's fingerprint with the first (cold) point and
    // replays only the cheap stripe-sensitive tail. `search.delta.cold`
    // runs the identical sweep with delta warm-starts disabled; the sweep
    // cell gates bit-identity (exact turnaround-sum equality) and the
    // >= 2x campaign-throughput floor against it in the same run.
    const DELTA_COLD: &str = "search.delta.cold";
    cells.push(CellDef {
        name: DELTA_COLD.into(),
        ci: true,
        note: "stripe sweep with delta warm-starts disabled (cold reference)".into(),
        platform: PlatformSpec::Paper,
        kind: CellKind::Service(ServiceProbe::DeltaCold),
        gates: vec![
            // A delta-disabled service must never warm-start.
            Gate::Max { key: keys::DELTA_HITS, max: 0.0 },
            Gate::drift(keys::TURNAROUND_SUM_S),
        ],
    });
    cells.push(CellDef {
        name: "search.delta.sweep".into(),
        ci: true,
        note: "same stripe sweep with delta warm-starts on".into(),
        platform: PlatformSpec::Paper,
        kind: CellKind::Service(ServiceProbe::DeltaSweep),
        gates: vec![
            // Bit-identity with the cold path: the answers are the same
            // doubles summed in the same order, so equality is exact.
            Gate::eq_cell(keys::TURNAROUND_SUM_S, DELTA_COLD),
            // The tentpole's acceptance floor: >= 2x evaluations/sec vs
            // the cold sweep of the same run (host-independent ratio).
            Gate::ratio_range(keys::EVALS_PER_SEC, DELTA_COLD, 2.0, f64::INFINITY),
            // Every non-cold point of a single-knob sweep must warm-start,
            // and warm-starts must actually skip stage work.
            Gate::Min { key: keys::DELTA_HITS, min: 1.0 },
            Gate::Min { key: keys::STAGES_SKIPPED_RATIO, min: 0.25 },
            // The counters are deterministic: pin them against drift.
            Gate::drift(keys::DELTA_HITS),
            Gate::drift(keys::STAGES_SKIPPED_RATIO),
        ],
    });

    // ── engine: the same acceptance point on every engine ────────────────
    {
        let mut c = campaign(
            "engine.accept.detailed",
            "acceptance point on the per-frame stochastic testbed tier",
            PlatformSpec::Paper,
            accept_workload(),
            accept_config(),
            false,
            4,
        );
        c.ci = true;
        c.gates = vec![Gate::drift(keys::ACTUAL_MEAN_S)];
        cells.push(c);
        let mut c = campaign(
            "engine.accept.detailed_aggregated",
            "same point, frame-aggregated stochastic tier",
            PlatformSpec::Paper,
            accept_workload(),
            accept_config(),
            true,
            4,
        );
        c.ci = true;
        c.gates = vec![
            Gate::drift(keys::ACTUAL_MEAN_S),
            // Aggregation must not move the stochastic mean materially.
            Gate::within_cell(keys::ACTUAL_MEAN_S, "engine.accept.detailed", 0.15),
        ];
        cells.push(c);
        let mut gates = drift2();
        // The coarse predictor must land inside the paper's accuracy
        // envelope of the detailed tier's campaign mean, same run.
        gates.push(Gate::RatioRange {
            key: keys::SIM_TURNAROUND_S,
            other: "engine.accept.detailed",
            other_key: keys::ACTUAL_MEAN_S,
            lo: 0.6,
            hi: 1.4,
        });
        cells.push(sim(
            "engine.accept.coarse",
            "same point on the coarse bulk predictor",
            accept_workload(),
            accept_config(),
            EngineSpec::Coarse,
            3,
            gates,
        ));
    }

    // ── figures: the paper-figure sweeps (records only, no CI gates) ─────
    for stripe in [1usize, 2, 4, 5, 8, 12, 16, 19] {
        cells.push(campaign(
            &format!("figures.fig1.stripe_{stripe}"),
            "Fig 1: Montage turnaround vs stripe width",
            PlatformSpec::Paper,
            WorkloadSpec::Montage { tiles: 19 },
            ConfigSpec::dss(19).stripe(stripe),
            true,
            6,
        ));
    }
    for (tag, wass) in [("dss", false), ("wass", true)] {
        cells.push(campaign(
            &format!("figures.fig4.{tag}"),
            "Fig 4: pipeline benchmark, predicted vs actual",
            PlatformSpec::Paper,
            WorkloadSpec::Pipeline { n: 19, scale: PatternScale::Medium, wass },
            if wass { ConfigSpec::wass(19) } else { ConfigSpec::dss(19) },
            false,
            6,
        ));
    }
    for (tag, wass) in [("dss", false), ("wass", true)] {
        cells.push(campaign(
            &format!("figures.fig5.med_{tag}"),
            "Fig 5: reduce benchmark, medium workload",
            PlatformSpec::Paper,
            WorkloadSpec::Reduce { n: 19, scale: PatternScale::Medium, wass },
            if wass { ConfigSpec::wass(19) } else { ConfigSpec::dss(19) },
            true,
            6,
        ));
        cells.push(campaign(
            &format!("figures.fig5.lg_{tag}"),
            "Fig 5: reduce benchmark, large workload on a heterogeneous platform",
            PlatformSpec::HostSpeed { host: 1, mult: 1.5 },
            WorkloadSpec::Reduce { n: 19, scale: PatternScale::Large, wass },
            if wass { ConfigSpec::wass(19) } else { ConfigSpec::dss(19) },
            true,
            6,
        ));
    }
    for replicas in [1u32, 2, 4] {
        cells.push(campaign(
            &format!("figures.fig6.r{replicas}"),
            "Fig 6: broadcast benchmark vs replication (WASS, round-robin)",
            PlatformSpec::Paper,
            WorkloadSpec::Broadcast { n: 19, scale: PatternScale::Medium, replicas },
            ConfigSpec::wass(19).replication(replicas).round_robin(),
            true,
            6,
        ));
    }
    for chunk_kb in [256u64, 1024, 4096] {
        for n_app in [1usize, 2, 4, 6, 8, 10, 12, 14, 16, 18] {
            cells.push(campaign(
                &format!("figures.fig8.c{chunk_kb}.a{n_app}"),
                "Fig 8: BLAST partitioning sweep (19 workers + manager)",
                PlatformSpec::Paper,
                WorkloadSpec::Blast { n_app, queries: 200 },
                ConfigSpec::partitioned(n_app, 19 - n_app).chunk_kb(chunk_kb),
                true,
                4,
            ));
        }
    }
    for total in [11usize, 17, 20] {
        for n_app in (2..=18usize).step_by(2).filter(|a| a + 1 < total) {
            for chunk_kb in [256u64, 1024] {
                cells.push(campaign(
                    &format!("figures.fig9.n{total}.a{n_app}.c{chunk_kb}"),
                    "Fig 9: BLAST provisioning (total allocation sweep, cost rows)",
                    PlatformSpec::Paper,
                    WorkloadSpec::Blast { n_app, queries: 200 },
                    ConfigSpec::partitioned(n_app, total - 1 - n_app).chunk_kb(chunk_kb),
                    true,
                    4,
                ));
            }
        }
    }
    for (tag, scale, wass) in
        [("med_dss", PatternScale::Medium, false), ("med_wass", PatternScale::Medium, true),
         ("lg_dss", PatternScale::Large, false), ("lg_wass", PatternScale::Large, true)]
    {
        cells.push(campaign(
            &format!("figures.fig10.{tag}"),
            "Fig 10: reduce benchmark on the HDD-backed platform",
            PlatformSpec::Hdd,
            WorkloadSpec::Reduce { n: 19, scale, wass },
            if wass { ConfigSpec::wass(19) } else { ConfigSpec::dss(19) },
            true,
            6,
        ));
    }
    // §3.3 speedup scenarios: time_ratio / resource_ratio come for free on
    // every campaign record; these three are the paper's quoted points.
    cells.push(campaign(
        "figures.speedup.pipeline_med",
        "§3.3: prediction speedup on the medium pipeline",
        PlatformSpec::Paper,
        WorkloadSpec::Pipeline { n: 19, scale: PatternScale::Medium, wass: false },
        ConfigSpec::dss(19),
        true,
        4,
    ));
    cells.push(campaign(
        "figures.speedup.reduce_lg_wass",
        "§3.3: prediction speedup on the large WASS reduce",
        PlatformSpec::Paper,
        WorkloadSpec::Reduce { n: 19, scale: PatternScale::Large, wass: true },
        ConfigSpec::wass(19),
        true,
        4,
    ));
    cells.push(campaign(
        "figures.speedup.blast_14",
        "§3.3: prediction speedup on the 14-worker BLAST partition",
        PlatformSpec::Paper,
        WorkloadSpec::Blast { n_app: 14, queries: 200 },
        ConfigSpec::partitioned(14, 5).chunk_kb(1024),
        true,
        4,
    ));

    // ── trace: flight-recorder overhead and attribution ──────────────────
    {
        let mut gates = drift2();
        // The no-op probe must be free: this cell is spec-identical to
        // `incast.1024` (which runs untraced `simulate_fid` — post-probe,
        // that IS the no-op-probe path), so its per-event cost may exceed
        // the peer's by at most 2% in the same run (min-over-reps
        // wallclock on both sides keeps the bound host-independent).
        gates.push(Gate::ratio_range(keys::NS_PER_EVENT_MIN, "incast.1024", 0.0, 1.02));
        cells.push(sim(
            "trace.overhead",
            "incast.1024 spec re-run as the probe-overhead sentinel",
            WorkloadSpec::Reduce { n: 1023, scale: PatternScale::Small, wass: false },
            ConfigSpec::dss(1023).stripe(64),
            EngineSpec::Coarse,
            3,
            gates,
        ));
    }
    // Record-only attribution rows for the four paper workloads: where
    // does the predicted critical path spend its time? (No gates — these
    // feed analysis, not CI.)
    let attribution: [(&str, WorkloadSpec, ConfigSpec); 4] = [
        (
            "trace.attribution.pipeline",
            WorkloadSpec::Pipeline { n: 19, scale: PatternScale::Medium, wass: false },
            ConfigSpec::dss(19),
        ),
        (
            "trace.attribution.reduce",
            WorkloadSpec::Reduce { n: 19, scale: PatternScale::Medium, wass: false },
            ConfigSpec::dss(19),
        ),
        ("trace.attribution.montage", WorkloadSpec::Montage { tiles: 19 }, ConfigSpec::dss(19)),
        (
            "trace.attribution.blast",
            WorkloadSpec::Blast { n_app: 14, queries: 200 },
            ConfigSpec::partitioned(14, 5).chunk_kb(1024),
        ),
    ];
    for (name, workload, config) in attribution {
        cells.push(extra(CellDef {
            name: name.to_string(),
            ci: true,
            note: "critical-path attribution of the coarse prediction".to_string(),
            platform: PlatformSpec::Paper,
            kind: CellKind::Trace { workload, config, engine: EngineSpec::Coarse },
            gates: Vec::new(),
        }));
    }

    // ── ablations: sensitivity sweeps (records only) ─────────────────────
    cells.push(extra(sim(
        "ablations.fidelity.full",
        "detailed tier, all noise sources on (6 seeds)",
        WorkloadSpec::Pipeline { n: 19, scale: PatternScale::Medium, wass: false },
        ConfigSpec::dss(19),
        EngineSpec::Detailed,
        6,
        Vec::new(),
    )));
    for knob in [
        AblationKnob::ControlRounds,
        AblationKnob::Connections,
        AblationKnob::Mux,
        AblationKnob::Stagger,
        AblationKnob::Jitter,
        AblationKnob::Hetero,
        AblationKnob::ManagerContention,
    ] {
        cells.push(extra(sim(
            &format!("ablations.fidelity.{}", knob.label()),
            "detailed tier with one noise source knocked out (6 seeds)",
            WorkloadSpec::Pipeline { n: 19, scale: PatternScale::Medium, wass: false },
            ConfigSpec::dss(19),
            EngineSpec::DetailedMinus(knob),
            6,
            Vec::new(),
        )));
    }
    for kb in [16u64, 64, 256, 1024] {
        cells.push(CellDef {
            name: format!("ablations.frames.f{kb}"),
            ci: false,
            note: "coarse predictor sensitivity to the modeled wire frame size".into(),
            platform: PlatformSpec::FrameKb(kb),
            kind: CellKind::Sim {
                workload: WorkloadSpec::Pipeline { n: 19, scale: PatternScale::Medium, wass: false },
                config: ConfigSpec::dss(19),
                engine: EngineSpec::Coarse,
                reps: 1,
            },
            gates: Vec::new(),
        });
    }
    for w in [1usize, 2, 4, 8, 16, 32] {
        cells.push(extra(sim(
            &format!("ablations.window.blast.w{w}"),
            "chunk-window sweep on the 14-worker BLAST partition",
            WorkloadSpec::Blast { n_app: 14, queries: 200 },
            ConfigSpec::partitioned(14, 5).chunk_kb(256).window(w),
            EngineSpec::Coarse,
            1,
            Vec::new(),
        )));
        cells.push(extra(sim(
            &format!("ablations.window.single.w{w}"),
            "chunk-window sweep on a single striped reader",
            WorkloadSpec::SingleReader { mb: 512 },
            ConfigSpec::partitioned(1, 8).chunk_kb(256).window(w),
            EngineSpec::Coarse,
            1,
            Vec::new(),
        )));
    }

    cells
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn glob_matching_covers_star_question_and_literal() {
        assert!(glob_match("scale.*", "scale.hosts_64"));
        assert!(glob_match("*", "anything.at_all"));
        assert!(glob_match("faults.r?_c16", "faults.r2_c16"));
        assert!(!glob_match("faults.r?_c16", "faults.r2_c1"));
        assert!(glob_match("*fullstripe*", "incast.4096_fullstripe"));
        assert!(glob_match("incast.4096", "incast.4096"));
        assert!(!glob_match("incast.4096", "incast.4096_fullstripe"));
        assert!(!glob_match("scale.*", "incast.256"));
        assert!(!glob_match("x?", "x"));
    }

    #[test]
    fn cell_names_are_unique_and_well_formed() {
        let cells = registry();
        let mut seen = BTreeSet::new();
        for c in &cells {
            assert!(seen.insert(c.name.clone()), "duplicate cell name {}", c.name);
            assert!(
                c.name.contains('.') && !c.name.contains(['*', '?', '/', ' ']),
                "cell name {:?} must be suite.cell and glob/path-safe",
                c.name
            );
        }
    }

    #[test]
    fn cross_cell_gates_reference_cells_that_run_alongside() {
        let cells = registry();
        let by_name: std::collections::BTreeMap<&str, &CellDef> =
            cells.iter().map(|c| (c.name.as_str(), c)).collect();
        for c in &cells {
            for g in &c.gates {
                if let Some(peer) = g.peer() {
                    let p = by_name
                        .get(peer)
                        .unwrap_or_else(|| panic!("{}: gate peer {peer:?} not registered", c.name));
                    assert!(
                        !c.ci || p.ci,
                        "{}: CI cell gates against non-CI peer {peer}",
                        c.name
                    );
                }
            }
        }
    }

    #[test]
    fn ci_suite_reproduces_the_retired_global_gate() {
        let cells = registry();
        let ci: Vec<&CellDef> = cells.iter().filter(|c| c.ci).collect();
        // Every named row of the old BENCH_frame_path gate is a cell.
        for name in [
            "frame_path.bulk",
            "frame_path.per_frame",
            "scale.hosts_64",
            "scale.hosts_256",
            "scale.hosts_1024",
            "incast.256",
            "incast.1024",
            "incast.4096",
            "incast.4096_fullstripe",
            "service.query_path",
            "service.dedup",
            "service.surrogate",
            "search.delta.cold",
            "search.delta.sweep",
        ] {
            assert!(ci.iter().any(|c| c.name == name), "CI suite lost cell {name}");
        }
        for repl in [1, 2, 3] {
            for crashes in [0, 1, 4, 16] {
                let name = format!("faults.r{repl}_c{crashes}");
                assert!(ci.iter().any(|c| c.name == name), "CI suite lost cell {name}");
            }
        }
        // Deterministic sim cells all carry the drift pair.
        for c in &ci {
            if let CellKind::Sim { .. } = c.kind {
                assert!(
                    c.gates.iter().any(|g| g.needs_baseline()),
                    "{}: deterministic CI cell without a drift gate",
                    c.name
                );
            }
        }
    }

    #[test]
    fn trace_cells_are_wired_as_designed() {
        let cells = registry();
        let ov = cells.iter().find(|c| c.name == "trace.overhead").expect("overhead cell");
        assert!(ov.ci, "the overhead sentinel must gate every CI run");
        assert!(
            ov.gates.iter().any(|g| g.peer() == Some("incast.1024")),
            "overhead is a same-run ratio against incast.1024"
        );
        for wl in ["pipeline", "reduce", "montage", "blast"] {
            let name = format!("trace.attribution.{wl}");
            let c = cells.iter().find(|c| c.name == name).unwrap_or_else(|| panic!("{name}"));
            assert!(!c.ci && c.gates.is_empty(), "{name}: attribution rows are record-only");
            assert!(matches!(c.kind, CellKind::Trace { .. }));
        }
    }

    #[test]
    fn topology_cells_are_wired_as_designed() {
        let cells = registry();
        let get = |name: &str| {
            cells.iter().find(|c| c.name == name).unwrap_or_else(|| panic!("{name} missing"))
        };
        // Identity cells: degenerate one-rack layouts, EqCell-pinned to
        // their star counterparts in the same run.
        for (name, peer) in
            [("topology.star_identity", "incast.1024"), ("topology.star_identity_accept", "frame_path.bulk")]
        {
            let c = get(name);
            assert!(c.ci, "{name} must gate every CI run");
            let PlatformSpec::RackTopo { rack_size, oversub } = c.platform else {
                panic!("{name}: expected a RackTopo platform");
            };
            assert_eq!(oversub, 1.0);
            let cfg = match &c.kind {
                CellKind::Sim { config, .. } => config.build(),
                _ => panic!("{name}: expected a Sim cell"),
            };
            assert!(rack_size >= cfg.n_hosts(), "{name}: one rack must cover every host");
            for key in [keys::EVENTS, keys::SIM_TURNAROUND_S] {
                assert!(
                    c.gates.iter().any(|g| matches!(
                        g,
                        Gate::EqCell { key: k, other, .. } if *k == key && *other == peer
                    )),
                    "{name}: missing EqCell({key}) vs {peer}"
                );
            }
        }
        // Oversubscription curve: monotone vs star, and the 8x point must
        // show a measurable increase (the PR's acceptance floor).
        let c2 = get("topology.oversub_2x");
        assert!(c2.gates.iter().any(|g| g.peer() == Some("incast.1024")));
        let c8 = get("topology.oversub_8x");
        assert!(c8.gates.iter().any(|g| g.peer() == Some("topology.oversub_2x")));
        assert!(
            c8.gates.iter().any(|g| matches!(
                g,
                Gate::RatioRange { key, other, lo, .. }
                    if *key == keys::SIM_TURNAROUND_S && *other == "incast.1024" && *lo > 1.0
            )),
            "oversub_8x must demand a measurable turnaround increase over star"
        );
    }

    #[test]
    fn selection_defaults_to_ci_and_rejects_dead_globs() {
        let cells = registry();
        let ci = select(&cells, &[]).unwrap();
        assert!(ci.iter().all(|c| c.ci));
        assert!(ci.len() >= 20, "CI suite unexpectedly small: {}", ci.len());
        let picked = select(&cells, &["scale.*".into(), "scale.hosts_64".into()]).unwrap();
        assert_eq!(picked.len(), 3, "overlapping globs must not duplicate cells");
        assert!(select(&cells, &["scale.hots_64".into()]).is_err(), "typo globs are errors");
    }
}
