//! The cell runner: execute selected cells, persist records + history,
//! and evaluate gates against per-cell baselines.
//!
//! One run does, in order:
//!
//! 1. resolve the selection ([`crate::bench::registry::select`]);
//! 2. read each selected cell's **armed baseline** from
//!    `<out_dir>/<cell>.json` *before* anything is overwritten;
//! 3. execute the cells (fan-out via
//!    [`crate::coordinator::par_map_indexed`]; default 1 thread so
//!    wallclock keys and same-run ratio gates stay meaningful);
//! 4. derive cross-cell keys (the full-stripe ns/event ratio);
//! 5. write one fresh record per cell and append one line per cell to
//!    `<out_dir>/history/<cell>.jsonl` — the trajectory that replaces
//!    silently overwriting the old global blob;
//! 6. regenerate `BENCH_frame_path.json` (one directory above `out_dir`)
//!    as a summary *view* whenever the full CI suite ran;
//! 7. with `check`, evaluate every gate and report failures **named by
//!    cell** — exit 1 on any failure, 2 on usage/selection errors.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::time::Instant;

use super::gate::{evaluate, GateOutcome};
use super::record::{keys, CellRecord};
use super::registry::{registry, select, CellDef, CellKind, ServiceProbe};
use crate::coordinator;
use crate::model::{simulate_fid, simulate_traced, Config, Platform};
use crate::predict::Predictor;
use crate::service::{GridCoord, Service};
use crate::trace::{critical_path, Class, N_CLASSES};
use crate::testbed::Testbed;
use crate::util::bench::black_box;
use crate::util::jsonw::Json;
use crate::util::stats::{rel_err, Summary};
use crate::util::units::Bytes;
use crate::workload::blast::{blast, BlastParams};

/// Everything `wfpred bench` can ask of a run.
#[derive(Clone, Debug)]
pub struct RunOptions {
    /// Cell-name globs; empty selects the CI suite.
    pub globs: Vec<String>,
    /// Evaluate gates and fail the run on violations.
    pub check: bool,
    /// Record/baseline directory (`results/records` from `rust/`).
    pub out_dir: PathBuf,
    /// Worker threads for cell fan-out. The default 1 keeps wallclock
    /// metrics and same-run ratio gates interference-free.
    pub threads: usize,
    /// Stamped on every record (`$GITHUB_SHA` in CI).
    pub run_id: String,
    /// Append to per-cell history files (off for throwaway runs).
    pub history: bool,
    /// Override every cell's reps/trials (testing hook; 0 = registry
    /// values).
    pub reps_override: u32,
}

impl Default for RunOptions {
    fn default() -> RunOptions {
        RunOptions {
            globs: Vec::new(),
            check: false,
            out_dir: PathBuf::from("results/records"),
            threads: 1,
            run_id: std::env::var("GITHUB_SHA").unwrap_or_else(|_| "local".into()),
            history: true,
            reps_override: 0,
        }
    }
}

/// Structured outcome of a run — what the CLI prints and tests assert on.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    /// 0 = all gates passed, 1 = at least one gate failed, 2 = usage or
    /// selection error.
    pub exit_code: i32,
    /// `(cell, detail)` per gate failure, in registry order.
    pub failures: Vec<(String, String)>,
    /// Cells whose drift gates were skipped for lack of an armed baseline.
    pub bootstrapped: Vec<String>,
    /// Fresh records, in registry order of the selection.
    pub records: Vec<CellRecord>,
}

impl RunReport {
    /// Distinct cell names with at least one failed gate.
    pub fn failing_cells(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for (cell, _) in &self.failures {
            if !out.contains(cell) {
                out.push(cell.clone());
            }
        }
        out
    }
}

/// Print the selection instead of running it (the `--list` path).
pub fn list_cells(globs: &[String]) -> Result<String, String> {
    let cells = registry();
    let picked = select(&cells, globs)?;
    let mut out = String::new();
    for c in &picked {
        out.push_str(&format!(
            "{:34} {:5} {:28} gates:{:2}  {}\n",
            c.name,
            if c.ci { "ci" } else { "extra" },
            c.engine_label(),
            c.gates.len(),
            c.note
        ));
    }
    out.push_str(&format!("{} cell(s)\n", picked.len()));
    Ok(out)
}

/// Execute a bench run end to end. Never panics on gate failures —
/// failures land in the report so callers can localize them.
pub fn run_cells(opts: &RunOptions) -> RunReport {
    let cells = registry();
    let picked = match select(&cells, &opts.globs) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("wfpred bench: {e}");
            return RunReport { exit_code: 2, ..RunReport::default() };
        }
    };

    // Read baselines before any write clobbers them.
    let baselines: BTreeMap<String, CellRecord> = picked
        .iter()
        .filter_map(|c| {
            let path = record_path(&opts.out_dir, &c.name);
            let text = fs::read_to_string(&path).ok()?;
            match CellRecord::parse(&text) {
                Ok(rec) => Some((c.name.clone(), rec)),
                Err(e) => {
                    eprintln!("[bench] {}: unreadable baseline ({e}); treating as unarmed", c.name);
                    None
                }
            }
        })
        .collect();

    let threads = opts.threads.max(1);
    let n = picked.len();
    let fresh: Vec<CellRecord> = coordinator::par_map_indexed(n, threads, |i| {
        let cell = picked[i];
        let rec = execute_cell(cell, &opts.run_id, opts.reps_override);
        println!("[bench] {:34} {}", cell.name, summary_line(&rec));
        rec
    });

    let mut by_name: BTreeMap<String, CellRecord> =
        fresh.iter().map(|r| (r.cell.clone(), r.clone())).collect();
    derive_cross_cell_keys(&mut by_name);
    let fresh: Vec<CellRecord> =
        picked.iter().map(|c| by_name.get(&c.name).expect("executed").clone()).collect();

    if let Err(e) = persist(opts, &fresh) {
        eprintln!("wfpred bench: cannot write records: {e}");
        return RunReport { exit_code: 2, records: fresh, ..RunReport::default() };
    }
    if picked.iter().filter(|c| c.ci).count() == cells.iter().filter(|c| c.ci).count() {
        if let Err(e) = write_summary_view(opts, &by_name) {
            eprintln!("wfpred bench: cannot write summary view: {e}");
        }
    }

    let mut report = RunReport { records: fresh.clone(), ..RunReport::default() };
    if opts.check {
        for (cell, rec) in picked.iter().zip(&fresh) {
            let baseline = baselines.get(&cell.name);
            let mut booted = false;
            for (gate, outcome) in evaluate(&cell.gates, rec, baseline, &by_name) {
                match outcome {
                    GateOutcome::Pass => {}
                    GateOutcome::Fail(detail) => {
                        println!("[bench-check] FAIL {}: {detail}", cell.name);
                        report.failures.push((cell.name.clone(), detail));
                    }
                    GateOutcome::Skip(why) => {
                        if gate.needs_baseline() && baseline.is_none() {
                            booted = true;
                        } else {
                            println!("[bench-check] skip {}: {gate}: {why}", cell.name);
                        }
                    }
                }
            }
            if booted {
                report.bootstrapped.push(cell.name.clone());
            }
        }
        for cell in &report.bootstrapped {
            println!(
                "[bench-check] {cell}: no armed baseline — drift gates skipped until the \
                 arm step commits this run's record (bootstrap)"
            );
        }
        if report.failures.is_empty() {
            println!(
                "[bench-check] OK — {} cell(s), {} bootstrapping",
                fresh.len(),
                report.bootstrapped.len()
            );
        } else {
            let cells = report.failing_cells();
            println!(
                "[bench-check] FAILED — {} gate failure(s) in {} cell(s): {}",
                report.failures.len(),
                cells.len(),
                cells.join(", ")
            );
            report.exit_code = 1;
        }
    }
    report
}

fn record_path(out_dir: &Path, cell: &str) -> PathBuf {
    out_dir.join(format!("{cell}.json"))
}

fn persist(opts: &RunOptions, fresh: &[CellRecord]) -> Result<(), String> {
    fs::create_dir_all(&opts.out_dir).map_err(|e| e.to_string())?;
    let hist_dir = opts.out_dir.join("history");
    if opts.history {
        fs::create_dir_all(&hist_dir).map_err(|e| e.to_string())?;
    }
    for rec in fresh {
        let line = rec.render_compact();
        fs::write(record_path(&opts.out_dir, &rec.cell), format!("{line}\n"))
            .map_err(|e| e.to_string())?;
        if opts.history {
            let path = hist_dir.join(format!("{}.jsonl", rec.cell));
            let mut body = fs::read_to_string(&path).unwrap_or_default();
            body.push_str(&line);
            body.push('\n');
            fs::write(&path, body).map_err(|e| e.to_string())?;
        }
    }
    Ok(())
}

/// Keys that only exist relative to a sibling cell of the same run.
fn derive_cross_cell_keys(by_name: &mut BTreeMap<String, CellRecord>) {
    let base = by_name.get("incast.4096").and_then(|r| r.get(keys::NS_PER_EVENT_MIN));
    if let (Some(base), Some(fs_rec)) = (base, by_name.get_mut("incast.4096_fullstripe")) {
        if let Some(v) = fs_rec.get(keys::NS_PER_EVENT_MIN) {
            if base > 0.0 {
                fs_rec.set(keys::NS_PER_EVENT_VS_STRIPE64_X, v / base);
            }
        }
    }
}

fn summary_line(rec: &CellRecord) -> String {
    let mut parts: Vec<String> = Vec::new();
    for key in [keys::EVENTS, keys::SIM_TURNAROUND_S, keys::ACTUAL_MEAN_S, keys::REL_ERR,
        keys::WARM_SPEEDUP_X, keys::DEDUP_FACTOR_X, keys::SURROGATE_MAX_REL_ERR,
        keys::EVALS_PER_SEC, keys::STAGES_SKIPPED_RATIO]
    {
        if let Some(v) = rec.get(key) {
            parts.push(format!("{key}={v:.6}"));
        }
    }
    format!("[{}] {}", rec.engine, parts.join(" "))
}

// ── cell execution ──────────────────────────────────────────────────────

fn execute_cell(cell: &CellDef, run_id: &str, reps_override: u32) -> CellRecord {
    let mut rec = CellRecord::new(&cell.name, &cell.engine_label(), run_id);
    let plat = cell.platform.build();
    match &cell.kind {
        CellKind::Sim { workload, config, engine, reps } => {
            let reps = if reps_override > 0 { reps_override } else { *reps }.max(1);
            let wl = workload.build();
            let cfg = config.build();
            if reps > 1 {
                black_box(simulate_fid(&wl, &cfg, &plat, engine.fidelity(0)).events);
            }
            let mut wall = Summary::new();
            let mut events = Summary::new();
            let mut cancelled = Summary::new();
            let mut sim_s = Summary::new();
            let mut ledger = [Summary::new(), Summary::new(), Summary::new(), Summary::new(),
                Summary::new()];
            for seed in 0..reps {
                let t0 = Instant::now();
                let r = simulate_fid(&wl, &cfg, &plat, engine.fidelity(seed as u64));
                wall.add(t0.elapsed().as_secs_f64());
                events.add(r.events as f64);
                cancelled.add(r.events_cancelled as f64);
                sim_s.add(r.turnaround.as_secs_f64());
                for (slot, v) in ledger.iter_mut().zip([
                    r.fault_retries,
                    r.fault_failovers,
                    r.fault_timeouts,
                    r.unrecoverable_ops,
                    r.failed_tasks,
                ]) {
                    slot.add(v as f64);
                }
                black_box(r.turnaround);
            }
            let ev = events.mean();
            rec.set(keys::REPS, reps as f64)
                .set(keys::EVENTS, ev)
                .set(keys::EVENTS_CANCELLED, cancelled.mean())
                .set(keys::STALE_EVENT_RATIO, cancelled.mean() / (ev + cancelled.mean()).max(1.0))
                .set(keys::SIM_TURNAROUND_S, sim_s.mean())
                .set(keys::WALL_SECS, wall.mean())
                .set(keys::WALL_SECS_MIN, wall.min())
                .set(keys::NS_PER_EVENT, wall.mean() * 1e9 / ev.max(1.0))
                .set(keys::NS_PER_EVENT_MIN, wall.min() * 1e9 / ev.max(1.0))
                .set(keys::EVENTS_PER_SEC, ev / wall.mean().max(1e-12));
            for (key, slot) in [
                (keys::FAULT_RETRIES, 0),
                (keys::FAULT_FAILOVERS, 1),
                (keys::FAULT_TIMEOUTS, 2),
                (keys::UNRECOVERABLE_OPS, 3),
                (keys::FAILED_TASKS, 4),
            ] {
                rec.set(key, ledger[slot].mean());
            }
            if config.crashes > 0 || config.replication.is_some() {
                rec.set(keys::REPLICATION, f64::from(config.replication.unwrap_or(1)));
                rec.set(keys::CRASHES, config.crashes as f64);
            }
        }
        CellKind::Campaign { workload, config, aggregated, trials } => {
            let trials = if reps_override > 0 { u64::from(reps_override) } else { *trials }.max(1);
            let wl = workload.build();
            let cfg = config.build();
            let mut tb = Testbed::new(plat.clone()).with_trials(trials, trials);
            if *aggregated {
                tb = tb.aggregated();
            }
            let t0 = Instant::now();
            let stats = tb.run(&wl, &cfg);
            let camp_wall = t0.elapsed().as_secs_f64();
            let pred = Predictor::new(plat).predict(&wl, &cfg);
            let actual = stats.turnaround.mean();
            let predicted = pred.turnaround.as_secs_f64();
            let hosts = cfg.n_hosts() as f64;
            let pw = pred.predictor_wallclock_secs.max(1e-12);
            rec.set(keys::TRIALS, stats.turnaround.n() as f64)
                .set(keys::ACTUAL_MEAN_S, actual)
                .set(keys::ACTUAL_STD_S, stats.turnaround.std())
                .set(keys::PREDICTED_S, predicted)
                .set(keys::REL_ERR, rel_err(predicted, actual))
                .set(keys::EVENTS, pred.report.events as f64)
                .set(keys::PREDICTOR_WALL_SECS, pred.predictor_wallclock_secs)
                .set(keys::TIME_RATIO, actual / pw)
                .set(keys::RESOURCE_RATIO, actual / pw * hosts)
                .set(keys::ACTUAL_COST_NODE_S, actual * hosts)
                .set(keys::PRED_COST_NODE_S, pred.cost_node_secs)
                .set(keys::WALL_SECS, camp_wall);
        }
        CellKind::Service(probe) => {
            run_service_probe(*probe, &mut rec);
        }
        CellKind::Trace { workload, config, engine } => {
            let wl = workload.build();
            let cfg = config.build();
            let t0 = Instant::now();
            let (r, trace) = simulate_traced(&wl, &cfg, &plat, engine.fidelity(0));
            let wall = t0.elapsed().as_secs_f64();
            let attr = critical_path(&trace);
            debug_assert!(attr.tiles_exactly(), "{}: attribution must tile", cell.name);
            let totals = attr.totals();
            // Keyed in Class::ALL order — one record key per class, so the
            // eight cp_*_s values sum to sim_turnaround_s by construction.
            const CP_KEYS: [&str; N_CLASSES] = [
                keys::CP_CLIENT_COMPUTE_S,
                keys::CP_OUT_NIC_S,
                keys::CP_IN_NIC_S,
                keys::CP_CORE_LINK_S,
                keys::CP_STORAGE_S,
                keys::CP_MANAGER_S,
                keys::CP_FAULT_RECOVERY_S,
                keys::CP_IDLE_S,
            ];
            rec.set(keys::REPS, 1.0)
                .set(keys::EVENTS, r.events as f64)
                .set(keys::SIM_TURNAROUND_S, r.turnaround.as_secs_f64())
                .set(keys::WALL_SECS, wall)
                .set(keys::TRACE_SPANS, trace.n_spans() as f64);
            for c in Class::ALL {
                rec.set(CP_KEYS[c.index()], totals[c.index()] as f64 / 1e9);
            }
        }
    }
    rec
}

/// The acceptance workload the service probes serve (same point as the
/// `frame_path.*` / `engine.accept.*` cells).
fn service_point() -> (crate::workload::Workload, Config) {
    let wl = blast(10, &BlastParams { queries: 40, ..BlastParams::default() });
    let cfg = Config::partitioned(10, 5, Bytes::mb(1));
    (wl, cfg)
}

fn run_service_probe(probe: ServiceProbe, rec: &mut CellRecord) {
    let (wl, cfg) = service_point();
    match probe {
        ServiceProbe::QueryPath => {
            let mut cold = Summary::new();
            for _ in 0..3 {
                let svc = Service::new(Predictor::new(Platform::paper_testbed()));
                let t0 = Instant::now();
                black_box(svc.evaluate(&wl, &cfg).turnaround);
                cold.add(t0.elapsed().as_secs_f64());
            }
            let warm_svc = Service::new(Predictor::new(Platform::paper_testbed()));
            let _ = warm_svc.evaluate(&wl, &cfg);
            let warm_iters = 200u32;
            let t0 = Instant::now();
            for _ in 0..warm_iters {
                black_box(warm_svc.evaluate(&wl, &cfg).turnaround);
            }
            let warm = t0.elapsed().as_secs_f64() / f64::from(warm_iters);
            rec.set(keys::COLD_SECS, cold.mean())
                .set(keys::WARM_SECS, warm)
                .set(keys::WARM_SPEEDUP_X, cold.mean() / warm.max(1e-12));
        }
        ServiceProbe::Dedup => {
            let clients = 8usize;
            let per_client = 4usize;
            let svc = Service::new(Predictor::new(Platform::paper_testbed()));
            let t0 = Instant::now();
            coordinator::par_map_indexed(clients, clients, |_| {
                for _ in 0..per_client {
                    black_box(svc.evaluate(&wl, &cfg).turnaround);
                }
            });
            let wall = t0.elapsed().as_secs_f64();
            let sims = svc.stats().misses;
            rec.set(keys::DEDUP_CLIENTS, clients as f64)
                .set(keys::DEDUP_QUERIES, (clients * per_client) as f64)
                .set(keys::DEDUP_SIMS, sims as f64)
                .set(keys::DEDUP_FACTOR_X, (clients * per_client) as f64 / sims.max(1) as f64)
                .set(keys::WALL_SECS, wall);
        }
        ServiceProbe::Surrogate => {
            let svc = Service::new(Predictor::new(Platform::paper_testbed()));
            let family = 0xFA57_11E5u64;
            let seed_apps = [1usize, 4, 7, 10, 13, 14];
            let params = BlastParams { queries: 40, ..BlastParams::default() };
            for &n_app in &seed_apps {
                let cfg = Config::partitioned(n_app, 15 - n_app, Bytes::kb(256));
                let wl = blast(n_app, &params);
                let p = svc.evaluate(&wl, &cfg);
                svc.note_sample(family, GridCoord::of(&cfg), p.turnaround.as_secs_f64());
            }
            let mut queries = 0u64;
            let mut answers = 0u64;
            let mut max_est_err = 0.0f64;
            let mut max_rel_err = 0.0f64;
            let mut spent = 0.0f64;
            for n_app in 1..=14usize {
                if seed_apps.contains(&n_app) {
                    continue;
                }
                queries += 1;
                let cfg = Config::partitioned(n_app, 15 - n_app, Bytes::kb(256));
                let t0 = Instant::now();
                let est = svc.interpolate(family, GridCoord::of(&cfg), f64::MAX);
                spent += t0.elapsed().as_secs_f64();
                if let Some(est) = est {
                    answers += 1;
                    max_est_err = max_est_err.max(est.est_err);
                    // Exact truth for the same off-grid point — the
                    // interpolator never sees it, so this is a real
                    // held-out error, and it is deterministic.
                    let wl = blast(n_app, &params);
                    let exact = svc.evaluate(&wl, &cfg).turnaround.as_secs_f64();
                    max_rel_err = max_rel_err.max(rel_err(est.time_s, exact));
                    black_box(est.time_s);
                }
            }
            rec.set(keys::SURROGATE_QUERIES, queries as f64)
                .set(keys::SURROGATE_ANSWERS, answers as f64)
                .set(keys::SURROGATE_MAX_EST_ERR, max_est_err)
                .set(keys::SURROGATE_MAX_REL_ERR, max_rel_err)
                .set(keys::SURROGATE_SECS_PER_QUERY, spent / queries.max(1) as f64);
        }
        ServiceProbe::DeltaSweep | ServiceProbe::DeltaCold => {
            run_delta_probe(matches!(probe, ServiceProbe::DeltaSweep), rec);
        }
    }
}

/// The delta-probe workload: a heavy stripe-insensitive stage (node-pinned
/// files, so its fingerprint ignores the stripe width) feeding one tiny
/// stripe-sensitive aggregation. Single-knob stripe neighbors then share
/// the expensive stage-0 prefix and replay only the cheap tail.
fn delta_sweep_workload() -> crate::workload::Workload {
    use crate::util::units::SimTime;
    use crate::workload::{FileHint, FileSpec, TaskSpec, Workload};
    let mut w = Workload::new("delta-sweep");
    let db = w.add_file(FileSpec::new("db", Bytes::mb(16)).hint(FileHint::OnNode(0)).prestaged());
    let mut mids = Vec::new();
    for i in 0..12usize {
        let f = w
            .add_file(FileSpec::new(format!("mid{i}"), Bytes::mb(1)).hint(FileHint::OnNode(i % 8)));
        mids.push(f);
        w.add_task(
            TaskSpec::new(format!("t0-{i}"), 0).reads(db).writes(f).compute(SimTime::from_ms(5)),
        );
    }
    let out = w.add_file(FileSpec::new("out", Bytes::mb(1)));
    let mut agg = TaskSpec::new("t1", 1).writes(out);
    for &m in &mids {
        agg = agg.reads(m);
    }
    w.add_task(agg);
    w
}

/// The `search.delta.*` cells: the same single-knob stripe sweep through
/// a delta-enabled (`delta = true`) or delta-disabled service. The sweep
/// cell's gates compare the two records from the same run.
fn run_delta_probe(delta: bool, rec: &mut CellRecord) {
    let wl = delta_sweep_workload();
    let mut svc = Service::new(Predictor::new(Platform::paper_testbed()));
    if !delta {
        svc = svc.without_delta();
    }
    let stripes = [1usize, 2, 3, 4, 5, 6, 7, 8];
    let t0 = Instant::now();
    // Sum in sweep order: delta answers are bit-identical to cold ones,
    // so identical doubles summed in identical order give exact cross-
    // cell equality on `turnaround_sum_s`.
    let mut sum_s = 0.0f64;
    for &w in &stripes {
        let cfg = Config::partitioned(4, 8, Bytes::mb(1)).with_stripe(w);
        sum_s += svc.evaluate(&wl, &cfg).turnaround.as_secs_f64();
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = svc.stats();
    let stage_work = (s.delta_stages_skipped + s.delta_stages_replayed).max(1);
    rec.set(keys::EVALS_PER_SEC, stripes.len() as f64 / wall.max(1e-12))
        .set(keys::TURNAROUND_SUM_S, sum_s)
        .set(keys::DELTA_HITS, s.delta_hits as f64)
        .set(keys::DELTA_STAGES_SKIPPED, s.delta_stages_skipped as f64)
        .set(keys::DELTA_STAGES_REPLAYED, s.delta_stages_replayed as f64)
        .set(keys::STAGES_SKIPPED_RATIO, s.delta_stages_skipped as f64 / stage_work as f64)
        .set(keys::WALL_SECS, wall);
}

// ── the legacy summary view ─────────────────────────────────────────────

/// Regenerate `results/BENCH_frame_path.json` as a *generated view* over
/// the per-cell records (kept so dashboards and muscle memory pointing at
/// the old path keep working; the records are the source of truth — see
/// `results/README.md`).
fn write_summary_view(
    opts: &RunOptions,
    by_name: &BTreeMap<String, CellRecord>,
) -> Result<(), String> {
    let path = opts
        .out_dir
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .join("BENCH_frame_path.json");
    let cell = |name: &str| by_name.get(name);
    let mut j = Json::obj()
        .set(
            "generated_from",
            "results/records/ (wfpred bench; do not edit or gate on this file)",
        )
        .set("run", by_name.values().next().map(|r| r.run_id.clone()).unwrap_or_default());
    if let (Some(b), Some(p)) = (cell("frame_path.bulk"), cell("frame_path.per_frame")) {
        let (eb, ep) = (b.get(keys::EVENTS).unwrap_or(0.0), p.get(keys::EVENTS).unwrap_or(0.0));
        let (sb, sp) = (
            b.get(keys::SIM_TURNAROUND_S).unwrap_or(0.0),
            p.get(keys::SIM_TURNAROUND_S).unwrap_or(0.0),
        );
        j = j
            .set("event_reduction_x", if eb > 0.0 { ep / eb } else { 0.0 })
            .set("turnaround_rel_err", rel_err(sb, sp));
    }
    for (section, prefix) in [
        ("frame_path", "frame_path."),
        ("scaling", "scale."),
        ("incast", "incast."),
        ("faults", "faults."),
        ("service", "service."),
        ("engines", "engine."),
    ] {
        let mut sec = Json::obj();
        let mut any = false;
        for (name, rec) in by_name.iter().filter(|(n, _)| n.starts_with(prefix)) {
            let mut row = Json::obj().set("engine", rec.engine.as_str());
            for (k, v) in rec.metrics() {
                row = row.set(k, *v);
            }
            sec = sec.set(&name[prefix.len()..], row);
            any = true;
        }
        if any {
            j = j.set(section, sec);
        }
    }
    fs::write(&path, j.render() + "\n").map_err(|e| e.to_string())
}
