//! The gate DSL: per-cell pass/fail predicates over [`CellRecord`]s.
//!
//! Three gate families, distinguished by what they compare against (see
//! `rust/METHODOLOGY.md` § Gate semantics):
//!
//! * **Absolute** ([`Gate::Min`], [`Gate::Max`], [`Gate::Range`],
//!   [`Gate::GeKey`]) — a bound on this run's record alone. Always
//!   enforced, including on bootstrap runs.
//! * **Drift** ([`Gate::Drift`]) — fresh value within a relative
//!   tolerance of the *same cell's* armed baseline record. Skipped (with
//!   a note) while the cell has no committed baseline — that is the
//!   bootstrap state, localized to the cell.
//! * **Same-run cross-cell** ([`Gate::EqCell`], [`Gate::WithinCell`],
//!   [`Gate::GeCell`], [`Gate::LeCell`], [`Gate::RatioRange`]) — this
//!   cell's fresh value against a *peer cell's* fresh value from the same
//!   run. Host-independent (both sides saw the same machine and load), so
//!   these hold even on bootstrap runs. If the peer was not selected into
//!   the run, the gate is skipped with a note — only a full `ci` suite
//!   run enforces every cross-cell gate.
//!
//! A key missing from the *fresh* record always fails the gate; a key
//! missing from a baseline record only skips the drift comparison (the
//! baseline predates the key).

use std::collections::BTreeMap;
use std::fmt;

use super::record::CellRecord;
use crate::util::bench::within_rel;

/// Default drift tolerance: ±10 %, matching the retired global gate.
pub const DRIFT_TOL: f64 = 0.10;

/// One pass/fail predicate attached to a cell definition.
#[derive(Clone, Debug)]
pub enum Gate {
    /// `rec[key] >= min`.
    Min { key: &'static str, min: f64 },
    /// `rec[key] <= max`.
    Max { key: &'static str, max: f64 },
    /// `lo <= rec[key] <= hi` (both inclusive).
    Range { key: &'static str, lo: f64, hi: f64 },
    /// `rec[key] >= rec[floor_key]` — both keys from this cell's record
    /// (e.g. dedup factor must reach the concurrent client count).
    GeKey { key: &'static str, floor_key: &'static str },
    /// `|rec[key] - base[key]| <= tol * |base[key]|` vs the armed
    /// baseline (exact match required when the baseline value is zero).
    Drift { key: &'static str, tol: f64 },
    /// `rec[key] == peer[other_key]` exactly (deterministic invariants,
    /// e.g. a zero-crash fault cell reproducing the fault-free event count).
    EqCell { key: &'static str, other: &'static str, other_key: &'static str },
    /// `|rec[key] - peer[other_key]| <= tol * |peer[other_key]|`.
    WithinCell { key: &'static str, other: &'static str, other_key: &'static str, tol: f64 },
    /// `rec[key] >= peer[other_key] * (1 - slack)` — monotone curves.
    GeCell { key: &'static str, other: &'static str, other_key: &'static str, slack: f64 },
    /// `rec[key] <= peer[other_key] * factor` — bounded blow-up.
    LeCell { key: &'static str, other: &'static str, other_key: &'static str, factor: f64 },
    /// `lo < rec[key] / peer[other_key] <= hi` (lo exclusive, hi
    /// inclusive — a ratio of positive quantities is never 0).
    RatioRange { key: &'static str, other: &'static str, other_key: &'static str, lo: f64, hi: f64 },
}

impl Gate {
    /// Drift gate at the default ±10 % tolerance.
    pub fn drift(key: &'static str) -> Gate {
        Gate::Drift { key, tol: DRIFT_TOL }
    }

    /// Same-key equality against a peer cell.
    pub fn eq_cell(key: &'static str, other: &'static str) -> Gate {
        Gate::EqCell { key, other, other_key: key }
    }

    /// Same-key relative band against a peer cell.
    pub fn within_cell(key: &'static str, other: &'static str, tol: f64) -> Gate {
        Gate::WithinCell { key, other, other_key: key, tol }
    }

    /// Same-key monotone floor against a peer cell.
    pub fn ge_cell(key: &'static str, other: &'static str, slack: f64) -> Gate {
        Gate::GeCell { key, other, other_key: key, slack }
    }

    /// Same-key factor ceiling against a peer cell.
    pub fn le_cell(key: &'static str, other: &'static str, factor: f64) -> Gate {
        Gate::LeCell { key, other, other_key: key, factor }
    }

    /// Same-key ratio band against a peer cell.
    pub fn ratio_range(key: &'static str, other: &'static str, lo: f64, hi: f64) -> Gate {
        Gate::RatioRange { key, other, other_key: key, lo, hi }
    }

    /// The peer cell this gate reads from, if it is a cross-cell gate.
    pub fn peer(&self) -> Option<&'static str> {
        match self {
            Gate::EqCell { other, .. }
            | Gate::WithinCell { other, .. }
            | Gate::GeCell { other, .. }
            | Gate::LeCell { other, .. }
            | Gate::RatioRange { other, .. } => Some(other),
            _ => None,
        }
    }

    /// Whether this gate needs an armed baseline to be enforceable.
    pub fn needs_baseline(&self) -> bool {
        matches!(self, Gate::Drift { .. })
    }
}

impl fmt::Display for Gate {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Gate::Min { key, min } => write!(f, "{key} >= {min}"),
            Gate::Max { key, max } => write!(f, "{key} <= {max}"),
            Gate::Range { key, lo, hi } => write!(f, "{key} in [{lo}, {hi}]"),
            Gate::GeKey { key, floor_key } => write!(f, "{key} >= {floor_key}"),
            Gate::Drift { key, tol } => write!(f, "{key} within {:.0}% of baseline", tol * 100.0),
            Gate::EqCell { key, other, other_key } => write!(f, "{key} == {other}.{other_key}"),
            Gate::WithinCell { key, other, other_key, tol } => {
                write!(f, "{key} within {:.1}% of {other}.{other_key}", tol * 100.0)
            }
            Gate::GeCell { key, other, other_key, slack } => {
                write!(f, "{key} >= {other}.{other_key} (slack {:.1}%)", slack * 100.0)
            }
            Gate::LeCell { key, other, other_key, factor } => {
                write!(f, "{key} <= {factor} x {other}.{other_key}")
            }
            Gate::RatioRange { key, other, other_key, lo, hi } => {
                write!(f, "{key} / {other}.{other_key} in ({lo}, {hi}]")
            }
        }
    }
}

/// One gate's outcome for one cell in one run.
#[derive(Clone, Debug, PartialEq)]
pub enum GateOutcome {
    Pass,
    /// Gate violated (or a required key missing from the fresh record) —
    /// the detail names the gate and both values.
    Fail(String),
    /// Gate not enforceable this run (bootstrap, baseline predates the
    /// key, or the peer cell was not selected) — never an error.
    Skip(String),
}

/// Evaluate every gate of one cell. `baseline` is the cell's armed record
/// (None while bootstrapping); `peers` maps cell name → fresh record for
/// everything executed this run (including `fresh` itself).
pub fn evaluate(
    gates: &[Gate],
    fresh: &CellRecord,
    baseline: Option<&CellRecord>,
    peers: &BTreeMap<String, CellRecord>,
) -> Vec<(Gate, GateOutcome)> {
    gates.iter().map(|g| (g.clone(), eval_one(g, fresh, baseline, peers))).collect()
}

fn eval_one(
    gate: &Gate,
    fresh: &CellRecord,
    baseline: Option<&CellRecord>,
    peers: &BTreeMap<String, CellRecord>,
) -> GateOutcome {
    let need = |key: &'static str| -> Result<f64, GateOutcome> {
        fresh
            .get(key)
            .ok_or_else(|| GateOutcome::Fail(format!("fresh record lacks key {key:?}")))
    };
    let peer_val = |other: &'static str, key: &'static str| -> Result<f64, GateOutcome> {
        let Some(peer) = peers.get(other) else {
            return Err(GateOutcome::Skip(format!("peer cell {other} not in this run")));
        };
        peer.get(key)
            .ok_or_else(|| GateOutcome::Fail(format!("peer {other} lacks key {key:?}")))
    };
    let res = match *gate {
        Gate::Min { key, min } => need(key).map(|v| (v >= min, format!("{v} < {min}"))),
        Gate::Max { key, max } => need(key).map(|v| (v <= max, format!("{v} > {max}"))),
        Gate::Range { key, lo, hi } => {
            need(key).map(|v| (v >= lo && v <= hi, format!("{v} outside [{lo}, {hi}]")))
        }
        Gate::GeKey { key, floor_key } => match (need(key), need(floor_key)) {
            (Ok(v), Ok(floor)) => Ok((v >= floor, format!("{v} < {floor_key} = {floor}"))),
            (Err(e), _) | (_, Err(e)) => Err(e),
        },
        Gate::Drift { key, tol } => {
            let fresh_v = match need(key) {
                Ok(v) => v,
                Err(e) => return e,
            };
            let Some(base) = baseline else {
                return GateOutcome::Skip("no armed baseline (bootstrap)".into());
            };
            let Some(base_v) = base.get(key) else {
                return GateOutcome::Skip(format!("baseline predates key {key:?}"));
            };
            Ok((
                within_rel(fresh_v, base_v, tol),
                format!("{fresh_v} vs baseline {base_v} (tol {:.0}%)", tol * 100.0),
            ))
        }
        Gate::EqCell { key, other, other_key } => match (need(key), peer_val(other, other_key)) {
            (Ok(v), Ok(p)) => Ok((v == p, format!("{v} != {other}.{other_key} = {p}"))),
            (Err(e), _) | (_, Err(e)) => Err(e),
        },
        Gate::WithinCell { key, other, other_key, tol } => {
            match (need(key), peer_val(other, other_key)) {
                (Ok(v), Ok(p)) => Ok((
                    within_rel(v, p, tol),
                    format!("{v} vs {other}.{other_key} = {p} (tol {:.1}%)", tol * 100.0),
                )),
                (Err(e), _) | (_, Err(e)) => Err(e),
            }
        }
        Gate::GeCell { key, other, other_key, slack } => {
            match (need(key), peer_val(other, other_key)) {
                (Ok(v), Ok(p)) => {
                    Ok((v >= p * (1.0 - slack), format!("{v} < {other}.{other_key} = {p}")))
                }
                (Err(e), _) | (_, Err(e)) => Err(e),
            }
        }
        Gate::LeCell { key, other, other_key, factor } => {
            match (need(key), peer_val(other, other_key)) {
                (Ok(v), Ok(p)) => {
                    Ok((v <= p * factor, format!("{v} > {factor} x {other}.{other_key} = {p}")))
                }
                (Err(e), _) | (_, Err(e)) => Err(e),
            }
        }
        Gate::RatioRange { key, other, other_key, lo, hi } => {
            match (need(key), peer_val(other, other_key)) {
                (Ok(v), Ok(p)) => {
                    let ratio = if p == 0.0 { f64::INFINITY } else { v / p };
                    Ok((ratio > lo && ratio <= hi, format!("ratio {ratio:.4} outside ({lo}, {hi}]")))
                }
                (Err(e), _) | (_, Err(e)) => Err(e),
            }
        }
    };
    match res {
        Ok((true, _)) => GateOutcome::Pass,
        Ok((false, why)) => GateOutcome::Fail(format!("{gate}: {why}")),
        Err(outcome) => outcome,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::record::keys;

    fn rec(cell: &str, kv: &[(&'static str, f64)]) -> CellRecord {
        let mut r = CellRecord::new(cell, "coarse", "test");
        for (k, v) in kv {
            r.set(k, *v);
        }
        r
    }

    fn peers(recs: &[CellRecord]) -> BTreeMap<String, CellRecord> {
        recs.iter().map(|r| (r.cell.clone(), r.clone())).collect()
    }

    fn one(g: Gate, fresh: &CellRecord, base: Option<&CellRecord>) -> GateOutcome {
        let p = peers(std::slice::from_ref(fresh));
        evaluate(&[g], fresh, base, &p).pop().unwrap().1
    }

    #[test]
    fn absolute_gates_hit_their_edges() {
        let r = rec("a.x", &[(keys::STALE_EVENT_RATIO, 0.5), (keys::EVENTS, 10.0)]);
        assert_eq!(one(Gate::Range { key: keys::STALE_EVENT_RATIO, lo: 0.0, hi: 0.5 }, &r, None), GateOutcome::Pass);
        assert!(matches!(
            one(Gate::Range { key: keys::STALE_EVENT_RATIO, lo: 0.0, hi: 0.49 }, &r, None),
            GateOutcome::Fail(_)
        ));
        assert_eq!(one(Gate::Min { key: keys::EVENTS, min: 10.0 }, &r, None), GateOutcome::Pass);
        assert!(matches!(one(Gate::Min { key: keys::EVENTS, min: 10.1 }, &r, None), GateOutcome::Fail(_)));
        assert!(matches!(
            one(Gate::Min { key: keys::UNRECOVERABLE_OPS, min: 1.0 }, &r, None),
            GateOutcome::Fail(_)
        ), "missing fresh key is a failure, not a skip");
    }

    #[test]
    fn ge_key_compares_two_keys_of_one_record() {
        let r = rec("svc.dedup", &[(keys::DEDUP_FACTOR_X, 32.0), (keys::DEDUP_CLIENTS, 8.0)]);
        let g = Gate::GeKey { key: keys::DEDUP_FACTOR_X, floor_key: keys::DEDUP_CLIENTS };
        assert_eq!(one(g, &r, None), GateOutcome::Pass);
        let low = rec("svc.dedup", &[(keys::DEDUP_FACTOR_X, 7.9), (keys::DEDUP_CLIENTS, 8.0)]);
        let g = Gate::GeKey { key: keys::DEDUP_FACTOR_X, floor_key: keys::DEDUP_CLIENTS };
        assert!(matches!(one(g, &low, None), GateOutcome::Fail(_)));
    }

    #[test]
    fn drift_skips_on_bootstrap_and_fails_past_tolerance() {
        let fresh = rec("a.x", &[(keys::EVENTS, 110.0)]);
        let g = Gate::drift(keys::EVENTS);
        assert!(matches!(one(g.clone(), &fresh, None), GateOutcome::Skip(_)));
        let base = rec("a.x", &[(keys::EVENTS, 100.0)]);
        assert_eq!(one(g.clone(), &fresh, Some(&base)), GateOutcome::Pass, "exactly +10% passes");
        let hot = rec("a.x", &[(keys::EVENTS, 111.0)]);
        assert!(matches!(one(g.clone(), &hot, Some(&base)), GateOutcome::Fail(_)));
        let stale_base = rec("a.x", &[(keys::SIM_TURNAROUND_S, 1.0)]);
        assert!(
            matches!(one(g, &fresh, Some(&stale_base)), GateOutcome::Skip(_)),
            "baseline lacking the key skips, not fails"
        );
    }

    #[test]
    fn drift_vs_zero_baseline_requires_exact_match() {
        let base = rec("f.c0", &[(keys::UNRECOVERABLE_OPS, 0.0)]);
        let exact = rec("f.c0", &[(keys::UNRECOVERABLE_OPS, 0.0)]);
        let off = rec("f.c0", &[(keys::UNRECOVERABLE_OPS, 1.0)]);
        let g = Gate::drift(keys::UNRECOVERABLE_OPS);
        assert_eq!(one(g.clone(), &exact, Some(&base)), GateOutcome::Pass);
        assert!(matches!(one(g, &off, Some(&base)), GateOutcome::Fail(_)));
    }

    #[test]
    fn cross_cell_gates_use_peer_records_from_the_same_run() {
        let a = rec("curve.c0", &[(keys::SIM_TURNAROUND_S, 10.0), (keys::EVENTS, 500.0)]);
        let b = rec("curve.c1", &[(keys::SIM_TURNAROUND_S, 9.96), (keys::EVENTS, 500.0)]);
        let p = peers(&[a.clone(), b.clone()]);
        // Monotone with 0.5% slack: 9.96 >= 10.0 * 0.995 just passes.
        let g = Gate::ge_cell(keys::SIM_TURNAROUND_S, "curve.c0", 0.005);
        assert_eq!(evaluate(&[g], &b, None, &p).pop().unwrap().1, GateOutcome::Pass);
        let g = Gate::ge_cell(keys::SIM_TURNAROUND_S, "curve.c0", 0.001);
        assert!(matches!(evaluate(&[g], &b, None, &p).pop().unwrap().1, GateOutcome::Fail(_)));
        // Exact event-count equality across cells.
        let g = Gate::eq_cell(keys::EVENTS, "curve.c0");
        assert_eq!(evaluate(&[g], &b, None, &p).pop().unwrap().1, GateOutcome::Pass);
        // Factor ceiling.
        let g = Gate::le_cell(keys::SIM_TURNAROUND_S, "curve.c0", 3.0);
        assert_eq!(evaluate(&[g], &b, None, &p).pop().unwrap().1, GateOutcome::Pass);
    }

    #[test]
    fn ratio_range_is_exclusive_low_inclusive_high() {
        let base = rec("i.s64", &[(keys::NS_PER_EVENT_MIN, 100.0)]);
        let exact = rec("i.fs", &[(keys::NS_PER_EVENT_MIN, 110.0)]);
        let p = peers(&[base.clone(), exact.clone()]);
        let g = Gate::ratio_range(keys::NS_PER_EVENT_MIN, "i.s64", 0.0, 1.1);
        assert_eq!(evaluate(&[g.clone()], &exact, None, &p).pop().unwrap().1, GateOutcome::Pass);
        let over = rec("i.fs", &[(keys::NS_PER_EVENT_MIN, 110.2)]);
        let p = peers(&[base.clone(), over.clone()]);
        assert!(matches!(evaluate(&[g.clone()], &over, None, &p).pop().unwrap().1, GateOutcome::Fail(_)));
        let zero = rec("i.fs", &[(keys::NS_PER_EVENT_MIN, 0.0)]);
        let p = peers(&[base, zero.clone()]);
        assert!(
            matches!(evaluate(&[g], &zero, None, &p).pop().unwrap().1, GateOutcome::Fail(_)),
            "ratio 0 is outside the exclusive low edge"
        );
    }

    #[test]
    fn missing_peer_is_a_skip_not_a_failure() {
        let b = rec("curve.c1", &[(keys::EVENTS, 500.0)]);
        let p = peers(std::slice::from_ref(&b));
        let g = Gate::eq_cell(keys::EVENTS, "curve.c0");
        assert!(matches!(evaluate(&[g], &b, None, &p).pop().unwrap().1, GateOutcome::Skip(_)));
    }
}
