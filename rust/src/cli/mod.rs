//! Command-line interface.
//!
//! ```text
//! wfpred identify [--file-size-mb N --chunk-kb N]      system identification (§2.5)
//! wfpred predict  --pattern P [--scale S --trace F ..]  one prediction (coarse model)
//! wfpred explain  --pattern P [--json --trace F]       critical-path attribution
//! wfpred run      --pattern P [--trials N ...]         "actual" testbed campaign
//! wfpred search   [--allocations 11,17,20 ...]         configuration-space search
//! wfpred batch    [--in FILE --store FILE ...]         serve query JSON in bulk
//! wfpred serve    [--store FILE ...]                   line-protocol serving loop
//! wfpred trace    --emit P --out FILE | --show FILE    workload trace tools
//! wfpred bench    [globs…] [--check --list ...]        benchmark barometer (METHODOLOGY.md)
//! ```

use crate::ident::{identify, IdentConfig};
use crate::model::{simulate_traced, Config, FaultPlan, Fidelity, Placement, Platform, Topology};
use crate::predict::Predictor;
use crate::runtime::{ScorerRuntime, StageDesc};
use crate::search::{SearchSpace, Searcher};
use crate::service::{Answer, Query, Service, StatsSnapshot};
use crate::trace::{chrome_trace, critical_path, Class};
use crate::testbed::Testbed;
use crate::util::flags::Flags;
use crate::util::hash::Fnv64;
use crate::util::jsonw::{self, Json, Scalar};
use crate::util::table::Table;
use crate::util::units::Bytes;
use crate::workload::blast::{blast, BlastParams};
use crate::workload::modftdock::{modftdock, DockParams};
use crate::workload::montage::montage;
use crate::workload::patterns::{broadcast, pipeline, reduce, PatternScale};
use crate::workload::{trace, Workload};

pub fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = run(&args);
    std::process::exit(code);
}

pub fn run(args: &[String]) -> i32 {
    let Some(cmd) = args.first() else {
        eprintln!("{USAGE}");
        return 2;
    };
    let rest = &args[1..];
    let result = match cmd.as_str() {
        "identify" => cmd_identify(rest),
        "predict" => cmd_predict(rest),
        "explain" => cmd_explain(rest),
        "run" => cmd_run(rest),
        "compare" => cmd_compare(rest),
        "search" => cmd_search(rest),
        "batch" => cmd_batch(rest),
        "serve" => cmd_serve(rest),
        "trace" => cmd_trace(rest),
        // Bench has its own exit-code contract (1 = gate failure,
        // 2 = usage error), so it bypasses the Result mapping below.
        "bench" => return cmd_bench(rest),
        "--help" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n{USAGE}")),
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

const USAGE: &str = "wfpred — predicting intermediate storage performance for workflow applications

commands:
  identify   run the system-identification procedure against the in-tree TCP store
  predict    predict a workload's turnaround with the queue-based model
  explain    attribute the predicted turnaround to its critical path by component class
  run        measure a workload on the emulated testbed (mean ± std over trials)
  compare    actual vs predicted side by side, with energy estimates
  search     explore the provisioning/partitioning/configuration space (BLAST)
  batch      answer newline-delimited prediction queries through the service layer
  serve      read queries from stdin, stream one answer line per query
  trace      emit or inspect workload trace files
  bench      run benchmark cells from the registry; --check gates per-cell baselines

run `wfpred <command> --help` for flags.";

fn platform_by_name(name: &str) -> Result<Platform, String> {
    match name {
        "paper" | "ram" => Ok(Platform::paper_testbed()),
        "hdd" => Ok(Platform::paper_testbed_hdd()),
        "ssd" => Ok(Platform::paper_testbed_ssd()),
        "10g" => Ok(Platform::paper_testbed_10g()),
        other => Err(format!("unknown platform {other:?} (paper|hdd|ssd|10g)")),
    }
}

/// Parse `--topology`: `star` (the single shared-medium network every
/// paper scenario uses), or `rack:<rack-size>:<oversub>` — racks of
/// `rack-size` hosts behind an uplink/downlink pair provisioned at
/// `rack_size / oversub` NIC rates (see `sim::FabricPlan`).
fn topology_by_name(name: &str) -> Result<Topology, String> {
    if name == "star" {
        return Ok(Topology::Star);
    }
    if let Some(spec) = name.strip_prefix("rack:") {
        let mut it = spec.split(':');
        let (Some(rs), Some(ov), None) = (it.next(), it.next(), it.next()) else {
            return Err(format!("bad topology {name:?} (want rack:<rack-size>:<oversub>)"));
        };
        let rack_size = rs
            .parse::<usize>()
            .map_err(|_| format!("bad rack size {rs:?} in --topology {name:?}"))?;
        let oversub = ov
            .parse::<f64>()
            .map_err(|_| format!("bad oversubscription ratio {ov:?} in --topology {name:?}"))?;
        return Ok(Topology::Rack { rack_size, oversub });
    }
    Err(format!("unknown topology {name:?} (star | rack:<rack-size>:<oversub>)"))
}

/// The platform a command runs against: `--platform` resolved by name,
/// then routed through the `--topology` fabric and re-validated (so a
/// zero rack size or non-finite ratio is a flag error, not a panic).
fn platform_from_flags(f: &Flags) -> Result<Platform, String> {
    let mut plat = platform_by_name(&f.get("platform"))?;
    plat.topology = topology_by_name(&f.get("topology"))?;
    plat.validate().map_err(|e| format!("--topology: {e}"))?;
    Ok(plat)
}

fn scale_by_name(name: &str) -> Result<PatternScale, String> {
    match name {
        "small" => Ok(PatternScale::Small),
        "medium" => Ok(PatternScale::Medium),
        "large" => Ok(PatternScale::Large),
        other => Err(format!("unknown scale {other:?}")),
    }
}

/// Build (workload, config) for the CLI's shared pattern flags.
fn build_workload(f: &Flags) -> Result<(Workload, Config), String> {
    let n = f.get_u64("nodes") as usize;
    let wass = f.get_bool("wass");
    let scale = scale_by_name(&f.get("scale"))?;
    let chunk = Bytes::kb(f.get_u64("chunk-kb"));
    if f.get("pattern") == "blast" {
        let n_app = f.get_u64("app-nodes") as usize;
        if n_app == 0 || n_app >= n {
            return Err(format!("--app-nodes {n_app} must be in [1, nodes-1] (nodes = {n})"));
        }
    }
    let wl = match f.get("pattern").as_str() {
        "pipeline" => pipeline(n, scale, wass),
        "reduce" => reduce(n, scale, wass),
        "broadcast" => broadcast(n, scale, f.get_u64("replicas") as u32),
        "montage" => montage(n),
        "modftdock" => modftdock(&DockParams::default(), wass),
        "blast" => {
            let params = BlastParams { queries: f.get_u64("queries") as u32, ..Default::default() };
            blast(f.get_u64("app-nodes") as usize, &params)
        }
        other => return Err(format!("unknown pattern {other:?}")),
    };
    let cfg = if f.get("pattern") == "blast" {
        let n_app = f.get_u64("app-nodes") as usize;
        Config::partitioned(n_app, n - n_app, chunk)
    } else if wass {
        let mut c = Config::wass(n).with_chunk(chunk);
        if f.get("pattern") == "broadcast" {
            c.placement = Placement::RoundRobin; // broadcast optimizes via replication
        }
        c
    } else {
        Config::dss(n).with_chunk(chunk)
    };
    let stripe = f.get_u64("stripe") as usize;
    let cfg = if stripe == 0 { cfg } else { cfg.with_stripe(stripe.min(cfg.n_storage)) };
    let plan = f.get("fault-plan");
    let cfg = if plan.is_empty() {
        cfg
    } else {
        let plan = FaultPlan::parse(&plan).map_err(|e| format!("--fault-plan: {e}"))?;
        // Check indices against the cluster here so a bad plan is a flag
        // error, not a panic deep inside the simulator.
        plan.validate(cfg.n_storage, cfg.n_hosts()).map_err(|e| format!("--fault-plan: {e}"))?;
        cfg.with_fault_plan(plan)
    };
    Ok((wl, cfg))
}

/// Resolve a `--threads` flag: 0 means "all cores, capped".
fn campaign_threads_flag(f: &Flags) -> usize {
    match f.get_u64("threads") {
        0 => crate::coordinator::campaign_threads(),
        n => n as usize,
    }
}

fn pattern_flags(f: Flags) -> Flags {
    f.flag("pattern", "pipeline", "pipeline|reduce|broadcast|montage|blast|modftdock")
        .flag("nodes", "19", "worker nodes (excl. manager)")
        .flag("scale", "medium", "small|medium|large")
        .switch("wass", "workflow-aware configuration (placement hints + locality)")
        .flag("replicas", "1", "broadcast-file replicas")
        .flag("chunk-kb", "1024", "chunk size in KB")
        .flag("stripe", "0", "stripe width override (0 = deployment default; capped at storage nodes)")
        .flag("queries", "200", "BLAST query count")
        .flag("app-nodes", "14", "BLAST application nodes")
        .flag("platform", "paper", "paper|hdd|ssd|10g")
        .flag("topology", "star", "network fabric: star | rack:<rack-size>:<oversub>")
        .flag(
            "fault-plan",
            "",
            "fault plan: seed=N;crash=<storage>@<secs>;slow=<host>@<secs>x<mult>;\
             drop=<src>-<dst>@<from>-<until>p<prob> (empty = fault-free)",
        )
}

fn cmd_identify(args: &[String]) -> Result<(), String> {
    let f = Flags::new("wfpred identify")
        .flag("file-size-mb", "8", "benchmark file size")
        .flag("chunk-kb", "1024", "chunk size")
        .flag("min-samples", "5", "Jain floor")
        .flag("max-samples", "60", "Jain ceiling")
        .parse(args)?;
    let cfg = IdentConfig {
        file_size: Bytes::mb(f.get_u64("file-size-mb")),
        chunk_size: Bytes::kb(f.get_u64("chunk-kb")),
        probe_size: Bytes::mb(f.get_u64("file-size-mb")),
        campaign: crate::ident::CampaignCfg {
            rel_accuracy: 0.05,
            min_samples: f.get_u64("min-samples"),
            max_samples: f.get_u64("max-samples"),
        },
    };
    let id = identify(&cfg).map_err(|e| e.to_string())?;
    println!("system identification (paper §2.5) against the in-tree TCP store:");
    println!("{}", id.summary());
    Ok(())
}

fn cmd_predict(args: &[String]) -> Result<(), String> {
    let f = pattern_flags(Flags::new("wfpred predict"))
        .flag("trace", "", "write Chrome trace-event JSON of the run here (open in Perfetto)")
        .parse(args)?;
    let (wl, cfg) = build_workload(&f)?;
    let plat = platform_from_flags(&f)?;
    let pred = Predictor::new(plat.clone()).predict(&wl, &cfg);
    println!("workload {:<24} config {}", wl.name, cfg.label);
    println!("predicted turnaround: {}", pred.turnaround);
    for (s, t) in pred.stage_times.iter().enumerate() {
        println!("  stage {s}: {t}");
    }
    println!("cost: {:.1} node-seconds", pred.cost_node_secs);
    println!("predictor wallclock: {:.3}s ({} events)", pred.predictor_wallclock_secs, pred.report.events);
    let tpath = f.get("trace");
    if !tpath.is_empty() {
        // The traced re-run reproduces the prediction above bit for bit
        // (probes observe, they never feed back), so the trace describes
        // exactly the run whose numbers were just printed.
        let (_, rec) = simulate_traced(&wl, &cfg, &plat, Fidelity::coarse());
        std::fs::write(&tpath, chrome_trace(&rec)).map_err(|e| e.to_string())?;
        println!("wrote trace: {tpath} ({} spans)", rec.n_spans());
    }
    Ok(())
}

fn cmd_explain(args: &[String]) -> Result<(), String> {
    let f = pattern_flags(Flags::new("wfpred explain"))
        .switch("json", "emit one flat-JSON line instead of tables")
        .flag("trace", "", "also write Chrome trace-event JSON here (open in Perfetto)")
        .parse(args)?;
    let (wl, cfg) = build_workload(&f)?;
    let plat = platform_from_flags(&f)?;
    // Attribution needs every event probed, so explain always runs one
    // cold traced simulation — the delta warm-start path and the service
    // caches are deliberately not consulted (batch/serve report their
    // cold/delta/memo composition on their own stats lines).
    eprintln!("[explain] cold traced run: delta re-simulation and service caches bypassed");
    let (report, rec) = simulate_traced(&wl, &cfg, &plat, Fidelity::coarse());
    let attr = critical_path(&rec);
    if !attr.tiles_exactly() {
        return Err("internal error: attribution does not tile [0, turnaround]".into());
    }
    let tpath = f.get("trace");
    if !tpath.is_empty() {
        std::fs::write(&tpath, chrome_trace(&rec)).map_err(|e| e.to_string())?;
    }
    let secs = |ns: u64| ns as f64 / 1e9;
    let totals = attr.totals();
    let waits = attr.waits();
    let turn_ns = report.turnaround.as_ns();
    // Per-stage windows: first task start to last task end of each stage
    // (stages may overlap; each window clips the one attributed path).
    let windows: Vec<(u64, u64)> = (0..report.n_stages())
        .map(|s| {
            report.tasks.iter().filter(|t| t.stage == s).fold((u64::MAX, 0u64), |(lo, hi), t| {
                (lo.min(t.start.as_ns()), hi.max(t.end.as_ns()))
            })
        })
        .collect();
    if f.get_bool("json") {
        let mut j = Json::obj()
            .set("workload", wl.name.clone())
            .set("config", cfg.label.clone())
            .set("turnaround_s", secs(turn_ns));
        for c in Class::ALL {
            j = j.set(&format!("cp_{}_s", c.as_str()), secs(totals[c.index()]));
            j = j.set(&format!("cp_{}_wait_s", c.as_str()), secs(waits[c.index()]));
        }
        for (s, &(lo, hi)) in windows.iter().enumerate() {
            if lo >= hi {
                continue;
            }
            let t = attr.totals_in(lo, hi);
            for c in Class::ALL {
                j = j.set(&format!("stage{s}_{}_s", c.as_str()), secs(t[c.index()]));
            }
        }
        println!("{}", j.render_compact());
        return Ok(());
    }
    println!("workload {:<24} config {}", wl.name, cfg.label);
    println!("turnaround {} — critical-path attribution (segments tile [0, turnaround]):", report.turnaround);
    let mut t = Table::new(&["class", "time (s)", "share", "of which wait (s)"]);
    for c in Class::ALL {
        if totals[c.index()] == 0 {
            continue;
        }
        t.row(&[
            c.as_str().into(),
            format!("{:.3}", secs(totals[c.index()])),
            format!("{:.1}%", totals[c.index()] as f64 / turn_ns.max(1) as f64 * 100.0),
            format!("{:.3}", secs(waits[c.index()])),
        ]);
    }
    print!("{}", t.render());
    println!("\nper-stage breakdown (path time inside each stage window, s):");
    let mut hdr: Vec<&str> = vec!["stage", "window (s)"];
    for c in Class::ALL {
        hdr.push(c.as_str());
    }
    let mut t = Table::new(&hdr);
    for (s, &(lo, hi)) in windows.iter().enumerate() {
        if lo >= hi {
            continue;
        }
        let per = attr.totals_in(lo, hi);
        let mut row = vec![s.to_string(), format!("{:.3}–{:.3}", secs(lo), secs(hi))];
        for c in Class::ALL {
            row.push(format!("{:.3}", secs(per[c.index()])));
        }
        t.row(&row);
    }
    print!("{}", t.render());
    if !tpath.is_empty() {
        println!("wrote trace: {tpath} ({} spans)", rec.n_spans());
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<(), String> {
    let f = pattern_flags(Flags::new("wfpred run"))
        .flag("trials", "15", "minimum trials")
        .flag("threads", "0", "campaign worker threads (0 = all cores; results identical)")
        .flag("trace", "", "write Chrome trace-event JSON of trial 0 here (open in Perfetto)")
        .parse(args)?;
    let (wl, cfg) = build_workload(&f)?;
    let plat = platform_from_flags(&f)?;
    let trials = f.get_u64("trials");
    let tb = Testbed::new(plat)
        .with_trials(trials, trials * 3)
        .with_threads(campaign_threads_flag(&f));
    let stats = tb.run(&wl, &cfg);
    println!("workload {:<24} config {} ({} trials)", wl.name, cfg.label, stats.turnaround.n());
    println!("actual turnaround: {:.3}s ± {:.3}s", stats.mean(), stats.std());
    for (s, st) in stats.stages.iter().enumerate() {
        println!("  stage {s}: {:.3}s ± {:.3}s", st.mean(), st.std());
    }
    println!("conn retries/trial: {:.1}", stats.mean_conn_retries);
    let tpath = f.get("trace");
    if !tpath.is_empty() {
        // One representative trial: the campaign's fidelity on trial 0's
        // seed stream, so the trace is a run the campaign actually took.
        let fid = Fidelity { seed: tb.trial_seed(0), ..tb.fidelity.clone() };
        let (_, rec) = simulate_traced(&wl, &cfg, &tb.platform, fid);
        std::fs::write(&tpath, chrome_trace(&rec)).map_err(|e| e.to_string())?;
        println!("wrote trace: {tpath} (trial 0, {} spans)", rec.n_spans());
    }
    Ok(())
}

fn cmd_compare(args: &[String]) -> Result<(), String> {
    let f = pattern_flags(Flags::new("wfpred compare"))
        .flag("trials", "8", "minimum trials")
        .flag("threads", "0", "campaign worker threads (0 = all cores; results identical)")
        .parse(args)?;
    let (wl, cfg) = build_workload(&f)?;
    let plat = platform_from_flags(&f)?;
    let trials = f.get_u64("trials");
    let tb = Testbed::new(plat.clone())
        .with_trials(trials, trials * 3)
        .with_threads(campaign_threads_flag(&f));
    let stats = tb.run(&wl, &cfg);
    let pred = Predictor::new(plat).predict(&wl, &cfg);
    let pm = crate::model::PowerModel::xeon_e5345();
    let actual_t = stats.mean();
    let pred_t = pred.turnaround.as_secs_f64();
    let mut t = Table::new(&["metric", "actual (testbed)", "predicted (model)"]);
    t.row(&["turnaround".into(), format!("{actual_t:.2}s ± {:.2}", stats.std()), format!("{pred_t:.2}s")]);
    t.row(&[
        "energy".into(),
        format!("{:.3} kWh", pm.energy_kwh(&stats.sample)),
        format!("{:.3} kWh", pm.energy_kwh(&pred.report)),
    ]);
    t.row(&[
        "cost".into(),
        format!("{:.0} node-s", actual_t * cfg.n_hosts() as f64),
        format!("{:.0} node-s", pred.cost_node_secs),
    ]);
    println!("workload {:<24} config {} ({} trials)", wl.name, cfg.label, stats.turnaround.n());
    print!("{}", t.render());
    println!("prediction error: {:+.1}%", (pred_t - actual_t) / actual_t * 100.0);
    Ok(())
}

fn cmd_search(args: &[String]) -> Result<(), String> {
    let f = Flags::new("wfpred search")
        .flag("allocations", "11,17,20", "total cluster sizes")
        .flag("chunks-kb", "256,1024,4096", "chunk sizes (KB)")
        .flag("queries", "200", "BLAST query count")
        .flag("top-k", "12", "candidates refined with the DES predictor")
        .flag("platform", "paper", "paper|hdd|ssd|10g")
        .flag("topology", "star", "network fabric: star | rack:<rack-size>:<oversub>")
        .flag("artifact", "artifacts/predictor.hlo.txt", "AOT scorer (empty to disable)")
        .flag("surrogate", "0", "surrogate error gate, e.g. 0.3 (0 = off: refine exactly)")
        .flag("fault-plan", "", "fault plan applied to every candidate (empty = fault-free)")
        .parse(args)?;
    let plat = platform_from_flags(&f)?;
    let chunks: Vec<Bytes> = f.get_u64_list("chunks-kb").into_iter().map(Bytes::kb).collect();
    let mut space = SearchSpace::elastic(
        f.get_u64_list("allocations").into_iter().map(|x| x as usize).collect(),
        chunks,
    );
    if !f.get("fault-plan").is_empty() {
        space.faults =
            FaultPlan::parse(&f.get("fault-plan")).map_err(|e| format!("--fault-plan: {e}"))?;
    }
    let params = BlastParams { queries: f.get_u64("queries") as u32, ..Default::default() };
    let predictor = Predictor::new(plat);
    let surrogate_gate = f.get_f64("surrogate");
    let rt = if f.get("artifact").is_empty() {
        None
    } else if surrogate_gate > 0.0 {
        // The surrogate-gated search replaces the analytic prescreen as
        // the pruner; don't pay for an artifact that won't be consulted.
        eprintln!("note: --surrogate replaces the analytic prescreen; artifact not loaded");
        None
    } else {
        match ScorerRuntime::load(f.get("artifact")) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("note: no AOT prescreen ({e}); refining the whole grid");
                None
            }
        }
    };
    let mut searcher = Searcher::new(&predictor).with_top_k(f.get_u64("top-k") as usize);
    if let Some(rt) = rt.as_ref() {
        searcher = searcher.with_runtime(rt);
    }
    if surrogate_gate > 0.0 {
        searcher = searcher.with_surrogate(surrogate_gate);
    }
    let stages = vec![StageDesc {
        tasks_per_app: true,
        tasks_fixed: 0.0,
        read_mb: params.db_size.as_f64() as f32 / (1u64 << 20) as f32,
        read_local_frac: 0.0,
        write_mb: params.output_file.as_f64() as f32 / (1u64 << 20) as f32,
        fan_single: false,
        compute_total_s: params.queries as f32 * params.per_query.as_secs_f64() as f32,
    }];
    let report = searcher.search(&space, &stages, |cfg| blast(cfg.n_app, &params));

    let pruned_by = if surrogate_gate > 0.0 {
        "answered by the gated surrogate"
    } else {
        "pruned by the analytic prescreen"
    };
    println!(
        "searched {} configurations ({} {pruned_by}) in {:.2}s\n",
        report.candidates.len(),
        report.pruned,
        report.wallclock_secs
    );
    let show = |label: &str, i: usize| {
        let c = &report.candidates[i];
        println!(
            "{label:<22} {:<28} time {:.1}s  cost {:.0} node-s",
            c.config.label,
            c.time_s(),
            c.cost_node_s()
        );
    };
    show("best performance:", report.best_time);
    show("lowest cost:", report.best_cost);
    show("most cost-efficient:", report.best_efficiency);
    if surrogate_gate > 0.0 {
        let n_sur = report
            .candidates
            .iter()
            .filter(|c| c.refined.is_none() && c.surrogate.is_some())
            .count();
        println!(
            "surrogate answered {n_sur} off-frontier candidates (est_err <= {surrogate_gate}); \
             frontier refined exactly"
        );
    }
    println!("\npareto front (time vs cost):");
    let mut t = Table::new(&["config", "time (s)", "cost (node-s)"]);
    for &i in &report.pareto {
        let c = &report.candidates[i];
        t.row(&[c.config.label.clone(), format!("{:.1}", c.time_s()), format!("{:.0}", c.cost_node_s())]);
    }
    print!("{}", t.render());
    Ok(())
}

/// One line of the batch/serve query protocol: a flat JSON object whose
/// keys are the shared pattern flags (hyphenated), e.g.
/// `{"pattern": "blast", "app-nodes": 14, "nodes": 19, "chunk-kb": 256}`.
/// Values are rewritten as `--key=value` tokens and run through the same
/// flag parser as `wfpred predict`, so the two surfaces cannot drift.
fn parse_query(line: &str, extra_argv: &[String]) -> Result<Flags, String> {
    let kv = jsonw::parse_flat(line).map_err(|e| format!("bad query JSON: {e}"))?;
    // Command-level defaults come first so a per-query key overrides them.
    let mut argv = extra_argv.to_vec();
    for (k, v) in kv {
        let val = match v {
            Scalar::Str(s) => s,
            Scalar::Num(x) if x == x.trunc() && x.abs() < 1e15 => (x as i64).to_string(),
            Scalar::Num(x) => x.to_string(),
            Scalar::Bool(b) => b.to_string(),
            Scalar::Null => continue,
            Scalar::NumArr(_) => return Err(format!("array value for {k:?} unsupported")),
        };
        argv.push(format!("--{k}={val}"));
    }
    pattern_flags(Flags::new("query")).parse(&argv)
}

/// Surrogate-family key of one query: everything that identifies the
/// workload family *except* the grid coordinate axes (partitioning,
/// allocation, chunk, replication), which vary inside a family.
fn query_family(f: &Flags, plat: &Platform) -> u64 {
    let mut h = Fnv64::new();
    h.write_str(&f.get("pattern"));
    h.write_str(&f.get("scale"));
    h.write_bool(f.get_bool("wass"));
    h.write_u64(f.get_u64("queries"));
    h.write_u64(f.get_u64("replicas"));
    // A degraded run is a different response surface than a clean one, so
    // fault plans never share a surrogate grid with fault-free queries
    // (or with differently-faulted ones).
    h.write_str(&f.get("fault-plan"));
    h.write_str(&plat.label);
    // A routed fabric reshapes the whole response surface, so rack
    // families never share a surrogate grid with star families (or with
    // differently-dimensioned racks). Star hashes nothing: pre-fabric
    // family keys stay valid.
    if let Topology::Rack { rack_size, oversub } = plat.topology {
        h.write_str("rack");
        h.write_u64(rack_size as u64);
        h.write_u64(oversub.to_bits());
    }
    h.finish()
}

fn query_to_service(line: &str, plat: &Platform, extra_argv: &[String]) -> Result<Query, String> {
    let qf = parse_query(line, extra_argv)?;
    // Flag getters panic on type mismatches — fine for a developer's own
    // command line, not for untrusted query input. Convert panics from
    // malformed values (e.g. "queries": 2.5) into per-line errors so one
    // bad query cannot kill a serving loop.
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let (workload, config) = build_workload(&qf)?;
        Ok(Query { family: query_family(&qf, plat), workload, config })
    }))
    .unwrap_or_else(|e| {
        let msg = e
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_else(|| "invalid query".into());
        Err(format!("bad query: {msg}"))
    })
}

fn answer_json(a: &Answer) -> Json {
    match a {
        Answer::Exact { fp, turnaround_s, cost_node_s, source, engine, failures, delta } => {
            let mut o = Json::obj()
                .set("fp", fp.to_string())
                .set("kind", "exact")
                .set("turnaround_s", *turnaround_s)
                .set("cost_node_s", *cost_node_s)
                .set("source", source.as_str())
                .set("engine", engine.as_str())
                .set("fault_retries", failures.retries)
                .set("fault_failovers", failures.failovers)
                .set("fault_timeouts", failures.timeouts)
                .set("unrecoverable", failures.unrecoverable);
            if let Some(d) = delta {
                o = o
                    .set("delta_stages_skipped", d.stages_skipped as u64)
                    .set("delta_stages_replayed", d.stages_replayed as u64);
            }
            o
        }
        Answer::Surrogate { fp, turnaround_s, cost_node_s, est_err } => Json::obj()
            .set("fp", fp.to_string())
            .set("kind", "surrogate")
            .set("turnaround_s", *turnaround_s)
            .set("cost_node_s", *cost_node_s)
            .set("engine", a.engine().as_str())
            .set("est_err", *est_err),
    }
}

fn service_flags(f: Flags) -> Flags {
    f.flag("platform", "paper", "paper|hdd|ssd|10g")
        .flag("topology", "star", "network fabric: star | rack:<rack-size>:<oversub>")
        .flag("store", "", "append-only JSONL prediction store (warm-starts across runs)")
        .flag("surrogate", "0", "surrogate error gate, e.g. 0.3 (0 = off: always exact)")
        .flag("fault-plan", "", "fault plan for queries without their own (empty = fault-free)")
}

/// Command-level default argv prepended to every query line (per-query
/// keys override these).
fn service_query_defaults(f: &Flags) -> Vec<String> {
    let mut extra = Vec::new();
    if !f.get("fault-plan").is_empty() {
        extra.push(format!("--fault-plan={}", f.get("fault-plan")));
    }
    extra
}

/// The serving-tier counter line `batch` and `serve` print on exit:
/// answer attribution plus the raw shard-level cache probe counters.
fn eprint_service_stats(queries: usize, s: &StatsSnapshot) {
    eprintln!(
        "[service] {queries} queries: {} simulated ({} cold / {} delta warm-started), \
         {} memory hits, {} disk hits, {} deduped, {} surrogate; \
         delta stages {} skipped / {} replayed; cache probes {} hit / {} miss / {} evicted",
        s.misses,
        s.misses.saturating_sub(s.delta_hits),
        s.delta_hits,
        s.hits,
        s.disk_hits,
        s.dedup_waits,
        s.surrogate_answers,
        s.delta_stages_skipped,
        s.delta_stages_replayed,
        s.cache.hits,
        s.cache.misses,
        s.cache.evictions
    );
}

fn open_service(f: &Flags, plat: &Platform) -> Result<Service, String> {
    let service = Service::new(Predictor::new(plat.clone()));
    if f.get("store").is_empty() {
        Ok(service)
    } else {
        service.with_disk_store(f.get("store"))
    }
}

fn cmd_batch(args: &[String]) -> Result<(), String> {
    let f = service_flags(Flags::new("wfpred batch"))
        .flag("in", "", "newline-delimited query JSON file (empty = read stdin)")
        .flag("threads", "0", "worker threads (0 = all cores; answers stay in input order)")
        .parse(args)?;
    let plat = platform_from_flags(&f)?;
    let text = if f.get("in").is_empty() {
        let mut s = String::new();
        std::io::Read::read_to_string(&mut std::io::stdin(), &mut s).map_err(|e| e.to_string())?;
        s
    } else {
        std::fs::read_to_string(f.get("in")).map_err(|e| e.to_string())?
    };
    let service = open_service(&f, &plat)?;
    let extra = service_query_defaults(&f);
    let mut queries = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        queries.push(query_to_service(line, &plat, &extra)?);
    }
    if queries.is_empty() {
        return Err("no queries in input".into());
    }
    let answers = service.serve_batch(&queries, campaign_threads_flag(&f), f.get_f64("surrogate"));
    for a in &answers {
        println!("{}", answer_json(a).render_compact());
    }
    eprint_service_stats(queries.len(), &service.stats());
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<(), String> {
    let f = service_flags(Flags::new("wfpred serve")).parse(args)?;
    let plat = platform_from_flags(&f)?;
    let service = open_service(&f, &plat)?;
    let extra = service_query_defaults(&f);
    let gate = f.get_f64("surrogate");
    let stdin = std::io::stdin();
    let mut line = String::new();
    let mut served = 0usize;
    loop {
        line.clear();
        let n = stdin.read_line(&mut line).map_err(|e| e.to_string())?;
        if n == 0 {
            break; // EOF
        }
        let l = line.trim();
        if l.is_empty() {
            continue;
        }
        if l == "quit" {
            break;
        }
        let out = match query_to_service(l, &plat, &extra) {
            Ok(q) => {
                served += 1;
                let answers = service.serve_batch(std::slice::from_ref(&q), 1, gate);
                answer_json(&answers[0])
            }
            Err(e) => Json::obj().set("error", e),
        };
        println!("{}", out.render_compact());
        // stdout is block-buffered on pipes; answers must stream.
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
    }
    eprint_service_stats(served, &service.stats());
    Ok(())
}

fn cmd_trace(args: &[String]) -> Result<(), String> {
    let f = pattern_flags(Flags::new("wfpred trace"))
        .flag("out", "", "write the generated trace here")
        .flag("show", "", "parse and summarize an existing trace file")
        .parse(args)?;
    if !f.get("show").is_empty() {
        let text = std::fs::read_to_string(f.get("show")).map_err(|e| e.to_string())?;
        let wl = trace::from_text(&text)?;
        println!(
            "workload {}: {} files, {} tasks, {} stages, reads {} writes {}",
            wl.name,
            wl.files.len(),
            wl.tasks.len(),
            wl.n_stages(),
            wl.bytes_read(),
            wl.bytes_written()
        );
        return Ok(());
    }
    let (wl, _) = build_workload(&f)?;
    let text = trace::to_text(&wl);
    let out = f.get("out");
    if out.is_empty() {
        print!("{text}");
    } else {
        std::fs::write(&out, &text).map_err(|e| e.to_string())?;
        println!("wrote {} ({} lines)", out, text.lines().count());
    }
    Ok(())
}

/// `wfpred bench [globs…]` — the prediction barometer (see
/// `rust/METHODOLOGY.md`). Exit 0 = ran (and, with `--check`, every gate
/// passed), 1 = at least one gate failed, 2 = usage/selection error.
fn cmd_bench(args: &[String]) -> i32 {
    let parsed = Flags::new("wfpred bench")
        .switch("check", "evaluate gates against per-cell baselines; exit 1 on failure")
        .switch("list", "print the selected cells and their gates instead of running")
        .switch("no-history", "skip appending to results/records/history/")
        .flag("out", "results/records", "record/baseline directory")
        .flag("threads", "1", "cell fan-out workers (1 keeps wallclock keys clean)")
        .flag("run-id", "", "record tag (default $GITHUB_SHA, else \"local\")")
        .flag("reps", "0", "override every cell's reps/trials (0 = registry values)")
        .parse(args);
    let f = match parsed {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if f.get_bool("list") {
        return match crate::bench::list_cells(&f.positionals) {
            Ok(listing) => {
                print!("{listing}");
                0
            }
            Err(e) => {
                eprintln!("wfpred bench: {e}");
                2
            }
        };
    }
    let mut opts = crate::bench::RunOptions {
        globs: f.positionals.clone(),
        check: f.get_bool("check"),
        out_dir: std::path::PathBuf::from(f.get("out")),
        threads: f.get_u64("threads").max(1) as usize,
        history: !f.get_bool("no-history"),
        reps_override: f.get_u64("reps") as u32,
        ..crate::bench::RunOptions::default()
    };
    if !f.get("run-id").is_empty() {
        opts.run_id = f.get("run-id");
    }
    if opts.check && opts.threads > 1 {
        eprintln!(
            "wfpred bench: --threads {} under --check — wallclock-ratio gates may see \
             cross-cell interference",
            opts.threads
        );
    }
    crate::bench::run_cells(&opts).exit_code
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(run(&argv(&["bogus"])), 2);
        assert_eq!(run(&[]), 2);
    }

    #[test]
    fn bench_list_runs_and_dead_globs_exit_2() {
        assert_eq!(run(&argv(&["bench", "--list"])), 0);
        assert_eq!(run(&argv(&["bench", "--list", "scale.*"])), 0);
        assert_eq!(run(&argv(&["bench", "--list", "no.such.cell"])), 2);
        assert_eq!(run(&argv(&["bench", "--check", "no.such.cell"])), 2);
    }

    #[test]
    fn predict_pipeline_runs() {
        assert_eq!(run(&argv(&["predict", "--pattern", "pipeline", "--nodes", "4", "--scale", "small"])), 0);
    }

    #[test]
    fn explain_runs_tables_and_json() {
        assert_eq!(
            run(&argv(&["explain", "--pattern", "reduce", "--nodes", "4", "--scale", "small"])),
            0
        );
        assert_eq!(
            run(&argv(&["explain", "--pattern", "montage", "--nodes", "5", "--json"])),
            0
        );
    }

    #[test]
    fn predict_emits_chrome_trace() {
        let path =
            std::env::temp_dir().join(format!("wfpred_cli_chrome_{}.json", std::process::id()));
        let p = path.to_str().unwrap().to_string();
        assert_eq!(
            run(&argv(&[
                "predict", "--pattern", "pipeline", "--nodes", "4", "--scale", "small",
                "--trace", &p,
            ])),
            0
        );
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("[\n") && text.trim_end().ends_with(']'));
        assert!(text.contains("\"ph\": \"X\""), "trace events are complete spans");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn run_testbed_quick() {
        assert_eq!(
            run(&argv(&["run", "--pattern", "reduce", "--nodes", "4", "--scale", "small", "--trials", "3"])),
            0
        );
    }

    #[test]
    fn trace_roundtrip_via_cli() {
        let dir = std::env::temp_dir().join("wfpred_cli_trace_test.trace");
        let path = dir.to_str().unwrap().to_string();
        assert_eq!(
            run(&argv(&["trace", "--pattern", "reduce", "--nodes", "3", "--scale", "small", "--out", &path])),
            0
        );
        assert_eq!(run(&argv(&["trace", "--show", &path])), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compare_runs_modftdock() {
        assert_eq!(
            run(&argv(&[
                "compare", "--pattern", "modftdock", "--nodes", "6", "--scale", "small", "--trials", "2"
            ])),
            0
        );
    }

    #[test]
    fn predict_rejects_bad_pattern() {
        assert_eq!(run(&argv(&["predict", "--pattern", "nope"])), 2);
    }

    #[test]
    fn predict_runs_end_to_end_on_a_rack_topology() {
        // The tier-1 smoke for the routed fabric: a full prediction over
        // racks of 8 with a 4x-oversubscribed core.
        assert_eq!(
            run(&argv(&[
                "predict", "--pattern", "reduce", "--nodes", "8", "--scale", "small",
                "--topology", "rack:8:4",
            ])),
            0
        );
        // `star` is accepted explicitly and stays the default.
        assert_eq!(
            run(&argv(&[
                "predict", "--pattern", "pipeline", "--nodes", "4", "--scale", "small",
                "--topology", "star",
            ])),
            0
        );
    }

    #[test]
    fn predict_rejects_bad_topologies() {
        for topo in ["rack", "rack:8", "rack:0:4", "rack:8:0", "rack:8:inf", "rack:8:4:2", "mesh:4"]
        {
            assert_eq!(
                run(&argv(&[
                    "predict", "--pattern", "pipeline", "--nodes", "4", "--scale", "small",
                    "--topology", topo,
                ])),
                2,
                "{topo:?} must be rejected"
            );
        }
    }

    #[test]
    fn predict_with_fault_plan_runs() {
        assert_eq!(
            run(&argv(&[
                "predict", "--pattern", "pipeline", "--nodes", "4", "--scale", "small",
                "--fault-plan", "crash=1@0.5;slow=2@0.1x0.5",
            ])),
            0
        );
    }

    #[test]
    fn predict_rejects_bad_fault_plans() {
        for plan in ["crash=oops", "crash=99@1", "slow=1@1x0"] {
            assert_eq!(
                run(&argv(&[
                    "predict", "--pattern", "pipeline", "--nodes", "4", "--scale", "small",
                    "--fault-plan", plan,
                ])),
                2,
                "{plan:?} must be rejected"
            );
        }
    }

    #[test]
    fn batch_applies_command_level_fault_plan() {
        let dir = std::env::temp_dir();
        let qpath = dir.join(format!("wfpred_cli_faultq_{}.jsonl", std::process::id()));
        let queries = "\
{\"pattern\": \"blast\", \"queries\": 20, \"app-nodes\": 4, \"nodes\": 8, \"chunk-kb\": 256}\n\
{\"pattern\": \"blast\", \"queries\": 20, \"app-nodes\": 4, \"nodes\": 8, \"chunk-kb\": 256, \
\"fault-plan\": \"crash=0@0.1;crash=1@0.1\"}\n";
        std::fs::write(&qpath, queries).unwrap();
        assert_eq!(
            run(&argv(&[
                "batch",
                "--in",
                qpath.to_str().unwrap(),
                "--fault-plan",
                "crash=0@0.1",
            ])),
            0
        );
        let _ = std::fs::remove_file(&qpath);
    }

    #[test]
    fn batch_serves_query_file_and_warm_starts_from_store() {
        let dir = std::env::temp_dir();
        let qpath = dir.join(format!("wfpred_cli_batch_{}.jsonl", std::process::id()));
        let spath = dir.join(format!("wfpred_cli_store_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&spath);
        let queries = "\
{\"pattern\": \"blast\", \"queries\": 20, \"app-nodes\": 4, \"nodes\": 8, \"chunk-kb\": 256}\n\
{\"pattern\": \"blast\", \"queries\": 20, \"app-nodes\": 5, \"nodes\": 8, \"chunk-kb\": 256}\n\
{\"pattern\": \"blast\", \"queries\": 20, \"app-nodes\": 4, \"nodes\": 8, \"chunk-kb\": 256}\n";
        std::fs::write(&qpath, queries).unwrap();
        let q = qpath.to_str().unwrap();
        let s = spath.to_str().unwrap();
        assert_eq!(run(&argv(&["batch", "--in", q, "--threads", "2", "--store", s])), 0);
        // Second run warm-starts from the JSONL store (answers come from
        // disk; exercised for exit status here, byte-level assertions live
        // in tests/service_layer.rs).
        assert_eq!(run(&argv(&["batch", "--in", q, "--store", s])), 0);
        assert_eq!(std::fs::read_to_string(&spath).unwrap().lines().count(), 2);
        let _ = std::fs::remove_file(&qpath);
        let _ = std::fs::remove_file(&spath);
    }

    #[test]
    fn stripe_flag_feeds_config_and_a_stripe_sweep_warm_starts() {
        let parse = |stripe: &str| {
            let f = pattern_flags(Flags::new("t"))
                .parse(&argv(&[
                    "--pattern", "reduce", "--nodes", "4", "--scale", "small", "--wass",
                    "--stripe", stripe,
                ]))
                .unwrap();
            build_workload(&f).unwrap()
        };
        let (wl1, c1) = parse("1");
        let (wl2, c2) = parse("2");
        assert_eq!(c1.stripe_width, 1);
        assert_eq!(c2.stripe_width, 2);
        // The two-point campaign the CI workflow smoke-tests end to end:
        // every file of a WASS reduce carries a node-pinned or node-local
        // hint (all projections stripe-insensitive), so a stripe-only
        // perturbation shares the whole stage-fingerprint prefix and the
        // second point warm-starts.
        let svc = Service::new(Predictor::new(Platform::paper_testbed()));
        let _ = svc.evaluate(&wl1, &c1);
        let _ = svc.evaluate(&wl2, &c2);
        let st = svc.stats();
        assert_eq!(st.misses, 2, "stripe is a distinct service fingerprint");
        assert_eq!(st.delta_hits, 1, "the second point must warm-start");
    }

    #[test]
    fn batch_rejects_bad_queries() {
        let dir = std::env::temp_dir();
        let qpath = dir.join(format!("wfpred_cli_badq_{}.jsonl", std::process::id()));
        std::fs::write(&qpath, "{\"pattern\": \"nope\"}\n").unwrap();
        assert_eq!(run(&argv(&["batch", "--in", qpath.to_str().unwrap()])), 2);
        std::fs::write(&qpath, "not json\n").unwrap();
        assert_eq!(run(&argv(&["batch", "--in", qpath.to_str().unwrap()])), 2);
        let _ = std::fs::remove_file(&qpath);
    }

    #[test]
    fn search_with_surrogate_runs() {
        assert_eq!(
            run(&argv(&[
                "search",
                "--allocations",
                "10",
                "--chunks-kb",
                "256",
                "--queries",
                "20",
                "--artifact",
                "",
                "--surrogate",
                "0.4",
            ])),
            0
        );
    }
}
