//! Configuration-space exploration — the purpose the predictor serves
//! (paper §1: "enable selecting a good choice in a reasonable time" across
//! provisioning, partitioning and per-subsystem configuration).
//!
//! Pipeline: enumerate the grid → **analytic prescreen** (one PJRT
//! execution of the AOT artifact scores the whole grid; L1/L2) → refine
//! the top candidates with the discrete-event predictor (L3) → report the
//! answers to the paper's four user questions: best-performance
//! configuration, lowest-cost allocation, best partitioning, and most
//! cost-efficient point — plus the time/cost pareto front of Scenario II.
//!
//! All discrete-event refinement flows through a [`Service`] handle
//! (memoized, deduplicated; see `crate::service`). Without an external
//! handle the searcher uses a private cold one, so results are
//! byte-identical to direct prediction; with [`Searcher::with_surrogate`]
//! the interior of the grid can instead be answered by gated
//! interpolation, paying full simulation only near the frontier.
//!
//! Campaign evaluations additionally ride the service's **incremental
//! re-simulation** path (`crate::model::delta`): each cold simulation
//! captures stage-boundary checkpoints, and a neighbor candidate whose
//! stage-fingerprint prefix matches replays only the suffix of stages its
//! knobs actually perturb — bit-identical to a cold run by construction.
//! [`SearchReport::delta_hits`] / `delta_stages_skipped` /
//! `delta_stages_replayed` account for what the sweep saved.

pub mod anneal;

use crate::coordinator;
use crate::model::{Config, FaultPlan};
use crate::predict::{Prediction, Predictor};
use crate::runtime::{encode_config, encode_platform, Score, ScorerRuntime, StageDesc};
use crate::service::{Estimate, GridCoord, Service, StatsSnapshot};
use crate::util::units::Bytes;
use crate::workload::Workload;
use std::collections::HashMap;
use std::sync::Arc;

/// The decision space (paper §1 "The Problem"): provisioning ×
/// partitioning × configuration.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    /// Candidate total allocation sizes (incl. the manager host).
    pub allocations: Vec<usize>,
    /// Chunk sizes to explore.
    pub chunk_sizes: Vec<Bytes>,
    /// Replication levels to explore.
    pub replication: Vec<u32>,
    /// Minimum storage nodes to consider per partitioning.
    pub min_storage: usize,
    /// Fault plan applied to every candidate — search under degraded
    /// conditions ("what is the best configuration if a node dies
    /// mid-run?"). Empty by default: a fault-free search.
    pub faults: FaultPlan,
}

impl SearchSpace {
    /// Scenario I space: one fixed cluster, all partitionings × chunks.
    pub fn fixed_cluster(total_nodes: usize, chunk_sizes: Vec<Bytes>) -> SearchSpace {
        SearchSpace {
            allocations: vec![total_nodes],
            chunk_sizes,
            replication: vec![1],
            min_storage: 1,
            faults: FaultPlan::default(),
        }
    }

    /// Scenario II space: several allocation sizes (paper: 11, 17, 20).
    pub fn elastic(allocations: Vec<usize>, chunk_sizes: Vec<Bytes>) -> SearchSpace {
        SearchSpace {
            allocations,
            chunk_sizes,
            replication: vec![1],
            min_storage: 1,
            faults: FaultPlan::default(),
        }
    }

    /// Enumerate all candidate configurations.
    pub fn enumerate(&self) -> Vec<Config> {
        let mut out = Vec::new();
        for &total in &self.allocations {
            assert!(total >= 3, "need at least app + storage + manager");
            let workers = total - 1; // manager takes one host
            for n_app in 1..=(workers - self.min_storage) {
                let n_storage = workers - n_app;
                for &chunk in &self.chunk_sizes {
                    for &r in &self.replication {
                        if r as usize > n_storage {
                            continue;
                        }
                        let mut cfg =
                            Config::partitioned(n_app, n_storage, chunk).with_replication(r);
                        if !self.faults.is_empty() {
                            // A plan names concrete node indices; drop the
                            // partitionings too small to contain them
                            // (e.g. crash=5 when only 3 storage nodes).
                            if self.faults.validate(n_storage, cfg.n_hosts()).is_err() {
                                continue;
                            }
                            cfg = cfg.with_fault_plan(self.faults.clone());
                        }
                        out.push(cfg);
                    }
                }
            }
        }
        out
    }
}

/// One evaluated candidate.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub config: Config,
    /// Analytic prescreen score (None when no artifact is available).
    pub prescreen: Option<Score>,
    /// Discrete-event refinement (None if pruned). Shared with the
    /// service's cache — an `Arc`, so a big sweep holds one copy of each
    /// `SimReport`, not two.
    pub refined: Option<Arc<Prediction>>,
    /// Surrogate interpolation, when the candidate was answered by the
    /// service's gated fast-path instead of a full simulation. Always
    /// carries its error estimate; None whenever the gate is off.
    pub surrogate: Option<Estimate>,
}

impl Candidate {
    /// Best available time estimate (refined preferred, then surrogate,
    /// then prescreen).
    pub fn time_s(&self) -> f64 {
        self.refined
            .as_ref()
            .map(|p| p.turnaround.as_secs_f64())
            .or_else(|| self.surrogate.map(|e| e.time_s))
            .or_else(|| self.prescreen.map(|s| s.time_s as f64))
            .unwrap_or(f64::INFINITY)
    }

    pub fn cost_node_s(&self) -> f64 {
        self.refined
            .as_ref()
            .map(|p| p.cost_node_secs)
            .or_else(|| self.surrogate.map(|e| e.time_s * self.config.n_hosts() as f64))
            .or_else(|| self.prescreen.map(|s| s.cost_node_s as f64))
            .unwrap_or(f64::INFINITY)
    }
}

/// Search outcome: the paper's four questions, answered.
#[derive(Debug)]
pub struct SearchReport {
    pub candidates: Vec<Candidate>,
    /// Index of the fastest refined configuration.
    pub best_time: usize,
    /// Index of the cheapest refined configuration.
    pub best_cost: usize,
    /// Index of the most cost-efficient (lowest cost × time product).
    pub best_efficiency: usize,
    /// Pareto-optimal (time, cost) candidates, sorted by time.
    pub pareto: Vec<usize>,
    /// How many candidates the prescreen pruned before refinement.
    pub pruned: usize,
    pub wallclock_secs: f64,
    /// Of the simulations this search issued, how many were answered by
    /// the service's incremental re-simulation path (delta warm-starts
    /// spliced from a neighbor's stage checkpoints) rather than a cold
    /// run. Counter deltas over this search only; all three are zero when
    /// the service was built [`Service::without_delta`].
    pub delta_hits: u64,
    /// Total stages skipped (replayed from checkpoints) across this
    /// search's delta warm-starts.
    pub delta_stages_skipped: u64,
    /// Total stages actually re-simulated across this search's delta
    /// warm-starts.
    pub delta_stages_replayed: u64,
}

/// The search engine.
pub struct Searcher<'a> {
    pub predictor: &'a Predictor,
    /// AOT analytic scorer; when None every candidate is refined.
    pub runtime: Option<&'a ScorerRuntime>,
    /// Candidates refined with the discrete-event predictor.
    pub refine_top_k: usize,
    /// Worker threads for the refinement sweep (candidates are
    /// independent `World`s; results are returned in enumeration order,
    /// byte-identical to the `threads == 1` sequential path).
    pub threads: usize,
    /// External service handle. When None, a private cold service is
    /// created per search — all evaluation traffic still flows through a
    /// `Service`, and a cold cache reproduces direct prediction
    /// byte-for-byte. Supplying a handle shares its cache (a warm handle
    /// makes a rescore free) and its single-flight table.
    service: Option<&'a Service>,
    /// Surrogate error gate: when set, grid-interior candidates whose
    /// interpolation error fits the bound are answered by the service's
    /// surrogate instead of a full simulation (the frontier is always
    /// simulated exactly). None — the default — refines exactly, and the
    /// surrogate is never consulted.
    surrogate: Option<f64>,
}

impl<'a> Searcher<'a> {
    pub fn new(predictor: &'a Predictor) -> Searcher<'a> {
        Searcher {
            predictor,
            runtime: None,
            refine_top_k: 12,
            threads: coordinator::available_threads(),
            service: None,
            surrogate: None,
        }
    }

    pub fn with_runtime(mut self, rt: &'a ScorerRuntime) -> Searcher<'a> {
        self.runtime = Some(rt);
        self
    }

    pub fn with_top_k(mut self, k: usize) -> Searcher<'a> {
        self.refine_top_k = k.max(1);
        self
    }

    /// Bound the refinement sweep's parallelism (1 = sequential).
    pub fn with_threads(mut self, t: usize) -> Searcher<'a> {
        self.threads = t.max(1);
        self
    }

    /// Evaluate through `service` (shared memoization across searches and
    /// with other callers) instead of a private cold service.
    pub fn with_service(mut self, service: &'a Service) -> Searcher<'a> {
        self.service = Some(service);
        self
    }

    /// Enable the surrogate fast-path with relative error gate `max_err`.
    pub fn with_surrogate(mut self, max_err: f64) -> Searcher<'a> {
        assert!(max_err > 0.0, "surrogate gate must be positive");
        self.surrogate = Some(max_err);
        self
    }

    /// Explore `space` for a workload family: `workload_for(config)`
    /// builds the concrete workload for a candidate (e.g. BLAST's task
    /// count follows the app-node count). `stage_descs` describes the
    /// family for the analytic prescreen.
    pub fn search(
        &self,
        space: &SearchSpace,
        stage_descs: &[StageDesc],
        workload_for: impl Fn(&Config) -> Workload + Sync,
    ) -> SearchReport {
        let t0 = std::time::Instant::now();
        // All evaluation traffic flows through a service: the caller's
        // handle when given, a private cold one otherwise (which makes
        // this path byte-identical to direct prediction).
        let owned_service;
        let service = match self.service {
            Some(s) => s,
            None => {
                owned_service = Service::new(self.predictor.clone());
                &owned_service
            }
        };
        // Neighbor evaluations ride the service's incremental
        // re-simulation path by default (see `model::delta`): the counter
        // deltas over this search become the report's delta_* fields.
        let stats0 = service.stats();
        if let Some(bound) = self.surrogate {
            let mut report = self.search_surrogate(space, bound, service, &workload_for, t0);
            stamp_delta(&mut report, &stats0, &service.stats());
            return report;
        }
        let configs = space.enumerate();
        assert!(!configs.is_empty(), "empty search space");

        // --- analytic prescreen (one artifact execution) ---
        let prescreen: Vec<Option<Score>> = match self.runtime {
            Some(rt) => {
                let cols: Vec<[f32; 8]> = configs.iter().map(encode_config).collect();
                let plat = encode_platform(&self.predictor.platform);
                match rt.score(&cols, stage_descs, &plat) {
                    Ok(scores) => scores.into_iter().map(Some).collect(),
                    Err(e) => {
                        eprintln!("prescreen failed ({e}); refining everything");
                        vec![None; configs.len()]
                    }
                }
            }
            None => vec![None; configs.len()],
        };

        // --- pick refinement set: union of top-K by time and by cost ---
        let k = self.refine_top_k.min(configs.len());
        let mut order_time: Vec<usize> = (0..configs.len()).collect();
        let mut order_cost = order_time.clone();
        let time_of = |i: usize| prescreen[i].map(|s| s.time_s).unwrap_or(0.0);
        let cost_of = |i: usize| prescreen[i].map(|s| s.cost_node_s).unwrap_or(0.0);
        order_time.sort_by(|&a, &b| time_of(a).partial_cmp(&time_of(b)).unwrap());
        order_cost.sort_by(|&a, &b| cost_of(a).partial_cmp(&cost_of(b)).unwrap());
        let mut refine: Vec<bool> = vec![false; configs.len()];
        let all_prescreened = prescreen.iter().all(|p| p.is_some());
        if all_prescreened {
            for &i in order_time.iter().take(k).chain(order_cost.iter().take(k)) {
                refine[i] = true;
            }
        } else {
            refine.iter_mut().for_each(|r| *r = true);
        }

        // --- discrete-event refinement (parallel over candidates) ---
        // Each candidate's simulation is deterministic and self-contained,
        // so the sweep fans out across scoped threads; results come back
        // in enumeration order, making the report byte-identical to the
        // sequential path.
        let refined: Vec<Option<Arc<Prediction>>> =
            coordinator::par_map_indexed(configs.len(), self.threads, |i| {
                if refine[i] {
                    let wl = workload_for(&configs[i]);
                    Some(service.evaluate(&wl, &configs[i]))
                } else {
                    None
                }
            });
        let mut candidates: Vec<Candidate> = Vec::with_capacity(configs.len());
        let mut pruned = 0;
        for (i, (cfg, refined)) in configs.into_iter().zip(refined).enumerate() {
            if refined.is_none() {
                pruned += 1;
            }
            candidates.push(Candidate {
                config: cfg,
                prescreen: prescreen[i],
                refined,
                surrogate: None,
            });
        }
        let mut report = assemble_report(candidates, pruned, t0);
        stamp_delta(&mut report, &stats0, &service.stats());
        report
    }

    /// The surrogate-gated search: exact seed evaluations pin each
    /// (allocation, chunk, replication) line of the grid, the interior is
    /// answered by gated interpolation, estimates outside the gate fall
    /// back to full simulation, and the apparent frontier (top-K by time
    /// and by cost) is always re-evaluated exactly. Every stage is a
    /// slot-ordered parallel map, so the report is deterministic at any
    /// thread count.
    fn search_surrogate(
        &self,
        space: &SearchSpace,
        bound: f64,
        service: &Service,
        workload_for: &(impl Fn(&Config) -> Workload + Sync),
        t0: std::time::Instant,
    ) -> SearchReport {
        const SEED_STRIDE: usize = 3;
        let configs = space.enumerate();
        assert!(!configs.is_empty(), "empty search space");
        // Surrogate-grid namespace for this search's workload family:
        // the canonical fingerprint of the first evaluation point, so two
        // searches sharing a warm service mix their grids only when
        // workload *content* (not just its name) and space agree —
        // parameters a workload name omits still separate families.
        let family = {
            let wl0 = workload_for(&configs[0]);
            service.fingerprint(&wl0, &configs[0]).hi
        };

        // Seed pass: every SEED_STRIDE-th n_app (plus the last) of each
        // (allocation, chunk, replication) line is evaluated exactly.
        let mut lines: HashMap<(usize, u64, u32), Vec<usize>> = HashMap::new();
        for (i, cfg) in configs.iter().enumerate() {
            lines
                .entry((cfg.n_hosts(), cfg.chunk_size.as_u64(), cfg.replication))
                .or_default()
                .push(i);
        }
        let mut is_seed = vec![false; configs.len()];
        for idx in lines.values_mut() {
            idx.sort_by_key(|&i| configs[i].n_app);
            for (k, &i) in idx.iter().enumerate() {
                if k % SEED_STRIDE == 0 || k == idx.len() - 1 {
                    is_seed[i] = true;
                }
            }
        }
        let eval = |i: usize| -> Arc<Prediction> {
            let wl = workload_for(&configs[i]);
            service.evaluate(&wl, &configs[i])
        };
        let mut refined: Vec<Option<Arc<Prediction>>> =
            coordinator::par_map_indexed(configs.len(), self.threads, |i| {
                if is_seed[i] {
                    Some(eval(i))
                } else {
                    None
                }
            });
        for (i, p) in refined.iter().enumerate() {
            if let Some(p) = p {
                service.note_sample(family, GridCoord::of(&configs[i]), p.turnaround.as_secs_f64());
            }
        }

        // Interior pass: interpolate; estimates outside the gate pay a
        // full simulation immediately.
        let mut surrogate: Vec<Option<Estimate>> = vec![None; configs.len()];
        let need_exact: Vec<usize> = (0..configs.len())
            .filter(|&i| refined[i].is_none())
            .filter(|&i| match service.interpolate(family, GridCoord::of(&configs[i]), bound) {
                Some(est) => {
                    surrogate[i] = Some(est);
                    false
                }
                None => true,
            })
            .collect();
        let extra: Vec<Arc<Prediction>> =
            coordinator::par_map_indexed(need_exact.len(), self.threads, |k| eval(need_exact[k]));
        for (&i, p) in need_exact.iter().zip(extra) {
            service.note_sample(family, GridCoord::of(&configs[i]), p.turnaround.as_secs_f64());
            refined[i] = Some(p);
        }

        // Frontier pass: the top-K by estimated time and by estimated
        // cost must be exact — only the flat interior stays surrogate.
        {
            let time_est = |i: usize| {
                refined[i]
                    .as_ref()
                    .map(|p| p.turnaround.as_secs_f64())
                    .or_else(|| surrogate[i].map(|e| e.time_s))
                    .unwrap_or(f64::INFINITY)
            };
            let cost_est = |i: usize| time_est(i) * configs[i].n_hosts() as f64;
            let k = self.refine_top_k.min(configs.len());
            let mut by_time: Vec<usize> = (0..configs.len()).collect();
            let mut by_cost = by_time.clone();
            by_time.sort_by(|&a, &b| time_est(a).partial_cmp(&time_est(b)).unwrap());
            by_cost.sort_by(|&a, &b| cost_est(a).partial_cmp(&cost_est(b)).unwrap());
            let mut frontier: Vec<usize> = by_time
                .iter()
                .take(k)
                .chain(by_cost.iter().take(k))
                .copied()
                .filter(|&i| refined[i].is_none())
                .collect();
            frontier.sort_unstable();
            frontier.dedup();
            let exact: Vec<Arc<Prediction>> =
                coordinator::par_map_indexed(frontier.len(), self.threads, |k2| eval(frontier[k2]));
            for (&i, p) in frontier.iter().zip(exact) {
                service.note_sample(family, GridCoord::of(&configs[i]), p.turnaround.as_secs_f64());
                refined[i] = Some(p);
                // The exact answer supersedes the interpolation; keep the
                // invariant that `surrogate` is set only on candidates the
                // fast-path actually answered.
                surrogate[i] = None;
            }
        }

        let mut candidates: Vec<Candidate> = Vec::with_capacity(configs.len());
        let mut pruned = 0;
        for (i, (cfg, refined)) in configs.into_iter().zip(refined).enumerate() {
            if refined.is_none() {
                pruned += 1;
            }
            candidates.push(Candidate {
                config: cfg,
                prescreen: None,
                refined,
                surrogate: surrogate[i],
            });
        }
        assemble_report(candidates, pruned, t0)
    }
}

/// Rank the answered candidates and assemble the report (shared by the
/// exact and surrogate search paths). Best-of answers and the pareto
/// front are computed over exactly-refined candidates only.
fn assemble_report(
    candidates: Vec<Candidate>,
    pruned: usize,
    t0: std::time::Instant,
) -> SearchReport {
    let refined_idx: Vec<usize> =
        (0..candidates.len()).filter(|&i| candidates[i].refined.is_some()).collect();
    let best_by = |f: &dyn Fn(&Candidate) -> f64| {
        *refined_idx
            .iter()
            .min_by(|&&a, &&b| f(&candidates[a]).partial_cmp(&f(&candidates[b])).unwrap())
            .unwrap()
    };
    let best_time = best_by(&|c| c.time_s());
    let best_cost = best_by(&|c| c.cost_node_s());
    let best_efficiency = best_by(&|c| c.time_s() * c.cost_node_s());

    // Pareto front over refined candidates.
    let mut front: Vec<usize> = Vec::new();
    for &i in &refined_idx {
        let (t, c) = (candidates[i].time_s(), candidates[i].cost_node_s());
        let dominated = refined_idx.iter().any(|&j| {
            j != i
                && candidates[j].time_s() <= t
                && candidates[j].cost_node_s() <= c
                && (candidates[j].time_s() < t || candidates[j].cost_node_s() < c)
        });
        if !dominated {
            front.push(i);
        }
    }
    front.sort_by(|&a, &b| candidates[a].time_s().partial_cmp(&candidates[b].time_s()).unwrap());

    SearchReport {
        candidates,
        best_time,
        best_cost,
        best_efficiency,
        pareto: front,
        pruned,
        wallclock_secs: t0.elapsed().as_secs_f64(),
        delta_hits: 0,
        delta_stages_skipped: 0,
        delta_stages_replayed: 0,
    }
}

/// Stamp the service's incremental re-simulation counter deltas for this
/// search onto its report. Counters are monotone, so the subtraction is
/// exact even on a shared warm handle.
fn stamp_delta(report: &mut SearchReport, before: &StatsSnapshot, after: &StatsSnapshot) {
    report.delta_hits = after.delta_hits - before.delta_hits;
    report.delta_stages_skipped = after.delta_stages_skipped - before.delta_stages_skipped;
    report.delta_stages_replayed = after.delta_stages_replayed - before.delta_stages_replayed;
}

/// Ranking agreement between prescreen and refined estimates over a
/// report: fraction of refined candidate pairs ordered identically
/// (Kendall-τ-style; used by the prescreen ablation bench).
pub fn ranking_agreement(report: &SearchReport) -> f64 {
    let xs: Vec<(f64, f64)> = report
        .candidates
        .iter()
        .filter(|c| c.refined.is_some() && c.prescreen.is_some())
        .map(|c| {
            (c.prescreen.unwrap().time_s as f64, c.refined.as_ref().unwrap().turnaround.as_secs_f64())
        })
        .collect();
    let mut agree = 0usize;
    let mut total = 0usize;
    for i in 0..xs.len() {
        for j in (i + 1)..xs.len() {
            total += 1;
            if ((xs[i].0 < xs[j].0) == (xs[i].1 < xs[j].1)) || (xs[i].0 == xs[j].0) {
                agree += 1;
            }
        }
    }
    if total == 0 {
        1.0
    } else {
        agree as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Platform;
    use crate::workload::blast::{blast, BlastParams};

    #[test]
    fn space_enumeration_counts() {
        let s = SearchSpace::fixed_cluster(20, vec![Bytes::kb(256), Bytes::mb(1)]);
        // 19 workers → n_app 1..18 → 18 partitionings × 2 chunks.
        assert_eq!(s.enumerate().len(), 36);
        let e = SearchSpace::elastic(vec![11, 17, 20], vec![Bytes::mb(1)]);
        assert_eq!(e.enumerate().len(), 9 + 15 + 18);
    }

    #[test]
    fn fault_plans_flow_into_enumerated_candidates() {
        let mut s = SearchSpace::fixed_cluster(8, vec![Bytes::mb(1)]);
        s.faults = FaultPlan::parse("crash=2@1").unwrap();
        let cfgs = s.enumerate();
        assert!(!cfgs.is_empty());
        // The plan names storage node 2, so partitionings with fewer than
        // 3 storage nodes are dropped; everything kept carries the plan.
        assert!(cfgs.iter().all(|c| c.n_storage >= 3));
        assert!(cfgs.iter().all(|c| !c.faults.is_empty()));
        let fault_free = SearchSpace::fixed_cluster(8, vec![Bytes::mb(1)]).enumerate();
        assert!(cfgs.len() < fault_free.len());
        assert!(fault_free.iter().all(|c| c.faults.is_empty()));
    }

    #[test]
    fn search_without_runtime_refines_everything() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let searcher = Searcher::new(&predictor);
        let space = SearchSpace {
            allocations: vec![8],
            chunk_sizes: vec![Bytes::mb(1)],
            replication: vec![1],
            min_storage: 1,
            faults: FaultPlan::default(),
        };
        let params = BlastParams { queries: 20, ..Default::default() };
        let report = searcher.search(&space, &[], |cfg| blast(cfg.n_app, &params));
        assert_eq!(report.pruned, 0);
        assert!(report.candidates.iter().all(|c| c.refined.is_some()));
        assert!(!report.pareto.is_empty());
        // Best-time config is faster than the 1-app edge.
        let edge = report.candidates.iter().find(|c| c.config.n_app == 1).unwrap();
        assert!(report.candidates[report.best_time].time_s() <= edge.time_s());
    }

    #[test]
    fn parallel_sweep_matches_sequential_byte_for_byte() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let space = SearchSpace::fixed_cluster(10, vec![Bytes::kb(256), Bytes::mb(1)]);
        let params = BlastParams { queries: 20, ..Default::default() };
        let seq = Searcher::new(&predictor)
            .with_threads(1)
            .search(&space, &[], |cfg| blast(cfg.n_app, &params));
        let par = Searcher::new(&predictor)
            .with_threads(4)
            .search(&space, &[], |cfg| blast(cfg.n_app, &params));
        assert_eq!(seq.candidates.len(), par.candidates.len());
        assert_eq!(seq.best_time, par.best_time);
        assert_eq!(seq.best_cost, par.best_cost);
        assert_eq!(seq.best_efficiency, par.best_efficiency);
        assert_eq!(seq.pareto, par.pareto);
        for (a, b) in seq.candidates.iter().zip(&par.candidates) {
            assert_eq!(a.config.label, b.config.label);
            match (&a.refined, &b.refined) {
                (Some(x), Some(y)) => {
                    assert_eq!(x.turnaround, y.turnaround, "{}", a.config.label);
                    assert_eq!(x.report.events, y.report.events);
                    assert_eq!(x.report.net_bytes, y.report.net_bytes);
                }
                (None, None) => {}
                _ => panic!("refinement sets differ between thread counts"),
            }
        }
    }

    #[test]
    fn cold_service_matches_direct_search() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let space = SearchSpace::fixed_cluster(10, vec![Bytes::kb(256), Bytes::mb(1)]);
        let params = BlastParams { queries: 20, ..Default::default() };
        let direct = Searcher::new(&predictor)
            .with_threads(2)
            .search(&space, &[], |cfg| blast(cfg.n_app, &params));
        let svc = Service::new(predictor.clone());
        let via = Searcher::new(&predictor)
            .with_service(&svc)
            .with_threads(2)
            .search(&space, &[], |cfg| blast(cfg.n_app, &params));
        assert_eq!(direct.best_time, via.best_time);
        assert_eq!(direct.best_cost, via.best_cost);
        assert_eq!(direct.pareto, via.pareto);
        for (a, b) in direct.candidates.iter().zip(&via.candidates) {
            let (x, y) = (a.refined.as_ref().unwrap(), b.refined.as_ref().unwrap());
            assert_eq!(x.turnaround, y.turnaround, "{}", a.config.label);
            assert_eq!(x.report.events, y.report.events);
            assert!(b.surrogate.is_none(), "gate off must never answer by surrogate");
        }
        assert_eq!(svc.stats().misses as usize, via.candidates.len());
    }

    #[test]
    fn surrogate_prunes_interior_and_keeps_frontier_exact() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let space = SearchSpace::fixed_cluster(16, vec![Bytes::kb(256), Bytes::mb(1)]);
        let params = BlastParams { queries: 40, ..Default::default() };
        let exhaustive = Searcher::new(&predictor)
            .with_top_k(usize::MAX)
            .search(&space, &[], |cfg| blast(cfg.n_app, &params));
        let best_exact = exhaustive.candidates[exhaustive.best_time].time_s();

        let svc = Service::new(predictor.clone());
        let report = Searcher::new(&predictor)
            .with_service(&svc)
            .with_top_k(8)
            .with_surrogate(0.5)
            .search(&space, &[], |cfg| blast(cfg.n_app, &params));
        assert_eq!(report.candidates.len(), exhaustive.candidates.len());
        // Every candidate is answered one way or the other; surrogate
        // answers always carry an error estimate within the gate.
        for c in &report.candidates {
            assert!(c.refined.is_some() || c.surrogate.is_some(), "{}", c.config.label);
            if let (None, Some(e)) = (&c.refined, &c.surrogate) {
                assert!(e.est_err >= 0.0 && e.est_err <= 0.5, "{}", e.est_err);
            }
        }
        assert!(report.pruned > 0, "the flat interior should be answered by the surrogate");
        assert!(
            (svc.stats().misses as usize) < report.candidates.len(),
            "surrogate must save simulations"
        );
        // The frontier answers are exact and near the exhaustive optimum.
        for i in [report.best_time, report.best_cost, report.best_efficiency] {
            assert!(report.candidates[i].refined.is_some(), "frontier must be exact");
        }
        let best = report.candidates[report.best_time].time_s();
        assert!(
            best <= best_exact * 1.05,
            "surrogate search lost the optimum: {best:.1}s vs {best_exact:.1}s"
        );
    }

    #[test]
    fn surrogate_search_is_deterministic_across_thread_counts() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let space = SearchSpace::fixed_cluster(12, vec![Bytes::kb(256)]);
        let params = BlastParams { queries: 20, ..Default::default() };
        let run = |threads: usize| {
            let svc = Service::new(predictor.clone());
            Searcher::new(&predictor)
                .with_service(&svc)
                .with_threads(threads)
                .with_surrogate(0.4)
                .search(&space, &[], |cfg| blast(cfg.n_app, &params))
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(a.best_time, b.best_time);
        assert_eq!(a.pruned, b.pruned);
        for (x, y) in a.candidates.iter().zip(&b.candidates) {
            assert_eq!(x.refined.is_some(), y.refined.is_some(), "{}", x.config.label);
            match (&x.refined, &y.refined) {
                (Some(p), Some(q)) => assert_eq!(p.turnaround, q.turnaround),
                _ => {
                    let (e, f) = (x.surrogate.unwrap(), y.surrogate.unwrap());
                    assert_eq!(e.time_s.to_bits(), f.time_s.to_bits());
                    assert_eq!(e.est_err.to_bits(), f.est_err.to_bits());
                }
            }
        }
    }

    #[test]
    fn pareto_front_is_nondominated() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let searcher = Searcher::new(&predictor);
        let space = SearchSpace::elastic(vec![6, 10], vec![Bytes::mb(1)]);
        let params = BlastParams { queries: 20, ..Default::default() };
        let report = searcher.search(&space, &[], |cfg| blast(cfg.n_app, &params));
        for &i in &report.pareto {
            for &j in &report.pareto {
                if i != j {
                    let dom = report.candidates[j].time_s() < report.candidates[i].time_s()
                        && report.candidates[j].cost_node_s() < report.candidates[i].cost_node_s();
                    assert!(!dom, "pareto member dominated");
                }
            }
        }
    }
}
