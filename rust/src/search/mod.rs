//! Configuration-space exploration — the purpose the predictor serves
//! (paper §1: "enable selecting a good choice in a reasonable time" across
//! provisioning, partitioning and per-subsystem configuration).
//!
//! Pipeline: enumerate the grid → **analytic prescreen** (one PJRT
//! execution of the AOT artifact scores the whole grid; L1/L2) → refine
//! the top candidates with the discrete-event predictor (L3) → report the
//! answers to the paper's four user questions: best-performance
//! configuration, lowest-cost allocation, best partitioning, and most
//! cost-efficient point — plus the time/cost pareto front of Scenario II.

pub mod anneal;

use crate::coordinator;
use crate::model::Config;
use crate::predict::{Prediction, Predictor};
use crate::runtime::{encode_config, encode_platform, Score, ScorerRuntime, StageDesc};
use crate::util::units::Bytes;
use crate::workload::Workload;

/// The decision space (paper §1 "The Problem"): provisioning ×
/// partitioning × configuration.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    /// Candidate total allocation sizes (incl. the manager host).
    pub allocations: Vec<usize>,
    /// Chunk sizes to explore.
    pub chunk_sizes: Vec<Bytes>,
    /// Replication levels to explore.
    pub replication: Vec<u32>,
    /// Minimum storage nodes to consider per partitioning.
    pub min_storage: usize,
}

impl SearchSpace {
    /// Scenario I space: one fixed cluster, all partitionings × chunks.
    pub fn fixed_cluster(total_nodes: usize, chunk_sizes: Vec<Bytes>) -> SearchSpace {
        SearchSpace { allocations: vec![total_nodes], chunk_sizes, replication: vec![1], min_storage: 1 }
    }

    /// Scenario II space: several allocation sizes (paper: 11, 17, 20).
    pub fn elastic(allocations: Vec<usize>, chunk_sizes: Vec<Bytes>) -> SearchSpace {
        SearchSpace { allocations, chunk_sizes, replication: vec![1], min_storage: 1 }
    }

    /// Enumerate all candidate configurations.
    pub fn enumerate(&self) -> Vec<Config> {
        let mut out = Vec::new();
        for &total in &self.allocations {
            assert!(total >= 3, "need at least app + storage + manager");
            let workers = total - 1; // manager takes one host
            for n_app in 1..=(workers - self.min_storage) {
                let n_storage = workers - n_app;
                for &chunk in &self.chunk_sizes {
                    for &r in &self.replication {
                        if r as usize > n_storage {
                            continue;
                        }
                        let cfg = Config::partitioned(n_app, n_storage, chunk).with_replication(r);
                        out.push(cfg);
                    }
                }
            }
        }
        out
    }
}

/// One evaluated candidate.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub config: Config,
    /// Analytic prescreen score (None when no artifact is available).
    pub prescreen: Option<Score>,
    /// Discrete-event refinement (None if pruned).
    pub refined: Option<Prediction>,
}

impl Candidate {
    /// Best available time estimate (refined preferred).
    pub fn time_s(&self) -> f64 {
        self.refined
            .as_ref()
            .map(|p| p.turnaround.as_secs_f64())
            .or(self.prescreen.map(|s| s.time_s as f64))
            .unwrap_or(f64::INFINITY)
    }

    pub fn cost_node_s(&self) -> f64 {
        self.refined
            .as_ref()
            .map(|p| p.cost_node_secs)
            .or(self.prescreen.map(|s| s.cost_node_s as f64))
            .unwrap_or(f64::INFINITY)
    }
}

/// Search outcome: the paper's four questions, answered.
#[derive(Debug)]
pub struct SearchReport {
    pub candidates: Vec<Candidate>,
    /// Index of the fastest refined configuration.
    pub best_time: usize,
    /// Index of the cheapest refined configuration.
    pub best_cost: usize,
    /// Index of the most cost-efficient (lowest cost × time product).
    pub best_efficiency: usize,
    /// Pareto-optimal (time, cost) candidates, sorted by time.
    pub pareto: Vec<usize>,
    /// How many candidates the prescreen pruned before refinement.
    pub pruned: usize,
    pub wallclock_secs: f64,
}

/// The search engine.
pub struct Searcher<'a> {
    pub predictor: &'a Predictor,
    /// AOT analytic scorer; when None every candidate is refined.
    pub runtime: Option<&'a ScorerRuntime>,
    /// Candidates refined with the discrete-event predictor.
    pub refine_top_k: usize,
    /// Worker threads for the refinement sweep (candidates are
    /// independent `World`s; results are returned in enumeration order,
    /// byte-identical to the `threads == 1` sequential path).
    pub threads: usize,
}

impl<'a> Searcher<'a> {
    pub fn new(predictor: &'a Predictor) -> Searcher<'a> {
        Searcher {
            predictor,
            runtime: None,
            refine_top_k: 12,
            threads: coordinator::available_threads(),
        }
    }

    pub fn with_runtime(mut self, rt: &'a ScorerRuntime) -> Searcher<'a> {
        self.runtime = Some(rt);
        self
    }

    pub fn with_top_k(mut self, k: usize) -> Searcher<'a> {
        self.refine_top_k = k.max(1);
        self
    }

    /// Bound the refinement sweep's parallelism (1 = sequential).
    pub fn with_threads(mut self, t: usize) -> Searcher<'a> {
        self.threads = t.max(1);
        self
    }

    /// Explore `space` for a workload family: `workload_for(config)`
    /// builds the concrete workload for a candidate (e.g. BLAST's task
    /// count follows the app-node count). `stage_descs` describes the
    /// family for the analytic prescreen.
    pub fn search(
        &self,
        space: &SearchSpace,
        stage_descs: &[StageDesc],
        workload_for: impl Fn(&Config) -> Workload + Sync,
    ) -> SearchReport {
        let t0 = std::time::Instant::now();
        let configs = space.enumerate();
        assert!(!configs.is_empty(), "empty search space");

        // --- analytic prescreen (one artifact execution) ---
        let prescreen: Vec<Option<Score>> = match self.runtime {
            Some(rt) => {
                let cols: Vec<[f32; 8]> = configs.iter().map(encode_config).collect();
                let plat = encode_platform(&self.predictor.platform);
                match rt.score(&cols, stage_descs, &plat) {
                    Ok(scores) => scores.into_iter().map(Some).collect(),
                    Err(e) => {
                        eprintln!("prescreen failed ({e}); refining everything");
                        vec![None; configs.len()]
                    }
                }
            }
            None => vec![None; configs.len()],
        };

        // --- pick refinement set: union of top-K by time and by cost ---
        let k = self.refine_top_k.min(configs.len());
        let mut order_time: Vec<usize> = (0..configs.len()).collect();
        let mut order_cost = order_time.clone();
        let time_of = |i: usize| prescreen[i].map(|s| s.time_s).unwrap_or(0.0);
        let cost_of = |i: usize| prescreen[i].map(|s| s.cost_node_s).unwrap_or(0.0);
        order_time.sort_by(|&a, &b| time_of(a).partial_cmp(&time_of(b)).unwrap());
        order_cost.sort_by(|&a, &b| cost_of(a).partial_cmp(&cost_of(b)).unwrap());
        let mut refine: Vec<bool> = vec![false; configs.len()];
        let all_prescreened = prescreen.iter().all(|p| p.is_some());
        if all_prescreened {
            for &i in order_time.iter().take(k).chain(order_cost.iter().take(k)) {
                refine[i] = true;
            }
        } else {
            refine.iter_mut().for_each(|r| *r = true);
        }

        // --- discrete-event refinement (parallel over candidates) ---
        // Each candidate's simulation is deterministic and self-contained,
        // so the sweep fans out across scoped threads; results come back
        // in enumeration order, making the report byte-identical to the
        // sequential path.
        let predictor = self.predictor;
        let refined: Vec<Option<Prediction>> =
            coordinator::par_map_indexed(configs.len(), self.threads, |i| {
                if refine[i] {
                    let wl = workload_for(&configs[i]);
                    Some(predictor.predict(&wl, &configs[i]))
                } else {
                    None
                }
            });
        let mut candidates: Vec<Candidate> = Vec::with_capacity(configs.len());
        let mut pruned = 0;
        for (i, (cfg, refined)) in configs.into_iter().zip(refined).enumerate() {
            if refined.is_none() {
                pruned += 1;
            }
            candidates.push(Candidate { config: cfg, prescreen: prescreen[i], refined });
        }

        // --- answers ---
        let refined_idx: Vec<usize> =
            (0..candidates.len()).filter(|&i| candidates[i].refined.is_some()).collect();
        let best_by = |f: &dyn Fn(&Candidate) -> f64| {
            *refined_idx
                .iter()
                .min_by(|&&a, &&b| f(&candidates[a]).partial_cmp(&f(&candidates[b])).unwrap())
                .unwrap()
        };
        let best_time = best_by(&|c| c.time_s());
        let best_cost = best_by(&|c| c.cost_node_s());
        let best_efficiency = best_by(&|c| c.time_s() * c.cost_node_s());

        // Pareto front over refined candidates.
        let mut front: Vec<usize> = Vec::new();
        for &i in &refined_idx {
            let (t, c) = (candidates[i].time_s(), candidates[i].cost_node_s());
            let dominated = refined_idx.iter().any(|&j| {
                j != i
                    && candidates[j].time_s() <= t
                    && candidates[j].cost_node_s() <= c
                    && (candidates[j].time_s() < t || candidates[j].cost_node_s() < c)
            });
            if !dominated {
                front.push(i);
            }
        }
        front.sort_by(|&a, &b| candidates[a].time_s().partial_cmp(&candidates[b].time_s()).unwrap());

        SearchReport {
            candidates,
            best_time,
            best_cost,
            best_efficiency,
            pareto: front,
            pruned,
            wallclock_secs: t0.elapsed().as_secs_f64(),
        }
    }
}

/// Ranking agreement between prescreen and refined estimates over a
/// report: fraction of refined candidate pairs ordered identically
/// (Kendall-τ-style; used by the prescreen ablation bench).
pub fn ranking_agreement(report: &SearchReport) -> f64 {
    let xs: Vec<(f64, f64)> = report
        .candidates
        .iter()
        .filter(|c| c.refined.is_some() && c.prescreen.is_some())
        .map(|c| {
            (c.prescreen.unwrap().time_s as f64, c.refined.as_ref().unwrap().turnaround.as_secs_f64())
        })
        .collect();
    let mut agree = 0usize;
    let mut total = 0usize;
    for i in 0..xs.len() {
        for j in (i + 1)..xs.len() {
            total += 1;
            if ((xs[i].0 < xs[j].0) == (xs[i].1 < xs[j].1)) || (xs[i].0 == xs[j].0) {
                agree += 1;
            }
        }
    }
    if total == 0 {
        1.0
    } else {
        agree as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Platform;
    use crate::workload::blast::{blast, BlastParams};

    #[test]
    fn space_enumeration_counts() {
        let s = SearchSpace::fixed_cluster(20, vec![Bytes::kb(256), Bytes::mb(1)]);
        // 19 workers → n_app 1..18 → 18 partitionings × 2 chunks.
        assert_eq!(s.enumerate().len(), 36);
        let e = SearchSpace::elastic(vec![11, 17, 20], vec![Bytes::mb(1)]);
        assert_eq!(e.enumerate().len(), 9 + 15 + 18);
    }

    #[test]
    fn search_without_runtime_refines_everything() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let searcher = Searcher::new(&predictor);
        let space = SearchSpace {
            allocations: vec![8],
            chunk_sizes: vec![Bytes::mb(1)],
            replication: vec![1],
            min_storage: 1,
        };
        let params = BlastParams { queries: 20, ..Default::default() };
        let report = searcher.search(&space, &[], |cfg| blast(cfg.n_app, &params));
        assert_eq!(report.pruned, 0);
        assert!(report.candidates.iter().all(|c| c.refined.is_some()));
        assert!(!report.pareto.is_empty());
        // Best-time config is faster than the 1-app edge.
        let edge = report.candidates.iter().find(|c| c.config.n_app == 1).unwrap();
        assert!(report.candidates[report.best_time].time_s() <= edge.time_s());
    }

    #[test]
    fn parallel_sweep_matches_sequential_byte_for_byte() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let space = SearchSpace::fixed_cluster(10, vec![Bytes::kb(256), Bytes::mb(1)]);
        let params = BlastParams { queries: 20, ..Default::default() };
        let seq = Searcher::new(&predictor)
            .with_threads(1)
            .search(&space, &[], |cfg| blast(cfg.n_app, &params));
        let par = Searcher::new(&predictor)
            .with_threads(4)
            .search(&space, &[], |cfg| blast(cfg.n_app, &params));
        assert_eq!(seq.candidates.len(), par.candidates.len());
        assert_eq!(seq.best_time, par.best_time);
        assert_eq!(seq.best_cost, par.best_cost);
        assert_eq!(seq.best_efficiency, par.best_efficiency);
        assert_eq!(seq.pareto, par.pareto);
        for (a, b) in seq.candidates.iter().zip(&par.candidates) {
            assert_eq!(a.config.label, b.config.label);
            match (&a.refined, &b.refined) {
                (Some(x), Some(y)) => {
                    assert_eq!(x.turnaround, y.turnaround, "{}", a.config.label);
                    assert_eq!(x.report.events, y.report.events);
                    assert_eq!(x.report.net_bytes, y.report.net_bytes);
                }
                (None, None) => {}
                _ => panic!("refinement sets differ between thread counts"),
            }
        }
    }

    #[test]
    fn pareto_front_is_nondominated() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let searcher = Searcher::new(&predictor);
        let space = SearchSpace::elastic(vec![6, 10], vec![Bytes::mb(1)]);
        let params = BlastParams { queries: 20, ..Default::default() };
        let report = searcher.search(&space, &[], |cfg| blast(cfg.n_app, &params));
        for &i in &report.pareto {
            for &j in &report.pareto {
                if i != j {
                    let dom = report.candidates[j].time_s() < report.candidates[i].time_s()
                        && report.candidates[j].cost_node_s() < report.candidates[i].cost_node_s();
                    assert!(!dom, "pareto member dominated");
                }
            }
        }
    }
}
