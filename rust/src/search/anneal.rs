//! Simulated-annealing solver — the paper's future-work item (iv):
//! "explore different optimization solvers to search the configuration
//! space". Exhaustive enumeration is fine at 20 nodes; at thousands of
//! nodes × chunk sizes × replication levels the grid explodes, and a
//! local-search solver with the DES predictor as its objective gets
//! within a few percent of the optimum at a fraction of the evaluations.

use crate::coordinator;
use crate::model::Config;
use crate::predict::Predictor;
use crate::search::SearchSpace;
use crate::util::rng::Rng;
use crate::workload::Workload;
use std::collections::HashMap;

/// Result of an annealing run.
#[derive(Clone, Debug)]
pub struct AnnealResult {
    pub best: Config,
    pub best_time_s: f64,
    /// Distinct DES evaluations performed (cache hits excluded; summed
    /// across chains).
    pub evaluations: usize,
    /// (time_s per accepted step) — the winning chain's descent trace.
    pub trace: Vec<f64>,
}

/// Simulated annealing over (allocation, partitioning, chunk, replication).
pub struct Annealer {
    pub steps: u32,
    pub t0: f64,
    pub cooling: f64,
    pub seed: u64,
    /// Independent restart chains, run in parallel across scoped threads
    /// (each chain derives its RNG from `seed` + chain index, so any
    /// chain count is deterministic). 1 = the classic sequential run.
    pub chains: u32,
}

impl Default for Annealer {
    fn default() -> Self {
        Annealer { steps: 60, t0: 0.3, cooling: 0.93, seed: 0xA11EA1, chains: 1 }
    }
}

impl Annealer {
    /// Key for the evaluation cache.
    fn key(cfg: &Config) -> (usize, usize, u64, u32) {
        (cfg.n_app, cfg.n_storage, cfg.chunk_size.as_u64(), cfg.replication)
    }

    /// Random neighbor: perturb one axis within the space.
    fn neighbor(&self, rng: &mut Rng, space: &SearchSpace, cfg: &Config) -> Config {
        let total = cfg.n_hosts();
        let workers = total - 1;
        let mut n_app = cfg.n_app;
        let mut chunk = cfg.chunk_size;
        let mut repl = cfg.replication;
        let mut alloc = total;
        match rng.below(4) {
            0 => {
                // Move one node between partitions.
                let delta: i64 = if rng.next_f64() < 0.5 { -1 } else { 1 };
                n_app = (n_app as i64 + delta)
                    .clamp(1, (workers - space.min_storage) as i64) as usize;
            }
            1 => chunk = *rng.choose(&space.chunk_sizes),
            2 => repl = *rng.choose(&space.replication),
            _ => {
                alloc = *rng.choose(&space.allocations);
                let w = alloc - 1;
                n_app = n_app.clamp(1, w - space.min_storage);
            }
        }
        let n_storage = (alloc - 1) - n_app;
        let repl = repl.min(n_storage as u32).max(1);
        Config::partitioned(n_app, n_storage, chunk).with_replication(repl)
    }

    /// Minimize predicted turnaround over `space` for the workload family.
    ///
    /// Runs [`Annealer::chains`] independent chains in parallel (the DES
    /// objective dominates the cost and every chain is self-contained) and
    /// returns the best, breaking ties by chain index so the result is
    /// deterministic regardless of thread scheduling.
    pub fn minimize(
        &self,
        predictor: &Predictor,
        space: &SearchSpace,
        workload_for: impl Fn(&Config) -> Workload + Sync,
    ) -> AnnealResult {
        assert!(!space.allocations.is_empty() && !space.chunk_sizes.is_empty());
        let chains = self.chains.max(1) as usize;
        // Cap workers at the core count; slot-by-index results make the
        // outcome independent of how many threads actually run.
        let workers = coordinator::available_threads().min(chains);
        let mut results = coordinator::par_map_indexed(chains, workers, |i| {
            // Chain 0 reproduces the single-chain run bit-for-bit.
            let seed = self.seed.wrapping_add(i as u64 * 0x9E37_79B9_7F4A_7C15);
            self.minimize_chain(predictor, space, &workload_for, seed)
        });
        let total_evals: usize = results.iter().map(|r| r.evaluations).sum();
        let mut best_idx = 0;
        for i in 1..results.len() {
            // Strict `<` keeps the lowest chain index on ties.
            if results[i].best_time_s < results[best_idx].best_time_s {
                best_idx = i;
            }
        }
        let mut best = results.swap_remove(best_idx);
        best.evaluations = total_evals;
        best
    }

    /// One annealing chain (sequential; the unit of parallelism).
    fn minimize_chain(
        &self,
        predictor: &Predictor,
        space: &SearchSpace,
        workload_for: &(impl Fn(&Config) -> Workload + Sync),
        seed: u64,
    ) -> AnnealResult {
        let mut rng = Rng::new(seed);
        let mut cache: HashMap<(usize, usize, u64, u32), f64> = HashMap::new();
        let mut evals = 0usize;
        let mut eval = |cfg: &Config, evals: &mut usize| -> f64 {
            let k = Self::key(cfg);
            if let Some(&t) = cache.get(&k) {
                return t;
            }
            let wl = workload_for(cfg);
            let t = predictor.predict(&wl, cfg).turnaround.as_secs_f64();
            cache.insert(k, t);
            *evals += 1;
            t
        };

        // Start from a balanced middle point.
        let alloc0 = space.allocations[space.allocations.len() / 2];
        let w0 = alloc0 - 1;
        let mut cur = Config::partitioned(w0 / 2, w0 - w0 / 2, space.chunk_sizes[0]);
        let mut cur_t = eval(&cur, &mut evals);
        let mut best = cur.clone();
        let mut best_t = cur_t;
        let mut trace = vec![cur_t];
        let mut temp = self.t0;

        for _ in 0..self.steps {
            let cand = self.neighbor(&mut rng, space, &cur);
            if cand.validate().is_err() {
                continue;
            }
            let cand_t = eval(&cand, &mut evals);
            let rel = (cand_t - cur_t) / cur_t;
            if rel <= 0.0 || rng.next_f64() < (-rel / temp).exp() {
                cur = cand;
                cur_t = cand_t;
                trace.push(cur_t);
                if cur_t < best_t {
                    best_t = cur_t;
                    best = cur.clone();
                }
            }
            temp *= self.cooling;
        }

        AnnealResult { best, best_time_s: best_t, evaluations: evals, trace }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Platform;
    use crate::util::units::Bytes;
    use crate::workload::blast::{blast, BlastParams};

    #[test]
    fn anneal_finds_near_optimal_blast_partitioning_cheaply() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let space = SearchSpace::fixed_cluster(
            20,
            vec![Bytes::kb(256), Bytes::mb(1), Bytes::mb(4)],
        );
        let params = BlastParams { queries: 100, ..Default::default() };
        let grid = space.enumerate();

        // Exhaustive optimum for reference.
        let exhaustive_best = grid
            .iter()
            .map(|cfg| predictor.predict(&blast(cfg.n_app, &params), cfg).turnaround.as_secs_f64())
            .fold(f64::MAX, f64::min);

        let r = Annealer::default().minimize(&predictor, &space, |cfg| blast(cfg.n_app, &params));
        println!(
            "anneal: best {:.1}s vs exhaustive {:.1}s with {}/{} evaluations",
            r.best_time_s,
            exhaustive_best,
            r.evaluations,
            grid.len()
        );
        assert!(
            r.best_time_s <= exhaustive_best * 1.05,
            "annealing should land within 5% of the optimum"
        );
        assert!(
            r.evaluations < grid.len(),
            "annealing should evaluate fewer points than the grid ({} vs {})",
            r.evaluations,
            grid.len()
        );
        // The descent trace improves overall.
        assert!(r.trace.last().unwrap() <= r.trace.first().unwrap());
    }

    #[test]
    fn parallel_chains_deterministic_and_no_worse_than_chain_zero() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let space = SearchSpace::fixed_cluster(10, vec![Bytes::kb(256), Bytes::mb(1)]);
        let params = BlastParams { queries: 30, ..Default::default() };
        let wl = |cfg: &Config| blast(cfg.n_app, &params);
        let single = Annealer { steps: 12, ..Default::default() }.minimize(&predictor, &space, wl);
        let a = Annealer { steps: 12, chains: 4, ..Default::default() }
            .minimize(&predictor, &space, wl);
        let b = Annealer { steps: 12, chains: 4, ..Default::default() }
            .minimize(&predictor, &space, wl);
        assert_eq!(a.best_time_s, b.best_time_s, "chains must not introduce nondeterminism");
        assert_eq!(a.evaluations, b.evaluations);
        // Chain 0 reproduces the single-chain run, so the 4-chain best can
        // only match or improve on it.
        assert!(a.best_time_s <= single.best_time_s);
        assert!(a.evaluations >= single.evaluations);
    }

    #[test]
    fn anneal_is_deterministic_per_seed() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let space = SearchSpace::fixed_cluster(10, vec![Bytes::mb(1)]);
        let params = BlastParams { queries: 30, ..Default::default() };
        let a = Annealer { steps: 20, ..Default::default() }
            .minimize(&predictor, &space, |cfg| blast(cfg.n_app, &params));
        let b = Annealer { steps: 20, ..Default::default() }
            .minimize(&predictor, &space, |cfg| blast(cfg.n_app, &params));
        assert_eq!(a.best_time_s, b.best_time_s);
        assert_eq!(a.evaluations, b.evaluations);
    }
}
