//! Simulated-annealing solver — the paper's future-work item (iv):
//! "explore different optimization solvers to search the configuration
//! space". Exhaustive enumeration is fine at 20 nodes; at thousands of
//! nodes × chunk sizes × replication levels the grid explodes, and a
//! local-search solver with the DES predictor as its objective gets
//! within a few percent of the optimum at a fraction of the evaluations.
//!
//! Evaluation flows through a [`Service`] handle: chains share its
//! memoization, so a point any chain has visited is never simulated
//! twice, and concurrent chains hitting the same fresh point collapse
//! onto one in-flight simulation (single-flight). With
//! [`Annealer::exchange_every`] set, chains periodically exchange their
//! best state (parallel-tempering-style broadcast) at a deterministic
//! barrier — cheap now that the service cache absorbs the revisits an
//! adopted state causes.
//!
//! Fresh points ride the service's incremental re-simulation path
//! (`crate::model::delta`): annealing moves perturb one knob at a time,
//! so a neighbor usually shares a stage-fingerprint prefix with the
//! point it came from and replays only the suffix of stages the knob
//! touches. [`AnnealResult::delta_hits`] reports how often that paid off.

use crate::coordinator;
use crate::model::Config;
use crate::predict::Predictor;
use crate::search::SearchSpace;
use crate::service::Service;
use crate::util::rng::Rng;
use crate::workload::Workload;

/// Result of an annealing run.
#[derive(Clone, Debug)]
pub struct AnnealResult {
    pub best: Config,
    pub best_time_s: f64,
    /// Distinct DES simulations issued through the service. Chains share
    /// the cache, so a point visited by several chains counts once.
    /// Delta warm-starts count here too — they are real simulations, just
    /// cheaper ones.
    pub evaluations: usize,
    /// (time_s per accepted step) — the winning chain's descent trace.
    pub trace: Vec<f64>,
    /// Of `evaluations`, how many were delta warm-starts spliced from a
    /// neighbor's stage checkpoints (see `crate::model::delta`) instead
    /// of cold simulations. Annealing moves perturb one knob at a time,
    /// which is exactly the access pattern the delta path favors.
    pub delta_hits: u64,
    /// Total stages skipped across this run's delta warm-starts.
    pub delta_stages_skipped: u64,
    /// Total stages re-simulated across this run's delta warm-starts.
    pub delta_stages_replayed: u64,
}

/// Simulated annealing over (allocation, partitioning, chunk, replication).
pub struct Annealer {
    pub steps: u32,
    pub t0: f64,
    pub cooling: f64,
    pub seed: u64,
    /// Independent restart chains, run in parallel across scoped threads
    /// (each chain derives its RNG from `seed` + chain index, so any
    /// chain count is deterministic). 1 = the classic sequential run.
    pub chains: u32,
    /// Steps between best-state exchanges across chains. 0 (default)
    /// keeps chains fully independent — chain 0 then reproduces the
    /// single-chain run bit-for-bit. When set, every chain whose current
    /// state is worse than the global best-so-far adopts it at the
    /// exchange barrier; the barrier operates on slot-ordered chain
    /// states with ties broken to the lowest chain index, and each chain
    /// keeps its own RNG stream and temperature, so the outcome is
    /// deterministic at any thread count.
    pub exchange_every: u32,
}

impl Default for Annealer {
    fn default() -> Self {
        Annealer { steps: 60, t0: 0.3, cooling: 0.93, seed: 0xA11EA1, chains: 1, exchange_every: 0 }
    }
}

/// One chain's mutable state between segments.
#[derive(Clone)]
struct ChainState {
    rng: Rng,
    cur: Config,
    cur_t: f64,
    best: Config,
    best_t: f64,
    trace: Vec<f64>,
    temp: f64,
}

impl Annealer {
    /// Random neighbor: perturb one axis within the space.
    fn neighbor(&self, rng: &mut Rng, space: &SearchSpace, cfg: &Config) -> Config {
        let total = cfg.n_hosts();
        let workers = total - 1;
        let mut n_app = cfg.n_app;
        let mut chunk = cfg.chunk_size;
        let mut repl = cfg.replication;
        let mut alloc = total;
        match rng.below(4) {
            0 => {
                // Move one node between partitions.
                let delta: i64 = if rng.next_f64() < 0.5 { -1 } else { 1 };
                n_app = (n_app as i64 + delta)
                    .clamp(1, (workers - space.min_storage) as i64) as usize;
            }
            1 => chunk = *rng.choose(&space.chunk_sizes),
            2 => repl = *rng.choose(&space.replication),
            _ => {
                alloc = *rng.choose(&space.allocations);
                let w = alloc - 1;
                n_app = n_app.clamp(1, w - space.min_storage);
            }
        }
        let n_storage = (alloc - 1) - n_app;
        // n_storage can be 0 when `min_storage == 0`; keep repl
        // well-formed (clamp panics on an empty range) and let the
        // caller's validate() reject the candidate.
        let repl = repl.clamp(1, (n_storage as u32).max(1));
        Config::partitioned(n_app, n_storage, chunk).with_replication(repl)
    }

    /// Minimize predicted turnaround over `space` for the workload family
    /// through a private cold service.
    pub fn minimize(
        &self,
        predictor: &Predictor,
        space: &SearchSpace,
        workload_for: impl Fn(&Config) -> Workload + Sync,
    ) -> AnnealResult {
        let service = Service::new(predictor.clone());
        self.minimize_with(&service, space, workload_for)
    }

    /// Minimize through an external service handle — chains share its
    /// cache with each other and with any other caller (a warm handle
    /// from a previous search skips re-simulating visited points, which
    /// only shows up in `evaluations`, never in the trajectory).
    ///
    /// Runs [`Annealer::chains`] independent chains in parallel (the DES
    /// objective dominates the cost and every chain is self-contained
    /// between exchange barriers) and returns the best, breaking ties by
    /// chain index so the result is deterministic regardless of thread
    /// scheduling.
    pub fn minimize_with(
        &self,
        service: &Service,
        space: &SearchSpace,
        workload_for: impl Fn(&Config) -> Workload + Sync,
    ) -> AnnealResult {
        assert!(!space.allocations.is_empty() && !space.chunk_sizes.is_empty());
        let chains = self.chains.max(1) as usize;
        // Cap workers at the core count; slot-by-index results make the
        // outcome independent of how many threads actually run.
        let workers = coordinator::available_threads().min(chains);
        let stats0 = service.stats();

        let mut states = coordinator::par_map_indexed(chains, workers, |i| {
            // Chain 0 reproduces the single-chain run bit-for-bit.
            let seed = self.seed.wrapping_add(i as u64 * 0x9E37_79B9_7F4A_7C15);
            self.chain_init(service, space, &workload_for, seed)
        });

        let mut done = 0u32;
        while done < self.steps {
            let segment = if self.exchange_every == 0 {
                self.steps - done
            } else {
                self.exchange_every.min(self.steps - done)
            };
            let snapshot = states;
            states = coordinator::par_map_indexed(chains, workers, |i| {
                let mut st = snapshot[i].clone();
                self.chain_run(service, space, &workload_for, &mut st, segment);
                st
            });
            done += segment;
            if self.exchange_every > 0 && done < self.steps {
                Self::exchange(&mut states);
            }
        }

        let mut best_idx = 0;
        for i in 1..states.len() {
            // Strict `<` keeps the lowest chain index on ties.
            if states[i].best_t < states[best_idx].best_t {
                best_idx = i;
            }
        }
        let winner = states.swap_remove(best_idx);
        let stats1 = service.stats();
        AnnealResult {
            best: winner.best,
            best_time_s: winner.best_t,
            evaluations: (stats1.misses - stats0.misses) as usize,
            trace: winner.trace,
            delta_hits: stats1.delta_hits - stats0.delta_hits,
            delta_stages_skipped: stats1.delta_stages_skipped - stats0.delta_stages_skipped,
            delta_stages_replayed: stats1.delta_stages_replayed - stats0.delta_stages_replayed,
        }
    }

    fn eval(
        service: &Service,
        workload_for: &(impl Fn(&Config) -> Workload + Sync),
        cfg: &Config,
    ) -> f64 {
        let wl = workload_for(cfg);
        service.evaluate(&wl, cfg).turnaround.as_secs_f64()
    }

    /// Start a chain from the balanced middle point.
    fn chain_init(
        &self,
        service: &Service,
        space: &SearchSpace,
        workload_for: &(impl Fn(&Config) -> Workload + Sync),
        seed: u64,
    ) -> ChainState {
        let rng = Rng::new(seed);
        let alloc0 = space.allocations[space.allocations.len() / 2];
        let w0 = alloc0 - 1;
        let cur = Config::partitioned(w0 / 2, w0 - w0 / 2, space.chunk_sizes[0]);
        let cur_t = Self::eval(service, workload_for, &cur);
        ChainState {
            rng,
            best: cur.clone(),
            best_t: cur_t,
            trace: vec![cur_t],
            temp: self.t0,
            cur,
            cur_t,
        }
    }

    /// Advance one chain by `steps` annealing steps (the unit of
    /// parallelism between exchange barriers).
    fn chain_run(
        &self,
        service: &Service,
        space: &SearchSpace,
        workload_for: &(impl Fn(&Config) -> Workload + Sync),
        st: &mut ChainState,
        steps: u32,
    ) {
        for _ in 0..steps {
            let cand = self.neighbor(&mut st.rng, space, &st.cur);
            if cand.validate().is_err() {
                continue;
            }
            let cand_t = Self::eval(service, workload_for, &cand);
            let rel = (cand_t - st.cur_t) / st.cur_t;
            if rel <= 0.0 || st.rng.next_f64() < (-rel / st.temp).exp() {
                st.cur = cand;
                st.cur_t = cand_t;
                st.trace.push(st.cur_t);
                if st.cur_t < st.best_t {
                    st.best_t = st.cur_t;
                    st.best = st.cur.clone();
                }
            }
            st.temp *= self.cooling;
        }
    }

    /// Exchange barrier: broadcast the global best-so-far state to every
    /// chain whose *current* state is worse. Chains keep their own RNG
    /// streams and temperatures; adoption is recorded in the trace.
    fn exchange(states: &mut [ChainState]) {
        let mut b = 0;
        for i in 1..states.len() {
            if states[i].best_t < states[b].best_t {
                b = i;
            }
        }
        let (best_cfg, best_t) = (states[b].best.clone(), states[b].best_t);
        for st in states.iter_mut() {
            if best_t < st.cur_t {
                st.cur = best_cfg.clone();
                st.cur_t = best_t;
                st.trace.push(best_t);
                if best_t < st.best_t {
                    st.best = best_cfg.clone();
                    st.best_t = best_t;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Platform;
    use crate::util::units::Bytes;
    use crate::workload::blast::{blast, BlastParams};

    #[test]
    fn anneal_finds_near_optimal_blast_partitioning_cheaply() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let space = SearchSpace::fixed_cluster(
            20,
            vec![Bytes::kb(256), Bytes::mb(1), Bytes::mb(4)],
        );
        let params = BlastParams { queries: 100, ..Default::default() };
        let grid = space.enumerate();

        // Exhaustive optimum for reference.
        let exhaustive_best = grid
            .iter()
            .map(|cfg| predictor.predict(&blast(cfg.n_app, &params), cfg).turnaround.as_secs_f64())
            .fold(f64::MAX, f64::min);

        let r = Annealer::default().minimize(&predictor, &space, |cfg| blast(cfg.n_app, &params));
        println!(
            "anneal: best {:.1}s vs exhaustive {:.1}s with {}/{} evaluations",
            r.best_time_s,
            exhaustive_best,
            r.evaluations,
            grid.len()
        );
        assert!(
            r.best_time_s <= exhaustive_best * 1.05,
            "annealing should land within 5% of the optimum"
        );
        assert!(
            r.evaluations < grid.len(),
            "annealing should evaluate fewer points than the grid ({} vs {})",
            r.evaluations,
            grid.len()
        );
        // The descent trace improves overall.
        assert!(r.trace.last().unwrap() <= r.trace.first().unwrap());
    }

    #[test]
    fn parallel_chains_deterministic_and_no_worse_than_chain_zero() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let space = SearchSpace::fixed_cluster(10, vec![Bytes::kb(256), Bytes::mb(1)]);
        let params = BlastParams { queries: 30, ..Default::default() };
        let wl = |cfg: &Config| blast(cfg.n_app, &params);
        let single = Annealer { steps: 12, ..Default::default() }.minimize(&predictor, &space, wl);
        let a = Annealer { steps: 12, chains: 4, ..Default::default() }
            .minimize(&predictor, &space, wl);
        let b = Annealer { steps: 12, chains: 4, ..Default::default() }
            .minimize(&predictor, &space, wl);
        assert_eq!(a.best_time_s, b.best_time_s, "chains must not introduce nondeterminism");
        assert_eq!(a.evaluations, b.evaluations);
        // Chain 0 reproduces the single-chain run, so the 4-chain best can
        // only match or improve on it.
        assert!(a.best_time_s <= single.best_time_s);
        assert!(a.evaluations >= single.evaluations);
    }

    #[test]
    fn anneal_is_deterministic_per_seed() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let space = SearchSpace::fixed_cluster(10, vec![Bytes::mb(1)]);
        let params = BlastParams { queries: 30, ..Default::default() };
        let a = Annealer { steps: 20, ..Default::default() }
            .minimize(&predictor, &space, |cfg| blast(cfg.n_app, &params));
        let b = Annealer { steps: 20, ..Default::default() }
            .minimize(&predictor, &space, |cfg| blast(cfg.n_app, &params));
        assert_eq!(a.best_time_s, b.best_time_s);
        assert_eq!(a.evaluations, b.evaluations);
    }

    #[test]
    fn chains_share_the_service_cache() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let space = SearchSpace::fixed_cluster(10, vec![Bytes::kb(256), Bytes::mb(1)]);
        let params = BlastParams { queries: 30, ..Default::default() };
        let svc = Service::new(predictor.clone());
        let r = Annealer { steps: 12, chains: 4, ..Default::default() }
            .minimize_with(&svc, &space, |cfg| blast(cfg.n_app, &params));
        let s = svc.stats();
        assert_eq!(r.evaluations as u64, s.misses, "evaluations = simulations issued");
        assert!(
            s.hits > 0,
            "chains revisit points; the shared cache must serve them ({s:?})"
        );
    }

    #[test]
    fn tempering_exchange_is_deterministic_and_near_optimal() {
        let predictor = Predictor::new(Platform::paper_testbed());
        let space = SearchSpace::fixed_cluster(10, vec![Bytes::kb(256), Bytes::mb(1)]);
        let params = BlastParams { queries: 30, ..Default::default() };
        let wl = |cfg: &Config| blast(cfg.n_app, &params);
        let run = || {
            Annealer { steps: 18, chains: 3, exchange_every: 6, ..Default::default() }
                .minimize(&predictor, &space, wl)
        };
        let a = run();
        let b = run();
        assert_eq!(a.best_time_s, b.best_time_s, "exchange must stay deterministic");
        assert_eq!(a.evaluations, b.evaluations);
        // Exchange never loses the best-ever state, so on this small grid
        // the tempered run should land on the exhaustive optimum's
        // neighborhood.
        let exhaustive_best = space
            .enumerate()
            .iter()
            .map(|cfg| predictor.predict(&wl(cfg), cfg).turnaround.as_secs_f64())
            .fold(f64::MAX, f64::min);
        assert!(
            a.best_time_s <= exhaustive_best * 1.05,
            "tempered best {:.1}s vs exhaustive {exhaustive_best:.1}s",
            a.best_time_s
        );
        // Adopted states appear in the winner's trace; it still descends.
        assert!(a.trace.last().unwrap() <= a.trace.first().unwrap());
    }
}
