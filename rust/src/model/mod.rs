//! The paper's queue-based storage-system model (§2.3–2.4).
//!
//! "All participating machines are modeled similarly, regardless of their
//! specific role: each machine hosts a network component and can host one
//! or more system components (each modeled as a service with its own
//! queue)." — this module is that model, instantiated on the [`crate::sim`]
//! engine and driven by a workload's I/O trace.
//!
//! Layout:
//! * [`config`] — storage-system + deployment configuration (the knobs the
//!   search explores: stripe width, replication, chunk size, placement,
//!   app/storage partitioning).
//! * [`platform`] — service times from system identification (μ_net, μ_sm,
//!   μ_man, μ_cli) and platform presets (paper testbed, HDD, SSD, 10GbE).
//! * [`proto`] — message types of the (coarse) storage protocol.
//! * [`placement`] — interned replica-group placement: every distinct
//!   replica group and write allocation is stored once behind a copyable
//!   id, derived lazily from `(primary, repl)` ring arithmetic, so
//!   full-stripe cluster-wide configurations stop paying O(n·stripe)
//!   placement vectors per workload.
//! * [`faults`] — deterministic fault injection: seeded crash/straggler/
//!   message-loss schedules ([`FaultPlan`], part of [`Config`]) and the
//!   timeout/backoff constants of the degraded-mode protocol.
//! * [`engine`] — the simulation world: per-host NIC queues, component
//!   stations, manager metadata, client operations.
//! * [`driver`] — the application driver: releases tasks when their input
//!   files exist, with optional data-location-aware scheduling (WASS).
//! * [`delta`] — incremental re-simulation: per-stage input fingerprints,
//!   stage-boundary checkpoints, and delta warm-starts that replay only
//!   the stages a neighbor config actually changes (bit-identical to the
//!   cold path by construction).
//! * [`report`] — simulation output: turnaround, per-stage/per-task times,
//!   transfer and storage accounting, per-component utilization.

pub mod config;
pub mod platform;
pub mod proto;
pub mod placement;
pub mod fidelity;
pub mod energy;
pub mod faults;
pub mod engine;
pub mod driver;
pub mod delta;
pub mod report;

pub use config::{Config, Placement};
pub use delta::{stage_fingerprints, DeltaBase, DeltaOutcome, DeltaResult, StageCheckpoint, StageFp};
pub use faults::{Crash, FaultPlan, LinkLoss, Straggler};
pub use placement::{AllocId, GroupId, PlacementArena, RefPlacement};
pub use engine::{simulate, simulate_fid, simulate_traced};
pub use energy::PowerModel;
pub use fidelity::Fidelity;
pub use platform::{DiskKind, Platform, Topology};
pub use report::SimReport;
