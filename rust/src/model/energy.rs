//! Energy model — the paper's future-work item (iii): "enable different
//! optimization functions … including adding energy models [13]" (their
//! [13] is the authors' own deduplication energy/performance study).
//!
//! A deliberately simple, explanatory model in the spirit of §2.1's
//! "explore the impact of configuration choices in situations where
//! direct measurement is difficult": every allocated host draws idle
//! power for the whole run; busy components (CPU-side services) and NICs
//! add active deltas weighted by their utilization integrals, which the
//! simulator already tracks per station.

use crate::model::report::SimReport;

/// Per-host power characteristics (watts).
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// Idle draw of one powered-on host.
    pub idle_w: f64,
    /// Extra draw when the host's CPU-side services are busy.
    pub cpu_active_w: f64,
    /// Extra draw when a NIC direction is transferring.
    pub nic_active_w: f64,
}

impl PowerModel {
    /// A 2007-era Xeon E5345 1U server: ~220 W idle, ~80 W CPU delta,
    /// a few watts per busy NIC direction.
    pub fn xeon_e5345() -> PowerModel {
        PowerModel { idle_w: 220.0, cpu_active_w: 80.0, nic_active_w: 4.0 }
    }

    /// Estimate total energy (joules) of a simulated run.
    ///
    /// idle: every host × turnaround; active: per-station busy time from
    /// the report's utilization integrals.
    pub fn energy_joules(&self, report: &SimReport) -> f64 {
        let t = report.turnaround.as_secs_f64();
        let hosts = report.util.nic.len() as f64;
        let idle = self.idle_w * hosts * t;

        // NIC busy time (both directions, all hosts).
        let nic_busy: f64 = report.util.nic.iter().map(|&(o, i)| (o + i) * t).sum();
        // CPU-side busy time: manager + storage components (clients mostly
        // block on I/O; their service slices are charged too).
        let cpu_busy: f64 = report.util.manager_util * t
            + report.util.storage.iter().map(|&(u, _)| u * t).sum::<f64>();

        idle + self.nic_active_w * nic_busy + self.cpu_active_w * cpu_busy
    }

    /// Energy in kWh (what a cost-conscious user compares).
    pub fn energy_kwh(&self, report: &SimReport) -> f64 {
        self.energy_joules(report) / 3.6e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{simulate, Config, Platform};
    use crate::workload::patterns::{pipeline, PatternScale};

    #[test]
    fn energy_scales_with_time_and_hosts() {
        let plat = Platform::paper_testbed();
        let pm = PowerModel::xeon_e5345();
        let small = simulate(&pipeline(4, PatternScale::Small, false), &Config::dss(4), &plat);
        let medium = simulate(&pipeline(4, PatternScale::Medium, false), &Config::dss(4), &plat);
        let e_small = pm.energy_joules(&small);
        let e_medium = pm.energy_joules(&medium);
        assert!(e_small > 0.0);
        assert!(e_medium > e_small, "10x data must cost more energy");
        // Idle power dominates: energy roughly tracks hosts × time.
        let floor = pm.idle_w * 5.0 * medium.turnaround.as_secs_f64();
        assert!(e_medium >= floor);
        assert!(e_medium < floor * 2.0, "active delta should not double idle draw");
    }

    #[test]
    fn wass_saves_energy_on_pipeline() {
        // Same workload, faster configuration ⇒ less idle-time energy.
        let plat = Platform::paper_testbed();
        let pm = PowerModel::xeon_e5345();
        let dss = simulate(&pipeline(19, PatternScale::Medium, false), &Config::dss(19), &plat);
        let wass = simulate(&pipeline(19, PatternScale::Medium, true), &Config::wass(19), &plat);
        assert!(
            pm.energy_joules(&wass) < pm.energy_joules(&dss) * 0.5,
            "the 6x-faster configuration should save well over half the energy"
        );
    }
}
