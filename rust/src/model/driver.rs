//! Application driver: replays the workflow DAG over the storage model.
//!
//! "Once the simulator instantiates the storage system, it starts the
//! application driver that processes the application workload" (§2.4).
//! A task becomes runnable when all its input files are committed; the
//! driver then assigns it to an application node. Under WASS deployments
//! the assignment is data-location-aware: "for a given compute task, if
//! all input file chunks exist on a single storage node, the task is
//! scheduled on that node to increase access locality" (§3.1).

use crate::model::engine::{Ev, World};
use crate::model::proto::OpKind;
use crate::model::report::TaskRecord;
use crate::sim::Scheduler;
use crate::trace::{Probe, TaskPhase};
use crate::util::units::SimTime;
use crate::workload::{Workload, TaskId};
use std::collections::VecDeque;

/// Per-task execution phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    /// Not yet started.
    Waiting,
    /// Reading input file `cursor`.
    Reading(usize),
    /// Compute delay in progress.
    Computing,
    /// Writing output file `cursor`.
    Writing(usize),
    Done,
}

/// Driver bookkeeping, separated from the protocol state of `World`.
#[derive(Clone, Debug)]
pub struct DriverState {
    /// Per task: number of input files not yet committed.
    deps_left: Vec<usize>,
    /// Per file: tasks waiting on it.
    waiting: Vec<Vec<TaskId>>,
    /// Released tasks not yet assigned to a client.
    ready: VecDeque<TaskId>,
    /// Per client: busy flag.
    busy: Vec<bool>,
    phase: Vec<Phase>,
    task_client: Vec<usize>,
    task_start: Vec<SimTime>,
    finished: usize,
    /// Tasks abandoned because an operation was unrecoverable (degraded
    /// mode; always 0 fault-free).
    failed: usize,
}

impl DriverState {
    pub fn new(wl: &Workload, cfg: &crate::model::config::Config) -> DriverState {
        let n = wl.tasks.len();
        let mut deps_left = vec![0usize; n];
        let mut waiting: Vec<Vec<TaskId>> = vec![Vec::new(); wl.files.len()];
        for (ti, t) in wl.tasks.iter().enumerate() {
            for &f in &t.reads {
                if !wl.files[f].prestaged {
                    deps_left[ti] += 1;
                    waiting[f].push(ti);
                }
            }
        }
        DriverState {
            deps_left,
            waiting,
            ready: VecDeque::new(),
            busy: vec![false; cfg.n_app],
            phase: vec![Phase::Waiting; n],
            task_client: vec![usize::MAX; n],
            task_start: vec![SimTime::ZERO; n],
            finished: 0,
            failed: 0,
        }
    }

    /// Tasks with no unmet dependencies at t=0.
    pub fn initially_ready(&self) -> Vec<TaskId> {
        (0..self.deps_left.len()).filter(|&t| self.deps_left[t] == 0).collect()
    }

    pub fn finished_tasks(&self) -> usize {
        self.finished
    }

    /// Tasks abandoned as unrecoverable (degraded mode).
    pub fn failed_tasks(&self) -> usize {
        self.failed
    }
}

impl<P: Probe> World<P> {
    /// A file committed at the manager: notify waiting tasks.
    pub(crate) fn file_committed(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, file: usize) {
        let waiters = std::mem::take(&mut self.driver.waiting[file]);
        for t in waiters {
            debug_assert!(self.driver.deps_left[t] > 0);
            self.driver.deps_left[t] -= 1;
            if self.driver.deps_left[t] == 0 {
                sched.at(now, Ev::Release(t));
            }
        }
    }

    /// A task's dependencies are satisfied: queue it and try to place it.
    pub(crate) fn driver_release(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, task: TaskId) {
        self.driver.ready.push_back(task);
        self.try_assign(sched, now);
    }

    /// The client a task prefers, if constrained.
    ///
    /// Pin wins; otherwise, under data-location-aware scheduling, if all
    /// committed input chunks of the task live on one storage node whose
    /// host runs a client, that client is preferred.
    fn preferred_client(&self, task: TaskId) -> Option<usize> {
        let t = &self.wl.tasks[task];
        if let Some(c) = t.pin_client {
            return Some(c);
        }
        if !self.cfg.location_aware || t.reads.is_empty() {
            return None;
        }
        let mut node: Option<usize> = None;
        for &f in &t.reads {
            let meta = self.meta[f]?; // all inputs are committed at release
            // A chunk counts as "on node s" if any replica is on s —
            // follow the primary for the locality decision. Chunk i maps
            // to stripe position i % width of the interned allocation, so
            // the first min(n_chunks, width) positions cover every chunk.
            let used = self.placement.alloc_width(meta.alloc).min(meta.n_chunks as usize);
            for j in 0..used {
                let primary = self.placement.chunk_primary(meta.alloc, j as u64);
                match node {
                    None => node = Some(primary),
                    Some(n) if n == primary => {}
                    Some(_) => return None, // spread over >1 node
                }
            }
        }
        let s = node?;
        self.cfg.client_on_storage_host(s)
    }

    /// Match ready tasks to free clients (FIFO, honoring preferences).
    fn try_assign(&mut self, sched: &mut Scheduler<Ev>, now: SimTime) {
        let mut remaining = VecDeque::new();
        while let Some(task) = self.driver.ready.pop_front() {
            let choice = match self.preferred_client(task) {
                Some(c) => {
                    if self.driver.busy[c] {
                        None // wait for the preferred node specifically
                    } else {
                        Some(c)
                    }
                }
                None => (0..self.cfg.n_app).find(|&c| !self.driver.busy[c]),
            };
            match choice {
                Some(c) => self.start_task(sched, now, task, c),
                None => remaining.push_back(task),
            }
        }
        self.driver.ready = remaining;
    }

    fn start_task(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, task: TaskId, client: usize) {
        debug_assert!(!self.driver.busy[client]);
        self.driver.busy[client] = true;
        self.driver.task_client[task] = client;
        self.driver.task_start[task] = now;
        self.driver.phase[task] = Phase::Reading(0);
        self.probe.task_phase(now, task, client, TaskPhase::Read);
        self.advance_task(sched, now, task);
    }

    /// An I/O operation of `task` completed; move its state machine.
    pub(crate) fn driver_io_done(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, task: TaskId) {
        match self.driver.phase[task] {
            Phase::Reading(i) => self.driver.phase[task] = Phase::Reading(i + 1),
            Phase::Writing(i) => self.driver.phase[task] = Phase::Writing(i + 1),
            p => unreachable!("io_done in phase {p:?}"),
        }
        self.advance_task(sched, now, task);
    }

    pub(crate) fn driver_compute_done(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, task: TaskId) {
        debug_assert_eq!(self.driver.phase[task], Phase::Computing);
        self.driver.phase[task] = Phase::Writing(0);
        self.probe.task_phase(now, task, self.driver.task_client[task], TaskPhase::Write);
        self.advance_task(sched, now, task);
    }

    /// Issue the next step of a task's read → compute → write sequence.
    fn advance_task(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, task: TaskId) {
        let client = self.driver.task_client[task];
        let spec = &self.wl.tasks[task];
        match self.driver.phase[task] {
            Phase::Reading(i) => {
                if i < spec.reads.len() {
                    let f = spec.reads[i];
                    self.start_op(sched, now, OpKind::Read, client, task, f);
                } else if spec.compute > SimTime::ZERO {
                    self.driver.phase[task] = Phase::Computing;
                    self.probe.task_phase(now, task, client, TaskPhase::Compute);
                    // Detailed fidelity: compute times jitter like any
                    // other service (OS scheduling, cache effects).
                    let t = SimTime::from_secs_f64(spec.compute.as_secs_f64() * self.jitter());
                    sched.after(t, Ev::ComputeDone(task));
                } else {
                    self.driver.phase[task] = Phase::Writing(0);
                    self.probe.task_phase(now, task, client, TaskPhase::Write);
                    self.advance_task(sched, now, task);
                }
            }
            Phase::Writing(i) => {
                if i < spec.writes.len() {
                    let f = spec.writes[i];
                    self.start_op(sched, now, OpKind::Write, client, task, f);
                } else {
                    self.finish_task(sched, now, task);
                }
            }
            p => unreachable!("advance in phase {p:?}"),
        }
    }

    /// Abandon a task whose operation was declared unrecoverable
    /// (degraded mode): free its client for other work, but record no
    /// completion — its outputs never commit, so dependents never
    /// release, and `finished_tasks` keeps meaning "ran to completion".
    pub(crate) fn abandon_task(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, task: TaskId) {
        let client = self.driver.task_client[task];
        debug_assert_ne!(client, usize::MAX, "abandoning a task that never started");
        self.driver.phase[task] = Phase::Done;
        self.probe.task_phase(now, task, client, TaskPhase::Done);
        self.driver.busy[client] = false;
        self.driver.failed += 1;
        self.try_assign(sched, now);
    }

    fn finish_task(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, task: TaskId) {
        let client = self.driver.task_client[task];
        self.driver.phase[task] = Phase::Done;
        self.probe.task_phase(now, task, client, TaskPhase::Done);
        self.driver.busy[client] = false;
        self.driver.finished += 1;
        self.task_records.push(TaskRecord {
            task,
            stage: self.wl.tasks[task].stage,
            client,
            start: self.driver.task_start[task],
            end: now,
        });
        self.try_assign(sched, now);
    }
}
