//! Protocol entities of the coarse storage model.
//!
//! The paper models "the data paths at chunk-level granularity, and the
//! control paths at a coarser granularity: modeling only one control
//! message to initiate a specific storage function". The write path is
//! exactly the paper's §2.4 walk-through: alloc at the manager → chunk
//! puts to storage (round-robin over the allocated stripe, chained
//! replication) → chunk-map commit at the manager. Reads are lookup →
//! per-chunk gets.
//!
//! Messages name hosts, not links: how a message physically reaches its
//! destination — directly under the star topology, or via a rack
//! uplink/downlink pair under a routed [`Topology`] — is resolved per
//! hop by the engine through [`crate::sim::FabricPlan`], so the
//! protocol layer is topology-agnostic by construction.
//!
//! [`Topology`]: crate::model::Topology

use crate::model::placement::{AllocId, GroupId};
use crate::util::units::Bytes;
use crate::workload::{FileId, TaskId};

/// A system component (service + queue) in the model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompId {
    Manager,
    Storage(usize),
    Client(usize),
}

pub type MsgId = usize;
pub type OpId = usize;

/// Fixed size the model assumes for every control message ("we model all
/// control messages as having the same size", §5).
pub const CTRL_MSG: Bytes = Bytes(1024);

/// Message payloads. Data messages (`ChunkPut`, `ReplicaPut`, `ChunkData`)
/// carry chunk-sized payloads; everything else is control. Replica
/// chains travel as interned [`GroupId`]s plus a hop index — a few
/// copyable words — so every payload is `Copy` and nothing on the
/// protocol path clones per-replica vectors.
#[derive(Clone, Copy, Debug)]
pub enum Payload {
    // ---- application → client SAI ----
    /// The driver hands an operation to the client service.
    AppIssue { op: OpId },

    // ---- write path ----
    /// client → manager: allocate space for a write.
    WriteAlloc { op: OpId },
    /// manager → client: stripe targets decided (stored in op state).
    WriteAllocResp { op: OpId },
    /// client → storage: store one chunk. `group` is the chunk's interned
    /// replica chain and `hop` the receiver's position in it; the storage
    /// node forwards to the next *surviving* member while one exists
    /// (chained replication), resolving members through the world's
    /// [`PlacementArena`](crate::model::placement::PlacementArena).
    /// `attempt` is the degraded-mode retry number (always 0 fault-free).
    ChunkPut { op: OpId, chunk: u32, size: Bytes, group: GroupId, hop: u32, attempt: u32 },
    /// tail storage → client: chunk fully stored on all surviving replicas.
    ChunkPutAck { op: OpId, chunk: u32, attempt: u32 },
    /// client → manager: chunk map, closes the write.
    ChunkCommit { op: OpId },
    /// manager → client: commit acknowledged; file becomes visible.
    CommitAck { op: OpId },

    // ---- read path ----
    /// client → manager: where are the chunks of this file?
    ReadLookup { op: OpId },
    /// manager → client: chunk map available (stored in op state).
    ReadLookupResp { op: OpId },
    /// client → storage: send one chunk. `attempt` tags the degraded-mode
    /// retry this request belongs to (always 0 fault-free).
    ChunkGet { op: OpId, chunk: u32, size: Bytes, attempt: u32 },
    /// storage → client: chunk payload (echoes the request's `attempt`).
    ChunkData { op: OpId, chunk: u32, size: Bytes, attempt: u32 },

    // ---- detailed-fidelity control rounds (testbed protocol only) ----
    /// client → manager: open the file handle (FUSE-ish extra round).
    Open { op: OpId },
    /// manager → client.
    OpenResp { op: OpId },
    /// client → manager: close the handle.
    Close { op: OpId },
    /// manager → client.
    CloseResp { op: OpId },
    /// client → manager: periodic allocation/metadata round (no reply;
    /// pure manager + network load).
    MetaPing,
}

impl Payload {
    /// Wire size of a message carrying this payload.
    pub fn wire_size(&self) -> Bytes {
        match self {
            Payload::ChunkPut { size, .. }
            | Payload::ChunkData { size, .. } => *size + CTRL_MSG,
            _ => CTRL_MSG,
        }
    }

    /// The op this message belongs to *if* it travels on a per-op data
    /// connection (client↔storage / storage↔storage streams). Metadata
    /// traffic uses long-lived manager connections and returns `None`.
    pub fn data_path_op(&self) -> Option<OpId> {
        match self {
            Payload::ChunkPut { op, .. }
            | Payload::ChunkPutAck { op, .. }
            | Payload::ChunkGet { op, .. }
            | Payload::ChunkData { op, .. } => Some(*op),
            _ => None,
        }
    }
}

/// An in-flight message.
#[derive(Clone, Copy, Debug)]
pub struct Msg {
    pub from: CompId,
    pub to: CompId,
    pub payload: Payload,
    /// Whether source and destination share a host (loopback transfer).
    pub local: bool,
}

/// A network frame — or, on the bulk fast path, a whole frame *train*:
/// every wire frame of one message traversing the NIC queues as a single
/// analytically-drained entry (`frames > 1`).
#[derive(Clone, Copy, Debug)]
pub struct Frame {
    pub msg: MsgId,
    /// Bytes carried: one frame's worth on the per-frame path, the whole
    /// message's wire size on the bulk path.
    pub bytes: Bytes,
    /// Number of wire frames this entry aggregates (1 on the per-frame
    /// path).
    pub frames: u32,
    /// Last frame of its message — delivery trigger (frames of one message
    /// traverse the same FIFO queues, so order within a message holds).
    pub last: bool,
}

impl Frame {
    /// Bytes of this train's final wire frame under a `frame_cap`-byte
    /// MTU: fragmentation fills frames in order, so only the last one can
    /// be short. The bulk path uses this for exact leading/last-frame
    /// bookkeeping — the per-frame path's short last frame waits
    /// `full − last` service behind its full-sized siblings at the
    /// receive queue, and that slack is charged analytically so the
    /// aggregated integrals are exact for arbitrary wire sizes.
    pub fn tail_frame_bytes(&self, frame_cap: u64) -> u64 {
        debug_assert!(self.frames >= 1 && frame_cap > 0);
        self.bytes.as_u64() - (self.frames as u64 - 1) * frame_cap
    }
}

/// Client-side operation kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Write,
    Read,
}

/// Client-side state of a whole-file operation.
#[derive(Clone, Debug)]
pub struct Op {
    pub kind: OpKind,
    pub client: usize,
    pub task: TaskId,
    pub file: FileId,
    pub size: Bytes,
    pub n_chunks: u32,
    /// Write: the interned allocation chosen by the manager (per-chunk
    /// replica groups are derived from it on demand). `None` until the
    /// manager's `WriteAllocResp`; reads resolve placement through the
    /// committed metadata instead.
    pub alloc: Option<AllocId>,
    /// Chunks completed (acked / received).
    pub done: u32,
    /// Next chunk index to issue (window flow control).
    pub next: u32,
    pub started_ns: u64,
}

impl Op {
    /// Size of chunk `i` (the last chunk may be partial).
    pub fn chunk_bytes(&self, i: u32, chunk_size: Bytes) -> Bytes {
        debug_assert!(i < self.n_chunks);
        if self.size.as_u64() == 0 {
            return Bytes::ZERO;
        }
        let full = chunk_size.as_u64();
        let rem = self.size.as_u64() - i as u64 * full;
        Bytes(rem.min(full))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_messages_have_fixed_size() {
        let p = Payload::WriteAlloc { op: 0 };
        assert_eq!(p.wire_size(), CTRL_MSG);
        let p = Payload::ChunkPutAck { op: 0, chunk: 3, attempt: 0 };
        assert_eq!(p.wire_size(), CTRL_MSG);
    }

    #[test]
    fn data_messages_carry_payload() {
        let mut arena = crate::model::placement::PlacementArena::new(2);
        let g = arena.ring_group(0, 2);
        let p =
            Payload::ChunkPut { op: 0, chunk: 0, size: Bytes::mb(1), group: g, hop: 0, attempt: 0 };
        assert_eq!(p.wire_size(), Bytes::mb(1) + CTRL_MSG);
        let p = Payload::ChunkData { op: 0, chunk: 0, size: Bytes::kb(256), attempt: 0 };
        assert_eq!(p.wire_size(), Bytes::kb(256) + CTRL_MSG);
    }

    #[test]
    fn partial_last_chunk() {
        let op = Op {
            kind: OpKind::Write,
            client: 0,
            task: 0,
            file: 0,
            size: Bytes(2_500_000),
            n_chunks: 3,
            alloc: None,
            done: 0,
            next: 0,
            started_ns: 0,
        };
        let cs = Bytes::mb(1);
        assert_eq!(op.chunk_bytes(0, cs), Bytes::mb(1));
        assert_eq!(op.chunk_bytes(1, cs), Bytes::mb(1));
        assert_eq!(op.chunk_bytes(2, cs), Bytes(2_500_000 - 2 * 1_048_576));
    }

    #[test]
    fn tail_frame_bytes_only_last_is_short() {
        let cap = 64 * 1024u64;
        let aligned = Frame { msg: 0, bytes: Bytes(3 * cap), frames: 3, last: true };
        assert_eq!(aligned.tail_frame_bytes(cap), cap);
        let ragged = Frame { msg: 0, bytes: Bytes(2 * cap + 100), frames: 3, last: true };
        assert_eq!(ragged.tail_frame_bytes(cap), 100);
        let single = Frame { msg: 0, bytes: Bytes(999), frames: 1, last: true };
        assert_eq!(single.tail_frame_bytes(cap), 999);
    }

    #[test]
    fn zero_size_op_single_empty_chunk() {
        let op = Op {
            kind: OpKind::Write,
            client: 0,
            task: 0,
            file: 0,
            size: Bytes::ZERO,
            n_chunks: 1,
            alloc: None,
            done: 0,
            next: 0,
            started_ns: 0,
        };
        assert_eq!(op.chunk_bytes(0, Bytes::mb(1)), Bytes::ZERO);
    }
}
