//! Simulation fidelity: what separates the coarse predictor from the
//! high-fidelity "actual system" emulator.
//!
//! The paper's predictor deliberately simplifies (§2.3: "not simulating in
//! detail some of the control paths"; §5 lists the resulting inaccuracy
//! sources). Our testbed emulator — the stand-in for the paper's 20-node
//! MosaStore deployment (DESIGN.md §3–4) — turns those very mechanisms
//! *on*:
//!
//! * multi-round control paths (FUSE-ish opens/closes, periodic allocation
//!   rounds) instead of "only one control message to initiate a specific
//!   storage function";
//! * per-operation data connections with congestion-dependent SYN loss and
//!   the 3 s TCP connect-timeout retry the authors report discovering;
//! * staggered task launch ("in the experiments on real hardware
//!   coordination overheads make them slightly staggered");
//! * service-time jitter and per-host heterogeneity ("we were ignoring
//!   platform heterogeneity");
//! * manager lock contention under queueing ("unreasonable locking
//!   overheads at the manager");
//! * randomized placement cursors ("limited randomness in the data
//!   placement decisions").
//!
//! Every knob is independent, so `benches/ablations.rs` can attribute the
//! prediction error to individual mechanisms.
//!
//! The network topology is *not* a fidelity knob: it lives on
//! [`Platform`](crate::model::Platform) because it describes the
//! machine, not the simulation detail level. Every tier — bulk-train
//! coarse, per-frame, detailed — routes through the same
//! [`crate::sim::FabricPlan`], and the frame-aggregation knob below
//! only selects whether core links serve whole trains (weighted-fair)
//! or individual frames (FIFO store-and-forward).

use crate::util::units::SimTime;

/// Fidelity knobs. `coarse()` is the paper's predictor; `detailed(seed)`
/// is the emulated testbed; `coarse_per_frame()` is the predictor with
/// the network fast path disabled (frame-level events), kept for
/// equivalence testing and interleaving-sensitive studies.
#[derive(Clone, Debug)]
pub struct Fidelity {
    /// Bulk network fast path: service a message's whole frame train as a
    /// single analytically-drained entry at each NIC station (O(1) events
    /// per message) instead of one event chain per wire frame
    /// (O(n_frames)). Turnaround and station integrals are preserved (see
    /// PERF.md §Frame path); turn it off for runs where frame-level
    /// interleaving or SYN-loss dynamics matter (the detailed tier does).
    pub frame_aggregation: bool,
    /// Extra control rounds: per-op open/close round trips plus one
    /// manager round per `alloc_batch` chunks.
    pub control_rounds: bool,
    /// Chunks per allocation round when `control_rounds` is on.
    pub alloc_batch: u32,
    /// Per-(op, host-pair) data connections with SYN loss under congestion.
    pub connections: bool,
    /// TCP connect retry timeout (Linux-era initial SYN timeout: 3 s).
    pub conn_timeout: SimTime,
    /// In-NIC queue length at which SYN drop probability starts rising.
    pub syn_drop_qlen: usize,
    /// Queue length over which SYNs are (almost) always dropped.
    pub syn_drop_full: usize,
    /// Mean of the exponential task-launch stagger (zero = none).
    pub stagger_mean: SimTime,
    /// Multiplicative service-time noise sigma (zero = deterministic).
    pub jitter_sigma: f64,
    /// Manager service inflation per queued request (lock contention).
    pub manager_contention: f64,
    /// Per-host speed spread sigma (drawn once per trial).
    pub hetero_sigma: f64,
    /// Receive-side multiplexing overhead: remote data frames arriving at
    /// a backlogged in-NIC are served slower by
    /// `1 + mux_eta · ln(1 + qlen)` — the aggregate cost of many
    /// concurrent TCP flows (context switches, small-window restarts)
    /// that the coarse model's clean FIFO fabric ignores. This is the
    /// main source of the paper's DSS-pipeline under-prediction (Fig 4).
    pub mux_eta: f64,
    /// Per-(operation, distinct storage target) stream-setup cost paid by
    /// the client before its chunk window opens — connection handling +
    /// per-stripe metadata, the "connection handling and metadata access
    /// overheads" that make very wide stripes lose in Fig 1.
    pub per_target_setup: SimTime,
    /// Scale applied to observed in-NIC queue depths before the SYN-drop
    /// and mux laws. Those laws are calibrated against *per-frame* queue
    /// dynamics, where a transfer's backlog ramps up gradually as frames
    /// pace in; a cut-through bulk train posts its whole frame count the
    /// instant its leading frame lands, reading roughly twice the depth
    /// the same backlog shows mid-ramp. `detailed_aggregated` therefore
    /// halves the observed depth (train-weighted calibration); the
    /// per-frame tiers keep 1.0.
    pub train_qlen_scale: f64,
    /// Randomize the stripe start per operation instead of a global
    /// round-robin cursor.
    pub random_placement: bool,
    /// RNG seed (unused when all stochastic knobs are off).
    pub seed: u64,
}

impl Fidelity {
    /// The predictor's fidelity: deterministic, single-control-message
    /// protocol — exactly the paper's model.
    pub fn coarse() -> Fidelity {
        Fidelity {
            frame_aggregation: true,
            control_rounds: false,
            alloc_batch: u32::MAX,
            connections: false,
            conn_timeout: SimTime::from_secs_f64(3.0),
            syn_drop_qlen: 0,
            syn_drop_full: 0,
            stagger_mean: SimTime::ZERO,
            jitter_sigma: 0.0,
            manager_contention: 0.0,
            hetero_sigma: 0.0,
            mux_eta: 0.0,
            per_target_setup: SimTime::ZERO,
            train_qlen_scale: 1.0,
            random_placement: false,
            seed: 0,
        }
    }

    /// The testbed's fidelity: everything on. `seed` selects the trial.
    pub fn detailed(seed: u64) -> Fidelity {
        Fidelity {
            // Frame-level events: SYN-loss probabilities and mux overhead
            // are calibrated against frame-granularity queue depths.
            frame_aggregation: false,
            control_rounds: true,
            alloc_batch: 16,
            connections: true,
            conn_timeout: SimTime::from_secs_f64(3.0),
            // Thresholds in in-NIC frames (64 KB each): SYN loss becomes
            // possible only under a deep data backlog — the rare "3 s
            // connect timeout" stalls the paper reports, not a tax on
            // every stream.
            syn_drop_qlen: 3500,
            syn_drop_full: 9000,
            stagger_mean: SimTime::from_ms(50),
            jitter_sigma: 0.04,
            manager_contention: 0.02,
            hetero_sigma: 0.03,
            mux_eta: 0.02,
            per_target_setup: SimTime::from_us(800),
            train_qlen_scale: 1.0,
            random_placement: true,
            seed,
        }
    }

    /// The testbed's fidelity over the bulk train path: every stochastic
    /// mechanism of [`Fidelity::detailed`], but messages traverse the NICs
    /// as weighted-fair trains (O(1) events per message — roughly an order
    /// of magnitude cheaper trials on chunk-heavy workloads). The
    /// SYN-drop and mux laws keep their per-frame thresholds and observe
    /// *train-weighted* queue depths instead: a cut-through train posts
    /// all its frames at once where per-frame pacing ramps the backlog up
    /// from zero, so the instantaneous depth reads about twice the
    /// per-frame average over a transfer — `train_qlen_scale: 0.5`
    /// recalibrates the observation (checked statistically against the
    /// per-frame tier in `testbed::tests`).
    pub fn detailed_aggregated(seed: u64) -> Fidelity {
        Fidelity {
            frame_aggregation: true,
            train_qlen_scale: 0.5,
            ..Fidelity::detailed(seed)
        }
    }

    /// The predictor's fidelity with the bulk network fast path disabled:
    /// identical protocol, one event chain per wire frame. Used by the
    /// equivalence tests and the `frame_path.per_frame` bench cell.
    pub fn coarse_per_frame() -> Fidelity {
        Fidelity { frame_aggregation: false, ..Fidelity::coarse() }
    }

    /// Does any knob need an RNG?
    pub fn stochastic(&self) -> bool {
        self.stagger_mean > SimTime::ZERO
            || self.jitter_sigma > 0.0
            || self.hetero_sigma > 0.0
            || self.random_placement
            || self.connections
    }

    /// SYN drop probability at a given destination in-queue length.
    pub fn syn_drop_prob(&self, qlen: usize) -> f64 {
        if !self.connections || qlen <= self.syn_drop_qlen {
            return 0.0;
        }
        if self.syn_drop_full <= self.syn_drop_qlen {
            return 1.0;
        }
        let x = (qlen - self.syn_drop_qlen) as f64 / (self.syn_drop_full - self.syn_drop_qlen) as f64;
        x.min(1.0) * 0.3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coarse_is_deterministic() {
        let f = Fidelity::coarse();
        assert!(!f.stochastic());
        assert_eq!(f.syn_drop_prob(10_000), 0.0);
        assert!(f.frame_aggregation, "predictor defaults to the bulk fast path");
    }

    #[test]
    fn coarse_per_frame_differs_only_in_frame_path() {
        let a = Fidelity::coarse();
        let b = Fidelity::coarse_per_frame();
        assert!(!b.frame_aggregation);
        assert!(!b.stochastic());
        assert_eq!(a.control_rounds, b.control_rounds);
        assert_eq!(a.connections, b.connections);
    }

    #[test]
    fn detailed_is_stochastic() {
        assert!(Fidelity::detailed(1).stochastic());
    }

    #[test]
    fn detailed_aggregated_differs_only_in_frame_path_calibration() {
        let a = Fidelity::detailed(3);
        let b = Fidelity::detailed_aggregated(3);
        assert!(b.frame_aggregation && !a.frame_aggregation);
        assert!(b.stochastic());
        assert_eq!(b.train_qlen_scale, 0.5, "train-weighted depth calibration");
        assert_eq!(a.control_rounds, b.control_rounds);
        assert_eq!(a.connections, b.connections);
        assert_eq!(a.syn_drop_qlen, b.syn_drop_qlen);
        assert_eq!(a.mux_eta, b.mux_eta);
        assert_eq!(a.seed, b.seed);
    }

    #[test]
    fn syn_drop_ramps() {
        let f = Fidelity::detailed(0);
        assert_eq!(f.syn_drop_prob(f.syn_drop_qlen), 0.0);
        let mid = f.syn_drop_prob((f.syn_drop_qlen + f.syn_drop_full) / 2);
        let cap = f.syn_drop_prob(f.syn_drop_full + 100);
        assert!(mid > 0.0 && mid < cap, "mid={mid} cap={cap}");
        assert!(cap > 0.0 && cap <= 1.0);
        // Monotone in queue length.
        assert!(f.syn_drop_prob(f.syn_drop_qlen + 10) <= mid);
    }
}
