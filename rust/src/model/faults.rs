//! Deterministic fault injection: seeded, reproducible node crashes,
//! slow-node stragglers, and per-link message loss, threaded through the
//! model engine.
//!
//! A [`FaultPlan`] is part of [`Config`](super::Config) and therefore of
//! the service fingerprint: two plans are two distinct points of the
//! configuration space, and the same plan reproduces byte-identical
//! predictions across runs and thread counts. Every random choice the
//! degraded-mode protocol makes — whether a message on a lossy link is
//! dropped, how long a retry backs off — is a *pure function* of the plan
//! seed and the identity of the thing being decided
//! ([`Rng::stream_seed`]), never a draw from the simulation's own RNG.
//! That keeps the fault-free path bit-identical to the pre-fault engine
//! (an empty plan injects nothing, arms no timers, and draws nothing) and
//! makes faulty runs independent of event-processing order.
//!
//! The degraded-mode protocol the engine builds on this plan:
//!
//! * per-chunk timeouts with bounded exponential backoff
//!   ([`timeout_for`], [`backoff_delay`], [`MAX_ATTEMPTS`]);
//! * read failover to surviving replicas via O(1)
//!   [`PlacementArena`](super::PlacementArena) ring membership;
//! * write re-allocation and replica-chain forwarding that skip dead
//!   nodes;
//! * explicit unrecoverable accounting when every replica of a needed
//!   chunk is gone (e.g. replication 1 + one crash).

use crate::util::rng::Rng;
use crate::util::units::SimTime;

/// A storage-node crash: storage node `storage` fails at simulated time
/// `at`. Its queued work is abandoned, in-flight service completes
/// without effect, and later requests addressed to it are lost.
#[derive(Clone, Debug, PartialEq)]
pub struct Crash {
    pub storage: usize,
    pub at: SimTime,
}

/// A slow-node straggler: from `at` on, host `host`'s service rate is
/// multiplied by `slowdown` (a speed factor in `(0, 1]`; smaller is
/// slower). Services already in flight keep their scheduled completion.
#[derive(Clone, Debug, PartialEq)]
pub struct Straggler {
    pub host: usize,
    pub at: SimTime,
    pub slowdown: f64,
}

/// Per-link message loss: a message sent from host `src` to host `dst`
/// during `[from, until)` is dropped with probability `prob`. The drop
/// decision for one message is a pure hash of `(plan seed, src, dst,
/// message id)`, so it is identical across runs and thread counts.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkLoss {
    pub src: usize,
    pub dst: usize,
    pub from: SimTime,
    pub until: SimTime,
    pub prob: f64,
}

/// A deterministic fault schedule. The default (empty) plan is the
/// fault-free engine: nothing is injected, no timers are armed, and the
/// simulation is bit-identical to a build without this module.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Seed for every per-decision hash (drops, backoff jitter).
    pub seed: u64,
    pub crashes: Vec<Crash>,
    pub stragglers: Vec<Straggler>,
    pub links: Vec<LinkLoss>,
}

/// Retry attempts per chunk (initial try + retries) before the owning
/// operation is declared unrecoverable.
pub const MAX_ATTEMPTS: u32 = 5;

/// Per-chunk timeout for attempt 0 (5 s — generous next to healthy chunk
/// latencies, so congestion alone does not fire retries); later attempts
/// double it (see [`timeout_for`]).
pub const TIMEOUT_BASE: SimTime = SimTime(5_000_000_000);

/// Timeout armed for 0-based attempt `attempt`: `TIMEOUT_BASE`
/// exponentially doubled, capped at 16×.
pub fn timeout_for(attempt: u32) -> SimTime {
    SimTime(TIMEOUT_BASE.0 << attempt.min(4))
}

/// Backoff delay before re-issuing `(op, chunk)` as attempt `attempt`:
/// uniform in `[0, timeout_for(attempt) / 2]`, a pure function of
/// `(seed, op, chunk, attempt)` so the schedule is byte-identical across
/// runs and thread counts.
pub fn backoff_delay(seed: u64, op: usize, chunk: u32, attempt: u32) -> SimTime {
    let stream = (op as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(((chunk as u64) << 32) | attempt as u64);
    let half = timeout_for(attempt).0 / 2;
    SimTime(Rng::stream_seed(seed, stream) % (half + 1))
}

fn parse_idx(s: &str, what: &str) -> Result<usize, String> {
    s.trim().parse().map_err(|_| format!("bad {what} {s:?}"))
}

fn parse_secs(s: &str, what: &str) -> Result<SimTime, String> {
    let secs: f64 = s.trim().parse().map_err(|_| format!("bad {what} {s:?}"))?;
    if !(secs >= 0.0 && secs.is_finite()) {
        return Err(format!("bad {what} {s:?}"));
    }
    Ok(SimTime::from_secs_f64(secs))
}

impl FaultPlan {
    /// Whether the plan injects anything. Empty plans take the engine's
    /// pre-fault path exactly.
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty() && self.stragglers.is_empty() && self.links.is_empty()
    }

    /// Parse the `--fault-plan` DSL: semicolon-separated directives
    /// `seed=<u64>`, `crash=<storage>@<secs>`, `slow=<host>@<secs>x<mult>`,
    /// and `drop=<src>-<dst>@<from_secs>-<until_secs>p<prob>`, e.g.
    /// `seed=7;crash=0@2.5;crash=3@4;slow=1@1x0.25;drop=1-2@0-10p0.05`.
    pub fn parse(text: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in text.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("fault directive {part:?} is not key=value"))?;
            match key {
                "seed" => {
                    plan.seed =
                        val.parse().map_err(|_| format!("bad fault seed {val:?}"))?;
                }
                "crash" => {
                    let (node, at) = val
                        .split_once('@')
                        .ok_or_else(|| format!("crash {val:?} is not <storage>@<secs>"))?;
                    plan.crashes.push(Crash {
                        storage: parse_idx(node, "crash storage")?,
                        at: parse_secs(at, "crash time")?,
                    });
                }
                "slow" => {
                    let (node, rest) = val
                        .split_once('@')
                        .ok_or_else(|| format!("slow {val:?} is not <host>@<secs>x<mult>"))?;
                    let (at, mult) = rest
                        .split_once('x')
                        .ok_or_else(|| format!("slow {val:?} is not <host>@<secs>x<mult>"))?;
                    plan.stragglers.push(Straggler {
                        host: parse_idx(node, "slow host")?,
                        at: parse_secs(at, "slow time")?,
                        slowdown: mult
                            .parse()
                            .map_err(|_| format!("bad slowdown {mult:?}"))?,
                    });
                }
                "drop" => {
                    let (link, rest) = val.split_once('@').ok_or_else(|| {
                        format!("drop {val:?} is not <src>-<dst>@<from>-<until>p<prob>")
                    })?;
                    let (src, dst) = link
                        .split_once('-')
                        .ok_or_else(|| format!("drop link {link:?} is not <src>-<dst>"))?;
                    let (window, prob) = rest
                        .split_once('p')
                        .ok_or_else(|| format!("drop {val:?} has no p<prob>"))?;
                    let (from, until) = window
                        .split_once('-')
                        .ok_or_else(|| format!("drop window {window:?} is not <from>-<until>"))?;
                    plan.links.push(LinkLoss {
                        src: parse_idx(src, "drop src host")?,
                        dst: parse_idx(dst, "drop dst host")?,
                        from: parse_secs(from, "drop window start")?,
                        until: parse_secs(until, "drop window end")?,
                        prob: prob.parse().map_err(|_| format!("bad drop prob {prob:?}"))?,
                    });
                }
                other => return Err(format!("unknown fault directive {other:?}")),
            }
        }
        Ok(plan)
    }

    /// Validate against a cluster shape: crash targets are storage
    /// indices, straggler hosts and link endpoints are host indices.
    pub fn validate(&self, n_storage: usize, n_hosts: usize) -> Result<(), String> {
        for c in &self.crashes {
            if c.storage >= n_storage {
                return Err(format!(
                    "fault plan crashes storage {} but the config has {n_storage} storage nodes",
                    c.storage
                ));
            }
        }
        for s in &self.stragglers {
            if s.host >= n_hosts {
                return Err(format!(
                    "fault plan slows host {} but the config has {n_hosts} hosts",
                    s.host
                ));
            }
            if !(s.slowdown > 0.0 && s.slowdown <= 1.0) {
                return Err(format!(
                    "straggler slowdown {} is outside (0, 1]",
                    s.slowdown
                ));
            }
        }
        for l in &self.links {
            if l.src >= n_hosts || l.dst >= n_hosts {
                return Err(format!(
                    "fault plan drops on link {}-{} but the config has {n_hosts} hosts",
                    l.src, l.dst
                ));
            }
            if !(0.0..=1.0).contains(&l.prob) {
                return Err(format!("drop probability {} is outside [0, 1]", l.prob));
            }
            if l.until < l.from {
                return Err(format!(
                    "drop window [{}, {}) on link {}-{} is inverted",
                    l.from, l.until, l.src, l.dst
                ));
            }
        }
        Ok(())
    }

    /// Whether a message from host `src` to host `dst` with identity
    /// `msg_id`, sent at `now`, is dropped. Pure in `(seed, src, dst,
    /// msg_id)` for a given plan — independent of run and thread count.
    pub fn drops(&self, src: usize, dst: usize, now: SimTime, msg_id: u64) -> bool {
        for l in &self.links {
            if l.src == src && l.dst == dst && now >= l.from && now < l.until {
                let stream = (src as u64)
                    .wrapping_mul(0x2545_F491_4F6C_DD1D)
                    .wrapping_add((dst as u64) << 20)
                    .wrapping_add(msg_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let r = Rng::stream_seed(self.seed, stream);
                let u = (r >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
                return u < l.prob;
            }
        }
        false
    }

    /// A benchmark schedule: `n_crashes` storage nodes spread evenly
    /// around the ring (so no two crashed nodes fall within one replica
    /// chain at replication ≥ 2, keeping every chunk recoverable), all
    /// crashing at `at`.
    pub fn spread_crashes(n_storage: usize, n_crashes: usize, at: SimTime) -> FaultPlan {
        assert!(n_crashes <= n_storage, "cannot crash more nodes than exist");
        let step = if n_crashes == 0 { 1 } else { n_storage / n_crashes };
        FaultPlan {
            seed: 1,
            crashes: (0..n_crashes).map(|k| Crash { storage: k * step, at }).collect(),
            ..FaultPlan::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_empty() {
        assert!(FaultPlan::default().is_empty());
        assert!(FaultPlan { seed: 9, ..FaultPlan::default() }.is_empty());
        let p = FaultPlan::parse("seed=3").unwrap();
        assert!(p.is_empty());
        assert_eq!(p.seed, 3);
    }

    #[test]
    fn parse_roundtrips_every_directive() {
        let p = FaultPlan::parse("seed=7; crash=0@2.5; crash=3@4; slow=1@1x0.25; drop=1-2@0-10p0.05")
            .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(
            p.crashes,
            vec![
                Crash { storage: 0, at: SimTime::from_secs_f64(2.5) },
                Crash { storage: 3, at: SimTime::from_secs_f64(4.0) },
            ]
        );
        assert_eq!(
            p.stragglers,
            vec![Straggler { host: 1, at: SimTime::from_secs_f64(1.0), slowdown: 0.25 }]
        );
        assert_eq!(
            p.links,
            vec![LinkLoss {
                src: 1,
                dst: 2,
                from: SimTime::ZERO,
                until: SimTime::from_secs_f64(10.0),
                prob: 0.05,
            }]
        );
        assert!(!p.is_empty());
    }

    #[test]
    fn parse_rejects_malformed_directives() {
        for bad in ["crash=0", "slow=1@2", "drop=1-2@5p0.1", "warp=9", "crash"] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn validate_checks_cluster_shape() {
        let p = FaultPlan::parse("crash=5@1").unwrap();
        assert!(p.validate(5, 10).is_err(), "storage index out of range");
        assert!(p.validate(6, 10).is_ok());
        let s = FaultPlan::parse("slow=3@1x1.5").unwrap();
        assert!(s.validate(4, 10).is_err(), "slowdown above 1 is a speedup");
        let l = FaultPlan::parse("drop=0-1@5-2p0.5").unwrap();
        assert!(l.validate(4, 10).is_err(), "inverted drop window");
    }

    #[test]
    fn drop_decisions_are_pure_and_respect_the_window() {
        let p = FaultPlan::parse("seed=11;drop=1-2@1-2p0.5").unwrap();
        let inside = SimTime::from_secs_f64(1.5);
        for id in 0..64u64 {
            assert_eq!(p.drops(1, 2, inside, id), p.drops(1, 2, inside, id));
        }
        let hits = (0..1000u64).filter(|&id| p.drops(1, 2, inside, id)).count();
        assert!((300..700).contains(&hits), "p=0.5 should drop roughly half: {hits}");
        assert!(!p.drops(2, 1, inside, 0), "reverse direction is unaffected");
        assert!(!p.drops(1, 2, SimTime::from_secs_f64(2.0), 0), "window is half-open");
        assert!((0..1000u64).all(|id| !p.drops(1, 2, SimTime::from_secs_f64(0.5), id)));
    }

    #[test]
    fn backoff_schedule_is_deterministic_and_bounded() {
        for op in 0..8usize {
            for chunk in 0..8u32 {
                for attempt in 0..MAX_ATTEMPTS {
                    let a = backoff_delay(42, op, chunk, attempt);
                    let b = backoff_delay(42, op, chunk, attempt);
                    assert_eq!(a, b, "backoff must be pure in (seed, op, chunk, attempt)");
                    assert!(a <= timeout_for(attempt) / 2);
                }
            }
        }
        assert_ne!(
            backoff_delay(1, 0, 0, 1),
            backoff_delay(2, 0, 0, 1),
            "distinct seeds give distinct jitter"
        );
    }

    #[test]
    fn timeouts_double_and_cap() {
        assert_eq!(timeout_for(0), TIMEOUT_BASE);
        assert_eq!(timeout_for(1), TIMEOUT_BASE * 2);
        assert_eq!(timeout_for(4), TIMEOUT_BASE * 16);
        assert_eq!(timeout_for(9), TIMEOUT_BASE * 16, "cap at 16x");
    }

    #[test]
    fn spread_crashes_never_adjacent_at_low_counts() {
        let p = FaultPlan::spread_crashes(1023, 16, SimTime::from_secs_f64(1.0));
        assert_eq!(p.crashes.len(), 16);
        let mut nodes: Vec<usize> = p.crashes.iter().map(|c| c.storage).collect();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes.len(), 16, "crashed nodes are distinct");
        for w in nodes.windows(2) {
            assert!(w[1] - w[0] >= 2, "no two crashed nodes are ring-adjacent");
        }
    }
}
