//! Storage-system and deployment configuration — the decision space of the
//! paper (§1 "The Problem"): provisioning (how many nodes), partitioning
//! (app vs storage nodes), and configuration (stripe width, replication,
//! chunk size, placement policy).

use super::faults::FaultPlan;
use crate::util::units::Bytes;

/// System-wide data placement policy (paper §2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Placement {
    /// Default: chunks round-robin across a stripe of `stripe_width` nodes.
    RoundRobin,
    /// Workflow-aware: place output on the storage node collocated with
    /// the writing client (pipeline optimization); files may still
    /// override via their own hints.
    Local,
}

impl std::fmt::Display for Placement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Placement::RoundRobin => write!(f, "round-robin"),
            Placement::Local => write!(f, "local"),
        }
    }
}

/// A complete deployment + storage configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Descriptive label (DSS / WASS / "14app-5sto-256KB" …).
    pub label: String,
    /// Number of application (client) nodes.
    pub n_app: usize,
    /// Number of storage nodes.
    pub n_storage: usize,
    /// Clients and storage nodes share hosts (the paper's synthetic-
    /// benchmark testbed runs "both a storage node and a client access
    /// module" on every machine). When false, clients and storage nodes
    /// occupy disjoint hosts (the BLAST partitioning scenarios).
    pub collocated: bool,
    /// Stripe width: number of storage nodes a file's chunks spread over.
    pub stripe_width: usize,
    /// System-wide replication level (≥ 1).
    pub replication: u32,
    /// Chunk size.
    pub chunk_size: Bytes,
    /// System-wide placement policy.
    pub placement: Placement,
    /// Data-location-aware task scheduling (WASS deployments: "for a given
    /// compute task, if all input file chunks exist on a single storage
    /// node, the task is scheduled on that node").
    pub location_aware: bool,
    /// Max outstanding chunk requests per client operation (SAI pipeline
    /// window; MosaStore-like clients bound in-flight chunks).
    pub io_window: usize,
    /// Deterministic fault schedule (empty by default: the fault-free
    /// engine, bit-identical to a run without fault support).
    pub faults: FaultPlan,
}

impl Config {
    /// The paper's DSS baseline on `n` collocated nodes: stripe over all
    /// storage nodes, no replication, 1 MB chunks, round-robin, no
    /// pattern-aware optimization.
    pub fn dss(n: usize) -> Config {
        Config {
            label: "DSS".into(),
            n_app: n,
            n_storage: n,
            collocated: true,
            stripe_width: n,
            replication: 1,
            chunk_size: Bytes::mb(1),
            placement: Placement::RoundRobin,
            location_aware: false,
            io_window: 8,
            faults: FaultPlan::default(),
        }
    }

    /// The paper's WASS configuration on `n` collocated nodes: local
    /// placement + data-location-aware scheduling; per-file hints
    /// (collocation, replication) come from the workload.
    pub fn wass(n: usize) -> Config {
        Config {
            label: "WASS".into(),
            placement: Placement::Local,
            location_aware: true,
            ..Config::dss(n)
        }
    }

    /// A partitioned deployment (BLAST scenarios): `n_app` application
    /// nodes and `n_storage` dedicated storage nodes on disjoint hosts.
    pub fn partitioned(n_app: usize, n_storage: usize, chunk: Bytes) -> Config {
        Config {
            label: format!("{n_app}app/{n_storage}sto/{chunk}"),
            n_app,
            n_storage,
            collocated: false,
            stripe_width: n_storage,
            replication: 1,
            chunk_size: chunk,
            placement: Placement::RoundRobin,
            location_aware: false,
            io_window: 8,
            faults: FaultPlan::default(),
        }
    }

    pub fn with_label(mut self, label: impl Into<String>) -> Config {
        self.label = label.into();
        self
    }

    pub fn with_stripe(mut self, w: usize) -> Config {
        self.stripe_width = w;
        self
    }

    pub fn with_replication(mut self, r: u32) -> Config {
        self.replication = r;
        self
    }

    pub fn with_chunk(mut self, c: Bytes) -> Config {
        self.chunk_size = c;
        self
    }

    pub fn with_window(mut self, w: usize) -> Config {
        self.io_window = w;
        self
    }

    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Config {
        self.faults = plan;
        self
    }

    /// Total hosts: manager host + app/storage hosts.
    pub fn n_hosts(&self) -> usize {
        1 + if self.collocated { self.n_app.max(self.n_storage) } else { self.n_app + self.n_storage }
    }

    /// Host of client `c` (manager is host 0; clients follow).
    pub fn client_host(&self, c: usize) -> usize {
        debug_assert!(c < self.n_app);
        1 + c
    }

    /// Host of storage node `s`.
    pub fn storage_host(&self, s: usize) -> usize {
        debug_assert!(s < self.n_storage);
        if self.collocated {
            1 + s
        } else {
            1 + self.n_app + s
        }
    }

    /// The storage node collocated with client `c`, if any.
    pub fn storage_on_client_host(&self, c: usize) -> Option<usize> {
        if self.collocated && c < self.n_storage {
            Some(c)
        } else {
            None
        }
    }

    /// The client collocated with storage node `s`, if any.
    pub fn client_on_storage_host(&self, s: usize) -> Option<usize> {
        if self.collocated && s < self.n_app {
            Some(s)
        } else {
            None
        }
    }

    /// Validate invariants; called by `simulate`.
    pub fn validate(&self) -> Result<(), String> {
        if self.n_app == 0 || self.n_storage == 0 {
            return Err("need at least one app node and one storage node".into());
        }
        if self.stripe_width == 0 || self.stripe_width > self.n_storage {
            return Err(format!(
                "stripe width {} must be in [1, n_storage={}]",
                self.stripe_width, self.n_storage
            ));
        }
        if self.replication == 0 {
            return Err("replication level must be >= 1".into());
        }
        if self.replication as usize > self.n_storage {
            return Err(format!(
                "replication {} exceeds storage nodes {}",
                self.replication, self.n_storage
            ));
        }
        if self.chunk_size.as_u64() == 0 {
            return Err("chunk size must be positive".into());
        }
        if self.io_window == 0 {
            return Err("io window must be >= 1".into());
        }
        self.faults.validate(self.n_storage, self.n_hosts())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dss_defaults_match_paper() {
        let c = Config::dss(19);
        assert_eq!(c.n_hosts(), 20, "19 dual-role nodes + manager = paper testbed");
        assert_eq!(c.stripe_width, 19);
        assert_eq!(c.replication, 1);
        assert_eq!(c.chunk_size, Bytes::mb(1));
        assert!(!c.location_aware);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn host_mapping_collocated() {
        let c = Config::dss(19);
        assert_eq!(c.client_host(0), 1);
        assert_eq!(c.storage_host(0), 1);
        assert_eq!(c.storage_on_client_host(3), Some(3));
        assert_eq!(c.client_on_storage_host(3), Some(3));
    }

    #[test]
    fn host_mapping_partitioned() {
        let c = Config::partitioned(14, 5, Bytes::kb(256));
        assert_eq!(c.n_hosts(), 20);
        assert_eq!(c.client_host(13), 14);
        assert_eq!(c.storage_host(0), 15);
        assert_eq!(c.storage_host(4), 19);
        assert_eq!(c.storage_on_client_host(2), None);
    }

    #[test]
    fn validation_catches_bad_configs() {
        assert!(Config::dss(19).with_stripe(20).validate().is_err());
        assert!(Config::dss(19).with_replication(0).validate().is_err());
        assert!(Config::dss(19).with_replication(20).validate().is_err());
        assert!(Config::partitioned(0, 5, Bytes::mb(1)).validate().is_err());
        assert!(Config::dss(19).with_chunk(Bytes(0)).validate().is_err());
    }

    #[test]
    fn fault_plan_validated_against_cluster_shape() {
        let plan = FaultPlan::parse("crash=19@1").unwrap();
        assert!(Config::dss(19).with_fault_plan(plan.clone()).validate().is_err());
        assert!(Config::dss(20).with_fault_plan(plan).validate().is_ok());
        let slow = FaultPlan::parse("slow=25@1x0.5").unwrap();
        assert!(Config::dss(19).with_fault_plan(slow).validate().is_err(), "host out of range");
    }
}
