//! Simulation output: what the simulator "reports … per each read or
//! write" (§2.4: time spent, data transferred, storage used) plus the
//! aggregates the evaluation plots (turnaround, per-stage makespan) and
//! the diagnostics the paper's §5 uses (component utilization).

use crate::util::units::{Bytes, SimTime};

/// Record of one completed whole-file operation.
#[derive(Clone, Debug)]
pub struct OpRecord {
    pub client: usize,
    pub task: usize,
    pub file: usize,
    pub is_write: bool,
    pub bytes: Bytes,
    pub start: SimTime,
    pub end: SimTime,
}

impl OpRecord {
    pub fn latency(&self) -> SimTime {
        self.end - self.start
    }
}

/// Record of one completed task.
#[derive(Clone, Debug)]
pub struct TaskRecord {
    pub task: usize,
    pub stage: u32,
    pub client: usize,
    pub start: SimTime,
    pub end: SimTime,
}

/// Per-component utilization diagnostics.
#[derive(Clone, Debug)]
pub struct UtilReport {
    pub manager_util: f64,
    pub manager_mean_qlen: f64,
    /// (utilization, mean queue length) per storage node.
    pub storage: Vec<(f64, f64)>,
    /// (out-NIC utilization, in-NIC utilization) per host.
    pub nic: Vec<(f64, f64)>,
    /// (out-NIC, in-NIC) time-averaged queue length per host, in *frames*.
    /// Trains are unit-weighted and intra-train waiting is accounted
    /// analytically (see `sim::station`), so under bulk aggregation these
    /// integrals match the per-frame path exactly at uncontended stations
    /// (property-tested). At a *backlogged* in-NIC a queued train posts
    /// all its frames at once while the per-frame path still paces them
    /// in at the sender; the engine accumulates that analytic excess
    /// (`unit · u(u−1)/2` per busy train arrival) and subtracts it here
    /// (`StationStats::mean_qlen_corrected`), so the reported in-NIC
    /// depth is the paced one in both modes.
    pub nic_qlen: Vec<(f64, f64)>,
    /// (utilization, mean queue length) per core-fabric link, in rack
    /// layout order (uplink then downlink per rack). Empty under the
    /// star topology — the star fabric has no core links, which is what
    /// keeps star reports bit-identical to the pre-fabric engine.
    pub links: Vec<(f64, f64)>,
}

/// Full output of one simulated run.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub config_label: String,
    /// Application turnaround — the paper's headline metric.
    pub turnaround: SimTime,
    pub ops: Vec<OpRecord>,
    pub tasks: Vec<TaskRecord>,
    /// Bytes that crossed the network (both directions, data + control).
    pub net_bytes: Bytes,
    /// Wire frames modeled — counted whether or not the frame path
    /// aggregated them into bulk trains, so `events / net_frames` exposes
    /// the aggregation factor.
    pub net_frames: u64,
    /// Bytes stored per storage node at the end of the run.
    pub stored: Vec<Bytes>,
    /// Storage nodes whose stored bytes exceeded the platform capacity.
    pub capacity_overflows: usize,
    pub util: UtilReport,
    /// Total simulation events processed (cost metric for §3.3).
    pub events: u64,
    /// Completion announcements withdrawn before firing (bulk-path
    /// weighted-fair in-NICs cancel the superseded announcement whenever
    /// an arrival changes the fair shares). Stale work the engine skipped
    /// for a slab-generation compare instead of a delivered event; the
    /// `incast.*` bench cells report
    /// `events_cancelled / (events + events_cancelled)` as the
    /// stale-event ratio.
    pub events_cancelled: u64,
    /// Connection SYN retries (detailed fidelity only; 0 for the
    /// predictor — one of the paper's named sources of real-system noise).
    pub conn_retries: u64,
    /// Degraded-mode accounting (all zero when the fault plan is empty).
    /// Chunk attempts re-issued after a timeout.
    pub fault_retries: u64,
    /// Chunk attempts routed away from the fault-free target (read
    /// failover to a surviving replica, write chain entry past dead
    /// members).
    pub fault_failovers: u64,
    /// Per-chunk timeouts that fired.
    pub fault_timeouts: u64,
    /// Messages dropped by lossy links.
    pub fault_msgs_dropped: u64,
    /// Service units lost to crashes: a crashed node's abandoned queue,
    /// its in-flight service, and later arrivals addressed to it.
    pub fault_work_lost: u64,
    /// Operations declared unrecoverable (every replica of a needed chunk
    /// lost, or the retry budget spent).
    pub unrecoverable_ops: u64,
    /// Tasks abandoned because an operation was unrecoverable.
    pub failed_tasks: u64,
}

impl SimReport {
    /// Whether any operation was lost for good — the headline availability
    /// signal of a degraded run (always false fault-free).
    pub fn unrecoverable(&self) -> bool {
        self.unrecoverable_ops > 0
    }

    /// Makespan of one stage: last task end − first task start.
    /// Single-pass fold — the bench runner calls this per cell, so it
    /// must not allocate.
    pub fn stage_time(&self, stage: u32) -> SimTime {
        let (start, end) = self
            .tasks
            .iter()
            .filter(|t| t.stage == stage)
            .fold((SimTime::MAX, SimTime::ZERO), |(s, e), t| (s.min(t.start), e.max(t.end)));
        if start > end {
            SimTime::ZERO // no tasks in this stage
        } else {
            end - start
        }
    }

    pub fn n_stages(&self) -> u32 {
        self.tasks.iter().map(|t| t.stage + 1).max().unwrap_or(0)
    }

    /// Total bytes currently stored across nodes.
    pub fn stored_total(&self) -> Bytes {
        Bytes(self.stored.iter().map(|b| b.as_u64()).sum())
    }

    /// Peak per-node stored bytes.
    pub fn stored_max(&self) -> Bytes {
        self.stored.iter().copied().max().unwrap_or(Bytes::ZERO)
    }

    /// Mean operation latency for reads or writes. Single-pass fold —
    /// called per cell in the bench runner, so it must not allocate.
    pub fn mean_op_latency(&self, writes: bool) -> SimTime {
        let (sum, n) = self
            .ops
            .iter()
            .filter(|o| o.is_write == writes)
            .fold((0u64, 0u64), |(s, n), o| (s + o.latency().as_ns(), n + 1));
        if n == 0 {
            SimTime::ZERO
        } else {
            SimTime(sum / n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with_tasks(tasks: Vec<TaskRecord>) -> SimReport {
        SimReport {
            config_label: "t".into(),
            turnaround: SimTime::from_ms(10),
            ops: vec![],
            tasks,
            net_bytes: Bytes::ZERO,
            net_frames: 0,
            stored: vec![Bytes::mb(1), Bytes::mb(3)],
            capacity_overflows: 0,
            util: UtilReport {
                manager_util: 0.0,
                manager_mean_qlen: 0.0,
                storage: vec![],
                nic: vec![],
                nic_qlen: vec![],
                links: vec![],
            },
            events: 0,
            events_cancelled: 0,
            conn_retries: 0,
            fault_retries: 0,
            fault_failovers: 0,
            fault_timeouts: 0,
            fault_msgs_dropped: 0,
            fault_work_lost: 0,
            unrecoverable_ops: 0,
            failed_tasks: 0,
        }
    }

    #[test]
    fn stage_time_spans_first_start_to_last_end() {
        let r = report_with_tasks(vec![
            TaskRecord { task: 0, stage: 0, client: 0, start: SimTime::from_ms(1), end: SimTime::from_ms(5) },
            TaskRecord { task: 1, stage: 0, client: 1, start: SimTime::from_ms(2), end: SimTime::from_ms(9) },
            TaskRecord { task: 2, stage: 1, client: 0, start: SimTime::from_ms(9), end: SimTime::from_ms(10) },
        ]);
        assert_eq!(r.stage_time(0), SimTime::from_ms(8));
        assert_eq!(r.stage_time(1), SimTime::from_ms(1));
        assert_eq!(r.stage_time(7), SimTime::ZERO);
        assert_eq!(r.n_stages(), 2);
    }

    #[test]
    fn storage_aggregates() {
        let r = report_with_tasks(vec![]);
        assert_eq!(r.stored_total(), Bytes::mb(4));
        assert_eq!(r.stored_max(), Bytes::mb(3));
    }
}
