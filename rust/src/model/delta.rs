//! Incremental re-simulation: stage-boundary checkpoints and delta
//! warm-starts for search campaigns.
//!
//! Search neighbors differ by one knob (stripe width, replication, chunk
//! size), so most of a neighbor's simulation is identical work re-done:
//! every stage whose files don't observe the changed knob unfolds
//! event-for-event the same. This module makes that sharing explicit:
//!
//! * [`stage_fingerprints`] — a per-stage fingerprint of *exactly the
//!   inputs stages `0..=s` can observe*: the full workload / platform /
//!   fidelity / fault plan, the config's global knobs, and a **per-file
//!   projection** of the value-dependent knobs (chunking pattern,
//!   effective replication, stripe width where the file's placement is
//!   stripe-sensitive) restricted to files touched by tasks of stage
//!   `<= s` plus all prestaged files. Two configs that agree on a prefix
//!   of stage fingerprints provably produce the identical event sequence
//!   over that prefix.
//! * [`DeltaBase::capture`] — a cold simulation that additionally
//!   snapshots the whole simulation (`Simulation<World>` is `Clone` since
//!   the world owns its inputs) at every stage boundary, labeled with the
//!   deepest stage fully incorporated so far.
//! * [`DeltaBase::resume`] — given a neighbor config, verifies the
//!   stage-fingerprint prefix match, splices the deepest valid snapshot
//!   (rebinding the owned config — [`World::rebind_config`]), and replays
//!   only the suffix.
//!
//! ## Exactness (the house rule)
//!
//! The cold path is the reference oracle: a delta answer must be
//! **bit-identical** to a cold simulation of the same config — no
//! tolerances. This holds by construction: the capture loop is the plain
//! run loop (same `prepare_sim`, same delivery order — peeking and
//! cloning never perturb the queue), a snapshot is the entire state
//! including the RNG stream position and the scheduler's
//! processed/cancelled totals, and a snapshot is only resumed under a
//! config whose fingerprint prefix proves every decision taken so far
//! would have been identical. Pinned by `prop_delta_resim_matches_cold`
//! (single-knob perturbations × fault plans × fidelity modes).
//!
//! ## Boundary rule
//!
//! Tasks enter the event stream only through `Ev::Release` (the driver
//! releases a task when its inputs commit), so just before delivering the
//! first `Release` of a task of stage `s_next >` every stage released so
//! far, the state contains work of stages `<= max_released` only. That
//! instant is snapshotted with label `max_released` — the *weakest* sound
//! validity requirement, so a neighbor differing only in later stages can
//! still splice. Stages releasing out of order (wide DAG fan-in) simply
//! yield fewer checkpoints, never unsound ones.
//!
//! ## Memory
//!
//! Snapshots are in-memory only and hold the full message arena of the
//! prefix, so a base costs O(prefix events) bytes per snapshot. The
//! answer store persists only the compact [`StageCheckpoint`] summaries
//! (fingerprint, boundary time, station integrals, RNG position) — enough
//! to prove prefix sharing across processes and warm-start *accounting*,
//! not to resume; resumption needs a live base captured this process
//! (the serving layer keeps the most recent one, see `service/`).

use crate::model::config::{Config, Placement};
use crate::model::engine::{self, Ev, World};
use crate::model::faults::FaultPlan;
use crate::model::fidelity::Fidelity;
use crate::model::platform::{DiskKind, Platform, Topology};
use crate::model::report::SimReport;
use crate::sim::Simulation;
use crate::trace::NoopProbe;
use crate::util::hash::Fnv64;
use crate::workload::{FileHint, FileSpec, Workload};
use std::fmt;
use std::sync::Arc;

/// 128-bit per-stage fingerprint (two independently-seeded FNV-1a
/// streams, like the service's evaluation-point fingerprint but over the
/// stage-restricted input projection).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct StageFp {
    pub hi: u64,
    pub lo: u64,
}

impl StageFp {
    /// Parse the 32-hex-digit form produced by `Display`.
    pub fn parse(s: &str) -> Option<StageFp> {
        if s.len() != 32 || !s.is_ascii() {
            return None;
        }
        let hi = u64::from_str_radix(&s[..16], 16).ok()?;
        let lo = u64::from_str_radix(&s[16..], 16).ok()?;
        Some(StageFp { hi, lo })
    }
}

impl fmt::Display for StageFp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}{:016x}", self.hi, self.lo)
    }
}

impl fmt::Debug for StageFp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "StageFp({self})")
    }
}

/// Two independently-seeded FNV-1a streams fed the same byte sequence.
/// Seeded differently from the service fingerprint's pair so the two
/// families never collide by construction.
struct H2 {
    a: Fnv64,
    b: Fnv64,
}

impl H2 {
    fn new() -> H2 {
        H2 { a: Fnv64::with_seed(0x5EED_0011), b: Fnv64::with_seed(0x5EED_0012) }
    }

    fn u32(&mut self, x: u32) {
        self.a.write_u32(x);
        self.b.write_u32(x);
    }

    fn u64(&mut self, x: u64) {
        self.a.write_u64(x);
        self.b.write_u64(x);
    }

    fn usize(&mut self, x: usize) {
        self.u64(x as u64);
    }

    fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }

    fn bool(&mut self, x: bool) {
        self.a.write_bool(x);
        self.b.write_bool(x);
    }

    fn str(&mut self, s: &str) {
        self.a.write_str(s);
        self.b.write_str(s);
    }

    fn finish(&self) -> (u64, u64) {
        (self.a.finish(), self.b.finish())
    }

    fn fp(&self) -> StageFp {
        StageFp { hi: self.a.finish(), lo: self.b.finish() }
    }
}

/// Full positional workload hash. Deliberately *more* discriminating than
/// the service fingerprint's order-canonical one: within a campaign the
/// workload object is shared verbatim, and a false mismatch only costs a
/// cold fallback — a false match would cost correctness.
fn hash_workload(h: &mut H2, wl: &Workload) {
    h.str(&wl.name);
    h.usize(wl.files.len());
    for f in &wl.files {
        h.str(&f.name);
        h.u64(f.size.as_u64());
        match f.hint {
            FileHint::Default => h.u32(0),
            FileHint::Local => h.u32(1),
            FileHint::OnNode(n) => {
                h.u32(2);
                h.usize(n);
            }
            FileHint::Striped => h.u32(3),
        }
        match f.replication {
            None => h.u32(0),
            Some(r) => {
                h.u32(1);
                h.u32(r);
            }
        }
        h.bool(f.prestaged);
    }
    h.usize(wl.tasks.len());
    for t in &wl.tasks {
        h.str(&t.name);
        h.u32(t.stage);
        h.u64(t.compute.as_ns());
        h.u64(t.release.as_ns());
        match t.pin_client {
            None => h.u32(0),
            Some(c) => {
                h.u32(1);
                h.usize(c);
            }
        }
        h.usize(t.reads.len());
        for &f in &t.reads {
            h.usize(f);
        }
        h.usize(t.writes.len());
        for &f in &t.writes {
            h.usize(f);
        }
    }
}

/// Every `Platform` field feeds the hash (keep in sync with the struct;
/// the service fingerprint hashes the same list).
fn hash_platform(h: &mut H2, p: &Platform) {
    h.str(&p.label);
    h.f64(p.net_remote_bps);
    h.f64(p.net_local_bps);
    h.u64(p.net_latency.as_ns());
    h.u64(p.net_latency_local.as_ns());
    h.u64(p.frame_size.as_u64());
    h.f64(p.storage_ns_per_byte_write);
    h.f64(p.storage_ns_per_byte_read);
    h.u64(p.storage_op.as_ns());
    h.u64(p.manager_op.as_ns());
    h.u64(p.client_op.as_ns());
    h.u64(p.hdd_seek.as_ns());
    h.u64(p.host_speed.len() as u64);
    for &s in &p.host_speed {
        h.f64(s);
    }
    h.u64(p.node_capacity.as_u64());
    h.u32(match p.disk {
        DiskKind::Ram => 0,
        DiskKind::Hdd => 1,
        DiskKind::Ssd => 2,
    });
    // Star hashes nothing (pre-fabric fingerprints stay valid); any rack
    // layout perturbs the context hash and with it *every* stage
    // fingerprint, so a topology change always empties the warm-start
    // prefix — spliced state can never leak across topologies.
    if let Topology::Rack { rack_size, oversub } = p.topology {
        h.str("topology.v1");
        h.usize(rack_size);
        h.f64(oversub);
    }
}

/// Every `Fidelity` switch feeds the hash (any of them can change the
/// event sequence from the very first event — RNG draws at world
/// construction included).
fn hash_fidelity(h: &mut H2, f: &Fidelity) {
    h.bool(f.frame_aggregation);
    h.bool(f.control_rounds);
    h.u32(f.alloc_batch);
    h.bool(f.connections);
    h.u64(f.conn_timeout.as_ns());
    h.usize(f.syn_drop_qlen);
    h.usize(f.syn_drop_full);
    h.u64(f.stagger_mean.as_ns());
    h.f64(f.jitter_sigma);
    h.f64(f.manager_contention);
    h.f64(f.hetero_sigma);
    h.f64(f.mux_eta);
    h.u64(f.per_target_setup.as_ns());
    h.f64(f.train_qlen_scale);
    h.bool(f.random_placement);
    h.u64(f.seed);
}

/// The whole fault plan, seed included, feeds every stage fingerprint:
/// crash/straggle events are armed at t=0 and link-loss verdicts hash the
/// plan seed, so *any* plan change can perturb the very first stage — a
/// changed plan must invalidate the whole prefix (cold fallback).
fn hash_faults(h: &mut H2, plan: &FaultPlan) {
    h.bool(plan.is_empty());
    if plan.is_empty() {
        return;
    }
    h.u64(plan.seed);
    h.usize(plan.crashes.len());
    for c in &plan.crashes {
        h.usize(c.storage);
        h.u64(c.at.as_ns());
    }
    h.usize(plan.stragglers.len());
    for s in &plan.stragglers {
        h.usize(s.host);
        h.u64(s.at.as_ns());
        h.f64(s.slowdown);
    }
    h.usize(plan.links.len());
    for l in &plan.links {
        h.usize(l.src);
        h.usize(l.dst);
        h.u64(l.from.as_ns());
        h.u64(l.until.as_ns());
        h.f64(l.prob);
    }
}

/// Per-file projection of the value-dependent config knobs: what the
/// protocol can actually observe about this file. Chunk size enters as
/// the chunking *pattern* (count, full-chunk bytes when more than one
/// chunk, last-chunk bytes), effective replication resolves the per-file
/// override, and the stripe width is hashed only where the file's
/// placement is stripe-sensitive — so a stripe sweep leaves stages whose
/// files are all node-pinned with identical fingerprints.
fn file_projection(h: &mut H2, f: &FileSpec, cfg: &Config) {
    let full = cfg.chunk_size.as_u64();
    let n_chunks = f.size.chunks(cfg.chunk_size);
    h.u64(n_chunks);
    h.u64(if n_chunks > 1 { full } else { 0 });
    let last = if f.size.as_u64() == 0 { 0 } else { f.size.as_u64() - (n_chunks - 1) * full };
    h.u64(last);
    h.u32(f.replication.unwrap_or(cfg.replication));
    match f.hint {
        FileHint::OnNode(s) => {
            h.u32(1);
            h.usize(s % cfg.n_storage);
        }
        FileHint::Local => h.u32(2),
        FileHint::Striped => {
            h.u32(3);
            h.usize(cfg.stripe_width.min(cfg.n_storage));
        }
        FileHint::Default => match cfg.placement {
            Placement::RoundRobin => {
                h.u32(4);
                h.usize(cfg.stripe_width.min(cfg.n_storage));
            }
            Placement::Local => h.u32(5),
        },
    }
}

/// Per-stage fingerprints of one evaluation point: entry `s` commits to
/// everything stages `0..=s` can observe. Two configs with equal entries
/// `0..=s` produce the identical event sequence until the first release
/// of a task of stage `> s` (see the module doc's boundary rule).
///
/// The config `label` is deliberately excluded: it flows only into the
/// final report, which the resume path produces under the neighbor's own
/// (rebound) config.
pub fn stage_fingerprints(wl: &Workload, cfg: &Config, plat: &Platform, fid: &Fidelity) -> Vec<StageFp> {
    let n = wl.n_stages() as usize;
    let mut ctx = H2::new();
    ctx.str("wfpred.stagefp.v1");
    hash_workload(&mut ctx, wl);
    hash_platform(&mut ctx, plat);
    hash_fidelity(&mut ctx, fid);
    hash_faults(&mut ctx, &cfg.faults);
    // Config globals every protocol path reads, whatever the stage.
    ctx.usize(cfg.n_app);
    ctx.usize(cfg.n_storage);
    ctx.bool(cfg.collocated);
    ctx.u32(match cfg.placement {
        Placement::RoundRobin => 0,
        Placement::Local => 1,
    });
    ctx.bool(cfg.location_aware);
    ctx.usize(cfg.io_window);
    let (ca, cb) = ctx.finish();

    // First stage that can touch each file (prestaged files are committed
    // at t=0 and consume placement state, so they belong to every stage).
    let mut first_touch: Vec<Option<u32>> = vec![None; wl.files.len()];
    for (i, f) in wl.files.iter().enumerate() {
        if f.prestaged {
            first_touch[i] = Some(0);
        }
    }
    for t in &wl.tasks {
        for &f in t.reads.iter().chain(t.writes.iter()) {
            let e = &mut first_touch[f];
            *e = Some(e.map_or(t.stage, |s| s.min(t.stage)));
        }
    }

    (0..n as u32)
        .map(|s| {
            let mut h = H2::new();
            h.u64(ca);
            h.u64(cb);
            h.u32(s);
            for (i, f) in wl.files.iter().enumerate() {
                match first_touch[i] {
                    Some(fs) if fs <= s => {}
                    _ => continue,
                }
                h.usize(i);
                file_projection(&mut h, f, cfg);
            }
            h.fp()
        })
        .collect()
}

/// Compact summary of one stage-boundary snapshot — what the answer
/// store persists (fingerprinted per stage, so two configs can be *seen*
/// to share a prefix across processes) and what the stats lines report.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageCheckpoint {
    /// Snapshot label: deepest stage fully incorporated in the state.
    pub stage: u32,
    /// `stage_fingerprints(..)[stage]` of the captured config.
    pub fp: StageFp,
    /// Virtual time of the boundary (ns).
    pub t_ns: u64,
    /// Events delivered up to the boundary.
    pub events: u64,
    /// Tasks finished up to the boundary.
    pub tasks_finished: u32,
    /// Network bytes modeled up to the boundary.
    pub net_bytes: u64,
    /// Interned placement outcomes so far (distinct allocations/groups —
    /// the `AllocId`/`GroupId` population of `placement.rs`).
    pub n_allocs: u32,
    pub n_groups: u32,
    /// Manager-station busy integral at the boundary (ns).
    pub manager_busy_ns: u64,
    /// Summed storage-station busy integral at the boundary (ns).
    pub storage_busy_ns: u64,
    /// Exact RNG stream position (xoshiro256** state words).
    pub rng: [u64; 4],
}

/// What a delta warm-start did, surfaced on the answer and the campaign
/// stats lines. Stage counts use the snapshot label as the boundary:
/// `stages_skipped` were spliced from the checkpoint, `stages_replayed`
/// were simulated (a stage released concurrently with an earlier one
/// counts as replayed — attribution is conservative).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeltaOutcome {
    pub stages_skipped: u32,
    pub stages_replayed: u32,
}

/// A resumed neighbor evaluation: the (bit-identical-to-cold) report,
/// the skip attribution, and the matched prefix's checkpoint summaries
/// (valid for the resumed config — their fingerprints matched — so the
/// store can persist them under its answer too).
pub struct DeltaResult {
    pub report: SimReport,
    pub outcome: DeltaOutcome,
    pub checkpoints: Vec<StageCheckpoint>,
}

/// One in-memory stage-boundary snapshot: the compact summary plus the
/// full cloned simulation it summarizes.
struct Snapshot {
    ck: StageCheckpoint,
    sim: Simulation<World<NoopProbe>>,
}

/// A captured base simulation: the cold answer's stage fingerprints plus
/// resumable snapshots at every stage boundary.
pub struct DeltaBase {
    wl: Arc<Workload>,
    plat: Arc<Platform>,
    fid: Fidelity,
    n_stages: u32,
    fps: Vec<StageFp>,
    snaps: Vec<Snapshot>,
}

fn checkpoint_of(label: u32, fp: StageFp, sim: &Simulation<World<NoopProbe>>) -> StageCheckpoint {
    let w = &sim.state;
    StageCheckpoint {
        stage: label,
        fp,
        t_ns: sim.sched.now().as_ns(),
        events: sim.sched.processed(),
        tasks_finished: w.driver.finished_tasks() as u32,
        net_bytes: w.net_bytes,
        n_allocs: w.placement.n_allocs() as u32,
        n_groups: w.placement.n_groups() as u32,
        manager_busy_ns: w.manager_st.stats.busy_ns,
        storage_busy_ns: w.storage_st.iter().map(|s| s.stats.busy_ns).sum(),
        rng: w.rng.state_words(),
    }
}

impl DeltaBase {
    /// Run a cold simulation, capturing a resumable snapshot at every
    /// stage boundary. The report is bit-identical to
    /// [`crate::model::simulate_fid`] on the same inputs: the loop is the
    /// same prepare → deliver-in-order → finalize sequence, and peeking /
    /// cloning never perturbs delivery.
    pub fn capture(wl: &Workload, cfg: &Config, plat: &Platform, fid: Fidelity) -> (SimReport, DeltaBase) {
        let wl = Arc::new(wl.clone());
        let cfg = Arc::new(cfg.clone());
        let plat = Arc::new(plat.clone());
        let n_stages = wl.n_stages();
        let fps = stage_fingerprints(&wl, &cfg, &plat, &fid);
        let mut sim =
            engine::prepare_sim(wl.clone(), cfg.clone(), plat.clone(), fid.clone(), NoopProbe);
        let mut snaps: Vec<Snapshot> = Vec::new();
        let mut max_released: i64 = -1;
        let mut n = 0u64;
        loop {
            // Boundary rule: snapshot just before the first release of a
            // task of a not-yet-seen-higher stage (see module doc).
            let boundary = match sim.sched.peek() {
                None => break,
                Some((_, Ev::Release(t))) => {
                    let s = wl.tasks[*t].stage as i64;
                    if s > max_released { Some(s) } else { None }
                }
                Some(_) => None,
            };
            if let Some(s_next) = boundary {
                if max_released >= 0 {
                    let label = max_released as u32;
                    let ck = checkpoint_of(label, fps[label as usize], &sim);
                    snaps.push(Snapshot { ck, sim: sim.clone() });
                }
                max_released = s_next;
            }
            let stepped = sim.step();
            debug_assert!(stepped, "peek saw a live event but step found none");
            n += 1;
            if n >= engine::MAX_SIM_EVENTS {
                panic!("simulation exceeded {} events — livelock?", engine::MAX_SIM_EVENTS);
            }
        }
        let end = sim.sched.now();
        let (report, _probe) = engine::finalize_sim(sim, end);
        (report, DeltaBase { wl, plat, fid, n_stages, fps, snaps })
    }

    /// Warm-start a neighbor: verify the stage-fingerprint prefix match,
    /// splice the deepest valid snapshot under the neighbor's config, and
    /// replay only the suffix. `None` when no prefix matches (changed
    /// fault plan, changed workload, changed global knob, or a first-stage
    /// knob difference) — the caller falls back to the cold path.
    ///
    /// The neighbor's fingerprints are computed over the *caller's*
    /// workload: a workload differing anywhere from the base's perturbs
    /// the context hash and with it every stage fingerprint, so prefix
    /// length 0 forces the cold fallback rather than replaying the wrong
    /// DAG.
    pub fn resume(&self, wl: &Workload, cfg: &Config) -> Option<DeltaResult> {
        cfg.validate().ok()?;
        let theirs = stage_fingerprints(wl, cfg, &self.plat, &self.fid);
        let mut matched = 0usize;
        while matched < self.fps.len()
            && matched < theirs.len()
            && self.fps[matched] == theirs[matched]
        {
            matched += 1;
        }
        // Deepest snapshot whose incorporated stages all matched.
        let snap = self.snaps.iter().rev().find(|s| (s.ck.stage as usize) < matched)?;
        let mut sim = snap.sim.clone();
        sim.state.rebind_config(Arc::new(cfg.clone()));
        let end = sim.run_capped(engine::MAX_SIM_EVENTS);
        let (report, _probe) = engine::finalize_sim(sim, end);
        let skipped = snap.ck.stage + 1;
        let checkpoints =
            self.snaps.iter().filter(|s| (s.ck.stage as usize) < matched).map(|s| s.ck.clone()).collect();
        Some(DeltaResult {
            report,
            outcome: DeltaOutcome {
                stages_skipped: skipped,
                stages_replayed: self.n_stages.saturating_sub(skipped),
            },
            checkpoints,
        })
    }

    /// The captured run's compact checkpoint summaries (for persistence).
    pub fn checkpoints(&self) -> Vec<StageCheckpoint> {
        self.snaps.iter().map(|s| s.ck.clone()).collect()
    }

    /// The workload this base was captured from.
    pub fn workload(&self) -> &Workload {
        &self.wl
    }

    /// Per-stage fingerprints of the captured config.
    pub fn stage_fps(&self) -> &[StageFp] {
        &self.fps
    }

    /// Resumable snapshots captured (≤ stages − 1; fewer when stages
    /// release out of order).
    pub fn n_snapshots(&self) -> usize {
        self.snaps.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::engine::simulate_fid;
    use crate::util::units::{Bytes, SimTime};
    use crate::workload::TaskSpec;

    /// Stage 0 writes node-pinned files (stripe-insensitive); stage 1
    /// reads them and writes a round-robin (stripe-sensitive) output.
    fn two_stage_wl(n_stage0: usize) -> Workload {
        let mut w = Workload::new("delta-test");
        // Node-pinned so stage 0's fingerprint is stripe-insensitive.
        let db =
            w.add_file(FileSpec::new("db", Bytes::mb(2)).hint(FileHint::OnNode(0)).prestaged());
        let mut mids = Vec::new();
        for i in 0..n_stage0 {
            let f = w.add_file(
                FileSpec::new(format!("mid{i}"), Bytes::mb(4)).hint(FileHint::OnNode(i)),
            );
            mids.push(f);
            w.add_task(TaskSpec::new(format!("t0-{i}"), 0).reads(db).writes(f).compute(SimTime::from_ms(5)));
        }
        let out = w.add_file(FileSpec::new("out", Bytes::mb(1)));
        let mut agg = TaskSpec::new("t1", 1).writes(out);
        for &m in &mids {
            agg = agg.reads(m);
        }
        w.add_task(agg);
        w
    }

    fn plat() -> Platform {
        Platform::paper_testbed()
    }

    fn base_cfg() -> Config {
        Config::partitioned(4, 4, Bytes::mb(1)).with_label("delta-base").with_stripe(1)
    }

    fn assert_reports_identical(a: &SimReport, b: &SimReport) {
        // Bit-identity, no tolerances: Debug formats f64 with shortest
        // round-trip precision, so equal strings ⇒ equal bits here.
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn capture_report_matches_cold_exactly() {
        let wl = two_stage_wl(3);
        let cfg = base_cfg();
        let cold = simulate_fid(&wl, &cfg, &plat(), Fidelity::coarse());
        let (captured, base) = DeltaBase::capture(&wl, &cfg, &plat(), Fidelity::coarse());
        assert_reports_identical(&cold, &captured);
        assert_eq!(base.n_snapshots(), 1, "one boundary between two stages");
        let cks = base.checkpoints();
        assert_eq!(cks[0].stage, 0);
        assert!(cks[0].t_ns > 0 && cks[0].events > 0);
        assert_eq!(cks[0].fp, base.stage_fps()[0]);
    }

    #[test]
    fn stripe_perturbation_resumes_bit_identical() {
        let wl = two_stage_wl(3);
        let (_, base) = DeltaBase::capture(&wl, &base_cfg(), &plat(), Fidelity::coarse());
        for stripe in [2usize, 3, 4] {
            let neighbor = Config::partitioned(4, 4, Bytes::mb(1))
                .with_label("delta-neighbor")
                .with_stripe(stripe);
            let r = base.resume(&wl, &neighbor).expect("stage-0 prefix must match");
            let cold = simulate_fid(&wl, &neighbor, &plat(), Fidelity::coarse());
            assert_reports_identical(&cold, &r.report);
            assert_eq!(r.outcome, DeltaOutcome { stages_skipped: 1, stages_replayed: 1 });
            assert_eq!(r.checkpoints.len(), 1, "matched prefix summaries travel along");
        }
    }

    #[test]
    fn stage_fps_isolate_stripe_sensitivity() {
        let wl = two_stage_wl(2);
        let a = stage_fingerprints(&wl, &base_cfg(), &plat(), &Fidelity::coarse());
        let b = stage_fingerprints(
            &wl,
            &Config::partitioned(4, 4, Bytes::mb(1)).with_label("other").with_stripe(3),
            &plat(),
            &Fidelity::coarse(),
        );
        assert_eq!(a.len(), 2);
        assert_eq!(a[0], b[0], "stage 0 files are node-pinned — stripe-insensitive");
        assert_ne!(a[1], b[1], "stage 1 output is round-robin — stripe-sensitive");
        // The label is not part of the stage fingerprint (it only names
        // the final report).
        let relabeled = stage_fingerprints(
            &wl,
            &base_cfg().with_label("renamed"),
            &plat(),
            &Fidelity::coarse(),
        );
        assert_eq!(a, relabeled);
    }

    #[test]
    fn changed_fault_plan_invalidates_the_whole_prefix() {
        let wl = two_stage_wl(2);
        let (_, base) = DeltaBase::capture(&wl, &base_cfg(), &plat(), Fidelity::coarse());
        let faulty = base_cfg().with_fault_plan(FaultPlan::parse("crash=1@2").unwrap());
        assert!(base.resume(&wl, &faulty).is_none(), "a changed plan must fall back to cold");
        // And the reverse: a base captured *with* the plan rejects the
        // plan-free neighbor.
        let (_, fbase) = DeltaBase::capture(&wl, &faulty, &plat(), Fidelity::coarse());
        assert!(fbase.resume(&wl, &base_cfg()).is_none());
    }

    #[test]
    fn changed_global_knob_invalidates_the_whole_prefix() {
        let wl = two_stage_wl(2);
        let (_, base) = DeltaBase::capture(&wl, &base_cfg(), &plat(), Fidelity::coarse());
        let wider =
            Config::partitioned(4, 5, Bytes::mb(1)).with_label("delta-base").with_stripe(1);
        assert!(base.resume(&wl, &wider).is_none(), "n_storage is read from the first event on");
    }

    #[test]
    fn changed_topology_perturbs_every_stage_fingerprint() {
        let wl = two_stage_wl(2);
        let star = stage_fingerprints(&wl, &base_cfg(), &plat(), &Fidelity::coarse());
        let mut rack_plat = plat();
        rack_plat.topology = Topology::Rack { rack_size: 2, oversub: 4.0 };
        let rack = stage_fingerprints(&wl, &base_cfg(), &rack_plat, &Fidelity::coarse());
        for (s, (a, b)) in star.iter().zip(rack.iter()).enumerate() {
            assert_ne!(a, b, "stage {s} fingerprint must observe the topology");
        }
        let mut other_rack = plat();
        other_rack.topology = Topology::Rack { rack_size: 2, oversub: 8.0 };
        let other = stage_fingerprints(&wl, &base_cfg(), &other_rack, &Fidelity::coarse());
        assert_ne!(rack[0], other[0], "oversubscription ratio is part of the point");
    }

    #[test]
    fn changed_workload_invalidates_the_whole_prefix() {
        let wl = two_stage_wl(2);
        let (_, base) = DeltaBase::capture(&wl, &base_cfg(), &plat(), Fidelity::coarse());
        let other = two_stage_wl(3);
        assert!(
            base.resume(&other, &base_cfg()).is_none(),
            "a different DAG must never splice the base's state"
        );
    }

    #[test]
    fn faulty_base_resumes_bit_identical_when_plan_is_shared() {
        // Same fault plan on both sides: the prefix matches and the
        // degraded-mode suffix replays under the neighbor's stripe.
        let wl = two_stage_wl(3);
        let plan = FaultPlan::parse("seed=7;crash=2@30").unwrap();
        let cfg_a = base_cfg().with_fault_plan(plan.clone());
        let (_, base) = DeltaBase::capture(&wl, &cfg_a, &plat(), Fidelity::coarse());
        let neighbor = Config::partitioned(4, 4, Bytes::mb(1))
            .with_label("delta-neighbor")
            .with_stripe(2)
            .with_fault_plan(plan);
        if let Some(r) = base.resume(&wl, &neighbor) {
            let cold = simulate_fid(&wl, &neighbor, &plat(), Fidelity::coarse());
            assert_reports_identical(&cold, &r.report);
        }
    }
}
