//! The simulation world: instantiates the queue-based model (paper Fig 2)
//! for a (workload, config, platform) triple and runs it to completion.
//!
//! Every host owns a NIC modeled as an out-queue and an in-queue service;
//! messages are fragmented into frames at the out-queue, cross the core
//! with a latency, are reassembled after the in-queue, and are then handed
//! to the destination component's own queue. Manager, storage and client
//! components are FIFO single-server stations with service times from the
//! [`Platform`] (system identification). The application driver
//! (`driver.rs`) feeds client queues by replaying the workload DAG.
//!
//! ## Network fast path (bulk frame trains)
//!
//! Under [`Fidelity::frame_aggregation`] (the predictor's default) a
//! message's whole frame train is serviced as **one** analytically-drained
//! entry per NIC station — O(1) scheduler events per message instead of
//! O(n_frames) — with the pipelined overlap between the two NICs
//! preserved: the train "arrives" at the destination one frame-service
//! after it starts transmitting (cut-through), exactly when the per-frame
//! path would deliver its first frame, and the in-NIC then charges the
//! full train service. Two mechanisms make the aggregation *exact* rather
//! than banded (see `sim::station` and PERF.md §Frame path):
//!
//! * **weighted-fair in-NIC service** — concurrent trains at a contended
//!   receive queue share the server with byte-proportional rates
//!   ([`FairStation`], a virtual-time GPS server: O(log m) per event in
//!   the m active trains) instead of serializing whole messages, matching
//!   the frame interleaving the per-frame path produces under incast.
//!   Each share change moves the head's completion instant, so the
//!   superseded announcement is *cancelled* at the engine
//!   (`Scheduler::at_cancellable`/`cancel`) — stale completions are
//!   counted (`SimReport::events_cancelled`), never delivered;
//! * **exact leading/last-partial-frame bookkeeping** — the short last
//!   frame of a non-frame-aligned message waits `full − last` behind its
//!   siblings on the per-frame path, which the bulk path charges
//!   analytically, so turnaround and every station integral agree for
//!   arbitrary wire sizes on uncontended paths (property-tested).
//!
//! ## Routed fabric (topologies beyond the star)
//!
//! Under [`Topology::Rack`] cross-rack transfers are routed over core
//! links — the source rack's uplink, then the destination rack's
//! downlink — each a weighted-fair station serving `rack_size /
//! oversub` host lines (see `sim::fabric`). Bulk trains cut-through
//! every hop (one leading-frame service each; path latency charged
//! once) and deliver when the *last* gating station finishes the train,
//! so routing stays O(1) events per train per hop; the per-frame path
//! store-and-forwards individual frames through FIFO link stations. The
//! star — and any rack layout that fits in a single rack — resolves to
//! an empty link set and schedules *no* link events, keeping it
//! bit-identical to the pre-fabric engine (pinned by
//! `prop_star_fabric_matches_reference` and the `fabric_topology`
//! integration suite).
//!
//! ## Degraded mode (fault injection)
//!
//! When the config carries a non-empty [`faults::FaultPlan`], the engine
//! runs the degraded-mode protocol: seeded node crashes abandon a storage
//! station's queue and silently discard later arrivals; stragglers scale a
//! host's service rate from their trigger time on; lossy links drop
//! messages by a pure per-message hash. Every in-flight chunk carries an
//! attempt number and arms a cancellable timeout
//! ([`faults::timeout_for`]); a fired timeout retries with bounded
//! exponential backoff ([`faults::backoff_delay`]) — reads fail over to
//! the next surviving replica via O(1) ring membership, writes enter the
//! replica chain at its first surviving member and forwarding skips dead
//! hops — until the attempt budget ([`faults::MAX_ATTEMPTS`]) is spent or
//! no replica survives, at which point the op is *unrecoverable*: its task
//! is abandoned at the driver and dependents never release. With an empty
//! plan none of this machinery runs — no timers, no extra RNG draws, no
//! extra events — so the fault-free path is bit-identical to the
//! pre-fault engine (pinned by `prop_empty_fault_plan_matches_baseline`).
//!
//! The per-frame path remains selectable as the equivalence reference;
//! the detailed tier can run either per-frame (`Fidelity::detailed`) or
//! aggregated with train-weighted SYN-drop/mux calibration
//! (`Fidelity::detailed_aggregated`, ~an order of magnitude cheaper
//! trials).
//!
//! ## Interned placement
//!
//! Placement decisions flow through a [`PlacementArena`]: a write
//! allocation is one interned [`AllocId`] (every policy produces a ring
//! stripe), the committed-metadata table stores that id plus a chunk
//! count, and `ChunkPut` messages carry an interned
//! [`GroupId`](crate::model::placement::GroupId) + hop index instead of
//! an owned replica-chain `Vec` — so full-stripe cluster-wide configs pay
//! O(distinct groups) placement work instead of O(n·stripe) per workload
//! (see [`crate::model::placement`] and PERF.md §Interned placement).

use crate::model::config::{Config, Placement};
use crate::model::driver::DriverState;
use crate::model::faults;
use crate::model::fidelity::Fidelity;
use crate::model::placement::{AllocId, GroupId, PlacementArena};
use crate::model::platform::{Platform, Topology};
use crate::model::proto::*;
use crate::model::report::{OpRecord, SimReport, TaskRecord, UtilReport};
use crate::sim::{
    EventToken, FabricPlan, FairStation, Scheduler, SimState, Simulation, Station, StationStats,
};
use crate::trace::{Lane, MsgTag, NoopProbe, Probe, Recorder, NO_OP};
use crate::util::rng::Rng;
use crate::util::units::{Bytes, SimTime};
use crate::workload::{FileHint, Workload};
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

/// Connection key: canonical (host, host) pair. Data-path connections are
/// pooled per host pair (as the real SAI does) and persist for the run;
/// the handshake is paid on first use and SYNs can be lost when the
/// passive side's in-NIC is deeply backlogged.
pub(crate) type ConnKey = (usize, usize);

/// State of a per-(op, host-pair) data connection (detailed fidelity).
#[derive(Clone, Debug)]
pub(crate) enum ConnState {
    /// Awaiting SYN/ACK; messages queue up. `dst` is the passive side
    /// whose in-NIC congestion governs SYN loss.
    Pending { dst: usize, buf: Vec<MsgId> },
    Up,
}

/// Decomposed service times of one frame train (see `World::train_svc`).
#[derive(Clone, Copy, Debug)]
struct TrainSvc {
    /// Exact sum of per-frame service times.
    total: SimTime,
    /// Leading frame's service time (cut-through offset).
    first: SimTime,
    /// Full-frame service time (analytic intra-train queueing unit).
    unit: SimTime,
    /// Final frame's service time (short when the wire size is not
    /// frame-aligned; equals `unit` otherwise).
    last: SimTime,
}

/// An in-NIC receive queue. The per-frame path keeps the strict FIFO of
/// individual frames; the bulk path services concurrent trains
/// weighted-fair ([`FairStation`]) so incast messages interleave like
/// their frames would instead of serializing whole trains. The fair
/// station has exactly one completion announcement outstanding at a time
/// (`pending`): an arrival changes the fair shares, so the superseded
/// event is cancelled at the engine and the new announcement scheduled in
/// its place — stale completions never reach the handler.
#[derive(Clone)]
pub(crate) enum NicIn {
    Fifo(Station<Frame>),
    Fair { st: FairStation<Frame>, pending: Option<EventToken> },
}

impl NicIn {
    /// Waiting frames (the SYN-drop / mux laws observe this depth).
    pub(crate) fn queue_len(&self) -> usize {
        match self {
            NicIn::Fifo(st) => st.queue_len(),
            NicIn::Fair { st, .. } => st.queue_len(),
        }
    }

    pub(crate) fn stats(&self) -> &StationStats {
        match self {
            NicIn::Fifo(st) => &st.stats,
            NicIn::Fair { st, .. } => &st.stats,
        }
    }

    fn finish(&mut self, now: SimTime) {
        match self {
            NicIn::Fifo(st) => st.finish(now),
            NicIn::Fair { st, .. } => st.finish(now),
        }
    }
}

/// Committed file metadata at the manager: the interned allocation plus
/// the chunk count. Chunk `i`'s replica group is derived from the
/// allocation on demand (see [`crate::model::placement`]) — the table
/// never materializes per-chunk group vectors, so committing an n-chunk
/// file over a w-wide stripe costs O(1) instead of O(n·w).
#[derive(Clone, Copy, Debug)]
pub struct FileMeta {
    pub alloc: AllocId,
    pub n_chunks: u32,
}

/// Simulation events.
#[derive(Clone, Debug)]
pub enum Ev {
    /// A frame finished service at host's out-NIC.
    NicOutDone(usize),
    /// A frame finished service at host's in-NIC (per-frame FIFO path).
    NicInDone(usize),
    /// A train finished weighted-fair service at host's in-NIC (bulk
    /// path). Only ever delivered for the live announcement: a later
    /// arrival changes the fair shares, and the superseded event is
    /// cancelled at the engine instead of firing stale.
    NicInFairDone(usize),
    /// A frame arrives at the destination host (post-latency).
    FrameArrive(usize, Frame),
    /// A frame (or bulk train) reaches a core fabric link on its route
    /// (routed topologies only; the star schedules none of these).
    LinkArrive(usize, Frame),
    /// A frame finished service at a core link (per-frame FIFO path).
    LinkDone(usize),
    /// A train finished weighted-fair service at a core link (bulk
    /// path; cancellable, like `NicInFairDone`).
    LinkFairDone(usize),
    /// A component station finished serving a message.
    CompDone(CompId),
    /// A task's dependencies are satisfied.
    Release(usize),
    /// A task's compute phase finished.
    ComputeDone(usize),
    /// Attempt (or retry) a data-connection handshake.
    ConnTry(ConnKey),
    /// Handshake completed; flush buffered messages.
    ConnUp(ConnKey),
    /// Per-target stream setup finished; open the op's chunk window
    /// (detailed fidelity only).
    OpenWindow(OpId),
    /// Storage node crashes (fault plan).
    Crash(usize),
    /// Straggler trigger: index into the plan's straggler list.
    Straggle(usize),
    /// A chunk attempt's timeout fired (degraded mode; cancelled when the
    /// matching response settles the chunk first).
    ChunkTimeout(OpId, u32, u32),
    /// Re-issue a timed-out chunk as the given attempt, after backoff.
    ChunkRetry(OpId, u32, u32),
}

/// A live chunk attempt awaiting its response: the armed timeout token
/// and the attempt number it covers (responses and timeouts of
/// superseded attempts are ignored).
#[derive(Clone, Copy, Debug)]
struct PendingChunk {
    token: EventToken,
    attempt: u32,
}

/// The probe [`Lane`] a component's service queue reports as.
fn lane_of(c: CompId) -> Lane {
    match c {
        CompId::Manager => Lane::Manager,
        CompId::Storage(s) => Lane::Storage(s as u32),
        CompId::Client(c) => Lane::Client(c as u32),
    }
}

/// The probe [`MsgTag`] describing a payload (kind + op/chunk lineage).
fn tag_of(p: &Payload) -> MsgTag {
    match *p {
        Payload::AppIssue { op } => MsgTag::ctrl("AppIssue", op),
        Payload::WriteAlloc { op } => MsgTag::ctrl("WriteAlloc", op),
        Payload::WriteAllocResp { op } => MsgTag::ctrl("WriteAllocResp", op),
        Payload::ChunkPut { op, chunk, attempt, .. } => {
            MsgTag::data("ChunkPut", op, chunk, attempt)
        }
        Payload::ChunkPutAck { op, chunk, attempt } => {
            MsgTag { kind: "ChunkPutAck", ctrl: true, op, chunk, attempt }
        }
        Payload::ChunkCommit { op } => MsgTag::ctrl("ChunkCommit", op),
        Payload::CommitAck { op } => MsgTag::ctrl("CommitAck", op),
        Payload::ReadLookup { op } => MsgTag::ctrl("ReadLookup", op),
        Payload::ReadLookupResp { op } => MsgTag::ctrl("ReadLookupResp", op),
        Payload::ChunkGet { op, chunk, attempt, .. } => {
            MsgTag { kind: "ChunkGet", ctrl: true, op, chunk, attempt }
        }
        Payload::ChunkData { op, chunk, attempt, .. } => {
            MsgTag::data("ChunkData", op, chunk, attempt)
        }
        Payload::Open { op } => MsgTag::ctrl("Open", op),
        Payload::OpenResp { op } => MsgTag::ctrl("OpenResp", op),
        Payload::Close { op } => MsgTag::ctrl("Close", op),
        Payload::CloseResp { op } => MsgTag::ctrl("CloseResp", op),
        Payload::MetaPing => MsgTag::ctrl("MetaPing", NO_OP),
    }
}

/// The model state is fully owned (`Arc`-shared inputs, value state
/// everywhere else) and `Clone`: cloning a `Simulation<World<P>>`
/// snapshots the entire simulation mid-flight. The delta re-simulation
/// path (`model/delta.rs`) captures such snapshots at stage boundaries
/// and resumes them under a neighboring config by rebinding `cfg` — see
/// [`World::rebind_config`].
#[derive(Clone)]
pub struct World<P: Probe = NoopProbe> {
    pub(crate) cfg: Arc<Config>,
    pub(crate) plat: Arc<Platform>,
    pub(crate) wl: Arc<Workload>,
    pub(crate) fid: Fidelity,
    pub(crate) rng: Rng,
    /// Per-host speed multiplier drawn per trial (heterogeneity knob).
    pub(crate) speed_mult: Vec<f64>,
    /// Data connections (detailed fidelity only).
    pub(crate) conns: HashMap<ConnKey, ConnState>,
    pub(crate) conn_retries: u64,
    /// Precomputed network service times (ns per byte) — the frame path
    /// is the simulator's hot loop (§Perf).
    ns_per_byte_remote: f64,
    ns_per_byte_local: f64,

    // Per-host NIC stations. The out-NIC stays a FIFO in both modes (the
    // per-frame path enqueues a message's frames as one contiguous burst,
    // so message-FIFO is already exact there); the in-NIC discipline
    // follows the fidelity's frame path.
    pub(crate) nic_out: Vec<Station<Frame>>,
    pub(crate) nic_in: Vec<NicIn>,
    // Routed fabric: the resolved topology plan and one station per
    // core link (same receive-discipline split as the in-NICs: fair for
    // bulk trains, FIFO for per-frame). Both are empty under the star
    // and under any rack layout that fits in one rack.
    pub(crate) fabric: FabricPlan,
    pub(crate) link_st: Vec<NicIn>,
    // Component stations.
    pub(crate) manager_st: Station<MsgId>,
    pub(crate) storage_st: Vec<Station<MsgId>>,
    pub(crate) client_st: Vec<Station<MsgId>>,

    // Message arena (messages are retired in place; ids stay stable).
    pub(crate) msgs: Vec<Msg>,

    // Manager state.
    pub(crate) meta: Vec<Option<FileMeta>>,
    pub(crate) rr_cursor: usize,
    /// Interned placement decisions: every distinct replica group and
    /// write allocation is stored once and referenced by copyable ids.
    pub(crate) placement: PlacementArena,

    // Client operation state.
    pub(crate) ops: Vec<Op>,

    // Driver state.
    pub(crate) driver: DriverState,

    // Accounting.
    pub(crate) stored: Vec<u64>,
    pub(crate) net_bytes: u64,
    /// Wire frames modeled (independent of whether they were aggregated).
    pub(crate) net_frames: u64,
    pub(crate) op_records: Vec<OpRecord>,
    pub(crate) task_records: Vec<TaskRecord>,
    /// Per-host in-NIC queue-integral over-count under bulk aggregation
    /// (ns·frames): a train posting `u` frame-units at a *busy* fair
    /// in-NIC charges its whole backlog for the full wait, where the
    /// per-frame path paces those frames in one unit-service apart —
    /// ramping the same backlog up gradually. The analytic excess,
    /// `unit · u(u−1)/2` per busy arrival, is accumulated here and
    /// subtracted when reporting `nic_qlen` (see `model/report.rs`).
    nic_in_pacing_overcount: Vec<u128>,
    /// Per-link analogue of `nic_in_pacing_overcount`: a bulk train
    /// posts its frame-units at once at a busy core link too.
    link_pacing_overcount: Vec<u128>,
    /// Routed bulk messages: remaining gating-station completions (core
    /// links on the route + the destination in-NIC) before the message
    /// is handed to its component. Star messages never enter this map.
    pending_hops: HashMap<MsgId, u32>,

    /// Tracing probe (zero-cost [`NoopProbe`] by default — its empty
    /// `#[inline(always)]` hooks monomorphize away, see `trace/`).
    pub(crate) probe: P,

    // Degraded-mode state. All of it is inert when `cfg.faults` is empty:
    // `dead` stays all-false, no timers are armed, and every counter
    // stays zero — the fault-free path is bit-identical to a build
    // without this machinery.
    pub(crate) dead: Vec<bool>,
    pending_chunks: BTreeMap<(OpId, u32), PendingChunk>,
    op_failed: Vec<bool>,
    fault_retries: u64,
    fault_failovers: u64,
    fault_timeouts: u64,
    fault_msgs_dropped: u64,
    fault_work_lost: u64,
    unrecoverable_ops: u64,
}

impl World {
    pub fn new(wl: Arc<Workload>, cfg: Arc<Config>, plat: Arc<Platform>, fid: Fidelity) -> World {
        World::with_probe(wl, cfg, plat, fid, NoopProbe)
    }
}

impl<P: Probe> World<P> {
    /// Build a world reporting into `probe` (the untraced path goes
    /// through [`World::new`], which plugs in the zero-cost [`NoopProbe`]).
    pub fn with_probe(
        wl: Arc<Workload>,
        cfg: Arc<Config>,
        plat: Arc<Platform>,
        fid: Fidelity,
        probe: P,
    ) -> World<P> {
        let h = cfg.n_hosts();
        let (n_app, n_storage) = (cfg.n_app, cfg.n_storage);
        let mut rng = Rng::new(fid.seed ^ 0x5EED_CAFE);
        let speed_mult = (0..h)
            .map(|_| {
                if fid.hetero_sigma > 0.0 {
                    rng.normal(1.0, fid.hetero_sigma).clamp(0.7, 1.3)
                } else {
                    1.0
                }
            })
            .collect();
        let aggregated = fid.frame_aggregation;
        let fabric = match plat.topology {
            Topology::Star => FabricPlan::star(),
            Topology::Rack { rack_size, oversub } => {
                FabricPlan::rack(h, rack_size, oversub, 1e9 / plat.net_remote_bps)
            }
        };
        let n_links = fabric.n_links();
        let mut w = World {
            fid,
            rng,
            speed_mult,
            conns: HashMap::new(),
            conn_retries: 0,
            ns_per_byte_remote: 1e9 / plat.net_remote_bps,
            ns_per_byte_local: 1e9 / plat.net_local_bps,
            nic_out: (0..h).map(|_| Station::new()).collect(),
            nic_in: (0..h)
                .map(|_| {
                    if aggregated {
                        NicIn::Fair { st: FairStation::new(), pending: None }
                    } else {
                        NicIn::Fifo(Station::new())
                    }
                })
                .collect(),
            fabric,
            link_st: (0..n_links)
                .map(|_| {
                    if aggregated {
                        NicIn::Fair { st: FairStation::new(), pending: None }
                    } else {
                        NicIn::Fifo(Station::new())
                    }
                })
                .collect(),
            manager_st: Station::new(),
            storage_st: (0..n_storage).map(|_| Station::new()).collect(),
            client_st: (0..n_app).map(|_| Station::new()).collect(),
            msgs: Vec::with_capacity(1024),
            meta: vec![None; wl.files.len()],
            rr_cursor: 0,
            placement: PlacementArena::new(n_storage),
            ops: Vec::with_capacity(wl.tasks.len() * 4),
            driver: DriverState::new(&wl, &cfg),
            stored: vec![0; n_storage],
            net_bytes: 0,
            net_frames: 0,
            op_records: Vec::new(),
            task_records: Vec::new(),
            nic_in_pacing_overcount: vec![0; h],
            link_pacing_overcount: vec![0; n_links],
            pending_hops: HashMap::new(),
            probe,
            dead: vec![false; n_storage],
            pending_chunks: BTreeMap::new(),
            op_failed: Vec::new(),
            fault_retries: 0,
            fault_failovers: 0,
            fault_timeouts: 0,
            fault_msgs_dropped: 0,
            fault_work_lost: 0,
            unrecoverable_ops: 0,
            cfg,
            plat,
            wl,
        };
        w.prestage_files();
        w
    }

    /// Swap in a different owned config without touching any other state.
    ///
    /// This is the delta warm-start splice point: a snapshot captured
    /// under config A is resumed under neighbor B after
    /// `model/delta.rs` has proven (via the per-stage fingerprints) that
    /// every decision taken *so far* — placement, chunking, timeouts,
    /// RNG draws — would have been identical under B, so only the
    /// not-yet-simulated suffix can observe the difference.
    pub(crate) fn rebind_config(&mut self, cfg: Arc<Config>) {
        self.cfg = cfg;
    }

    /// Commit prestaged files' metadata at t=0 (e.g., the BLAST database
    /// "already loaded in intermediate storage"). Bytes are accounted but
    /// no traffic is generated.
    fn prestage_files(&mut self) {
        let wl = self.wl.clone();
        for (fid, f) in wl.files.iter().enumerate() {
            if !f.prestaged {
                continue;
            }
            let repl = f.replication.unwrap_or(self.cfg.replication) as usize;
            let alloc = self.alloc_for(fid, None, repl);
            let n_chunks = f.size.chunks(self.cfg.chunk_size);
            for i in 0..n_chunks {
                let b = if f.size.as_u64() == 0 {
                    0
                } else {
                    let full = self.cfg.chunk_size.as_u64();
                    (f.size.as_u64() - i * full).min(full)
                };
                for k in 0..self.placement.chunk_group_len(alloc, i) {
                    let s = self.placement.chunk_member(alloc, i, k);
                    self.stored[s] += b;
                }
            }
            self.meta[fid] = Some(FileMeta { alloc, n_chunks: n_chunks as u32 });
        }
    }

    // ---------------- placement (manager policy) ----------------

    /// Interned allocation for writing `file` from `client` (None =
    /// prestage): the placement policy resolved to a ring stripe —
    /// `(start, width)` plus the replication level — and interned once.
    /// Every policy (hints included) produces a ring, so this is O(1)
    /// regardless of stripe width; per-chunk replica groups are derived
    /// from the id on demand and materialized never.
    pub(crate) fn alloc_for(&mut self, file: usize, client: Option<usize>, repl: usize) -> AllocId {
        let hint = self.wl.files[file].hint;
        let n = self.cfg.n_storage;
        let (start, width) = match hint {
            FileHint::OnNode(s) => (s % n, 1),
            FileHint::Striped => {
                let w = self.cfg.stripe_width.min(n);
                (self.next_cursor(n), w)
            }
            FileHint::Local => match client.and_then(|c| self.cfg.storage_on_client_host(c)) {
                Some(s) => (s, 1),
                // No collocated storage: fall back to one rotating node.
                None => (self.next_cursor(n), 1),
            },
            FileHint::Default => match self.cfg.placement {
                Placement::Local => match client.and_then(|c| self.cfg.storage_on_client_host(c)) {
                    Some(s) => (s, 1),
                    None => (self.next_cursor(n), 1),
                },
                Placement::RoundRobin => {
                    let w = self.cfg.stripe_width.min(n);
                    (self.next_cursor(n), w)
                }
            },
        };
        self.placement.alloc_ring(start, width, repl)
    }

    /// Next stripe start: a global round-robin cursor in the coarse model,
    /// randomized per op in the detailed one ("limited randomness in the
    /// data placement decisions" was a real-system anomaly the paper found).
    fn next_cursor(&mut self, n: usize) -> usize {
        if self.fid.random_placement {
            self.rng.below(n as u64) as usize
        } else {
            let s = self.rr_cursor % n;
            self.rr_cursor += 1;
            s
        }
    }

    /// Multiplicative service-time noise (detailed fidelity).
    pub(crate) fn jitter(&mut self) -> f64 {
        if self.fid.jitter_sigma > 0.0 {
            self.rng.normal(1.0, self.fid.jitter_sigma).clamp(0.5, 2.0)
        } else {
            1.0
        }
    }

    // ---------------- network ----------------

    pub(crate) fn host_of(&self, c: CompId) -> usize {
        match c {
            CompId::Manager => 0,
            CompId::Storage(s) => self.cfg.storage_host(s),
            CompId::Client(c) => self.cfg.client_host(c),
        }
    }

    /// Send a message. In the coarse model this fragments straight into
    /// frames; in the detailed model, data-path messages first need a
    /// per-(op, host-pair) connection, whose SYN can be lost under
    /// congestion (3 s retry).
    pub(crate) fn send(
        &mut self,
        sched: &mut Scheduler<Ev>,
        now: SimTime,
        from: CompId,
        to: CompId,
        payload: Payload,
    ) {
        let src = self.host_of(from);
        let dst = self.host_of(to);
        let local = src == dst;
        let needs_conn = self.fid.connections && !local && payload.data_path_op().is_some();
        let msg_id = self.msgs.len();
        let tag = tag_of(&payload);
        self.msgs.push(Msg { from, to, payload, local });
        self.probe.msg(msg_id, tag);

        // Lossy links (fault plan): the drop decision is a pure hash of
        // (plan seed, src, dst, msg id), so it is identical across runs
        // and thread counts. The id is consumed either way — a retry of a
        // dropped message hashes a fresh id, not the same verdict again.
        if !self.cfg.faults.links.is_empty()
            && !local
            && self.cfg.faults.drops(src, dst, now, msg_id as u64)
        {
            self.fault_msgs_dropped += 1;
            return;
        }

        if needs_conn {
            let key: ConnKey = (src.min(dst), src.max(dst));
            match self.conns.get_mut(&key) {
                Some(ConnState::Up) => self.transmit(sched, now, msg_id),
                Some(ConnState::Pending { buf, .. }) => buf.push(msg_id),
                None => {
                    self.conns.insert(key, ConnState::Pending { dst, buf: vec![msg_id] });
                    sched.at(now, Ev::ConnTry(key));
                }
            }
        } else {
            self.transmit(sched, now, msg_id);
        }
    }

    /// Frame service time on a NIC (hot path: precomputed rate, no float
    /// rounding round-trip through seconds).
    #[inline(always)]
    fn frame_svc(&self, bytes: u64, local: bool) -> SimTime {
        let nspb = if local { self.ns_per_byte_local } else { self.ns_per_byte_remote };
        SimTime((bytes as f64 * nspb) as u64)
    }

    /// Service-time decomposition of a whole frame train. `total` is the
    /// exact sum of the per-frame service times (so aggregated busy
    /// integrals match the per-frame path bit-for-bit), `first` is the
    /// leading frame's service (cut-through offset), `unit` the full-frame
    /// service used for analytic intra-train queueing, and `last` the
    /// final (possibly short, see [`Frame::tail_frame_bytes`]) frame's
    /// service — the per-frame path's last frame waits `unit − last`
    /// behind its siblings at the receive queue, which the bulk path
    /// charges analytically (exact for all wire sizes).
    #[inline(always)]
    fn train_svc(&self, frame: &Frame, local: bool) -> TrainSvc {
        let n_frames = frame.frames as u64;
        debug_assert!(n_frames >= 1);
        let cap = self.plat.frame_size.as_u64();
        let full = self.frame_svc(cap, local);
        let last = self.frame_svc(frame.tail_frame_bytes(cap), local);
        let total = SimTime(full.0 * (n_frames - 1)) + last;
        let first = if n_frames > 1 { full } else { last };
        TrainSvc { total, first, unit: full, last }
    }

    /// Schedule a train's arrival at its first post-out-NIC station, one
    /// frame-service after its out-NIC service *starts* (when the leading
    /// frame lands), preserving the per-frame path's pipelined overlap.
    /// Star and in-rack pairs land straight on the destination in-NIC —
    /// the pre-fabric path, verbatim; cross-rack pairs land on the first
    /// core link of their route (the path latency is charged once, here)
    /// and register the delivery gate over every gating station.
    fn schedule_train_arrival(
        &mut self,
        sched: &mut Scheduler<Ev>,
        start: SimTime,
        frame: Frame,
        first_svc: SimTime,
    ) {
        let msg = &self.msgs[frame.msg];
        let dst = self.host_of(msg.to);
        let src = self.host_of(msg.from);
        let lat = if msg.local { self.plat.net_latency_local } else { self.plat.net_latency };
        let route = self.fabric.route(src, dst);
        match route.first() {
            None => sched.at(start + first_svc + lat, Ev::FrameArrive(dst, frame)),
            Some(link) => {
                self.pending_hops.insert(frame.msg, route.len() as u32 + 1);
                sched.at(start + first_svc + lat, Ev::LinkArrive(link, frame));
            }
        }
    }

    /// Fragment a message into frames and enqueue at the source out-NIC —
    /// either as one bulk train (fast path) or one entry per wire frame.
    fn transmit(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, msg_id: MsgId) {
        let msg = &self.msgs[msg_id];
        let src = self.host_of(msg.from);
        let local = msg.local;
        let size = msg.payload.wire_size();
        self.net_bytes += size.as_u64();

        let frame_cap = self.plat.frame_size.as_u64();
        let total = size.as_u64().max(1);
        let n_frames = total.div_ceil(frame_cap);
        self.net_frames += n_frames;

        if self.fid.frame_aggregation {
            let frame =
                Frame { msg: msg_id, bytes: Bytes(total), frames: n_frames as u32, last: true };
            let ts = self.train_svc(&frame, local);
            self.probe.station_arrive(now, Lane::NicOut(src as u32), msg_id, ts.total);
            if let Some(t) = self.nic_out[src].arrive_train(now, frame, ts.total, n_frames, ts.unit)
            {
                sched.at(t, Ev::NicOutDone(src));
                self.schedule_train_arrival(sched, now, frame, ts.first);
            }
            // Queued trains get their arrival scheduled when they reach
            // the head of the out-NIC (see on_nic_out_done).
        } else {
            let mut left = total;
            for i in 0..n_frames {
                let b = left.min(frame_cap);
                left -= b;
                let frame =
                    Frame { msg: msg_id, bytes: Bytes(b), frames: 1, last: i == n_frames - 1 };
                let svc = self.frame_svc(b, local);
                self.probe.station_arrive(now, Lane::NicOut(src as u32), msg_id, svc);
                if let Some(t) = self.nic_out[src].arrive(now, frame, svc) {
                    sched.at(t, Ev::NicOutDone(src));
                }
            }
        }
    }

    /// Attempt a connection handshake: SYNs are dropped with a probability
    /// that grows with the passive side's in-NIC backlog — the mechanism
    /// behind the "TCP connection initiation timeout of 3s" stalls the
    /// paper reports (§5).
    fn on_conn_try(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, key: ConnKey) {
        let dst = match self.conns.get(&key) {
            Some(ConnState::Pending { dst, .. }) => *dst,
            _ => return, // already up (stale retry)
        };
        // Train-weighted calibration: under aggregation a cut-through
        // train posts its whole frame count at once where per-frame
        // pacing ramps the same backlog up gradually, so the observed
        // depth is scaled before the (frame-calibrated) SYN-drop law.
        let qlen = (self.nic_in[dst].queue_len() as f64 * self.fid.train_qlen_scale) as usize;
        let p = self.fid.syn_drop_prob(qlen);
        if p > 0.0 && self.rng.next_f64() < p {
            self.conn_retries += 1;
            sched.at(now + self.fid.conn_timeout, Ev::ConnTry(key));
        } else {
            // Handshake RTT before the stream opens.
            sched.at(now + self.plat.net_latency * 2, Ev::ConnUp(key));
        }
    }

    fn on_conn_up(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, key: ConnKey) {
        let buf = match self.conns.insert(key, ConnState::Up) {
            Some(ConnState::Pending { buf, .. }) => buf,
            _ => return,
        };
        for msg_id in buf {
            self.transmit(sched, now, msg_id);
        }
    }

    fn on_nic_out_done(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, host: usize) {
        let (frame, next) = self.nic_out[host].complete(now);
        if frame.last {
            self.probe.station_depart(now, Lane::NicOut(host as u32), frame.msg);
        }
        if let Some(t) = next {
            sched.at(t, Ev::NicOutDone(host));
            if self.fid.frame_aggregation {
                // The next train starts service now — schedule its
                // cut-through arrival at the destination.
                let nf = self.nic_out[host].in_service().copied();
                if let Some(nf) = nf {
                    let local = self.msgs[nf.msg].local;
                    let ts = self.train_svc(&nf, local);
                    self.schedule_train_arrival(sched, now, nf, ts.first);
                }
            }
        }
        if !self.fid.frame_aggregation {
            let msg = &self.msgs[frame.msg];
            let dst = self.host_of(msg.to);
            let src = self.host_of(msg.from);
            let lat = if msg.local { self.plat.net_latency_local } else { self.plat.net_latency };
            // Routed pairs store-and-forward each frame over the core
            // links; the path latency is still charged exactly once.
            match self.fabric.route(src, dst).first() {
                None => sched.at(now + lat, Ev::FrameArrive(dst, frame)),
                Some(link) => sched.at(now + lat, Ev::LinkArrive(link, frame)),
            }
        }
        // Bulk trains already had their arrival scheduled at service start.
    }

    /// [`World::train_svc`] at the core-link rate: a cross-rack hop
    /// serves frames at `rack_size / oversub` host lines (see
    /// [`FabricPlan`]). Routed messages are never loopback-local, so
    /// there is no local variant.
    #[inline(always)]
    fn link_train_svc(&self, frame: &Frame) -> TrainSvc {
        let nspb = self.fabric.ns_per_byte_link();
        let n_frames = frame.frames as u64;
        debug_assert!(n_frames >= 1);
        let cap = self.plat.frame_size.as_u64();
        let full = SimTime((cap as f64 * nspb) as u64);
        let last = SimTime((frame.tail_frame_bytes(cap) as f64 * nspb) as u64);
        let total = SimTime(full.0 * (n_frames - 1)) + last;
        let first = if n_frames > 1 { full } else { last };
        TrainSvc { total, first, unit: full, last }
    }

    /// The event that carries `frame` onward from core link `link`: the
    /// next link on its route, or the destination in-NIC.
    fn next_hop_ev(&self, link: usize, frame: Frame) -> Ev {
        let msg = &self.msgs[frame.msg];
        let dst = self.host_of(msg.to);
        let src = self.host_of(msg.from);
        match self.fabric.route(src, dst).after(link) {
            Some(next) => Ev::LinkArrive(next, frame),
            None => Ev::FrameArrive(dst, frame),
        }
    }

    /// A frame (or bulk train) reaches a core link on its route.
    fn on_link_arrive(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, link: usize, frame: Frame) {
        let ts = self.link_train_svc(&frame);
        let next_ev = self.next_hop_ev(link, frame);
        self.probe.station_arrive(now, Lane::Link(link as u32), frame.msg, ts.total);
        match &mut self.link_st[link] {
            NicIn::Fifo(st) => {
                // Per-frame path: store-and-forward — the frame moves on
                // when the link finishes serving it (on_link_done).
                if let Some(t) = st.arrive(now, frame, ts.total) {
                    sched.at(t, Ev::LinkDone(link));
                }
            }
            NicIn::Fair { st, pending } => {
                // Bulk path: the whole train shares the link weighted by
                // its wire bytes (the fair in-NIC's exact bookkeeping,
                // at the link rate) and cut-throughs into the next hop
                // one link-rate leading-frame service after arriving.
                // The train's completion *here* co-gates final delivery,
                // so a contended link delays the message even though
                // downstream stations started early.
                let tail_wait =
                    if frame.frames > 1 { ts.unit.as_ns() - ts.last.as_ns() } else { 0 };
                let weight = frame.bytes.as_u64().max(1);
                if frame.frames > 1 && st.is_busy() {
                    let u = frame.frames as u128;
                    self.link_pacing_overcount[link] +=
                        ts.unit.as_ns() as u128 * (u * (u - 1) / 2);
                }
                let t = st.arrive(now, frame, ts.total, frame.frames as u64, weight, tail_wait);
                if let Some(tok) = pending.take() {
                    let withdrawn = sched.cancel(tok);
                    debug_assert!(withdrawn, "pending link completion was already spent");
                }
                *pending = Some(sched.at_cancellable(t, Ev::LinkFairDone(link)));
                sched.at(now + ts.first, next_ev);
            }
        }
    }

    /// Per-frame path: a frame finished service at a core link.
    fn on_link_done(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, link: usize) {
        let st = match &mut self.link_st[link] {
            NicIn::Fifo(st) => st,
            NicIn::Fair { .. } => unreachable!("per-frame completion on a fair link"),
        };
        let (frame, next) = st.complete(now);
        if let Some(t) = next {
            sched.at(t, Ev::LinkDone(link));
        }
        if frame.last {
            self.probe.station_depart(now, Lane::Link(link as u32), frame.msg);
        }
        // Store-and-forward: the frame enters the next hop immediately
        // (the path latency was charged on the first hop).
        let ev = self.next_hop_ev(link, frame);
        sched.at(now, ev);
    }

    /// Bulk path: a train finished weighted-fair service at a core link.
    fn on_link_fair_done(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, link: usize) {
        let (st, pending) = match &mut self.link_st[link] {
            NicIn::Fair { st, pending } => (st, pending),
            NicIn::Fifo(_) => unreachable!("fair completion on a per-frame link"),
        };
        // This event was the live announcement; its token is now spent.
        *pending = None;
        let (frame, next) = st.complete(now);
        if let Some(t) = next {
            *pending = Some(sched.at_cancellable(t, Ev::LinkFairDone(link)));
        }
        self.probe.station_depart(now, Lane::Link(link as u32), frame.msg);
        self.deliver(sched, now, frame.msg);
    }

    /// A message finished at one of its gating stations (each core link
    /// on its route plus the destination in-NIC). Routed bulk messages
    /// deliver when their *last* gate opens — the bottleneck station
    /// sets the delivery time; star messages (never in the gate map)
    /// pass straight through to their component.
    fn deliver(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, msg_id: MsgId) {
        if let Some(left) = self.pending_hops.get_mut(&msg_id) {
            *left -= 1;
            if *left > 0 {
                return;
            }
            self.pending_hops.remove(&msg_id);
        }
        let to = self.msgs[msg_id].to;
        self.comp_arrive(sched, now, to, msg_id);
    }

    fn on_frame_arrive(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, host: usize, frame: Frame) {
        let local = self.msgs[frame.msg].local;
        let ts = if frame.frames > 1 {
            self.train_svc(&frame, local)
        } else {
            let svc = self.frame_svc(frame.bytes.as_u64(), local);
            TrainSvc { total: svc, first: svc, unit: svc, last: svc }
        };
        let mut svc = ts.total;
        // Detailed fidelity: concurrent-flow multiplexing overhead on
        // remote receive under backlog (see Fidelity::mux_eta). On the
        // bulk path the whole train is inflated once, using the
        // train-weighted (scaled) backlog its leading frame sees.
        if self.fid.mux_eta > 0.0 && !local {
            let q = self.nic_in[host].queue_len() as f64 * self.fid.train_qlen_scale;
            svc = SimTime((svc.0 as f64 * (1.0 + self.fid.mux_eta * (1.0 + q).ln())) as u64);
        }
        self.probe.station_arrive(now, Lane::NicIn(host as u32), frame.msg, svc);
        match &mut self.nic_in[host] {
            NicIn::Fifo(st) => {
                // Per-frame path: frames pace in at the service rate and
                // never wait on their siblings.
                if let Some(t) = st.arrive(now, frame, svc) {
                    sched.at(t, Ev::NicInDone(host));
                }
            }
            NicIn::Fair { st, pending } => {
                // Bulk path: the train shares the in-NIC weighted by its
                // wire bytes. Exact partial-frame bookkeeping: per-frame,
                // a short last frame arrives early (it left the out-NIC
                // after only `last` service) and waits `unit − last`
                // behind its full-sized siblings — charged analytically so
                // the waiting integral is exact for arbitrary wire sizes.
                let tail_wait =
                    if frame.frames > 1 { ts.unit.as_ns() - ts.last.as_ns() } else { 0 };
                let weight = frame.bytes.as_u64().max(1);
                // The bulk train posts all `u` frame-units at once; the
                // per-frame path would pace them in one unit-service
                // apart, so a train joining a *busy* queue over-charges
                // the queue-length integral by `unit · u(u−1)/2` (the
                // waiting ramp). An idle arrival starts service
                // immediately on both paths — no excess (the uncontended
                // exactness proptests pin this term to zero).
                if frame.frames > 1 && st.is_busy() {
                    let u = frame.frames as u128;
                    self.nic_in_pacing_overcount[host] +=
                        ts.unit.as_ns() as u128 * (u * (u - 1) / 2);
                }
                let t = st.arrive(now, frame, svc, frame.frames as u64, weight, tail_wait);
                // The new shares move the head's completion: withdraw the
                // superseded announcement and schedule the live one. The
                // token is always live here — a fired announcement clears
                // `pending` in its handler before anything else runs.
                if let Some(tok) = pending.take() {
                    let withdrawn = sched.cancel(tok);
                    debug_assert!(withdrawn, "pending fair completion was already spent");
                }
                *pending = Some(sched.at_cancellable(t, Ev::NicInFairDone(host)));
            }
        }
    }

    fn on_nic_in_done(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, host: usize) {
        let st = match &mut self.nic_in[host] {
            NicIn::Fifo(st) => st,
            NicIn::Fair { .. } => unreachable!("per-frame completion on a fair in-NIC"),
        };
        let (frame, next) = st.complete(now);
        if let Some(t) = next {
            sched.at(t, Ev::NicInDone(host));
        }
        if frame.last {
            self.probe.station_depart(now, Lane::NicIn(host as u32), frame.msg);
            // Message fully assembled: deliver it (routed bulk messages
            // additionally wait for their core-link gates to open).
            self.deliver(sched, now, frame.msg);
        }
    }

    fn on_nic_in_fair_done(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, host: usize) {
        let (st, pending) = match &mut self.nic_in[host] {
            NicIn::Fair { st, pending } => (st, pending),
            NicIn::Fifo(_) => unreachable!("fair completion on a per-frame in-NIC"),
        };
        // This event was the live announcement (stale ones are cancelled
        // at the engine and never delivered); its token is now spent.
        *pending = None;
        let (frame, next) = st.complete(now);
        if let Some(t) = next {
            *pending = Some(sched.at_cancellable(t, Ev::NicInFairDone(host)));
        }
        if frame.last {
            self.probe.station_depart(now, Lane::NicIn(host as u32), frame.msg);
            // Message fully assembled: deliver it (routed bulk messages
            // additionally wait for their core-link gates to open).
            self.deliver(sched, now, frame.msg);
        }
    }

    // ---------------- components ----------------

    /// Service time a component charges for a message (with detailed-
    /// fidelity jitter, heterogeneity and manager lock contention).
    fn comp_service(&mut self, comp: CompId, msg: MsgId) -> SimTime {
        let base = match comp {
            CompId::Manager => {
                let t = self.plat.manager_time(0);
                // Lock contention: service inflates with the backlog
                // ("unreasonable locking overheads at the manager", §5).
                let q = self.manager_st.queue_len() as f64;
                SimTime::from_secs_f64(t.as_secs_f64() * (1.0 + self.fid.manager_contention * q))
            }
            CompId::Storage(s) => {
                let host = self.cfg.storage_host(s);
                match &self.msgs[msg].payload {
                    Payload::ChunkPut { size, .. } => self.plat.storage_time(*size, true, host),
                    Payload::ChunkGet { size, .. } => self.plat.storage_time(*size, false, host),
                    _ => self.plat.storage_time(Bytes::ZERO, false, host),
                }
            }
            CompId::Client(c) => self.plat.client_time(self.cfg.client_host(c)),
        };
        let host = self.host_of(comp);
        let mult = self.jitter() / self.speed_mult[host];
        SimTime::from_secs_f64(base.as_secs_f64() * mult)
    }

    /// A message (or application op) arrives at a component's queue.
    pub(crate) fn comp_arrive(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, comp: CompId, msg: MsgId) {
        // A crashed storage node silently loses whatever reaches it; the
        // sender's chunk timeout is what notices. (`dead` is all-false
        // when the fault plan is empty.)
        if let CompId::Storage(s) = comp {
            if self.dead[s] {
                self.fault_work_lost += 1;
                return;
            }
        }
        let svc = self.comp_service(comp, msg);
        self.probe.station_arrive(now, lane_of(comp), msg, svc);
        let st = match comp {
            CompId::Manager => &mut self.manager_st,
            CompId::Storage(s) => &mut self.storage_st[s],
            CompId::Client(c) => &mut self.client_st[c],
        };
        if let Some(t) = st.arrive(now, msg, svc) {
            sched.at(t, Ev::CompDone(comp));
        }
    }

    fn on_comp_done(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, comp: CompId) {
        let st = match comp {
            CompId::Manager => &mut self.manager_st,
            CompId::Storage(s) => &mut self.storage_st[s],
            CompId::Client(c) => &mut self.client_st[c],
        };
        let (msg, next) = st.complete(now);
        if let Some(t) = next {
            sched.at(t, Ev::CompDone(comp));
        }
        self.probe.station_depart(now, lane_of(comp), msg);
        // A service that was in flight when its node crashed completes
        // without effect (the crash drained the rest of the queue, so
        // `next` is None and the station idles forever).
        if let CompId::Storage(s) = comp {
            if self.dead[s] {
                self.fault_work_lost += 1;
                return;
            }
        }
        match comp {
            CompId::Manager => self.manager_process(sched, now, msg),
            CompId::Storage(s) => self.storage_process(sched, now, s, msg),
            CompId::Client(c) => self.client_process(sched, now, c, msg),
        }
    }

    // ---------------- manager protocol ----------------

    fn manager_process(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, msg: MsgId) {
        // Payloads are plain-data `Copy` (replica chains travel as
        // interned `GroupId`s), so reading one out of the arena is free.
        let payload = self.msgs[msg].payload;
        match payload {
            Payload::WriteAlloc { op } => {
                let (client, file) = (self.ops[op].client, self.ops[op].file);
                let repl = self.wl.files[file].replication.unwrap_or(self.cfg.replication) as usize;
                // The whole allocation — stripe and replica groups — is
                // one interned id; the old path materialized O(stripe)
                // replica-group Vecs here on every write.
                let alloc = self.alloc_for(file, Some(client), repl);
                self.ops[op].alloc = Some(alloc);
                self.send(sched, now, CompId::Manager, CompId::Client(client), Payload::WriteAllocResp { op });
            }
            Payload::ChunkCommit { op } => {
                let o = &self.ops[op];
                let (client, file, n_chunks) = (o.client, o.file, o.n_chunks);
                // Commit copies the interned allocation id — O(1), where
                // the old path cloned one replica-group Vec per chunk.
                let alloc = o.alloc.expect("commit before alloc");
                self.meta[file] = Some(FileMeta { alloc, n_chunks });
                self.send(sched, now, CompId::Manager, CompId::Client(client), Payload::CommitAck { op });
                // File becomes visible: release dependents.
                self.file_committed(sched, now, file);
            }
            Payload::ReadLookup { op } => {
                let client = self.ops[op].client;
                debug_assert!(
                    self.meta[self.ops[op].file].is_some(),
                    "read of uncommitted file {} — driver bug",
                    self.wl.files[self.ops[op].file].name
                );
                self.send(sched, now, CompId::Manager, CompId::Client(client), Payload::ReadLookupResp { op });
            }
            // Detailed fidelity: FUSE-ish open/close round trips and
            // periodic allocation rounds.
            Payload::Open { op } => {
                let client = self.ops[op].client;
                self.send(sched, now, CompId::Manager, CompId::Client(client), Payload::OpenResp { op });
            }
            Payload::Close { op } => {
                let client = self.ops[op].client;
                self.send(sched, now, CompId::Manager, CompId::Client(client), Payload::CloseResp { op });
            }
            Payload::MetaPing => {} // pure manager load, no reply
            p => unreachable!("manager got {p:?}"),
        }
    }

    // ---------------- storage protocol ----------------

    fn storage_process(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, s: usize, msg: MsgId) {
        // Payloads are plain-data `Copy` (replica chains travel as
        // interned `GroupId`s), so reading one out of the arena is free.
        let payload = self.msgs[msg].payload;
        match payload {
            Payload::ChunkPut { op, chunk, size, group, hop, attempt } => {
                self.stored[s] += size.as_u64();
                let glen = self.placement.group_len(group);
                let mut next_hop = hop as usize + 1;
                // Degraded mode: forwarding skips dead hops; if no
                // replica survives downstream, the chain ends here with
                // degraded replication (`dead` is all-false fault-free,
                // so the scan is the plain `hop + 1`).
                while next_hop < glen && self.dead[self.placement.group_member(group, next_hop)] {
                    next_hop += 1;
                }
                if next_hop < glen {
                    // Chained replication: forward to the next replica,
                    // resolved from the interned group in O(1).
                    let next_s = self.placement.group_member(group, next_hop);
                    self.send(
                        sched,
                        now,
                        CompId::Storage(s),
                        CompId::Storage(next_s),
                        Payload::ChunkPut { op, chunk, size, group, hop: next_hop as u32, attempt },
                    );
                } else {
                    let client = self.ops[op].client;
                    self.send(
                        sched,
                        now,
                        CompId::Storage(s),
                        CompId::Client(client),
                        Payload::ChunkPutAck { op, chunk, attempt },
                    );
                }
            }
            Payload::ChunkGet { op, chunk, size, attempt } => {
                let client = self.ops[op].client;
                self.send(
                    sched,
                    now,
                    CompId::Storage(s),
                    CompId::Client(client),
                    Payload::ChunkData { op, chunk, size, attempt },
                );
            }
            p => unreachable!("storage got {p:?}"),
        }
    }

    // ---------------- client protocol ----------------

    fn client_process(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, c: usize, msg: MsgId) {
        // Payloads are plain-data `Copy` (replica chains travel as
        // interned `GroupId`s), so reading one out of the arena is free.
        let payload = self.msgs[msg].payload;
        match payload {
            Payload::AppIssue { op } => {
                // Detailed protocol opens the file at the manager first;
                // the coarse model goes straight to alloc/lookup ("only
                // one control message to initiate a storage function").
                let req = if self.fid.control_rounds {
                    Payload::Open { op }
                } else {
                    self.first_meta_request(op)
                };
                self.send(sched, now, CompId::Client(c), CompId::Manager, req);
            }
            Payload::OpenResp { op } => {
                let req = self.first_meta_request(op);
                self.send(sched, now, CompId::Client(c), CompId::Manager, req);
            }
            Payload::WriteAllocResp { op } | Payload::ReadLookupResp { op } => {
                // Detailed fidelity charges a stream-setup cost per
                // distinct storage target before the chunk window opens
                // (Fig 1's "connection handling and metadata access
                // overheads" at wide stripes); the coarse model opens
                // immediately.
                let setup = self.fid.per_target_setup;
                if setup > SimTime::ZERO {
                    let n_targets = self.op_distinct_targets(op) as u64;
                    sched.at(now + setup * n_targets, Ev::OpenWindow(op));
                } else {
                    self.open_window(sched, now, op);
                }
            }
            Payload::ChunkPutAck { op, chunk, attempt }
            | Payload::ChunkData { op, chunk, attempt, .. } => {
                // Degraded mode only: match the response against the live
                // attempt and disarm its timeout; stale attempts (already
                // retried) and failed ops are ignored so a chunk settles
                // exactly once. Fault-free, no timers exist and every
                // response counts.
                if !self.cfg.faults.is_empty() && !self.settle_chunk(sched, op, chunk, attempt) {
                    return;
                }
                self.probe.chunk_settle(now, op, chunk, attempt);
                self.ops[op].done += 1;
                if self.ops[op].next < self.ops[op].n_chunks {
                    self.issue_next_chunk(sched, now, op);
                } else if self.ops[op].done == self.ops[op].n_chunks {
                    match self.ops[op].kind {
                        OpKind::Write => {
                            self.send(sched, now, CompId::Client(c), CompId::Manager, Payload::ChunkCommit { op });
                        }
                        OpKind::Read => self.finish_or_close(sched, now, c, op),
                    }
                }
            }
            Payload::CommitAck { op } => self.finish_or_close(sched, now, c, op),
            Payload::CloseResp { op } => self.op_finished(sched, now, op),
            p => unreachable!("client got {p:?}"),
        }
    }

    /// Open an op's chunk window: issue the first `io_window` chunks.
    fn open_window(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, op: OpId) {
        let burst = (self.cfg.io_window as u32).min(self.ops[op].n_chunks);
        for _ in 0..burst {
            self.issue_next_chunk(sched, now, op);
        }
    }

    /// Distinct storage nodes this op will touch.
    fn op_distinct_targets(&self, op: OpId) -> usize {
        let o = &self.ops[op];
        let mut seen = [false; 64];
        let mut extra = Vec::new(); // for > 64 storage nodes
        let mut count = 0usize;
        let mut mark = |s: usize| {
            if s < 64 {
                if !seen[s] {
                    seen[s] = true;
                    count += 1;
                }
            } else if !extra.contains(&s) {
                extra.push(s);
                count += 1;
            }
        };
        match o.kind {
            OpKind::Write => {
                // Every stripe position's replica group, resolved
                // arithmetically from the interned allocation.
                if let Some(alloc) = o.alloc {
                    for j in 0..self.placement.alloc_width(alloc) {
                        for k in 0..self.placement.chunk_group_len(alloc, j as u64) {
                            mark(self.placement.chunk_member(alloc, j as u64, k));
                        }
                    }
                }
            }
            OpKind::Read => {
                // Distinct primaries over the chunks that exist: chunk i
                // maps to stripe position i % width, so the first
                // min(n_chunks, width) positions cover them all.
                if let Some(meta) = self.meta[o.file] {
                    let used = self.placement.alloc_width(meta.alloc).min(meta.n_chunks as usize);
                    for j in 0..used {
                        mark(self.placement.chunk_primary(meta.alloc, j as u64));
                    }
                }
            }
        }
        count.max(1)
    }

    /// The first metadata request of an op.
    fn first_meta_request(&self, op: OpId) -> Payload {
        match self.ops[op].kind {
            OpKind::Write => Payload::WriteAlloc { op },
            OpKind::Read => Payload::ReadLookup { op },
        }
    }

    /// Finish an op directly (coarse) or via a close round trip (detailed).
    fn finish_or_close(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, c: usize, op: OpId) {
        if self.fid.control_rounds {
            self.send(sched, now, CompId::Client(c), CompId::Manager, Payload::Close { op });
        } else {
            self.op_finished(sched, now, op);
        }
    }

    /// Issue the next chunk of an op (window flow control).
    fn issue_next_chunk(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, op: OpId) {
        let i = self.ops[op].next;
        debug_assert!(i < self.ops[op].n_chunks);
        self.ops[op].next += 1;
        // Detailed protocol touches the manager once per allocation batch
        // (non-blocking metadata round — pure manager + network load).
        if self.fid.control_rounds && i > 0 && i % self.fid.alloc_batch == 0 {
            let c = self.ops[op].client;
            self.send(sched, now, CompId::Client(c), CompId::Manager, Payload::MetaPing);
        }
        self.issue_chunk_attempt(sched, now, op, i, 0);
    }

    /// Issue one attempt of one chunk — the initial try (attempt 0) and
    /// every degraded-mode retry share this path. Under a fault plan the
    /// target selection routes around dead nodes (read failover, write
    /// chain entry at the first surviving replica) and a cancellable
    /// timeout is armed; fault-free it reduces to exactly the pre-fault
    /// issue path.
    fn issue_chunk_attempt(
        &mut self,
        sched: &mut Scheduler<Ev>,
        now: SimTime,
        op: OpId,
        chunk: u32,
        attempt: u32,
    ) {
        if self.op_failed[op] {
            return; // failed mid-burst: the window loop keeps calling
        }
        self.probe.chunk_issue(now, op, chunk, attempt);
        let faulty = !self.cfg.faults.is_empty();
        let size = self.ops[op].chunk_bytes(chunk, self.cfg.chunk_size);
        let c = self.ops[op].client;
        match self.ops[op].kind {
            OpKind::Write => {
                // The chunk's replica group is interned (lazily, once per
                // *distinct* group) so the put can carry a copyable id.
                let alloc = self.ops[op].alloc.expect("write before alloc");
                let group = self.placement.group_of(alloc, chunk as u64);
                let (target, hop) = if faulty {
                    // Re-allocation: enter the chain at its first
                    // surviving member; a fully-dead group means every
                    // replica of this chunk would be lost.
                    match self.first_alive_member(group) {
                        Some((k, s)) => {
                            if k > 0 {
                                self.fault_failovers += 1;
                            }
                            (s, k as u32)
                        }
                        None => {
                            self.fail_op(sched, now, op);
                            return;
                        }
                    }
                } else {
                    (self.placement.group_member(group, 0), 0)
                };
                self.send(
                    sched,
                    now,
                    CompId::Client(c),
                    CompId::Storage(target),
                    Payload::ChunkPut { op, chunk, size, group, hop, attempt },
                );
            }
            OpKind::Read => {
                let file = self.ops[op].file;
                let meta = self.meta[file].expect("read before commit");
                // Prefer a replica on our own host; otherwise spread
                // deterministically by (chunk, client). Both answers are
                // O(1) ring arithmetic on the interned allocation.
                let glen = self.placement.chunk_group_len(meta.alloc, chunk as u64);
                let own = self
                    .cfg
                    .storage_on_client_host(c)
                    .filter(|&s| self.placement.chunk_contains(meta.alloc, chunk as u64, s));
                let default = own.unwrap_or_else(|| {
                    self.placement.chunk_member(meta.alloc, chunk as u64, (chunk as usize + c) % glen)
                });
                let src = if faulty {
                    // Failover: first surviving replica in ring order,
                    // rotated by the attempt so consecutive retries probe
                    // different members first.
                    let alive = own.filter(|&s| !self.dead[s]).or_else(|| {
                        let start = (chunk as usize + c + attempt as usize) % glen;
                        self.placement.chunk_first_alive(meta.alloc, chunk as u64, start, &self.dead)
                    });
                    match alive {
                        Some(s) => {
                            if s != default {
                                self.fault_failovers += 1;
                            }
                            s
                        }
                        None => {
                            self.fail_op(sched, now, op);
                            return;
                        }
                    }
                } else {
                    default
                };
                self.send(
                    sched,
                    now,
                    CompId::Client(c),
                    CompId::Storage(src),
                    Payload::ChunkGet { op, chunk, size, attempt },
                );
            }
        }
        if faulty {
            let tok = sched.at_cancellable(
                now + faults::timeout_for(attempt),
                Ev::ChunkTimeout(op, chunk, attempt),
            );
            self.pending_chunks.insert((op, chunk), PendingChunk { token: tok, attempt });
        }
    }

    /// First surviving member of a replica group, as `(position, node)`.
    fn first_alive_member(&self, group: GroupId) -> Option<(usize, usize)> {
        (0..self.placement.group_len(group))
            .map(|k| (k, self.placement.group_member(group, k)))
            .find(|&(_, s)| !self.dead[s])
    }

    /// Degraded-mode bookkeeping for a chunk response: matches it against
    /// the live attempt and disarms the timeout. Returns false — the
    /// response must be ignored — for stale attempts (already retried,
    /// possibly already settled by the retry) and failed ops, so every
    /// chunk settles exactly once.
    fn settle_chunk(&mut self, sched: &mut Scheduler<Ev>, op: OpId, chunk: u32, attempt: u32) -> bool {
        if self.op_failed[op] {
            return false;
        }
        match self.pending_chunks.get(&(op, chunk)) {
            Some(p) if p.attempt == attempt => {
                let p = self.pending_chunks.remove(&(op, chunk)).expect("entry just seen");
                sched.cancel(p.token);
                true
            }
            _ => false,
        }
    }

    fn on_chunk_timeout(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, op: OpId, chunk: u32, attempt: u32) {
        // Only the live attempt's timer can fire (settled or superseded
        // timers are cancelled at the engine); check anyway.
        match self.pending_chunks.get(&(op, chunk)) {
            Some(p) if p.attempt == attempt => {}
            _ => return,
        }
        self.pending_chunks.remove(&(op, chunk));
        if self.op_failed[op] {
            return;
        }
        self.fault_timeouts += 1;
        let next = attempt + 1;
        if next >= faults::MAX_ATTEMPTS {
            self.fail_op(sched, now, op);
        } else {
            let delay = faults::backoff_delay(self.cfg.faults.seed, op, chunk, next);
            sched.at(now + delay, Ev::ChunkRetry(op, chunk, next));
        }
    }

    fn on_chunk_retry(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, op: OpId, chunk: u32, attempt: u32) {
        if self.op_failed[op] {
            return;
        }
        self.fault_retries += 1;
        self.issue_chunk_attempt(sched, now, op, chunk, attempt);
    }

    /// Declare `op` unrecoverable: every replica of a needed chunk is
    /// gone, or its retry budget is spent. Pending timers are withdrawn,
    /// late responses are ignored from here on, and the owning task is
    /// abandoned at the driver — its outputs never commit, so dependent
    /// tasks never release.
    fn fail_op(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, op: OpId) {
        if self.op_failed[op] {
            return;
        }
        self.op_failed[op] = true;
        self.unrecoverable_ops += 1;
        self.probe.op_abandoned(now, op);
        let stale: Vec<u32> = self
            .pending_chunks
            .range((op, 0)..=(op, u32::MAX))
            .map(|(&(_, chunk), _)| chunk)
            .collect();
        for chunk in stale {
            let p = self.pending_chunks.remove(&(op, chunk)).expect("pending entry vanished");
            sched.cancel(p.token);
        }
        let task = self.ops[op].task;
        self.abandon_task(sched, now, task);
    }

    fn on_crash(&mut self, now: SimTime, s: usize) {
        if self.dead[s] {
            return; // duplicate crash directive
        }
        self.dead[s] = true;
        // Queued work is abandoned; the in-service entry keeps its
        // scheduled completion, whose effect `on_comp_done` discards.
        self.fault_work_lost += self.storage_st[s].drain_waiting(now);
    }

    fn on_straggle(&mut self, idx: usize) {
        let host = self.cfg.faults.stragglers[idx].host;
        let slowdown = self.cfg.faults.stragglers[idx].slowdown;
        // Services arriving from now on are slower; in-flight ones keep
        // their scheduled completion.
        self.speed_mult[host] *= slowdown;
    }

    /// A whole-file operation completed at the client.
    fn op_finished(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, op: OpId) {
        self.probe.op_end(now, op);
        let o = &self.ops[op];
        self.op_records.push(OpRecord {
            client: o.client,
            task: o.task,
            file: o.file,
            is_write: o.kind == OpKind::Write,
            bytes: o.size,
            start: SimTime(o.started_ns),
            end: now,
        });
        let task = o.task;
        self.driver_io_done(sched, now, task);
    }

    /// Create a new client op and enqueue it at the client service.
    pub(crate) fn start_op(
        &mut self,
        sched: &mut Scheduler<Ev>,
        now: SimTime,
        kind: OpKind,
        client: usize,
        task: usize,
        file: usize,
    ) {
        let size = self.wl.files[file].size;
        let n_chunks = size.chunks(self.cfg.chunk_size) as u32;
        let op = self.ops.len();
        self.op_failed.push(false);
        self.ops.push(Op {
            kind,
            client,
            task,
            file,
            size,
            n_chunks,
            alloc: None,
            done: 0,
            next: 0,
            started_ns: now.as_ns(),
        });
        let msg_id = self.msgs.len();
        self.msgs.push(Msg {
            from: CompId::Client(client),
            to: CompId::Client(client),
            payload: Payload::AppIssue { op },
            local: true,
        });
        self.probe.msg(msg_id, MsgTag::ctrl("AppIssue", op));
        self.probe.op_start(now, op, task, client, kind == OpKind::Write, size.as_u64());
        self.comp_arrive(sched, now, CompId::Client(client), msg_id);
    }

    fn finish_report(&mut self, end: SimTime, events: u64, events_cancelled: u64) -> SimReport {
        for st in self.nic_out.iter_mut() {
            st.finish(end);
        }
        for q in self.nic_in.iter_mut() {
            q.finish(end);
        }
        for l in self.link_st.iter_mut() {
            l.finish(end);
        }
        self.manager_st.finish(end);
        for st in self.storage_st.iter_mut().chain(self.client_st.iter_mut()) {
            st.finish(end);
        }
        let cap = self.plat.node_capacity.as_u64();
        let overflows = if cap == 0 {
            0
        } else {
            self.stored.iter().filter(|&&b| b > cap).count()
        };
        let util = UtilReport {
            manager_util: self.manager_st.stats.utilization(end),
            manager_mean_qlen: self.manager_st.stats.mean_qlen(end),
            storage: self
                .storage_st
                .iter()
                .map(|s| (s.stats.utilization(end), s.stats.mean_qlen(end)))
                .collect(),
            nic: self
                .nic_out
                .iter()
                .zip(self.nic_in.iter())
                .map(|(o, i)| (o.stats.utilization(end), i.stats().utilization(end)))
                .collect(),
            nic_qlen: self
                .nic_out
                .iter()
                .zip(self.nic_in.iter())
                .zip(self.nic_in_pacing_overcount.iter())
                .map(|((o, i), &oc)| {
                    // In-NIC depth under bulk aggregation: subtract the
                    // analytic pacing over-count so the reported mean is
                    // the per-frame path's (see the field doc and
                    // `model/report.rs`).
                    (o.stats.mean_qlen(end), i.stats().mean_qlen_corrected(end, oc))
                })
                .collect(),
            links: self
                .link_st
                .iter()
                .zip(self.link_pacing_overcount.iter())
                .map(|(l, &oc)| {
                    (l.stats().utilization(end), l.stats().mean_qlen_corrected(end, oc))
                })
                .collect(),
        };
        SimReport {
            config_label: self.cfg.label.clone(),
            turnaround: end,
            ops: std::mem::take(&mut self.op_records),
            tasks: std::mem::take(&mut self.task_records),
            net_bytes: Bytes(self.net_bytes),
            net_frames: self.net_frames,
            stored: self.stored.iter().map(|&b| Bytes(b)).collect(),
            capacity_overflows: overflows,
            util,
            events,
            events_cancelled,
            conn_retries: self.conn_retries,
            fault_retries: self.fault_retries,
            fault_failovers: self.fault_failovers,
            fault_timeouts: self.fault_timeouts,
            fault_msgs_dropped: self.fault_msgs_dropped,
            fault_work_lost: self.fault_work_lost,
            unrecoverable_ops: self.unrecoverable_ops,
            failed_tasks: self.driver.failed_tasks() as u64,
        }
    }
}

impl<P: Probe> SimState for World<P> {
    type Ev = Ev;

    fn handle(&mut self, sched: &mut Scheduler<Ev>, now: SimTime, ev: Ev) {
        match ev {
            Ev::NicOutDone(h) => self.on_nic_out_done(sched, now, h),
            Ev::NicInDone(h) => self.on_nic_in_done(sched, now, h),
            Ev::NicInFairDone(h) => self.on_nic_in_fair_done(sched, now, h),
            Ev::FrameArrive(h, f) => self.on_frame_arrive(sched, now, h, f),
            Ev::LinkArrive(l, f) => self.on_link_arrive(sched, now, l, f),
            Ev::LinkDone(l) => self.on_link_done(sched, now, l),
            Ev::LinkFairDone(l) => self.on_link_fair_done(sched, now, l),
            Ev::CompDone(c) => self.on_comp_done(sched, now, c),
            Ev::Release(t) => self.driver_release(sched, now, t),
            Ev::ComputeDone(t) => self.driver_compute_done(sched, now, t),
            Ev::ConnTry(k) => self.on_conn_try(sched, now, k),
            Ev::ConnUp(k) => self.on_conn_up(sched, now, k),
            Ev::OpenWindow(op) => self.open_window(sched, now, op),
            Ev::Crash(s) => self.on_crash(now, s),
            Ev::Straggle(i) => self.on_straggle(i),
            Ev::ChunkTimeout(op, chunk, a) => self.on_chunk_timeout(sched, now, op, chunk, a),
            Ev::ChunkRetry(op, chunk, a) => self.on_chunk_retry(sched, now, op, chunk, a),
        }
    }
}

/// Run the predictor once: simulate `wl` on `cfg`/`plat` at coarse
/// fidelity (the paper's model) and report.
///
/// Panics on invalid inputs (config/workload validation errors are
/// programming errors at this level; the CLI validates earlier with
/// friendly messages).
pub fn simulate(wl: &Workload, cfg: &Config, plat: &Platform) -> SimReport {
    simulate_fid(wl, cfg, plat, Fidelity::coarse())
}

/// Run one simulation at an explicit fidelity (the testbed uses
/// `Fidelity::detailed(seed)` per trial). This is the untraced path: the
/// [`NoopProbe`]'s empty inline hooks monomorphize away, so it is the
/// exact event sequence — and the exact report, bit for bit — of the
/// engine before the probe existed (pinned by
/// `prop_noop_probe_and_recorder_are_bit_identical`).
pub fn simulate_fid(wl: &Workload, cfg: &Config, plat: &Platform, fid: Fidelity) -> SimReport {
    run_sim(wl, cfg, plat, fid, NoopProbe).0
}

/// Run one simulation with the flight recorder attached and return the
/// finished recording alongside the report. Recording cannot perturb the
/// prediction — probes observe, they never feed back — so the report is
/// identical to [`simulate_fid`]'s.
pub fn simulate_traced(
    wl: &Workload,
    cfg: &Config,
    plat: &Platform,
    fid: Fidelity,
) -> (SimReport, Recorder) {
    let (report, mut rec) = run_sim(wl, cfg, plat, fid, Recorder::new());
    rec.finish(report.turnaround);
    (report, rec)
}

/// Runaway guard shared by every run loop over a [`World`] (the plain
/// path here and the stepping capture loop in `model/delta.rs`).
pub(crate) const MAX_SIM_EVENTS: u64 = 50_000_000_000;

/// Build a ready-to-run simulation: validate, construct the world, arm
/// the fault schedule, and schedule the initial task releases. Shared
/// verbatim by the plain path ([`simulate_fid`]) and the delta
/// checkpoint-capture path (`model/delta.rs`), so both produce the exact
/// same event sequence.
pub(crate) fn prepare_sim<P: Probe>(
    wl: Arc<Workload>,
    cfg: Arc<Config>,
    plat: Arc<Platform>,
    fid: Fidelity,
    probe: P,
) -> Simulation<World<P>> {
    cfg.validate().unwrap_or_else(|e| panic!("invalid config: {e}"));
    plat.validate().unwrap_or_else(|e| panic!("invalid platform: {e}"));
    wl.validate().unwrap_or_else(|e| panic!("invalid workload: {e}"));

    let stagger = fid.stagger_mean;
    let n_tasks = wl.tasks.len();
    let faults = cfg.faults.clone();
    let mut sim = Simulation::new(World::with_probe(wl, cfg, plat, fid, probe));
    // Pre-size the event arena past the initial burst so the frame-path
    // hot loop runs entirely on recycled slots.
    sim.sched.reserve(256 + n_tasks * 4);
    // Arm the fault schedule (an empty plan schedules nothing, keeping
    // event sequence numbers — and hence same-time ordering — identical
    // to the pre-fault engine).
    if !faults.is_empty() {
        for c in &faults.crashes {
            sim.sched.at(c.at, Ev::Crash(c.storage));
        }
        for (i, s) in faults.stragglers.iter().enumerate() {
            sim.sched.at(s.at, Ev::Straggle(i));
        }
    }
    // Release initially-runnable tasks (staggered under detailed fidelity:
    // "coordination overheads make them slightly staggered", §5).
    let initial = sim.state.driver.initially_ready();
    for t in initial {
        // Workload-declared release time (richer description, §5) plus
        // the testbed's stochastic coordination stagger.
        let mut at = sim.state.wl.tasks[t].release;
        if stagger > SimTime::ZERO {
            at += SimTime::from_secs_f64(sim.state.rng.exp(stagger.as_secs_f64()));
        }
        sim.sched.at(at, Ev::Release(t));
    }
    sim
}

/// Tear a drained simulation down into its report (+ probe): checks the
/// fault-free drain invariant and finishes every station at `end`.
/// Shared by the plain path and both delta paths (capture and resume),
/// so the accounting — including the scheduler's processed/cancelled
/// totals, which a resumed clone carries over from the shared prefix —
/// is identical everywhere.
pub(crate) fn finalize_sim<P: Probe>(sim: Simulation<World<P>>, end: SimTime) -> (SimReport, P) {
    let events = sim.sched.processed();
    let cancelled = sim.sched.cancelled();
    let done = sim.state.driver.finished_tasks();
    // Under a fault plan, unrecoverable ops legitimately strand their
    // task (and its dependents); fault-free, an undrained workload is a
    // deadlock bug.
    if sim.state.cfg.faults.is_empty() {
        assert_eq!(
            done,
            sim.state.wl.tasks.len(),
            "simulation drained with {done}/{} tasks finished — workload deadlock (config {})",
            sim.state.wl.tasks.len(),
            sim.state.cfg.label
        );
    }
    let mut state = sim.state;
    let report = state.finish_report(end, events, cancelled);
    (report, state.probe)
}

/// The engine entry point, generic over the probe: validate, arm the
/// fault schedule, release the initial tasks, run to completion, and
/// hand back the report plus the probe (so recording probes can be
/// harvested).
fn run_sim<P: Probe>(
    wl: &Workload,
    cfg: &Config,
    plat: &Platform,
    fid: Fidelity,
    probe: P,
) -> (SimReport, P) {
    // The world owns its inputs (so mid-flight snapshots are 'static and
    // cloneable); one clone per simulation is noise next to the run itself.
    let mut sim = prepare_sim(
        Arc::new(wl.clone()),
        Arc::new(cfg.clone()),
        Arc::new(plat.clone()),
        fid,
        probe,
    );
    let end = sim.run_capped(MAX_SIM_EVENTS);
    finalize_sim(sim, end)
}
