//! Platform characterization: the service times produced by system
//! identification (paper §2.5) plus deployment-wide constants.
//!
//! The paper seeds four parameters — μ_net (remote and loopback), μ_sm
//! (storage, per chunk byte), μ_ma (manager, per operation), μ_cli
//! (client; the paper pins T_cli = 0 and charges 0-size operations to the
//! manager) — and we keep exactly that structure. Presets encode the
//! paper's testbed (20 × Xeon E5345, 1 Gbps, RAMdisk-backed MosaStore)
//! and the what-if variants (§5 HDD discussion, §2.1 SSD/new-hardware
//! exploration).

use crate::util::units::{Bytes, SimTime};

/// Backing medium of the storage nodes; selects the storage service-time
/// model. The paper's storage service is history-free (a RAMdisk
/// assumption it calls out in §5); HDD adds a positional/seek component
/// as the "more sophisticated model of the storage service" it sketches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiskKind {
    Ram,
    Hdd,
    Ssd,
}

/// Network topology of the deployment.
///
/// The paper's testbed is a single non-blocking switch, so every
/// transfer crosses exactly one out-NIC/in-NIC station pair — the
/// [`Topology::Star`] default, and the shape every pre-fabric prediction
/// was made under. [`Topology::Rack`] models the two-tier rack + core
/// fabrics the paper could not explore (§5 "larger scales"): hosts are
/// packed into racks of `rack_size`, in-rack traffic still only crosses
/// the NIC pair, and cross-rack traffic is additionally routed over a
/// rack-uplink and a rack-downlink core link, each a weighted-fair
/// server whose capacity is `rack_size / oversub` host lines
/// (`oversub` = 1 is a non-blocking core; larger ratios model
/// oversubscription). A `Rack` that fits every host into one rack
/// degenerates to the star — bit-identically (see `sim::fabric`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Topology {
    /// Single switching domain (the paper's testbed; the default).
    Star,
    /// Two-tier rack + core with an oversubscription ratio.
    Rack {
        /// Hosts per rack (hosts `[k·rack_size, (k+1)·rack_size)` share
        /// rack `k`).
        rack_size: usize,
        /// Core oversubscription ratio: each rack's uplink/downlink
        /// carries `rack_size / oversub` host lines of bandwidth.
        oversub: f64,
    },
}

/// Everything system identification tells the simulator about the
/// deployment platform.
#[derive(Clone, Debug)]
pub struct Platform {
    pub label: String,
    /// Effective remote network throughput, bytes/s (goodput after
    /// protocol overhead — measured, not the 125 MB/s line rate).
    pub net_remote_bps: f64,
    /// Loopback throughput, bytes/s (collocated component transfers).
    pub net_local_bps: f64,
    /// One-way network latency per frame, remote.
    pub net_latency: SimTime,
    /// One-way latency, loopback.
    pub net_latency_local: SimTime,
    /// Frame size the network components fragment requests into.
    pub frame_size: Bytes,
    /// Storage service time per byte (ns/B) — μ_sm normalized by chunk
    /// size, write path.
    pub storage_ns_per_byte_write: f64,
    /// Storage service time per byte (ns/B), read path.
    pub storage_ns_per_byte_read: f64,
    /// Fixed per-request storage service time.
    pub storage_op: SimTime,
    /// Manager service time per request — μ_ma (the paper charges all
    /// 0-size-op cost here).
    pub manager_op: SimTime,
    /// Client service time per request — μ_cli (paper: T_cli := 0; we keep
    /// a small request-handling cost slot, default 0).
    pub client_op: SimTime,
    /// HDD only: average positioning time charged once per chunk request.
    pub hdd_seek: SimTime,
    /// Per-host relative speed factor (service times are divided by this;
    /// 1.0 = nominal). Indexed by host id; missing entries = 1.0. Models
    /// the paper's heterogeneous reduce node (Fig 5b).
    pub host_speed: Vec<f64>,
    /// RAMdisk capacity per storage node (the paper's large pipeline
    /// workload "does not fit in the RAMdisk"); simulation reports
    /// overflow. 0 = unlimited.
    pub node_capacity: Bytes,
    pub disk: DiskKind,
    /// Network topology (star, or routed two-tier rack + core).
    pub topology: Topology,
}

impl Platform {
    /// The paper's testbed: 1 Gbps NICs, RAMdisk-backed storage nodes,
    /// one manager + 19 dual-role machines. Numbers are what our system
    /// identification (`ident/`) measures on the real in-tree store,
    /// scaled to 1 Gbps-era hardware (see EXPERIMENTS.md §Identification).
    pub fn paper_testbed() -> Platform {
        Platform {
            label: "paper-testbed-1gbps-ramdisk".into(),
            // 1 Gbps line rate = 125 MB/s; ~94% goodput after TCP/IP
            // framing — the value an iperf-style probe reports.
            net_remote_bps: 117.5e6,
            // Loopback through the client SAI (FUSE-era user-space copies):
            // well above NIC rate but far below raw memcpy.
            net_local_bps: 600e6,
            net_latency: SimTime::from_us(90),
            net_latency_local: SimTime::from_us(12),
            frame_size: Bytes::kb(64),
            // RAMdisk + memcpy path ≈ 1.1 GB/s effective per node.
            storage_ns_per_byte_write: 0.9,
            storage_ns_per_byte_read: 0.75,
            storage_op: SimTime::from_us(60),
            manager_op: SimTime::from_us(230),
            client_op: SimTime::from_us(25),
            hdd_seek: SimTime::ZERO,
            host_speed: Vec::new(),
            // 4 GB RAM machines: ~3 GB usable as RAMdisk.
            node_capacity: Bytes::gb(3),
            disk: DiskKind::Ram,
            // One non-blocking switch (the other presets inherit this).
            topology: Topology::Star,
        }
    }

    /// §5 variant: storage nodes backed by spinning disks.
    pub fn paper_testbed_hdd() -> Platform {
        Platform {
            label: "paper-testbed-1gbps-hdd".into(),
            // 7200rpm-era SATA disk: ~85 MB/s sequential write, ~95 read.
            storage_ns_per_byte_write: 11.8,
            storage_ns_per_byte_read: 10.5,
            storage_op: SimTime::from_us(120),
            hdd_seek: SimTime::from_ms(8),
            node_capacity: Bytes::ZERO, // disks fit everything
            disk: DiskKind::Hdd,
            ..Platform::paper_testbed()
        }
    }

    /// What-if: SSD-backed storage nodes (§2.1 "what would be the
    /// performance improvement if we used SSDs?").
    pub fn paper_testbed_ssd() -> Platform {
        Platform {
            label: "paper-testbed-1gbps-ssd".into(),
            storage_ns_per_byte_write: 4.0, // ~250 MB/s SATA-2-era SSD
            storage_ns_per_byte_read: 2.0,  // ~500 MB/s
            storage_op: SimTime::from_us(80),
            node_capacity: Bytes::ZERO,
            disk: DiskKind::Ssd,
            ..Platform::paper_testbed()
        }
    }

    /// What-if: 10 GbE fabric, RAMdisk nodes.
    pub fn paper_testbed_10g() -> Platform {
        Platform {
            label: "paper-testbed-10gbps-ramdisk".into(),
            net_remote_bps: 1.17e9,
            net_latency: SimTime::from_us(25),
            ..Platform::paper_testbed()
        }
    }

    /// Speed factor for a host (1.0 when not specified).
    pub fn speed(&self, host: usize) -> f64 {
        self.host_speed.get(host).copied().unwrap_or(1.0)
    }

    /// Set one host's speed factor (builder style).
    pub fn with_host_speed(mut self, host: usize, factor: f64) -> Platform {
        if self.host_speed.len() <= host {
            self.host_speed.resize(host + 1, 1.0);
        }
        self.host_speed[host] = factor;
        self
    }

    /// Network service time for `bytes` on the wire (remote or loopback).
    pub fn net_time(&self, bytes: Bytes, local: bool) -> SimTime {
        let bps = if local { self.net_local_bps } else { self.net_remote_bps };
        SimTime::from_secs_f64(bytes.as_f64() / bps)
    }

    /// Storage service time for a chunk request of `bytes` on `host`.
    pub fn storage_time(&self, bytes: Bytes, write: bool, host: usize) -> SimTime {
        let per_byte = if write { self.storage_ns_per_byte_write } else { self.storage_ns_per_byte_read };
        let mut ns = self.storage_op.as_ns() as f64 + bytes.as_f64() * per_byte;
        if self.disk == DiskKind::Hdd {
            // History-free positional cost approximation: charge a mean
            // seek per request (the paper's model is deliberately
            // history-free; §5 discusses the accuracy cost).
            ns += self.hdd_seek.as_ns() as f64;
        }
        SimTime::from_secs_f64(ns / 1e9 / self.speed(host))
    }

    /// Manager service time per request.
    pub fn manager_time(&self, host: usize) -> SimTime {
        SimTime::from_secs_f64(self.manager_op.as_ns() as f64 / 1e9 / self.speed(host))
    }

    /// Client service time per request.
    pub fn client_time(&self, host: usize) -> SimTime {
        SimTime::from_secs_f64(self.client_op.as_ns() as f64 / 1e9 / self.speed(host))
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.net_remote_bps <= 0.0 || self.net_local_bps <= 0.0 {
            return Err("network throughput must be positive".into());
        }
        if self.frame_size.as_u64() == 0 {
            return Err("frame size must be positive".into());
        }
        if self.storage_ns_per_byte_write < 0.0 || self.storage_ns_per_byte_read < 0.0 {
            return Err("negative storage service time".into());
        }
        if self.host_speed.iter().any(|&s| s <= 0.0) {
            return Err("host speed factors must be positive".into());
        }
        if let Topology::Rack { rack_size, oversub } = self.topology {
            if rack_size == 0 {
                return Err("rack size must be at least 1".into());
            }
            if !(oversub > 0.0 && oversub.is_finite()) {
                return Err("core oversubscription ratio must be positive and finite".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for p in [
            Platform::paper_testbed(),
            Platform::paper_testbed_hdd(),
            Platform::paper_testbed_ssd(),
            Platform::paper_testbed_10g(),
        ] {
            assert!(p.validate().is_ok(), "{}", p.label);
        }
    }

    #[test]
    fn net_time_matches_throughput() {
        let p = Platform::paper_testbed();
        let t = p.net_time(Bytes(117_500_000), false);
        assert!((t.as_secs_f64() - 1.0).abs() < 1e-9);
        assert!(p.net_time(Bytes::mb(1), true) < p.net_time(Bytes::mb(1), false));
    }

    #[test]
    fn hdd_slower_than_ram() {
        let ram = Platform::paper_testbed();
        let hdd = Platform::paper_testbed_hdd();
        let b = Bytes::mb(1);
        assert!(hdd.storage_time(b, true, 1) > ram.storage_time(b, true, 1) * 5);
    }

    #[test]
    fn host_speed_scales_service() {
        let p = Platform::paper_testbed().with_host_speed(3, 2.0);
        let slow = p.storage_time(Bytes::mb(1), false, 1);
        let fast = p.storage_time(Bytes::mb(1), false, 3);
        assert!((slow.as_ns() as f64 / fast.as_ns() as f64 - 2.0).abs() < 0.01);
    }

    #[test]
    fn bad_platform_rejected() {
        let mut p = Platform::paper_testbed();
        p.net_remote_bps = 0.0;
        assert!(p.validate().is_err());
        let p2 = Platform::paper_testbed().with_host_speed(1, 0.0);
        assert!(p2.validate().is_err());
    }

    #[test]
    fn presets_default_to_star() {
        for p in [
            Platform::paper_testbed(),
            Platform::paper_testbed_hdd(),
            Platform::paper_testbed_ssd(),
            Platform::paper_testbed_10g(),
        ] {
            assert_eq!(p.topology, Topology::Star, "{}", p.label);
        }
    }

    #[test]
    fn rack_topology_validates() {
        let mut p = Platform::paper_testbed();
        p.topology = Topology::Rack { rack_size: 8, oversub: 4.0 };
        assert!(p.validate().is_ok());
        p.topology = Topology::Rack { rack_size: 0, oversub: 4.0 };
        assert!(p.validate().is_err());
        p.topology = Topology::Rack { rack_size: 8, oversub: 0.0 };
        assert!(p.validate().is_err());
        p.topology = Topology::Rack { rack_size: 8, oversub: f64::INFINITY };
        assert!(p.validate().is_err());
    }
}
