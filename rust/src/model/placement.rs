//! Interned replica-group placement.
//!
//! Before this module existed, every write allocation materialized its
//! placement as `Vec<Vec<usize>>` — one heap vector per stripe position,
//! each listing that chunk's replica nodes — and the commit path cloned
//! the whole structure into the committed-metadata table, chunk by chunk.
//! On an n-wide stripe that is O(stripe) allocations per write and
//! O(n·stripe) work per workload: cheap at paper scale (20 nodes), but
//! the term that dominated full-stripe 4096-host configurations after the
//! virtual-time event core (PR 4) made the *event* cost flat — the
//! incast bench cells had to cap the stripe at 64 to isolate the event
//! core.
//!
//! The fix is that placement decisions have almost no entropy. Every
//! built-in policy — round-robin stripes, local-first, per-file
//! `OnNode`/`Striped` hints, and the randomized variant behind
//! `Fidelity::random_placement` — produces *ring* replica groups
//! `(primary + k) % n_storage` for `k < repl`, laid out over *ring*
//! stripes `(start + j) % n_storage` for `j < width`. A whole allocation
//! is therefore three integers, and a cluster has at most
//! `n_storage × distinct replication levels` distinct replica groups no
//! matter how many files are written.
//!
//! [`PlacementArena`] exploits this:
//!
//! * an **allocation** (one write's placement decision) is interned once
//!   behind a copyable [`AllocId`]; the operation state and the
//!   committed-metadata table ([`super::engine::FileMeta`]) store the id,
//!   so the commit path copies 4 bytes instead of cloning per-chunk
//!   vectors;
//! * a **replica group** is interned once behind a copyable [`GroupId`],
//!   derived lazily from its `(primary, repl)` pair the first time a
//!   protocol message actually needs to carry the chain
//!   (`Payload::ChunkPut` carries a `GroupId` + hop index, not an owned
//!   `Vec`);
//! * membership questions on the read path ("prefer a replica on our own
//!   host", distinct-target counts, location-aware scheduling) are
//!   answered arithmetically in O(1) from the ring definition without
//!   materializing anything.
//!
//! Explicit (non-ring) groups remain representable — [`explicit_group`]
//! canonicalizes ring-shaped member lists back to the interned ring id,
//! so two policy paths that coincide yield *the same* id (testable by
//! equality) — but no built-in policy produces them.
//!
//! The pre-interning materialized shape survives as [`RefPlacement`], the
//! equivalence oracle a property test drives in lockstep with the arena
//! (same role `RefFairStation` plays for the virtual-time fair server):
//! bit-identical groups, chunk maps, and membership answers across
//! policies × stripe widths × replication levels.
//!
//! [`explicit_group`]: PlacementArena::explicit_group

use std::collections::HashMap;

/// Handle to one interned replica group (a chunk's ordered replica
/// chain). Small and copyable: protocol messages carry it by value.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GroupId(u32);

impl GroupId {
    /// Arena slot index (stable for the arena's lifetime).
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Handle to one interned allocation (a whole write's placement: the
/// mapping from chunk index to replica group). Copyable; the op state
/// and the committed-metadata table store this instead of materialized
/// group vectors.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AllocId(u32);

impl AllocId {
    /// Arena slot index (stable for the arena's lifetime).
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Shape of one distinct replica group.
#[derive(Clone, Debug, PartialEq, Eq)]
enum GroupDef {
    /// Ring successors `(primary + k) % n_storage` for `k < len` — the
    /// shape every built-in policy produces (chained replication walks
    /// the storage ring).
    Ring { primary: u32, len: u32 },
    /// Explicit ordered member list (no built-in policy produces one;
    /// kept so externally described placements stay representable).
    Explicit(Box<[u32]>),
}

/// Shape of one allocation: chunk `i` maps to stripe position
/// `i % width`.
#[derive(Clone, Debug, PartialEq, Eq)]
enum AllocDef {
    /// Ring stripe: position `j`'s replica group is the ring group of
    /// primary `(start + j) % n_storage` at replication `repl`.
    Ring { start: u32, width: u32, repl: u32 },
    /// Explicit per-position groups.
    Explicit(Box<[GroupId]>),
}

/// Interning arena for replica groups and allocations.
///
/// Owned by the simulation `World` (and mirrored, in spirit, by the real
/// store's metadata manager): every placement decision made during a run
/// resolves to ids into this arena, and each distinct group or
/// allocation is stored exactly once regardless of how many chunks,
/// files, or operations share it.
#[derive(Clone, Debug)]
pub struct PlacementArena {
    n_storage: u32,
    groups: Vec<GroupDef>,
    ring_groups: HashMap<(u32, u32), GroupId>,
    explicit_groups: HashMap<Box<[u32]>, GroupId>,
    allocs: Vec<AllocDef>,
    ring_allocs: HashMap<(u32, u32, u32), AllocId>,
    explicit_allocs: HashMap<Box<[GroupId]>, AllocId>,
}

impl PlacementArena {
    /// An arena over `n_storage` storage nodes (the ring modulus; fixed
    /// for the arena's lifetime).
    pub fn new(n_storage: usize) -> PlacementArena {
        PlacementArena {
            n_storage: n_storage as u32,
            groups: Vec::new(),
            ring_groups: HashMap::new(),
            explicit_groups: HashMap::new(),
            allocs: Vec::new(),
            ring_allocs: HashMap::new(),
            explicit_allocs: HashMap::new(),
        }
    }

    /// Ring modulus (number of storage nodes).
    pub fn n_storage(&self) -> usize {
        self.n_storage as usize
    }

    /// Distinct replica groups interned so far.
    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Distinct allocations interned so far.
    pub fn n_allocs(&self) -> usize {
        self.allocs.len()
    }

    // ---------------- groups ----------------

    /// Intern the ring group of `primary` at replication `repl`
    /// (clamped to the storage count, exactly as the materialized path
    /// clamped it). O(1) amortized; each distinct `(primary, len)` pair
    /// is stored once.
    pub fn ring_group(&mut self, primary: usize, repl: usize) -> GroupId {
        let n = self.n_storage;
        debug_assert!(n > 0, "placement over zero storage nodes");
        let primary = primary as u32 % n;
        let len = (repl as u32).clamp(1, n);
        if let Some(&id) = self.ring_groups.get(&(primary, len)) {
            return id;
        }
        let id = GroupId(self.groups.len() as u32);
        self.groups.push(GroupDef::Ring { primary, len });
        self.ring_groups.insert((primary, len), id);
        id
    }

    /// Intern an explicit ordered member list. Ring-shaped lists
    /// canonicalize to the ring id, so an override that coincides with a
    /// policy-derived group returns the *same* `GroupId`.
    pub fn explicit_group(&mut self, members: &[usize]) -> GroupId {
        assert!(!members.is_empty(), "replica group cannot be empty");
        let n = self.n_storage as usize;
        let is_ring = members
            .iter()
            .enumerate()
            .all(|(k, &m)| m == (members[0] + k) % n);
        if is_ring && members.len() <= n {
            return self.ring_group(members[0], members.len());
        }
        let key: Box<[u32]> = members.iter().map(|&m| m as u32).collect();
        if let Some(&id) = self.explicit_groups.get(&key) {
            return id;
        }
        let id = GroupId(self.groups.len() as u32);
        self.groups.push(GroupDef::Explicit(key.clone()));
        self.explicit_groups.insert(key, id);
        id
    }

    /// Number of replicas in a group.
    pub fn group_len(&self, g: GroupId) -> usize {
        match &self.groups[g.index()] {
            GroupDef::Ring { len, .. } => *len as usize,
            GroupDef::Explicit(m) => m.len(),
        }
    }

    /// The `k`-th replica of a group (0 = primary).
    pub fn group_member(&self, g: GroupId, k: usize) -> usize {
        match &self.groups[g.index()] {
            GroupDef::Ring { primary, len } => {
                debug_assert!((k as u32) < *len);
                ((*primary as usize) + k) % self.n_storage as usize
            }
            GroupDef::Explicit(m) => m[k] as usize,
        }
    }

    /// Whether storage node `s` holds a replica. O(1) for ring groups.
    pub fn group_contains(&self, g: GroupId, s: usize) -> bool {
        match &self.groups[g.index()] {
            GroupDef::Ring { primary, len } => {
                let n = self.n_storage as usize;
                s < n && ((s + n - *primary as usize) % n) < *len as usize
            }
            GroupDef::Explicit(m) => m.contains(&(s as u32)),
        }
    }

    /// Materialize the explicit replica chain — only for protocol
    /// encodings and tests; the hot paths never call this.
    pub fn materialize(&self, g: GroupId) -> Vec<usize> {
        (0..self.group_len(g)).map(|k| self.group_member(g, k)).collect()
    }

    // ---------------- allocations ----------------

    /// Intern a ring-stripe allocation: stripe position `j` is primary
    /// `(start + j) % n_storage`, each position a ring group at `repl`.
    /// Every built-in policy path funnels through here.
    pub fn alloc_ring(&mut self, start: usize, width: usize, repl: usize) -> AllocId {
        let n = self.n_storage;
        debug_assert!(n > 0, "placement over zero storage nodes");
        let start = start as u32 % n;
        let width = (width as u32).clamp(1, n);
        let repl = (repl as u32).clamp(1, n);
        if let Some(&id) = self.ring_allocs.get(&(start, width, repl)) {
            return id;
        }
        let id = AllocId(self.allocs.len() as u32);
        self.allocs.push(AllocDef::Ring { start, width, repl });
        self.ring_allocs.insert((start, width, repl), id);
        id
    }

    /// Intern an allocation from explicit per-position groups. Like the
    /// ring path, each distinct group sequence is stored exactly once.
    pub fn alloc_explicit(&mut self, groups: &[GroupId]) -> AllocId {
        assert!(!groups.is_empty(), "allocation cannot be empty");
        let key: Box<[GroupId]> = groups.into();
        if let Some(&id) = self.explicit_allocs.get(&key) {
            return id;
        }
        let id = AllocId(self.allocs.len() as u32);
        self.allocs.push(AllocDef::Explicit(key.clone()));
        self.explicit_allocs.insert(key, id);
        id
    }

    /// Stripe width (number of stripe positions) of an allocation.
    pub fn alloc_width(&self, a: AllocId) -> usize {
        match &self.allocs[a.index()] {
            AllocDef::Ring { width, .. } => *width as usize,
            AllocDef::Explicit(g) => g.len(),
        }
    }

    /// Replica group of chunk `i` — interned lazily on first use (this
    /// is the only allocation-path operation that may insert, and it
    /// inserts at most once per *distinct* group, not per chunk).
    pub fn group_of(&mut self, a: AllocId, chunk: u64) -> GroupId {
        // Resolve the def to owned data first so the lazy intern below
        // can take `&mut self` without fighting the arena borrow.
        let ring = match &self.allocs[a.index()] {
            &AllocDef::Ring { start, width, repl } => Ok((start, width, repl)),
            AllocDef::Explicit(g) => Err(g[(chunk % g.len() as u64) as usize]),
        };
        match ring {
            Ok((start, width, repl)) => {
                let primary = (start as u64 + chunk % width as u64) % self.n_storage as u64;
                self.ring_group(primary as usize, repl as usize)
            }
            Err(gid) => gid,
        }
    }

    /// Replicas in chunk `i`'s group, without interning.
    pub fn chunk_group_len(&self, a: AllocId, chunk: u64) -> usize {
        match &self.allocs[a.index()] {
            AllocDef::Ring { repl, .. } => *repl as usize,
            AllocDef::Explicit(g) => self.group_len(g[(chunk % g.len() as u64) as usize]),
        }
    }

    /// The `k`-th replica of chunk `i`'s group, without interning.
    pub fn chunk_member(&self, a: AllocId, chunk: u64, k: usize) -> usize {
        match &self.allocs[a.index()] {
            &AllocDef::Ring { start, width, .. } => {
                let n = self.n_storage as u64;
                ((start as u64 + chunk % width as u64 + k as u64) % n) as usize
            }
            AllocDef::Explicit(g) => self.group_member(g[(chunk % g.len() as u64) as usize], k),
        }
    }

    /// Primary replica of chunk `i`.
    pub fn chunk_primary(&self, a: AllocId, chunk: u64) -> usize {
        self.chunk_member(a, chunk, 0)
    }

    /// Whether node `s` holds a replica of chunk `i`. O(1) — this is the
    /// read path's "prefer a replica on our own host" test.
    pub fn chunk_contains(&self, a: AllocId, chunk: u64, s: usize) -> bool {
        match &self.allocs[a.index()] {
            &AllocDef::Ring { start, width, repl } => {
                let n = self.n_storage as usize;
                if s >= n {
                    return false;
                }
                let primary = (start as usize + (chunk % width as u64) as usize) % n;
                ((s + n - primary) % n) < repl as usize
            }
            AllocDef::Explicit(g) => self.group_contains(g[(chunk % g.len() as u64) as usize], s),
        }
    }

    /// Degraded-mode failover scan: the first member of chunk `i`'s
    /// replica group that is not `dead`, probing ring positions
    /// `start_k, start_k+1, …` (mod group length). Each probe is the O(1)
    /// ring arithmetic of [`chunk_member`](Self::chunk_member); `None`
    /// means every replica of the chunk is lost.
    pub fn chunk_first_alive(
        &self,
        a: AllocId,
        chunk: u64,
        start_k: usize,
        dead: &[bool],
    ) -> Option<usize> {
        let glen = self.chunk_group_len(a, chunk);
        (0..glen).map(|d| self.chunk_member(a, chunk, (start_k + d) % glen)).find(|&s| !dead[s])
    }
}

/// The pre-interning materialized placement shape, retained as the
/// equivalence oracle (the same role [`crate::sim::RefFairStation`]
/// plays for the virtual-time fair server): it computes replica groups,
/// stripe targets, and per-chunk commit maps exactly the way the engine
/// did before the arena existed — eager `Vec<Vec<usize>>`s — so a
/// property test can drive both shapes in lockstep over
/// policies × stripe widths × replication levels and demand
/// bit-identical groups, chunk maps, and membership answers.
#[derive(Clone, Copy, Debug)]
pub struct RefPlacement {
    pub n_storage: usize,
}

impl RefPlacement {
    /// Replica group for a primary: ring successors on the storage set
    /// (verbatim the old `World::replica_group`).
    pub fn replica_group(&self, primary: usize, repl: usize) -> Vec<usize> {
        let n = self.n_storage;
        (0..repl.clamp(1, n)).map(|k| (primary + k) % n).collect()
    }

    /// Stripe targets of a ring allocation (verbatim the old
    /// round-robin arm of `World::stripe_targets_for`).
    pub fn stripe_targets(&self, start: usize, width: usize) -> Vec<usize> {
        let n = self.n_storage;
        let w = width.clamp(1, n);
        (0..w).map(|k| (start + k) % n).collect()
    }

    /// The materialized per-position groups of one allocation (verbatim
    /// the old `WriteAlloc` handler body).
    pub fn alloc_groups(&self, start: usize, width: usize, repl: usize) -> Vec<Vec<usize>> {
        self.stripe_targets(start, width)
            .iter()
            .map(|&p| self.replica_group(p, repl))
            .collect()
    }

    /// The materialized per-chunk commit map (verbatim the old
    /// `ChunkCommit` handler body).
    pub fn chunk_groups(&self, groups: &[Vec<usize>], n_chunks: u64) -> Vec<Vec<usize>> {
        (0..n_chunks)
            .map(|i| groups[i as usize % groups.len()].clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_groups_intern_once() {
        let mut a = PlacementArena::new(5);
        let g1 = a.ring_group(2, 3);
        let g2 = a.ring_group(2, 3);
        assert_eq!(g1, g2, "same (primary, repl) pair, same id");
        assert_eq!(a.n_groups(), 1);
        assert_eq!(a.materialize(g1), vec![2, 3, 4]);
        let g3 = a.ring_group(4, 2);
        assert_ne!(g1, g3);
        assert_eq!(a.materialize(g3), vec![4, 0], "ring wraps the storage set");
    }

    #[test]
    fn chunk_first_alive_skips_dead_members_in_ring_order() {
        let mut a = PlacementArena::new(5);
        let alloc = a.alloc_ring(0, 5, 3);
        // Chunk 0's replica group is {0, 1, 2}.
        let mut dead = vec![false; 5];
        assert_eq!(a.chunk_first_alive(alloc, 0, 0, &dead), Some(0));
        dead[0] = true;
        assert_eq!(a.chunk_first_alive(alloc, 0, 0, &dead), Some(1), "failover to next replica");
        dead[1] = true;
        assert_eq!(a.chunk_first_alive(alloc, 0, 0, &dead), Some(2));
        assert_eq!(a.chunk_first_alive(alloc, 0, 2, &dead), Some(2), "offset start wraps");
        dead[2] = true;
        assert_eq!(a.chunk_first_alive(alloc, 0, 0, &dead), None, "all replicas lost");
        // Other chunks' groups are unaffected by those deaths.
        assert_eq!(a.chunk_first_alive(alloc, 3, 0, &dead), Some(3));
    }

    #[test]
    fn replication_clamped_to_storage_count() {
        let mut a = PlacementArena::new(3);
        let g = a.ring_group(1, 10);
        assert_eq!(a.group_len(g), 3);
        assert_eq!(a.materialize(g), vec![1, 2, 0]);
    }

    #[test]
    fn membership_is_exact() {
        let mut a = PlacementArena::new(7);
        let g = a.ring_group(5, 3); // {5, 6, 0}
        for s in 0..7 {
            assert_eq!(
                a.group_contains(g, s),
                [5, 6, 0].contains(&s),
                "membership of node {s}"
            );
        }
        assert!(!a.group_contains(g, 7), "out-of-range node is never a member");
    }

    #[test]
    fn explicit_ring_shaped_group_canonicalizes_to_ring_id() {
        let mut a = PlacementArena::new(6);
        let ring = a.ring_group(4, 3); // {4, 5, 0}
        let explicit = a.explicit_group(&[4, 5, 0]);
        assert_eq!(ring, explicit, "ring-shaped override coincides with the policy id");
        assert_eq!(a.n_groups(), 1);
        let scattered = a.explicit_group(&[1, 4]);
        assert_ne!(ring, scattered);
        assert_eq!(a.materialize(scattered), vec![1, 4]);
        assert!(a.group_contains(scattered, 4) && !a.group_contains(scattered, 2));
    }

    #[test]
    fn alloc_chunk_map_wraps_stripe() {
        let mut a = PlacementArena::new(4);
        let al = a.alloc_ring(2, 3, 2);
        assert_eq!(a.alloc_width(al), 3);
        // Chunks walk the stripe positions cyclically: 2, 3, 0, 2, 3, …
        assert_eq!(a.chunk_primary(al, 0), 2);
        assert_eq!(a.chunk_primary(al, 1), 3);
        assert_eq!(a.chunk_primary(al, 2), 0);
        assert_eq!(a.chunk_primary(al, 3), 2);
        assert_eq!(a.chunk_member(al, 1, 1), 0, "replica ring wraps too");
        assert!(a.chunk_contains(al, 1, 3) && a.chunk_contains(al, 1, 0));
        assert!(!a.chunk_contains(al, 1, 2));
        // Lazily interned group of a chunk matches the arithmetic view.
        let g = a.group_of(al, 1);
        assert_eq!(a.materialize(g), vec![3, 0]);
        assert_eq!(a.n_groups(), 1, "only the touched group got interned");
    }

    #[test]
    fn allocs_intern_once_and_groups_dedup_across_allocs() {
        let mut a = PlacementArena::new(8);
        let x = a.alloc_ring(1, 4, 2);
        let y = a.alloc_ring(1, 4, 2);
        assert_eq!(x, y);
        assert_eq!(a.n_allocs(), 1);
        // Explicit allocations intern by content too.
        let g0 = a.ring_group(1, 2);
        let g1 = a.ring_group(5, 2);
        let e1 = a.alloc_explicit(&[g0, g1]);
        let e2 = a.alloc_explicit(&[g0, g1]);
        assert_eq!(e1, e2, "same group sequence, same alloc id");
        assert_eq!(a.n_allocs(), 2);
        // A different allocation whose stripe overlaps shares group ids.
        let z = a.alloc_ring(3, 2, 2);
        let g_from_x = a.group_of(x, 2); // primary 3
        let g_from_z = a.group_of(z, 0); // primary 3
        assert_eq!(g_from_x, g_from_z, "distinct groups are stored once, arena-wide");
    }

    #[test]
    fn reference_shape_matches_arena_on_a_known_case() {
        let (n, start, width, repl, n_chunks) = (5usize, 3usize, 4usize, 2usize, 9u64);
        let mut a = PlacementArena::new(n);
        let r = RefPlacement { n_storage: n };
        let al = a.alloc_ring(start, width, repl);
        let groups = r.alloc_groups(start, width, repl);
        let chunks = r.chunk_groups(&groups, n_chunks);
        for (i, want) in chunks.iter().enumerate() {
            let gid = a.group_of(al, i as u64);
            assert_eq!(&a.materialize(gid), want, "chunk {i} group");
        }
    }
}
