//! # wfpred — predicting intermediate storage performance for workflow applications
//!
//! Full-system reproduction of Costa et al., *"Predicting Intermediate
//! Storage Performance for Workflow Applications"* (CS.DC 2013).
//!
//! The crate contains, bottom-up:
//!
//! * [`util`] — self-contained substrates (deterministic RNG, statistics
//!   with Jain's confidence-interval procedure, a mini argument parser, a
//!   JSON writer, unit helpers, a property-testing harness). The build
//!   environment is offline, so these are implemented in-tree.
//! * [`sim`] — a discrete-event simulation core: virtual clock, event
//!   queue, and FIFO single-server service stations (the "queues" of the
//!   paper's queue-based model).
//! * [`trace`] — the flight recorder: a zero-cost [`trace::Probe`]
//!   threaded through the model engine (no-op by default, bit-identical
//!   predictions), a recording probe capturing op → chunk-attempt →
//!   station-residency spans with queue-wait vs service splits,
//!   critical-path attribution that tiles `[0, turnaround]` exactly
//!   (`wfpred explain`), and Chrome trace-event output for Perfetto.
//! * [`model`] — **the paper's contribution**: the coarse queue-based
//!   model of a distributed object-based storage system (manager, storage
//!   nodes, client SAIs, per-host network in/out queues) plus the
//!   application driver that replays a workflow's I/O trace over it.
//! * [`workload`] — workload descriptions: file-dependency DAGs, the
//!   pipeline / reduce / broadcast synthetic patterns, the BLAST and
//!   Montage-like workflows, and a text trace format.
//! * [`testbed`] — a high-fidelity emulator of the *actual* system
//!   (detailed control paths, connection timeouts and retries, stagger,
//!   jitter, heterogeneity). Plays the role of the paper's 20-node
//!   MosaStore deployment; see DESIGN.md §3–4.
//! * [`store`] — a real, threaded, TCP distributed object store
//!   (manager + storage nodes + client SAI) used for real-byte runs and
//!   to seed system identification.
//! * [`ident`] — the paper's §2.5 system-identification procedure.
//! * [`predict`] — the user-facing predictor façade.
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled analytic
//!   prescreen (`artifacts/predictor.hlo.txt`).
//! * [`service`] — the prediction-serving subsystem every evaluation
//!   flows through: canonical fingerprints, a sharded in-memory LRU of
//!   predictions, an append-only on-disk store for cross-process warm
//!   starts, single-flight deduplication of concurrent identical
//!   requests, and a gated surrogate fast-path (grid interpolation with
//!   per-answer error estimates).
//! * [`search`] — configuration-space exploration: analytic prescreen →
//!   discrete-event refinement (through the service) → pareto front /
//!   scenario reports.
//! * [`coordinator`] — deterministic scoped-thread execution of
//!   independent candidate simulations (the search layers fan out
//!   through it; results stay byte-identical to sequential runs).
//! * [`bench`] — the prediction barometer behind `wfpred bench`: a
//!   declarative registry of benchmark cells
//!   (workload × platform × engine × fault-plan), a runner that emits
//!   one flat-JSON record per cell with per-cell history, and a gate DSL
//!   that localizes regressions to a named cell (see
//!   [`bench::methodology`], the compiled `rust/METHODOLOGY.md`).
//!
//! A file-level architecture guide — module map, a "life of a
//! prediction" walkthrough, and a paper-section → module
//! cross-reference — lives in `rust/README.md`; these rustdoc pages are
//! the authoritative per-module documentation (CI fails on rustdoc
//! warnings, so neither can silently rot).
//!
//! ## Quickstart
//!
//! ```no_run
//! use wfpred::prelude::*;
//!
//! let platform = Platform::paper_testbed();        // 20 nodes, 1 Gbps, RAMdisk
//! let workload = patterns::pipeline(19, PatternScale::Medium, false);
//! let config = Config::dss(19);                     // default MosaStore-like setup
//! let report = Predictor::new(platform).predict(&workload, &config);
//! println!("predicted turnaround: {}", report.turnaround);
//! ```
pub mod util;
pub mod sim;
pub mod trace;
pub mod model;
pub mod workload;
pub mod testbed;
pub mod store;
pub mod ident;
pub mod predict;
pub mod runtime;
pub mod coordinator;
pub mod service;
pub mod search;
pub mod bench;
pub mod cli;

/// Convenience re-exports of the most used public types.
pub mod prelude {
    pub use crate::model::config::{Config, Placement};
    pub use crate::model::platform::{Platform, DiskKind};
    pub use crate::predict::{Predictor, Prediction};
    pub use crate::service::{Answer, Service};
    pub use crate::testbed::{Testbed, TrialStats};
    pub use crate::workload::{patterns, patterns::PatternScale, Workload};
    pub use crate::util::units::{Bytes, SimTime};
}
