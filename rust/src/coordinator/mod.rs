//! Deterministic parallel coordination of independent simulations.
//!
//! The configuration-space search (paper §1/§3.2) evaluates many
//! (workload, config) candidates, and every candidate's `World` is fully
//! self-contained — the refinement sweep is embarrassingly parallel. This
//! module is the one place that owns threads: a work-stealing indexed map
//! over `0..n` built on `std::thread::scope`, returning results in input
//! order so parallel runs are **byte-identical** to sequential ones
//! (asserted by `tests/bulk_path.rs`). The grid `Searcher`, the
//! multi-chain `Annealer`, and the testbed's trial campaigns
//! (`Campaign::run_par` driving `Testbed::run`) all dispatch through
//! here.
//!
//! Design constraints:
//! * determinism — results are slotted by index, never by completion
//!   order, and each work item derives any seed from its index alone;
//! * zero dependencies — scoped threads + atomics from `std` only;
//! * panic transparency — a panicking worker propagates through
//!   `thread::scope`, so a failing candidate fails the sweep loudly
//!   instead of silently dropping a result.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker threads to use by default: one per available core.
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Worker threads for measurement campaigns (`Testbed::run`, CLI, bench
/// drivers): all cores, capped — campaign trials are coarse-grained
/// (whole simulations), so more workers than cores only adds scheduling
/// noise to the wallclock numbers campaigns report.
pub fn campaign_threads() -> usize {
    available_threads().clamp(1, 16)
}

/// Apply `f` to every index in `0..n` across up to `threads` scoped
/// workers and return the results in index order.
///
/// `threads <= 1` (or `n <= 1`) runs inline on the caller's thread — the
/// sequential reference path. Workers pull indices from a shared atomic
/// counter (dynamic load balancing: candidate simulations vary wildly in
/// cost), and each result lands in its own slot, so the output is
/// identical to `(0..n).map(f).collect()` whenever `f` is deterministic.
pub fn par_map_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                *slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_in_index_order() {
        let xs = par_map_indexed(100, 8, |i| i * i);
        assert_eq!(xs, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_matches_sequential() {
        let work = |i: usize| {
            // Uneven per-item cost to exercise the dynamic scheduler.
            (0..(i % 7) * 1000).fold(i as u64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64))
        };
        let seq = par_map_indexed(64, 1, work);
        let par = par_map_indexed(64, 4, work);
        assert_eq!(seq, par, "parallel sweep must be byte-identical to sequential");
    }

    #[test]
    fn degenerate_sizes() {
        assert_eq!(par_map_indexed(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(par_map_indexed(1, 4, |i| i + 1), vec![1]);
        assert!(available_threads() >= 1);
    }

    #[test]
    fn more_threads_than_items_is_fine() {
        assert_eq!(par_map_indexed(3, 64, |i| i), vec![0, 1, 2]);
    }
}
