//! Offline shim for the `anyhow` crate (the build environment has no
//! network access to crates.io). It implements exactly the subset of the
//! real API this workspace uses: [`Error`], [`Result`], the [`Context`]
//! extension trait on `Result`/`Option`, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Error values are a message plus an optional cause
//! chain rendered as `context: cause`, which is all the callers format.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`, so the blanket `From<E: std::error::Error>`
//! conversion (what makes `?` work on `io::Error` etc.) cannot overlap
//! with core's reflexive `From<T> for T`.

use std::fmt;

/// A catch-all error: rendered message with its cause chain flattened in.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything printable (what `anyhow!` expands to).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap with higher-level context, mirroring `anyhow::Error::context`.
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Include the source chain the way `{:#}` would print it.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg = format!("{msg}: {s}");
            src = s.source();
        }
        Error { msg }
    }
}

/// `Result` defaulting to [`Error`], as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let n: u32 = s.parse()?; // From<ParseIntError>
        ensure!(n < 100, "too big: {n}");
        Ok(n)
    }

    #[test]
    fn conversions_and_macros() {
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
        assert!(parse("500").unwrap_err().to_string().contains("too big"));
        let e: Error = anyhow!("code {}", 3);
        assert_eq!(e.to_string(), "code 3");
    }

    #[test]
    fn context_chains() {
        let r: Result<()> = Err(anyhow!("inner")).context("outer");
        assert_eq!(r.unwrap_err().to_string(), "outer: inner");
        let o: Option<u32> = None;
        assert!(o.context("missing").is_err());
        let o2: Option<u32> = Some(1);
        assert_eq!(o2.with_context(|| "unused").unwrap(), 1);
    }
}
