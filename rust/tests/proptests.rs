//! Property-based tests over the predictor's invariants, using the
//! in-tree `util::prop` harness (replay any failure with the reported
//! seed via `WFPRED_PROP_SEED`).

use wfpred::model::{simulate, simulate_fid, Config, Fidelity, Placement, Platform};
use wfpred::util::prop::{check, Gen};
use wfpred::util::units::{Bytes, SimTime};
use wfpred::workload::patterns::{broadcast, pipeline, reduce, PatternScale};
use wfpred::workload::{trace, FileHint, FileSpec, TaskSpec, Workload};

/// A random but valid (acyclic, single-writer) workload.
fn random_workload(g: &mut Gen, max_stage_tasks: usize) -> Workload {
    let mut wl = Workload::new("prop");
    let stages = g.usize(1, 3);
    let mut prev_outputs: Vec<usize> = Vec::new();
    for s in 0..stages {
        let tasks = g.usize(1, max_stage_tasks);
        let mut outs = Vec::new();
        for t in 0..tasks {
            let mut task = TaskSpec::new(format!("t{s}.{t}"), s as u32);
            // Read 0-2 files from the previous stage (or prestaged inputs).
            if prev_outputs.is_empty() {
                let f = wl.add_file(
                    FileSpec::new(format!("in{s}.{t}"), Bytes::mb(g.u64(0, 64))).prestaged(),
                );
                task = task.reads(f);
            } else {
                for _ in 0..g.usize(1, 2.min(prev_outputs.len())) {
                    let f = *g.choose(&prev_outputs);
                    if !task.reads.contains(&f) {
                        task = task.reads(f);
                    }
                }
            }
            let hint = match g.u64(0, 2) {
                0 => FileHint::Default,
                1 => FileHint::Local,
                _ => FileHint::OnNode(g.usize(0, 3)),
            };
            let out =
                wl.add_file(FileSpec::new(format!("f{s}.{t}"), Bytes::mb(g.u64(0, 64))).hint(hint));
            task = task.writes(out).compute(SimTime::from_ms(g.u64(0, 500)));
            outs.push(out);
            wl.add_task(task);
        }
        prev_outputs = outs;
    }
    wl
}

fn random_config(g: &mut Gen) -> Config {
    let n = g.usize(2, 8);
    let mut cfg = if g.bool() { Config::dss(n) } else { Config::wass(n) };
    cfg.stripe_width = g.usize(1, n);
    cfg.replication = g.u64(1, 2.min(n as u64)) as u32;
    cfg.chunk_size = Bytes::kb(*g.choose(&[64, 256, 1024, 4096]));
    cfg.io_window = g.usize(1, 16);
    if g.bool() {
        cfg.placement = Placement::RoundRobin;
    }
    cfg
}

#[test]
fn prop_simulation_terminates_and_accounts_bytes() {
    check("termination + conservation", 60, |g| {
        let wl = random_workload(g, 4);
        if wl.validate().is_err() {
            return; // generator produced a degenerate case; skip
        }
        let cfg = random_config(g);
        let plat = Platform::paper_testbed();
        let rep = simulate(&wl, &cfg, &plat);
        // All tasks completed.
        assert_eq!(rep.tasks.len(), wl.tasks.len());
        // Conservation: stored bytes = Σ file size × replication for every
        // materialized file (prestaged + written).
        let mut expect = 0u64;
        for (fid, f) in wl.files.iter().enumerate() {
            let written = f.prestaged || wl.writer_of(fid).is_some();
            if written {
                let r = f.replication.unwrap_or(cfg.replication) as u64;
                expect += f.size.as_u64() * r.min(cfg.n_storage as u64);
            }
        }
        assert_eq!(rep.stored_total().as_u64(), expect, "stored-bytes conservation");
        // Turnaround covers every op interval.
        for op in &rep.ops {
            assert!(op.end <= rep.turnaround);
            assert!(op.start <= op.end);
        }
    });
}

#[test]
fn prop_deterministic_same_inputs() {
    check("determinism", 25, |g| {
        let wl = random_workload(g, 3);
        if wl.validate().is_err() {
            return;
        }
        let cfg = random_config(g);
        let plat = Platform::paper_testbed();
        let a = simulate(&wl, &cfg, &plat);
        let b = simulate(&wl, &cfg, &plat);
        assert_eq!(a.turnaround, b.turnaround);
        assert_eq!(a.events, b.events);
        assert_eq!(a.net_bytes, b.net_bytes);
    });
}

#[test]
fn prop_testbed_seed_determinism() {
    check("testbed seed determinism", 15, |g| {
        let wl = random_workload(g, 3);
        if wl.validate().is_err() {
            return;
        }
        let cfg = random_config(g);
        let plat = Platform::paper_testbed();
        let seed = g.u64(0, 1 << 40);
        let a = simulate_fid(&wl, &cfg, &plat, Fidelity::detailed(seed));
        let b = simulate_fid(&wl, &cfg, &plat, Fidelity::detailed(seed));
        assert_eq!(a.turnaround, b.turnaround, "same seed, same trial");
    });
}

#[test]
fn prop_more_data_never_faster() {
    check("monotone in data size", 20, |g| {
        let n = g.usize(3, 8);
        let plat = Platform::paper_testbed();
        let cfg = Config::dss(n);
        let wl_s = pipeline(n, PatternScale::Small, false);
        let wl_m = pipeline(n, PatternScale::Medium, false);
        let t_s = simulate(&wl_s, &cfg, &plat).turnaround;
        let t_m = simulate(&wl_m, &cfg, &plat).turnaround;
        assert!(t_s <= t_m, "10x data finished faster: {t_s} vs {t_m}");
    });
}

#[test]
fn prop_faster_network_never_slower() {
    check("monotone in bandwidth", 20, |g| {
        let wl = random_workload(g, 3);
        if wl.validate().is_err() {
            return;
        }
        let cfg = random_config(g);
        let slow = Platform::paper_testbed();
        let mut fast = slow.clone();
        fast.net_remote_bps *= 4.0;
        fast.net_local_bps *= 4.0;
        let t_slow = simulate(&wl, &cfg, &slow).turnaround;
        let t_fast = simulate(&wl, &cfg, &fast).turnaround;
        assert!(t_fast <= t_slow, "faster network slowed things down: {t_fast} vs {t_slow}");
    });
}

#[test]
fn prop_replication_never_shrinks_storage() {
    check("replication storage cost", 20, |g| {
        let n = g.usize(3, 8);
        let plat = Platform::paper_testbed();
        let wl1 = broadcast(n, PatternScale::Small, 1);
        let wl2 = broadcast(n, PatternScale::Small, 2.min(n as u32));
        let cfg = Config::dss(n);
        let a = simulate(&wl1, &cfg, &plat);
        let b = simulate(&wl2, &cfg, &plat);
        assert!(b.stored_total() > a.stored_total());
    });
}

#[test]
fn prop_trace_roundtrip_random_workloads() {
    check("trace round-trip", 40, |g| {
        let wl = random_workload(g, 4);
        if wl.validate().is_err() {
            return;
        }
        let text = trace::to_text(&wl);
        let back = trace::from_text(&text).expect("parse");
        assert_eq!(back.files.len(), wl.files.len());
        assert_eq!(back.tasks.len(), wl.tasks.len());
        // Same simulation outcome from the round-tripped description.
        let cfg = Config::dss(4);
        let plat = Platform::paper_testbed();
        assert_eq!(
            simulate(&wl, &cfg, &plat).turnaround,
            simulate(&back, &cfg, &plat).turnaround,
            "round-tripped workload simulates identically"
        );
    });
}

#[test]
fn prop_stripe_width_within_bounds_always_valid() {
    check("stripe validity", 30, |g| {
        let n = g.usize(2, 10);
        let w = g.usize(1, n);
        let cfg = Config::dss(n).with_stripe(w);
        let wl = reduce(n, PatternScale::Small, false);
        let rep = simulate(&wl, &cfg, &Platform::paper_testbed());
        assert_eq!(rep.tasks.len(), wl.tasks.len());
    });
}

/// A strictly sequential single-client/single-storage chain whose wire
/// sizes are exact multiples of the 64 KB frame (chunk = k·64 KB − 1 KB of
/// control header), so the bulk fast path's cut-through timing coincides
/// with the per-frame path *exactly* — no partial-last-frame slack, no
/// cross-message contention.
fn frame_aligned_chain(g: &mut Gen) -> (Workload, Config) {
    let frame = 64 * 1024u64;
    let chunk = Bytes(frame * g.u64(2, 8) - 1024);
    let mut wl = Workload::new("aligned-chain");
    let mut prev =
        wl.add_file(FileSpec::new("in", Bytes(chunk.as_u64() * g.u64(1, 5))).prestaged());
    for i in 0..g.usize(1, 4) {
        let out = wl.add_file(FileSpec::new(format!("f{i}"), Bytes(chunk.as_u64() * g.u64(1, 5))));
        wl.add_task(TaskSpec::new(format!("t{i}"), i as u32).reads(prev).writes(out));
        prev = out;
    }
    let cfg = Config::partitioned(1, 1, chunk).with_window(1);
    (wl, cfg)
}

#[test]
fn prop_frame_aligned_aggregation_is_exact() {
    // Under frame-aligned wire sizes and zero contention, aggregation is
    // not an approximation at all: turnaround and every station integral
    // (busy time, queue-length, arrival/departure counts in frames) are
    // identical, with several-fold fewer scheduler events.
    check("aligned aggregation exact", 40, |g| {
        let (wl, cfg) = frame_aligned_chain(g);
        let plat = Platform::paper_testbed();
        let bulk = simulate_fid(&wl, &cfg, &plat, Fidelity::coarse());
        let frames = simulate_fid(&wl, &cfg, &plat, Fidelity::coarse_per_frame());

        assert_eq!(bulk.turnaround, frames.turnaround, "aligned trains shift nothing");
        assert_eq!(bulk.net_bytes, frames.net_bytes);
        assert_eq!(bulk.net_frames, frames.net_frames);
        assert!(bulk.events < frames.events, "aggregation must save events");

        // Same horizon ⇒ utilization and mean-qlen integrals must agree
        // bit-for-bit (busy_ns and qlen_ns are identical integers).
        for (h, (a, b)) in bulk.util.nic.iter().zip(frames.util.nic.iter()).enumerate() {
            assert!((a.0 - b.0).abs() < 1e-12, "host {h} out-NIC utilization");
            assert!((a.1 - b.1).abs() < 1e-12, "host {h} in-NIC utilization");
        }
        for (h, (a, b)) in bulk.util.nic_qlen.iter().zip(frames.util.nic_qlen.iter()).enumerate()
        {
            assert!((a.0 - b.0).abs() < 1e-12, "host {h} out-NIC qlen integral");
            assert!((a.1 - b.1).abs() < 1e-12, "host {h} in-NIC qlen integral");
        }
        assert!((bulk.util.manager_util - frames.util.manager_util).abs() < 1e-12);
    });
}

/// Like `frame_aligned_chain`, but with *arbitrary* chunk sizes, so wire
/// sizes land anywhere relative to the 64 KB frame and messages end in
/// partial wire frames. Still strictly sequential and uncontended: the
/// single client holds each task until its commit ack is fully processed.
fn any_size_chain(g: &mut Gen) -> (Workload, Config) {
    let chunk = Bytes(g.u64(1, 512 * 1024));
    let mut wl = Workload::new("any-size-chain");
    let mut prev =
        wl.add_file(FileSpec::new("in", Bytes(chunk.as_u64() * g.u64(1, 4))).prestaged());
    for i in 0..g.usize(1, 4) {
        let out =
            wl.add_file(FileSpec::new(format!("f{i}"), Bytes(chunk.as_u64() * g.u64(1, 4))));
        wl.add_task(TaskSpec::new(format!("t{i}"), i as u32).reads(prev).writes(out));
        prev = out;
    }
    let cfg = Config::partitioned(1, 1, chunk).with_window(1);
    (wl, cfg)
}

#[test]
fn prop_bulk_path_exact_for_any_wire_size() {
    // With exact leading/last-partial-frame bookkeeping the bulk path is
    // exact — not banded — for arbitrary wire sizes on uncontended paths:
    // a short last frame leaves the out-NIC early and waits `full − last`
    // behind its siblings at the in-NIC, which the aggregated path
    // charges analytically. Turnaround and every station integral
    // (busy, queue-length) must be identical, not merely close.
    check("partial-frame exactness", 40, |g| {
        let (wl, cfg) = any_size_chain(g);
        let plat = Platform::paper_testbed();
        let bulk = simulate_fid(&wl, &cfg, &plat, Fidelity::coarse());
        let frames = simulate_fid(&wl, &cfg, &plat, Fidelity::coarse_per_frame());

        assert_eq!(bulk.turnaround, frames.turnaround, "partial frames shift nothing");
        assert_eq!(bulk.net_bytes, frames.net_bytes);
        assert_eq!(bulk.net_frames, frames.net_frames);
        assert!(bulk.events <= frames.events, "aggregation never adds events");

        for (h, (a, b)) in bulk.util.nic.iter().zip(frames.util.nic.iter()).enumerate() {
            assert!((a.0 - b.0).abs() < 1e-12, "host {h} out-NIC utilization");
            assert!((a.1 - b.1).abs() < 1e-12, "host {h} in-NIC utilization");
        }
        for (h, (a, b)) in
            bulk.util.nic_qlen.iter().zip(frames.util.nic_qlen.iter()).enumerate()
        {
            assert!((a.0 - b.0).abs() < 1e-12, "host {h} out-NIC qlen integral");
            assert!((a.1 - b.1).abs() < 1e-12, "host {h} in-NIC qlen integral");
        }
        assert!((bulk.util.manager_util - frames.util.manager_util).abs() < 1e-12);
    });
}

#[test]
fn prop_interned_placement_matches_materialized_reference() {
    // The interning arena (`model/placement.rs`) and the retained
    // pre-interning materialized shape (`RefPlacement` — the same role
    // `RefFairStation` plays for the virtual-time fair server) implement
    // one placement policy over different representations. Drive both in
    // lockstep across policies × stripe widths × replication levels and
    // demand bit-identical replica chains, chunk maps, and membership
    // answers — the quantities that feed ChunkPut chains, the committed
    // metadata table, the read path's own-host preference, and the
    // location-aware scheduler. No tolerances.
    use wfpred::model::{PlacementArena, RefPlacement};
    check("interned placement matches reference", 120, |g| {
        let n = g.usize(1, 12);
        let mut arena = PlacementArena::new(n);
        let rp = RefPlacement { n_storage: n };
        for _ in 0..g.usize(1, 8) {
            // Every policy (round-robin stripes, local-first, OnNode /
            // Striped hints, randomized placement) resolves to a ring
            // (start, width) at some replication level — sweep them all.
            let start = g.usize(0, n - 1);
            let width = g.usize(1, n);
            let repl = g.usize(1, n);
            let n_chunks = g.u64(1, 40);
            let alloc = arena.alloc_ring(start, width, repl);
            let groups = rp.alloc_groups(start, width, repl);
            let chunks = rp.chunk_groups(&groups, n_chunks);
            assert_eq!(arena.alloc_width(alloc), groups.len(), "stripe width");
            for (i, want) in chunks.iter().enumerate() {
                let i = i as u64;
                // The materialized chain (what a ChunkPut hop walk visits).
                let gid = arena.group_of(alloc, i);
                assert_eq!(&arena.materialize(gid), want, "chunk {i} replica chain");
                // The arithmetic, never-materialized views must agree too.
                assert_eq!(arena.chunk_group_len(alloc, i), want.len(), "chunk {i} len");
                for (k, &m) in want.iter().enumerate() {
                    assert_eq!(arena.chunk_member(alloc, i, k), m, "chunk {i} member {k}");
                }
                assert_eq!(arena.chunk_primary(alloc, i), want[0], "chunk {i} primary");
                for s in 0..=n {
                    assert_eq!(
                        arena.chunk_contains(alloc, i, s),
                        want.contains(&s),
                        "membership of node {s} in chunk {i}"
                    );
                }
                // Interning is stable: asking again yields the same id.
                assert_eq!(gid, arena.group_of(alloc, i));
            }
            // Re-interning the same decision yields the same alloc id.
            assert_eq!(arena.alloc_ring(start, width, repl), alloc);
        }
        // Each distinct group is stored once: the arena can never hold
        // more than one entry per (primary, replication-level) pair.
        assert!(
            arena.n_groups() <= n * n,
            "{} groups interned over {n} nodes",
            arena.n_groups()
        );
    });
}

#[test]
fn prop_weighted_fair_station_conserves_work_and_bytes() {
    // Drive the weighted-fair station directly with random concurrent
    // trains: whatever the interleaving, (a) every frame that arrives
    // departs, (b) the server's busy integral equals the total dedicated
    // service (work conservation, within 1 ns rounding per train), and
    // (c) no train finishes before its own dedicated service could.
    check("weighted-fair conservation", 60, |g| {
        use wfpred::sim::FairStation;
        let n = g.usize(1, 12);
        let mut trains: Vec<(u64, u64, u64, u64)> = (0..n)
            .map(|_| {
                (
                    g.u64(0, 2_000_000),       // arrival ns
                    g.u64(1, 40),              // units (frames)
                    g.u64(1, 1_000_000),       // dedicated service ns
                    g.u64(1, 4 * 1024 * 1024), // weight (bytes)
                )
            })
            .collect();
        trains.sort_unstable();

        let mut fq: FairStation<usize> = FairStation::new();
        // At most one live announcement, exactly like the engine keeps at
        // most one cancellable completion event per fair station: the
        // time returned by `arrive` supersedes (cancels) the previous one.
        let mut pending: Option<SimTime> = None;
        let mut completions: Vec<(usize, u64)> = Vec::new(); // (train, at ns)
        let mut next_arrival = 0usize;
        loop {
            // Next event: the earlier of next arrival and announced
            // completion (completions first on ties, like a scheduler
            // firing the earlier-scheduled event).
            let arr = trains.get(next_arrival).map(|t| t.0);
            match (arr, pending) {
                (Some(a), Some(c)) if SimTime::from_ns(a) >= c => {
                    let (item, next) = fq.complete(c);
                    completions.push((item, c.as_ns()));
                    pending = next;
                }
                (Some(a), _) => {
                    let (at, units, svc, weight) = trains[next_arrival];
                    debug_assert_eq!(a, at);
                    let t = fq.arrive(
                        SimTime::from_ns(at),
                        next_arrival,
                        SimTime::from_ns(svc),
                        units,
                        weight,
                        0,
                    );
                    pending = Some(t);
                    next_arrival += 1;
                }
                (None, Some(c)) => {
                    let (item, next) = fq.complete(c);
                    completions.push((item, c.as_ns()));
                    pending = next;
                }
                (None, None) => break,
            }
        }
        let end = completions.iter().map(|&(_, t)| t).max().unwrap_or(0);
        fq.finish(SimTime::from_ns(end));

        let total_units: u64 = trains.iter().map(|t| t.1).sum();
        let total_svc: u64 = trains.iter().map(|t| t.2).sum();
        assert_eq!(fq.stats.arrivals, total_units, "every frame arrives");
        assert_eq!(fq.stats.departures, total_units, "every frame departs");
        assert_eq!(completions.len(), trains.len(), "every train completes");
        let slack = trains.len() as u64 + 1;
        assert!(
            fq.stats.busy_ns >= total_svc.saturating_sub(slack)
                && fq.stats.busy_ns <= total_svc + slack,
            "work conservation: busy {} vs Σ svc {}",
            fq.stats.busy_ns,
            total_svc
        );
        for &(item, at) in &completions {
            let (arrival, _, svc, _) = trains[item];
            assert!(
                at + 2 >= arrival + svc,
                "train {item} finished at {at}, before its dedicated service \
                 ({arrival} + {svc}) could"
            );
        }
    });
}

#[test]
fn prop_virtual_time_fair_station_matches_reference() {
    // The O(log m) virtual-time server and the retained O(m) linear-scan
    // reference (`RefFairStation`) implement the same GPS arithmetic over
    // different data structures. Drive both in lockstep over randomized
    // train mixes — clustered and simultaneous arrivals, zero-service and
    // zero-weight trains, single-train busy periods — and demand
    // *bit-identical* behavior: every announced completion time, every
    // completion (item and next announcement), every queue depth, and
    // every final station integral. No tolerances.
    check("virtual-time matches linear-scan reference", 80, |g| {
        use wfpred::sim::{FairStation, RefFairStation};
        let n = g.usize(1, 24);
        let mut trains: Vec<(u64, u64, u64, u64)> = (0..n)
            .map(|_| {
                // Cluster arrival instants so deep sharing and exact ties
                // both happen; leave gaps so busy periods also end.
                let at = if g.bool() {
                    g.u64(0, 10) * 150_000
                } else {
                    g.u64(0, 2_000_000)
                };
                let units = g.u64(1, 40);
                let svc = g.u64(0, 1_000_000); // zero-service trains included
                let weight = if g.u64(0, 9) == 0 { 0 } else { g.u64(1, 4 * 1024 * 1024) };
                (at, units, svc, weight)
            })
            .collect();
        trains.sort_unstable();

        let mut fast: FairStation<usize> = FairStation::new();
        let mut slow: RefFairStation<usize> = RefFairStation::new();
        let mut pending: Option<SimTime> = None;
        let mut next_arrival = 0usize;
        let mut end = 0u64;
        loop {
            let arr = trains.get(next_arrival).map(|t| t.0);
            match (arr, pending) {
                (Some(a), Some(c)) if SimTime::from_ns(a) >= c => {
                    let (fi, fnext) = fast.complete(c);
                    let (si, snext) = slow.complete(c);
                    assert_eq!(fi, si, "completion order diverged at {c}");
                    assert_eq!(fnext, snext, "next announcement diverged after {c}");
                    end = end.max(c.as_ns());
                    pending = fnext;
                }
                (Some(a), _) => {
                    let (at, units, svc, weight) = trains[next_arrival];
                    debug_assert_eq!(a, at);
                    let now = SimTime::from_ns(at);
                    let svc = SimTime::from_ns(svc);
                    let tf = fast.arrive(now, next_arrival, svc, units, weight, 0);
                    let ts = slow.arrive(now, next_arrival, svc, units, weight, 0);
                    assert_eq!(
                        tf, ts,
                        "announced completion diverged on arrival {next_arrival}"
                    );
                    assert_eq!(fast.queue_len(), slow.queue_len(), "queue depth diverged");
                    pending = Some(tf);
                    next_arrival += 1;
                }
                (None, Some(c)) => {
                    let (fi, fnext) = fast.complete(c);
                    let (si, snext) = slow.complete(c);
                    assert_eq!(fi, si, "completion order diverged at {c}");
                    assert_eq!(fnext, snext, "next announcement diverged after {c}");
                    end = end.max(c.as_ns());
                    pending = fnext;
                }
                (None, None) => break,
            }
        }
        fast.finish(SimTime::from_ns(end));
        slow.finish(SimTime::from_ns(end));
        assert_eq!(fast.stats.busy_ns, slow.stats.busy_ns, "busy integral");
        assert_eq!(fast.stats.qlen_ns, slow.stats.qlen_ns, "queue-length integral");
        assert_eq!(fast.stats.max_qlen, slow.stats.max_qlen, "max queue depth");
        assert_eq!(fast.stats.arrivals, slow.stats.arrivals);
        assert_eq!(fast.stats.departures, slow.stats.departures);
        assert_eq!(fast.stats.departures, trains.iter().map(|t| t.1).sum::<u64>());
        assert!(!fast.is_busy() && !slow.is_busy(), "both drained");
    });
}

#[test]
fn prop_bulk_path_is_work_conserving() {
    // On arbitrary workloads the bulk path may shift individual message
    // completions (partial last frames, train serialization under
    // incast), but it must conserve work exactly — identical bytes,
    // frames, storage, busy integrals — and keep turnaround within the
    // per-message cut-through slack.
    check("bulk path work conservation", 30, |g| {
        let wl = random_workload(g, 4);
        if wl.validate().is_err() {
            return;
        }
        let cfg = random_config(g);
        let plat = Platform::paper_testbed();
        let bulk = simulate(&wl, &cfg, &plat); // coarse = aggregated
        let frames = simulate_fid(&wl, &cfg, &plat, Fidelity::coarse_per_frame());

        assert_eq!(bulk.net_bytes, frames.net_bytes);
        assert_eq!(bulk.net_frames, frames.net_frames);
        assert_eq!(bulk.stored_total(), frames.stored_total());
        assert_eq!(bulk.tasks.len(), frames.tasks.len());
        // Superseded weighted-fair completions are cancelled at the engine
        // (they never count as processed events), so the bulk path's event
        // count is bounded by per-message chains alone. On zero-data
        // workloads (every message a single control frame) aggregation
        // saves nothing, so allow frame-count slack; any data frames at
        // all put the bulk path far below the per-frame count.
        assert!(bulk.events <= frames.events + bulk.net_frames);
        assert!(
            frames.events_cancelled == 0,
            "the per-frame path never cancels announcements"
        );

        // Busy integrals are exact under aggregation (train service =
        // exact sum of per-frame services).
        let (tb, tf) = (bulk.turnaround.as_ns() as f64, frames.turnaround.as_ns() as f64);
        for (h, (a, b)) in bulk.util.nic.iter().zip(frames.util.nic.iter()).enumerate() {
            for (x, y, side) in [(a.0, b.0, "out"), (a.1, b.1, "in")] {
                let (bx, by) = (x * tb, y * tf);
                assert!(
                    (bx - by).abs() < 10.0 + 1e-6 * by.abs(),
                    "host {h} {side}-NIC busy integral {bx} vs {by}"
                );
            }
        }

        let diff = (tb - tf).abs();
        assert!(
            diff <= 0.05 * tf + 80e6,
            "turnaround diverged: bulk {} vs per-frame {}",
            bulk.turnaround,
            frames.turnaround
        );
    });
}

#[test]
fn prop_detailed_at_least_as_slow_as_coarse() {
    // The detailed protocol only adds work (rounds, handshakes,
    // mux overhead ≥ 0). Heterogeneity/jitter can make hosts faster and
    // randomized placement or stagger can accidentally balance load
    // better than the round-robin cursor — disable the perturbation
    // knobs and compare pure added-work fidelity.
    check("detail slower", 15, |g| {
        let wl = random_workload(g, 3);
        if wl.validate().is_err() {
            return;
        }
        let cfg = random_config(g);
        let plat = Platform::paper_testbed();
        let coarse = simulate(&wl, &cfg, &plat).turnaround;
        let fid = Fidelity {
            hetero_sigma: 0.0,
            jitter_sigma: 0.0,
            random_placement: false,
            stagger_mean: SimTime::ZERO,
            ..Fidelity::detailed(g.u64(0, 1 << 30))
        };
        let detailed = simulate_fid(&wl, &cfg, &plat, fid).turnaround;
        assert!(detailed >= coarse, "detailed {detailed} < coarse {coarse} — protocol removed work?");
    });
}

#[test]
fn prop_fingerprint_invariant_under_file_and_task_reorder() {
    // The service cache key must be canonical over workload layout: a
    // random permutation of the file array (with task references
    // remapped) and of the task array is the same evaluation point.
    use wfpred::service::fingerprint;
    check("fingerprint reorder-invariant", 48, |g| {
        let wl = random_workload(g, 4);
        let cfg = random_config(g);
        let plat = Platform::paper_testbed();
        let fid = Fidelity::coarse();
        let base = fingerprint(&wl, &cfg, &plat, &fid);

        let nf = wl.files.len();
        let mut new_index: Vec<usize> = (0..nf).collect();
        g.rng().shuffle(&mut new_index);
        let mut files2: Vec<Option<FileSpec>> = vec![None; nf];
        for (old, f) in wl.files.iter().enumerate() {
            files2[new_index[old]] = Some(f.clone());
        }
        let mut wl2 = Workload::new(wl.name.clone());
        wl2.files = files2.into_iter().map(Option::unwrap).collect();
        let mut tasks2: Vec<TaskSpec> = wl
            .tasks
            .iter()
            .map(|t| {
                let mut t2 = t.clone();
                t2.reads = t.reads.iter().map(|&f| new_index[f]).collect();
                t2.writes = t.writes.iter().map(|&f| new_index[f]).collect();
                t2
            })
            .collect();
        g.rng().shuffle(&mut tasks2);
        wl2.tasks = tasks2;

        assert_eq!(
            base,
            fingerprint(&wl2, &cfg, &plat, &fid),
            "reordering files/tasks must not change the fingerprint"
        );
    });
}

#[test]
fn prop_fingerprint_distinct_across_single_knob_changes() {
    // Any single knob change — config axis, platform, fidelity, workload
    // content — must move the fingerprint.
    use wfpred::service::fingerprint;
    check("fingerprint knob-sensitive", 48, |g| {
        let wl = random_workload(g, 3);
        let cfg = random_config(g);
        let plat = Platform::paper_testbed();
        let fid = Fidelity::coarse();
        let base = fingerprint(&wl, &cfg, &plat, &fid);

        let mut variants: Vec<Config> = Vec::new();
        {
            let mut c = cfg.clone();
            c.chunk_size += Bytes::kb(1);
            variants.push(c);
        }
        {
            let mut c = cfg.clone();
            c.replication += 1;
            variants.push(c);
        }
        {
            let mut c = cfg.clone();
            c.io_window += 1;
            variants.push(c);
        }
        {
            let mut c = cfg.clone();
            c.n_app += 1;
            variants.push(c);
        }
        {
            let mut c = cfg.clone();
            c.n_storage += 1;
            variants.push(c);
        }
        {
            let mut c = cfg.clone();
            c.location_aware = !c.location_aware;
            variants.push(c);
        }
        {
            let mut c = cfg.clone();
            c.collocated = !c.collocated;
            variants.push(c);
        }
        {
            let mut c = cfg.clone();
            c.placement = match c.placement {
                Placement::RoundRobin => Placement::Local,
                Placement::Local => Placement::RoundRobin,
            };
            variants.push(c);
        }
        {
            let mut c = cfg.clone();
            c.faults = wfpred::model::FaultPlan::parse("crash=0@1").unwrap();
            variants.push(c);
        }
        for (k, v) in variants.iter().enumerate() {
            assert_ne!(
                base,
                fingerprint(&wl, v, &plat, &fid),
                "config knob {k} change must move the fingerprint"
            );
        }
        assert_ne!(base, fingerprint(&wl, &cfg, &Platform::paper_testbed_10g(), &fid));
        assert_ne!(base, fingerprint(&wl, &cfg, &plat, &Fidelity::coarse_per_frame()));
        assert_ne!(base, fingerprint(&wl, &cfg, &plat, &Fidelity::detailed(1)));
        let mut wl2 = wl.clone();
        wl2.files[0].size += Bytes(1);
        assert_ne!(base, fingerprint(&wl2, &cfg, &plat, &fid));
    });
}

#[test]
fn prop_empty_fault_plan_matches_baseline() {
    // The fault-injection machinery must be *free* when unused: a config
    // whose plan schedules nothing (any seed) takes the pre-fault code
    // path exactly. Run both configs in lockstep and demand bit-identical
    // reports — turnaround, event counts, byte/frame accounting, stored
    // bytes, every utilization integral — plus an identical service
    // fingerprint, so warm stores written before fault injection existed
    // keep answering. No tolerances.
    use wfpred::model::FaultPlan;
    use wfpred::service::fingerprint;
    check("empty fault plan is free", 30, |g| {
        let wl = random_workload(g, 3);
        if wl.validate().is_err() {
            return;
        }
        let cfg = random_config(g);
        let mut seeded = cfg.clone();
        seeded.faults = FaultPlan { seed: g.u64(0, 1 << 60), ..FaultPlan::default() };
        let plat = Platform::paper_testbed();
        let a = simulate(&wl, &cfg, &plat);
        let b = simulate(&wl, &seeded, &plat);

        assert_eq!(a.turnaround, b.turnaround, "empty plan shifted turnaround");
        assert_eq!(a.events, b.events, "empty plan created or removed events");
        assert_eq!(a.events_cancelled, b.events_cancelled);
        assert_eq!(a.net_bytes, b.net_bytes);
        assert_eq!(a.net_frames, b.net_frames);
        assert_eq!(a.stored, b.stored);
        assert_eq!(a.tasks.len(), b.tasks.len());
        assert_eq!(a.ops.len(), b.ops.len());
        for (x, y) in a.ops.iter().zip(b.ops.iter()) {
            assert_eq!((x.start, x.end), (y.start, y.end), "op interval moved");
        }
        assert_eq!(a.util.manager_util.to_bits(), b.util.manager_util.to_bits());
        assert_eq!(a.util.manager_mean_qlen.to_bits(), b.util.manager_mean_qlen.to_bits());
        for (h, (x, y)) in a.util.storage.iter().zip(b.util.storage.iter()).enumerate() {
            assert_eq!(x.0.to_bits(), y.0.to_bits(), "storage {h} utilization");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "storage {h} qlen");
        }
        for (h, (x, y)) in a.util.nic.iter().zip(b.util.nic.iter()).enumerate() {
            assert_eq!(x.0.to_bits(), y.0.to_bits(), "host {h} out-NIC utilization");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "host {h} in-NIC utilization");
        }
        for (h, (x, y)) in a.util.nic_qlen.iter().zip(b.util.nic_qlen.iter()).enumerate() {
            assert_eq!(x.0.to_bits(), y.0.to_bits(), "host {h} out-NIC qlen integral");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "host {h} in-NIC qlen integral");
        }
        assert_eq!(a.util.links.len(), b.util.links.len());
        for (l, (x, y)) in a.util.links.iter().zip(b.util.links.iter()).enumerate() {
            assert_eq!(x.0.to_bits(), y.0.to_bits(), "link {l} utilization");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "link {l} qlen");
        }
        for rep in [&a, &b] {
            assert_eq!(rep.fault_retries, 0);
            assert_eq!(rep.fault_failovers, 0);
            assert_eq!(rep.fault_timeouts, 0);
            assert_eq!(rep.fault_msgs_dropped, 0);
            assert_eq!(rep.fault_work_lost, 0);
            assert_eq!(rep.unrecoverable_ops, 0);
            assert_eq!(rep.failed_tasks, 0);
            assert!(!rep.unrecoverable());
        }
        let fid = Fidelity::coarse();
        assert_eq!(
            fingerprint(&wl, &cfg, &plat, &fid),
            fingerprint(&wl, &seeded, &plat, &fid),
            "an empty plan must not move the service fingerprint"
        );
    });
}

#[test]
fn prop_noop_probe_and_recorder_are_bit_identical() {
    // The flight recorder must observe, never participate: attaching the
    // recording probe yields the *same prediction*, bit for bit, as the
    // probe-free path (`simulate_fid` compiles the no-op probe away).
    // Lockstep over random workloads × configs × fidelity tiers, every
    // float compared by bit pattern, no tolerances — and the span log the
    // recorder kept must explain the whole turnaround (exact critical-path
    // tiling over the component classes).
    use wfpred::model::simulate_traced;
    use wfpred::trace::critical_path;
    check("recording probe is invisible", 25, |g| {
        let wl = random_workload(g, 3);
        if wl.validate().is_err() {
            return;
        }
        let cfg = random_config(g);
        let plat = Platform::paper_testbed();
        let fid = if g.bool() {
            Fidelity::coarse()
        } else {
            Fidelity::detailed(g.u64(0, 1 << 40))
        };
        let a = simulate_fid(&wl, &cfg, &plat, fid.clone());
        let (b, rec) = simulate_traced(&wl, &cfg, &plat, fid);

        assert_eq!(a.turnaround, b.turnaround, "tracing shifted turnaround");
        assert_eq!(a.events, b.events, "tracing created or removed events");
        assert_eq!(a.events_cancelled, b.events_cancelled);
        assert_eq!(a.net_bytes, b.net_bytes);
        assert_eq!(a.net_frames, b.net_frames);
        assert_eq!(a.stored, b.stored);
        assert_eq!(a.tasks.len(), b.tasks.len());
        assert_eq!(a.ops.len(), b.ops.len());
        for (x, y) in a.ops.iter().zip(b.ops.iter()) {
            assert_eq!((x.start, x.end), (y.start, y.end), "op interval moved");
        }
        assert_eq!(a.util.manager_util.to_bits(), b.util.manager_util.to_bits());
        assert_eq!(a.util.manager_mean_qlen.to_bits(), b.util.manager_mean_qlen.to_bits());
        for (h, (x, y)) in a.util.storage.iter().zip(b.util.storage.iter()).enumerate() {
            assert_eq!(x.0.to_bits(), y.0.to_bits(), "storage {h} utilization");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "storage {h} qlen");
        }
        for (h, (x, y)) in a.util.nic.iter().zip(b.util.nic.iter()).enumerate() {
            assert_eq!(x.0.to_bits(), y.0.to_bits(), "host {h} out-NIC utilization");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "host {h} in-NIC utilization");
        }
        for (h, (x, y)) in a.util.nic_qlen.iter().zip(b.util.nic_qlen.iter()).enumerate() {
            assert_eq!(x.0.to_bits(), y.0.to_bits(), "host {h} out-NIC qlen integral");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "host {h} in-NIC qlen integral");
        }
        assert_eq!(a.util.links.len(), b.util.links.len());
        for (l, (x, y)) in a.util.links.iter().zip(b.util.links.iter()).enumerate() {
            assert_eq!(x.0.to_bits(), y.0.to_bits(), "link {l} utilization");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "link {l} qlen");
        }

        // The recorder closed at the run's turnaround and its span log
        // decomposes it exactly.
        assert_eq!(rec.turnaround, b.turnaround.as_ns(), "recorder horizon");
        let attr = critical_path(&rec);
        assert!(attr.tiles_exactly(), "attributed segments must tile [0, turnaround]");
        assert_eq!(
            attr.totals().iter().sum::<u64>(),
            rec.turnaround,
            "class totals sum to turnaround"
        );
    });
}

#[test]
fn prop_faulty_runs_are_deterministic_and_account_consistently() {
    // A non-empty plan is a point of the configuration space like any
    // other: the same plan must reproduce byte-identical predictions and
    // failure accounting, and the accounting must be self-consistent
    // (every task either finishes or is counted failed; stalls from
    // control-path loss are the only third outcome, and only when links
    // are lossy).
    use wfpred::model::{Crash, FaultPlan, Straggler};
    check("faulty runs deterministic", 20, |g| {
        let wl = random_workload(g, 3);
        if wl.validate().is_err() {
            return;
        }
        let cfg = random_config(g);
        let n_hosts = cfg.n_hosts();
        let mut plan = FaultPlan { seed: g.u64(0, 1 << 40), ..FaultPlan::default() };
        for _ in 0..g.usize(0, 2) {
            plan.crashes.push(Crash {
                storage: g.usize(0, cfg.n_storage - 1),
                at: SimTime::from_ms(g.u64(0, 2_000)),
            });
        }
        for _ in 0..g.usize(0, 2) {
            plan.stragglers.push(Straggler {
                host: g.usize(0, n_hosts - 1),
                at: SimTime::from_ms(g.u64(0, 2_000)),
                slowdown: g.f64(0.1, 1.0),
            });
        }
        if plan.is_empty() {
            plan.crashes.push(Crash { storage: 0, at: SimTime::from_ms(g.u64(0, 1_000)) });
        }
        let faulted = cfg.clone().with_fault_plan(plan);
        let plat = Platform::paper_testbed();
        let a = simulate(&wl, &faulted, &plat);
        let b = simulate(&wl, &faulted, &plat);

        assert_eq!(a.turnaround, b.turnaround, "same plan, same turnaround");
        assert_eq!(a.events, b.events);
        assert_eq!(a.net_bytes, b.net_bytes);
        assert_eq!(a.fault_retries, b.fault_retries);
        assert_eq!(a.fault_failovers, b.fault_failovers);
        assert_eq!(a.fault_timeouts, b.fault_timeouts);
        assert_eq!(a.fault_work_lost, b.fault_work_lost);
        assert_eq!(a.unrecoverable_ops, b.unrecoverable_ops);
        assert_eq!(a.failed_tasks, b.failed_tasks);

        // Crash/straggler plans have no lossy links, so nothing is ever
        // dropped. A task finishes, fails, or — when its producer failed
        // and its inputs never commit — stalls unreleased; never more
        // than the workload holds.
        assert_eq!(a.fault_msgs_dropped, 0);
        let resolved = a.tasks.len() + a.failed_tasks as usize;
        assert!(resolved <= wl.tasks.len(), "{resolved} resolved of {} tasks", wl.tasks.len());
        if a.unrecoverable_ops == 0 {
            assert_eq!(a.failed_tasks, 0);
            assert_eq!(a.tasks.len(), wl.tasks.len(), "no failures ⇒ everything finishes");
        } else {
            assert!(a.failed_tasks > 0, "unrecoverable ops must fail their tasks");
        }
        if a.failed_tasks > 0 {
            assert!(a.unrecoverable_ops > 0, "tasks only fail via unrecoverable ops");
        }
    });
}

#[test]
fn prop_delta_resim_matches_cold() {
    // The incremental re-simulation contract, end to end: capture is
    // bit-identical to a cold run; resuming a single-knob neighbor —
    // when the stage-fingerprint prefix admits it — is bit-identical to
    // cold-simulating that neighbor (reports AND fault ledgers, via
    // Debug-string equality: f64 Debug is shortest-round-trip, so equal
    // strings ⇒ equal bits); a changed fault plan always invalidates the
    // whole prefix. Swept across fault plans and fidelity modes.
    use wfpred::model::{DeltaBase, FaultPlan};
    check("delta resim bit-identity", 35, |g| {
        let wl = random_workload(g, 3);
        if wl.validate().is_err() {
            return;
        }
        let n_app = g.usize(1, 4);
        let n_storage = g.usize(2, 6);
        let mut base =
            Config::partitioned(n_app, n_storage, Bytes::kb(*g.choose(&[256, 1024])));
        base.stripe_width = g.usize(1, n_storage);
        base.replication = g.u64(1, 2.min(n_storage as u64)) as u32;
        let plan_txt = *g.choose(&["", "crash=0@1", "seed=5;slow=1@0.5x2.0"]);
        if !plan_txt.is_empty() {
            let plan = FaultPlan::parse(plan_txt).expect("plan parses");
            if plan.validate(n_storage, base.n_hosts()).is_err() {
                return;
            }
            base = base.with_fault_plan(plan);
        }
        if base.validate().is_err() {
            return;
        }
        let fid = match g.u64(0, 2) {
            0 => Fidelity::coarse(),
            1 => Fidelity::coarse_per_frame(),
            _ => Fidelity::detailed(g.u64(0, 1 << 32)),
        };
        let plat = Platform::paper_testbed();

        // Capture is the cold path plus snapshots — same answer, always.
        let cold_base = simulate_fid(&wl, &base, &plat, fid.clone());
        let (captured, dbase) = DeltaBase::capture(&wl, &base, &plat, fid.clone());
        assert_eq!(
            format!("{cold_base:?}"),
            format!("{captured:?}"),
            "capture must not perturb the simulation"
        );

        // Single-knob neighbor: stripe / replication / chunk / window.
        let mut nb = base.clone();
        match g.u64(0, 3) {
            0 => nb.stripe_width = g.usize(1, n_storage),
            1 => nb.replication = g.u64(1, n_storage as u64) as u32,
            2 => nb.chunk_size = Bytes::kb(*g.choose(&[256, 512, 1024, 2048])),
            _ => nb.io_window = g.usize(1, 16),
        }
        if nb.validate().is_err() {
            return;
        }
        let cold_nb = simulate_fid(&wl, &nb, &plat, fid.clone());
        if let Some(r) = dbase.resume(&wl, &nb) {
            assert_eq!(
                format!("{cold_nb:?}"),
                format!("{:?}", r.report),
                "delta warm-start must be bit-identical to the cold run"
            );
            let n_stages = dbase.stage_fps().len() as u32;
            assert_eq!(
                r.outcome.stages_skipped + r.outcome.stages_replayed,
                n_stages,
                "skip/replay accounting must tile the stage list"
            );
            assert!(r.outcome.stages_skipped >= 1, "a hit always skips at least one stage");
            for ck in &r.checkpoints {
                assert_eq!(ck.fp, dbase.stage_fps()[ck.stage as usize]);
            }
        }

        // A different fault plan (never one of the base choices above)
        // perturbs the shared context hash, so no prefix survives.
        let other = nb.clone().with_fault_plan(FaultPlan::parse("crash=1@2").expect("plan"));
        if other.validate().is_ok() {
            assert!(
                dbase.resume(&wl, &other).is_none(),
                "a changed fault plan must invalidate the whole prefix"
            );
        }
    });
}

#[test]
fn prop_star_fabric_matches_reference() {
    // The routed fabric path collapsed to its star shape — one source
    // out-NIC feeding one fair hop, zero core links — and the retained
    // single-pair oracle (`RefStarFabric`) are the same protocol over
    // different plumbing. Drive both in lockstep over randomized train
    // mixes (clustered arrivals, zero-service trains, short tail frames,
    // zero weights, analytic tail waits) and demand *bit-identical*
    // behavior: every pending event, every step, every delivery, every
    // queue depth, and every final station integral. No tolerances.
    check("star fabric path matches single-pair oracle", 80, |g| {
        use wfpred::sim::fabric::{FabricPath, TrainSpec};
        use wfpred::sim::RefStarFabric;
        let mk_spec = |g: &mut Gen| {
            let units = g.u64(1, 24);
            let unit = g.u64(0, 50_000);
            let tail = if unit == 0 { 0 } else { g.u64(0, unit) };
            TrainSpec {
                total: SimTime::from_ns(unit * (units - 1) + tail),
                first: SimTime::from_ns(if units == 1 { tail } else { unit }),
                unit: SimTime::from_ns(unit),
                units,
                weight: if g.u64(0, 9) == 0 { 0 } else { g.u64(1, 4 * 1024 * 1024) },
                tail_wait_ns: if g.bool() { 0 } else { g.u64(0, 10_000) },
            }
        };
        let n = g.usize(1, 16);
        let mut sends: Vec<(u64, TrainSpec, TrainSpec)> = (0..n)
            .map(|_| {
                let at = if g.bool() {
                    g.u64(0, 10) * 150_000
                } else {
                    g.u64(0, 2_000_000)
                };
                (at, mk_spec(&mut *g), mk_spec(&mut *g))
            })
            .collect();
        sends.sort_unstable_by_key(|s| s.0);

        let lat = SimTime::from_ns(g.u64(0, 200_000));
        let mut path = FabricPath::new(lat, 1);
        let mut oracle = RefStarFabric::new(lat);
        for &(at, out_spec, in_spec) in &sends {
            let now = SimTime::from_ns(at);
            let a = path.send(now, vec![out_spec, in_spec]);
            let b = oracle.send(now, out_spec, in_spec);
            assert_eq!(a, b, "message ids diverged");
        }
        let mut delivered = 0usize;
        for _ in 0..(8 * n + 16) {
            match (path.next(), oracle.next()) {
                (None, None) => break,
                (a, b) => assert_eq!(a, b, "pending event diverged"),
            }
            let sa = path.step();
            let sb = oracle.step();
            assert_eq!(sa, sb, "step diverged");
            assert_eq!(path.out_queue_len(), oracle.out_queue_len(), "out queue depth");
            assert_eq!(path.hop_queue_len(0), oracle.in_queue_len(), "in queue depth");
            if sa.delivered.is_some() {
                delivered += 1;
            }
        }
        assert!(path.is_idle() && oracle.is_idle(), "both mini-sims drained");
        assert_eq!(delivered, n, "every message delivered exactly once");
        let end = SimTime::from_ns(100_000_000_000);
        let fa = path.finish(end);
        let fb = oracle.finish(end);
        assert_eq!(fa.len(), fb.len());
        for (a, b) in fa.iter().zip(fb.iter()) {
            assert_eq!(a.busy_ns, b.busy_ns, "busy integral");
            assert_eq!(a.qlen_ns, b.qlen_ns, "queue-length integral");
            assert_eq!(a.max_qlen, b.max_qlen, "max queue depth");
            assert_eq!(a.arrivals, b.arrivals);
            assert_eq!(a.departures, b.departures);
        }
    });
}

#[test]
fn prop_topology_change_empties_warm_prefix_and_moves_fingerprints() {
    // The topology enters the delta layer's shared context hash and the
    // service fingerprint: on any workload/config, switching the star
    // for a rack layout must perturb *every* stage fingerprint (so the
    // warm-start prefix a `resume` could splice on is empty) and move
    // the memo key, and two different rack layouts must be distinct
    // points. Star itself hashes nothing, so pre-fabric fingerprints
    // stay valid — checked here by the explicit-star round trip.
    use wfpred::model::{stage_fingerprints, Topology};
    use wfpred::service::fingerprint;
    check("topology change empties the warm-start prefix", 30, |g| {
        let wl = random_workload(g, 3);
        if wl.validate().is_err() {
            return;
        }
        let cfg = random_config(g);
        let fid = Fidelity::coarse();
        let star = Platform::paper_testbed();
        let mut rack = star.clone();
        rack.topology = Topology::Rack {
            rack_size: g.usize(1, 64),
            oversub: g.u64(1, 64) as f64 / 4.0,
        };
        rack.validate().expect("generated rack layout is valid");

        let a = stage_fingerprints(&wl, &cfg, &star, &fid);
        let b = stage_fingerprints(&wl, &cfg, &rack, &fid);
        assert_eq!(a.len(), b.len(), "stage structure is topology-independent");
        assert!(!a.is_empty(), "a valid workload has at least one stage");
        for (s, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_ne!(x, y, "stage {s} fingerprint survived a topology change");
        }
        let prefix = a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count();
        assert_eq!(prefix, 0, "no warm-start prefix may survive a topology change");

        let key_star = fingerprint(&wl, &cfg, &star, &fid);
        let key_rack = fingerprint(&wl, &cfg, &rack, &fid);
        assert_ne!(key_star, key_rack, "memoized answers must not leak across topologies");

        // An explicitly-set star is the same point as the default star.
        let mut star2 = star.clone();
        star2.topology = Topology::Star;
        assert_eq!(key_star, fingerprint(&wl, &cfg, &star2, &fid));
        assert_eq!(a, stage_fingerprints(&wl, &cfg, &star2, &fid));

        // Distinct rack layouts are distinct points too.
        let mut rack2 = rack.clone();
        let Topology::Rack { rack_size, oversub } = rack.topology else { unreachable!() };
        rack2.topology = Topology::Rack { rack_size: rack_size + 1, oversub };
        assert_ne!(key_rack, fingerprint(&wl, &cfg, &rack2, &fid));
        assert_ne!(b[0], stage_fingerprints(&wl, &cfg, &rack2, &fid)[0]);
    });
}
